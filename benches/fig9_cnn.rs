//! Figure 9 (a/b): 5-layer CNN, DP vs MP vs SOYBEAN as image size and
//! filter count trade off (batch 256).
//!
//! 9(a): 6×6 images, 2048 filters — weights dominate, MP/SOYBEAN win.
//! 9(b): 24×24 images, 512 filters — activations dominate, DP beats MP;
//! SOYBEAN matches or beats both by cutting different layers differently.
//! Run with `cargo bench --bench fig9_cnn`.

use std::time::Duration;

use soybean::figures;
use soybean::sim::SimConfig;
use soybean::util::bench::time_it;

fn main() {
    let cfg = SimConfig::default();
    for (label, image, filters) in [
        ("fig9a: image=6px  filters=2048", 6usize, 2048usize),
        ("fig9b: image=24px filters=512", 24, 512),
    ] {
        let (table, pts) = figures::fig9(image, filters, &cfg);
        println!("{table}");
        let at8 = |s: &str| pts.iter().find(|p| p.devices == 8 && p.strategy == s).unwrap();
        let (dp, mp, soy) = (at8("DP"), at8("MP"), at8("SOYBEAN"));
        println!(
            "  8-dev comm: DP {:.1} MB, MP {:.1} MB, SOY {:.1} MB (winner: {})",
            dp.comm_bytes as f64 / 1e6,
            mp.comm_bytes as f64 / 1e6,
            soy.comm_bytes as f64 / 1e6,
            if dp.runtime_s < mp.runtime_s { "DP over MP" } else { "MP over DP" },
        );
        let m = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(figures::fig9(image, filters, &cfg));
        });
        println!("  [{label}] pipeline: {:.2} ms/iter ({} iters)\n", m.mean_ms(), m.iters);
    }
}
