//! Transformer planner micro-benchmark: the first post-paper workload
//! class, planned end to end.
//!
//! Asserts (the ISSUE-2 acceptance criteria):
//!
//! - an 8-device (`k = 3`) plan for the 4-layer encoder stack
//!   ([`TransformerConfig::micro`]) completes in **< 1 s**;
//! - the one-cut DP matches the pre-LUT reference bit for bit on the
//!   1-layer configuration, and matches **brute force** on the enumerable
//!   1-layer attention core ([`attention_probe`]);
//! - SOYBEAN's plan moves no more bytes than stock data parallelism, and
//!   the simulator meters exactly the plan's Theorem-1 cost.
//!
//! Results are written to `BENCH_transformer.json` (same schema as
//! `BENCH_planner.json`; DESIGN.md §Perf) so CI can diff the trajectory
//! against the committed baseline.
//!
//! Run with `cargo bench --bench transformer_micro`.

use std::time::Duration;

use soybean::graph::bfs_levels;
use soybean::models::{attention_probe, transformer, TransformerConfig};
use soybean::planner::bruteforce::brute_force;
use soybean::planner::{classify, try_k_cut, try_one_cut, reference::one_cut_reference, Planner, PlanFamily};
use soybean::sim::{try_simulate, try_simulate_classic_dp, SimConfig};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== transformer planner micro-benchmarks ==");
    let mut log = BenchLog::new("transformer_micro");

    let one_layer = TransformerConfig { layers: 1, ..TransformerConfig::micro() };
    let workloads: Vec<(&str, soybean::Graph)> = vec![
        ("encoder-1L", transformer(&one_layer)),
        ("encoder-4L", transformer(&TransformerConfig::micro())),
    ];

    // Optimality pins before any timing: brute force on the enumerable
    // 1-layer attention core, reference equivalence on both stacks.
    let probe = attention_probe();
    let bf = brute_force(&probe, 100_000);
    let dp = try_one_cut(&probe).unwrap();
    assert_eq!(dp.cost, bf.cost, "one-cut diverged from brute force on the attention core");
    for (name, g) in &workloads {
        let fast = try_one_cut(g).unwrap();
        let slow = one_cut_reference(g);
        assert_eq!(fast.cost, slow.cost, "{name}: cost diverged from reference");
        assert_eq!(fast.tiles, slow.tiles, "{name}: tiles diverged from reference");
    }

    for (name, g) in &workloads {
        let lv = bfs_levels(g);
        let m = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(try_one_cut(g).unwrap());
        });
        let mut cols = vec![
            ("ms", format!("{:.2}", m.mean_ms())),
            ("ops", g.ops.len().to_string()),
            ("levels", lv.levels.len().to_string()),
            ("maxwidth", lv.max_width().to_string()),
        ];
        if *name == "encoder-1L" {
            // Reference timing only on the small stack — the pre-LUT
            // implementation re-derives Eq. (2) per state visit and is
            // deliberately slow on the 4-layer boundary spaces.
            let m_ref = time_it(1, Duration::from_millis(300), || {
                std::hint::black_box(one_cut_reference(g));
            });
            let speedup = m_ref.mean.as_secs_f64() / m.mean.as_secs_f64();
            cols.push(("ref_ms", format!("{:.2}", m_ref.mean_ms())));
            cols.push(("speedup", format!("{speedup:.1}")));
        }
        log.row(&format!("one_cut/{name}"), &cols);
    }

    // The acceptance gate: a full 8-device plan for the 4-layer stack
    // (solved once up front for the cost/classification row; the timing
    // loop then measures fresh solves).
    let g4 = &workloads[1].1;
    let plan = try_k_cut(g4, 3).unwrap();
    let m = time_it(1, Duration::from_millis(500), || {
        std::hint::black_box(try_k_cut(g4, 3).unwrap());
    });
    log.row(
        "k_cut3/encoder-4L",
        &[
            ("ms", format!("{:.2}", m.mean_ms())),
            ("cost_bytes", plan.total_cost().to_string()),
            ("class", classify(g4, &plan.tiles).to_string()),
        ],
    );
    assert!(
        m.mean.as_secs_f64() < 1.0,
        "8-device transformer plan took {:.0} ms (target < 1 s)",
        m.mean_ms()
    );

    // Byte-level sanity against stock data parallelism + the simulator's
    // one-theory contract (metered bytes == Theorem-1 cost).
    let cfg = SimConfig::default();
    let dp_plan = Planner::try_plan(g4, 3, PlanFamily::DataParallel).unwrap();
    assert!(
        plan.total_cost() <= dp_plan.total_cost(),
        "SOYBEAN plan moves more bytes than DP ({} > {})",
        plan.total_cost(),
        dp_plan.total_cost()
    );
    let soy_sim = try_simulate(g4, &plan, &cfg).unwrap();
    assert_eq!(soy_sim.total_bytes, plan.total_cost(), "sim bytes != plan cost");
    let dp_sim = try_simulate_classic_dp(g4, &dp_plan, &cfg).unwrap();
    log.row(
        "simulate/encoder-4L",
        &[
            ("soy_mb", format!("{:.2}", soy_sim.total_bytes as f64 / 1e6)),
            ("dp_mb", format!("{:.2}", dp_sim.total_bytes as f64 / 1e6)),
            ("soy_step_ms", format!("{:.2}", soy_sim.step_s * 1e3)),
            ("dp_step_ms", format!("{:.2}", dp_sim.step_s * 1e3)),
        ],
    );

    log.write_json("BENCH_transformer.json").expect("writing BENCH_transformer.json");
    println!("wrote BENCH_transformer.json");
}
