//! Observability micro-benchmark (ISSUE-8 acceptance gates):
//!
//! - **tracing is near-free**: the same 8-device (`k = 3`) 4-layer
//!   encoder step from `exec_micro` is timed with `ExecOptions::trace`
//!   off and on, and the traced run must stay within **5%** of the
//!   untraced one (min-of-iterations, the least noisy statistic; the
//!   gate is overridable via `OBS_MICRO_MAX_OVERHEAD` for noisy shared
//!   runners, mirroring `PLANNER_MICRO_MIN_SPEEDUP`);
//! - **bytes reconcile**: the traced step's metered collective markers
//!   sum to the executor's collective meter, which equals the plan's
//!   Theorem-1 total bit for bit;
//! - **the drift report exists**: [`soybean::obs::calibrate`] joins the
//!   measured spans against the engine's modeled step and the resulting
//!   [`soybean::obs::CalibrationReport`] is dumped to `obs_report.json`
//!   (with the measured Chrome trace beside it as
//!   `obs_trace_measured.json`) for the CI artifact upload.
//!
//! Results go to `BENCH_obs.json` (the `BENCH_planner.json` schema) for
//! the CI perf-trajectory diff against `ci/baselines/BENCH_obs.json`.
//!
//! Run with `cargo bench --bench obs_micro`.

use std::time::Duration;

use soybean::graph::seed_values;
use soybean::lower::try_lower;
use soybean::models::{transformer, TransformerConfig};
use soybean::obs::{calibrate, measured_trace_json};
use soybean::planner::try_k_cut;
use soybean::sim::{try_run_program, SimConfig, Topology};
use soybean::spmd::{execute_with, ExecOptions};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== observability micro-benchmarks ==");
    let mut log = BenchLog::new("obs_micro");
    let cfg = SimConfig::default();

    // The exec_micro workload: the 8-device 4-layer encoder.
    let bench_cfg = TransformerConfig {
        batch: 8,
        seq: 32,
        d_model: 64,
        heads: 4,
        d_ff: 128,
        layers: 4,
        classes: 64,
    };
    let g = transformer(&bench_cfg);
    let plan = try_k_cut(&g, 3).unwrap();
    let program = try_lower(&g, &plan, &cfg).unwrap();
    let init = seed_values(&g, 42);
    let topo = Topology::from_sim(&cfg, 3);

    // Reconciliation gate before timing: one traced step's metered
    // collective markers == executor meter == Theorem-1.
    let traced_opts = ExecOptions::default().trace(true);
    let report =
        execute_with(&g, &plan, &program, &init, &traced_opts).expect("traced execution");
    let trace = report.trace.as_ref().expect("tracing was on");
    assert_eq!(
        trace.collective_bytes(),
        report.instr_bytes,
        "metered span bytes != executor collective meter"
    );
    assert_eq!(report.instr_bytes, plan.total_cost(), "executor meter != Theorem-1");
    assert!(!trace.spans.is_empty(), "traced step produced no spans");

    // The drift report for the same step, dumped beside the bench JSON.
    let modeled = try_run_program(&program, &topo).expect("modeled run");
    let cal = calibrate(&g, &program, &topo, &modeled, trace);
    assert_eq!(cal.metered_span_bytes, plan.total_cost());
    assert!(cal.collectives.iter().all(|c| c.measured_bytes == c.modeled_bytes));
    print!("{cal}");
    cal.write_json("obs_report.json").expect("writing obs_report.json");
    std::fs::write("obs_trace_measured.json", measured_trace_json(trace, &program))
        .expect("writing obs_trace_measured.json");

    // The overhead gate: tracing off vs on over the identical step.
    let plain_opts = ExecOptions::default();
    let m_off = time_it(1, Duration::from_millis(200), || {
        std::hint::black_box(
            execute_with(&g, &plan, &program, &init, &plain_opts).expect("execution"),
        );
    });
    let m_on = time_it(1, Duration::from_millis(200), || {
        std::hint::black_box(
            execute_with(&g, &plan, &program, &init, &traced_opts).expect("execution"),
        );
    });
    let overhead = m_on.min.as_secs_f64() / m_off.min.as_secs_f64() - 1.0;
    log.row(
        "obs/exec-untraced",
        &[("ms", format!("{:.2}", m_off.mean_ms())), ("iters", m_off.iters.to_string())],
    );
    log.row(
        "obs/exec-traced",
        &[
            ("ms", format!("{:.2}", m_on.mean_ms())),
            ("iters", m_on.iters.to_string()),
            ("overhead_pct", format!("{:.2}", overhead * 100.0)),
            ("spans", trace.spans.len().to_string()),
        ],
    );
    log.row(
        "obs/drift-report",
        &[
            ("step_ratio", format!("{:.4}", cal.step_ratio)),
            ("kernel_rows", cal.kernels.len().to_string()),
            ("collective_rows", cal.collectives.len().to_string()),
            ("metered_MB", format!("{:.3}", cal.metered_span_bytes as f64 / 1e6)),
        ],
    );

    // Shared CI runners time noisily; the committed default is the
    // ISSUE-8 5% bound, overridable the way PLANNER_MICRO_MIN_SPEEDUP is.
    let max_overhead = std::env::var("OBS_MICRO_MAX_OVERHEAD")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.05);
    assert!(
        overhead <= max_overhead,
        "tracing overhead {:.2}% exceeds the {:.2}% gate \
         (untraced min {:.2} ms, traced min {:.2} ms; override via OBS_MICRO_MAX_OVERHEAD)",
        overhead * 100.0,
        max_overhead * 100.0,
        m_off.min.as_secs_f64() * 1e3,
        m_on.min.as_secs_f64() * 1e3
    );

    log.write_json("BENCH_obs.json").expect("writing BENCH_obs.json");
    println!("wrote BENCH_obs.json, obs_report.json, obs_trace_measured.json");
}
