//! Lowering + discrete-event engine micro-benchmark (ISSUE-3 acceptance
//! gates):
//!
//! - lowering **plus** event-engine simulation of the 8-device (`k = 3`)
//!   4-layer transformer plan completes in **< 1 s**;
//! - the lowered per-instruction bytes sum exactly to the plan's
//!   Theorem-1 cost, and the engine's per-tier meter matches
//!   `sim::try_simulate` bit for bit;
//! - the engine's step time stays inside the documented envelope of the
//!   analytic model (DESIGN.md §Lowering).
//!
//! Results go to `BENCH_engine.json` (the `BENCH_planner.json` schema) for
//! the CI perf-trajectory diff, and the transformer run's Chrome-trace
//! timeline to `engine_trace.json` — CI uploads it as an artifact; load it
//! in `chrome://tracing` or Perfetto to inspect the schedule.
//!
//! Run with `cargo bench --bench engine_micro`.

use std::time::Duration;

use soybean::lower::try_lower;
use soybean::models::{alexnet, transformer, TransformerConfig};
use soybean::planner::try_k_cut;
use soybean::sim::{chrome_trace_json, try_run_program, try_simulate, SimConfig, Topology};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== SPMD lowering + event-engine micro-benchmarks ==");
    let mut log = BenchLog::new("engine_micro");
    let cfg = SimConfig::default();
    let topo = Topology::from_sim(&cfg, 3);

    let workloads: Vec<(&str, soybean::Graph)> = vec![
        ("alexnet", alexnet(64)),
        ("encoder-4L", transformer(&TransformerConfig::micro())),
    ];

    let mut gate = None;
    for (name, g) in &workloads {
        let plan = try_k_cut(g, 3).unwrap();
        let p = try_lower(g, &plan, &cfg).unwrap();
        let sim = try_simulate(g, &plan, &cfg).expect("plan simulates");

        // One-theory contract before any timing: lowered bytes == plan's
        // Theorem-1 cost == per-tier simulator meter.
        assert_eq!(p.total_bytes(), plan.total_cost(), "{name}: lowered bytes != plan cost");
        assert_eq!(p.tier_bytes(), sim.tier_bytes, "{name}: tier meter != sim");

        let r = try_run_program(&p, &topo).unwrap();
        assert_eq!(r.compute_s, sim.compute_s, "{name}: compute model diverged");
        let slack = cfg.latency * r.transfers_per_device as f64 + 1e-9;
        assert!(
            r.step_s >= sim.compute_s && r.step_s <= sim.compute_s + sim.comm_s + slack,
            "{name}: engine step {} outside the documented envelope",
            r.step_s
        );

        let m_lower = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(try_lower(g, &plan, &cfg).unwrap());
        });
        let m_engine = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(try_run_program(&p, &topo).unwrap());
        });
        log.row(
            &format!("lower/{name}"),
            &[
                ("ms", format!("{:.2}", m_lower.mean_ms())),
                ("instrs", p.programs[0].instrs.len().to_string()),
                ("collectives", p.transfers.len().to_string()),
                ("bytes", p.total_bytes().to_string()),
            ],
        );
        log.row(
            &format!("engine/{name}"),
            &[
                ("ms", format!("{:.2}", m_engine.mean_ms())),
                ("step_ms", format!("{:.3}", r.step_s * 1e3)),
                ("sim_step_ms", format!("{:.3}", sim.step_s * 1e3)),
                ("compute_ms", format!("{:.3}", r.compute_s * 1e3)),
                ("events", r.trace.len().to_string()),
            ],
        );

        if *name == "encoder-4L" {
            gate = Some(m_lower.mean.as_secs_f64() + m_engine.mean.as_secs_f64());
            // The artifact CI uploads: the 8-device transformer timeline.
            std::fs::write("engine_trace.json", chrome_trace_json(&r, &topo))
                .expect("writing engine_trace.json");
            println!("wrote engine_trace.json ({} events)", r.trace.len());
        }
    }

    let gate = gate.expect("transformer workload ran");
    assert!(
        gate < 1.0,
        "lowering + event simulation of the 8-device transformer took {:.0} ms (target < 1 s)",
        gate * 1e3
    );

    log.write_json("BENCH_engine.json").expect("writing BENCH_engine.json");
    println!("wrote BENCH_engine.json");
}
