//! Pipeline strategy micro-benchmark (PR-10 acceptance gates).
//!
//! Builds 2-stage pipelined strategies for the 4-layer encoder on the
//! two-tier 2×4 machine ([`Topology::two_tier`]) and scores the full
//! strategy portfolio ([`plan_strategy`]) for vgg16 and the encoder.
//! The gates assert the pipeline axis actually closed:
//!
//! - **schedule structure** — neither scheduled step exceeds the
//!   serial-stage reference, 1F1B stays within the documented 1.5×
//!   envelope of GPipe (neither schedule dominates on step time — the
//!   in-flight cap can delay tail forwards, which is why the portfolio
//!   scores both; `tools/proto/pipeline_mirror.py` pins the envelope),
//!   and 1F1B's peak activation stash is **strictly** smaller than
//!   GPipe's on stage 0 (the cap is the whole point of 1F1B);
//! - **portfolio dominance** — `plan_strategy`'s winner is never worse
//!   than the pure-tiling candidate (structural: tiling is in the
//!   portfolio and ties go to it) and **strictly better on at least
//!   one** of vgg16 / encoder-4L: tiling must all-reduce every gradient
//!   across the ethernet tier while a pipeline ships only boundary
//!   activations over it;
//! - **one-theory contract** — the lowered pipelined program's bytes
//!   equal [`Strategy::total_cost`] bit for bit;
//! - planning both models stays under the wall-clock budget.
//!
//! Results go to `BENCH_pipeline.json` (the `BENCH_planner.json`
//! schema) for the CI perf-trajectory diff.
//!
//! Run with `cargo bench --bench pipeline_micro`.

use std::time::Duration;

use soybean::graph::bfs_levels;
use soybean::lower::try_lower_strategy;
use soybean::models::{transformer, vgg16, TransformerConfig};
use soybean::planner::{pick_microbatches, plan_strategy, stage_cuts, Schedule, Strategy};
use soybean::sim::{try_simulate_strategy, Topology};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== pipeline strategy micro-benchmarks ==");
    let mut log = BenchLog::new("pipeline_micro");
    let topo = Topology::two_tier(3);
    let cfg = topo.to_sim_config();

    // Gate 1: schedule structure — the 4-layer encoder, 2 stages × 4
    // microbatches, both schedules over the same cells.
    let g = transformer(&TransformerConfig::micro());
    let m = pick_microbatches(&g, 4);
    assert_eq!(m, 4, "encoder-4L stopped being 4-microbatchable");
    let levels = bfs_levels(&g);
    let cuts = stage_cuts(&g, &levels, 2, 2, m).unwrap();
    let gpipe = Strategy::try_build(&g, &cuts, 3, m, Schedule::GPipe).unwrap();
    let f1b = Strategy::try_build(&g, &cuts, 3, m, Schedule::OneF1B).unwrap();

    // One-theory contract across the stage axis.
    let pp = try_lower_strategy(&g, &gpipe, &cfg).unwrap();
    assert_eq!(pp.total_bytes(), gpipe.total_cost(), "lowered bytes != strategy cost");

    let r_gpipe = try_simulate_strategy(&gpipe, &topo).unwrap();
    let r_f1b = try_simulate_strategy(&f1b, &topo).unwrap();
    assert!(
        r_gpipe.step_s <= r_gpipe.serial_step_s + 1e-12,
        "pipelined step {} worse than the serial-stage reference {}",
        r_gpipe.step_s,
        r_gpipe.serial_step_s
    );
    assert!(
        r_f1b.step_s <= r_f1b.serial_step_s + 1e-12,
        "1F1B step {} worse than the serial-stage reference {}",
        r_f1b.step_s,
        r_f1b.serial_step_s
    );
    // Neither schedule dominates on step time (the in-flight cap can
    // delay tail forwards; the portfolio scores both), but 1F1B stays
    // within the envelope the scheduler mirror pins.
    assert!(
        r_f1b.step_s <= r_gpipe.step_s * 1.5 + 1e-9,
        "1F1B step {} outside the 1.5x GPipe envelope ({})",
        r_f1b.step_s,
        r_gpipe.step_s
    );
    // The in-flight cap is the whole point of 1F1B: stage 0 stashes at
    // most its pipeline depth (2) while GPipe stashes all m microbatches.
    assert!(
        r_f1b.peak_stash[0] < r_gpipe.peak_stash[0],
        "1F1B peak stash {} not strictly below GPipe's {}",
        r_f1b.peak_stash[0],
        r_gpipe.peak_stash[0]
    );
    log.row(
        "schedule/encoder-4L",
        &[
            ("gpipe_step_ms", format!("{:.3}", r_gpipe.step_s * 1e3)),
            ("f1b_step_ms", format!("{:.3}", r_f1b.step_s * 1e3)),
            ("serial_step_ms", format!("{:.3}", r_gpipe.serial_step_s * 1e3)),
            ("gpipe_bubble", format!("{:.3}", r_gpipe.bubble_fraction)),
            ("f1b_bubble", format!("{:.3}", r_f1b.bubble_fraction)),
            ("gpipe_stash0", r_gpipe.peak_stash[0].to_string()),
            ("f1b_stash0", r_f1b.peak_stash[0].to_string()),
        ],
    );

    // Gate 2: portfolio dominance on the two-tier machine.
    let workloads: Vec<(&str, soybean::Graph)> = vec![
        ("vgg16", vgg16(32)),
        ("encoder-4L", transformer(&TransformerConfig::micro())),
    ];
    let mut strictly_better = Vec::new();
    let mut total_plan_s = 0.0;
    for (name, g) in &workloads {
        let m_plan = time_it(0, Duration::from_millis(1), || {
            std::hint::black_box(plan_strategy(g, 8, &topo).unwrap());
        });
        total_plan_s += m_plan.min.as_secs_f64();

        let sp = plan_strategy(g, 8, &topo).unwrap();
        assert!(
            sp.step_s <= sp.tiling_step_s,
            "{name}: portfolio winner {} lost to its own tiling seed {}",
            sp.step_s,
            sp.tiling_step_s
        );
        if sp.step_s < sp.tiling_step_s {
            strictly_better.push(*name);
        }
        log.row(
            &format!("strategy/{name}"),
            &[
                ("ms", format!("{:.2}", m_plan.mean_ms())),
                ("chosen", sp.chosen.to_string()),
                ("stages", sp.strategy.stage_count().to_string()),
                ("ubatches", sp.strategy.microbatches.to_string()),
                ("tiling_step_ms", format!("{:.3}", sp.tiling_step_s * 1e3)),
                ("step_ms", format!("{:.3}", sp.step_s * 1e3)),
                ("speedup", format!("{:.4}", sp.tiling_step_s / sp.step_s)),
                ("bubble", format!("{:.3}", sp.report.bubble_fraction)),
            ],
        );
        for s in &sp.scores {
            println!(
                "  {name}: candidate {:<10} step {:.3} ms, {:.1} MB",
                s.name,
                s.step_s * 1e3,
                s.total_bytes as f64 / 1e6
            );
        }
    }

    // The PR-10 acceptance gate: on the two-tier 2×4 preset a pipelined
    // strategy strictly beats pure tiling on at least one model.
    assert!(
        !strictly_better.is_empty(),
        "no pipelined strategy strictly beat pure tiling on the two-tier preset"
    );
    println!("pipelining strictly better on: {}", strictly_better.join(", "));

    assert!(
        total_plan_s < 20.0,
        "strategy planning of both models took {:.0} ms (target < 20 s)",
        total_plan_s * 1e3
    );

    log.write_json("BENCH_pipeline.json").expect("writing BENCH_pipeline.json");
    println!("wrote BENCH_pipeline.json");
}
