//! Planner micro-benchmarks: the offline cost SOYBEAN adds to training.
//!
//! §3: "the runtime cost of the dataflow transformation can be amortized"
//! — but only if planning is fast. Targets (see DESIGN.md §Perf): a full
//! 8-device plan for VGG-16 in < 1 s.
//!
//! Run with `cargo bench --bench planner_micro`.

use std::time::Duration;

use soybean::graph::bfs_levels;
use soybean::models::{alexnet, cnn5, mlp, vgg16, MlpConfig};
use soybean::planner::{k_cut, one_cut};
use soybean::util::bench::{report_row, time_it};

fn main() {
    println!("== planner micro-benchmarks ==");
    let workloads: Vec<(&str, soybean::Graph)> = vec![
        ("mlp-4x8192", mlp(&MlpConfig::fig8(512, 8192))),
        ("mlp-e2e", mlp(&MlpConfig::e2e())),
        ("cnn5", cnn5(256, 6, 4, 2048, 10)),
        ("alexnet", alexnet(256)),
        ("vgg16", vgg16(64)),
    ];

    for (name, g) in &workloads {
        let lv = bfs_levels(g);
        let m = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(one_cut(g));
        });
        report_row(
            &format!("one_cut/{name}"),
            &[
                ("ms", format!("{:.2}", m.mean_ms())),
                ("ops", g.ops.len().to_string()),
                ("levels", lv.levels.len().to_string()),
                ("maxwidth", lv.max_width().to_string()),
            ],
        );
    }

    for (name, g) in &workloads {
        let m = time_it(1, Duration::from_millis(500), || {
            std::hint::black_box(k_cut(g, 3));
        });
        report_row(&format!("k_cut3/{name}"), &[("ms", format!("{:.2}", m.mean_ms()))]);
        if *name == "vgg16" {
            assert!(m.mean.as_secs_f64() < 1.0, "VGG 8-device plan exceeded 1s target");
        }
    }
}
