//! Planner micro-benchmarks: the offline cost SOYBEAN adds to training.
//!
//! §3: "the runtime cost of the dataflow transformation can be amortized"
//! — but only if planning is fast. Targets (see DESIGN.md §Perf): a full
//! 8-device plan for VGG-16 in < 1 s, and the LUT-backed one-cut at least
//! 5× faster than the pre-LUT reference on VGG-16.
//!
//! Each `one_cut/*` row times both implementations, asserts they return
//! the identical optimal cost, and reports the speedup. Results are also
//! written to `BENCH_planner.json` (machine-readable; schema documented in
//! DESIGN.md §Perf) so the trajectory is tracked across PRs.
//!
//! Run with `cargo bench --bench planner_micro`.

use std::time::Duration;

use soybean::graph::bfs_levels;
use soybean::models::{alexnet, cnn5, mlp, vgg16, MlpConfig};
use soybean::planner::{try_k_cut, try_one_cut, reference::one_cut_reference};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== planner micro-benchmarks ==");
    let workloads: Vec<(&str, soybean::Graph)> = vec![
        ("mlp-4x8192", mlp(&MlpConfig::fig8(512, 8192))),
        ("mlp-e2e", mlp(&MlpConfig::e2e())),
        ("cnn5", cnn5(256, 6, 4, 2048, 10)),
        ("alexnet", alexnet(256)),
        ("vgg16", vgg16(64)),
    ];
    let mut log = BenchLog::new("planner_micro");

    for (name, g) in &workloads {
        let lv = bfs_levels(g);
        // Bit-identical equivalence is part of the bench contract: a fast
        // wrong planner is not a speedup. Solve once for the check; the
        // timed loops below only measure.
        let fast = try_one_cut(g).unwrap();
        let slow = one_cut_reference(g);
        assert_eq!(fast.cost, slow.cost, "{name}: cost diverged");
        let m = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(try_one_cut(g).unwrap());
        });
        let m_ref = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(one_cut_reference(g));
        });
        let speedup = m_ref.mean.as_secs_f64() / m.mean.as_secs_f64();
        log.row(
            &format!("one_cut/{name}"),
            &[
                ("ms", format!("{:.2}", m.mean_ms())),
                ("ref_ms", format!("{:.2}", m_ref.mean_ms())),
                ("speedup", format!("{speedup:.1}")),
                ("ops", g.ops.len().to_string()),
                ("levels", lv.levels.len().to_string()),
                ("maxwidth", lv.max_width().to_string()),
            ],
        );
        if *name == "vgg16" {
            // Target: >= 5x (DESIGN.md §Perf). Shared CI runners have noisy
            // neighbors, so CI relaxes the gate via env var and tracks the
            // real number through BENCH_planner.json instead.
            let min_speedup: f64 = std::env::var("PLANNER_MICRO_MIN_SPEEDUP")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(5.0);
            assert!(
                speedup >= min_speedup,
                "one_cut/vgg16 only {speedup:.1}x faster than the reference (floor {min_speedup}x)"
            );
        }
    }

    for (name, g) in &workloads {
        let m = time_it(1, Duration::from_millis(500), || {
            std::hint::black_box(try_k_cut(g, 3).unwrap());
        });
        log.row(&format!("k_cut3/{name}"), &[("ms", format!("{:.2}", m.mean_ms()))]);
        if *name == "vgg16" {
            assert!(m.mean.as_secs_f64() < 1.0, "VGG 8-device plan exceeded 1s target");
        }
    }

    log.write_json("BENCH_planner.json").expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json ({} rows)", 2 * workloads.len());
}
