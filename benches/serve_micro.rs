//! Serving-runtime micro-benchmark (the PR-7 acceptance gates):
//!
//! - **correctness first**: the engine's reassembled per-request outputs
//!   for the 4-layer encoder match `eval_serial` on the request's own
//!   graph within 1e-5, across unit counts that straddle the padding
//!   boundary;
//! - **dynamic batching pays**: under the same 8-client closed loop,
//!   sustained throughput with coalescing enabled (`max_batch = 16`)
//!   strictly beats the batch-1 configuration of the same engine;
//! - **the plan cache holds**: after warming every padded batch extent,
//!   the measured window re-plans nothing (`cache_hit_rate == 1`).
//!
//! Results go to `BENCH_serve.json` (the `BENCH_planner.json` schema)
//! for the CI perf-trajectory diff. Total budget < 10 s wall-clock.
//!
//! Run with `cargo bench --bench serve_micro`.

use std::time::{Duration, Instant};

use soybean::graph::{eval_serial, max_rel_err, seed_values, Graph};
use soybean::models::{transformer, TransformerConfig};
use soybean::serve::{ServeEngine, ServeOptions, ServeRequest};
use soybean::sim::Topology;
use soybean::util::bench::BenchLog;
use soybean::{ServeStats, Session};

/// One serving unit = two encoder sequences (the transformer builder
/// requires an even batch).
fn encoder(u: usize) -> Graph {
    transformer(&TransformerConfig {
        batch: 2 * u,
        seq: 16,
        d_model: 32,
        heads: 4,
        d_ff: 64,
        layers: 4,
        classes: 32,
    })
}

const OUTPUT: &str = "head.out";
const DEVICES: usize = 4;
const MAX_BATCH: usize = 16;
const SEED: u64 = 42;

fn launch(session: &Session, max_batch: usize) -> ServeEngine {
    let base_init = seed_values(session.graph(), SEED);
    ServeEngine::launch(
        session,
        encoder,
        &base_init,
        ServeOptions::default()
            .max_batch(max_batch)
            .max_linger(Duration::from_micros(500))
            .output(OUTPUT),
    )
    .expect("engine launch")
}

/// A well-formed `u`-unit request plus its serial expectation.
fn request_and_expected(feeds: &[String], u: usize, seed: u64) -> (ServeRequest, Vec<f32>) {
    let g = encoder(u);
    let init = seed_values(&g, seed);
    let mut req = ServeRequest::new(u);
    for name in feeds {
        let t = g.tensors.iter().find(|t| &t.name == name).expect("feed tensor");
        req = req.feed(name.clone(), init[t.id].clone().expect("feed value"));
    }
    let serial = eval_serial(&g, &init).expect("serial evaluation");
    let out = g.tensors.iter().find(|t| t.name == OUTPUT).expect("output tensor");
    (req, serial[out.id].clone())
}

/// Closed-loop load: `clients` threads each fire 1-unit requests
/// back-to-back for `window`; returns the engine's steady-state stats.
fn sustain(engine: &ServeEngine, feeds: &[String], clients: usize, window: Duration) -> ServeStats {
    // Warm every padded batch extent coalescing can produce (multiples
    // of the device alignment up to MAX_BATCH), so the measured window
    // is pure cache hits — and, since the blocked kernels landed, the
    // same warmup pass absorbs the one-time per-shape schedule search
    // (exec_micro's cold/warm split, applied to serving: each extent's
    // first execution populates the global ScheduleCache, so the
    // measured window is steady-state on both caches). A 1-unit request
    // is legal on every engine and pads to the smallest aligned extent.
    let (req, _) = request_and_expected(feeds, 1, SEED);
    engine.client().infer(req).expect("warmup");
    for extent in (DEVICES..=MAX_BATCH).step_by(DEVICES) {
        let (req, _) = request_and_expected(feeds, extent, SEED);
        // The batch-1 engine rejects multi-unit requests — fine, its
        // only padded extent is already warm.
        let _ = engine.client().infer(req);
    }
    engine.reset_stats();

    std::thread::scope(|scope| {
        for c in 0..clients {
            let client = engine.client();
            let (req, expected) = request_and_expected(feeds, 1, SEED + c as u64);
            scope.spawn(move || {
                let start = Instant::now();
                while start.elapsed() < window {
                    let resp = client.infer(req.clone()).expect("inference under load");
                    debug_assert!(max_rel_err(&resp.outputs[OUTPUT], &expected) <= 1e-5);
                }
            });
        }
    });
    engine.stats()
}

fn main() {
    println!("== serving runtime micro-benchmarks ==");
    let mut log = BenchLog::new("serve_micro");
    let session =
        Session::build(encoder(DEVICES), DEVICES, &Topology::p2_8xlarge()).expect("session");

    // Phase 1 — the differential gate: per-request reassembly matches
    // the serial interpreter across the padding boundary.
    let engine = launch(&session, MAX_BATCH);
    let feeds: Vec<String> = engine.feed_names().to_vec();
    let client = engine.client();
    let mut worst = 0.0f64;
    for (i, u) in [1usize, 2, 3, 4, 5, 8].into_iter().enumerate() {
        let (req, expected) = request_and_expected(&feeds, u, SEED + 100 + i as u64);
        let resp = client.infer(req).expect("inference");
        assert_eq!(resp.units, u);
        let err = max_rel_err(&resp.outputs[OUTPUT], &expected);
        assert!(err <= 1e-5, "u={u}: diverged from serial by {err:e}");
        worst = worst.max(err);
    }
    println!("differential gate: worst per-request rel err {worst:.3e} (tolerance 1e-5)");

    // Phase 2 — sustained closed-loop throughput, batched vs batch-1.
    let clients = 8;
    let window = Duration::from_millis(1500);
    let batched = sustain(&engine, &feeds, clients, window);
    engine.shutdown();

    let engine1 = launch(&session, 1);
    let serial = sustain(&engine1, &feeds, clients, window);
    engine1.shutdown();

    let mean_batch = |s: &ServeStats| {
        let (mut units, mut n) = (0u64, 0u64);
        for (sz, count) in &s.batch_histogram {
            units += (*sz as u64) * count;
            n += count;
        }
        if n == 0 { 0.0 } else { units as f64 / n as f64 }
    };
    log.row(
        "serve/encoder-4L-batched",
        &[
            ("ms", format!("{:.3}", batched.p50_latency.as_secs_f64() * 1e3)),
            ("p95_ms", format!("{:.3}", batched.p95_latency.as_secs_f64() * 1e3)),
            ("rps", format!("{:.1}", batched.throughput_rps)),
            ("requests", batched.requests.to_string()),
            ("mean_batch_units", format!("{:.2}", mean_batch(&batched))),
            ("cache_hit_rate", format!("{:.3}", batched.cache_hit_rate)),
        ],
    );
    log.row(
        "serve/encoder-4L-batch1",
        &[
            ("ms", format!("{:.3}", serial.p50_latency.as_secs_f64() * 1e3)),
            ("p95_ms", format!("{:.3}", serial.p95_latency.as_secs_f64() * 1e3)),
            ("rps", format!("{:.1}", serial.throughput_rps)),
            ("requests", serial.requests.to_string()),
            ("cache_hit_rate", format!("{:.3}", serial.cache_hit_rate)),
        ],
    );

    // The acceptance gates.
    assert!(batched.requests > 0 && serial.requests > 0, "load loop produced no traffic");
    assert_eq!(batched.cache_hit_rate, 1.0, "batched window re-planned after warmup");
    assert_eq!(serial.cache_hit_rate, 1.0, "batch-1 window re-planned after warmup");
    assert!(
        batched.throughput_rps > serial.throughput_rps,
        "dynamic batching must beat batch-1: {:.1} rps vs {:.1} rps",
        batched.throughput_rps,
        serial.throughput_rps
    );
    assert!(
        mean_batch(&batched) > 1.0,
        "coalescing never happened: mean batch {:.2} units",
        mean_batch(&batched)
    );

    log.write_json("BENCH_serve.json").expect("writing BENCH_serve.json");
    println!("wrote BENCH_serve.json");
}
