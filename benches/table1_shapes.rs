//! Table 1: does matrix shape affect computation performance?
//!
//! The paper ran the 4-layer MLP's matrices (8192×8192 weights) uncut vs
//! cut into SOYBEAN's tiles on a *single* GPU and found the tiled shapes
//! ~1.5× faster (cuBLAS algorithm selection). This bench reruns the
//! experiment with **real PJRT CPU GEMMs** via the dynamic kernel path:
//! per batch size, the uncut layer GEMM vs the four 2-cut shards executed
//! back to back on one device. We report the measured CPU ratio next to
//! the paper's GPU ratio — same experiment, different BLAS.
//!
//! Run with `cargo bench --bench table1_shapes`.

use std::sync::Arc;
use std::time::Duration;

use soybean::runtime::{Client, HostTensor, KernelCache, KernelKind, KernelSig};
use soybean::util::bench::time_it;
use soybean::util::Rng;

/// Hidden size scaled down from the paper's 8192 (CPU GEMM at 8192³ takes
/// minutes per iteration; 2048 preserves the shape-selection effect).
const HIDDEN: usize = 2048;

fn gemm_time(cache: &KernelCache, m: usize, k: usize, n: usize, rng: &mut Rng) -> f64 {
    let sig = KernelSig {
        kind: KernelKind::MatMul { ta: false, tb: false },
        in_shapes: vec![vec![m, k], vec![k, n]],
    };
    let exe = cache.get(&sig).expect("compile");
    let x = HostTensor::from_vec(&[m, k], rng.normal_vec(m * k, 1.0));
    let w = HostTensor::from_vec(&[k, n], rng.normal_vec(k * n, 1.0));
    let meas = time_it(1, Duration::from_millis(400), || {
        std::hint::black_box(exe.run(&[x.clone(), w.clone()]).unwrap());
    });
    meas.min.as_secs_f64()
}

fn main() {
    let client = Arc::new(Client::cpu().expect("PJRT client"));
    let cache = KernelCache::new(client);
    let mut rng = Rng::new(0xBEEF);

    println!("== Table 1: single-device GEMM, uncut vs SOYBEAN 2-cut tiles ==");
    println!("   (hidden {HIDDEN}, 4 layers; paper used 8192 on GK210: 512→0.31/0.19s)");
    println!("{:>8} {:>14} {:>18} {:>8}", "batch", "uncut (ms)", "4 tiles (ms)", "ratio");
    for batch in [512usize, 1024, 2048] {
        // Uncut: one [batch, H] × [H, H] GEMM per layer (×4 layers).
        let uncut = 4.0 * gemm_time(&cache, batch, HIDDEN, HIDDEN, &mut rng);
        // SOYBEAN's RC 2-cut: four [batch/2, H] × [H, H/2] shards per
        // layer, all run sequentially on the same device (paper §6.3).
        let shard = gemm_time(&cache, batch / 2, HIDDEN, HIDDEN / 2, &mut rng);
        let tiled = 4.0 * 4.0 * shard;
        println!(
            "{batch:>8} {:>14.2} {:>18.2} {:>8.2}",
            uncut * 1e3,
            tiled * 1e3,
            uncut / tiled
        );
    }
    println!("\n(paper's GPU ratios: 1.63, 1.44, 1.55 — shape-dependent BLAS\n\
              selection; the CPU backend shows its own shape effect, reported\n\
              honestly above and fed into the simulator's EffModel)");
}
