//! Topology-aware planning micro-benchmark (ISSUE-4 acceptance gates).
//!
//! Plans vgg16 and the 4-layer transformer encoder for a 2×4 two-tier
//! machine (2 nodes of 4 GPUs: ethernet between nodes, a shared PCIe bus
//! inside — [`Topology::two_tier`]) **both ways**: the byte-objective flat
//! plan and [`try_plan_topology_aware`]'s simulator-scored plan. Each plan
//! is lowered to SPMD programs and scheduled by the discrete-event engine
//! on that topology, and the gates assert the loop actually closed:
//!
//! - the topology-aware plan's engine-simulated step is **never worse**
//!   than the flat plan's (structural: the flat plan is in the candidate
//!   portfolio and ties go to it), and **strictly better on at least one**
//!   of the two models;
//! - both plans keep the one-theory contract (lowered bytes equal the
//!   Theorem-1 total);
//! - planning both models both ways stays under the wall-clock budget.
//!
//! Results go to `BENCH_topology.json` (the `BENCH_planner.json` schema)
//! for the CI perf-trajectory diff.
//!
//! Run with `cargo bench --bench topology_micro`.

use std::time::Duration;

use soybean::lower::try_lower;
use soybean::models::{transformer, vgg16, TransformerConfig};
use soybean::planner::{try_k_cut, try_plan_topology_aware};
use soybean::sim::{try_run_program, Topology};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== topology-aware planning micro-benchmarks ==");
    let mut log = BenchLog::new("topology_micro");
    let topo = Topology::two_tier(3);
    let cfg = topo.to_sim_config();

    let workloads: Vec<(&str, soybean::Graph)> = vec![
        ("vgg16", vgg16(32)),
        ("encoder-4L", transformer(&TransformerConfig::micro())),
    ];

    let mut strictly_better = Vec::new();
    let mut total_plan_s = 0.0;
    for (name, g) in &workloads {
        let m_plan = time_it(0, Duration::from_millis(1), || {
            std::hint::black_box(try_plan_topology_aware(g, 8, &topo).unwrap());
        });
        total_plan_s += m_plan.min.as_secs_f64();

        let aware = try_plan_topology_aware(g, 8, &topo).unwrap();
        let flat = try_k_cut(g, 3).unwrap();

        // One-theory contract on both plans: lowered bytes == Theorem-1.
        let p_flat = try_lower(g, &flat, &cfg).unwrap();
        let p_aware = try_lower(g, &aware.plan, &cfg).unwrap();
        assert_eq!(p_flat.total_bytes(), flat.total_cost(), "{name}: flat bytes != plan");
        assert_eq!(p_aware.total_bytes(), aware.plan.total_cost(), "{name}: aware bytes != plan");

        // Engine-simulated steps on the two-tier topology — the bench
        // re-runs the exact pipeline the planner scored candidates with,
        // so the report's numbers must reproduce.
        let flat_step = try_run_program(&p_flat, &topo).unwrap().step_s;
        let aware_step = try_run_program(&p_aware, &topo).unwrap().step_s;
        assert_eq!(flat_step, aware.flat_step_s, "{name}: flat step not reproducible");
        assert_eq!(aware_step, aware.step_s, "{name}: aware step not reproducible");
        assert!(
            aware_step <= flat_step,
            "{name}: topology-aware step {aware_step} worse than flat {flat_step}"
        );
        if aware_step < flat_step {
            strictly_better.push(*name);
        }

        log.row(
            &format!("topology/{name}"),
            &[
                ("ms", format!("{:.2}", m_plan.mean_ms())),
                ("flat_step_ms", format!("{:.3}", flat_step * 1e3)),
                ("topo_step_ms", format!("{:.3}", aware_step * 1e3)),
                ("speedup", format!("{:.4}", flat_step / aware_step)),
                ("chosen", aware.chosen.to_string()),
                ("flat_bytes", flat.total_cost().to_string()),
                ("topo_bytes", aware.plan.total_cost().to_string()),
            ],
        );
        for s in &aware.scores {
            println!(
                "  {name}: candidate {:<14} step {:.3} ms, {:.1} MB",
                s.name,
                s.step_s * 1e3,
                s.total_bytes as f64 / 1e6
            );
        }
    }

    // The ISSUE-4 acceptance gate: on the two-tier 2×4 preset the
    // topology-aware plan is strictly faster on at least one model.
    assert!(
        !strictly_better.is_empty(),
        "topology-aware planning never strictly beat the flat plan on the two-tier preset"
    );
    println!("strictly better on: {}", strictly_better.join(", "));

    assert!(
        total_plan_s < 10.0,
        "topology-aware planning of both models took {:.0} ms (target < 10 s)",
        total_plan_s * 1e3
    );

    log.write_json("BENCH_topology.json").expect("writing BENCH_topology.json");
    println!("wrote BENCH_topology.json");
}
