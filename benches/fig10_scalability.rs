//! Figure 10 (a/b): AlexNet & VGG-16 speedup over single-device training
//! on 8 devices, swept over batch size.
//!
//! The paper's headline: SOYBEAN reaches >7× speedup on AlexNet at batch
//! 256 while data parallelism needs >1K to catch up; VGG tells the same
//! story. Run with `cargo bench --bench fig10_scalability`.

use std::time::Duration;

use soybean::figures;
use soybean::sim::SimConfig;
use soybean::util::bench::time_it;

fn main() {
    let cfg = SimConfig::default();

    let (table, rows) = figures::fig10("alexnet", &[64, 128, 256, 512, 1024, 2048], &cfg);
    println!("{table}");
    let at256 = rows.iter().find(|r| r.0 == 256).unwrap();
    let dp_catch = rows
        .iter()
        .find(|r| r.1 >= at256.2)
        .map(|r| r.0.to_string())
        .unwrap_or_else(|| ">2048".into());
    println!(
        "  AlexNet @256: SOYBEAN {:.2}x vs DP {:.2}x; DP reaches SOYBEAN's @256 speedup at batch {}\n",
        at256.2, at256.1, dp_catch
    );

    let (table, rows) = figures::fig10("vgg", &[16, 32, 64, 128, 256], &cfg);
    println!("{table}");
    for (b, dp, soy) in &rows {
        assert!(soy >= dp, "SOYBEAN slower than DP on VGG at batch {b}");
    }
    println!("  VGG: SOYBEAN ≥ DP at every batch size ✓");

    let m = time_it(0, Duration::from_millis(200), || {
        std::hint::black_box(figures::fig10("alexnet", &[256], &cfg));
    });
    println!("\n  [fig10] single-point pipeline: {:.2} ms/iter ({} iters)", m.mean_ms(), m.iters);
}
