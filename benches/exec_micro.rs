//! Threaded SPMD executor micro-benchmark (ISSUE-5 acceptance gates):
//!
//! - one **steady-state execute step** of the 8-device (`k = 3`) 4-layer
//!   transformer encoder — plan → lower → run on real `f32` shard buffers
//!   across 8 worker threads — completes in **< 2 s** wall-clock;
//! - the run is differentially checked on the spot: executor output ==
//!   serial interpreter within 1e-5 relative tolerance, and the
//!   executor's collective byte meter == the plan's Theorem-1 total bit
//!   for bit (docs/execution.md).
//!
//! Results go to `BENCH_exec.json` (the `BENCH_planner.json` schema) for
//! the CI perf-trajectory diff.
//!
//! Since ISSUE-6 this bench is also the **"fault hooks are free"** gate:
//! `execute` runs with fault injection disabled (`ExecOptions::default()`
//! — no fault plan, checksums always on), so CI's diff of this JSON
//! against the pre-fault-injection `ci/baselines/BENCH_exec.json` pins
//! that the injection hooks and watchdog plumbing cost the fault-free
//! path nothing beyond the committed noise threshold.
//!
//! The fast kernels' per-shape schedule search is a one-time cost: the
//! first step at a new shape set searches and memoizes, every later step
//! hits the [`ScheduleCache`]. The timed windows therefore warm one step
//! before measuring (steady state is what the gate bounds), and the cold,
//! search-inclusive first step is reported separately (`cold_ms`) after an
//! explicit cache clear — previously the warmup-less serial window silently
//! folded the search into its mean.
//!
//! Run with `cargo bench --bench exec_micro`.

use std::time::{Duration, Instant};

use soybean::graph::{eval_serial, seed_values, ScheduleCache};
use soybean::lower::try_lower;
use soybean::models::{transformer, TransformerConfig};
use soybean::planner::try_k_cut;
use soybean::sim::SimConfig;
use soybean::spmd::{execute, worst_divergence};
use soybean::util::bench::{time_it, BenchLog};

fn main() {
    println!("== threaded SPMD executor micro-benchmarks ==");
    let mut log = BenchLog::new("exec_micro");
    let cfg = SimConfig::default();

    // The bench workload: the 4-layer encoder topology at a width that
    // gives the kernels measurable work while staying CI-friendly.
    let bench_cfg = TransformerConfig {
        batch: 8,
        seq: 32,
        d_model: 64,
        heads: 4,
        d_ff: 128,
        layers: 4,
        classes: 64,
    };
    let g = transformer(&bench_cfg);
    let plan = try_k_cut(&g, 3).unwrap();
    let program = try_lower(&g, &plan, &cfg).unwrap();
    assert_eq!(program.total_bytes(), plan.total_cost(), "lowered bytes != plan cost");
    let init = seed_values(&g, 42);

    // The cold, search-inclusive first step: clear the global schedule
    // cache so this one timing pays every per-shape search, then never
    // clear again — all later windows measure the steady state.
    ScheduleCache::global().clear();
    let t0 = Instant::now();
    let report = execute(&g, &plan, &program, &init).expect("threaded execution");
    let cold = t0.elapsed();
    let schedules = ScheduleCache::global().len();

    // Correctness before timing: the differential gate on this config.
    let serial = eval_serial(&g, &init).unwrap();
    assert_eq!(report.instr_bytes, plan.total_cost(), "executor meter != Theorem-1");
    let (worst, tensor) = worst_divergence(&g, &report, &serial);
    assert!(worst <= 1e-5, "differential gate: diverged on `{tensor}` by {worst:e}");

    // Steady state: one warmup iteration before each measured window (the
    // cache is already warm, but the warmup also settles allocator state).
    let m_serial = time_it(1, Duration::from_millis(1), || {
        std::hint::black_box(eval_serial(&g, &init).expect("serial evaluation"));
    });
    let m_exec = time_it(1, Duration::from_millis(200), || {
        std::hint::black_box(execute(&g, &plan, &program, &init).expect("execution"));
    });
    log.row(
        "exec/encoder-4L",
        &[
            ("ms", format!("{:.2}", m_exec.mean_ms())),
            ("cold_ms", format!("{:.2}", cold.as_secs_f64() * 1e3)),
            ("schedules", schedules.to_string()),
            ("serial_ms", format!("{:.2}", m_serial.mean_ms())),
            ("devices", report.devices.to_string()),
            ("collective_MB", format!("{:.3}", report.instr_bytes as f64 / 1e6)),
            ("payload_MB", format!("{:.3}", report.payload_bytes as f64 / 1e6)),
            ("max_rel_err", format!("{worst:.3e}")),
        ],
    );

    // The acceptance gate (tightened from 10 s when the blocked kernels
    // landed): one steady-state executed step of the 8-device 4-layer
    // encoder stays under 2 s even on noisy shared runners.
    assert!(
        m_exec.mean.as_secs_f64() < 2.0,
        "8-device 4-layer encoder execute step took {:.0} ms (target < 2 s)",
        m_exec.mean_ms()
    );

    // The differential-harness config (rust/tests/differential.rs), as a
    // tracked row so its cost trend stays visible.
    let g_tiny = transformer(&TransformerConfig::tiny4());
    let plan_tiny = try_k_cut(&g_tiny, 3).unwrap();
    let program_tiny = try_lower(&g_tiny, &plan_tiny, &cfg).unwrap();
    let init_tiny = seed_values(&g_tiny, 42);
    let m_tiny = time_it(1, Duration::from_millis(200), || {
        std::hint::black_box(execute(&g_tiny, &plan_tiny, &program_tiny, &init_tiny).expect("execution"));
    });
    log.row("exec/encoder-4L-tiny", &[("ms", format!("{:.2}", m_tiny.mean_ms()))]);

    log.write_json("BENCH_exec.json").expect("writing BENCH_exec.json");
    println!("wrote BENCH_exec.json");
}
