//! Figure 8 (a/b/c): 4-layer MLP runtime & communication overhead for
//! DP/MP/SOYBEAN across 2–8 devices, three (batch, hidden) settings.
//!
//! Regenerates the paper's series through the planner + testbed simulator
//! and times the end-to-end plan+simulate pipeline (the part of SOYBEAN a
//! user actually waits for; it is amortized over all training iterations,
//! §3). Run with `cargo bench --bench fig8_mlp`.

use std::time::Duration;

use soybean::figures;
use soybean::sim::SimConfig;
use soybean::util::bench::time_it;

fn main() {
    let cfg = SimConfig::default();
    for (label, batch, hidden) in [
        ("fig8a: batch=512  hidden=8192", 512usize, 8192usize),
        ("fig8b: batch=2048 hidden=8192", 2048, 8192),
        ("fig8c: batch=2048 hidden=12288", 2048, 12288),
    ] {
        let (table, pts) = figures::fig8(batch, hidden, &cfg);
        println!("{table}");
        // Paper shape checks, reported inline.
        let at8 = |s: &str| pts.iter().find(|p| p.devices == 8 && p.strategy == s).unwrap();
        let (dp, mp, soy) = (at8("DP"), at8("MP"), at8("SOYBEAN"));
        println!(
            "  8-dev overhead/compute: DP {:.2}x  MP {:.2}x  SOY {:.2}x  | SOY speedup over DP: {:.2}x",
            dp.overhead_s / dp.compute_s,
            mp.overhead_s / mp.compute_s,
            soy.overhead_s / soy.compute_s,
            dp.runtime_s / soy.runtime_s
        );
        let m = time_it(1, Duration::from_millis(300), || {
            std::hint::black_box(figures::fig8(batch, hidden, &cfg));
        });
        println!("  [{label}] plan+simulate pipeline: {:.2} ms/iter ({} iters)\n", m.mean_ms(), m.iters);
    }
}
