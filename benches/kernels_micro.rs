//! Per-kernel micro-benchmark: blocked fast kernels vs the naive oracle
//! (the PR-9 acceptance gate).
//!
//! The workload shapes are not hand-picked: the bench scans the same
//! 4-layer encoder training graph `exec_micro` executes and ranks its
//! `MatMul` / `BatchedMatMul` shapes by total FLOP volume, so the gate is
//! tied to the shapes that actually dominate the executor's step time.
//! For each ranked shape the fast path (warm [`ScheduleCache`]) and the
//! naive oracle are timed back-to-back, reporting GFLOP/s and the
//! speedup ratio; outputs are cross-checked within [`KERNEL_ORACLE_TOL`]
//! before any timing is trusted.
//!
//! **Gate**: the top two matmul shapes must show ≥ 10× speedup over the
//! naive oracle (override with `KERNELS_MICRO_MIN_SPEEDUP` for unusual
//! runners). The batched-matmul and conv rows are reported un-gated —
//! their trend is tracked by the CI diff against
//! `ci/baselines/BENCH_kernels.json`.
//!
//! Row labels are rank-based (`kernel/mm-rank1`…), not shape-based, so the
//! label-seeded baseline stays valid if the encoder config shifts.
//!
//! Run with `cargo bench --bench kernels_micro`.

use std::time::Duration;

use soybean::graph::{
    apply_op_with, max_rel_err, Graph, KernelBackend, Op, OpKind, ScheduleCache, View, KERNEL_ORACLE_TOL,
};
use soybean::models::{transformer, TransformerConfig};
use soybean::util::bench::{time_it, BenchLog};
use soybean::util::rng::Rng;

/// One aggregated GEMM shape from the scanned graph.
#[derive(Debug, Clone, Copy, PartialEq)]
struct GemmShape {
    /// `0` for MatMul; the batch-group count for BatchedMatMul.
    groups: usize,
    m: usize,
    k: usize,
    n: usize,
    ta: bool,
    tb: bool,
    /// How many ops in the graph run this exact shape per step.
    count: usize,
}

impl GemmShape {
    fn flops_per_op(&self) -> f64 {
        2.0 * self.groups.max(1) as f64 * (self.m * self.k * self.n) as f64
    }
}

/// Scan a training graph and rank its GEMM shapes by per-step FLOP volume.
fn ranked_gemm_shapes(g: &Graph) -> (Vec<GemmShape>, Vec<GemmShape>) {
    let mut shapes: Vec<GemmShape> = Vec::new();
    for op in &g.ops {
        let (groups, ta, tb) = match op.kind {
            OpKind::MatMul { ta, tb } => (0, ta, tb),
            OpKind::BatchedMatMul { ta, tb } => (g.tensors[op.inputs[0]].shape[0], ta, tb),
            _ => continue,
        };
        let sa = &g.tensors[op.inputs[0]].shape;
        let sb = &g.tensors[op.inputs[1]].shape;
        let off = usize::from(groups > 0);
        let (m, k) = if ta { (sa[off + 1], sa[off]) } else { (sa[off], sa[off + 1]) };
        let n = if tb { sb[off] } else { sb[off + 1] };
        let probe = GemmShape { groups, m, k, n, ta, tb, count: 1 };
        match shapes.iter_mut().find(|s| {
            (s.groups, s.m, s.k, s.n, s.ta, s.tb) == (probe.groups, probe.m, probe.k, probe.n, probe.ta, probe.tb)
        }) {
            Some(s) => s.count += 1,
            None => shapes.push(probe),
        }
    }
    let mut mm: Vec<GemmShape> = shapes.iter().copied().filter(|s| s.groups == 0).collect();
    let mut bmm: Vec<GemmShape> = shapes.iter().copied().filter(|s| s.groups > 0).collect();
    let volume = |s: &GemmShape| s.flops_per_op() * s.count as f64;
    mm.sort_by(|a, b| volume(b).partial_cmp(&volume(a)).unwrap().then_with(|| (a.m, a.k, a.n).cmp(&(b.m, b.k, b.n))));
    bmm.sort_by(|a, b| volume(b).partial_cmp(&volume(a)).unwrap().then_with(|| (a.m, a.k, a.n).cmp(&(b.m, b.k, b.n))));
    (mm, bmm)
}

/// Time one shape on both backends (fast first warms the schedule cache
/// before its measured window via `time_it`'s warmup iteration) and return
/// `(fast_ms, naive_ms, gflops_fast)`.
fn bench_shape(shape: &GemmShape, rng: &mut Rng) -> (f64, f64, f64) {
    let (groups, m, k, n) = (shape.groups, shape.m, shape.k, shape.n);
    let (ar, ac) = if shape.ta { (k, m) } else { (m, k) };
    let (br, bc) = if shape.tb { (n, k) } else { (k, n) };
    let ga = groups.max(1);
    let a = rng.normal_vec(ga * ar * ac, 1.0);
    let b = rng.normal_vec(ga * br * bc, 1.0);
    let (kind, ashape, bshape, oshape) = if groups > 0 {
        (
            OpKind::BatchedMatMul { ta: shape.ta, tb: shape.tb },
            vec![groups, ar, ac],
            vec![groups, br, bc],
            vec![groups, m, n],
        )
    } else {
        (OpKind::MatMul { ta: shape.ta, tb: shape.tb }, vec![ar, ac], vec![br, bc], vec![m, n])
    };
    let g = Graph::default();
    let op = Op { id: 0, kind, inputs: vec![0, 0], outputs: vec![0], name: "bench".into() };
    let views = [View::full(&a, &ashape), View::full(&b, &bshape)];

    // Correctness before timing.
    let fast = apply_op_with(KernelBackend::Fast, &g, &op, &views, &oshape);
    let naive = apply_op_with(KernelBackend::Naive, &g, &op, &views, &oshape);
    let err = max_rel_err(&fast, &naive);
    assert!(err <= KERNEL_ORACLE_TOL, "{shape:?}: fast diverged from oracle by {err:e}");

    let m_fast = time_it(1, Duration::from_millis(100), || {
        std::hint::black_box(apply_op_with(KernelBackend::Fast, &g, &op, &views, &oshape));
    });
    let m_naive = time_it(1, Duration::from_millis(100), || {
        std::hint::black_box(apply_op_with(KernelBackend::Naive, &g, &op, &views, &oshape));
    });
    let gflops = shape.flops_per_op() / m_fast.mean.as_secs_f64() / 1e9;
    (m_fast.mean_ms(), m_naive.mean_ms(), gflops)
}

fn main() {
    println!("== blocked kernel micro-benchmarks (fast vs naive oracle) ==");
    let mut log = BenchLog::new("kernels_micro");
    let mut rng = Rng::new(0x4B4D_4943);

    // The exec_micro bench workload: rank its GEMM shapes by volume.
    let bench_cfg = TransformerConfig {
        batch: 8,
        seq: 32,
        d_model: 64,
        heads: 4,
        d_ff: 128,
        layers: 4,
        classes: 64,
    };
    let g = transformer(&bench_cfg);
    let (mm, bmm) = ranked_gemm_shapes(&g);
    assert!(mm.len() >= 2 && !bmm.is_empty(), "encoder graph lost its GEMM shapes?");

    let min_speedup: f64 = std::env::var("KERNELS_MICRO_MIN_SPEEDUP")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10.0);

    let mut gated_speedups: Vec<(String, f64)> = Vec::new();
    for (rank, shape) in mm.iter().take(4).enumerate() {
        let (fast_ms, naive_ms, gflops) = bench_shape(shape, &mut rng);
        let speedup = naive_ms / fast_ms;
        let label = format!("kernel/mm-rank{}", rank + 1);
        log.row(
            &label,
            &[
                ("ms", format!("{fast_ms:.4}")),
                ("naive_ms", format!("{naive_ms:.4}")),
                ("speedup", format!("{speedup:.2}")),
                ("gflops", format!("{gflops:.2}")),
                ("m", shape.m.to_string()),
                ("k", shape.k.to_string()),
                ("n", shape.n.to_string()),
                ("ops_per_step", shape.count.to_string()),
            ],
        );
        if rank < 2 {
            gated_speedups.push((label, speedup));
        }
    }

    for (rank, shape) in bmm.iter().take(2).enumerate() {
        let (fast_ms, naive_ms, gflops) = bench_shape(shape, &mut rng);
        log.row(
            &format!("kernel/bmm-rank{}", rank + 1),
            &[
                ("ms", format!("{fast_ms:.4}")),
                ("naive_ms", format!("{naive_ms:.4}")),
                ("speedup", format!("{:.2}", naive_ms / fast_ms)),
                ("gflops", format!("{gflops:.2}")),
                ("groups", shape.groups.to_string()),
                ("m", shape.m.to_string()),
                ("k", shape.k.to_string()),
                ("n", shape.n.to_string()),
            ],
        );
    }

    // A representative conv lowering (un-gated; VGG-ish interior layer).
    {
        let (n, h, w, cin, kh, kw, cout) = (4usize, 16usize, 16usize, 16usize, 3usize, 3usize, 16usize);
        let x = rng.normal_vec(n * h * w * cin, 1.0);
        let wt = rng.normal_vec(kh * kw * cin * cout, 1.0);
        let g0 = Graph::default();
        let op = Op {
            id: 0,
            kind: OpKind::Conv2d { stride: 1, pad: 1 },
            inputs: vec![0, 0],
            outputs: vec![0],
            name: "bench-conv".into(),
        };
        let oshape = [n, h, w, cout];
        let views = [View::full(&x, &[n, h, w, cin]), View::full(&wt, &[kh, kw, cin, cout])];
        let fast = apply_op_with(KernelBackend::Fast, &g0, &op, &views, &oshape);
        let naive = apply_op_with(KernelBackend::Naive, &g0, &op, &views, &oshape);
        let err = max_rel_err(&fast, &naive);
        assert!(err <= KERNEL_ORACLE_TOL, "conv: fast diverged from oracle by {err:e}");
        let m_fast = time_it(1, Duration::from_millis(100), || {
            std::hint::black_box(apply_op_with(KernelBackend::Fast, &g0, &op, &views, &oshape));
        });
        let m_naive = time_it(1, Duration::from_millis(100), || {
            std::hint::black_box(apply_op_with(KernelBackend::Naive, &g0, &op, &views, &oshape));
        });
        let flops = 2.0 * (n * h * w * cout * kh * kw * cin) as f64;
        log.row(
            "kernel/conv-fwd",
            &[
                ("ms", format!("{:.4}", m_fast.mean_ms())),
                ("naive_ms", format!("{:.4}", m_naive.mean_ms())),
                ("speedup", format!("{:.2}", m_naive.mean.as_secs_f64() / m_fast.mean.as_secs_f64())),
                ("gflops", format!("{:.2}", flops / m_fast.mean.as_secs_f64() / 1e9)),
            ],
        );
    }

    // Schedule-search bookkeeping: how many shapes this run memoized.
    let cache = ScheduleCache::global();
    log.row(
        "kernel/schedule-cache",
        &[("schedules", cache.len().to_string()), ("searches", cache.searches().to_string())],
    );

    // The acceptance gate: the encoder's two dominant matmul shapes must
    // ride the blocked kernels at ≥ 10× the naive oracle.
    for (label, speedup) in &gated_speedups {
        assert!(
            speedup >= &min_speedup,
            "{label}: fast kernel is only {speedup:.2}x over naive (gate: >= {min_speedup}x)"
        );
    }

    log.write_json("BENCH_kernels.json").expect("writing BENCH_kernels.json");
    println!("wrote BENCH_kernels.json");
}
