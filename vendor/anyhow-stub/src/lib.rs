//! Offline stub of the `anyhow` error-handling surface used by the
//! `pjrt`-gated runtime (`Error`, `Result`, `Context`, the `anyhow!` /
//! `bail!` / `ensure!` macros).
//!
//! Exists so `cargo check --features pjrt` works in the offline image —
//! the feature gate is compile-checked in CI instead of rotting silently.
//! Behavior matches the real crate closely enough for that job: errors are
//! a message string plus a context chain rendered through `Display`.

use std::fmt;

/// String-backed error with context frames (outermost first).
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), context: Vec::new() }
    }

    fn push_context<C: fmt::Display>(mut self, c: C) -> Self {
        self.context.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(c) => write!(f, "{c}: {}", self.msg),
            None => write!(f, "{}", self.msg),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

// Same shape as the real crate: any std error converts into `Error`
// (which itself deliberately does *not* implement `std::error::Error`,
// keeping the blanket impl coherent).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

// Sealed conversion covering both std errors and `Error` itself, so
// `.context(...)` works on `Result<_, anyhow::Error>` too — the same
// two-impl shape the real crate uses.
mod ext {
    use super::Error;

    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::msg(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().push_context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn context_chains_render() {
        let e = io_err().context("reading artifact").unwrap_err();
        assert_eq!(format!("{e}"), "reading artifact: boom");
        let e2: Error = io_err().unwrap_err().into();
        assert_eq!(format!("{e2}"), "boom");
    }

    #[test]
    fn macros_compose() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert!(format!("{}", f(false).unwrap_err()).contains("false"));
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(5u32).with_context(|| "unused").unwrap(), 5);
    }
}
