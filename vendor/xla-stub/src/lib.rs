//! Offline stub of the PJRT `xla` bindings: the exact API surface
//! `rust/src/runtime/{client,dynamic}.rs` compiles against, with every
//! entry point returning a runtime error (or unreachable on types that
//! can never be constructed without a real backend).
//!
//! Purpose: `cargo check --features pjrt` must keep working in the
//! offline image so CI can compile-check the feature gate. Execution
//! requires swapping this path dependency for the real vendored crate.

use std::fmt;

/// Error for every stubbed entry point.
#[derive(Debug)]
pub struct XlaError(String);

impl XlaError {
    fn stub(what: &str) -> Self {
        XlaError(format!(
            "{what}: offline xla stub — point Cargo.toml's `xla` path dependency at the real \
             vendored crate to execute"
        ))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

/// Uninhabited payload: values of the wrapping types cannot exist, so
/// their methods are statically unreachable.
#[derive(Debug, Clone)]
enum Void {}

fn unreachable_void(v: &Void) -> ! {
    match *v {}
}

// -- client types -----------------------------------------------------------

pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(XlaError::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        unreachable_void(&self.0)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unreachable_void(&self.0)
    }
}

pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unreachable_void(&self.0)
    }
}

pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unreachable_void(&self.0)
    }
}

// -- HLO / computations -----------------------------------------------------

pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn parse_and_return_unverified_module(_text: &[u8]) -> Result<Self> {
        Err(XlaError::stub("HloModuleProto::parse_and_return_unverified_module"))
    }
}

pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        unreachable_void(&proto.0)
    }
}

// -- literals ---------------------------------------------------------------

/// Host literal. Constructible (so `to_literal` conversion code
/// compiles), but every consuming operation fails.
pub struct Literal;

impl Literal {
    pub fn scalar(_v: f32) -> Self {
        Literal
    }

    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(XlaError::stub("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(XlaError::stub("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(XlaError::stub("Literal::to_tuple"))
    }
}

// -- builder ----------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
}

pub struct Shape;

impl Shape {
    pub fn array<T: 'static>(_dims: Vec<i64>) -> Shape {
        Shape
    }
}

pub struct XlaBuilder;

impl XlaBuilder {
    pub fn new(_name: &str) -> Self {
        XlaBuilder
    }

    pub fn parameter_s(&self, _id: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        Err(XlaError::stub("XlaBuilder::parameter_s"))
    }

    pub fn c0(&self, _v: f32) -> Result<XlaOp> {
        Err(XlaError::stub("XlaBuilder::c0"))
    }

    pub fn tuple(&self, _elems: &[XlaOp]) -> Result<XlaOp> {
        Err(XlaError::stub("XlaBuilder::tuple"))
    }
}

#[derive(Clone)]
pub struct XlaOp(Void);

macro_rules! unary_ops {
    ($($name:ident),* $(,)?) => {
        $(pub fn $name(&self) -> Result<XlaOp> { unreachable_void(&self.0) })*
    };
}

macro_rules! binary_ops {
    ($($name:ident),* $(,)?) => {
        $(pub fn $name(&self, _rhs: &XlaOp) -> Result<XlaOp> { unreachable_void(&self.0) })*
    };
}

impl XlaOp {
    unary_ops!(exp, log);
    binary_ops!(matmul, add_, sub_, mul_, div_, max, gt);

    pub fn transpose(&self, _perm: &[i64]) -> Result<XlaOp> {
        unreachable_void(&self.0)
    }

    pub fn convert(&self, _ty: PrimitiveType) -> Result<XlaOp> {
        unreachable_void(&self.0)
    }

    pub fn broadcast_in_dim(&self, _dims: &[i64], _broadcast_dims: &[i64]) -> Result<XlaOp> {
        unreachable_void(&self.0)
    }

    pub fn reduce_sum(&self, _axes: &[i64], _keep_dims: bool) -> Result<XlaOp> {
        unreachable_void(&self.0)
    }

    pub fn reduce_max(&self, _axes: &[i64], _keep_dims: bool) -> Result<XlaOp> {
        unreachable_void(&self.0)
    }

    pub fn build(&self) -> Result<XlaComputation> {
        unreachable_void(&self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::parse_and_return_unverified_module(b"x").is_err());
        let b = XlaBuilder::new("t");
        assert!(b.parameter_s(0, &Shape::array::<f32>(vec![2, 2]), "p").is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"));
    }

    #[test]
    fn literal_constructors_exist() {
        let l = Literal::vec1(&[1.0, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        assert!(Literal::scalar(1.0).to_vec::<f32>().is_err());
    }
}
