//! Plan inspector: what does the optimal tiling actually look like?
//!
//! Prints, for the paper's three headline workloads, the tensor-by-tensor
//! tiling SOYBEAN chooses (in the paper's R/C/r notation), the per-cut
//! costs, and where the plan agrees with / departs from the pure
//! strategies. This is the qualitative heart of the paper: convolutional
//! front halves go data-parallel, FC-heavy tails go model-parallel, and
//! the cuts land on the interconnect tiers accordingly.
//!
//! Run with: `cargo run --release --example plan_inspector`
//!
//! With `--lower`, each 8-device plan is additionally compiled into
//! explicit per-device SPMD collective programs (vgg16, alexnet, and the
//! transformer encoder), printing the instruction mix, the per-tier byte
//! meter (which must equal the plan's Theorem-1 cost bit for bit — it is
//! asserted here), and the head of device 0's stream. With `--trace`, the
//! discrete-event engine schedules each program over the p2.8xlarge
//! topology and writes `plan_trace_<model>.json` — load it in
//! `chrome://tracing` or Perfetto to see the timeline.

use soybean::exec::Placement;
use soybean::lower::lower;
use soybean::models::{alexnet, mlp, transformer, vgg16, MlpConfig, TransformerConfig};
use soybean::planner::{classify, Planner, Strategy};
use soybean::sim::{chrome_trace_json, run_program, simulate, SimConfig, Topology};
use soybean::tiling::describe_seq;

/// Compile the plan to SPMD programs and (optionally) schedule it.
fn lower_and_trace(name: &str, g: &soybean::Graph, trace: bool) {
    let cfg = SimConfig::default();
    let topo = Topology::p2_8xlarge();
    let plan = Planner::plan(g, 3, Strategy::Soybean);
    let p = lower(g, &plan, &cfg);
    assert_eq!(p.total_bytes(), plan.total_cost(), "{name}: lowered bytes != Theorem-1 cost");
    println!("\n--- {name}: lowered SPMD program (8 devices) ---");
    let mix: Vec<String> = p.histogram().iter().map(|(k, c)| format!("{c} {k}")).collect();
    println!("instruction mix per device: {}", mix.join(", "));
    for (j, (bytes, tier)) in p.tier_bytes().iter().zip(&topo.tiers).enumerate() {
        println!("  tier {j} ({:>12}): {:.3} MB", tier.name, *bytes as f64 / 1e6);
    }
    println!("device 0 stream (head):");
    print!("{}", p.describe_device(0, 14));
    if trace {
        let r = run_program(&p, &topo);
        let sim = simulate(g, &plan, &cfg);
        println!(
            "event-engine step {:.3} ms (analytic model {:.3} ms, compute floor {:.3} ms)",
            r.step_s * 1e3,
            sim.step_s * 1e3,
            r.compute_s * 1e3
        );
        let path = format!("plan_trace_{name}.json");
        std::fs::write(&path, chrome_trace_json(&r, &topo)).expect("writing trace");
        println!("wrote {path} ({} events) — open in chrome://tracing", r.trace.len());
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let do_lower = args.iter().any(|a| a == "--lower");
    let do_trace = args.iter().any(|a| a == "--trace");
    let placement = Placement::p2_8xlarge();

    // 1. The §2.2 MLP: hybrid wins.
    let g = mlp(&MlpConfig { batch: 400, dims: vec![300; 6], bias: false });
    let plan = Planner::plan(&g, 3, Strategy::Soybean);
    println!("=== 5-layer MLP(300) batch 400, 8 devices ===");
    println!("classification: {}", classify(&g, &plan.tiles));
    for (i, (d, tier)) in plan.cut_costs.iter().zip(&placement.tiers).enumerate() {
        println!("  cut {i} ({tier:>12}): {:.3} MB", *d as f64 / 1e6);
    }
    for t in g.tensors.iter().filter(|t| t.kind == soybean::graph::TensorKind::Weight) {
        println!("  {:<8} {:?} -> {}", t.name, t.shape, describe_seq(&plan.tiles[t.id]));
    }

    // 2. AlexNet: the per-layer story of Figure 10(a).
    let g = alexnet(256);
    let plan = Planner::plan(&g, 3, Strategy::Soybean);
    println!("\n=== AlexNet batch 256, 8 devices ===");
    println!("classification: {}", classify(&g, &plan.tiles));
    println!("total comm: {:.1} MB (DP baseline: {:.1} MB)",
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(&g, 3).total_cost() as f64 / 1e6);
    println!("{:<12} {:<20} tiling", "layer", "shape");
    for t in &g.tensors {
        if t.kind == soybean::graph::TensorKind::Weight {
            println!("  {:<12} {:<20} {}", t.name, format!("{:?}", t.shape), describe_seq(&plan.tiles[t.id]));
        }
    }
    println!("\nReading: conv filters replicated (data parallelism) while the\n\
              FC weights split (model parallelism) — the mixed strategy of\n\
              Krizhevsky's 'one weird trick', discovered automatically.");

    // 3. The post-paper workload: a GPT-2-style encoder stack.
    let g = transformer(&TransformerConfig::micro());
    let plan = Planner::plan(&g, 3, Strategy::Soybean);
    println!("\n=== transformer encoder (4 layers, 4 heads, d_model 256), 8 devices ===");
    println!("classification: {}", classify(&g, &plan.tiles));
    println!(
        "total comm: {:.1} MB (DP baseline: {:.1} MB)",
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(&g, 3).total_cost() as f64 / 1e6
    );
    for name in ["l0.wqkv", "l0.wo", "l0.ff1.w", "l0.slice_q.out", "l0.scores.out"] {
        let t = g.tensors.iter().find(|t| t.name == name).unwrap();
        println!("  {:<16} {:<18} {}", t.name, format!("{:?}", t.shape), describe_seq(&plan.tiles[t.id]));
    }

    // 4. `--lower [--trace]`: the back half of the system — compile each
    // plan into explicit per-device collective programs and (with
    // `--trace`) schedule them on the event engine.
    if do_lower || do_trace {
        lower_and_trace("vgg16", &vgg16(32), do_trace);
        lower_and_trace("alexnet", &alexnet(128), do_trace);
        lower_and_trace("transformer", &transformer(&TransformerConfig::micro()), do_trace);
    }
}
