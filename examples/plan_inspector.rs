//! Plan inspector: what does the optimal tiling actually look like?
//!
//! Prints, for the paper's three headline workloads, the tensor-by-tensor
//! tiling SOYBEAN chooses (in the paper's R/C/r notation), the per-cut
//! costs, and where the plan agrees with / departs from the pure
//! strategies. This is the qualitative heart of the paper: convolutional
//! front halves go data-parallel, FC-heavy tails go model-parallel, and
//! the cuts land on the interconnect tiers accordingly.
//!
//! Run with: `cargo run --release --example plan_inspector`

use soybean::exec::Placement;
use soybean::models::{alexnet, mlp, transformer, MlpConfig, TransformerConfig};
use soybean::planner::{classify, Planner, Strategy};
use soybean::tiling::describe_seq;

fn main() {
    let placement = Placement::p2_8xlarge();

    // 1. The §2.2 MLP: hybrid wins.
    let g = mlp(&MlpConfig { batch: 400, dims: vec![300; 6], bias: false });
    let plan = Planner::plan(&g, 3, Strategy::Soybean);
    println!("=== 5-layer MLP(300) batch 400, 8 devices ===");
    println!("classification: {}", classify(&g, &plan.tiles));
    for (i, (d, tier)) in plan.cut_costs.iter().zip(&placement.tiers).enumerate() {
        println!("  cut {i} ({tier:>12}): {:.3} MB", *d as f64 / 1e6);
    }
    for t in g.tensors.iter().filter(|t| t.kind == soybean::graph::TensorKind::Weight) {
        println!("  {:<8} {:?} -> {}", t.name, t.shape, describe_seq(&plan.tiles[t.id]));
    }

    // 2. AlexNet: the per-layer story of Figure 10(a).
    let g = alexnet(256);
    let plan = Planner::plan(&g, 3, Strategy::Soybean);
    println!("\n=== AlexNet batch 256, 8 devices ===");
    println!("classification: {}", classify(&g, &plan.tiles));
    println!("total comm: {:.1} MB (DP baseline: {:.1} MB)",
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(&g, 3).total_cost() as f64 / 1e6);
    println!("{:<12} {:<20} tiling", "layer", "shape");
    for t in &g.tensors {
        if t.kind == soybean::graph::TensorKind::Weight {
            println!("  {:<12} {:<20} {}", t.name, format!("{:?}", t.shape), describe_seq(&plan.tiles[t.id]));
        }
    }
    println!("\nReading: conv filters replicated (data parallelism) while the\n\
              FC weights split (model parallelism) — the mixed strategy of\n\
              Krizhevsky's 'one weird trick', discovered automatically.");

    // 3. The post-paper workload: a GPT-2-style encoder stack.
    let g = transformer(&TransformerConfig::micro());
    let plan = Planner::plan(&g, 3, Strategy::Soybean);
    println!("\n=== transformer encoder (4 layers, 4 heads, d_model 256), 8 devices ===");
    println!("classification: {}", classify(&g, &plan.tiles));
    println!(
        "total comm: {:.1} MB (DP baseline: {:.1} MB)",
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(&g, 3).total_cost() as f64 / 1e6
    );
    for name in ["l0.wqkv", "l0.wo", "l0.ff1.w", "l0.slice_q.out", "l0.scores.out"] {
        let t = g.tensors.iter().find(|t| t.name == name).unwrap();
        println!("  {:<16} {:<18} {}", t.name, format!("{:?}", t.shape), describe_seq(&plan.tiles[t.id]));
    }
}
