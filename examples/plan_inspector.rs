//! Plan inspector: what does the optimal tiling actually look like?
//!
//! Prints, for the paper's three headline workloads, the tensor-by-tensor
//! tiling SOYBEAN chooses (in the paper's R/C/r notation), the per-cut
//! costs, and where the plan agrees with / departs from the pure
//! strategies. This is the qualitative heart of the paper: convolutional
//! front halves go data-parallel, FC-heavy tails go model-parallel, and
//! the cuts land on the interconnect tiers accordingly.
//!
//! Run with: `cargo run --release --example plan_inspector`
//!
//! With `--lower`, each 8-device plan is additionally compiled into
//! explicit per-device SPMD collective programs (vgg16, alexnet, and the
//! transformer encoder), printing the instruction mix, the per-tier byte
//! meter (which must equal the plan's Theorem-1 cost bit for bit — it is
//! asserted here), and the head of device 0's stream. With `--trace`, the
//! discrete-event engine schedules each program over the p2.8xlarge
//! topology and writes `plan_trace_<model>.json` — load it in
//! `chrome://tracing` or Perfetto to see the timeline.
//!
//! With `--inject <spec>` (`kind[@device[:op]]`, kind one of
//! `kill|panic|drop|delay|corrupt`), a named fault scenario is injected
//! into a 4-device MLP execution and the structured error chain plus the
//! recovery outcome are printed — `kill` demonstrates the elastic re-plan
//! onto the surviving devices (docs/execution.md §Fault tolerance).
//!
//! With `--topology <flat|two-tier|fat-tree>`, vgg16 and the transformer
//! encoder are planned **both ways** for 8 devices on the named preset —
//! the byte-objective flat plan and the topology-aware plan
//! (`try_plan_topology_aware`, docs/topology.md) — and the full candidate
//! scoreboard plus both engine-simulated step times are printed.
//!
//! With `--execute`, each 8-device plan additionally **runs** on the
//! threaded SPMD executor with real `f32` shard buffers (docs/
//! execution.md): the differential report prints the worst elementwise
//! deviation from the serial interpreter, the Theorem-1 byte meter the
//! executor observed (asserted equal to the plan cost), and the real
//! channel payload volume.
//!
//! With `--pipeline`, the strategy portfolio (docs/pipeline.md) is
//! scored for vgg16 and the transformer encoder on the two-tier preset:
//! pure tiling vs `{2, 4}`-stage × `{GPipe, 1F1B}` pipelines, each
//! engine-simulated. The candidate scoreboard, the winner's per-stage
//! scoreboard (level range, device group, intra-cell bytes, busy time,
//! peak activation stash, bubble fraction) and the stage-lane Chrome
//! trace (`pipeline_trace_<model>.json`) are printed/written.
//!
//! With `--profile`, each executable workload runs one **traced** step
//! ([`Session::profile`], docs/observability.md): the drift report
//! (per-kernel and per-collective modeled-vs-measured ratios, worst
//! offenders) prints to stdout and is written as
//! `obs_report_<model>.json`, with the modeled+measured overlay Chrome
//! trace beside it as `obs_overlay_<model>.json`.

use soybean::exec::Placement;
use soybean::graph::{eval_serial, seed_values};
use soybean::models::{
    alexnet, alexnet_scaled, mlp, transformer, vgg16, MlpConfig, TransformerConfig,
};
use soybean::obs::{overlay_trace_json, pipeline_trace_json};
use soybean::planner::{classify, plan_strategy, try_plan_topology_aware};
use soybean::sim::{chrome_trace_json, try_run_program, Topology};
use soybean::spmd::{
    execute_with_recovery, worst_divergence, ExecOptions, FaultPlan, RecoverOptions,
    RecoveryOutcome,
};
use soybean::tiling::describe_seq;
use soybean::Session;

/// The byte-objective planning surface: a flat topology makes the
/// topology-aware portfolio fall back to the byte-LUT k-cut plan bit for
/// bit, so these sections print the same tilings the paper's optimizer
/// chooses.
fn flat_topo(k: usize) -> Topology {
    Topology::flat(k, 10.0e9, 20e-6, 4.0)
}

/// `--inject <spec>`: reproduce a named fault scenario on the 4-device
/// MLP plan and print the structured error chain plus the recovery
/// outcome (docs/execution.md §Fault tolerance).
///
/// Spec grammar: `kind[@device[:op]]` with kind one of
/// `kill | panic | drop | delay | corrupt`; device defaults to 1, op to 0.
/// E.g. `--inject kill@1:0` (permanent device loss at op 0),
/// `--inject drop@2:1` (swallow device 2's first exchange of op 1).
fn inject_scenario(spec: &str) {
    let (kind, site) = spec.split_once('@').map_or((spec, None), |(k, s)| (k, Some(s)));
    let (device, op) = match site {
        None => (1usize, 0usize),
        Some(s) => match s.split_once(':') {
            Some((d, o)) => (
                d.parse().expect("--inject device must be a number"),
                o.parse().expect("--inject op must be a number"),
            ),
            None => (s.parse().expect("--inject device must be a number"), 0),
        },
    };
    let faults = match kind {
        "kill" => FaultPlan::kill(device, op),
        "panic" => FaultPlan::panic_at(device, op),
        "drop" => FaultPlan::drop_message(device, op),
        "delay" => FaultPlan::delay_message(device, op, 5),
        "corrupt" => FaultPlan::corrupt_payload(device, op),
        other => panic!("unknown fault kind `{other}` (kill|panic|drop|delay|corrupt)"),
    };

    let session =
        Session::build(mlp(&MlpConfig::fig8(16, 16)), 4, &flat_topo(2)).expect("session build");
    let init = seed_values(session.graph(), 42);
    let desc = faults.describe();
    let opts = RecoverOptions::default()
        .exec(
            ExecOptions::default().deadline(std::time::Duration::from_secs(2)).fault_plan(faults),
        )
        .backoff(std::time::Duration::from_millis(5));

    println!("\n=== fault scenario: {desc} (mlp, 4 devices) ===");
    let (g, plan, program) = (session.graph(), session.plan(), session.program());
    match execute_with_recovery(g, plan, program, &init, &opts) {
        Ok(r) => {
            for (i, e) in r.failures.iter().enumerate() {
                println!("  attempt {i}: {e}");
            }
            match &r.outcome {
                RecoveryOutcome::Clean => println!("  outcome: clean (fault tolerated in-flight)"),
                RecoveryOutcome::Retried { retries } => {
                    println!("  outcome: recovered after {retries} retr{}", if *retries == 1 { "y" } else { "ies" })
                }
                RecoveryOutcome::Replanned { lost_device, devices } => println!(
                    "  outcome: device {lost_device} lost permanently; \
                     re-planned onto {devices} survivors and resumed from checkpoint"
                ),
            }
            let serial = eval_serial(g, &init).expect("serial evaluation");
            let (worst, tensor) = worst_divergence(g, &r.report, &serial);
            let status = if worst <= 1e-5 { "OK" } else { "DIVERGED" };
            println!("  differential: max rel err {worst:.2e} on `{tensor}` [{status}]");
            assert!(worst <= 1e-5, "recovered run diverged from serial");
        }
        Err(e) => println!("  unrecovered: {e}"),
    }
}

/// `--execute`: run the 8-device SOYBEAN plan on the threaded executor
/// and print the differential report against the serial interpreter.
fn execute_and_compare(name: &str, g: soybean::Graph) {
    let session = Session::build(g, 8, &flat_topo(3)).expect("session build");
    let init = seed_values(session.graph(), 42);
    let report = session.execute(&init).expect("threaded execution");
    assert_eq!(report.instr_bytes, session.plan().total_cost(), "{name}: meter != Theorem-1");
    let serial = eval_serial(session.graph(), &init).expect("serial evaluation");
    let (worst, tensor) = worst_divergence(session.graph(), &report, &serial);
    let status = if worst <= 1e-5 { "OK" } else { "DIVERGED" };
    println!(
        "  {name:<16} 8 devices: max rel err {worst:.2e} on `{tensor}` [{status}]  \
         collective meter {:.3} MB (== Theorem-1)  payload {:.3} MB",
        report.instr_bytes as f64 / 1e6,
        report.payload_bytes as f64 / 1e6
    );
    assert!(worst <= 1e-5, "{name}: differential gate failed");
}

/// `--profile`: run one traced 8-device step, join the measured spans
/// against the engine's modeled schedule, and dump the drift report plus
/// the modeled+measured overlay trace (docs/observability.md).
fn profile_workload(name: &str, g: soybean::Graph) {
    let topo = Topology::p2_8xlarge();
    let session = Session::build(g, 8, &topo).expect("session build");
    let init = seed_values(session.graph(), 42);
    let p = session.profile(&init).expect("profiled step");
    println!("\n--- {name}: measured vs modeled (8 devices) ---");
    print!("{}", p.calibration);
    let report_path = format!("obs_report_{name}.json");
    p.calibration.write_json(&report_path).expect("writing drift report");
    let trace = p.exec.trace.as_ref().expect("profile always traces");
    let trace_path = format!("obs_overlay_{name}.json");
    std::fs::write(&trace_path, overlay_trace_json(&p.modeled, &topo, trace, session.program()))
        .expect("writing overlay trace");
    println!("wrote {report_path} and {trace_path} — open the overlay in chrome://tracing");
}

/// `--pipeline`: score the full strategy portfolio — pure tiling vs
/// `{2, 4}`-stage × `{GPipe, 1F1B}` pipelines — on a hierarchical
/// topology and print the winner's stage scoreboard (docs/pipeline.md).
fn pipeline_report(name: &str, g: &soybean::Graph, topo: &Topology) {
    let sp = plan_strategy(g, 8, topo).expect("strategy planning");
    println!("\n--- {name}: strategy portfolio (8 devices) ---");
    for s in &sp.scores {
        let marker = if s.name == sp.chosen { " <- chosen" } else { "" };
        println!(
            "  {:<10} step {:8.3} ms   {:9.3} MB{marker}",
            s.name,
            s.step_s * 1e3,
            s.total_bytes as f64 / 1e6
        );
    }
    let (strat, rep) = (&sp.strategy, &sp.report);
    println!(
        "  winner `{}`: {} stage(s) x {} microbatch(es), schedule {}, bubble {:.1}%",
        sp.chosen,
        strat.stage_count(),
        strat.microbatches,
        strat.schedule.name(),
        rep.bubble_fraction * 100.0
    );
    for spec in &strat.stages {
        let cell_bytes: u64 = strat
            .cells
            .iter()
            .filter(|c| c.stage == spec.stage)
            .map(|c| c.plan.total_cost())
            .sum();
        println!(
            "    stage {}: levels [{}, {}), devices {}..{} (k={}), \
             intra-cell {:.3} MB/ubatch, busy {:.3} ms, peak stash {}",
            spec.stage,
            spec.level_lo,
            spec.level_hi,
            spec.device_lo,
            spec.device_lo + spec.devices(),
            spec.k,
            cell_bytes as f64 / 1e6,
            rep.stage_busy_s[spec.stage] * 1e3,
            rep.peak_stash[spec.stage]
        );
    }
    println!(
        "  boundary {:.3} MB/ubatch; serial-stage {:.3} ms -> pipelined {:.3} ms",
        strat.boundary_bytes() as f64 / 1e6,
        rep.serial_step_s * 1e3,
        rep.step_s * 1e3
    );
    let path = format!("pipeline_trace_{name}.json");
    std::fs::write(&path, pipeline_trace_json(rep, &strat.cell_labels()))
        .expect("writing pipeline trace");
    println!("  wrote {path} — open in chrome://tracing");
}

/// Compile the plan to SPMD programs and (optionally) schedule it.
fn lower_and_trace(name: &str, g: soybean::Graph, trace: bool) {
    let topo = Topology::p2_8xlarge();
    let session = Session::build(g, 8, &topo).expect("session build");
    let (plan, p) = (session.plan(), session.program());
    assert_eq!(p.total_bytes(), plan.total_cost(), "{name}: lowered bytes != Theorem-1 cost");
    println!("\n--- {name}: lowered SPMD program (8 devices) ---");
    let mix: Vec<String> = p.histogram().iter().map(|(k, c)| format!("{c} {k}")).collect();
    println!("instruction mix per device: {}", mix.join(", "));
    for (j, (bytes, tier)) in p.tier_bytes().iter().zip(&topo.tiers).enumerate() {
        println!("  tier {j} ({:>12}): {:.3} MB", tier.name, *bytes as f64 / 1e6);
    }
    println!("device 0 stream (head):");
    print!("{}", p.describe_device(0, 14));
    if trace {
        let r = try_run_program(p, &topo).unwrap();
        let sim = session.simulate().expect("analytic simulation");
        println!(
            "event-engine step {:.3} ms (analytic model {:.3} ms, compute floor {:.3} ms)",
            r.step_s * 1e3,
            sim.step_s * 1e3,
            r.compute_s * 1e3
        );
        let path = format!("plan_trace_{name}.json");
        std::fs::write(&path, chrome_trace_json(&r, &topo)).expect("writing trace");
        println!("wrote {path} ({} events) — open in chrome://tracing", r.trace.len());
    }
}

/// Plan one workload both ways on `topo` and print the scoreboard.
fn topology_report(name: &str, g: &soybean::Graph, preset: &str, topo: &Topology) {
    let aware = try_plan_topology_aware(g, 8, topo).expect("topology-aware planning");
    println!("\n--- {name}: topology-aware vs flat on `{preset}` (8 devices) ---");
    for s in &aware.scores {
        let marker = if s.name == aware.chosen { " <- chosen" } else { "" };
        println!(
            "  {:<14} step {:8.3} ms   {:9.1} MB{marker}",
            s.name,
            s.step_s * 1e3,
            s.total_bytes as f64 / 1e6
        );
    }
    println!(
        "  flat {:.3} ms -> topology-aware {:.3} ms ({:+.1}%)",
        aware.flat_step_s * 1e3,
        aware.step_s * 1e3,
        (aware.step_s / aware.flat_step_s - 1.0) * 100.0
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let do_lower = args.iter().any(|a| a == "--lower");
    let do_trace = args.iter().any(|a| a == "--trace");
    let do_execute = args.iter().any(|a| a == "--execute");
    let do_profile = args.iter().any(|a| a == "--profile");
    let do_pipeline = args.iter().any(|a| a == "--pipeline");
    let topo_preset = args
        .iter()
        .position(|a| a == "--topology")
        .map(|i| args.get(i + 1).expect("--topology needs a preset name").as_str());
    let inject_spec = args
        .iter()
        .position(|a| a == "--inject")
        .map(|i| args.get(i + 1).expect("--inject needs a fault spec (e.g. kill@1:0)").as_str());
    // `--inject` is a focused reproduction tool: run just the scenario.
    if let Some(spec) = inject_spec {
        inject_scenario(spec);
        return;
    }
    let placement = Placement::p2_8xlarge();

    // 1. The §2.2 MLP: hybrid wins.
    let session = Session::build(
        mlp(&MlpConfig { batch: 400, dims: vec![300; 6], bias: false }),
        8,
        &flat_topo(3),
    )
    .expect("session build");
    let (g, plan) = (session.graph(), session.plan());
    println!("=== 5-layer MLP(300) batch 400, 8 devices ===");
    println!("{}", session.plan_summary());
    for (i, (d, tier)) in plan.cut_costs.iter().zip(&placement.tiers).enumerate() {
        println!("  cut {i} ({tier:>12}): {:.3} MB", *d as f64 / 1e6);
    }
    for t in g.tensors.iter().filter(|t| t.kind == soybean::graph::TensorKind::Weight) {
        println!("  {:<8} {:?} -> {}", t.name, t.shape, describe_seq(&plan.tiles[t.id]));
    }

    // 2. AlexNet: the per-layer story of Figure 10(a).
    let session = Session::build(alexnet(256), 8, &flat_topo(3)).expect("session build");
    let (g, plan) = (session.graph(), session.plan());
    println!("\n=== AlexNet batch 256, 8 devices ===");
    println!("classification: {}", classify(g, &plan.tiles));
    println!("total comm: {:.1} MB (DP baseline: {:.1} MB)",
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(g, 3).total_cost() as f64 / 1e6);
    println!("{:<12} {:<20} tiling", "layer", "shape");
    for t in &g.tensors {
        if t.kind == soybean::graph::TensorKind::Weight {
            println!("  {:<12} {:<20} {}", t.name, format!("{:?}", t.shape), describe_seq(&plan.tiles[t.id]));
        }
    }
    println!("\nReading: conv filters replicated (data parallelism) while the\n\
              FC weights split (model parallelism) — the mixed strategy of\n\
              Krizhevsky's 'one weird trick', discovered automatically.");

    // 3. The post-paper workload: a GPT-2-style encoder stack.
    let session =
        Session::build(transformer(&TransformerConfig::micro()), 8, &flat_topo(3))
            .expect("session build");
    let (g, plan) = (session.graph(), session.plan());
    println!("\n=== transformer encoder (4 layers, 4 heads, d_model 256), 8 devices ===");
    println!("classification: {}", classify(g, &plan.tiles));
    println!(
        "total comm: {:.1} MB (DP baseline: {:.1} MB)",
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(g, 3).total_cost() as f64 / 1e6
    );
    for name in ["l0.wqkv", "l0.wo", "l0.ff1.w", "l0.slice_q.out", "l0.scores.out"] {
        let t = g.tensors.iter().find(|t| t.name == name).unwrap();
        println!("  {:<16} {:<18} {}", t.name, format!("{:?}", t.shape), describe_seq(&plan.tiles[t.id]));
    }

    // 4. `--lower [--trace]`: the back half of the system — compile each
    // plan into explicit per-device collective programs and (with
    // `--trace`) schedule them on the event engine.
    if do_lower || do_trace {
        lower_and_trace("vgg16", vgg16(32), do_trace);
        lower_and_trace("alexnet", alexnet(128), do_trace);
        lower_and_trace("transformer", transformer(&TransformerConfig::micro()), do_trace);
    }

    // 5. `--execute`: the correctness loop — run each 8-device plan on
    // real tensors and diff against the serial interpreter
    // (docs/execution.md). Workloads are the numerically tractable
    // instances of the same topologies.
    if do_execute {
        println!("\n=== threaded SPMD execution vs serial interpreter (8 devices) ===");
        execute_and_compare("mlp", mlp(&MlpConfig::fig8(16, 16)));
        execute_and_compare("transformer-4L", transformer(&TransformerConfig::tiny4()));
        execute_and_compare("alexnet-67px", alexnet_scaled(8, 67, 256));
    }

    // 6. `--profile`: the observability loop — one traced step per
    // executable workload, joined against the engine's model
    // (docs/observability.md).
    if do_profile {
        println!("\n=== measured vs modeled profiling (8 devices) ===");
        profile_workload("mlp", mlp(&MlpConfig::fig8(16, 16)));
        profile_workload("transformer-4L", transformer(&TransformerConfig::tiny4()));
    }

    // 7. `--pipeline`: the pipeline axis — score tiling vs pipelined
    // strategies on a hierarchical interconnect and print the winner's
    // stage scoreboard (docs/pipeline.md).
    if do_pipeline {
        let topo = Topology::two_tier(3);
        println!("\n=== strategy portfolio on `two-tier` (8 devices) ===");
        pipeline_report("vgg16", &vgg16(32), &topo);
        pipeline_report("transformer", &transformer(&TransformerConfig::micro()), &topo);
    }

    // 8. `--topology <preset>`: close the planner/topology loop — plan
    // both ways on a hierarchical interconnect and show the candidate
    // scoreboard (docs/topology.md).
    if let Some(preset) = topo_preset {
        let topo = match preset {
            "flat" => Topology::flat(3, 10.0e9, 20e-6, 4.0),
            "two-tier" => Topology::two_tier(3),
            "fat-tree" => Topology::fat_tree(3),
            other => panic!("unknown --topology preset `{other}` (flat|two-tier|fat-tree)"),
        };
        println!("\n=== topology preset `{preset}` ===");
        for (j, tier) in topo.tiers.iter().enumerate() {
            println!(
                "  tier {j} ({:>12}): {:.1} GB/s, {:.0} us latency, {} slot(s)",
                tier.name,
                tier.bandwidth / 1e9,
                tier.latency * 1e6,
                tier.slots
            );
        }
        topology_report("vgg16", &vgg16(32), preset, &topo);
        topology_report("transformer", &transformer(&TransformerConfig::micro()), preset, &topo);
    }
}
