//! End-to-end training driver: the full system on a real workload.
//!
//! Trains a ~10M-parameter MLP (784→2048→2048→2048→10, the paper's own
//! model family) on synthetic 10-class data through the complete stack:
//! semantic graph → k-cut optimal plan → parallel engine over 4 virtual
//! devices executing PJRT shard kernels with real tiling-conversion
//! traffic — and cross-checks the loss trajectory against the serial AOT
//! artifact (`mlp_step`, lowered once by `python/compile/aot.py`).
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run with: `cargo run --release --example train_mlp_e2e -- [steps]`

use std::sync::Arc;
use std::time::Instant;

use soybean::coordinator::{init_mlp_params, ParallelTrainer, SerialTrainer, SyntheticData};
use soybean::models::{mlp, MlpConfig};
use soybean::planner::{classify, Planner, PlanFamily};
use soybean::runtime::{ArtifactRegistry, Client};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let cfg = MlpConfig::e2e();
    let dims = cfg.dims.clone();
    let g = mlp(&cfg);
    let params_m = g.weight_bytes() as f64 / 4e6;
    println!("model: {:?}, batch {}, {:.1}M parameters", dims, cfg.batch, params_m);

    let client = Arc::new(Client::cpu()?);
    let reg = ArtifactRegistry::load(std::path::Path::new("artifacts"))?;
    let params = init_mlp_params(1, &dims);
    let lr = 0.05;

    // Serial anchor: the whole training step as one AOT executable.
    let mut serial = SerialTrainer::from_artifact(&client, &reg, "mlp_step", params.clone(), lr)?;

    // Parallel: SOYBEAN's optimal 4-device plan through the engine.
    let plan = Planner::try_plan(&g, 2, PlanFamily::Soybean).unwrap();
    println!(
        "plan: {} over {} devices, {:.2} MB per step (vs DP {:.2} MB)",
        classify(&g, &plan.tiles),
        plan.devices(),
        plan.total_cost() as f64 / 1e6,
        soybean::planner::baselines::data_parallel(&g, 2).total_cost() as f64 / 1e6,
    );
    let mut parallel = ParallelTrainer::new(client.clone(), g, plan, &params, lr)?;

    let mut data = SyntheticData::new(99, dims[0], *dims.last().unwrap());
    let t0 = Instant::now();
    let mut first = None;
    let mut last = 0.0f32;
    println!("\n{:>6} {:>14} {:>14} {:>10}", "step", "serial loss", "parallel loss", "elapsed");
    for s in 0..steps {
        let (x, y) = data.batch(cfg.batch);
        let lp = parallel.step(&x, &y)?;
        // Cross-check against the serial artifact periodically (running it
        // every step would double the wall-clock for no extra signal).
        if s % 25 == 0 || s + 1 == steps {
            let ls = serial.step(&x, &y)?;
            println!("{s:>6} {ls:>14.4} {lp:>14.4} {:>9.1}s", t0.elapsed().as_secs_f64());
            assert!((ls - lp).abs() < 0.05 * ls.abs().max(0.1), "paths diverged at step {s}");
        } else {
            // Keep the serial params in lockstep so the comparison stays
            // meaningful across the whole run.
            let _ = serial.step(&x, &y)?;
        }
        first.get_or_insert(lp);
        last = lp;
    }
    let first = first.unwrap();
    println!(
        "\nloss {first:.4} -> {last:.4} over {steps} steps ({:.1}s, {:.1} steps/min)",
        t0.elapsed().as_secs_f64(),
        steps as f64 / t0.elapsed().as_secs_f64() * 60.0
    );
    println!(
        "engine traffic: {:.1} MB total, {} transfers, {} kernel launches",
        parallel.engine.metrics.total_bytes() as f64 / 1e6,
        parallel.engine.metrics.transfers,
        parallel.engine.metrics.kernel_launches
    );
    assert!(last < first * 0.5, "training did not converge");
    println!("converged ✓ (parallel ≡ serial throughout)");
    Ok(())
}
