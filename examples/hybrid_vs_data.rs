//! The §2.2 motivating example, measured end to end with real execution.
//!
//! The paper's opening argument: on a 5-layer MLP(300) with batch 400,
//! hybrid tiling moves ~42% fewer bytes than data parallelism. This
//! example verifies the claim twice —
//!
//! 1. analytically, with both the paper's simplified accounting and the §4
//!    conversion-cost model, and
//! 2. empirically, by running real training steps through the engine under
//!    both plans on 4 virtual devices and comparing the *metered* traffic
//!    (and checking the losses agree with each other to fp32 tolerance).
//!
//! Run with: `cargo run --release --example hybrid_vs_data`

use std::sync::Arc;

use soybean::coordinator::{init_mlp_params, ParallelTrainer, SyntheticData};
use soybean::figures;
use soybean::models::{mlp, MlpConfig};
use soybean::planner::{Planner, PlanFamily};
use soybean::runtime::Client;

fn main() -> anyhow::Result<()> {
    println!("{}", figures::example22());

    // Empirical check on 4 virtual devices with real buffers.
    let dims = vec![304usize, 304, 304, 304, 304, 304];
    let cfg = MlpConfig { batch: 400, dims: dims.clone(), bias: true };
    let g = mlp(&cfg);
    let client = Arc::new(Client::cpu()?);
    let mut data = SyntheticData::new(5, dims[0], *dims.last().unwrap());
    let (x, y) = data.batch(400);

    let mut results = Vec::new();
    for strat in [PlanFamily::DataParallel, PlanFamily::Soybean] {
        let params = init_mlp_params(3, &dims);
        let plan = Planner::try_plan(&g, 2, strat).unwrap();
        let mut t = ParallelTrainer::new(client.clone(), g.clone(), plan, &params, 0.05)?;
        let mut loss = 0.0;
        for _ in 0..3 {
            loss = t.step(&x, &y)?;
        }
        println!(
            "{:<8}: loss {:.4}, metered traffic {:.2} MB over 3 steps",
            strat.name(),
            loss,
            t.engine.metrics.total_bytes() as f64 / 1e6
        );
        results.push((loss, t.engine.metrics.total_bytes()));
    }
    let (dp_loss, dp_bytes) = results[0];
    let (soy_loss, soy_bytes) = results[1];
    assert!((dp_loss - soy_loss).abs() < 2e-3, "plans computed different numbers!");
    println!(
        "\nSOYBEAN moved {:.1}% less data than DP for identical numerics ✓",
        (1.0 - soy_bytes as f64 / dp_bytes as f64) * 100.0
    );
    Ok(())
}
