//! Quickstart: the full SOYBEAN pipeline in one page.
//!
//! 1. Build the training graph of a small MLP (the frontend's job).
//! 2. Find the communication-optimal tiling for 4 devices (the paper's
//!    k-cut algorithm) and compare against pure data/model parallelism.
//! 3. Run one real training step through the parallel engine (PJRT) and
//!    check it against the AOT Pallas-kernel artifact.
//!
//! Run with: `cargo run --release --example quickstart`
//! (needs `make artifacts` once beforehand).

use std::sync::Arc;

use soybean::coordinator::{init_mlp_params, ParallelTrainer, SerialTrainer, SyntheticData};
use soybean::models::{mlp, MlpConfig};
use soybean::planner::{classify, Planner, PlanFamily};
use soybean::runtime::{ArtifactRegistry, Client};
use soybean::sim::{try_simulate, try_simulate_classic_dp, SimConfig};

fn main() -> anyhow::Result<()> {
    // 1. The serial dataflow graph of one training step.
    let dims = vec![64usize, 128, 128, 10];
    let cfg = MlpConfig { batch: 32, dims: dims.clone(), bias: true };
    let g = mlp(&cfg);
    println!("semantic graph: {} ops, {} tensors\n", g.ops.len(), g.tensors.len());

    // 2. Plan for 4 devices; compare the three strategies.
    let sim_cfg = SimConfig::default();
    for strat in PlanFamily::all() {
        let plan = Planner::try_plan(&g, 2, strat).unwrap();
        let r = if strat == PlanFamily::DataParallel {
            try_simulate_classic_dp(&g, &plan, &sim_cfg).unwrap()
        } else {
            try_simulate(&g, &plan, &sim_cfg).unwrap()
        };
        println!(
            "{:<8}  comm {:>8.3} MB   simulated step {:>7.3} ms   ({})",
            strat.name(),
            plan.total_cost() as f64 / 1e6,
            r.step_s * 1e3,
            classify(&g, &plan.tiles),
        );
    }

    // 3. Real numbers: engine (optimal plan, 4 virtual devices) vs the
    //    serial AOT artifact whose layers run the Pallas kernel.
    let client = Arc::new(Client::cpu()?);
    let reg = ArtifactRegistry::load(std::path::Path::new("artifacts"))?;
    let params = init_mlp_params(42, &dims);
    let mut serial =
        SerialTrainer::from_artifact(&client, &reg, "mlp_step_small_pallas", params.clone(), 0.1)?;
    let plan = Planner::try_plan(&g, 2, PlanFamily::Soybean).unwrap();
    let mut parallel = ParallelTrainer::new(client.clone(), g, plan, &params, 0.1)?;

    let mut data = SyntheticData::new(7, dims[0], *dims.last().unwrap());
    println!("\nstep | serial (Pallas artifact) | parallel engine (4 devices)");
    for s in 0..5 {
        let (x, y) = data.batch(32);
        let ls = serial.step(&x, &y)?;
        let lp = parallel.step(&x, &y)?;
        println!("{s:>4} | {ls:>24.5} | {lp:>27.5}");
        assert!((ls - lp).abs() < 2e-3, "engine diverged from artifact");
    }
    println!(
        "\nengine moved {:.3} MB across {} transfers — numerics identical. ✓",
        parallel.engine.metrics.total_bytes() as f64 / 1e6,
        parallel.engine.metrics.transfers
    );
    Ok(())
}
