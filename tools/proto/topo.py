#!/usr/bin/env python3
"""Prototype of the SOYBEAN one-cut DP's *state-space geometry* for
candidate transformer graph designs.

Mirrors rust/src/graph (builder + autodiff), candidate_tiles, bfs_levels,
and the OneCutSolver's component construction, then reports, per design:

  - per-level boundary state counts (the DP sweep is prev*cur per level)
  - per-component tabulation state counts (capped at 50M in Rust)
  - total sweep work  sum_l prev_len*cur_len*ncomp

Calibrated against the existing zoo (mlp/cnn5/vgg16), which is known to
plan in <1s release.
"""
from collections import defaultdict, deque

INPUT, LABEL, WEIGHT, ACT, GRAD, WGRAD, UPD, SCALAR = range(8)

class G:
    def __init__(self):
        self.tensors = []  # (name, shape, kind)
        self.ops = []      # (name, kind, inputs[tid], outputs[tid])

    def t(self, name, shape, kind):
        self.tensors.append([name, list(shape), kind])
        return len(self.tensors) - 1

    def op(self, name, kind, ins, out_shape, out_kind):
        out = self.t(name + ".out", out_shape, out_kind)
        self.ops.append([name, kind, list(ins), [out]])
        return out

    def shape(self, t):
        return self.tensors[t][1]

    def kind(self, t):
        return self.tensors[t][2]


def out_kind_for(g, a, b):
    if g.kind(a) in (GRAD, WGRAD) or g.kind(b) in (GRAD, WGRAD):
        return GRAD
    return ACT


# ---- builder ops (subset + new transformer ops) ----
def matmul(g, name, a, b, ta=False, tb=False):
    sa, sb = g.shape(a), g.shape(b)
    m, ka = (sa[1], sa[0]) if ta else (sa[0], sa[1])
    kb, n = (sb[1], sb[0]) if tb else (sb[0], sb[1])
    assert ka == kb, (name, sa, sb, ta, tb)
    return g.op(name, ("MatMul", ta, tb), [a, b], [m, n], out_kind_for(g, a, b))

def bmm(g, name, a, b, ta=False, tb=False):
    sa, sb = g.shape(a), g.shape(b)
    assert sa[0] == sb[0], (name, sa, sb)
    m, ka = (sa[2], sa[1]) if ta else (sa[1], sa[2])
    kb, n = (sb[2], sb[1]) if tb else (sb[1], sb[2])
    assert ka == kb, (name, sa, sb, ta, tb)
    return g.op(name, ("BMM", ta, tb), [a, b], [sa[0], m, n], out_kind_for(g, a, b))

def bmm2(g, name, a, w, ta=False, tb=False):
    # rank-3 lhs x rank-2 shared rhs (design V2)
    sa, sw = g.shape(a), g.shape(w)
    m, ka = (sa[2], sa[1]) if ta else (sa[1], sa[2])
    kb, n = (sw[1], sw[0]) if tb else (sw[0], sw[1])
    assert ka == kb, (name, sa, sw)
    return g.op(name, ("BMM2", ta, tb), [a, w], [sa[0], m, n], out_kind_for(g, a, w))

def relu(g, name, x):
    return g.op(name, ("Ew", "Relu"), [x], g.shape(x), ACT)

def gelu(g, name, x):
    return g.op(name, ("Ew", "Gelu"), [x], g.shape(x), ACT)

def add(g, name, a, b):
    return g.op(name, ("Ew", "Add"), [a, b], g.shape(a), out_kind_for(g, a, b))

def bias_add(g, name, x, b_):
    return g.op(name, ("BiasAdd",), [x, b_], g.shape(x), ACT)

def conv2d(g, name, x, w, stride, pad):
    sx, sw = g.shape(x), g.shape(w)
    oh = (sx[1] + 2 * pad - sw[0]) // stride + 1
    ow = (sx[2] + 2 * pad - sw[1]) // stride + 1
    return g.op(name, ("Conv2d", stride, pad), [x, w], [sx[0], oh, ow, sw[3]], ACT)

def pool2(g, name, x):
    sx = g.shape(x)
    return g.op(name, ("Pool2",), [x], [sx[0], sx[1] // 2, sx[2] // 2, sx[3]], ACT)

def flatten(g, name, x):
    sx = g.shape(x)
    return g.op(name, ("Flatten",), [x], [sx[0], sx[1] * sx[2] * sx[3]], ACT)

def softmax_xent(g, name, logits, labels):
    return g.op(name, ("SoftmaxXent",), [logits, labels], [], SCALAR)

def layer_norm(g, name, x, gamma=None, beta=None):
    ins = [x] + ([gamma, beta] if gamma is not None else [])
    return g.op(name, ("LayerNorm", gamma is not None), ins, g.shape(x), ACT)

def softmax_rows(g, name, x):
    return g.op(name, ("Softmax",), [x], g.shape(x), ACT)

def split_heads(g, name, x, heads, seq):
    r, d = g.shape(x)
    b_ = r // seq
    return g.op(name, ("SplitHeads", heads, seq), [x], [b_ * heads, seq, d // heads], ACT)

def merge_heads(g, name, x, heads):
    gg, s, dh = g.shape(x)
    return g.op(name, ("MergeHeads", heads, s), [x], [gg // heads * s, heads * dh], ACT)

def split_heads3(g, name, x, heads):
    b_, s, d = g.shape(x)
    return g.op(name, ("SplitHeads3", heads), [x], [b_ * heads, s, d // heads], ACT)

def merge_heads3(g, name, x, heads):
    gg, s, dh = g.shape(x)
    return g.op(name, ("MergeHeads3", heads), [x], [gg // heads, s, heads * dh], ACT)


# ---- autodiff (mirrors rust append_backward) ----
def topo_order(g):
    ready = [True] * len(g.tensors)
    for _, _, _, outs in g.ops:
        for o in outs:
            ready[o] = False
    order, emitted = [], [False] * len(g.ops)
    while len(order) < len(g.ops):
        prog = False
        for i, (_, _, ins, outs) in enumerate(g.ops):
            if not emitted[i] and all(ready[t] for t in ins):
                emitted[i] = True
                for o in outs:
                    ready[o] = True
                order.append(i)
                prog = True
        assert prog, "cycle"
    return order


def append_backward(g, loss):
    grads = {}

    def accumulate(t, dt):
        if t not in grads:
            grads[t] = dt
        else:
            prev = grads[t]
            s = add(g, g.tensors[t][0] + ".grad_acc", prev, dt)
            grads[t] = s

    order = topo_order(g)[::-1]
    for opid in order:
        name, kind, ins, outs = [x for x in g.ops[opid]]
        ins = list(ins)
        out = outs[0]
        if kind[0] == "SoftmaxXent":
            d = None
        else:
            if out not in grads:
                continue
            d = grads[out]
        k0 = kind[0]
        if k0 == "SoftmaxXent":
            logits, labels = ins
            dl = g.op(name + ".bwd", ("SoftmaxXentGrad",), [logits, labels], g.shape(logits), GRAD)
            accumulate(logits, dl)
        elif k0 == "MatMul":
            a, w = ins
            da = g.op(name + ".bwd_data", ("MatMul", False, True), [d, w], g.shape(a), GRAD)
            accumulate(a, da)
            dw = g.op(name + ".bwd_w", ("MatMul", True, False), [a, d], g.shape(w), WGRAD)
            accumulate(w, dw)
        elif k0 == "BMM":
            _, ta, tb = kind
            a, b_ = ins
            if not tb:
                da = g.op(name + ".bwd_a", ("BMM", False, True), [d, b_], g.shape(a), GRAD)
                db = g.op(name + ".bwd_b", ("BMM", True, False), [a, d], g.shape(b_), WGRAD if g.kind(b_) == WEIGHT else GRAD)
            else:
                da = g.op(name + ".bwd_a", ("BMM", False, False), [d, b_], g.shape(a), GRAD)
                db = g.op(name + ".bwd_b", ("BMM", True, False), [d, a], g.shape(b_), GRAD)
            accumulate(a, da)
            accumulate(b_, db)
        elif k0 == "BMM2":
            a, w = ins
            da = g.op(name + ".bwd_data", ("BMM2", False, True), [d, w], g.shape(a), GRAD)
            accumulate(a, da)
            dw = g.op(name + ".bwd_w", ("BMM2red", ), [a, d], g.shape(w), WGRAD)
            accumulate(w, dw)
        elif k0 == "Conv2d":
            _, stride, pad = kind
            x, w = ins
            dx = g.op(name + ".bwd_data", ("Conv2dBwdData", stride, pad), [d, w], g.shape(x), GRAD)
            accumulate(x, dx)
            dw = g.op(name + ".bwd_filter", ("Conv2dBwdFilter", stride, pad), [x, d], g.shape(w), WGRAD)
            accumulate(w, dw)
        elif k0 == "BiasAdd":
            x, b_ = ins
            accumulate(x, d)
            db = g.op(name + ".bwd_b", ("ReduceSumRows",), [d], g.shape(b_), WGRAD)
            accumulate(b_, db)
        elif k0 == "Pool2":
            x = ins[0]
            dx = g.op(name + ".bwd", ("Pool2Bwd",), [d, x, out], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "Flatten":
            x = ins[0]
            dx = g.op(name + ".bwd", ("FlattenBwd",), [d], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "Ew" and kind[1] == "Relu":
            x = ins[0]
            dx = g.op(name + ".bwd", ("Ew", "ReluGrad"), [d, out], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "Ew" and kind[1] == "Gelu":
            x = ins[0]
            dx = g.op(name + ".bwd", ("Ew", "GeluGrad"), [d, x], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "Ew" and kind[1] == "Add":
            for i_ in ins:
                accumulate(i_, d)
        elif k0 == "LayerNorm":
            affine = kind[1]
            x = ins[0]
            if affine:
                gamma, beta = ins[1], ins[2]
                dx = g.op(name + ".bwd", ("LayerNormGrad",), [d, x, gamma], g.shape(x), GRAD)
                accumulate(x, dx)
                dg = g.op(name + ".bwd_g", ("LayerNormGammaGrad",), [d, x], g.shape(gamma), WGRAD)
                accumulate(gamma, dg)
                db = g.op(name + ".bwd_b", ("ReduceSumRows",), [d], g.shape(beta), WGRAD)
                accumulate(beta, db)
            else:
                dx = g.op(name + ".bwd", ("LayerNormGrad",), [d, x], g.shape(x), GRAD)
                accumulate(x, dx)
        elif k0 == "Softmax":
            x = ins[0]
            dx = g.op(name + ".bwd", ("SoftmaxGrad",), [d, out], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "SplitHeads":
            _, heads, seq = kind
            x = ins[0]
            dx = g.op(name + ".bwd", ("MergeHeads", heads, seq), [d], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "MergeHeads":
            _, heads, seq = kind
            x = ins[0]
            dx = g.op(name + ".bwd", ("SplitHeads", heads, seq), [d], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "SplitHeads3":
            _, heads = kind
            x = ins[0]
            dx = g.op(name + ".bwd", ("MergeHeads3", heads), [d], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "MergeHeads3":
            _, heads = kind
            x = ins[0]
            dx = g.op(name + ".bwd", ("SplitHeads3", heads), [d], g.shape(x), GRAD)
            accumulate(x, dx)
        else:
            raise RuntimeError(f"no grad rule for {kind}")

    for t, (nm, shape, kind) in enumerate(list(g.tensors)):
        if kind == WEIGHT and t in grads:
            g.op(nm + ".sgd", ("SgdUpdate",), [t, grads[t]], shape, UPD)


# ---- candidate_tiles mirror (with the planned rank-3 rule) ----
def n_cands(g, t, rank3_dims=(0,)):
    nm, shape, kind = g.tensors[t]
    r = len(shape)
    if r == 0:
        return 1
    if r == 4 and kind in (WEIGHT, WGRAD, UPD):
        dims = [2, 3]
    elif r == 4:
        dims = [0, 3]
    elif r == 3:
        dims = list(rank3_dims)
    else:
        dims = list(range(r))
    return 1 + sum(1 for d in dims if shape[d] >= 2 and shape[d] % 2 == 0)


def aliases(g):
    alias = list(range(len(g.tensors)))
    for _, kind, ins, outs in g.ops:
        if kind[0] == "SgdUpdate":
            alias[outs[0]] = ins[0]
    return alias


def bfs_levels(g):
    n = len(g.ops)
    touching = defaultdict(list)
    for i, (_, _, ins, outs) in enumerate(g.ops):
        for t in ins + outs:
            touching[t].append(i)
    adj = defaultdict(set)
    for ops in touching.values():
        for i, a in enumerate(ops):
            for b in ops[i + 1:]:
                adj[a].add(b)
                adj[b].add(a)
    level_of = [-1] * n
    maxl = 0
    for start in range(n):
        if level_of[start] != -1:
            continue
        base = 0 if start == 0 else maxl + 1
        level_of[start] = base
        q = deque([start])
        while q:
            u = q.popleft()
            maxl = max(maxl, level_of[u])
            for v in adj[u]:
                if level_of[v] == -1:
                    level_of[v] = level_of[u] + 1
                    q.append(v)
    levels = [[] for _ in range(maxl + 1)]
    for op, l in enumerate(level_of):
        levels[l].append(op)
    boundary = [[] for _ in range(max(0, len(levels) - 1))]
    internal = [[] for _ in range(len(levels))]
    for t in sorted(touching):
        ls = [level_of[o] for o in touching[t]]
        lo, hi = min(ls), max(ls)
        assert hi - lo <= 1, f"tensor {g.tensors[t][0]} spans {lo}..{hi}"
        if lo == hi:
            internal[lo].append(t)
        else:
            boundary[lo].append(t)
    return levels, boundary, internal, level_of


def analyze(g, label, rank3_dims=(0,), verbose=False):
    alias = aliases(g)
    levels, boundary, internal, level_of = bfs_levels(g)
    nl = len(levels)
    internal_level = [-1] * len(g.tensors)
    for l, ts in enumerate(internal):
        for t in ts:
            internal_level[t] = l

    cands = [n_cands(g, t, rank3_dims) for t in range(len(g.tensors))]
    bnd_states = []
    for b in boundary:
        p = 1
        for t in b:
            p *= cands[t]
        bnd_states.append(p)

    # components per level (alias-resolved, as in OneCutSolver::new)
    max_comp = 0
    comp_info = []
    for l, ops in enumerate(levels):
        parent = list(range(len(ops)))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        owner = {}
        for oi, op in enumerate(ops):
            _, _, ins, outs = g.ops[op]
            for t in ins + outs:
                t = alias[t]
                if internal_level[t] == l:
                    if t not in owner:
                        owner[t] = oi
                    else:
                        a, b_ = find(owner[t]), find(oi)
                        if a != b_:
                            parent[a] = b_
        groups = defaultdict(list)
        for oi, op in enumerate(ops):
            groups[find(oi)].append(op)
        for comp_ops in groups.values():
            tens = set()
            for op in comp_ops:
                _, _, ins, outs = g.ops[op]
                for t in ins + outs:
                    tens.add(alias[t])
            p = 1
            for t in tens:
                p *= cands[t]
            max_comp = max(max_comp, p)
            comp_info.append((l, len(comp_ops), p))

    sweep = 0
    for l in range(nl):
        prev = bnd_states[l - 1] if l > 0 else 1
        cur = bnd_states[l] if l + 1 < nl else 1
        ncomp = len(set())  # per-level comp count below
        sweep += prev * cur
    widest = max(len(lv) for lv in levels)
    maxb = max(bnd_states) if bnd_states else 1
    print(f"{label:28} ops={len(g.ops):4} tensors={len(g.tensors):4} levels={nl:3} "
          f"maxwidth={widest:3} max_bnd_states={maxb:>12,} max_comp_states={max_comp:>12,} "
          f"sweep~={sweep:>16,}")
    if verbose:
        for l in range(nl):
            bs = bnd_states[l] if l < len(bnd_states) else 1
            names = [g.ops[o][0] for o in levels[l]]
            bn = [(g.tensors[t][0], cands[t]) for t in (boundary[l] if l < len(boundary) else [])]
            print(f"  L{l:3} ({len(levels[l])} ops) bnd_states={bs:,}")
            print(f"       ops: {names}")
            print(f"       bnd: {bn}")
    return maxb, max_comp, sweep


# ---- zoo calibration graphs ----
def mlp_graph(batch, dims, bias=False):
    g = G()
    h = g.t("x", [batch, dims[0]], INPUT)
    y = g.t("y", [batch, dims[-1]], LABEL)
    nl = len(dims) - 1
    for l in range(nl):
        w = g.t(f"w{l}", [dims[l], dims[l + 1]], WEIGHT)
        h = matmul(g, f"fc{l}", h, w)
        if bias:
            b_ = g.t(f"b{l}", [dims[l + 1]], WEIGHT)
            h = bias_add(g, f"fc{l}.ba", h, b_)
        if l + 1 < nl:
            h = relu(g, f"fc{l}.relu", h)
    loss = softmax_xent(g, "loss", h, y)
    append_backward(g, loss)
    return g


def cnn5_graph(batch, image, cin, filters, classes):
    g = G()
    h = g.t("x", [batch, image, image, cin], INPUT)
    y = g.t("y", [batch, classes], LABEL)
    c = cin
    for l in range(5):
        w = g.t(f"conv{l}.w", [3, 3, c, filters], WEIGHT)
        h = conv2d(g, f"conv{l}", h, w, 1, 1)
        h = relu(g, f"conv{l}.relu", h)
        c = filters
    flat = flatten(g, "flatten", h)
    wf = g.t("fc.w", [image * image * filters, classes], WEIGHT)
    logits = matmul(g, "fc", flat, wf)
    loss = softmax_xent(g, "loss", logits, y)
    append_backward(g, loss)
    return g


def vgg16_graph(batch):
    g = G()
    h = g.t("x", [batch, 224, 224, 3], INPUT)
    y = g.t("y", [batch, 1000], LABEL)

    def block(h, name, convs, cin, cout):
        c = cin
        for i in range(convs):
            w = g.t(f"{name}.conv{i}.w", [3, 3, c, cout], WEIGHT)
            h = conv2d(g, f"{name}.conv{i}", h, w, 1, 1)
            h = relu(g, f"{name}.conv{i}.relu", h)
            c = cout
        return pool2(g, f"{name}.pool", h)

    h = block(h, "b1", 2, 3, 64)
    h = block(h, "b2", 2, 64, 128)
    h = block(h, "b3", 3, 128, 256)
    h = block(h, "b4", 3, 256, 512)
    h = block(h, "b5", 3, 512, 512)
    flat = flatten(g, "flatten", h)
    w1 = g.t("fc1.w", [25088, 4096], WEIGHT)
    f = matmul(g, "fc1", flat, w1)
    f = relu(g, "fc1.relu", f)
    w2 = g.t("fc2.w", [4096, 4096], WEIGHT)
    f = matmul(g, "fc2", f, w2)
    f = relu(g, "fc2.relu", f)
    w3 = g.t("fc3.w", [4096, 1000], WEIGHT)
    logits = matmul(g, "fc3", f, w3)
    loss = softmax_xent(g, "loss", logits, y)
    append_backward(g, loss)
    return g


# ---- transformer variants ----
def transformer_v1(batch, seq, d, heads, dff, layers, classes, affine=True):
    """rank-2 folded [B*S, D] + separate q/k/v + SplitHeads."""
    g = G()
    rows = batch * seq
    x = g.t("x", [rows, d], INPUT)
    y = g.t("y", [rows, classes], LABEL)
    h = x
    for l in range(layers):
        p = f"l{l}."
        ga = g.t(p + "ln1.g", [d], WEIGHT) if affine else None
        be = g.t(p + "ln1.b", [d], WEIGHT) if affine else None
        h1 = layer_norm(g, p + "ln1", h, ga, be)
        wq = g.t(p + "wq", [d, d], WEIGHT)
        wk = g.t(p + "wk", [d, d], WEIGHT)
        wv = g.t(p + "wv", [d, d], WEIGHT)
        q = matmul(g, p + "q", h1, wq)
        k = matmul(g, p + "k", h1, wk)
        v = matmul(g, p + "v", h1, wv)
        qh = split_heads(g, p + "shq", q, heads, seq)
        kh = split_heads(g, p + "shk", k, heads, seq)
        vh = split_heads(g, p + "shv", v, heads, seq)
        sc = bmm(g, p + "scores", qh, kh, False, True)
        pr = softmax_rows(g, p + "probs", sc)
        ct = bmm(g, p + "ctx", pr, vh, False, False)
        cm = merge_heads(g, p + "mh", ct, heads)
        wo = g.t(p + "wo", [d, d], WEIGHT)
        ao = matmul(g, p + "proj", cm, wo)
        h = add(g, p + "res1", h, ao)
        ga2 = g.t(p + "ln2.g", [d], WEIGHT) if affine else None
        be2 = g.t(p + "ln2.b", [d], WEIGHT) if affine else None
        h2 = layer_norm(g, p + "ln2", h, ga2, be2)
        w1 = g.t(p + "ff1.w", [d, dff], WEIGHT)
        f1 = matmul(g, p + "ff1", h2, w1)
        ge = gelu(g, p + "gelu", f1)
        w2 = g.t(p + "ff2.w", [dff, d], WEIGHT)
        f2 = matmul(g, p + "ff2", ge, w2)
        h = add(g, p + "res2", h, f2)
    gaf = g.t("lnf.g", [d], WEIGHT) if affine else None
    bef = g.t("lnf.b", [d], WEIGHT) if affine else None
    hf = layer_norm(g, "lnf", h, gaf, bef)
    wh = g.t("head.w", [d, classes], WEIGHT)
    logits = matmul(g, "head", hf, wh)
    loss = softmax_xent(g, "loss", logits, y)
    append_backward(g, loss)
    return g


if __name__ == "__main__":
    print("== calibration (known to plan <1s in release) ==")
    analyze(mlp_graph(512, [8192] * 5), "mlp fig8 512x8192")
    analyze(mlp_graph(128, [784, 2048, 2048, 2048, 10], bias=True), "mlp e2e (bias)")
    analyze(cnn5_graph(256, 6, 4, 2048, 10), "cnn5 fig9a")
    analyze(vgg16_graph(64), "vgg16/64")
    print()
    print("== transformer V1: rank-2 folded, separate qkv ==")
    analyze(transformer_v1(8, 128, 256, 4, 1024, 1, 256), "tfm V1 L1")
    analyze(transformer_v1(8, 128, 256, 4, 1024, 4, 256), "tfm V1 L4")
    analyze(transformer_v1(8, 128, 256, 4, 1024, 1, 256, affine=False), "tfm V1 L1 no-affine")

def ident(g, name, x):
    return g.op(name, ("Ew", "Ident"), [x], g.shape(x), g.kind(x) if g.kind(x) in (ACT, GRAD) else ACT)

def wire(g, name, x, n):
    for i in range(n):
        x = ident(g, f"{name}{i}", x)
    return x

def slice_heads(g, name, x, part, heads, seq):
    rows, d3 = g.shape(x)
    d = d3 // 3
    b_ = rows // seq
    return g.op(name, ("SliceHeads", part, heads, seq), [x], [b_ * heads, seq, d // heads], ACT)

# patch autodiff: handled via kinds below (Ident handled as Ew passthrough-with-op)
_old_ab = append_backward
def append_backward2(g, loss):
    grads = {}
    def accumulate(t, dt):
        if t not in grads:
            grads[t] = dt
        else:
            s = add(g, g.tensors[t][0] + ".grad_acc", grads[t], dt)
            grads[t] = s
    pending_slices = {}  # src tensor -> {part: grad}
    order = topo_order(g)[::-1]
    for opid in order:
        name, kind, ins, outs = g.ops[opid]
        ins = list(ins); out = outs[0]
        if kind[0] == "SoftmaxXent":
            d = None
        else:
            if out not in grads: continue
            d = grads[out]
        k0 = kind[0]
        if k0 == "Ew" and kind[1] == "Ident":
            x = ins[0]
            dx = g.op(name + ".bwd", ("Ew", "Ident"), [d], g.shape(x), GRAD)
            accumulate(x, dx)
        elif k0 == "SliceHeads":
            _, part, heads, seq = kind
            src = ins[0]
            pending_slices.setdefault(src, {})[part] = d
            if len(pending_slices[src]) == 3:
                ps = pending_slices[src]
                dqkv = g.op(g.tensors[src][0] + ".concat_bwd", ("ConcatHeads", heads, seq),
                            [ps[0], ps[1], ps[2]], g.shape(src), GRAD)
                accumulate(src, dqkv)
        else:
            # reuse the original rules by faking a one-op pass
            _dispatch(g, name, kind, ins, out, d, accumulate)
    for t, (nm, shape, kd) in enumerate(list(g.tensors)):
        if kd == WEIGHT and t in grads:
            g.op(nm + ".sgd", ("SgdUpdate",), [t, grads[t]], shape, UPD)

def _dispatch(g, name, kind, ins, out, d, accumulate):
    k0 = kind[0]
    if k0 == "SoftmaxXent":
        logits, labels = ins
        dl = g.op(name + ".bwd", ("SoftmaxXentGrad",), [logits, labels], g.shape(logits), GRAD)
        accumulate(logits, dl)
    elif k0 == "MatMul":
        a, w = ins
        da = g.op(name + ".bwd_data", ("MatMul", False, True), [d, w], g.shape(a), GRAD)
        accumulate(a, da)
        dw = g.op(name + ".bwd_w", ("MatMul", True, False), [a, d], g.shape(w), WGRAD)
        accumulate(w, dw)
    elif k0 == "BMM":
        _, ta, tb = kind
        a, b_ = ins
        if not tb:
            da = g.op(name + ".bwd_a", ("BMM", False, True), [d, b_], g.shape(a), GRAD)
            db = g.op(name + ".bwd_b", ("BMM", True, False), [a, d], g.shape(b_), GRAD)
        else:
            da = g.op(name + ".bwd_a", ("BMM", False, False), [d, b_], g.shape(a), GRAD)
            db = g.op(name + ".bwd_b", ("BMM", True, False), [d, a], g.shape(b_), GRAD)
        accumulate(a, da); accumulate(b_, db)
    elif k0 == "Ew" and kind[1] == "Gelu":
        x = ins[0]
        dx = g.op(name + ".bwd", ("Ew", "GeluGrad"), [d, x], g.shape(x), GRAD)
        accumulate(x, dx)
    elif k0 == "Ew" and kind[1] == "Add":
        for i_ in ins: accumulate(i_, d)
    elif k0 == "LayerNorm":
        affine = kind[1]; x = ins[0]
        if affine:
            gamma, beta = ins[1], ins[2]
            dx = g.op(name + ".bwd", ("LayerNormGrad",), [d, x, gamma], g.shape(x), GRAD)
            accumulate(x, dx)
            dg = g.op(name + ".bwd_g", ("LayerNormGammaGrad",), [d, x], g.shape(gamma), WGRAD)
            accumulate(gamma, dg)
            db = g.op(name + ".bwd_b", ("ReduceSumRows",), [d], g.shape(beta), WGRAD)
            accumulate(beta, db)
        else:
            dx = g.op(name + ".bwd", ("LayerNormGrad",), [d, x], g.shape(x), GRAD)
            accumulate(x, dx)
    elif k0 == "Softmax":
        x = ins[0]
        dx = g.op(name + ".bwd", ("SoftmaxGrad",), [d, out], g.shape(x), GRAD)
        accumulate(x, dx)
    elif k0 == "SplitHeads":
        _, heads, seq = kind; x = ins[0]
        dx = g.op(name + ".bwd", ("MergeHeads", heads, seq), [d], g.shape(x), GRAD)
        accumulate(x, dx)
    elif k0 == "MergeHeads":
        _, heads, seq = kind; x = ins[0]
        dx = g.op(name + ".bwd", ("SplitHeads", heads, seq), [d], g.shape(x), GRAD)
        accumulate(x, dx)
    else:
        raise RuntimeError(f"no grad rule for {kind}")

def transformer_v2(batch, seq, d, heads, dff, layers, classes, affine=True,
                   skip1=8, skip2=4, vwires=2, fused=True):
    """rank-2 folded + wires; fused=True uses qkv fused projection + SliceHeads."""
    g = G()
    rows = batch * seq
    x = g.t("x", [rows, d], INPUT)
    y = g.t("y", [rows, classes], LABEL)
    h = x
    for l in range(layers):
        p = f"l{l}."
        ga = g.t(p + "ln1.g", [d], WEIGHT) if affine else None
        be = g.t(p + "ln1.b", [d], WEIGHT) if affine else None
        h1 = layer_norm(g, p + "ln1", h, ga, be)
        if fused:
            wqkv = g.t(p + "wqkv", [d, 3 * d], WEIGHT)
            qkv = matmul(g, p + "qkv", h1, wqkv)
            qh = slice_heads(g, p + "sq", qkv, 0, heads, seq)
            kh = slice_heads(g, p + "sk", qkv, 1, heads, seq)
            vh = slice_heads(g, p + "sv", qkv, 2, heads, seq)
            branch_len = 8  # ln1,qkv,slice,scores,probs,ctx,mh,proj -> add edges 9? tune below
        else:
            wq = g.t(p + "wq", [d, d], WEIGHT); wk = g.t(p + "wk", [d, d], WEIGHT); wv = g.t(p + "wv", [d, d], WEIGHT)
            q = matmul(g, p + "q", h1, wq); k = matmul(g, p + "k", h1, wk); v = matmul(g, p + "v", h1, wv)
            qh = split_heads(g, p + "shq", q, heads, seq)
            kh = split_heads(g, p + "shk", k, heads, seq)
            vh = split_heads(g, p + "shv", v, heads, seq)
        sc = bmm(g, p + "scores", qh, kh, False, True)
        pr = softmax_rows(g, p + "probs", sc)
        vw = wire(g, p + "vw", vh, vwires)
        ct = bmm(g, p + "ctx", pr, vw, False, False)
        cm = merge_heads(g, p + "mh", ct, heads)
        wo = g.t(p + "wo", [d, d], WEIGHT)
        ao = matmul(g, p + "proj", cm, wo)
        hs = wire(g, p + "rw", h, skip1 if fused else skip1 + 1)
        h = add(g, p + "res1", hs, ao)
        ga2 = g.t(p + "ln2.g", [d], WEIGHT) if affine else None
        be2 = g.t(p + "ln2.b", [d], WEIGHT) if affine else None
        h2 = layer_norm(g, p + "ln2", h, ga2, be2)
        w1 = g.t(p + "ff1.w", [d, dff], WEIGHT)
        f1 = matmul(g, p + "ff1", h2, w1)
        ge = gelu(g, p + "gelu", f1)
        w2 = g.t(p + "ff2.w", [dff, d], WEIGHT)
        f2 = matmul(g, p + "ff2", ge, w2)
        hs2 = wire(g, p + "rw2_", h, skip2)
        h = add(g, p + "res2", hs2, f2)
    gaf = g.t("lnf.g", [d], WEIGHT) if affine else None
    bef = g.t("lnf.b", [d], WEIGHT) if affine else None
    hf = layer_norm(g, "lnf", h, gaf, bef)
    wh = g.t("head.w", [d, classes], WEIGHT)
    logits = matmul(g, "head", hf, wh)
    loss = softmax_xent(g, "loss", logits, y)
    append_backward2(g, loss)
    return g

print()
print("== transformer V2/V3: wires (+ optional fused qkv) ==")
analyze(transformer_v2(8, 128, 256, 4, 1024, 1, 256, fused=False), "V2 sep-qkv wires L1")
analyze(transformer_v2(8, 128, 256, 4, 1024, 4, 256, fused=False), "V2 sep-qkv wires L4")
analyze(transformer_v2(8, 128, 256, 4, 1024, 1, 256, fused=True), "V3 fused-qkv wires L1")
analyze(transformer_v2(8, 128, 256, 4, 1024, 4, 256, fused=True), "V3 fused-qkv wires L4")
