#!/usr/bin/env python3
"""Topology-aware candidate-search mirror (ISSUE-4 design validation).

Pipeline per candidate plan: per-cut tiles -> lowering pass 1+2 (programs)
-> shard compute model -> discrete-event engine on a hierarchical topology.
Searches per-cut choice vectors over {B(yte-greedy), W(eighted-greedy),
D(ata-parallel), M(odel-parallel)}^k on the transformer micro-4L workload
and reports engine step times. This is how the `Topology::two_tier`
preset and the candidate portfolio of `planner::plan_topology_aware` were
chosen: under ethernet (1.25 GB/s, 50 us) over a one-slot PCIe bus
(12.5 GB/s, 20 us), the weighted-greedy plan (W at the inner cuts —
identical to the all-W `try_k_cut_weighted` plan after dedup) pays
~0.5 MB more bytes at the contended innermost cut to drop 4 collectives
and lands a ~5% strictly faster engine step than byte-greedy; every
strategy-mix candidate (D/M at any cut) is worse. Run: PRESET=ethpcie
python3 topo_search.py (takes a few minutes; 13+ DP solves in pure
Python).
"""
import heapq, itertools, math, os, sys, io, contextlib
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
with contextlib.redirect_stdout(io.StringIO()):
    from topo import (G, INPUT, LABEL, WEIGHT, ACT, GRAD, WGRAD, UPD, SCALAR,
                      aliases, bfs_levels, mlp_graph, transformer_v2)
from cost import (op_cost, candidates, price, dp_assignment, apply_cut,
                  bytes_of, REP, S, INF, semantics, feasible, req_tile, conv_cost)
from collections import defaultdict

NONE = ("none",)

def op_cost_detailed(g, op, ins_t, out_t):
    name, kind, ins, outs = op
    sem = semantics(g, op)
    bz = bytes_of(g, outs[0])
    best = None
    def consider(total, reqs, prod):
        nonlocal best
        if best is None or total < best[0]:
            best = (total, reqs, prod)
    if sem[0] == "mm":
        _, x, y, z = sem
        tx, ty, tz = ins[0], ins[1], outs[0]
        bx, by = bytes_of(g, tx), bytes_of(g, ty)
        forms = [
            (req_tile(("d", x[0][1])), REP, ("tile", req_tile(("d", z[0][1])))),
            (REP, req_tile(("d", y[1][1])), ("tile", req_tile(("d", z[1][1])))),
            (req_tile(("d", x[1][1])), req_tile(("d", y[0][1])), ("red",)),
        ]
        for rx, ry, prod in forms:
            if not feasible(g, tx, rx) or not feasible(g, ty, ry): continue
            if prod[0] == "tile" and not feasible(g, tz, prod[1]): continue
            c = conv_cost(bx, ("tile", ins_t[0]), rx) + conv_cost(by, ("tile", ins_t[1]), ry)
            c += conv_cost(bz, prod, out_t)
            consider(c, [rx, ry], prod)
        return best
    _, splittable, in_maps, out_map, allow_rep = sem
    if allow_rep:
        c = sum(conv_cost(bytes_of(g, t), ("tile", ins_t[i]), REP) for i, t in enumerate(ins))
        c += conv_cost(bz, ("tile", REP), out_t)
        consider(c, [REP]*len(ins), ("tile", REP))
    for ax, ok in enumerate(splittable):
        if not ok: continue
        c = 0; reqs = []; bad = False
        for i, m in enumerate(in_maps):
            r = req_tile(m[ax])
            if not feasible(g, ins[i], r): bad = True; break
            c += conv_cost(bytes_of(g, ins[i]), ("tile", ins_t[i]), r)
            reqs.append(r)
        if bad: continue
        if out_map[ax] == NONE:
            prod = ("red",)
        else:
            t = S(out_map[ax][1])
            if not feasible(g, outs[0], t): continue
            prod = ("tile", t)
        c += conv_cost(bz, prod, out_t)
        consider(c, reqs, prod)
    return best

def scatter_axis(shape):
    for i, d in enumerate(shape):
        if d >= 2 and d % 2 == 0: return i
    return None

def share(P, n, r):
    return P // n + (1 if r < P % n else 0)

# ---------------- weighted / parametrized one-cut DP ----------------
def one_cut_cost(g, cost_fn):
    """dp.py's one_cut with a pluggable per-op cost function."""
    alias = aliases(g)
    levels, boundary, internal, level_of = bfs_levels(g)
    nl = len(levels)
    nt = len(g.tensors)
    cands = [candidates(g, t) for t in range(nt)]
    internal_level = [-1] * nt
    for l, ts in enumerate(internal):
        for t in ts: internal_level[t] = l
    boundary_level = [-1] * nt
    pos_in_boundary = [-1] * nt
    for l, b in enumerate(boundary):
        for i, t in enumerate(b):
            boundary_level[t] = l; pos_in_boundary[t] = i

    comps_per_level = []
    for l, ops in enumerate(levels):
        parent = list(range(len(ops)))
        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]; x = parent[x]
            return x
        owner = {}
        for oi, op in enumerate(ops):
            _, _, ins, outs = g.ops[op]
            for t in ins + outs:
                t = alias[t]
                if internal_level[t] == l:
                    if t not in owner: owner[t] = oi
                    else:
                        a, b_ = find(owner[t]), find(oi)
                        if a != b_: parent[a] = b_
        groups = defaultdict(list)
        for oi, op in enumerate(ops):
            groups[find(oi)].append(op)
        comps = []
        for root in sorted(groups):
            comp_ops = groups[root]
            bids, iids = [], []
            for op in comp_ops:
                _, _, ins, outs = g.ops[op]
                for t in ins + outs:
                    t = alias[t]
                    if internal_level[t] == l:
                        if t not in iids: iids.append(t)
                    elif t not in bids: bids.append(t)
            bids.sort(); iids.sort()
            comps.append((comp_ops, bids, iids))
        comps_per_level.append(comps)

    def dec(idx, rad):
        out = []
        for r in rad:
            out.append(idx % r); idx //= r
        return out

    tabs_per_level = []
    for l, comps in enumerate(comps_per_level):
        tabs = []
        for comp_ops, bids, iids in comps:
            brad = [len(cands[t]) for t in bids]
            irad = [len(cands[t]) for t in iids]
            blen = 1
            for r in brad: blen *= r
            ilen = 1
            for r in irad: ilen *= r
            table = []
            for bidx in range(blen):
                bdig = dec(bidx, brad)
                best = (INF, 0)
                for iidx in range(ilen):
                    idig = dec(iidx, irad)
                    assign = {}
                    for i, t in enumerate(bids): assign[t] = cands[t][bdig[i]]
                    for i, t in enumerate(iids): assign[t] = cands[t][idig[i]]
                    cost = 0
                    for op in comp_ops:
                        _, _, ins, outs = g.ops[op]
                        c = cost_fn(g, g.ops[op],
                                    [assign[alias[t]] for t in ins],
                                    assign[alias[outs[0]]])
                        cost += c
                        if cost >= best[0]: break
                    if cost < best[0]: best = (cost, iidx)
                table.append(best)
            tabs.append((table, brad, bids, iids, irad))
        tabs_per_level.append(tabs)

    bnd_rad = [[len(cands[t]) for t in b] for b in boundary]
    bnd_len = []
    for rad in bnd_rad:
        p = 1
        for r in rad: p *= r
        bnd_len.append(p)

    dp = []
    for l in range(nl):
        prev_len = bnd_len[l-1] if l > 0 else 1
        cur_len = bnd_len[l] if l + 1 < nl else 1
        comp_contrib = []
        for (table, brad, bids, iids, irad) in tabs_per_level[l]:
            mults = []
            m = 1
            for r in brad:
                mults.append(m); m *= r
            wprev, wcur = [], []
            for i, t in enumerate(bids):
                if l > 0 and boundary_level[t] == l - 1:
                    wprev.append((pos_in_boundary[t], mults[i]))
                else:
                    wcur.append((pos_in_boundary[t], mults[i]))
            def contrib(ln, rad, w):
                out = [0] * ln
                dig = [0] * len(rad)
                for slot in range(ln):
                    s = 0
                    for (p_, m_) in w: s += dig[p_] * m_
                    out[slot] = s
                    for j in range(len(rad)):
                        dig[j] += 1
                        if dig[j] < rad[j]: break
                        dig[j] = 0
                return out
            cp = contrib(prev_len, bnd_rad[l-1] if l > 0 else [], wprev)
            cc = contrib(cur_len, bnd_rad[l] if l + 1 < nl else [], wcur)
            comp_contrib.append((table, cp, cc))
        cur_dp = [(INF, 0)] * cur_len
        for q in range(cur_len):
            best = (INF, 0)
            for p in range(prev_len):
                base = 0 if l == 0 else dp[l-1][p][0]
                if base >= best[0]: continue
                cost = base
                for (table, cp, cc) in comp_contrib:
                    cost += table[cp[p] + cc[q]][0]
                    if cost >= best[0]: break
                if cost < best[0]: best = (cost, p)
            cur_dp[q] = best
        dp.append(cur_dp)

    final_cost, state = min((c, i) for i, (c, _) in enumerate(dp[nl-1]))
    if final_cost >= INF: return None, None

    bdig = [None] * len(boundary)
    for l in range(nl - 1, -1, -1):
        prev_state = dp[l][state][1]
        if l >= 1: bdig[l-1] = dec(prev_state, bnd_rad[l-1])
        if l + 1 < nl: bdig[l] = dec(state, bnd_rad[l])
        state = prev_state
    tiles = [REP] * nt
    for l, b in enumerate(boundary):
        for i, t in enumerate(b):
            tiles[t] = cands[t][bdig[l][i]]
    for l, tabs in enumerate(tabs_per_level):
        for (table, brad, bids, iids, irad) in tabs:
            mults = []
            m = 1
            for r in brad:
                mults.append(m); m *= r
            idx = 0
            for i, t in enumerate(bids):
                idx += bdig[boundary_level[t]][pos_in_boundary[t]] * mults[i]
            iidx = table[idx][1]
            idig = dec(iidx, irad)
            for i, t in enumerate(iids):
                tiles[t] = cands[t][idig[i]]
    for t in range(nt):
        tiles[t] = tiles[alias[t]]
    return final_cost, tiles

def byte_cost_fn(g, op, ins_t, out_t):
    return op_cost(g, op, ins_t, out_t)

def make_weighted_fn(W, C):
    """bytes*W + C*[bytes>0], fixed-point; INF passthrough."""
    def f(g, op, ins_t, out_t):
        b = op_cost(g, op, ins_t, out_t)
        if b >= INF: return INF
        if b == 0: return 0
        return b * W + C
    return f

# ---------------- MP per-cut tiles (mirror of model_parallel_tiles k=1) --
def mp_assignment(g):
    tiles = []
    for t, (nm, shape, kind) in enumerate(g.tensors):
        r = len(shape)
        fits = lambda d: shape[d] % 2 == 0 and shape[d] >= 2
        if kind in (WEIGHT, WGRAD, UPD) and r == 2 and fits(0): tiles.append(S(0))
        elif kind in (WEIGHT, WGRAD, UPD) and r == 4 and fits(3): tiles.append(S(3))
        elif kind in (WEIGHT, WGRAD, UPD) and r == 1 and fits(0): tiles.append(S(0))
        elif kind == ACT and r == 2 and fits(1): tiles.append(S(1))
        elif kind == ACT and r == 4 and fits(3): tiles.append(S(3))
        elif kind == GRAD and r == 4 and fits(3): tiles.append(S(3))
        else: tiles.append(REP)
    return tiles

# ---------------- topology ----------------
class Tier:
    def __init__(self, bw, lat, slots): self.bw, self.lat, self.slots = bw, lat, slots

def tier_of(tiers, cut):
    return tiers[min(cut, len(tiers) - 1)]

def transfer_seconds(tiers, cut, pair_bytes):
    l = tier_of(tiers, cut)
    if pair_bytes == 0: return l.lat
    pairs = float(1 << cut)
    agg = l.bw * min(l.slots, pairs)
    return pair_bytes * pairs / agg + l.lat

def two_tier(inter_bw, inter_lat, intra_bw, intra_lat, intra_slots, k):
    return [Tier(inter_bw, inter_lat, 1.0)] + [Tier(intra_bw, intra_lat, intra_slots)] * (k - 1)

# ---------------- compute model (mirror of sim/compute.rs) ----------------
PEAK = 2.9e12
KNEE, FLOOR = 512.0, 0.05

def gemm_eff(m, k, n):
    mind = min(m, k, n)
    return max(FLOOR, min(1.0, math.sqrt(mind / KNEE)))

def vol(s):
    p = 1
    for d in s: p *= d
    return float(p)

VIEW_KINDS = {"SplitHeads", "MergeHeads", "SliceHeads", "ConcatHeads",
              "SplitHeads3", "MergeHeads3"}

def shard_seconds(g, op, local_ins, local_out):
    name, kind, ins, outs = op
    k0 = kind[0]
    if k0 == "Ew" and kind[1] == "Ident": return 0.0
    if k0 in VIEW_KINDS: return 0.0
    if k0 == "MatMul":
        _, ta, tb = kind
        m, kk = (local_ins[0][1], local_ins[0][0]) if ta else (local_ins[0][0], local_ins[0][1])
        n = local_out[1]
        fl = 2.0 * m * kk * n
        return fl / (PEAK * gemm_eff(m, kk, n))
    if k0 == "BMM":
        _, ta, tb = kind
        m, kk = (local_ins[0][2], local_ins[0][1]) if ta else (local_ins[0][1], local_ins[0][2])
        n = local_out[2]
        fl = 2.0 * local_ins[0][0] * m * kk * n
        return fl / (PEAK * gemm_eff(m, kk, n))
    if k0 in ("LayerNorm", "LayerNormGrad", "Softmax", "SoftmaxGrad",
              "SoftmaxXent", "SoftmaxXentGrad"):
        fl = 8.0 * vol(local_ins[0])
        return fl / (PEAK * 0.04)
    fl = 2.0 * max(vol(local_out), vol(local_ins[0]))
    return fl / (PEAK * 0.04)

def build_shard_locals(g, tiles_per_cut, k):
    """Mirror of try_build_shard_tasks: per op, stacked local in/out shapes."""
    locals_per_op = []
    for opid, op in enumerate(g.ops):
        name, kind, ins, outs = op
        lg = G()
        lg.tensors = [[n, list(s), kd] for n, s, kd in g.tensors]
        lg.ops = g.ops
        ok = True
        for j in range(k):
            ins_t = [tiles_per_cut[j][t] for t in ins]
            out_t = tiles_per_cut[j][outs[0]]
            det = op_cost_detailed(lg, op, ins_t, out_t)
            if det is None or det[0] >= INF:
                ok = False; break
            _, reqs, prod = det
            for slot, r in enumerate(reqs):
                if r != REP:
                    lg.tensors[ins[slot]][1][r[1]] //= 2
            if prod[0] == "tile" and prod[1] != REP:
                lg.tensors[outs[0]][1][prod[1][1]] //= 2
        if not ok:
            return None
        locals_per_op.append(([lg.tensors[t][1] for t in ins], lg.tensors[outs[0]][1]))
    return locals_per_op

# ---------------- lowering pass 1+2 (mirror of lowering.rs) ----------------
def lower_program(g, tiles_per_cut, k):
    """Returns (programs, meta, comp_per_device) or None if infeasible."""
    devices = 1 << k
    # pass 1: conversions per (cut, op)
    per_cut = []
    cur = g
    for j in range(k):
        tiles = tiles_per_cut[j]
        convs = []
        for op in cur.ops:
            name, kind, ins, outs = op
            ins_t = [tiles[t] for t in ins]
            out_t = tiles[outs[0]]
            det = op_cost_detailed(cur, op, ins_t, out_t)
            if det is None or det[0] >= INF: return None
            _, reqs, prod = det
            in_convs = []
            for i, t in enumerate(ins):
                b = conv_cost(bytes_of(cur, t), ("tile", ins_t[i]), reqs[i])
                if b > 0: in_convs.append((t, b))
            tz = outs[0]
            ob = conv_cost(bytes_of(cur, tz), prod, out_t)
            out_conv = None
            if ob > 0:
                out_conv = (tz, prod, out_t, ob, scatter_axis(cur.tensors[tz][1]))
            convs.append((in_convs, out_conv))
        per_cut.append(convs)
        cur = apply_cut(cur, tiles)

    locals_per_op = build_shard_locals(g, tiles_per_cut, k)
    if locals_per_op is None: return None

    meta = []   # per gid: cut
    progs = [[] for _ in range(devices)]

    def start(cut, pair_bytes):
        gid = len(meta)
        meta.append(cut)
        n = devices >> cut
        for d in range(devices):
            progs[d].append(('T', gid, share(pair_bytes, n, d & (n - 1))))
        return gid

    def wait(gid):
        for d in range(devices):
            progs[d].append(('W', gid))

    pending = defaultdict(list)
    comp = 0.0
    for opid, op in enumerate(g.ops):
        name, kind, ins, outs = op
        for t in ins:
            for gid in pending[t]: wait(gid)
            pending[t] = []
        own = []
        for j in range(k):
            for (t, b) in per_cut[j][opid][0]:
                own.append(start(j, b))
        for gid in own: wait(gid)
        lin, lout = locals_per_op[opid]
        s = shard_seconds(g, op, lin, lout)
        comp += s
        for d in range(devices):
            progs[d].append(('C', s))
        for j in range(k):
            oc = per_cut[j][opid][1]
            if oc is None: continue
            tz, prod, out_t, ob, ax = oc
            if prod[0] == "tile":
                pending[tz].append(start(j, ob))
            elif out_t != REP:           # Red -> Split
                pending[tz].append(start(j, ob))
            elif ax is not None:         # Red -> Rep allreduce decomposition
                rs = start(j, ob // 2)
                wait(rs)
                pending[tz].append(start(j, ob - ob // 2))
            else:                        # SendRecv exchange
                pending[tz].append(start(j, ob))
    for t in sorted(pending):
        for gid in pending[t]: wait(gid)
    return progs, meta, comp

# ---------------- engine (mirror of sim/engine.rs run_program) ----------------
def run_engine(k, progs, meta, tiers):
    devices = 1 << k
    instances = {}
    for gid, cut in enumerate(meta):
        for pair in range(1 << cut):
            instances[(gid, pair)] = dict(bytes=0, issued=0, ready=0.0, comp=None, waiters=[])
    pc = [0]*devices; end = [0.0]*devices; fin = [False]*devices
    heap = []; seq = 0
    for d in range(devices):
        seq += 1; heapq.heappush(heap, (0.0, seq, ('dev', d)))
    while heap:
        time, _, ev = heapq.heappop(heap)
        if ev[0] == 'done':
            _, gid, pair = ev
            inst = instances[(gid, pair)]
            ws = inst['waiters']; inst['waiters'] = []
            for w in ws:
                seq += 1; heapq.heappush(heap, (time, seq, ('dev', w)))
            continue
        d = ev[1]; t = time; prog = progs[d]
        while True:
            if pc[d] == len(prog):
                end[d] = t; fin[d] = True; break
            ins = prog[pc[d]]
            if ins[0] == 'C':
                t += ins[1]; pc[d] += 1
            elif ins[0] == 'W':
                gid = ins[1]; cut = meta[gid]; pair = d >> (k - cut)
                inst = instances[(gid, pair)]
                if inst['comp'] is not None:
                    if inst['comp'] > t: t = inst['comp']
                    pc[d] += 1
                else:
                    inst['waiters'].append(d); break
            else:
                gid = ins[1]; cut = meta[gid]; pair = d >> (k - cut)
                members = devices >> cut
                inst = instances[(gid, pair)]
                inst['bytes'] += ins[2]; inst['issued'] += 1
                inst['ready'] = max(inst['ready'], t)
                if inst['issued'] == members:
                    dur = transfer_seconds(tiers, cut, inst['bytes'])
                    cmp_ = inst['ready'] + dur; inst['comp'] = cmp_
                    seq += 1; heapq.heappush(heap, (cmp_, seq, ('done', gid, pair)))
                pc[d] += 1
    assert all(fin), "deadlock"
    return max(end)

# ---------------- candidate generation + search ----------------
def make_plan(g, k, choices, tiers):
    """choices: string over B(yte), W(eighted), D(ata-par), M(odel-par)."""
    alias = aliases(g)
    cur = g
    tiles_per_cut = []
    costs = []
    for j, ch in enumerate(choices):
        if ch == 'B':
            c, tiles = one_cut_cost(cur, byte_cost_fn)
            if tiles is None: return None
        elif ch == 'W':
            l = tier_of(tiers, j)
            pairs = float(1 << j)
            agg = l.bw * min(l.slots, pairs)
            ps_per_byte = 1e12 * pairs / agg
            W = max(1, round(ps_per_byte * 256.0))
            C = round(l.lat * 1e12 * 256.0)
            _, tiles = one_cut_cost(cur, make_weighted_fn(W, C))
            if tiles is None: return None
        elif ch == 'D':
            tiles = dp_assignment(cur)
            for t in range(len(tiles)): tiles[t] = tiles[alias[t]]
        elif ch == 'M':
            tiles = mp_assignment(cur)
            for t in range(len(tiles)): tiles[t] = tiles[alias[t]]
        c = price(cur, tiles)
        if c >= INF: return None
        costs.append(c)
        tiles_per_cut.append(tiles)
        cur = apply_cut(cur, tiles)
    return tiles_per_cut, costs

def evaluate(g, k, tiers, choices_list):
    results = {}
    plans_seen = {}
    for ch in choices_list:
        mp = make_plan(g, k, ch, tiers)
        if mp is None:
            results[ch] = None; continue
        tiles_per_cut, costs = mp
        key = tuple(tuple(t) for cut in tiles_per_cut for t in cut)
        if key in plans_seen:
            results[ch] = plans_seen[key] + ('dup',)
            continue
        lp = lower_program(g, tiles_per_cut, k)
        if lp is None:
            results[ch] = None; continue
        progs, meta, comp = lp
        step = run_engine(k, progs, meta, tiers)
        theorem1 = sum((1 << i) * c for i, c in enumerate(costs))
        res = (step, comp, theorem1, costs)
        plans_seen[key] = res
        results[ch] = res
    return results

def evaluate_tree(g, k, tiers, alphabet):
    """Expand choice vectors level by level, memoizing DP solves per prefix."""
    import time
    results = {}
    def tiles_for(cur, ch, j):
        alias = aliases(cur)
        if ch == 'B':
            _, tiles = one_cut_cost(cur, byte_cost_fn)
            return tiles
        if ch == 'W':
            l = tier_of(tiers, j)
            pairs = float(1 << j)
            agg = l.bw * min(l.slots, pairs)
            W = max(1, round(1e12 * pairs / agg * 256.0))
            C = round(l.lat * 1e12 * 256.0)
            _, tiles = one_cut_cost(cur, make_weighted_fn(W, C))
            return tiles
        if ch == 'D':
            tiles = dp_assignment(cur)
        else:
            tiles = mp_assignment(cur)
        for t in range(len(tiles)):
            tiles[t] = tiles[alias[t]]
        return tiles
    def expand(cur, prefix, tiles_acc, costs_acc):
        j = len(prefix)
        if j == k:
            results[prefix] = (list(tiles_acc), list(costs_acc))
            return
        for ch in alphabet:
            t0 = time.time()
            tiles = tiles_for(cur, ch, j)
            if tiles is None:
                continue
            c = price(cur, tiles)
            if c >= INF:
                continue
            if ch in 'BW':
                print(f"  solve {prefix+ch}: {time.time()-t0:.1f}s d={c:,}", flush=True)
            expand(apply_cut(cur, tiles), prefix + ch, tiles_acc + [tiles], costs_acc + [c])
    expand(g, '', [], [])
    return results

def count_transfers(g, tiles_per_cut, k):
    lp = lower_program(g, tiles_per_cut, k)
    if lp is None: return None
    progs, meta, comp = lp
    per_tier = [0]*k
    for cut in meta: per_tier[cut] += 1
    return per_tier

if __name__ == "__main__":
    k = 3
    import os
    preset = os.environ.get('PRESET', 'ethnv')
    if preset == 'ethnv':
        tiers = two_tier(1.25e9, 50e-6, 50e9, 5e-6, 4.0, k)
    elif preset == 'ethpcie':
        tiers = two_tier(1.25e9, 50e-6, 12.5e9, 20e-6, 1.0, k)
    elif preset == 'ethpcie2':
        tiers = two_tier(1.25e9, 50e-6, 12e9, 20e-6, 2.0, k)
    print('preset', preset)
    g = transformer_v2(8, 128, 256, 4, 1024, 4, 256, fused=True)
    plans = evaluate_tree(g, k, tiers, 'BWDM')
    res = {}
    seen = {}
    for ch, (tiles_per_cut, costs) in plans.items():
        key = tuple(tuple(t) for cut in tiles_per_cut for t in cut)
        if key in seen:
            res[ch] = seen[key]; continue
        lp = lower_program(g, tiles_per_cut, k)
        if lp is None:
            continue
        progs, meta, comp = lp
        step = run_engine(k, progs, meta, tiers)
        theorem1 = sum((1 << i) * c for i, c in enumerate(costs))
        seen[key] = res[ch] = (step, comp, theorem1, costs)
    flat = res['BBB']
    desc = " | ".join(f"{t.bw/1e9:g}GB/s/{t.lat*1e6:g}us/slots{t.slots:g}" for t in tiers)
    print(f"transformer micro-4L, two-tier 2x4 preset `{preset}` ({desc})")
    print(f"flat BBB: step={flat[0]*1e3:.3f}ms compute={flat[1]*1e3:.3f}ms t1={flat[2]:,}")
    rows = []
    for ch, r in sorted(res.items()):
        if r is None:
            continue
        step = r[0]
        rows.append((step, ch, r))
    rows.sort()
    for step, ch, r in rows[:20]:
        mark = " <-- FLAT" if ch == 'BBB' else ""
        counts = count_transfers(g, plans[ch][0], k)
        deltas = ','.join(f"{c/1e6:.2f}M" for c in r[3])
        print(f"  {ch}: step={step*1e3:8.3f}ms  t1={r[2]:>13,} d=[{deltas}] nx={counts}{mark}")
    best = rows[0]
    print(f"\nbest {best[1]} step {best[0]*1e3:.3f}ms vs flat {flat[0]*1e3:.3f}ms "
          f"-> improvement {(1 - best[0]/flat[0])*100:.1f}%")
