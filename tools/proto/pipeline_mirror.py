#!/usr/bin/env python3
"""Executable mirror of the pipeline axis (rust/src/{planner/strategy.rs,
sim/pipeline.rs, spmd/pipeline.rs}).

Three pieces of PR-10 logic are numeric enough to be worth validating
outside the type system, so this mirror re-implements them in plain
Python and property-checks them:

1. **The greedy list scheduler** (sim/pipeline.rs): the same eligibility
   rules (FIFO microbatches per cell, GPipe drain-all, the 1F1B
   in-flight cap), the same pick rule (earliest start, 1F1B prefers
   backward at ties), over the same cell structure `Strategy::try_build`
   emits (F0..F(S-1), B(S-2)..B0 — the last stage's backward fuses into
   its forward cell). Swept over stage counts, microbatch counts, cell
   times and transfer times, asserting: no deadlock, makespan <= the
   serial-stage reference, GPipe's stage-0 stash == m, 1F1B's stash <=
   its pipeline depth, and bubble in [0, 1). Notably NOT asserted:
   1F1B <= GPipe on step time — with heterogeneous cell times the
   in-flight cap can delay tail forwards and cost up to ~1.5x (this
   sweep found 1.47x), which is why the portfolio scores both schedules
   instead of hard-coding a winner; the mirror pins the 1.5x envelope.

2. **The stage-partition DP** (planner/strategy.rs stage_cuts): the same
   candidate thinning and `dp[s][j]` recurrence over synthetic range/
   boundary costs, checked against brute force over all cut choices.

3. **The microbatch merge algebra** (spmd/pipeline.rs): a tiny linear +
   mean-loss training step computed serially and microbatched; the
   merge rules (concat carrying tensors, scale carried gradients by
   1/m, average non-carrying products) must reproduce the serial values
   to f64 round-off.

Run: python3 tools/proto/pipeline_mirror.py
"""

import itertools
import random

FWD, BWD = "fwd", "bwd"


def build_cells(s_count):
    """Cell list in execution order, mirroring Strategy::try_build:
    F0..F(S-1) then B(S-2)..B0; the last stage has no separate backward
    cell. Returns (cells, deps) where cells[i] = (stage, phase) and
    deps[i] = list of (from_cell, kind) with kind 'wire' or 'stash'."""
    cells = [(s, FWD) for s in range(s_count)]
    cells += [(s, BWD) for s in reversed(range(s_count - 1))]
    idx = {c: i for i, c in enumerate(cells)}
    deps = [[] for _ in cells]
    for s in range(s_count - 1):
        deps[idx[(s + 1, FWD)]].append((idx[(s, FWD)], "wire"))
    for s in reversed(range(s_count - 1)):
        src = (s + 1, BWD) if (s + 1, BWD) in idx else (s + 1, FWD)
        deps[idx[(s, BWD)]].append((idx[src], "wire"))
        deps[idx[(s, BWD)]].append((idx[(s, FWD)], "stash"))
    return cells, deps


def schedule(cells, deps, cell_s, xfer_s, m, sched):
    """The greedy list scheduler of sim/pipeline.rs. Returns a dict of
    step_s, serial_step_s, peak_stash, stage_busy, bubble."""
    s_count = max(s for s, _ in cells) + 1
    dep_t = [[(fc, xfer_s if kind == "wire" else 0.0) for fc, kind in d] for d in deps]
    fwd_cell = [next((i for i, c in enumerate(cells) if c == (s, FWD)), None) for s in range(s_count)]
    bwd_cell = [next((i for i, c in enumerate(cells) if c == (s, BWD)), None) for s in range(s_count)]

    finish = [[None] * m for _ in cells]
    scheduled = [[False] * m for _ in cells]
    stage_free = [0.0] * s_count
    stage_busy = [0.0] * s_count
    fwd_done = [0] * s_count
    bwd_done = [0] * s_count
    peak_stash = [0] * s_count
    remaining = len(cells) * m

    while remaining > 0:
        pick = None  # (start, rank, cell, mu)
        for c, (s, phase) in enumerate(cells):
            try:
                mu = scheduled[c].index(False)
            except ValueError:
                continue
            if not all(scheduled[fc][mu] and finish[fc][mu] is not None for fc, _ in dep_t[c]):
                continue
            if phase == BWD:
                if sched == "gpipe" and fwd_cell[s] is not None:
                    if not all(scheduled[fwd_cell[s]]):
                        continue
            elif sched == "1f1b" and bwd_cell[s] is not None:
                cap = s_count - s
                if fwd_done[s] - bwd_done[s] >= cap and bwd_done[s] < m:
                    continue
            est = max((finish[fc][mu] + x for fc, x in dep_t[c]), default=0.0)
            start = max(est, stage_free[s])
            if sched == "1f1b":
                rank = c if phase == BWD else len(cells) + c
            else:
                rank = c
            if pick is None or start < pick[0] - 1e-15 or (abs(start - pick[0]) <= 1e-15 and rank < pick[1]):
                pick = (start, rank, c, mu)
        assert pick is not None, f"deadlock: sched={sched} S={s_count} m={m}"
        start, _, c, mu = pick
        s, phase = cells[c]
        end = start + cell_s[c]
        finish[c][mu] = end
        scheduled[c][mu] = True
        stage_free[s] = end
        stage_busy[s] += cell_s[c]
        if phase == FWD:
            fwd_done[s] += 1
        else:
            bwd_done[s] += 1
        if bwd_cell[s] is not None:
            peak_stash[s] = max(peak_stash[s], fwd_done[s] - bwd_done[s])
        else:
            peak_stash[s] = max(peak_stash[s], 1)
        remaining -= 1

    step = max(t for f in finish for t in f)
    serial = m * (sum(cell_s) + sum(x for d in dep_t for _, x in d))
    busy = sum(stage_busy)
    bubble = max(0.0, 1.0 - busy / (s_count * step)) if step > 0 else 0.0
    return dict(step_s=step, serial_step_s=serial, peak_stash=peak_stash,
                stage_busy=stage_busy, bubble=bubble)


def check_scheduler():
    rng = random.Random(7)
    trials = 0
    worst_ratio = 0.0
    for s_count in (2, 4):
        cells, deps = build_cells(s_count)
        for m in (1, 2, 4, 8):
            for _ in range(50):
                cell_s = [rng.uniform(0.5, 2.0) for _ in cells]
                xfer = rng.choice([0.0, 0.05, 0.5])
                rg = schedule(cells, deps, cell_s, xfer, m, "gpipe")
                rf = schedule(cells, deps, cell_s, xfer, m, "1f1b")
                for r in (rg, rf):
                    assert r["step_s"] <= r["serial_step_s"] + 1e-12, (s_count, m)
                    assert 0.0 <= r["bubble"] < 1.0, (s_count, m, r["bubble"])
                # Neither schedule dominates on step time (the in-flight
                # cap can delay tail forwards), but 1F1B stays within a
                # bounded envelope of GPipe — the portfolio scores both.
                worst_ratio = max(worst_ratio, rf["step_s"] / rg["step_s"])
                assert rf["step_s"] <= rg["step_s"] * 1.5 + 1e-9, \
                    f"1F1B {rf['step_s']} > 1.5x GPipe {rg['step_s']} (S={s_count} m={m})"
                # GPipe drains: stage 0 stashes every microbatch.
                assert rg["peak_stash"][0] == m, (rg["peak_stash"], m)
                # 1F1B caps in-flight microbatches at the pipeline depth.
                for s in range(s_count - 1):
                    assert rf["peak_stash"][s] <= s_count - s, (s, rf["peak_stash"])
                if m >= 4 and s_count == 2:
                    assert rf["peak_stash"][0] < rg["peak_stash"][0]
                trials += 1
    print(f"scheduler: {trials} random schedules OK (no deadlock, "
          f"step<=serial, stash caps hold; worst 1F1B/GPipe {worst_ratio:.3f})")


def stage_cuts_dp(n, s_count, range_cost, cut_bytes, max_cand=32):
    """The stage-partition DP of planner/strategy.rs, over synthetic
    costs. Returns (cuts, total)."""
    cand = list(range(1, n))
    if len(cand) > max_cand:
        step = len(cand) / max_cand
        cand = sorted(set(1 + int(i * step) for i in range(max_cand)))
    points = [0] + cand + [n]
    points = sorted(set(points))
    p = len(points)
    inf = float("inf")
    dp = [[inf] * p for _ in range(s_count + 1)]
    frm = [[None] * p for _ in range(s_count + 1)]
    dp[0][0] = 0
    for s in range(1, s_count + 1):
        for j in range(1, p):
            for i in range(s - 1, j):
                if dp[s - 1][i] == inf:
                    continue
                boundary = cut_bytes(points[i]) if i > 0 else 0
                c = dp[s - 1][i] + range_cost(points[i], points[j]) + boundary
                if c < dp[s][j]:
                    dp[s][j] = c
                    frm[s][j] = i
    assert dp[s_count][p - 1] < inf
    cuts, j = [], p - 1
    for s in range(s_count, 0, -1):
        i = frm[s][j]
        if i > 0:
            cuts.append(points[i])
        j = i
    cuts.reverse()
    return cuts, dp[s_count][p - 1]


def check_stage_dp():
    rng = random.Random(3)
    for trial in range(200):
        n = rng.randint(2, 12)
        s_count = rng.choice([s for s in (2, 3, 4) if s <= n])
        rcost = {}
        for lo in range(n):
            for hi in range(lo + 1, n + 1):
                rcost[(lo, hi)] = rng.randint(0, 1000)
        bbytes = [rng.randint(0, 500) for _ in range(n + 1)]
        cuts, total = stage_cuts_dp(n, s_count, lambda a, b: rcost[(a, b)],
                                    lambda l: bbytes[l])
        # Brute force over all interior cut choices (n small, no thinning).
        best = min(
            sum(rcost[(a, b)] for a, b in zip((0,) + cs, cs + (n,)))
            + sum(bbytes[c] for c in cs)
            for cs in itertools.combinations(range(1, n), s_count - 1)
        )
        assert total == best, (trial, cuts, total, best)
        got = sum(rcost[(a, b)] for a, b in zip([0] + cuts, cuts + [n])) \
            + sum(bbytes[c] for c in cuts)
        assert got == total, (trial, cuts)
    print("stage DP: 200 random instances match brute force (cuts + total)")


def check_merge_algebra():
    """Serial vs microbatched linear+mean-loss step with the merge rules
    of spmd/pipeline.rs (concat carrying, scale carried grads by 1/m,
    average non-carrying)."""
    rng = random.Random(11)
    B, D = 8, 3
    for m in (1, 2, 4, 8):
        W = [rng.uniform(-1, 1) for _ in range(D)]
        X = [[rng.uniform(-1, 1) for _ in range(D)] for _ in range(B)]
        Y = [rng.uniform(-1, 1) for _ in range(B)]

        def step(xs, ys, w):
            # y_hat = x.w ; loss = mean((y_hat - y)^2)
            n = len(xs)
            yh = [sum(x[d] * w[d] for d in range(D)) for x in xs]
            dyh = [2.0 * (yh[i] - ys[i]) / n for i in range(n)]  # dL/dyh (carrying grad)
            dw = [sum(dyh[i] * xs[i][d] for i in range(n)) for d in range(D)]
            loss = sum((yh[i] - ys[i]) ** 2 for i in range(n)) / n
            return yh, dyh, dw, loss

        yh_s, dyh_s, dw_s, loss_s = step(X, Y, W)

        # Microbatched: slice rows, run per-mu, merge.
        bs = B // m
        parts = [step(X[i * bs:(i + 1) * bs], Y[i * bs:(i + 1) * bs], W) for i in range(m)]
        yh_m = [v for p in parts for v in p[0]]                      # carrying activation: concat
        dyh_m = [v / m for p in parts for v in p[1]]                 # carrying gradient: concat x 1/m
        dw_m = [sum(p[2][d] for p in parts) / m for d in range(D)]   # non-carrying: average
        loss_m = sum(p[3] for p in parts) / m                        # non-carrying: average

        def close(a, b):
            return abs(a - b) <= 1e-12 * max(1.0, abs(a), abs(b))

        assert all(close(a, b) for a, b in zip(yh_m, yh_s))
        assert all(close(a, b) for a, b in zip(dyh_m, dyh_s)), (m, dyh_m[:2], dyh_s[:2])
        assert all(close(a, b) for a, b in zip(dw_m, dw_s)), (m, dw_m, dw_s)
        assert close(loss_m, loss_s)
    print("merge algebra: concat / concat*1/m / average reproduce the "
          "serial step exactly for m in {1,2,4,8}")


if __name__ == "__main__":
    check_scheduler()
    check_stage_dp()
    check_merge_algebra()
    print("pipeline_mirror: all checks passed")
