#!/usr/bin/env python3
"""Mirror of the spmd fault-tolerance protocol (rust/src/spmd/{fault,exec,
recover}.rs), validating the design claims the Rust tests assert:

1. FNV-1a 64 checksum constants/algorithm against the published vectors
   (util/checksum.rs).
2. **Termination**: for hundreds of seeded fault plans (panic/kill/drop/
   delay/corrupt at a random (device, op) site) over a model of the
   executor's three-phase exchange protocol, every run terminates within
   a small multiple of the watchdog deadline — no deadlock, because every
   wait site uses recv_timeout.
3. **Root-cause attribution**: picking the minimal error under rank
   (real=0 < timeout=1 < poison=2), tiebroken by (op, slot, device),
   always names the true fault site: the panicked/killed worker, the
   dropping peer at the faulted op, or the corrupting sender — even
   though which worker's error "arrives first" is a scheduling race.
   The proof sketch this validates: each phase sends before it receives,
   so a stall propagates only to strictly later (op, slot) wait sites.
4. **Recovery state machine**: transient faults disarm after firing
   (retry succeeds); persistent kills re-fire (retries exhaust, then the
   re-plan on half the devices runs clean from the checkpoint).

The protocol model is faithful to exec.rs in the properties that matter:
per-(op, slot, src) messages over per-device queues, sends before
receives in each phase, poison broadcast on non-silent failure, silent
kill (no poison), per-wait-site deadline.
"""
import queue
import random
import threading
import time

# ---------------------------------------------------------------- checksum

FNV_OFFSET = 0xcbf29ce484222325
FNV_PRIME = 0x100000001b3
MASK = (1 << 64) - 1


def fnv1a64(data: bytes) -> int:
    h = FNV_OFFSET
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK
    return h


assert fnv1a64(b"") == 0xcbf29ce484222325
assert fnv1a64(b"a") == 0xaf63dc4c8601ec8c
assert fnv1a64(b"foobar") == 0x85944171f73967e8
print("fnv-1a 64 vectors: OK")

# ----------------------------------------------------- protocol model

OUT_SLOT = 255
POISON = "poison"
DEADLINE = 0.25  # seconds, mirrors CHAOS_DEADLINE


class Fault:
    def __init__(self, device, op, kind, persistent):
        self.device, self.op, self.kind, self.persistent = device, op, kind, persistent
        self.armed = True
        self.lock = threading.Lock()

    def fire(self):
        if self.persistent:
            return True
        with self.lock:
            was = self.armed
            self.armed = False
            return was


def seeded_fault(seed, devices, ops):
    rng = random.Random(seed)
    device, op = rng.randrange(devices), rng.randrange(ops)
    kind = ["panic", "kill", "drop", "delay", "corrupt"][rng.randrange(5)]
    return Fault(device, op, kind, kind == "kill")


def run_protocol(devices, ops, slots_per_op, fault):
    """Model one execution: every op, every worker sends one message per
    (slot, peer) then receives one per (slot, peer); OUT_SLOT models the
    scatter phase. Returns the per-device error list."""
    qs = [queue.Queue() for _ in range(devices)]
    errors = [None] * devices

    def worker(d):
        inbox = {}

        def send(op, slot):
            payload, sum_ = b"x", fnv1a64(b"x")
            if fault and fault.device == d and fault.op == op and \
                    fault.kind in ("drop", "delay", "corrupt") and fault.fire():
                if fault.kind == "drop":
                    return
                if fault.kind == "delay":
                    time.sleep(0.004)
                if fault.kind == "corrupt":
                    payload = b"y"  # checksum stays the clean one
            for e in range(devices):
                if e != d:
                    qs[e].put((d, op, slot, payload, sum_))

        def recv(op, slot, src):
            expiry = time.monotonic() + DEADLINE
            while True:
                if (op, slot, src) in inbox:
                    return inbox.pop((op, slot, src))
                remaining = expiry - time.monotonic()
                if remaining <= 0:
                    raise Exc(("timeout", d, op, slot, src))
                try:
                    m = qs[d].get(timeout=remaining)
                except queue.Empty:
                    raise Exc(("timeout", d, op, slot, src))
                if m[2] == POISON:
                    raise Exc(("poison", d, m[0]))
                frm, mop, mslot, payload, sum_ = m
                if fnv1a64(payload) != sum_:
                    raise Exc(("corrupt", d, mop, frm))
                inbox[(mop, mslot, frm)] = payload

        class Exc(Exception):
            def __init__(self, err):
                self.err = err

        try:
            for op in range(ops):
                # compute-site faults fire at op entry
                if fault and fault.device == d and fault.op == op and \
                        fault.kind in ("panic", "kill") and fault.fire():
                    if fault.kind == "panic":
                        raise Exc(("panic", d, op))
                    errors[d] = ("killed", d, op)
                    return  # SILENT: no poison
                for slot in list(range(slots_per_op)) + [OUT_SLOT]:
                    send(op, slot)           # sends precede receives
                    for src in range(devices):
                        if src != d:
                            recv(op, slot, src)
        except Exc as ex:
            errors[d] = ex.err
            # Poison on real failures only. A timeout must NOT poison:
            # near-simultaneous deadline expiries would let a downstream
            # waiter poison the true victim first, converting the
            # root-cause timeout into a cascade (seed 33 caught this).
            # Every wait is supervised, so peers time out on their own.
            if ex.err[0] != "timeout":
                for q in qs:
                    q.put((d, 0, POISON, b"", 0))

    ts = [threading.Thread(target=worker, args=(d,)) for d in range(devices)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    return errors


def root_cause(errors):
    def key(e):
        kind = e[0]
        if kind == "poison":
            return (2, 0, 0, e[1])
        if kind == "timeout":
            _, d, op, slot, src = e
            return (1, op, slot, d)
        if kind == "corrupt":
            _, d, op, frm = e
            return (0, op, 0, d)
        return (0, 0, 0, e[1])  # panic / killed
    errs = [e for e in errors if e]
    return min(errs, key=key) if errs else None


DEVICES, OPS, SLOTS = 4, 5, 2
TRIALS = 240
counts = {}
t_all = time.monotonic()
for seed in range(TRIALS):
    f = seeded_fault(seed, DEVICES, OPS)
    t0 = time.monotonic()
    errors = run_protocol(DEVICES, OPS, SLOTS, f)
    elapsed = time.monotonic() - t0
    assert elapsed < DEADLINE * 10 + 2, f"seed {seed}: {elapsed:.2f}s — deadlock"
    rc = root_cause(errors)
    counts[f.kind] = counts.get(f.kind, 0) + 1
    if f.kind == "panic":
        assert rc == ("panic", f.device, f.op), (seed, rc)
    elif f.kind == "kill":
        assert rc == ("killed", f.device, f.op), (seed, rc)
    elif f.kind == "drop":
        # minimal timeout names the dropping peer at the faulted op
        assert rc[0] == "timeout" and rc[2] == f.op and rc[4] == f.device, (seed, rc)
    elif f.kind == "corrupt":
        assert rc[0] == "corrupt" and rc[2] == f.op and rc[3] == f.device, (seed, rc)
    else:  # delay: tolerated
        assert rc is None, (seed, rc)
print(f"termination + root-cause: {TRIALS} seeded plans OK "
      f"({time.monotonic() - t_all:.1f}s, kinds {counts})")

# ------------------------------------------ recovery state machine

for kind in ("panic", "drop", "corrupt"):
    f = Fault(1, 2, kind, persistent=False)
    first = root_cause(run_protocol(DEVICES, OPS, SLOTS, f))
    assert first is not None, kind
    retry = root_cause(run_protocol(DEVICES, OPS, SLOTS, f))  # disarmed
    assert retry is None, (kind, retry)
print("transient faults: fail once, retry clean: OK")

f = Fault(2, 1, "kill", persistent=True)
for attempt in range(3):  # attempt 0 + max_retries
    rc = root_cause(run_protocol(DEVICES, OPS, SLOTS, f))
    assert rc == ("killed", 2, 1), (attempt, rc)
# re-plan: survivors = half the devices, faults cleared
rc = root_cause(run_protocol(DEVICES // 2, OPS, SLOTS, None))
assert rc is None
print("persistent kill: retries exhaust, re-plan on survivors clean: OK")
print("fault_mirror: all protocol claims hold")
