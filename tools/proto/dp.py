#!/usr/bin/env python3
"""Full one-cut DP mirror (levels -> components -> tabulation -> sweep ->
traceback), to predict the Rust planner's results on transformer configs:
optimal costs per cut, k-cut totals, and the soy-vs-DP-baseline comparison
the integration test asserts."""
import sys
from collections import defaultdict
from topo import *
from cost import (op_cost, candidates, price, dp_assignment, apply_cut,
                  bytes_of, REP, S, INF)

def one_cut(g):
    alias = aliases(g)
    levels, boundary, internal, level_of = bfs_levels(g)
    nl = len(levels)
    nt = len(g.tensors)
    cands = [candidates(g, t) for t in range(nt)]
    internal_level = [-1] * nt
    for l, ts in enumerate(internal):
        for t in ts:
            internal_level[t] = l
    boundary_level = [-1] * nt
    pos_in_boundary = [-1] * nt
    for l, b in enumerate(boundary):
        for i, t in enumerate(b):
            boundary_level[t] = l
            pos_in_boundary[t] = i

    # components per level
    comps_per_level = []
    for l, ops in enumerate(levels):
        parent = list(range(len(ops)))
        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x
        owner = {}
        for oi, op in enumerate(ops):
            _, _, ins, outs = g.ops[op]
            for t in ins + outs:
                t = alias[t]
                if internal_level[t] == l:
                    if t not in owner:
                        owner[t] = oi
                    else:
                        a, b_ = find(owner[t]), find(oi)
                        if a != b_:
                            parent[a] = b_
        groups = defaultdict(list)
        for oi, op in enumerate(ops):
            groups[find(oi)].append(op)
        comps = []
        for root in sorted(groups):
            comp_ops = groups[root]
            bids, iids = [], []
            for op in comp_ops:
                _, _, ins, outs = g.ops[op]
                for t in ins + outs:
                    t = alias[t]
                    if internal_level[t] == l:
                        if t not in iids: iids.append(t)
                    elif t not in bids: bids.append(t)
            bids.sort(); iids.sort()
            comps.append((comp_ops, bids, iids))
        comps_per_level.append(comps)

    # tabulate each component
    def dec(idx, rad):
        out = []
        for r in rad:
            out.append(idx % r); idx //= r
        return out

    tabs_per_level = []
    for l, comps in enumerate(comps_per_level):
        tabs = []
        for comp_ops, bids, iids in comps:
            brad = [len(cands[t]) for t in bids]
            irad = [len(cands[t]) for t in iids]
            blen = 1
            for r in brad: blen *= r
            ilen = 1
            for r in irad: ilen *= r
            table = []
            for bidx in range(blen):
                bdig = dec(bidx, brad)
                best = (INF, 0)
                for iidx in range(ilen):
                    idig = dec(iidx, irad)
                    assign = {}
                    for i, t in enumerate(bids): assign[t] = cands[t][bdig[i]]
                    for i, t in enumerate(iids): assign[t] = cands[t][idig[i]]
                    cost = 0
                    for op in comp_ops:
                        _, _, ins, outs = g.ops[op]
                        c = op_cost(g, g.ops[op],
                                    [assign[alias[t]] for t in ins],
                                    assign[alias[outs[0]]])
                        cost += c
                        if cost >= best[0]: break
                    if cost < best[0]:
                        best = (cost, iidx)
                table.append(best)
            tabs.append((table, brad, bids, iids, irad))
        tabs_per_level.append(tabs)

    # DP sweep
    bnd_rad = [[len(cands[t]) for t in b] for b in boundary]
    bnd_len = []
    for rad in bnd_rad:
        p = 1
        for r in rad: p *= r
        bnd_len.append(p)

    dp = []
    for l in range(nl):
        prev_len = bnd_len[l-1] if l > 0 else 1
        cur_len = bnd_len[l] if l + 1 < nl else 1
        # precompute per-comp prev/cur index contributions
        comp_contrib = []
        for (table, brad, bids, iids, irad) in tabs_per_level[l]:
            mults = []
            m = 1
            for r in brad:
                mults.append(m); m *= r
            wprev, wcur = [], []
            for i, t in enumerate(bids):
                if l > 0 and boundary_level[t] == l - 1:
                    wprev.append((pos_in_boundary[t], mults[i]))
                else:
                    wcur.append((pos_in_boundary[t], mults[i]))
            def contrib(ln, rad, w):
                out = [0] * ln
                dig = [0] * len(rad)
                for slot in range(ln):
                    s = 0
                    for (p_, m_) in w:
                        s += dig[p_] * m_
                    out[slot] = s
                    for j in range(len(rad)):
                        dig[j] += 1
                        if dig[j] < rad[j]: break
                        dig[j] = 0
                return out
            cp = contrib(prev_len, bnd_rad[l-1] if l > 0 else [], wprev)
            cc = contrib(cur_len, bnd_rad[l] if l + 1 < nl else [], wcur)
            comp_contrib.append((table, cp, cc))
        cur_dp = [(INF, 0)] * cur_len
        for q in range(cur_len):
            best = (INF, 0)
            for p in range(prev_len):
                base = 0 if l == 0 else dp[l-1][p][0]
                if base >= best[0]: continue
                cost = base
                for (table, cp, cc) in comp_contrib:
                    cost += table[cp[p] + cc[q]][0]
                    if cost >= best[0]: break
                if cost < best[0]:
                    best = (cost, p)
            cur_dp[q] = best
        dp.append(cur_dp)

    final_cost, state = min((c, i) for i, (c, _) in enumerate(dp[nl-1]))
    assert final_cost < INF, "infeasible"

    # traceback
    bdig = [None] * len(boundary)
    for l in range(nl - 1, -1, -1):
        prev_state = dp[l][state][1]
        if l >= 1:
            bdig[l-1] = dec(prev_state, bnd_rad[l-1])
        if l + 1 < nl:
            bdig[l] = dec(state, bnd_rad[l])
        state = prev_state
    tiles = [REP] * nt
    for l, b in enumerate(boundary):
        for i, t in enumerate(b):
            tiles[t] = cands[t][bdig[l][i]]
    for l, tabs in enumerate(tabs_per_level):
        for (table, brad, bids, iids, irad) in tabs:
            mults = []
            m = 1
            for r in brad:
                mults.append(m); m *= r
            idx = 0
            for i, t in enumerate(bids):
                idx += bdig[boundary_level[t]][pos_in_boundary[t]] * mults[i]
            iidx = table[idx][1]
            idig = dec(iidx, irad)
            for i, t in enumerate(iids):
                tiles[t] = cands[t][idig[i]]
    for t in range(nt):
        tiles[t] = tiles[alias[t]]
    repriced = price(g, tiles)
    assert repriced == final_cost, f"reconstruction mismatch {repriced} != {final_cost}"
    return final_cost, tiles

def k_cut(g, k):
    cur = g
    costs = []
    tiles_seq = None
    for i in range(k):
        c, tiles = one_cut(cur)
        costs.append(c)
        cur = apply_cut(cur, tiles)
    total = sum((1 << i) * c for i, c in enumerate(costs))
    return costs, total

def dp_baseline(g, k):
    """mirror of baselines::data_parallel with forced classic forms —
    upper bound: price the DP tiles unforced (forced >= unforced, so if
    soy_total <= unforced_dp_total we're safe a fortiori... careful:
    actually forced >= unforced so dp_forced >= dp_unforced; asserting
    soy <= dp_unforced is the STRONGER claim)."""
    cur = g
    alias = aliases(g)
    costs = []
    for i in range(k):
        tiles = dp_assignment(cur)
        for t in range(len(tiles)):
            tiles[t] = tiles[alias[t]]
        costs.append(price(cur, tiles))
        cur = apply_cut(cur, tiles)
    total = sum((1 << i) * c for i, c in enumerate(costs))
    return costs, total

if __name__ == "__main__":
    for label, cfgargs, k in [
        ("tiny-1L", (4, 4, 8, 2, 16, 1, 8), 2),
        ("tiny-2L", (4, 4, 8, 2, 16, 2, 8), 2),
        ("micro-4L", (8, 128, 256, 4, 1024, 4, 256), 3),
    ]:
        g = transformer_v2(*cfgargs, fused=True)
        soy_costs, soy_total = k_cut(g, k)
        dp_costs, dp_total = dp_baseline(g, k)
        ok = "OK" if soy_total <= dp_total else "*** VIOLATION ***"
        print(f"{label}: soy cuts={soy_costs} total={soy_total:,} | "
              f"dp(unforced) cuts={dp_costs} total={dp_total:,} {ok}")
