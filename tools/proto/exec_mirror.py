#!/usr/bin/env python3
"""ISSUE-5 mirror: numeric validation of the threaded SPMD executor design.

The Rust container has no toolchain, so the executor's *semantic* design —
shard kernels for the full op vocabulary, the §5.2 ghost-gather input
conversions, and the reduce-bit contributor sum that realizes output
conversions (ReduceScatter / AllGather / AllToAll / SendRecv patterns) —
is validated here first:

  serial numpy reference  ==  sharded multi-device execution

for mlp / alexnet-tiny / vgg16-tiny / transformer-4L at 2/4/8 devices,
under SOYBEAN (one-cut DP mirror), data-parallel and model-parallel plans.

The sharded execution below is the Rust executor with the thread transport
removed: devices are a list, messages are direct array slices, but the
piece-assignment functions (`gather_sources`, reduce-bit contributors) are
exactly the ones rust/src/spmd ports.  Storage is float32, kernels
accumulate in float64 — the tolerance model docs/execution.md documents.

Run: python3 tools/proto/exec_mirror.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from topo import (G, INPUT, LABEL, WEIGHT, ACT, GRAD, WGRAD, UPD, SCALAR,
                  aliases, topo_order, matmul, bmm, relu, gelu, add, bias_add,
                  conv2d, pool2, flatten, softmax_xent, layer_norm,
                  softmax_rows, merge_heads, slice_heads, wire,
                  transformer_v2, mlp_graph, append_backward)
import dp as dpmod

REP = ("rep",)
def S(d): return ("split", d)
INF = 1 << 54
NONE = ("none",)
LR = np.float32(0.01)
LN_EPS = 1e-5

# Toggle for the LayerNormGammaGrad bug the harness flushes out: with the
# seed semantics (x required feature-split under the feature-split form)
# the kernel cannot recompute whole-row statistics and diverges.
FIX_GAMMA_GRAD = True


def bytes_of(g, t):
    p = 4
    for d in g.shape(t):
        p *= d
    return p


def conv_cost(nbytes, frm, to):
    if frm[0] == "tile":
        a = frm[1]
        if a == REP:
            return 0
        if a == to:
            return 0
        if a[0] == "split" and to[0] == "split":
            return nbytes // 2
        if a[0] == "split" and to == REP:
            return nbytes
        raise AssertionError((frm, to))
    if to[0] == "split":
        return nbytes
    return 2 * nbytes


def feasible(g, t, tile):
    if tile == REP:
        return True
    d = tile[1]
    sh = g.shape(t)
    return d < len(sh) and sh[d] >= 2 and sh[d] % 2 == 0


def ew_splittable(rank, weight_like):
    if rank == 4 and not weight_like:
        return [True, False, False, True]
    if rank == 4 and weight_like:
        return [False, False, True, True]
    return [True] * rank


def ident_map(rank):
    return [("d", i) for i in range(rank)]


def semantics(g, op):
    """Full mirror of tiling/aligned.rs semantics(), conv ops included.
    mm: ('mm', x(row,col), y(row,col), z(row,col)); grid as in cost.py."""
    name, kind, ins, outs = op
    k0 = kind[0]
    if k0 == "MatMul":
        _, ta, tb = kind
        return ("mm", (1 if ta else 0, 0 if ta else 1),
                (1 if tb else 0, 0 if tb else 1), (0, 1))
    if k0 == "Conv2d":
        return ("mm", (0, 3), (2, 3), (0, 3))
    if k0 == "Conv2dBwdData":
        return ("mm", (0, 3), (3, 2), (0, 3))
    if k0 == "Conv2dBwdFilter":
        return ("mm", (3, 0), (0, 3), (2, 3))
    if k0 == "BMM":
        _, ta, tb = kind
        am, ak = (2, 1) if ta else (1, 2)
        bk = 2 if tb else 1
        bn = 1 if tb else 2
        in_a = [("d", 0), ("d", am), NONE, ("d", ak)]
        in_b = [("d", 0), NONE, ("d", bn), ("d", bk)]
        out = [("d", 0), ("d", 1), ("d", 2), NONE]
        return ("grid", [True] * 4, [in_a, in_b], out, False)
    if k0 == "Ew":
        rank = len(g.shape(outs[0]))
        return ("grid", ew_splittable(rank, False),
                [ident_map(rank) for _ in ins], ident_map(rank), False)
    if k0 == "BiasAdd":
        rank = len(g.shape(ins[0]))
        bm = [NONE] * rank
        bm[rank - 1] = ("d", 0)
        return ("grid", ew_splittable(rank, False), [ident_map(rank), bm],
                ident_map(rank), False)
    if k0 == "Pool2":
        return ("grid", [True, False, False, True], [ident_map(4)],
                ident_map(4), False)
    if k0 == "Pool2Bwd":
        return ("grid", [True, False, False, True], [ident_map(4)] * 3,
                ident_map(4), False)
    if k0 == "Flatten":
        return ("grid", [True, True], [[("d", 0), ("d", 3)]],
                [("d", 0), ("d", 1)], False)
    if k0 == "FlattenBwd":
        return ("grid", [True, True], [[("d", 0), ("d", 1)]],
                [("d", 0), ("d", 3)], False)
    if k0 == "ReduceSumRows":
        return ("grid", [True, True], [ident_map(2)], [NONE, ("d", 0)], False)
    if k0 == "SoftmaxXent":
        return ("grid", [True, False], [ident_map(2)] * 2, [NONE, NONE], False)
    if k0 == "SoftmaxXentGrad":
        return ("grid", [True, False], [ident_map(2)] * 2, ident_map(2), False)
    if k0 == "SgdUpdate":
        rank = len(g.shape(ins[0]))
        return ("grid", ew_splittable(rank, rank == 4), [ident_map(rank)] * 2,
                ident_map(rank), True)
    if k0 == "LayerNorm":
        maps = [ident_map(2), [NONE, ("d", 0)], [NONE, ("d", 0)]]
        return ("grid", [True, False], maps[:len(ins)], ident_map(2), False)
    if k0 == "LayerNormGrad":
        maps = [ident_map(2), ident_map(2)] + ([[NONE, ("d", 0)]] if len(ins) == 3 else [])
        return ("grid", [True, False], maps, ident_map(2), False)
    if k0 == "LayerNormGammaGrad":
        if FIX_GAMMA_GRAD:
            # dgamma[j] = sum_i dy[i,j] * xhat[i,j]: xhat needs whole-row
            # statistics of x, so the feature-split form may slice dy (and
            # the output) but must keep x whole-row.
            return ("grid", [True, True],
                    [ident_map(2), [("d", 0), NONE]], [NONE, ("d", 0)], False)
        return ("grid", [True, True], [ident_map(2)] * 2, [NONE, ("d", 0)], False)
    if k0 == "Softmax":
        rank = len(g.shape(ins[0]))
        return ("grid", [True] * (rank - 1) + [False], [ident_map(rank)],
                ident_map(rank), False)
    if k0 == "SoftmaxGrad":
        rank = len(g.shape(ins[0]))
        return ("grid", [True] * (rank - 1) + [False], [ident_map(rank)] * 2,
                ident_map(rank), False)
    if k0 in ("SplitHeads", "MergeHeads", "SliceHeads"):
        return ("grid", [True], [[("d", 0)]], [("d", 0)], False)
    if k0 == "ConcatHeads":
        return ("grid", [True], [[("d", 0)]] * 3, [("d", 0)], False)
    raise AssertionError(k0)


def req_tile(m):
    return REP if m == NONE else S(m[1])


def op_cost_detailed(g, op, ins_t, out_t):
    """Rust op_cost_detailed: strict-min over the same candidate order.
    Returns (total, form, [input req tiles], prod) or None."""
    name, kind, ins, outs = op
    sem = semantics(g, op)
    bz = bytes_of(g, outs[0])
    best = None

    def consider(total, form, reqs, prod):
        nonlocal best
        if best is None or total < best[0]:
            best = (total, form, reqs, prod)

    if sem[0] == "mm":
        _, x, y, z = sem
        tx, ty, tz = ins[0], ins[1], outs[0]
        bx, by = bytes_of(g, tx), bytes_of(g, ty)
        forms = [
            (S(x[0]), REP, ("tile", S(z[0]))),
            (REP, S(y[1]), ("tile", S(z[1]))),
            (S(x[1]), S(y[0]), ("red",)),
        ]
        for fi, (rx, ry, prod) in enumerate(forms):
            if not feasible(g, tx, rx) or not feasible(g, ty, ry):
                continue
            if prod[0] == "tile" and not feasible(g, tz, prod[1]):
                continue
            c = conv_cost(bx, ("tile", ins_t[0]), rx)
            c += conv_cost(by, ("tile", ins_t[1]), ry)
            c += conv_cost(bz, prod, out_t)
            consider(c, ("mm", fi), [rx, ry], prod)
        return best

    _, splittable, in_maps, out_map, allow_rep = sem
    if allow_rep:
        c = sum(conv_cost(bytes_of(g, t), ("tile", ins_t[i]), REP)
                for i, t in enumerate(ins))
        c += conv_cost(bz, ("tile", REP), out_t)
        consider(c, ("rep",), [REP] * len(ins), ("tile", REP))
    for ax, ok in enumerate(splittable):
        if not ok:
            continue
        c, reqs, bad = 0, [], False
        for i, m in enumerate(in_maps):
            r = req_tile(m[ax])
            if not feasible(g, ins[i], r):
                bad = True
                break
            c += conv_cost(bytes_of(g, ins[i]), ("tile", ins_t[i]), r)
            reqs.append(r)
        if bad:
            continue
        if out_map[ax] == NONE:
            prod = ("red",)
        else:
            t = S(out_map[ax][1])
            if not feasible(g, outs[0], t):
                continue
            prod = ("tile", t)
        c += conv_cost(bz, prod, out_t)
        consider(c, ("grid", ax), reqs, prod)
    return best


def candidates(g, t, rank3_dims=(0,)):
    nm, shape, kind = g.tensors[t]
    r = len(shape)
    out = [REP]
    if r == 0:
        return out
    if r == 4 and kind in (WEIGHT, WGRAD, UPD):
        dims = [2, 3]
    elif r == 4:
        dims = [0, 3]
    elif r == 3:
        dims = list(rank3_dims)
    else:
        dims = list(range(r))
    for d in dims:
        if shape[d] >= 2 and shape[d] % 2 == 0:
            out.append(S(d))
    return out


def price(g, tiles):
    tot = 0
    for op in g.ops:
        _, _, ins, outs = op
        det = op_cost_detailed(g, op, [tiles[t] for t in ins], tiles[outs[0]])
        if det is None:
            return INF
        tot += det[0]
    return tot


def apply_cut(g, tiles):
    g2 = G()
    g2.tensors = [[n, list(s), k] for n, s, k in g.tensors]
    g2.ops = [[n, k, list(i), list(o)] for n, k, i, o in g.ops]
    for t, tile in enumerate(tiles):
        if tile != REP:
            d = tile[1]
            assert g2.tensors[t][1][d] % 2 == 0
            g2.tensors[t][1][d] //= 2
    return g2


# ---- shard tasks: mirror of rust/src/exec/shard.rs (stacked shapes) ----
def build_shard_tasks(g, plan_tiles):
    """plan_tiles: per tensor, list of k tiles. Returns per op:
    (required_ins: [TileSeq], produced: TileSeq, reduce_cuts: [int])."""
    k = len(plan_tiles[0]) if plan_tiles else 0
    tasks = []
    for op in g.ops:
        name, kind, ins, outs = op
        required = [[] for _ in ins]
        produced = []
        reduce_cuts = []
        local = apply_cut(g, [REP] * len(g.tensors))  # deep copy
        for i in range(k):
            ins_t = [plan_tiles[t][i] for t in ins]
            out_t = plan_tiles[outs[0]][i]
            det = op_cost_detailed(local, op, ins_t, out_t)
            assert det is not None, f"no feasible form for {name} at cut {i}"
            _, form, reqs, prod = det
            for slot, r in enumerate(reqs):
                required[slot].append(r)
                if r != REP:
                    local.tensors[ins[slot]][1][r[1]] //= 2
            if prod[0] == "red":
                produced.append(REP)
                reduce_cuts.append(i)
            else:
                produced.append(prod[1])
                if prod[1] != REP:
                    local.tensors[outs[0]][1][prod[1][1]] //= 2
        tasks.append((required, produced, reduce_cuts))
    return tasks


# ---- regions and gathering: mirror of rust/src/exec/{region,gather}.rs ----
def cut_bit(d, i, k):
    return (d >> (k - 1 - i)) & 1


def resident_region(shape, seq, d):
    k = len(seq)
    off = [0] * len(shape)
    sh = list(shape)
    for i, t in enumerate(seq):
        if t != REP:
            dim = t[1]
            half = sh[dim] // 2
            if cut_bit(d, i, k) == 1:
                off[dim] += half
            sh[dim] = half
    return (tuple(off), tuple(sh))


def intersect(a, b):
    ao, ash = a
    bo, bsh = b
    off, sh = [], []
    for d in range(len(ao)):
        lo = max(ao[d], bo[d])
        hi = min(ao[d] + ash[d], bo[d] + bsh[d])
        off.append(lo)
        sh.append(max(0, hi - lo))
    return (tuple(off), tuple(sh))


def contains(a, b):
    return intersect(a, b) == b


def is_empty(r):
    return any(d == 0 for d in r[1])


def gather_sources(shape, seq, devices, me, target):
    rank = len(shape)
    residents = [resident_region(shape, seq, d) for d in range(devices)]
    if rank == 0:
        return [(me, ((), ()))]
    cuts = [set() for _ in range(rank)]
    for off, sh in residents:
        for d in range(rank):
            cuts[d].add(off[d])
            cuts[d].add(off[d] + sh[d])
    for d in range(rank):
        cuts[d].add(target[0][d])
        cuts[d].add(target[0][d] + target[1][d])
    cuts = [sorted(c) for c in cuts]
    pieces = []
    idx = [0] * rank

    def cell_at(idx):
        off, sh = [], []
        for d in range(rank):
            off.append(cuts[d][idx[d]])
            sh.append(cuts[d][idx[d] + 1] - cuts[d][idx[d]])
        return (tuple(off), tuple(sh))

    import itertools
    ranges = [range(len(c) - 1) for c in cuts]
    for idx in itertools.product(*ranges):
        cell = cell_at(list(idx))
        if is_empty(cell) or not contains(target, cell):
            continue
        if contains(residents[me], cell):
            src = me
        else:
            owners = [d for d in range(devices) if contains(residents[d], cell)]
            assert owners, "cell owned by nobody"
            src = min(owners, key=lambda d: bin(d ^ me).count("1"))
        pieces.append((src, cell))
    return pieces


def sub_view(arr, arr_region, piece_region):
    """View of `piece_region` inside `arr` stored over `arr_region`."""
    if arr.ndim == 0:
        return arr
    sl = tuple(slice(piece_region[0][d] - arr_region[0][d],
                     piece_region[0][d] - arr_region[0][d] + piece_region[1][d])
               for d in range(arr.ndim))
    return arr[sl]


# ---- numeric kernels (float64 accumulation, float32 storage) ----
def f32(x):
    return np.asarray(x, dtype=np.float64).astype(np.float32)


def gelu_f(x):
    c = np.sqrt(2.0 / np.pi)
    return 0.5 * x * (1.0 + np.tanh(c * (x + 0.044715 * x ** 3)))


def gelu_grad_f(x):
    c = np.sqrt(2.0 / np.pi)
    u = c * (x + 0.044715 * x ** 3)
    t = np.tanh(u)
    du = c * (1.0 + 3 * 0.044715 * x ** 2)
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t ** 2) * du


def conv_fwd(x, w, stride, pad):
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    xp = np.zeros((n, h + 2 * pad, wd + 2 * pad, cin), dtype=np.float64)
    xp[:, pad:pad + h, pad:pad + wd, :] = x
    out = np.zeros((n, oh, ow, cout), dtype=np.float64)
    for a in range(kh):
        for b in range(kw):
            xs = xp[:, a:a + (oh - 1) * stride + 1:stride,
                    b:b + (ow - 1) * stride + 1:stride, :]
            out += np.tensordot(xs, w[a, b].astype(np.float64), axes=([3], [0]))
    return out


def conv_bwd_data(dz, w, stride, pad, x_shape):
    n, oh, ow, cout = dz.shape
    kh, kw, cin, _ = w.shape
    h, wd = x_shape[1], x_shape[2]
    dxp = np.zeros((n, h + 2 * pad, wd + 2 * pad, cin), dtype=np.float64)
    for a in range(kh):
        for b in range(kw):
            contrib = np.tensordot(dz.astype(np.float64),
                                   w[a, b].astype(np.float64), axes=([3], [1]))
            dxp[:, a:a + (oh - 1) * stride + 1:stride,
                b:b + (ow - 1) * stride + 1:stride, :] += contrib
    return dxp[:, pad:pad + h, pad:pad + wd, :]


def conv_bwd_filter(x, dz, stride, pad, w_shape):
    n, h, wd, cin = x.shape
    kh, kw = w_shape[0], w_shape[1]
    _, oh, ow, cout = dz.shape
    xp = np.zeros((n, h + 2 * pad, wd + 2 * pad, cin), dtype=np.float64)
    xp[:, pad:pad + h, pad:pad + wd, :] = x
    dw = np.zeros((kh, kw, cin, cout), dtype=np.float64)
    for a in range(kh):
        for b in range(kw):
            xs = xp[:, a:a + (oh - 1) * stride + 1:stride,
                    b:b + (ow - 1) * stride + 1:stride, :]
            dw[a, b] = np.tensordot(xs, dz.astype(np.float64),
                                    axes=([0, 1, 2], [0, 1, 2]))
    return dw


def pool2_fwd(x):
    n, h, w, c = x.shape
    oh, ow = h // 2, w // 2
    v = x[:, :2 * oh, :2 * ow, :].reshape(n, oh, 2, ow, 2, c)
    return v.max(axis=(2, 4))


def pool2_bwd(dz, x, out):
    n, h, w, c = x.shape
    oh, ow = out.shape[1], out.shape[2]
    dx = np.zeros_like(x, dtype=np.float64)
    taken = np.zeros_like(out, dtype=bool)
    for a in range(2):
        for b in range(2):
            xs = x[:, a:2 * oh:2, b:2 * ow:2, :]
            hit = (xs == out) & ~taken
            taken |= hit
            dx[:, a:2 * oh:2, b:2 * ow:2, :] += np.where(hit, dz, 0.0)
    return dx


def softmax_last(x):
    m = x.max(axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=-1, keepdims=True)


def ln_stats(x):
    mu = x.mean(axis=1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=1, keepdims=True)
    return mu, np.sqrt(var + LN_EPS)


def apply_kernel(g, op, arrs, regions, out_region, global_rows):
    """Compute op on local (region-sliced) float32 arrays; returns float32
    array of out_region's shape. All accumulation is float64."""
    name, kind, ins, outs = op
    k0 = kind[0]
    A = [a.astype(np.float64) for a in arrs]
    if k0 == "MatMul":
        _, ta, tb = kind
        a = A[0].T if ta else A[0]
        b = A[1].T if tb else A[1]
        return f32(a @ b)
    if k0 == "BMM":
        _, ta, tb = kind
        a = np.transpose(A[0], (0, 2, 1)) if ta else A[0]
        b = np.transpose(A[1], (0, 2, 1)) if tb else A[1]
        return f32(np.matmul(a, b))
    if k0 == "Conv2d":
        return f32(conv_fwd(A[0], A[1], kind[1], kind[2]))
    if k0 == "Conv2dBwdData":
        return f32(conv_bwd_data(A[0], A[1], kind[1], kind[2], out_region[1]))
    if k0 == "Conv2dBwdFilter":
        return f32(conv_bwd_filter(A[0], A[1], kind[1], kind[2], out_region[1]))
    if k0 == "Pool2":
        return f32(pool2_fwd(A[0]))
    if k0 == "Pool2Bwd":
        return f32(pool2_bwd(A[0], arrs[1].astype(np.float64),
                             arrs[2].astype(np.float64)))
    if k0 == "Flatten":
        n, h, w, c = A[0].shape
        return f32(np.transpose(A[0], (0, 3, 1, 2)).reshape(n, c * h * w))
    if k0 == "FlattenBwd":
        n, h, w, c = out_region[1]
        return f32(np.transpose(A[0].reshape(n, c, h, w), (0, 2, 3, 1)))
    if k0 == "BiasAdd":
        return f32(A[0] + A[1][None, :])
    if k0 == "Ew":
        e = kind[1]
        if e == "Relu":
            return f32(np.maximum(A[0], 0.0))
        if e == "ReluGrad":
            return f32(np.where(A[1] > 0.0, A[0], 0.0))
        if e == "Add":
            return f32(A[0] + A[1])
        if e == "Mul":
            return f32(A[0] * A[1])
        if e == "Gelu":
            return f32(gelu_f(A[0]))
        if e == "GeluGrad":
            return f32(A[0] * gelu_grad_f(A[1]))
        if e == "Ident":
            return arrs[0].copy()
        raise AssertionError(e)
    if k0 == "ReduceSumRows":
        return f32(A[0].sum(axis=0))
    if k0 == "SoftmaxXent":
        logits, onehot = A[0], A[1]
        m = logits.max(axis=1, keepdims=True)
        logp = (logits - m) - np.log(np.exp(logits - m).sum(axis=1, keepdims=True))
        return f32(np.array(-(onehot * logp).sum() / global_rows))
    if k0 == "SoftmaxXentGrad":
        return f32((softmax_last(A[0]) - A[1]) / global_rows)
    if k0 == "SgdUpdate":
        return f32(A[0] - np.float64(LR) * A[1])
    if k0 == "LayerNorm":
        x, gamma, beta = A[0], A[1], A[2]
        mu, sd = ln_stats(x)
        return f32((x - mu) / sd * gamma[None, :] + beta[None, :])
    if k0 == "LayerNormGrad":
        dy, x, gamma = A[0], A[1], A[2]
        mu, sd = ln_stats(x)
        xh = (x - mu) / sd
        dyg = dy * gamma[None, :]
        return f32((dyg - dyg.mean(axis=1, keepdims=True)
                    - xh * (dyg * xh).mean(axis=1, keepdims=True)) / sd)
    if k0 == "LayerNormGammaGrad":
        dy, x = A[0], A[1]
        mu, sd = ln_stats(x)
        xh = (x - mu) / sd
        if FIX_GAMMA_GRAD:
            # dy may be a column slice; x is whole-row. Align xh to dy's
            # columns via the region offset.
            c0 = regions[0][0][1]
            xh = xh[:, c0:c0 + dy.shape[1]]
        return f32((dy * xh).sum(axis=0))
    if k0 == "Softmax":
        return f32(softmax_last(A[0]))
    if k0 == "SoftmaxGrad":
        dy, y = A[0], A[1]
        return f32(y * (dy - (dy * y).sum(axis=-1, keepdims=True)))
    if k0 in ("SliceHeads", "SplitHeads"):
        if k0 == "SliceHeads":
            _, part, heads, _seq = kind
        else:
            _, heads, _seq = kind
            part = 0
        bh, s, dh = out_region[1]
        b_ = bh // heads
        d_model = dh * heads
        x = A[0].reshape(b_, s, -1)
        sl = x[:, :, part * d_model:(part + 1) * d_model]
        return f32(sl.reshape(b_, s, heads, dh).transpose(0, 2, 1, 3)
                   .reshape(bh, s, dh))
    if k0 == "MergeHeads":
        _, heads, _seq = kind
        bh, s, dh = A[0].shape
        b_ = bh // heads
        x = A[0].reshape(b_, heads, s, dh).transpose(0, 2, 1, 3)
        return f32(x.reshape(b_ * s, heads * dh))
    if k0 == "ConcatHeads":
        _, heads, _seq = kind
        bh, s, dh = A[0].shape
        b_ = bh // heads
        parts = []
        for a in A:
            parts.append(a.reshape(b_, heads, s, dh).transpose(0, 2, 1, 3)
                         .reshape(b_ * s, heads * dh))
        return f32(np.concatenate(parts, axis=1))
    raise AssertionError(k0)


# ---- serial reference ----
def seed_values(g, seed=7):
    vals = [None] * len(g.tensors)
    produced = set()
    for _, _, _, outs in g.ops:
        produced.update(outs)
    for t, (nm, shape, kind) in enumerate(g.tensors):
        if t in produced:
            continue
        rng = np.random.default_rng(seed * 1000003 + t)
        if kind == LABEL:
            m, c = shape
            v = np.zeros((m, c), dtype=np.float32)
            v[np.arange(m), rng.integers(0, c, size=m)] = 1.0
            vals[t] = v
        elif kind == WEIGHT:
            if len(shape) == 2:
                fan = shape[0]
            elif len(shape) == 4:
                fan = shape[0] * shape[1] * shape[2]
            else:
                fan = max(shape[0], 1)
            a = np.sqrt(3.0 / fan)
            if len(shape) == 1 and nm.endswith(".g"):
                vals[t] = (1.0 + 0.1 * rng.standard_normal(shape)).astype(np.float32)
            else:
                vals[t] = rng.uniform(-a, a, size=shape).astype(np.float32)
        else:
            vals[t] = (0.5 * rng.standard_normal(shape)).astype(np.float32)
    return vals


def run_serial(g, vals):
    vals = list(vals)
    for opid in topo_order(g):
        op = g.ops[opid]
        name, kind, ins, outs = op
        z = outs[0]
        zsh = tuple(g.shape(z))
        arrs = [vals[t] for t in ins]
        regs = [((0,) * len(g.shape(t)), tuple(g.shape(t))) for t in ins]
        grows = g.shape(ins[0])[0] if kind[0] in ("SoftmaxXent", "SoftmaxXentGrad") else 0
        out = apply_kernel(g, op, arrs, regs, ((0,) * len(zsh), zsh), grows)
        vals[z] = out.reshape(zsh) if zsh else out
    return vals


# ---- the sharded executor (threadless mirror of rust/src/spmd) ----
def run_sharded(g, plan_tiles, vals):
    k = len(plan_tiles[0]) if plan_tiles else 0
    devices = 1 << k
    tasks = build_shard_tasks(g, plan_tiles)
    home = [dict() for _ in range(devices)]
    produced_set = set()
    for _, _, _, outs in g.ops:
        produced_set.update(outs)
    for t in range(len(g.tensors)):
        if t in produced_set:
            continue
        shape = tuple(g.shape(t))
        for d in range(devices):
            reg = resident_region(shape, plan_tiles[t], d)
            home[d][t] = (np.ascontiguousarray(sub_view(vals[t], ((0,) * len(shape), shape), reg))
                          if shape else vals[t].copy())
    payload = 0

    for opid, op in enumerate(g.ops):
        name, kind, ins, outs = op
        required, produced, reduce_cuts = tasks[opid]
        z = outs[0]
        zshape = tuple(g.shape(z))

        # Phase 1: ghost-gather every input into its required layout.
        local_ins = [[None] * len(ins) for _ in range(devices)]
        local_regs = [[None] * len(ins) for _ in range(devices)]
        for d in range(devices):
            for slot, t in enumerate(ins):
                shape = tuple(g.shape(t))
                want = resident_region(shape, required[slot], d)
                buf = np.empty(want[1], dtype=np.float32)
                for src, cell in gather_sources(shape, plan_tiles[t], devices, d, want):
                    src_reg = resident_region(shape, plan_tiles[t], src)
                    piece = sub_view(home[src][t], src_reg, cell)
                    if shape:
                        sub_view(buf, want, cell)[...] = piece
                    else:
                        buf = home[src][t].copy()
                    if src != d:
                        payload += int(np.prod(cell[1], dtype=np.int64)) * 4 if shape else 4
                local_ins[d][slot] = buf
                local_regs[d][slot] = want

        # Phase 2: local compute.
        outs_local = []
        for d in range(devices):
            out_reg = resident_region(zshape, produced, d)
            grows = g.shape(ins[0])[0] if kind[0] in ("SoftmaxXent", "SoftmaxXentGrad") else 0
            r = apply_kernel(g, op, local_ins[d], local_regs[d], out_reg, grows)
            outs_local.append(r.reshape(out_reg[1]) if zshape else r)

        # Phase 3: scatter-reduce the produced shards into the home layout.
        rbits = [k - 1 - j for j in reduce_cuts]
        import itertools as it
        for e in range(devices):
            want = resident_region(zshape, plan_tiles[z], e)
            acc = np.zeros(want[1], dtype=np.float64)
            for src, cell in gather_sources(zshape, produced, devices, e, want):
                cell_acc = np.zeros(cell[1], dtype=np.float64)
                for combo in it.product((0, 1), repeat=len(rbits)):
                    c = src
                    for bit, v in zip(rbits, combo):
                        c = (c & ~(1 << bit)) | (v << bit)
                    creg = resident_region(zshape, produced, c)
                    cell_acc += sub_view(outs_local[c], creg, cell).astype(np.float64)
                    if c != e:
                        payload += (int(np.prod(cell[1], dtype=np.int64)) * 4
                                    if zshape else 4)
                if zshape:
                    sub_view(acc, want, cell)[...] += cell_acc
                else:
                    acc = acc + cell_acc
            home[e][z] = acc.astype(np.float32)
    return home, payload, tasks


def assemble(g, home, plan_tiles, t):
    devices = len(home)
    shape = tuple(g.shape(t))
    if not shape:
        vals = [home[d][t] for d in range(devices)]
        for v in vals[1:]:
            assert np.array_equal(v, vals[0]), "scalar replica divergence"
        return vals[0]
    full = np.full(shape, np.nan, dtype=np.float32)
    for d in range(devices):
        reg = resident_region(shape, plan_tiles[t], d)
        view = sub_view(full, ((0,) * len(shape), shape), reg)
        existing = ~np.isnan(view)
        assert np.array_equal(view[existing], home[d][t][existing]), \
            f"replica divergence on {g.tensors[t][0]}"
        view[...] = home[d][t]
    assert not np.isnan(full).any()
    return full


# ---- plans ----
def dp_tiles(g, k):
    tiles = []
    for t, (nm, shape, kind) in enumerate(g.tensors):
        if kind in (WEIGHT, WGRAD, UPD, SCALAR):
            tile = REP
        elif len(shape) >= 1 and shape[0] % (1 << k) == 0 and (shape[0] >> k) >= 1:
            tile = S(0)
        else:
            tile = REP
        tiles.append([tile] * k)
    return tiles


def mp_tiles(g, k):
    def fits(shape, d):
        return shape[d] % (1 << k) == 0 and (shape[d] >> k) >= 1
    tiles = []
    for t, (nm, shape, kind) in enumerate(g.tensors):
        r = len(shape)
        tile = REP
        if kind in (WEIGHT, WGRAD, UPD):
            if r == 2 and fits(shape, 0):
                tile = S(0)
            elif r == 4 and fits(shape, 3):
                tile = S(3)
            elif r == 1 and fits(shape, 0):
                tile = S(0)
        elif kind == ACT:
            if r == 2 and fits(shape, 1):
                tile = S(1)
            elif r == 4 and fits(shape, 3):
                tile = S(3)
        elif kind == GRAD and r == 4 and fits(shape, 3):
            tile = S(3)
        tiles.append([tile] * k)
    return tiles


def soy_tiles(g, k):
    # one-cut DP mirror with this module's (conv-complete) cost functions.
    dpmod.op_cost = lambda gg, op, ins_t, out_t: (
        (lambda d: d[0] if d is not None else INF)(op_cost_detailed(gg, op, ins_t, out_t)))
    dpmod.candidates = candidates
    dpmod.price = price
    dpmod.INF = INF
    dpmod.REP = REP
    alias = aliases(g)
    cur = g
    tiles = [[] for _ in g.tensors]
    for _ in range(k):
        _, cut = dpmod.one_cut(cur)
        for t in range(len(g.tensors)):
            tiles[t].append(cut[t])
        cur = apply_cut(cur, cut)
    return tiles


# ---- models ----
def alexnet_tiny(batch=8, image=67, fc=256, classes=1000):
    g = G()
    h = g.t("x", [batch, image, image, 3], INPUT)
    y = g.t("y", [batch, classes], LABEL)
    w1 = g.t("conv1.w", [11, 11, 3, 96], WEIGHT)
    h = conv2d(g, "conv1", h, w1, 4, 0)
    h = relu(g, "conv1.relu", h)
    h = pool2(g, "pool1", h)
    w2 = g.t("conv2.w", [5, 5, 96, 256], WEIGHT)
    h = conv2d(g, "conv2", h, w2, 1, 2)
    h = relu(g, "conv2.relu", h)
    h = pool2(g, "pool2", h)
    w3 = g.t("conv3.w", [3, 3, 256, 384], WEIGHT)
    h = conv2d(g, "conv3", h, w3, 1, 1)
    h = relu(g, "conv3.relu", h)
    w4 = g.t("conv4.w", [3, 3, 384, 384], WEIGHT)
    h = conv2d(g, "conv4", h, w4, 1, 1)
    h = relu(g, "conv4.relu", h)
    w5 = g.t("conv5.w", [3, 3, 384, 256], WEIGHT)
    h = conv2d(g, "conv5", h, w5, 1, 1)
    h = relu(g, "conv5.relu", h)
    h = pool2(g, "pool5", h)
    flat = flatten(g, "flatten", h)
    feat = 1
    for d in g.shape(flat)[1:]:
        feat *= d
    wf1 = g.t("fc6.w", [feat, fc], WEIGHT)
    f = matmul(g, "fc6", flat, wf1)
    f = relu(g, "fc6.relu", f)
    wf2 = g.t("fc7.w", [fc, fc], WEIGHT)
    f = matmul(g, "fc7", f, wf2)
    f = relu(g, "fc7.relu", f)
    wf3 = g.t("fc8.w", [fc, classes], WEIGHT)
    logits = matmul(g, "fc8", f, wf3)
    loss = softmax_xent(g, "loss", logits, y)
    append_backward(g, loss)
    return g


def vgg16_tiny(batch=8, image=32, fc=256, classes=1000):
    g = G()
    h = g.t("x", [batch, image, image, 3], INPUT)
    y = g.t("y", [batch, classes], LABEL)

    def block(h, name, convs, cin, cout):
        c = cin
        for i in range(convs):
            w = g.t(f"{name}.conv{i}.w", [3, 3, c, cout], WEIGHT)
            h = conv2d(g, f"{name}.conv{i}", h, w, 1, 1)
            h = relu(g, f"{name}.conv{i}.relu", h)
            c = cout
        return pool2(g, f"{name}.pool", h)

    h = block(h, "b1", 2, 3, 64)
    h = block(h, "b2", 2, 64, 128)
    h = block(h, "b3", 3, 128, 256)
    h = block(h, "b4", 3, 256, 512)
    h = block(h, "b5", 3, 512, 512)
    flat = flatten(g, "flatten", h)
    feat = 1
    for d in g.shape(flat)[1:]:
        feat *= d
    w1 = g.t("fc1.w", [feat, fc], WEIGHT)
    f = matmul(g, "fc1", flat, w1)
    f = relu(g, "fc1.relu", f)
    w2 = g.t("fc2.w", [fc, fc], WEIGHT)
    f = matmul(g, "fc2", f, w2)
    f = relu(g, "fc2.relu", f)
    w3 = g.t("fc3.w", [fc, classes], WEIGHT)
    logits = matmul(g, "fc3", f, w3)
    loss = softmax_xent(g, "loss", logits, y)
    append_backward(g, loss)
    return g


# ---- the differential harness ----
def diff(g, label, k, strat, serial_vals):
    if strat == "soy":
        tiles = soy_tiles(g, k)
    elif strat == "dp":
        tiles = dp_tiles(g, k)
    else:
        tiles = mp_tiles(g, k)
    alias = aliases(g)
    for t in range(len(tiles)):
        tiles[t] = tiles[alias[t]]
    home, payload, _tasks = run_sharded(g, tiles, serial_vals)
    worst = 0.0
    worst_t = None
    for t in range(len(g.tensors)):
        full = assemble(g, home, tiles, t)
        ref = serial_vals[t]
        scale = max(np.abs(ref).max() if ref.size else 0.0, 1e-6)
        err = (np.abs(full.astype(np.float64) - ref.astype(np.float64)).max()
               / scale) if ref.size else 0.0
        if err > worst:
            worst, worst_t = err, g.tensors[t][0]
    status = "OK " if worst <= 1e-5 else "FAIL"
    print(f"  {label:16} k={k} {strat:4} payload={payload:>12,}  "
          f"max rel err {worst:.2e} ({worst_t})  {status}")
    return worst


def main():
    models = [
        ("mlp", mlp_graph(16, [16] * 5)),
        ("mlp-bias", mlp_graph(16, [12, 24, 10], bias=True)),
        ("transformer-4L", transformer_v2(8, 4, 8, 2, 16, 4, 8, fused=True)),
        ("alexnet-tiny", alexnet_tiny()),
        ("vgg16-tiny", vgg16_tiny()),
    ]
    worst_all = 0.0
    for label, g in models:
        vals = run_serial(g, seed_values(g))
        print(f"{label}: {len(g.ops)} ops, {len(g.tensors)} tensors")
        for k in (1, 2, 3):
            for strat in ("soy", "dp", "mp"):
                worst_all = max(worst_all, diff(g, label, k, strat, vals))
    print(f"\nWORST relative error across the matrix: {worst_all:.3e}")
    assert worst_all <= 1e-5, "differential gate FAILED"
    print("DIFFERENTIAL GATE GREEN (serial == sharded on the full matrix)")


if __name__ == "__main__":
    main()
