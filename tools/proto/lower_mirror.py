"""Mirror of rust/src/lower/lowering.rs pass-1 + emission byte accounting.

Verifies, on real workload graphs, that the lowered per-device instruction
bytes sum EXACTLY to the k-cut plan's Theorem-1 total — the acceptance
criterion the Rust tests assert. Uses the PR-2 cost/dp mirrors.
"""
import os, sys
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from topo import *          # graph builders
from cost import (S, REP, bytes_of, conv_cost, feasible, semantics, req_tile,
                  op_cost, price, apply_cut, INF)
from dp import one_cut, k_cut

NONE = ("none",)

def op_cost_detailed(g, op, ins_t, out_t):
    """Same candidate order + strict-min tie-breaking as Rust op_cost_detailed.
    Returns (total, [per-input req tiles], prod)."""
    name, kind, ins, outs = op
    sem = semantics(g, op)
    bz = bytes_of(g, outs[0])
    best = None
    def consider(total, reqs, prod):
        nonlocal best
        if best is None or total < best[0]:
            best = (total, reqs, prod)
    if sem[0] == "mm":
        _, x, y, z = sem
        tx, ty, tz = ins[0], ins[1], outs[0]
        bx, by = bytes_of(g, tx), bytes_of(g, ty)
        forms = [
            (req_tile(("d", x[0][1])), REP, ("tile", req_tile(("d", z[0][1])))),
            (REP, req_tile(("d", y[1][1])), ("tile", req_tile(("d", z[1][1])))),
            (req_tile(("d", x[1][1])), req_tile(("d", y[0][1])), ("red",)),
        ]
        for rx, ry, prod in forms:
            if not feasible(g, tx, rx) or not feasible(g, ty, ry): continue
            if prod[0] == "tile" and not feasible(g, tz, prod[1]): continue
            c = conv_cost(bx, ("tile", ins_t[0]), rx) + conv_cost(by, ("tile", ins_t[1]), ry)
            c += conv_cost(bz, prod, out_t)
            consider(c, [rx, ry], prod)
        return best
    _, splittable, in_maps, out_map, allow_rep = sem
    if allow_rep:
        c = sum(conv_cost(bytes_of(g, t), ("tile", ins_t[i]), REP) for i, t in enumerate(ins))
        c += conv_cost(bz, ("tile", REP), out_t)
        consider(c, [REP]*len(ins), ("tile", REP))
    for ax, ok in enumerate(splittable):
        if not ok: continue
        c = 0; reqs = []; bad = False
        for i, m in enumerate(in_maps):
            r = req_tile(m[ax])
            if not feasible(g, ins[i], r): bad = True; break
            c += conv_cost(bytes_of(g, ins[i]), ("tile", ins_t[i]), r)
            reqs.append(r)
        if bad: continue
        if out_map[ax] == NONE or out_map[ax] == ("none",) or (isinstance(out_map[ax], tuple) and out_map[ax][0] == "none"):
            prod = ("red",)
        else:
            t = S(out_map[ax][1])
            if not feasible(g, outs[0], t): continue
            prod = ("tile", t)
        c += conv_cost(bz, prod, out_t)
        consider(c, reqs, prod)
    return best

def scatter_axis(shape):
    for i, d in enumerate(shape):
        if d >= 2 and d % 2 == 0: return i
    return None

def share(P, n, r):
    return P // n + (1 if r < P % n else 0)

def lower_bytes(g, tiles_per_cut, k):
    """Mirror of pass 1 + Emitter::start share distribution. Returns
    (total bytes across all devices, per-tier bytes)."""
    devices = 1 << k
    cur = g
    total = 0
    tier = [0]*k
    for j in range(k):
        tiles = tiles_per_cut[j]
        n = devices >> j               # devices per pair
        pairs = 1 << j
        for op in cur.ops:
            name, kind, ins, outs = op
            ins_t = [tiles[t] for t in ins]
            out_t = tiles[outs[0]]
            det = op_cost_detailed(cur, op, ins_t, out_t)
            assert det is not None, (name, j)
            c_total, reqs, prod = det
            pieces = []   # pair-level byte volumes for this op/cut
            for i, t in enumerate(ins):
                b = conv_cost(bytes_of(cur, t), ("tile", ins_t[i]), reqs[i])
                if b > 0:
                    # classify: must be a collective (given != Rep, != req)
                    given = ins_t[i]
                    assert given != REP and given != reqs[i]
                    pieces.append(b)
            tz = outs[0]
            ob = conv_cost(bytes_of(cur, tz), prod, out_t)
            if ob > 0:
                if prod[0] == "tile":
                    pieces.append(ob)
                else:  # red
                    if out_t == REP:
                        ax = scatter_axis(cur.tensors[tz][1])
                        if ax is not None:
                            pieces.append(ob // 2)          # RS
                            pieces.append(ob - ob // 2)     # AG
                        else:
                            pieces.append(ob)               # SendRecv exchange
                    else:
                        pieces.append(ob)                   # ReduceScatter
            assert sum(pieces) == c_total, (name, j, pieces, c_total)
            for P in pieces:
                # per-device shares across each pair, all pairs
                per_pair = sum(share(P, n, r) for r in range(n))
                assert per_pair == P
                total += per_pair * pairs
                tier[j] += P * pairs
        cur = apply_cut(cur, tiles)
    return total, tier

def run(label, g, k):
    # Soybean k-cut plan: collect per-cut tiles
    cur = g
    tiles_per_cut = []
    costs = []
    for i in range(k):
        c, tiles = one_cut(cur)
        costs.append(c)
        tiles_per_cut.append(tiles)
        cur = apply_cut(cur, tiles)
    theorem1 = sum((1 << i) * c for i, c in enumerate(costs))
    lowered, tier = lower_bytes(g, tiles_per_cut, k)
    ok = "OK" if lowered == theorem1 == sum(tier) else "*** MISMATCH ***"
    print(f"{label:24} k={k} theorem1={theorem1:>14,} lowered={lowered:>14,} {ok}")
    assert lowered == theorem1, (label, lowered, theorem1)
    assert sum(tier) == theorem1

run("mlp-§2.2",  mlp_graph(400, [300]*6), 3)
run("mlp-fig8",  mlp_graph(512, [8192]*5, bias=False), 3)
run("mlp-bias",  mlp_graph(64, [32, 128, 128, 10], bias=True), 3)
# conv ops are not modeled by the PR-2 cost mirror; Rust covers them
run("tiny-1L",   transformer_v2(4, 4, 8, 2, 16, 1, 8, fused=True), 2)
run("micro-4L",  transformer_v2(8, 128, 256, 4, 1024, 4, 256, fused=True), 3)
print("ALL LOWERING BYTE IDENTITIES HOLD")
