#!/usr/bin/env python3
"""Mirror of the blocked-kernel layer (rust/src/graph/fastk/), validating
the numeric design claims the Rust tests assert:

1. **Bitwise equivalence of the blocked GEMM**: the packed, blocked
   driver (pack A/B into k-major f64 micro-panels, mr x nr register
   tiles, f64 output scratch carried across kc blocks, explicit
   boundary tiles at their true extent — never padding) produces output
   BIT-IDENTICAL to the naive ascending-k f64 loop on float32 data, for
   every schedule on the candidate grid, across boundary-heavy shapes
   and all four transpose combinations. The argument mirrored: a
   product of two f32 values is exact in f64, and the blocked loop
   performs each element's f64 additions in the naive loop's exact
   order, so there is literally no rounding left to differ.
2. **Conv lowering order**: im2col with column order (a*kw + b)*cin + ci
   reproduces the naive window-loop accumulation order for conv fwd;
   bwd-data's col2im scatter-add and bwd-filter's xcol^T · dz carried
   accumulation also match their naive loops bit for bit.
3. **Schedule-search determinism**: the candidate enumeration (sorted
   canonical order) + first-strict-minimum selection is a pure function
   of (m, k, n) — two independent searches agree exactly.

numpy is used only for RNG and float32 containers; all contractions are
explicit Python loops / orderings so the accumulation order is visible.
"""
import numpy as np

# ------------------------------------------------------------- schedules
# Mirrors fastk/schedule.rs: grids, clamping, cost model, selection.

MICRO = [(4, 4), (4, 8), (8, 4), (8, 8)]
KC = [64, 128, 256]
MC = [32, 64, 128]
NC = [64, 128, 256]


def steps_dim(origin, tile):
    return (origin + tile - 1) // tile


def boundary_size(origin, tile):
    return origin % tile


def candidates(m, k, n):
    cands = set()
    for mr, nr in MICRO:
        for kc in KC:
            for mc in MC:
                for nc in NC:
                    cands.add((
                        max(min(mc, m), 1),
                        max(min(kc, k), 1),
                        max(min(nc, n), 1),
                        max(min(mr, m), 1),
                        max(min(nr, n), 1),
                    ))
    return sorted(cands)


def model_cost(m, k, n, s):
    mc, kc, nc, mr, nr = s
    pack_a = steps_dim(n, nc) * m * k * 2.0
    pack_b = k * n * 2.0
    c_traffic = 2.0 * m * n * steps_dim(k, kc)
    eff = (mr * nr) / (mr * nr + mr + nr)
    bm = boundary_size(m, mr)
    bn = boundary_size(n, nr)
    frac_m = (bm / m) if bm else 0.0
    frac_n = (bn / n) if bn else 0.0
    boundary = frac_m + frac_n - frac_m * frac_n
    macs = m * k * n
    compute = macs / eff * (1.0 + 2.0 * boundary)
    cost = pack_a + pack_b + c_traffic + compute
    if kc * nr * 8 > 32 * 1024:
        cost *= 1.5
    if mc * kc * 8 > 192 * 1024:
        cost *= 1.5
    if kc * nc * 8 > 2 * 1024 * 1024:
        cost *= 1.2
    return cost


def search(m, k, n):
    best, best_cost = None, None
    for s in candidates(m, k, n):
        c = model_cost(m, k, n, s)
        if best_cost is None or c < best_cost:
            best, best_cost = s, c
    return best


# ------------------------------------------------------------------ gemm
# Naive oracle: ascending-k sum of f64 products, rounded once to f32.


def dims(rows, cols, trans):
    return (cols, rows) if trans else (rows, cols)


def at(a, rows, cols, trans, i, j):
    # Logical (i, j) of the possibly-transposed row-major buffer.
    return a[j * cols + i] if trans else a[i * cols + j]


def naive_gemm(a, ad, ta, b, bd, tb):
    m, k = dims(*ad, ta)
    k2, n = dims(*bd, tb)
    assert k == k2
    out = np.empty(m * n, dtype=np.float32)
    for i in range(m):
        for j in range(n):
            acc = 0.0
            for p in range(k):
                acc += float(at(a, *ad, ta, i, p)) * float(at(b, *bd, tb, p, j))
            out[i * n + j] = np.float32(acc)
    return out


# Blocked driver: mirrors fastk/gemm.rs structurally — pack to k-major
# f64 micro-panels, mr x nr register tiles with an f64 scratch carried
# across kc blocks, boundary tiles at true extent.


def pack_a(a, ad, ta, i0, mc_, p0, kc_, mr):
    panels = []
    for it in range(0, mc_, mr):
        h = min(mr, mc_ - it)
        panel = [[float(at(a, *ad, ta, i0 + it + r, p0 + p)) for r in range(h)]
                 for p in range(kc_)]
        panels.append((h, panel))
    return panels


def pack_b(b, bd, tb, p0, kc_, j0, nc_, nr):
    panels = []
    for jt in range(0, nc_, nr):
        w = min(nr, nc_ - jt)
        panel = [[float(at(b, *bd, tb, p0 + p, j0 + jt + c)) for c in range(w)]
                 for p in range(kc_)]
        panels.append((w, panel))
    return panels


def blocked_gemm(a, ad, ta, b, bd, tb, sched):
    m, k = dims(*ad, ta)
    _, n = dims(*bd, tb)
    mc, kc, nc, mr, nr = sched
    c64 = [0.0] * (m * n)
    for j0 in range(0, n, nc):
        nc_ = min(nc, n - j0)
        for p0 in range(0, k, kc):
            kc_ = min(kc, k - p0)
            bp = pack_b(b, bd, tb, p0, kc_, j0, nc_, nr)
            for i0 in range(0, m, mc):
                mc_ = min(mc, m - i0)
                ap = pack_a(a, ad, ta, i0, mc_, p0, kc_, mr)
                for bj, (w, bpan) in enumerate(bp):
                    for ai, (h, apan) in enumerate(ap):
                        # micro-kernel: load scratch, rank-1 updates in
                        # ascending p, store back.
                        acc = [[c64[(i0 + ai * mr + r) * n + j0 + bj * nr + c]
                                for c in range(w)] for r in range(h)]
                        for p in range(kc_):
                            av, bv = apan[p], bpan[p]
                            for r in range(h):
                                for c in range(w):
                                    acc[r][c] += av[r] * bv[c]
                        for r in range(h):
                            for c in range(w):
                                c64[(i0 + ai * mr + r) * n + j0 + bj * nr + c] = acc[r][c]
    return np.array([np.float32(v) for v in c64], dtype=np.float32)


def check_gemm():
    rng = np.random.default_rng(0x4B45524E)
    shapes = [(1, 1, 1), (7, 5, 9), (65, 33, 17), (64, 64, 64), (13, 257, 3),
              (1, 63, 8), (31, 2, 31)]
    checked = 0
    for (m, k, n) in shapes:
        a32 = rng.standard_normal(m * k).astype(np.float32)
        b32 = rng.standard_normal(k * n).astype(np.float32)
        for ta in (False, True):
            for tb in (False, True):
                ad = (k, m) if ta else (m, k)
                bd = (n, k) if tb else (k, n)
                want = naive_gemm(a32, ad, ta, b32, bd, tb)
                scheds = {search(m, k, n), (max(min(32, m), 1), max(min(64, k), 1),
                                            max(min(64, n), 1), min(4, m), min(4, n)),
                          (m, k, n, min(8, m), min(8, n))}
                for s in scheds:
                    got = blocked_gemm(a32, ad, ta, b32, bd, tb, s)
                    assert got.tobytes() == want.tobytes(), \
                        f"gemm mismatch m={m} k={k} n={n} ta={ta} tb={tb} s={s}"
                    checked += 1
    print(f"gemm: {checked} (shape x transpose x schedule) cases bit-identical")


# ------------------------------------------------------------------ conv
# NHWC activations, HWIO filters; stride/pad as in graph/kernels.rs.


def conv_geom(n, h, w, cin, kh, kw, cout, stride, pad):
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    return oh, ow


def naive_conv(x, wt, g):
    n, h, w, cin, kh, kw, cout, stride, pad = g
    oh, ow = conv_geom(*g)
    out = np.empty(n * oh * ow * cout, dtype=np.float32)
    for ni in range(n):
        for oi in range(oh):
            for oj in range(ow):
                for co in range(cout):
                    acc = 0.0
                    for a in range(kh):
                        for b in range(kw):
                            ih = oi * stride + a - pad
                            iw = oj * stride + b - pad
                            if ih < 0 or ih >= h or iw < 0 or iw >= w:
                                continue
                            for ci in range(cin):
                                acc += float(x[((ni * h + ih) * w + iw) * cin + ci]) * \
                                    float(wt[((a * kw + b) * cin + ci) * cout + co])
                    out[((ni * oh + oi) * ow + oj) * cout + co] = np.float32(acc)
    return out


def im2col_rows(x, g, rows):
    n, h, w, cin, kh, kw, cout, stride, pad = g
    oh, ow = conv_geom(*g)
    k2 = kh * kw * cin
    col = np.zeros((len(rows), k2), dtype=np.float32)
    for r, site in enumerate(rows):
        ni, rem = divmod(site, oh * ow)
        oi, oj = divmod(rem, ow)
        for a in range(kh):
            for b in range(kw):
                ih = oi * stride + a - pad
                iw = oj * stride + b - pad
                if ih < 0 or ih >= h or iw < 0 or iw >= w:
                    continue
                for ci in range(cin):
                    col[r, (a * kw + b) * cin + ci] = x[((ni * h + ih) * w + iw) * cin + ci]
    return col


def fast_conv(x, wt, g, row_block=5):
    # conv fwd = im2col rows x [k2, cout] filter, GEMM'd per row block.
    n, *_rest = g
    cout = g[6]
    oh, ow = conv_geom(*g)
    kh, kw, cin = g[4], g[5], g[3]
    k2 = kh * kw * cin
    sites = n * oh * ow
    out = np.empty(sites * cout, dtype=np.float32)
    wbuf = wt  # HWIO buffer IS row-major [k2, cout]
    for r0 in range(0, sites, row_block):
        rows = list(range(r0, min(r0 + row_block, sites)))
        col = im2col_rows(x, g, rows).reshape(-1)
        s = search(len(rows), k2, cout)
        blk = blocked_gemm(col, (len(rows), k2), False, wbuf, (k2, cout), False, s)
        out[r0 * cout:(r0 + len(rows)) * cout] = blk
    return out


def naive_conv_bwd_data(dz, wt, g):
    n, h, w, cin, kh, kw, cout, stride, pad = g
    oh, ow = conv_geom(*g)
    dx64 = [0.0] * (n * h * w * cin)
    for ni in range(n):
        for oi in range(oh):
            for oj in range(ow):
                for a in range(kh):
                    for b in range(kw):
                        ih = oi * stride + a - pad
                        iw = oj * stride + b - pad
                        if ih < 0 or ih >= h or iw < 0 or iw >= w:
                            continue
                        for ci in range(cin):
                            acc = 0.0
                            for co in range(cout):
                                acc += float(dz[((ni * oh + oi) * ow + oj) * cout + co]) * \
                                    float(wt[((a * kw + b) * cin + ci) * cout + co])
                            dx64[((ni * h + ih) * w + iw) * cin + ci] += acc
    return np.array([np.float32(v) for v in dx64], dtype=np.float32)


def fast_conv_bwd_data(dz, wt, g, row_block=5):
    # dcol = dz · w^T per row block, scattered back through the same taps.
    n, h, w, cin, kh, kw, cout, stride, pad = g
    oh, ow = conv_geom(*g)
    k2 = kh * kw * cin
    sites = n * oh * ow
    dx64 = [0.0] * (n * h * w * cin)
    for r0 in range(0, sites, row_block):
        rows = list(range(r0, min(r0 + row_block, sites)))
        dzb = np.ascontiguousarray(
            dz.reshape(sites, cout)[r0:r0 + len(rows)]).reshape(-1)
        s = search(len(rows), cout, k2)
        # w^T via the trans flag, exactly as the Rust path does.
        dcol = blocked_gemm_f64(dzb, (len(rows), cout), False, wt, (k2, cout), True, s)
        for r, site in enumerate(rows):
            ni, rem = divmod(site, oh * ow)
            oi, oj = divmod(rem, ow)
            for a in range(kh):
                for b in range(kw):
                    ih = oi * stride + a - pad
                    iw = oj * stride + b - pad
                    if ih < 0 or ih >= h or iw < 0 or iw >= w:
                        continue
                    for ci in range(cin):
                        dx64[((ni * h + ih) * w + iw) * cin + ci] += \
                            dcol[r * k2 + (a * kw + b) * cin + ci]
    return np.array([np.float32(v) for v in dx64], dtype=np.float32)


def blocked_gemm_f64(a, ad, ta, b, bd, tb, sched, c64=None):
    # Same driver, f64 result (no final f32 round) — the bwd-data and
    # bwd-filter paths round only once, after the scatter/accumulate.
    # `c64` mirrors gemm_into's add-into contract: bwd-filter passes its
    # carried scratch so each dw element's terms accumulate across row
    # blocks in one sequential chain, exactly like the naive loop.
    m, k = dims(*ad, ta)
    _, n = dims(*bd, tb)
    mc, kc, nc, mr, nr = sched
    if c64 is None:
        c64 = [0.0] * (m * n)
    for j0 in range(0, n, nc):
        nc_ = min(nc, n - j0)
        for p0 in range(0, k, kc):
            kc_ = min(kc, k - p0)
            bp = pack_b(b, bd, tb, p0, kc_, j0, nc_, nr)
            for i0 in range(0, m, mc):
                mc_ = min(mc, m - i0)
                ap = pack_a(a, ad, ta, i0, mc_, p0, kc_, mr)
                for bj, (w, bpan) in enumerate(bp):
                    for ai, (h, apan) in enumerate(ap):
                        acc = [[c64[(i0 + ai * mr + r) * n + j0 + bj * nr + c]
                                for c in range(w)] for r in range(h)]
                        for p in range(kc_):
                            av, bv = apan[p], bpan[p]
                            for r in range(h):
                                for c in range(w):
                                    acc[r][c] += av[r] * bv[c]
                        for r in range(h):
                            for c in range(w):
                                c64[(i0 + ai * mr + r) * n + j0 + bj * nr + c] = acc[r][c]
    return c64


def naive_conv_bwd_filter(x, dz, g):
    n, h, w, cin, kh, kw, cout, stride, pad = g
    oh, ow = conv_geom(*g)
    k2 = kh * kw * cin
    dw64 = [0.0] * (k2 * cout)
    for a in range(kh):
        for b in range(kw):
            for ci in range(cin):
                for co in range(cout):
                    acc = 0.0
                    for ni in range(n):
                        for oi in range(oh):
                            for oj in range(ow):
                                ih = oi * stride + a - pad
                                iw = oj * stride + b - pad
                                if ih < 0 or ih >= h or iw < 0 or iw >= w:
                                    continue
                                acc += float(x[((ni * h + ih) * w + iw) * cin + ci]) * \
                                    float(dz[((ni * oh + oi) * ow + oj) * cout + co])
                    dw64[((a * kw + b) * cin + ci) * cout + co] = acc
    return np.array([np.float32(v) for v in dw64], dtype=np.float32)


def fast_conv_bwd_filter(x, dz, g, row_block=5):
    # dw += xcol^T · dz per row block, accumulated via gemm_into's
    # add-into contract directly into the carried f64 scratch, rounded
    # once at the end — so each dw element's site terms form one
    # sequential ascending chain, the naive inner loop's exact order.
    n, h, w, cin, kh, kw, cout, stride, pad = g
    oh, ow = conv_geom(*g)
    k2 = kh * kw * cin
    sites = n * oh * ow
    dw64 = [0.0] * (k2 * cout)
    for r0 in range(0, sites, row_block):
        rows = list(range(r0, min(r0 + row_block, sites)))
        col = im2col_rows(x, g, rows).reshape(-1)
        dzb = np.ascontiguousarray(
            dz.reshape(sites, cout)[r0:r0 + len(rows)]).reshape(-1)
        s = search(k2, len(rows), cout)
        blocked_gemm_f64(col, (len(rows), k2), True,
                         dzb, (len(rows), cout), False, s, c64=dw64)
    return np.array([np.float32(v) for v in dw64], dtype=np.float32)


def check_conv():
    rng = np.random.default_rng(0xC0DEC0DE)
    geoms = [
        (1, 5, 5, 2, 3, 3, 3, 1, 1),
        (2, 4, 6, 1, 2, 2, 2, 2, 0),
        (1, 7, 3, 3, 3, 1, 2, 1, 0),
        (1, 1, 1, 1, 1, 1, 1, 1, 0),
        (2, 6, 6, 2, 3, 3, 1, 3, 1),
    ]
    for g in geoms:
        n, h, w, cin, kh, kw, cout, stride, pad = g
        oh, ow = conv_geom(*g)
        x = rng.standard_normal(n * h * w * cin).astype(np.float32)
        wt = rng.standard_normal(kh * kw * cin * cout).astype(np.float32)
        dz = rng.standard_normal(n * oh * ow * cout).astype(np.float32)

        want = naive_conv(x, wt, g)
        got = fast_conv(x, wt, g)
        assert got.tobytes() == want.tobytes(), f"conv fwd mismatch {g}"

        want = naive_conv_bwd_data(dz, wt, g)
        got = fast_conv_bwd_data(dz, wt, g)
        assert got.tobytes() == want.tobytes(), f"conv bwd-data mismatch {g}"

        want = naive_conv_bwd_filter(x, dz, g)
        got = fast_conv_bwd_filter(x, dz, g)
        assert got.tobytes() == want.tobytes(), f"conv bwd-filter mismatch {g}"
    print(f"conv: {len(geoms)} geometries — fwd, bwd-data and bwd-filter "
          "all bit-identical to the naive loops")


def check_determinism():
    shapes = [(300, 77, 129), (64, 64, 64), (1, 257, 7), (13, 5, 3), (129, 65, 77)]
    for (m, k, n) in shapes:
        s1, s2 = search(m, k, n), search(m, k, n)
        assert s1 == s2, f"nondeterministic search {m},{k},{n}"
        mc, kc, nc, mr, nr = s1
        assert mc <= m and kc <= k and nc <= n and mr <= m and nr <= n
    print(f"schedule search: {len(shapes)} shapes deterministic and clamped")


if __name__ == "__main__":
    check_determinism()
    check_gemm()
    check_conv()
    print("fastk mirror: all checks passed")
