#!/usr/bin/env python3
"""Mirror of tiling/aligned.rs op_cost for the new transformer ops.
Validates: (a) every op in the V3 graph has a feasible aligned form under
candidate tilings at every k-cut level; (b) brute-force optimum on a tiny
attention core behaves sensibly (batch splits win; cost plausible)."""
import itertools
from topo import *

REP = ("rep",)
def S(d): return ("split", d)

INF = (1 << 54)

def bytes_of(g, t):
    p = 4
    for d in g.shape(t):
        p *= d
    return p

def conv_cost(nbytes, frm, to):
    # frm: ("tile", t) or ("red",); to: tile
    if frm[0] == "tile":
        a = frm[1]
        if a == REP: return 0
        if a == to: return 0
        if a[0] == "split" and to[0] == "split": return nbytes // 2
        if a[0] == "split" and to == REP: return nbytes
        raise AssertionError((frm, to))
    else:  # red
        if to[0] == "split": return nbytes
        return 2 * nbytes

def feasible(g, t, tile):
    if tile == REP: return True
    d = tile[1]
    sh = g.shape(t)
    return d < len(sh) and sh[d] >= 2 and sh[d] % 2 == 0

def ew_splittable(rank, weight_like):
    if rank == 4 and not weight_like: return [True, False, False, True]
    if rank == 4 and weight_like: return [False, False, True, True]
    return [True] * rank

def ident_map(rank): return [("d", i) for i in range(rank)]
NONE = ("none",)

def semantics(g, op):
    """returns ('grid', splittable, in_maps, out_map, allow_rep) or ('mm', xmap, ymap, zmap)"""
    name, kind, ins, outs = op
    k0 = kind[0]
    if k0 == "MatMul":
        _, ta, tb = kind
        x = (("d", 1 if ta else 0), ("d", 0 if ta else 1))
        y = (("d", 1 if tb else 0), ("d", 0 if tb else 1))
        z = (("d", 0), ("d", 1))
        return ("mm", x, y, z)
    if k0 == "BMM":
        _, ta, tb = kind
        am, ak = (2, 1) if ta else (1, 2)
        bk, bn = (1, 2) if tb else (2, 1)
        bn, bk = (1, 2) if tb else (2, 1)
        # careful: B stored [G, x, y]; k_dim = tb?2:1 ; n_dim = tb?1:2
        bk = 2 if tb else 1
        bn = 1 if tb else 2
        in_a = [("d",0), ("d",am), NONE, ("d",ak)]
        in_b = [("d",0), NONE, ("d",bn), ("d",bk)]
        out  = [("d",0), ("d",1), ("d",2), NONE]
        return ("grid", [True]*4, [in_a, in_b], out, False)
    if k0 == "Ew":
        rank = len(g.shape(op[3][0]))
        return ("grid", ew_splittable(rank, False), [ident_map(rank) for _ in ins], ident_map(rank), False)
    if k0 == "BiasAdd":
        rank = len(g.shape(ins[0]))
        bm = [NONE]*rank; bm[rank-1] = ("d",0)
        return ("grid", ew_splittable(rank, False), [ident_map(rank), bm], ident_map(rank), False)
    if k0 == "SoftmaxXent":
        return ("grid", [True, False], [ident_map(2), ident_map(2)], [NONE, NONE], False)
    if k0 == "SoftmaxXentGrad":
        return ("grid", [True, False], [ident_map(2), ident_map(2)], ident_map(2), False)
    if k0 == "ReduceSumRows":
        return ("grid", [True, True], [ident_map(2)], [NONE, ("d",0)], False)
    if k0 == "SgdUpdate":
        rank = len(g.shape(ins[0]))
        return ("grid", ew_splittable(rank, rank == 4), [ident_map(rank)]*2, ident_map(rank), True)
    if k0 == "LayerNorm":
        affine = kind[1]
        maps = [ident_map(2)]
        if affine: maps += [[NONE, ("d",0)], [NONE, ("d",0)]]
        return ("grid", [True, False], maps, ident_map(2), False)
    if k0 == "LayerNormGrad":
        maps = [ident_map(2), ident_map(2)] + ([[NONE, ("d",0)]] if len(ins) == 3 else [])
        return ("grid", [True, False], maps, ident_map(2), False)
    if k0 == "LayerNormGammaGrad":
        # ISSUE-5 fix: x must stay whole-row under the feature split (the
        # kernel recomputes per-row statistics) — mirrors tiling/aligned.rs.
        return ("grid", [True, True], [ident_map(2), [("d",0), NONE]], [NONE, ("d",0)], False)
    if k0 == "Softmax":
        rank = len(g.shape(ins[0]))
        return ("grid", [True]*(rank-1) + [False], [ident_map(rank)], ident_map(rank), False)
    if k0 == "SoftmaxGrad":
        rank = len(g.shape(ins[0]))
        return ("grid", [True]*(rank-1) + [False], [ident_map(rank)]*2, ident_map(rank), False)
    if k0 in ("SplitHeads", "MergeHeads", "SliceHeads"):
        return ("grid", [True], [[("d",0)] for _ in ins], [("d",0)], False)
    if k0 == "ConcatHeads":
        return ("grid", [True], [[("d",0)] for _ in ins], [("d",0)], False)
    raise AssertionError(k0)

def req_tile(m):
    return REP if m == NONE else S(m[1])

def op_cost(g, op, ins_t, out_t):
    name, kind, ins, outs = op
    sem = semantics(g, op)
    best = INF
    bz = bytes_of(g, outs[0])
    if sem[0] == "mm":
        _, x, y, z = sem
        tx, ty, tz = ins[0], ins[1], outs[0]
        bx, by = bytes_of(g, tx), bytes_of(g, ty)
        forms = [
            (req_tile(("d", x[0][1])), REP, ("tile", req_tile(("d", z[0][1])))),
            (REP, req_tile(("d", y[1][1])), ("tile", req_tile(("d", z[1][1])))),
            (req_tile(("d", x[1][1])), req_tile(("d", y[0][1])), ("red",)),
        ]
        for rx, ry, prod in forms:
            if not feasible(g, tx, rx) or not feasible(g, ty, ry): continue
            if prod[0] == "tile" and not feasible(g, tz, prod[1]): continue
            c = conv_cost(bx, ("tile", ins_t[0]), rx) + conv_cost(by, ("tile", ins_t[1]), ry)
            c += conv_cost(bz, prod, out_t)
            best = min(best, c)
        return best
    _, splittable, in_maps, out_map, allow_rep = sem
    if allow_rep:
        c = sum(conv_cost(bytes_of(g, t), ("tile", ins_t[i]), REP) for i, t in enumerate(ins))
        c += conv_cost(bz, ("tile", REP), out_t)
        best = min(best, c)
    for ax, ok in enumerate(splittable):
        if not ok: continue
        c = 0
        bad = False
        for i, m in enumerate(in_maps):
            r = req_tile(m[ax])
            if not feasible(g, ins[i], r): bad = True; break
            c += conv_cost(bytes_of(g, ins[i]), ("tile", ins_t[i]), r)
        if bad: continue
        if out_map[ax] == NONE:
            prod = ("red",)
        else:
            t = S(out_map[ax][1])
            if not feasible(g, outs[0], t): continue
            prod = ("tile", t)
        c += conv_cost(bz, prod, out_t)
        best = min(best, c)
    return best

def candidates(g, t, rank3_dims=(0,)):
    nm, shape, kind = g.tensors[t]
    r = len(shape)
    out = [REP]
    if r == 0: return out
    if r == 4 and kind in (WEIGHT, WGRAD, UPD): dims = [2, 3]
    elif r == 4: dims = [0, 3]
    elif r == 3: dims = list(rank3_dims)
    else: dims = list(range(r))
    for d in dims:
        if shape[d] >= 2 and shape[d] % 2 == 0: out.append(S(d))
    return out

def price(g, tiles):
    tot = 0
    for op in g.ops:
        _, _, ins, outs = op
        c = op_cost(g, op, [tiles[t] for t in ins], tiles[outs[0]])
        tot += c
        if c >= INF: return INF
    return tot

def dp_assignment(g):
    """The classic data-parallel assignment: params Rep, rest Split(0) if even."""
    tiles = []
    for t, (nm, shape, kind) in enumerate(g.tensors):
        if kind in (WEIGHT, WGRAD, UPD, SCALAR) or not shape:
            tiles.append(REP)
        elif shape[0] % 2 == 0:
            tiles.append(S(0))
        else:
            tiles.append(REP)
    return tiles

def apply_cut(g, tiles):
    import copy
    g2 = G()
    g2.tensors = [[n, list(s), k] for n, s, k in g.tensors]
    g2.ops = [[n, k, list(i), list(o)] for n, k, i, o in g.ops]
    for t, tile in enumerate(tiles):
        if tile != REP:
            d = tile[1]
            assert g2.tensors[t][1][d] % 2 == 0
            g2.tensors[t][1][d] //= 2
    return g2

if __name__ == "__main__":
    # (a) feasibility of DP assignment across 3 k-cut levels on micro config
    g = transformer_v2(8, 128, 256, 4, 1024, 4, 256, fused=True)
    alias = aliases(g)
    cur = g
    for cut in range(3):
        tiles = dp_assignment(cur)
        for t in range(len(tiles)):
            tiles[t] = tiles[alias[t]]
        c = price(cur, tiles)
        assert c < INF, f"cut {cut}: DP assignment infeasible"
        wb = sum(bytes_of(cur, t) for t, (n, s, k) in enumerate(cur.tensors) if k == WEIGHT)
        print(f"cut {cut}: DP-style price = {c:,} bytes (2*|w| = {2*wb:,})")
        cur = apply_cut(cur, tiles)
    # every op must have a finite-cost entry for at least one candidate combo, each cut
    cur = g
    for cut in range(3):
        for op in cur.ops:
            _, _, ins, outs = op
            ok = False
            for combo in itertools.product(*[candidates(cur, t) for t in ins + [outs[0]]]):
                if op_cost(cur, op, list(combo[:-1]), combo[-1]) < INF:
                    ok = True
                    break
            assert ok, f"cut {cut}: op {op[0]} has no feasible candidate combo"
        tiles = dp_assignment(cur)
        for t in range(len(tiles)):
            tiles[t] = tiles[alias[t]]
        cur = apply_cut(cur, tiles)
    print("feasibility: every op has a feasible combo at cuts 0..2")

    # (b) brute force tiny attention core (forward only)
    tg = G()
    qkv = tg.t("qkv", [8, 24], INPUT)   # rows=8 (B=2,S=4), 3D=24 (D=8, heads=2, dh=4)
    y = tg.t("y", [8, 8], LABEL)
    qh = slice_heads(tg, "sq", qkv, 0, 2, 4)
    kh = slice_heads(tg, "sk", qkv, 1, 2, 4)
    vh = slice_heads(tg, "sv", qkv, 2, 2, 4)
    sc = bmm(tg, "scores", qh, kh, False, True)
    pr = softmax_rows(tg, "probs", sc)
    ct = bmm(tg, "ctx", pr, vh, False, False)
    cm = merge_heads(tg, "mh", ct, 2)
    w = tg.t("w", [8, 8], WEIGHT)
    logits = matmul(tg, "head", cm, w)
    loss = softmax_xent(tg, "loss", logits, y)
    cands = [candidates(tg, t) for t in range(len(tg.tensors))]
    states = 1
    for c in cands: states *= len(c)
    print(f"attention core: {len(tg.tensors)} tensors, brute-force states = {states:,}")
    best, bt = INF, None
    for combo in itertools.product(*cands):
        c = price(tg, list(combo))
        if c < best: best, bt = c, combo
    print(f"brute-force optimum = {best:,} bytes")
    for t, tile in enumerate(bt):
        print(f"  {tg.tensors[t][0]:14} {tile}")
