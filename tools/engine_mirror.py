import heapq, random

# Mirror of the Rust engine semantics on synthetic SPMD programs.
# Instr kinds: ('C', seconds) compute; ('T', gid, bytes) transfer start; ('W', gid) wait.

def share(P, n, r):
    return P // n + (1 if r < P % n else 0)

def transfer_seconds(cut, pair_bytes, bw, slots, lat):
    pairs = float(1 << cut)
    agg = bw * min(slots, pairs)
    return pair_bytes * pairs / agg + lat

def run(k, programs, meta, bw=1e9, slots=2.0, lat=2e-5):
    devices = 1 << k
    instances = {}  # (gid,pair) -> dict
    for gid, m in enumerate(meta):
        for pair in range(1 << m['cut']):
            instances[(gid, pair)] = dict(bytes=0, issued=0, ready=0.0, comp=None, waiters=[])
    pc = [0]*devices; end = [0.0]*devices; fin=[False]*devices
    parked=[False]*devices; parked_at=[0.0]*devices
    xfer=[0.0]*devices
    heap=[]; seq=0
    for d in range(devices):
        seq+=1; heapq.heappush(heap,(0.0,seq,('dev',d)))
    while heap:
        time,_,ev=heapq.heappop(heap)
        if ev[0]=='done':
            _,gid,pair=ev
            inst=instances[(gid,pair)]
            ws=inst['waiters']; inst['waiters']=[]
            for w in ws:
                seq+=1; heapq.heappush(heap,(time,seq,('dev',w)))
            continue
        d=ev[1]; t=time; prog=programs[d]
        while True:
            if pc[d]==len(prog):
                end[d]=t; fin[d]=True; break
            ins=prog[pc[d]]
            if ins[0]=='C':
                t+=ins[1]; pc[d]+=1
            elif ins[0]=='W':
                gid=ins[1]; cut=meta[gid]['cut']; pair=d>>(k-cut)
                inst=instances[(gid,pair)]
                if inst['comp'] is not None:
                    parked[d]=False
                    if inst['comp']>t: t=inst['comp']
                    pc[d]+=1
                else:
                    inst['waiters'].append(d); parked[d]=True; parked_at[d]=t; break
            else:
                gid=ins[1]; cut=meta[gid]['cut']; pair=d>>(k-cut); members=devices>>cut
                inst=instances[(gid,pair)]
                inst['bytes']+=ins[2]; inst['issued']+=1
                inst['ready']=max(inst['ready'],t)
                if inst['issued']==members:
                    dur=transfer_seconds(cut,inst['bytes'],bw,slots,lat)
                    comp=inst['ready']+dur; inst['comp']=comp
                    for mem in range(pair*members,(pair+1)*members): xfer[mem]+=dur
                    seq+=1; heapq.heappush(heap,(comp,seq,('done',gid,pair)))
                pc[d]+=1
    assert all(fin), "deadlock"
    return max(end), xfer, instances

def build_random_program(k, n_ops, rng):
    # Mimics the lowering: per op, maybe input transfer(s)+waits, compute, maybe deferred output transfer
    devices=1<<k
    meta=[]; progs=[[] for _ in range(devices)]
    pending=[]  # list of gids to wait later
    comp_total=0.0
    for op in range(n_ops):
        # drain some pending (like consumer waits)
        while pending and rng.random()<0.5:
            gid=pending.pop(0)
            for d in range(devices): progs[d].append(('W',gid))
        own=[]
        for j in range(k):
            if rng.random()<0.4:
                gid=len(meta); P=rng.randrange(1, 500000)
                meta.append(dict(cut=j, P=P))
                n=devices>>j
                for d in range(devices):
                    progs[d].append(('T',gid,share(P,n,d&(n-1))))
                own.append(gid)
        for gid in own:
            for d in range(devices): progs[d].append(('W',gid))
        s=rng.random()*1e-3
        comp_total+=s
        for d in range(devices): progs[d].append(('C',s))
        for j in range(k):
            if rng.random()<0.3:
                gid=len(meta); P=rng.randrange(1,500000)
                meta.append(dict(cut=j,P=P))
                n=devices>>j
                for d in range(devices):
                    progs[d].append(('T',gid,share(P,n,d&(n-1))))
                pending.append(gid)
    for gid in pending:
        for d in range(devices): progs[d].append(('W',gid))
    return progs, meta, comp_total

rng=random.Random(7)
for trial in range(200):
    k=rng.choice([1,2,3])
    progs,meta,comp=build_random_program(k, rng.randrange(3,25), rng)
    step,xfer,instances=run(k,progs,meta)
    # invariant: instance bytes == P
    for gid,m in enumerate(meta):
        for pair in range(1<<m['cut']):
            assert instances[(gid,pair)]['bytes']==m['P'], (gid,pair)
            assert instances[(gid,pair)]['comp'] is not None
    # envelope
    assert step >= comp - 1e-12, (step, comp)
    assert step <= comp + max(xfer) + 1e-9, (trial, step, comp, max(xfer))
print("200 random trials OK: termination, byte reconstruction, envelope hold")
