//! Transformer encoder training graphs — the repo's first post-paper
//! workload class (pre-LN GPT-2-style blocks with a classification head).
//!
//! Each block is: `x + Wo·Attn(LN(x))` followed by `x + W2·gelu(W1·LN(x))`,
//! expressed over *folded* `[B·S, D]` activations (batch and sequence share
//! the row axis, so row splits are batch splits) with the attention core in
//! the `[B·H, S, D/H]` head view, whose leading axis tiles like a data
//! axis. The 1/√(D/H) score scaling is absorbed into the fused projection
//! weight — it is tiling-neutral and keeps the graph exactly the operator
//! set the planner prices.
//!
//! Two graph-shape decisions exist purely for the one-cut DP (see
//! DESIGN.md §Transformer for the measurements):
//!
//! - **Fused q/k/v projection** (`Wqkv: [D, 3D]` + [`OpKind::QkvSlice`]):
//!   three separate projections put {qᵒ, kᵒ, vᵒ, dqᵒ, dkᵒ, dvᵒ, Wq, Wk,
//!   Wv, dWq, dWk, dWv} into one DP boundary — ~3¹² states where the
//!   paper's workloads have ≤ 3⁵. Fusing collapses that to one
//!   activation/gradient/weight triple.
//! - **Identity wires on skip paths** ([`crate::graph::EwKind::Ident`]):
//!   a direct residual edge makes the undirected op graph's diameter tiny,
//!   so BFS levelization (§4.2.2) folds a whole block into a handful of
//!   levels with enormous boundaries. Free identity relays on the skip
//!   (and on the V path into attention·V) length-match every parallel
//!   path, restoring the layered-chain structure the DP's complexity
//!   argument assumes. Wires cost nothing under Eq. (2) when input and
//!   output tilings agree, so plan costs are unchanged.
//!
//! [`OpKind::QkvSlice`]: crate::graph::OpKind

use crate::graph::{append_backward, Graph, GraphBuilder, TensorId};

/// Transformer encoder configuration.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    /// Sequences per step. Must be even (and divisible by `2^k` for a
    /// k-cut plan to keep batch-tiling the attention view).
    pub batch: usize,
    /// Tokens per sequence.
    pub seq: usize,
    /// Embedding width.
    pub d_model: usize,
    /// Attention heads (must divide `d_model`).
    pub heads: usize,
    /// Feed-forward hidden width.
    pub d_ff: usize,
    /// Encoder block count.
    pub layers: usize,
    /// Output classes of the linear head (per-position labels).
    pub classes: usize,
}

impl TransformerConfig {
    /// The `transformer_micro` bench workload: a small GPT-2-block stack
    /// (4 layers, 4 heads, d_model 256, seq 128) planned for 8 devices.
    pub fn micro() -> Self {
        TransformerConfig {
            batch: 8,
            seq: 128,
            d_model: 256,
            heads: 4,
            d_ff: 1024,
            layers: 4,
            classes: 256,
        }
    }

    /// Single tiny block for tests: same graph topology as [`Self::micro`]
    /// per layer (so DP state spaces match), toy dimensions.
    pub fn tiny() -> Self {
        TransformerConfig {
            batch: 4,
            seq: 4,
            d_model: 8,
            heads: 2,
            d_ff: 16,
            layers: 1,
            classes: 8,
        }
    }

    /// The differential-harness encoder: the [`Self::micro`] 4-layer
    /// topology at [`Self::tiny`]-scale dimensions, batch 8 so every
    /// batch-axis tensor splits cleanly across up to 8 devices.
    pub fn tiny4() -> Self {
        TransformerConfig {
            batch: 8,
            seq: 4,
            d_model: 8,
            heads: 2,
            d_ff: 16,
            layers: 4,
            classes: 8,
        }
    }
}

/// Chain of free identity relays (see module docs).
fn wire(b: &mut GraphBuilder, name: &str, mut x: TensorId, hops: usize) -> TensorId {
    for i in 0..hops {
        x = b.ident(&format!("{name}{i}"), x);
    }
    x
}

/// Build the full training-step graph (forward + backward + SGD) of a
/// transformer encoder stack.
pub fn transformer(cfg: &TransformerConfig) -> Graph {
    assert!(cfg.layers >= 1, "at least one encoder layer");
    assert_eq!(cfg.d_model % cfg.heads, 0, "d_model must divide into heads");
    assert!(cfg.batch % 2 == 0, "batch must be even for batch-axis tiling");
    let rows = cfg.batch * cfg.seq;
    let d = cfg.d_model;

    let mut b = GraphBuilder::new();
    let x = b.input("x", &[rows, d]);
    let y = b.label("y", &[rows, cfg.classes]);
    let mut h = x;
    for l in 0..cfg.layers {
        let p = |s: &str| format!("l{l}.{s}");
        // Attention half: x + Wo·Attn(LN(x)).
        let g1 = b.weight(&p("ln1.g"), &[d]);
        let b1 = b.weight(&p("ln1.b"), &[d]);
        let h1 = b.layer_norm(&p("ln1"), h, g1, b1);
        let wqkv = b.weight(&p("wqkv"), &[d, 3 * d]);
        let qkv = b.matmul(&p("qkv"), h1, wqkv, false, false);
        let qh = b.qkv_slice(&p("slice_q"), qkv, 0, cfg.heads, cfg.seq);
        let kh = b.qkv_slice(&p("slice_k"), qkv, 1, cfg.heads, cfg.seq);
        let vh = b.qkv_slice(&p("slice_v"), qkv, 2, cfg.heads, cfg.seq);
        let sc = b.batched_matmul(&p("scores"), qh, kh, false, true);
        let pr = b.softmax_rows(&p("probs"), sc);
        // V waits two stages (scores, probs) before attention·V reads it.
        let vw = wire(&mut b, &p("v.wire"), vh, 2);
        let ct = b.batched_matmul(&p("ctx"), pr, vw, false, false);
        let cm = b.merge_heads(&p("merge"), ct, cfg.heads);
        let wo = b.weight(&p("wo"), &[d, d]);
        let ao = b.matmul(&p("proj"), cm, wo, false, false);
        // Skip path length-matched to the 8-op attention branch.
        let hs = wire(&mut b, &p("res1.wire"), h, 8);
        h = b.add(&p("res1"), hs, ao);

        // Feed-forward half: x + W2·gelu(W1·LN(x)).
        let g2 = b.weight(&p("ln2.g"), &[d]);
        let b2 = b.weight(&p("ln2.b"), &[d]);
        let h2 = b.layer_norm(&p("ln2"), h, g2, b2);
        let w1 = b.weight(&p("ff1.w"), &[d, cfg.d_ff]);
        let f1 = b.matmul(&p("ff1"), h2, w1, false, false);
        let ge = b.gelu(&p("gelu"), f1);
        let w2 = b.weight(&p("ff2.w"), &[cfg.d_ff, d]);
        let f2 = b.matmul(&p("ff2"), ge, w2, false, false);
        let hs2 = wire(&mut b, &p("res2.wire"), h, 4);
        h = b.add(&p("res2"), hs2, f2);
    }
    let gf = b.weight("lnf.g", &[d]);
    let bf = b.weight("lnf.b", &[d]);
    let hf = b.layer_norm("lnf", h, gf, bf);
    let wh = b.weight("head.w", &[d, cfg.classes]);
    let logits = b.matmul("head", hf, wh, false, false);
    let loss = b.softmax_xent("loss", logits, y);
    append_backward(&mut b, loss);
    b.finish()
}

/// A forward-only attention core small enough for *exhaustive* tiling
/// enumeration (~15k assignments): fused-projection slices, QKᵀ, row
/// softmax, attention·V, head merge, linear head, loss. The brute-force
/// property tests pin the one-cut DP on exactly this graph.
pub fn attention_probe() -> Graph {
    let mut b = GraphBuilder::new();
    let qkv = b.input("qkv", &[8, 24]); // batch 2, seq 4, d_model 8, heads 2
    let y = b.label("y", &[8, 8]);
    let qh = b.qkv_slice("slice_q", qkv, 0, 2, 4);
    let kh = b.qkv_slice("slice_k", qkv, 1, 2, 4);
    let vh = b.qkv_slice("slice_v", qkv, 2, 2, 4);
    let sc = b.batched_matmul("scores", qh, kh, false, true);
    let pr = b.softmax_rows("probs", sc);
    let ct = b.batched_matmul("ctx", pr, vh, false, false);
    let cm = b.merge_heads("merge", ct, 2);
    let w = b.weight("head.w", &[8, 8]);
    let logits = b.matmul("head", cm, w, false, false);
    b.softmax_xent("loss", logits, y);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{bfs_levels, OpKind, TensorKind};
    use crate::tiling::candidate_tiles;

    #[test]
    fn tiny_block_op_census() {
        let g = transformer(&TransformerConfig::tiny());
        let count = |f: &dyn Fn(&OpKind) -> bool| g.ops.iter().filter(|o| f(&o.kind)).count();
        // 2 forward batched matmuls + 4 backward per layer.
        assert_eq!(count(&|k| matches!(k, OpKind::BatchedMatMul { .. })), 6);
        // 3 layer norms (2 in-block + final) with one grad + gamma-grad each.
        assert_eq!(count(&|k| matches!(k, OpKind::LayerNorm)), 3);
        assert_eq!(count(&|k| matches!(k, OpKind::LayerNormGrad)), 3);
        assert_eq!(count(&|k| matches!(k, OpKind::LayerNormGammaGrad)), 3);
        // Fused projection: 3 slices forward, 1 concat backward.
        assert_eq!(count(&|k| matches!(k, OpKind::QkvSlice { .. })), 3);
        assert_eq!(count(&|k| matches!(k, OpKind::QkvConcat)), 1);
        assert_eq!(count(&|k| matches!(k, OpKind::Softmax)), 1);
        assert_eq!(count(&|k| matches!(k, OpKind::SoftmaxGrad)), 1);
        // merge forward + its split backward, plus the ctx-grad view swap.
        assert!(count(&|k| matches!(k, OpKind::MergeHeads { .. })) >= 1);
        assert!(count(&|k| matches!(k, OpKind::SplitHeads { .. })) >= 1);
    }

    #[test]
    fn every_weight_updated() {
        let g = transformer(&TransformerConfig::tiny());
        let weights = g.tensors.iter().filter(|t| t.kind == TensorKind::Weight).count();
        let updates = g.ops.iter().filter(|o| o.kind == OpKind::SgdUpdate).count();
        assert_eq!(weights, updates);
        // 2 LN pairs + wqkv + wo + 2 ff per layer, + final LN pair + head.
        assert_eq!(weights, 8 * 1 + 3);
    }

    #[test]
    fn graph_is_acyclic_and_levelizable() {
        let g = transformer(&TransformerConfig::tiny());
        assert_eq!(g.topo_order().len(), g.ops.len());
        // The wires keep the undirected levelization layered: narrow
        // levels are what keeps the one-cut DP polynomial here.
        let lv = bfs_levels(&g);
        assert!(lv.levels.len() >= 10, "transformer block collapsed to {} levels", lv.levels.len());
        assert!(lv.max_width() <= 12, "level width {} too wide for the DP", lv.max_width());
    }

    #[test]
    fn dp_boundary_spaces_stay_small() {
        // The fused-qkv + wire design caps every DP boundary state space;
        // this pins the graph-shape contract the planner's runtime relies
        // on (see module docs).
        let g = transformer(&TransformerConfig::micro());
        let lv = bfs_levels(&g);
        for (l, b) in lv.boundary.iter().enumerate() {
            let states: u128 = b
                .iter()
                .map(|&t| candidate_tiles(&g.tensors[t]).len() as u128)
                .product();
            assert!(states <= 10_000, "boundary {l} has {states} states");
        }
    }

    #[test]
    fn head_view_shapes() {
        let cfg = TransformerConfig::micro();
        let g = transformer(&cfg);
        let t = |name: &str| {
            g.tensors
                .iter()
                .find(|t| t.name == name)
                .unwrap_or_else(|| panic!("no tensor {name}"))
                .shape
                .clone()
        };
        assert_eq!(t("l0.slice_q.out"), vec![8 * 4, 128, 64]); // [B·H, S, D/H]
        assert_eq!(t("l0.scores.out"), vec![8 * 4, 128, 128]); // [B·H, S, S]
        assert_eq!(t("l0.merge.out"), vec![8 * 128, 256]); // back to [B·S, D]
    }

    #[test]
    fn attention_probe_is_enumerable() {
        let g = attention_probe();
        let states: u128 = g
            .steady_state_aliases()
            .iter()
            .enumerate()
            .filter(|&(t, &a)| a == t)
            .map(|(t, _)| candidate_tiles(&g.tensors[t]).len() as u128)
            .product();
        assert!(states <= 100_000, "probe space {states} too big for brute force");
    }
}
