//! Multi-layer perceptron training graphs (Figures 8a–c, Table 1, and the
//! end-to-end example).

use crate::graph::{append_backward, Graph, GraphBuilder, TensorId};

/// MLP configuration. `dims[0]` is the input width, `dims.last()` the class
/// count; every interior entry a hidden layer.
#[derive(Debug, Clone)]
pub struct MlpConfig {
    /// Rows per training step.
    pub batch: usize,
    /// Layer widths, input first (L = `dims.len() - 1` matmuls).
    pub dims: Vec<usize>,
    /// Include bias vectors (the paper's MLP experiments are pure matmul
    /// chains; the e2e example uses biases).
    pub bias: bool,
}

impl MlpConfig {
    /// The paper's Figure 8 configuration: a 4-layer MLP with square
    /// `hidden × hidden` weights.
    pub fn fig8(batch: usize, hidden: usize) -> Self {
        MlpConfig { batch, dims: vec![hidden; 5], bias: false }
    }

    /// The e2e training example (~13M parameters).
    pub fn e2e() -> Self {
        MlpConfig { batch: 128, dims: vec![784, 2048, 2048, 2048, 10], bias: true }
    }
}

/// Build the full training-step graph for an MLP.
pub fn mlp(cfg: &MlpConfig) -> Graph {
    let (g, _) = mlp_with_loss(cfg);
    g
}

/// Like [`mlp`] but also returning the loss tensor id (used by the engine).
pub fn mlp_with_loss(cfg: &MlpConfig) -> (Graph, TensorId) {
    let mut b = GraphBuilder::new();
    let nl = cfg.dims.len() - 1;
    let mut h = b.input("x", &[cfg.batch, cfg.dims[0]]);
    let y = b.label("y", &[cfg.batch, *cfg.dims.last().unwrap()]);
    for l in 0..nl {
        let w = b.weight(&format!("w{l}"), &[cfg.dims[l], cfg.dims[l + 1]]);
        h = b.matmul(&format!("fc{l}"), h, w, false, false);
        if cfg.bias {
            let bias = b.weight(&format!("b{l}"), &[cfg.dims[l + 1]]);
            h = b.bias_add(&format!("fc{l}.ba"), h, bias);
        }
        if l + 1 < nl {
            h = b.relu(&format!("fc{l}.relu"), h);
        }
    }
    let loss = b.softmax_xent("loss", h, y);
    append_backward(&mut b, loss);
    (b.finish(), loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn fig8_graph_matches_paper_counts() {
        // 4 layers => 12 matmuls (3N of §4.2.2), weights 8192².
        let g = mlp(&MlpConfig::fig8(512, 8192));
        let mm = g.ops.iter().filter(|o| matches!(o.kind, OpKind::MatMul { .. })).count();
        assert_eq!(mm, 12);
        assert_eq!(g.weight_bytes(), 4 * 8192 * 8192 * 4);
    }

    #[test]
    fn e2e_param_count() {
        let g = mlp(&MlpConfig::e2e());
        let params = g.weight_bytes() / 4;
        // 784·2048 + 2048² + 2048² + 2048·10 + biases ≈ 10.0M
        assert!(params > 9_000_000 && params < 15_000_000, "{params}");
    }
}
