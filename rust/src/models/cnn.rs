//! The 5-layer convolutional network of Figure 9, parameterized by image
//! size and filter count.
//!
//! Figure 9(a) trains on small 6×6 images with a large filter count (2048);
//! Figure 9(b) on larger 24×24 images with 512 filters; batch 256 in both.

use crate::graph::{append_backward, Graph, GraphBuilder};

/// 5 stacked 3×3 same-padding conv layers (+ReLU), global flatten, FC
/// softmax head — the §6.2 CNN shape.
pub fn cnn5(batch: usize, image: usize, channels_in: usize, filters: usize, classes: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let mut h = b.input("x", &[batch, image, image, channels_in]);
    let y = b.label("y", &[batch, classes]);
    let mut cin = channels_in;
    for l in 0..5 {
        let w = b.weight(&format!("conv{l}.w"), &[3, 3, cin, filters]);
        h = b.conv2d(&format!("conv{l}"), h, w, 1, 1);
        h = b.relu(&format!("conv{l}.relu"), h);
        cin = filters;
    }
    let flat = b.flatten("flatten", h);
    let feat = image * image * filters;
    let w_fc = b.weight("fc.w", &[feat, classes]);
    let logits = b.matmul("fc", flat, w_fc, false, false);
    let loss = b.softmax_xent("loss", logits, y);
    append_backward(&mut b, loss);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn fig9a_shape() {
        let g = cnn5(256, 6, 4, 2048, 10);
        let convs = g.ops.iter().filter(|o| matches!(o.kind, OpKind::Conv2d { .. })).count();
        assert_eq!(convs, 5);
        // Same-padding: spatial dims preserved.
        let act = g.tensors.iter().find(|t| t.name == "conv4.out").unwrap();
        assert_eq!(act.shape, vec![256, 6, 6, 2048]);
    }

    #[test]
    fn fig9_filter_vs_image_tradeoff() {
        // 9(a): small image, big filters => weights dominate activations.
        let a = cnn5(256, 6, 4, 2048, 10);
        assert!(a.weight_bytes() > a.activation_bytes() / 4);
        // 9(b): big image, small filters => activations dominate weights.
        let b = cnn5(256, 24, 4, 512, 10);
        assert!(b.activation_bytes() > b.weight_bytes());
    }

    #[test]
    fn backward_ops_present() {
        let g = cnn5(8, 6, 4, 16, 10);
        assert!(g.ops.iter().any(|o| matches!(o.kind, OpKind::Conv2dBwdData { .. })));
        assert!(g.ops.iter().any(|o| matches!(o.kind, OpKind::Conv2dBwdFilter { .. })));
        assert!(g.ops.iter().any(|o| o.kind == OpKind::FlattenBwd));
    }
}
