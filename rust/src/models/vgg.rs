//! VGG-16 (Simonyan & Zisserman 2015) as a training graph — Figure 10(b).
//!
//! 13 3×3 conv layers in five blocks with 2×2 pools, then the 3-layer FC
//! head (~138M parameters). "VGG has similar structure to AlexNet but with
//! more layers" (§6.4) — deeper conv stack, even heavier FC head.
//!
//! [`vgg16_scaled`] keeps the 13-conv/5-pool topology while
//! parameterizing image size and FC width; the differential execution
//! harness runs the 32×32, 256-wide instance (each pool halves cleanly:
//! 32 → 16 → 8 → 4 → 2 → 1).

use crate::graph::{append_backward, Graph, GraphBuilder, TensorId};

fn block(b: &mut GraphBuilder, mut h: TensorId, name: &str, convs: usize, cin: usize, cout: usize) -> TensorId {
    let mut c = cin;
    for i in 0..convs {
        let w = b.weight(&format!("{name}.conv{i}.w"), &[3, 3, c, cout]);
        h = b.conv2d(&format!("{name}.conv{i}"), h, w, 1, 1);
        h = b.relu(&format!("{name}.conv{i}.relu"), h);
        c = cout;
    }
    b.pool2(&format!("{name}.pool"), h)
}

/// Build VGG-16's training step for the given batch size (the full-size
/// Figure 10(b) model: 224×224 images, 4096-wide FC head).
pub fn vgg16(batch: usize) -> Graph {
    vgg16_scaled(batch, 224, 4096)
}

/// VGG-16's training step with parametric image size and FC width.
/// `vgg16_scaled(b, 224, 4096)` is exactly [`vgg16`]; the harness runs
/// reduced instances whose five pools still halve evenly.
pub fn vgg16_scaled(batch: usize, image: usize, fc: usize) -> Graph {
    assert!(image % 32 == 0 && image >= 32, "five 2x2 pools need image % 32 == 0, got {image}");
    let mut b = GraphBuilder::new();
    let mut h = b.input("x", &[batch, image, image, 3]);
    let y = b.label("y", &[batch, 1000]);

    h = block(&mut b, h, "b1", 2, 3, 64); // 224 -> 112
    h = block(&mut b, h, "b2", 2, 64, 128); // 112 -> 56
    h = block(&mut b, h, "b3", 3, 128, 256); // 56 -> 28
    h = block(&mut b, h, "b4", 3, 256, 512); // 28 -> 14
    h = block(&mut b, h, "b5", 3, 512, 512); // 14 -> 7

    let flat = b.flatten("flatten", h); // 7*7*512 = 25088 at full size
    let feat = b.graph.tensors[flat].shape[1];
    let wf1 = b.weight("fc1.w", &[feat, fc]);
    let mut f = b.matmul("fc1", flat, wf1, false, false);
    f = b.relu("fc1.relu", f);
    let wf2 = b.weight("fc2.w", &[fc, fc]);
    f = b.matmul("fc2", f, wf2, false, false);
    f = b.relu("fc2.relu", f);
    let wf3 = b.weight("fc3.w", &[fc, 1000]);
    let logits = b.matmul("fc3", f, wf3, false, false);

    let loss = b.softmax_xent("loss", logits, y);
    append_backward(&mut b, loss);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpKind;

    #[test]
    fn parameter_count_near_138m() {
        let g = vgg16(32);
        let params = g.weight_bytes() / 4;
        assert!(params > 130_000_000 && params < 140_000_000, "{params}");
    }

    #[test]
    fn thirteen_conv_layers() {
        let g = vgg16(32);
        let convs = g.ops.iter().filter(|o| matches!(o.kind, OpKind::Conv2d { .. })).count();
        assert_eq!(convs, 13);
    }

    #[test]
    fn final_spatial_shape() {
        let g = vgg16(16);
        let p5 = g.tensors.iter().find(|t| t.name == "b5.pool.out").unwrap();
        assert_eq!(p5.shape, vec![16, 7, 7, 512]);
    }

    #[test]
    fn scaled_instance_keeps_topology() {
        let g = vgg16_scaled(8, 32, 256);
        let p5 = g.tensors.iter().find(|t| t.name == "b5.pool.out").unwrap();
        assert_eq!(p5.shape, vec![8, 1, 1, 512]);
        let fc1 = g.tensors.iter().find(|t| t.name == "fc1.w").unwrap();
        assert_eq!(fc1.shape, vec![512, 256]);
        let full = vgg16(8);
        let kinds = |g: &Graph| g.ops.iter().map(|o| o.kind).collect::<Vec<_>>();
        assert_eq!(kinds(&g), kinds(&full));
    }
}
