//! AlexNet (Krizhevsky et al. 2012) as a training graph — Figure 10(a).
//!
//! Faithful layer shapes (227×227×3 input, 5 conv layers, 3 FC layers,
//! ~60M parameters), with the LRN layers omitted (they are
//! tiling-transparent elementwise ops with negligible traffic) and the
//! stride-4 11×11 stem expressed exactly.
//!
//! [`alexnet_scaled`] keeps the exact layer *topology* (same conv
//! stack, same pools, same op sequence) while parameterizing the image
//! size and FC width — the differential execution harness runs the
//! `image = 67, fc = 256` instance, which is numerically tractable on
//! real `f32` buffers while exercising every shape case of the full
//! model (stride-4 stem, odd pooled extents, the conv→FC flatten).

use crate::graph::{append_backward, Graph, GraphBuilder};

/// Build AlexNet's training step for the given batch size (the
/// full-size Figure 10(a) model: 227×227 images, 4096-wide FC head).
pub fn alexnet(batch: usize) -> Graph {
    alexnet_scaled(batch, 227, 4096)
}

/// AlexNet's training step with parametric input image size and FC
/// width. `alexnet_scaled(b, 227, 4096)` is exactly [`alexnet`];
/// smaller instances keep the layer topology but shrink the spatial
/// pipeline and head so the numeric harness can execute them.
pub fn alexnet_scaled(batch: usize, image: usize, fc: usize) -> Graph {
    assert!(image >= 11, "stride-4 11x11 stem needs image >= 11, got {image}");
    // The spatial pipeline must survive every stage: stem -> pool1 ->
    // conv2 -> pool2 -> conv3..5 -> pool5 needs pool2's extent >= 2 so
    // pool5 stays >= 1 (image 67 gives 15 -> 7 -> 3 -> 1; image 15
    // would collapse to zero and underflow conv shape inference).
    let stem = (image - 11) / 4 + 1;
    assert!(
        stem / 2 / 2 >= 2,
        "alexnet_scaled: image {image} collapses the spatial pipeline (pool5 would be empty)"
    );
    let mut b = GraphBuilder::new();
    let mut h = b.input("x", &[batch, image, image, 3]);
    let y = b.label("y", &[batch, 1000]);

    // conv1: 11x11/4 stem, pool (227 -> 55 -> 27; 67 -> 15 -> 7).
    let w1 = b.weight("conv1.w", &[11, 11, 3, 96]);
    h = b.conv2d("conv1", h, w1, 4, 0);
    h = b.relu("conv1.relu", h);
    h = b.pool2("pool1", h);
    // conv2: 5x5 pad 2, pool.
    let w2 = b.weight("conv2.w", &[5, 5, 96, 256]);
    h = b.conv2d("conv2", h, w2, 1, 2);
    h = b.relu("conv2.relu", h);
    h = b.pool2("pool2", h);
    // conv3..5: 3x3 pad 1.
    let w3 = b.weight("conv3.w", &[3, 3, 256, 384]);
    h = b.conv2d("conv3", h, w3, 1, 1);
    h = b.relu("conv3.relu", h);
    let w4 = b.weight("conv4.w", &[3, 3, 384, 384]);
    h = b.conv2d("conv4", h, w4, 1, 1);
    h = b.relu("conv4.relu", h);
    let w5 = b.weight("conv5.w", &[3, 3, 384, 256]);
    h = b.conv2d("conv5", h, w5, 1, 1);
    h = b.relu("conv5.relu", h);
    h = b.pool2("pool5", h);

    let flat = b.flatten("flatten", h);
    let feat = b.graph.tensors[flat].shape[1]; // 9216 at full size
    let wf1 = b.weight("fc6.w", &[feat, fc]);
    let mut f = b.matmul("fc6", flat, wf1, false, false);
    f = b.relu("fc6.relu", f);
    let wf2 = b.weight("fc7.w", &[fc, fc]);
    f = b.matmul("fc7", f, wf2, false, false);
    f = b.relu("fc7.relu", f);
    let wf3 = b.weight("fc8.w", &[fc, 1000]);
    let logits = b.matmul("fc8", f, wf3, false, false);

    let loss = b.softmax_xent("loss", logits, y);
    append_backward(&mut b, loss);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_count_near_60m() {
        let g = alexnet(128);
        let params = g.weight_bytes() / 4;
        // Canonical AlexNet (without biases): ~60.9M weights.
        assert!(params > 55_000_000 && params < 65_000_000, "{params}");
    }

    #[test]
    fn fc_layers_dominate_parameters() {
        // The property Figure 10(a) exploits: FC weights (fc6 alone is
        // 9216×4096 ≈ 37.7M) dwarf conv filters, so data parallelism pays
        // hugely for the FC gradients while activations entering fc6 are
        // tiny — hybrid tiling wins.
        let g = alexnet(128);
        let fc6 = g.tensors.iter().find(|t| t.name == "fc6.w").unwrap();
        assert_eq!(fc6.bytes(), 9216 * 4096 * 4);
        assert!(fc6.bytes() * 2 > g.weight_bytes() / 2);
    }

    #[test]
    fn spatial_pipeline_shapes() {
        let g = alexnet(64);
        let pool5 = g.tensors.iter().find(|t| t.name == "pool5.out").unwrap();
        assert_eq!(pool5.shape, vec![64, 6, 6, 256]);
    }

    #[test]
    #[should_panic(expected = "collapses the spatial pipeline")]
    fn scaled_instance_rejects_collapsing_images() {
        // image 15: stem 2 -> pool 1 -> pool 0; conv3 would underflow.
        alexnet_scaled(8, 15, 256);
    }

    #[test]
    fn scaled_instance_keeps_topology() {
        // The 67px harness instance: same op sequence, 1x1 pooled tail.
        let g = alexnet_scaled(8, 67, 256);
        let pool5 = g.tensors.iter().find(|t| t.name == "pool5.out").unwrap();
        assert_eq!(pool5.shape, vec![8, 1, 1, 256]);
        let fc6 = g.tensors.iter().find(|t| t.name == "fc6.w").unwrap();
        assert_eq!(fc6.shape, vec![256, 256]);
        let full = alexnet(8);
        let kinds = |g: &Graph| g.ops.iter().map(|o| o.kind).collect::<Vec<_>>();
        assert_eq!(kinds(&g), kinds(&full));
    }
}
