//! Model zoo: the paper's evaluation workloads as semantic training graphs.
//!
//! Every constructor returns the *full training step* (forward + backward +
//! SGD updates) built through [`crate::graph::GraphBuilder`] and
//! [`crate::graph::append_backward`] — the exact graphs the figures sweep:
//!
//! - [`mlp`] — the L-layer MLP of Figures 8(a–c) and Table 1;
//! - [`cnn5`] — the 5-layer CNN of Figures 9(a–b), parameterized by image
//!   size and filter count;
//! - [`alexnet`] — Figure 10(a);
//! - [`vgg16`] — Figure 10(b);
//! - [`transformer`] — the post-paper workload class: a pre-LN GPT-2-style
//!   encoder stack (attention + feed-forward blocks) with a linear head.

mod alexnet;
mod cnn;
mod mlp;
mod transformer;
mod vgg;

pub use alexnet::{alexnet, alexnet_scaled};
pub use cnn::cnn5;
pub use mlp::{mlp, mlp_with_loss, MlpConfig};
pub use transformer::{attention_probe, transformer, TransformerConfig};
pub use vgg::{vgg16, vgg16_scaled};
