//! Lowering pipelined strategies: per-cell SPMD programs plus explicit
//! stage-boundary `SendRecv` transfers.
//!
//! A [`PipelinedProgram`] is the [`Strategy`] analogue of
//! [`LoweredProgram`]: one lowered program per cell (each produced by
//! the existing [`try_lower`] on the cell's microbatch-shaped subgraph
//! and intra-cell plan) plus one [`StageTransfer`] record per
//! cross-stage boundary tensor. The byte identity extends across the
//! stage axis: `total_bytes()` equals
//! [`Strategy::total_cost`] bit for bit, because each cell program
//! already equals its cell plan's Theorem-1 cost and the boundary
//! records carry exactly the strategy's per-microbatch boundary bytes.
//!
//! For [`Strategy::single_stage`] the single cell program *is* the
//! plain `try_lower` output on the original graph — the degenerate path
//! stays bit-identical.

use crate::graph::{Graph, TensorId};
use crate::planner::{PlanError, Strategy};
use crate::sim::SimConfig;

use super::{try_lower, LoweredProgram};

/// One cross-stage boundary transfer: a point-to-point `SendRecv`
/// between the producing and consuming stage groups, repeated once per
/// microbatch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageTransfer {
    /// Tensor id in the original graph.
    pub tensor: TensorId,
    /// Producing stage.
    pub from_stage: usize,
    /// Consuming stage.
    pub to_stage: usize,
    /// Microbatch-shaped bytes per transfer.
    pub bytes: u64,
}

/// A strategy compiled into per-cell SPMD programs plus stage-boundary
/// transfers.
#[derive(Debug, Clone)]
pub struct PipelinedProgram {
    /// Microbatches per step.
    pub microbatches: usize,
    /// One lowered program per cell, in the strategy's execution order.
    pub cells: Vec<LoweredProgram>,
    /// Cross-stage boundary transfers (per microbatch).
    pub transfers: Vec<StageTransfer>,
    /// Tensor labels of the original graph (for dumps and traces).
    pub tensor_names: Vec<String>,
}

impl PipelinedProgram {
    /// Total modeled bytes: per-cell Theorem-1 totals plus boundary
    /// transfers, once per microbatch. Equals
    /// [`Strategy::total_cost`] bit for bit.
    pub fn total_bytes(&self) -> u64 {
        let per_micro: u64 = self.cells.iter().map(LoweredProgram::total_bytes).sum::<u64>()
            + self.transfers.iter().map(|t| t.bytes).sum::<u64>();
        self.microbatches as u64 * per_micro
    }

    /// Boundary bytes shipped across stage groups for the whole step.
    pub fn boundary_bytes(&self) -> u64 {
        self.microbatches as u64 * self.transfers.iter().map(|t| t.bytes).sum::<u64>()
    }

    /// Structural validation of every cell stream (the split-phase
    /// discipline of [`LoweredProgram::validate`]).
    pub fn validate(&self) -> Result<(), PlanError> {
        for cell in &self.cells {
            cell.validate()?;
        }
        Ok(())
    }
}

/// Compile a strategy into per-cell programs plus boundary transfers.
///
/// The single-stage path delegates to [`try_lower`] on the original
/// graph's clone inside the strategy — bytes, instruction streams, and
/// transfer metadata all match the plain `Plan` path exactly.
pub fn try_lower_strategy(
    g: &Graph,
    strategy: &Strategy,
    cfg: &SimConfig,
) -> Result<PipelinedProgram, PlanError> {
    let mut cells = Vec::with_capacity(strategy.cells.len());
    for cell in &strategy.cells {
        cells.push(try_lower(&cell.graph, &cell.plan, cfg)?);
    }
    let transfers = strategy
        .boundaries
        .iter()
        .filter(|b| b.bytes > 0)
        .map(|b| StageTransfer {
            tensor: b.tensor,
            from_stage: strategy.cells[b.from_cell].stage,
            to_stage: strategy.cells[b.to_cell].stage,
            bytes: b.bytes,
        })
        .collect();
    Ok(PipelinedProgram {
        microbatches: strategy.microbatches,
        cells,
        transfers,
        tensor_names: g.tensors.iter().map(|t| t.name.clone()).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::bfs_levels;
    use crate::models::{mlp, MlpConfig};
    use crate::planner::{try_k_cut, Schedule};

    fn small_mlp() -> Graph {
        mlp(&MlpConfig { batch: 16, dims: vec![8, 8, 8], bias: true })
    }

    /// The single-stage program is the plain `try_lower` output.
    #[test]
    fn single_stage_is_bit_identical() {
        let g = small_mlp();
        let cfg = SimConfig::default();
        let plan = try_k_cut(&g, 2).unwrap();
        let want = try_lower(&g, &plan, &cfg).unwrap();
        let s = Strategy::single_stage(&g, plan.clone());
        let pp = try_lower_strategy(&g, &s, &cfg).unwrap();
        assert_eq!(pp.cells.len(), 1);
        assert_eq!(pp.transfers.len(), 0);
        assert_eq!(pp.total_bytes(), want.total_bytes());
        assert_eq!(pp.total_bytes(), plan.total_cost());
        assert_eq!(pp.cells[0].programs.len(), want.programs.len());
        for (a, b) in pp.cells[0].programs.iter().zip(&want.programs) {
            assert_eq!(a.instrs, b.instrs);
        }
    }

    /// The byte identity extends across the stage axis.
    #[test]
    fn pipelined_total_matches_strategy_cost() {
        let g = small_mlp();
        let cut = bfs_levels(&g).levels.len() / 2;
        let s = Strategy::try_build(&g, &[cut], 2, 2, Schedule::GPipe).unwrap();
        let pp = try_lower_strategy(&g, &s, &SimConfig::default()).unwrap();
        assert_eq!(pp.total_bytes(), s.total_cost());
        assert!(pp.boundary_bytes() > 0);
        assert!(pp.validate().is_ok());
        // Every boundary transfer crosses distinct stages.
        assert!(pp.transfers.iter().all(|t| t.from_stage != t.to_stage));
    }
}
