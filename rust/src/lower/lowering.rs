//! Compiling a `(Graph, Plan)` pair into per-device SPMD programs.
//!
//! The lowering walks the plan exactly the way the §4 cost model prices it
//! (and [`crate::sim::try_simulate`] meters it): cut by cut on the
//! `j`-times-halved graph, selecting each operator's Eq. (2) aligned form
//! and decomposing its cost into *conversions*. Each conversion pattern
//! then names its collective:
//!
//! | pattern                          | collective                        |
//! |----------------------------------|-----------------------------------|
//! | `Split -> Rep`                   | `AllGather` (S)                   |
//! | `Split(a) -> Split(b)`           | `AllToAll` (S/2)                  |
//! | `Red -> Split`                   | `ReduceScatter` (S)               |
//! | `Red -> Rep`                     | `ReduceScatter + AllGather` (2S)  |
//! | `Red -> Rep`, unscatterable      | `SendRecv` partial exchange (2S)  |
//! | `Rep -> anything`, identity      | — (local slice / no-op)           |
//!
//! Because the byte count attached to every instruction is exactly the
//! conversion cost the plan was priced with, the lowered program's total
//! bytes equal the plan's Theorem-1 cost **bit for bit** — the same
//! one-theory contract the simulator keeps (asserted across the model zoo
//! in tests and in `benches/engine_micro.rs`).
//!
//! The per-device *realization* of these collectives is §5.2's ghost
//! gather: [`gather_realized_bytes`] reruns a conversion through
//! [`crate::exec::gather_sources`] and must agree with the collective's
//! pair volume for every `Tile -> Tile` pattern (the property test pins
//! this for random graphs and plans).

use crate::exec::{gather_sources, remote_bytes, resident_region, try_build_shard_tasks};
use crate::graph::{Graph, Op, OpId};
use crate::planner::{apply_cut, Plan, PlanError};
use crate::sim::compute::shard_seconds;
use crate::sim::SimConfig;
use crate::tiling::{
    conversion_cost, form_requirements, op_cost_detailed, op_cost_with_form, Form, Produced, Tile,
    TileSeq,
};

use super::ir::{CollectiveKind, DeviceProgram, Instr, LoweredProgram, TransferMeta};

/// One conversion to materialize: the pattern and its priced bytes at the
/// cut's halved granularity.
#[derive(Debug, Clone)]
struct Conversion {
    tensor: usize,
    from: Produced,
    to: Tile,
    bytes: u64,
    /// For `Red -> Rep`: the axis a reduce-scatter may split, if any.
    scatter_axis: Option<usize>,
}

/// The conversions of one op at one cut: inputs first, then the output.
#[derive(Debug, Clone, Default)]
struct OpConversions {
    ins: Vec<Conversion>,
    out: Option<Conversion>,
}

/// Which collective realizes a `Tile -> Tile` conversion; `None` when the
/// conversion is free (replicated source, or identity).
fn collective_for(given: Tile, req: Tile) -> Option<CollectiveKind> {
    match (given, req) {
        (Tile::Rep, _) => None,
        (a, b) if a == b => None,
        (Tile::Split(_), Tile::Rep) => Some(CollectiveKind::AllGather),
        (Tile::Split(_), Tile::Split(_)) => Some(CollectiveKind::AllToAll),
    }
}

/// First dimension along which a tensor of `shape` can be evenly halved —
/// the reduce-scatter axis for `Red -> Rep` conversions. `None` (scalars,
/// all-odd shapes) forces the point-to-point partial exchange.
fn scatter_axis(shape: &[usize]) -> Option<usize> {
    shape.iter().position(|&d| d >= 2 && d % 2 == 0)
}

/// Lower `(g, plan)` into per-device SPMD programs. Panics on plans with
/// no feasible form.
#[deprecated(note = "use `try_lower` and handle the `PlanError`")]
pub fn lower(g: &Graph, plan: &Plan, cfg: &SimConfig) -> LoweredProgram {
    try_lower(g, plan, cfg).expect("lowering failed")
}

/// Lower `(g, plan)` into per-device SPMD programs, with structured
/// errors for plans with no feasible form at some cut.
///
/// # Examples
///
/// ```
/// use soybean::lower::try_lower;
/// use soybean::models::{mlp, MlpConfig};
/// use soybean::planner::try_k_cut;
/// use soybean::sim::SimConfig;
///
/// let g = mlp(&MlpConfig { batch: 64, dims: vec![32, 32], bias: false });
/// let plan = try_k_cut(&g, 2).unwrap();
/// let program = try_lower(&g, &plan, &SimConfig::default()).unwrap();
/// assert_eq!(program.devices, 4);
/// // The one-theory contract: per-instruction bytes sum to Theorem 1.
/// assert_eq!(program.total_bytes(), plan.total_cost());
/// ```
pub fn try_lower(g: &Graph, plan: &Plan, cfg: &SimConfig) -> Result<LoweredProgram, PlanError> {
    try_lower_forced(g, plan, cfg, &|_, _| None)
}

/// [`try_lower`] with per-op forced aligned forms (the classic-DP
/// baseline lowers with [`crate::planner::classic_dp_form`], mirroring
/// [`crate::sim::try_simulate_classic_dp`]).
pub fn try_lower_forced(
    g: &Graph,
    plan: &Plan,
    cfg: &SimConfig,
    forced: &dyn Fn(&Graph, &Op) -> Option<Form>,
) -> Result<LoweredProgram, PlanError> {
    let k = plan.k;
    let devices = 1usize << k;
    let tasks = try_build_shard_tasks(g, plan)?;

    // Pass 1: per (cut, op), select the priced form on the j-halved graph
    // and decompose its Eq. (2) cost into conversions — the same walk
    // `sim::try_simulate` meters, so totals agree bit for bit.
    let mut per_cut: Vec<Vec<OpConversions>> = Vec::with_capacity(k);
    let mut cur = g.clone();
    for j in 0..k {
        let cut_tiles = plan.cut_tiles(j);
        let mut convs = Vec::with_capacity(cur.ops.len());
        for op in &cur.ops {
            let ins: Vec<Tile> = op.inputs.iter().map(|&t| cut_tiles[t]).collect();
            let out = cut_tiles[op.outputs[0]];
            let form = match forced(&cur, op) {
                Some(f) if op_cost_with_form(&cur, op, &ins, out, f).is_some() => f,
                _ => {
                    op_cost_detailed(&cur, op, &ins, out)
                        .ok_or_else(|| PlanError::NoFeasibleForm { op: op.name.clone(), cut: j })?
                        .form
                }
            };
            let (reqs, prod) = form_requirements(&cur, op, form);
            let mut oc = OpConversions::default();
            for ((&t, &req), &given) in op.inputs.iter().zip(&reqs).zip(&ins) {
                let bytes = conversion_cost(cur.tensors[t].bytes(), Produced::Tile(given), req);
                if bytes > 0 {
                    oc.ins.push(Conversion {
                        tensor: t,
                        from: Produced::Tile(given),
                        to: req,
                        bytes,
                        scatter_axis: None,
                    });
                }
            }
            let tz = op.outputs[0];
            let out_bytes = conversion_cost(cur.tensors[tz].bytes(), prod, out);
            if out_bytes > 0 {
                oc.out = Some(Conversion {
                    tensor: tz,
                    from: prod,
                    to: out,
                    bytes: out_bytes,
                    scatter_axis: scatter_axis(&cur.tensors[tz].shape),
                });
            }
            convs.push(oc);
        }
        per_cut.push(convs);
        cur = apply_cut(&cur, &cut_tiles);
    }

    // Pass 2: emit the aligned per-device streams in topological op order.
    let mut lw = Emitter {
        k,
        devices,
        programs: (0..devices).map(|d| DeviceProgram { device: d, instrs: Vec::new() }).collect(),
        transfers: Vec::new(),
        op: 0,
    };
    // Output conversions whose Wait is deferred to the first consumer (or
    // program end) so they overlap with independent compute.
    let mut pending: Vec<Vec<usize>> = vec![Vec::new(); g.tensors.len()];
    for op in &g.ops {
        lw.op = op.id;
        // The input gathers read tensors in plan tiling, which exists only
        // once the producers' output conversions have landed.
        for &t in &op.inputs {
            for gid in pending[t].drain(..) {
                lw.wait(gid);
            }
        }
        let mut own = Vec::new();
        for j in 0..k {
            for c in &per_cut[j][op.id].ins {
                let kind = match (c.from, c.to) {
                    (Produced::Tile(a), b) => collective_for(a, b),
                    _ => unreachable!("input conversions never leave Red"),
                };
                if let Some(kind) = kind {
                    own.push(lw.start(kind, j, c.tensor, c.from, c.to, c.bytes));
                }
            }
        }
        for gid in own {
            lw.wait(gid);
        }
        let seconds = shard_seconds(g, op, &tasks[op.id], cfg.peak_flops, &cfg.eff);
        for prog in &mut lw.programs {
            prog.instrs.push(Instr::Compute { op: op.id, seconds });
        }
        for j in 0..k {
            let Some(c) = &per_cut[j][op.id].out else { continue };
            match (c.from, c.to) {
                (Produced::Tile(a), b) => {
                    if let Some(kind) = collective_for(a, b) {
                        let gid = lw.start(kind, j, c.tensor, c.from, c.to, c.bytes);
                        pending[c.tensor].push(gid);
                    }
                }
                (Produced::Red, to @ Tile::Split(_)) => {
                    let gid =
                        lw.start(CollectiveKind::ReduceScatter, j, c.tensor, c.from, to, c.bytes);
                    pending[c.tensor].push(gid);
                }
                (Produced::Red, Tile::Rep) => match c.scatter_axis {
                    // The classic allreduce decomposition: scatter the
                    // partial sums (S), then gather the reduced halves (S).
                    Some(axis) => {
                        let half = c.bytes / 2;
                        let rs = lw.start(
                            CollectiveKind::ReduceScatter,
                            j,
                            c.tensor,
                            Produced::Red,
                            Tile::Split(axis),
                            half,
                        );
                        lw.wait(rs);
                        let ag = lw.start(
                            CollectiveKind::AllGather,
                            j,
                            c.tensor,
                            Produced::Tile(Tile::Split(axis)),
                            Tile::Rep,
                            c.bytes - half,
                        );
                        pending[c.tensor].push(ag);
                    }
                    // Unscatterable (the scalar loss): both sides exchange
                    // full partials point to point and add locally.
                    None => {
                        let gid = lw.start(
                            CollectiveKind::SendRecv,
                            j,
                            c.tensor,
                            Produced::Red,
                            Tile::Rep,
                            c.bytes,
                        );
                        pending[c.tensor].push(gid);
                    }
                },
            }
        }
    }
    // Conversions nothing consumed (terminal outputs, e.g. updated
    // weights) still gate step completion.
    for t in 0..g.tensors.len() {
        for gid in pending[t].drain(..) {
            lw.wait(gid);
        }
    }

    Ok(LoweredProgram {
        k,
        devices,
        programs: lw.programs,
        transfers: lw.transfers,
        op_names: g.ops.iter().map(|o| o.name.clone()).collect(),
        tensor_names: g.tensors.iter().map(|t| t.name.clone()).collect(),
    })
}

/// Instruction-emission state shared across the second pass.
struct Emitter {
    k: usize,
    devices: usize,
    programs: Vec<DeviceProgram>,
    transfers: Vec<TransferMeta>,
    /// The op whose conversions are being emitted (recorded on each
    /// collective's `TransferMeta`).
    op: OpId,
}

impl Emitter {
    /// Start a collective on every device; each device's share of the pair
    /// volume is `pair_bytes / n` with the remainder spread over the
    /// lowest in-pair ranks, so shares always sum back exactly.
    fn start(
        &mut self,
        kind: CollectiveKind,
        cut: usize,
        tensor: usize,
        from: Produced,
        to: Tile,
        pair_bytes: u64,
    ) -> usize {
        let gid = self.transfers.len();
        let op = self.op;
        self.transfers.push(TransferMeta { gid, kind, tensor, op, cut, from, to, pair_bytes });
        let n = (self.devices >> cut) as u64; // devices per group pair
        let mirror = 1usize << (self.k - 1 - cut);
        for d in 0..self.devices {
            let rank = (d as u64) & (n - 1);
            let bytes = pair_bytes / n + u64::from(rank < pair_bytes % n);
            let instr = match kind {
                CollectiveKind::AllGather => Instr::AllGather { gid, bytes },
                CollectiveKind::ReduceScatter => Instr::ReduceScatter { gid, bytes },
                CollectiveKind::AllToAll => Instr::AllToAll { gid, bytes },
                CollectiveKind::SendRecv => Instr::SendRecv { gid, peer: d ^ mirror, bytes },
            };
            self.programs[d].instrs.push(instr);
        }
        gid
    }

    fn wait(&mut self, gid: usize) {
        for prog in &mut self.programs {
            prog.instrs.push(Instr::Wait { gid });
        }
    }
}

/// §5.2 realization check: total remote bytes when every device gathers
/// its `target`-layout region of a tensor resident as `resident`, through
/// [`gather_sources`]. For single-cut `Tile -> Tile` conversions this must
/// equal the conversion-table volume the lowering attaches to the
/// collective (pinned by the random-plan property test).
pub fn gather_realized_bytes(
    shape: &[usize],
    dtype_bytes: u64,
    resident: &TileSeq,
    target: &TileSeq,
    devices: usize,
) -> u64 {
    (0..devices)
        .map(|d| {
            let want = resident_region(shape, target, d);
            let pieces = gather_sources(shape, resident, devices, d, &want);
            remote_bytes(&pieces, d, dtype_bytes)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder, TensorKind};
    use crate::models::{cnn5, mlp, transformer, MlpConfig, TransformerConfig};
    use crate::planner::{classic_dp_form, eval_plan, Planner, PlanFamily};
    use crate::sim::{try_simulate, try_simulate_classic_dp};
    use crate::tiling::candidate_tiles;
    use crate::util::rng::Rng;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn serial_plan_lowers_to_pure_compute() {
        let g = mlp(&MlpConfig::fig8(64, 32));
        let plan = Planner::try_plan(&g, 0, PlanFamily::Soybean).unwrap();
        let p = try_lower(&g, &plan, &cfg()).unwrap();
        assert_eq!(p.devices, 1);
        assert_eq!(p.total_bytes(), 0);
        assert!(p.transfers.is_empty());
        assert_eq!(p.programs[0].instrs.len(), g.ops.len());
        assert!(p.programs[0].instrs.iter().all(|i| matches!(i, Instr::Compute { .. })));
    }

    #[test]
    fn lowered_bytes_equal_plan_cost_and_sim_meter() {
        // The one-theory contract, three ways: lowered per-instruction
        // bytes == simulator-metered bytes == Theorem-1 plan cost, per
        // tier, across the zoo and every strategy.
        // PlanFamily sweeps stick to combinations the §5 schedule builder is
        // proven to realize (all strategies on MLP/CNN; the transformer's
        // model-parallel baseline is not a materialization target).
        let workloads: Vec<(&str, crate::graph::Graph, Vec<PlanFamily>)> = vec![
            ("mlp", mlp(&MlpConfig::fig8(64, 64)), PlanFamily::all().to_vec()),
            ("cnn", cnn5(64, 24, 4, 64, 10), PlanFamily::all().to_vec()),
            (
                "transformer",
                transformer(&TransformerConfig::tiny()),
                vec![PlanFamily::Soybean, PlanFamily::DataParallel],
            ),
        ];
        for (name, g, strategies) in &workloads {
            for &strat in strategies {
                for k in 1..=2 {
                    let plan = Planner::try_plan(g, k, strat).unwrap();
                    let (p, r) = if strat == PlanFamily::DataParallel {
                        (
                            try_lower_forced(g, &plan, &cfg(), &classic_dp_form).unwrap(),
                            try_simulate_classic_dp(g, &plan, &cfg()).unwrap(),
                        )
                    } else {
                        (try_lower(g, &plan, &cfg()).unwrap(), try_simulate(g, &plan, &cfg()).unwrap())
                    };
                    let label = format!("{name}/{}/k{k}", strat.name());
                    assert_eq!(p.total_bytes(), plan.total_cost(), "{label}: bytes != plan");
                    assert_eq!(p.tier_bytes(), r.tier_bytes, "{label}: tier bytes != sim");
                    // Shares per collective sum back to the pair volume.
                    for m in &p.transfers {
                        let total: u64 = p
                            .programs
                            .iter()
                            .flat_map(|prog| &prog.instrs)
                            .filter(|i| i.started_gid() == Some(m.gid))
                            .map(|i| i.bytes())
                            .sum();
                        assert_eq!(total, m.pair_bytes << m.cut, "{label}: g{} shares", m.gid);
                    }
                }
            }
        }
    }

    #[test]
    fn dp_gradient_aggregation_lowers_to_reduce_scatter_all_gather() {
        // Stock data parallelism's allreduce decomposes into the classic
        // reduce-scatter + all-gather pair on every weight gradient.
        let g = mlp(&MlpConfig { batch: 64, dims: vec![32, 32], bias: false });
        let plan = Planner::try_plan(&g, 1, PlanFamily::DataParallel).unwrap();
        let p = try_lower_forced(&g, &plan, &cfg(), &classic_dp_form).unwrap();
        let grad_ids: Vec<usize> = g
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::WeightGrad && t.rank() == 2)
            .map(|t| t.id)
            .collect();
        assert!(!grad_ids.is_empty());
        for t in grad_ids {
            let kinds: Vec<CollectiveKind> =
                p.transfers.iter().filter(|m| m.tensor == t).map(|m| m.kind).collect();
            assert_eq!(
                kinds,
                vec![CollectiveKind::ReduceScatter, CollectiveKind::AllGather],
                "tensor {} ({})",
                t,
                p.tensor_names[t]
            );
            // Together they move the 2S allreduce volume.
            let bytes: u64 =
                p.transfers.iter().filter(|m| m.tensor == t).map(|m| m.pair_bytes).sum();
            assert_eq!(bytes, 2 * g.tensors[t].bytes());
        }
    }

    #[test]
    fn scalar_loss_allreduce_falls_back_to_send_recv() {
        // The loss scalar cannot be scattered; its partial-sum exchange
        // lowers to the point-to-point SendRecv path at full 2S volume.
        let g = mlp(&MlpConfig { batch: 64, dims: vec![32, 16], bias: false });
        let loss = g.tensors.iter().find(|t| t.rank() == 0).expect("scalar loss");
        let plan = Planner::try_plan(&g, 1, PlanFamily::DataParallel).unwrap();
        let p = try_lower_forced(&g, &plan, &cfg(), &classic_dp_form).unwrap();
        let m = p
            .transfers
            .iter()
            .find(|m| m.tensor == loss.id)
            .expect("loss reduction lowered");
        assert_eq!(m.kind, CollectiveKind::SendRecv);
        assert_eq!(m.pair_bytes, 2 * loss.bytes());
        // The SendRecv peers mirror across the cut.
        for prog in &p.programs {
            for i in &prog.instrs {
                if let Instr::SendRecv { gid, peer, .. } = i {
                    if *gid == m.gid {
                        assert_eq!(*peer, prog.device ^ 1);
                    }
                }
            }
        }
    }

    #[test]
    fn every_wait_follows_its_start() {
        let g = transformer(&TransformerConfig::tiny());
        let plan = Planner::try_plan(&g, 2, PlanFamily::Soybean).unwrap();
        let p = try_lower(&g, &plan, &cfg()).unwrap();
        for prog in &p.programs {
            let mut started = vec![false; p.transfers.len()];
            let mut starts = 0usize;
            for i in &prog.instrs {
                if let Some(gid) = i.started_gid() {
                    assert!(!started[gid], "g{gid} started twice on device {}", prog.device);
                    started[gid] = true;
                    starts += 1;
                }
                if let Instr::Wait { gid } = i {
                    assert!(started[*gid], "wait before start of g{gid}");
                }
            }
            // Every collective appears on every device (aligned streams).
            assert_eq!(starts, p.transfers.len(), "device {}", prog.device);
        }
    }

    /// Satellite property test: for random small graphs and random
    /// single-cut plans, three accountings of communication agree —
    /// (1) the lowered collectives' bytes, (2) the plan's Theorem-1 total,
    /// and (3) for every `Tile -> Tile` conversion, the §5.2 ghost-gather
    /// realization through `exec::gather_sources`.
    #[test]
    fn random_plans_lowered_bytes_match_gather_sources_and_theorem1() {
        let mut rng = Rng::new(0x50_4c_41_4e);
        let mut checked_transfers = 0usize;
        for trial in 0..40 {
            // Random training MLP: 1-3 layers, even dims in [4, 32].
            let even = |rng: &mut Rng| 2 * (rng.below(15) + 2);
            let batch = even(&mut rng);
            let layers = 1 + rng.below(3);
            let dims: Vec<usize> = (0..=layers).map(|_| even(&mut rng)).collect();
            let mut b = GraphBuilder::new();
            let mut h = b.input("x", &[batch, dims[0]]);
            let y = b.label("y", &[batch, dims[layers]]);
            for l in 0..layers {
                let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
                h = b.matmul(&format!("fc{l}"), h, w, false, false);
                if l + 1 < layers {
                    h = b.relu(&format!("relu{l}"), h);
                }
            }
            let loss = b.softmax_xent("loss", h, y);
            append_backward(&mut b, loss);
            let g = b.finish();

            // Random single-cut tiling from each tensor's candidate set.
            let tiles: Vec<TileSeq> =
                g.tensors.iter().map(|t| vec![*rng.choose(&candidate_tiles(t))]).collect();
            let plan = eval_plan(&g, &tiles);
            let p = try_lower(&g, &plan, &cfg()).unwrap_or_else(|e| {
                panic!("trial {trial}: lowering rejected a priceable plan: {e}")
            });

            // (1) == (2): every instruction byte, summed, is the plan cost.
            assert_eq!(p.total_bytes(), plan.total_cost(), "trial {trial}");
            // And the independent simulator meter agrees.
            let r = try_simulate(&g, &plan, &cfg()).unwrap();
            assert_eq!(p.total_bytes(), r.total_bytes, "trial {trial}: sim meter");

            // (3): each Tile->Tile collective's pair volume equals its
            // ghost-gather realization.
            for m in &p.transfers {
                if let Produced::Tile(from) = m.from {
                    let t = &g.tensors[m.tensor];
                    let realized = gather_realized_bytes(
                        &t.shape,
                        t.dtype_bytes as u64,
                        &vec![from],
                        &vec![m.to],
                        2,
                    );
                    assert_eq!(
                        m.pair_bytes, realized,
                        "trial {trial}: {} {} -> {:?} ({:?})",
                        p.tensor_names[m.tensor],
                        m.kind.name(),
                        m.to,
                        m.from
                    );
                    checked_transfers += 1;
                }
            }
        }
        assert!(checked_transfers > 50, "property test exercised only {checked_transfers} transfers");
    }

    #[test]
    fn gather_realized_bytes_matches_conversion_table() {
        // Direct spot checks of the §4.2.1 table through the §5.2 path.
        let shape = [8, 8];
        let s: u64 = 8 * 8 * 4;
        let r = vec![Tile::Split(0)];
        let c = vec![Tile::Split(1)];
        let rep = vec![Tile::Rep];
        assert_eq!(gather_realized_bytes(&shape, 4, &r, &rep, 2), s);
        assert_eq!(gather_realized_bytes(&shape, 4, &r, &c, 2), s / 2);
        assert_eq!(gather_realized_bytes(&shape, 4, &rep, &r, 2), 0);
        assert_eq!(gather_realized_bytes(&shape, 4, &r, &r, 2), 0);
    }

    #[test]
    fn infeasible_plan_reports_structured_error() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 5]);
        let w = b.weight("w", &[5, 7]);
        b.matmul("odd", x, w, false, false);
        let g = b.finish();
        let plan = Plan { k: 1, tiles: vec![vec![Tile::Rep]; g.tensors.len()], cut_costs: vec![0] };
        match try_lower(&g, &plan, &cfg()) {
            Err(PlanError::NoFeasibleForm { op, cut }) => {
                assert_eq!(op, "odd");
                assert_eq!(cut, 0);
            }
            other => panic!("expected NoFeasibleForm, got {other:?}"),
        }
    }
}
