//! The SPMD instruction set and lowered-program containers.
//!
//! A lowered program is one instruction stream *per device*. The streams
//! are aligned: every device executes the same sequence of instruction
//! kinds (SPMD), differing only in the byte share each device contributes
//! to a collective. Transfers are *split-phase*: a collective instruction
//! starts the transfer asynchronously and [`Instr::Wait`] joins it, which
//! is what lets the event engine overlap communication with the compute of
//! independent operators instead of applying a scalar overlap factor.
//!
//! Every transfer instruction references a [`TransferMeta`] by `gid`
//! (global transfer id, shared by all participating devices), which records
//! the tiling-conversion pattern the collective realizes and the bytes
//! moved within each group pair — the unit the §4 cost model prices.

use crate::graph::{OpId, TensorId};
use crate::tiling::{Produced, Tile};

/// Which collective realizes a tiling conversion (see
/// [`super::lowering`] for the inference rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// `Split -> Rep`: every group fetches the half it is missing.
    AllGather,
    /// `Red -> Split`: partial sums cross the wire once, landing scattered.
    ReduceScatter,
    /// `Split(a) -> Split(b)`: each group swaps the off-diagonal quarter.
    AllToAll,
    /// Point-to-point ghost fetch between paired devices — the §5.2
    /// realization for conversions with no symmetric collective shape
    /// (e.g. the scalar loss allreduce, which cannot be scattered).
    SendRecv,
}

impl CollectiveKind {
    /// Lowercase wire name (trace labels, instruction dumps).
    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::AllGather => "all_gather",
            CollectiveKind::ReduceScatter => "reduce_scatter",
            CollectiveKind::AllToAll => "all_to_all",
            CollectiveKind::SendRecv => "send_recv",
        }
    }
}

/// One logical collective: the conversion it realizes and its group-pair
/// byte volume. Shared by the instructions of every participating device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransferMeta {
    /// Dense collective id; instructions reference it.
    pub gid: usize,
    /// Which collective realizes the conversion.
    pub kind: CollectiveKind,
    /// The tensor being converted (id in the original, un-halved graph).
    pub tensor: TensorId,
    /// The op whose Eq. (2) form priced this conversion: the consumer for
    /// input gathers, the producer for output conversions. Lets traces and
    /// the executor's per-op payload meter tie collectives back to
    /// operators without re-deriving the form selection.
    pub op: OpId,
    /// The cut (= interconnect tier, outermost first) this transfer
    /// crosses. `2^cut` group pairs run the collective simultaneously.
    pub cut: usize,
    /// The layout the data leaves (producer side of the conversion).
    pub from: Produced,
    /// The layout the data arrives in.
    pub to: Tile,
    /// Bytes moved within *each* group pair — the §4.2.1 conversion cost of
    /// this pattern at this cut's halved granularity. Tier traffic is
    /// `pair_bytes << cut`; Theorem 1's weights fall out of that product.
    pub pair_bytes: u64,
}

/// One SPMD instruction on one device.
#[derive(Debug, Clone, PartialEq)]
pub enum Instr {
    /// Execute this device's shard of `op` locally (all `k` cuts applied).
    Compute { op: OpId, seconds: f64 },
    /// Start an all-gather; `bytes` is this device's share of the pair
    /// volume (shares over a pair sum to `TransferMeta::pair_bytes`).
    AllGather { gid: usize, bytes: u64 },
    /// Start a reduce-scatter of partial sums.
    ReduceScatter { gid: usize, bytes: u64 },
    /// Start an all-to-all re-tiling exchange.
    AllToAll { gid: usize, bytes: u64 },
    /// Start a point-to-point exchange with `peer` (the device mirrored
    /// across the transfer's cut).
    SendRecv { gid: usize, peer: usize, bytes: u64 },
    /// Block until the transfer `gid` (started earlier on this device)
    /// completes for this device's group pair.
    Wait { gid: usize },
}

impl Instr {
    /// Bytes this device moves for this instruction (0 for compute/wait).
    pub fn bytes(&self) -> u64 {
        match self {
            Instr::AllGather { bytes, .. }
            | Instr::ReduceScatter { bytes, .. }
            | Instr::AllToAll { bytes, .. }
            | Instr::SendRecv { bytes, .. } => *bytes,
            Instr::Compute { .. } | Instr::Wait { .. } => 0,
        }
    }

    /// The transfer this instruction starts, if it is a transfer start.
    pub fn started_gid(&self) -> Option<usize> {
        match self {
            Instr::AllGather { gid, .. }
            | Instr::ReduceScatter { gid, .. }
            | Instr::AllToAll { gid, .. }
            | Instr::SendRecv { gid, .. } => Some(*gid),
            Instr::Compute { .. } | Instr::Wait { .. } => None,
        }
    }

    /// Lowercase mnemonic for dumps and histograms.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Instr::Compute { .. } => "compute",
            Instr::AllGather { .. } => "all_gather",
            Instr::ReduceScatter { .. } => "reduce_scatter",
            Instr::AllToAll { .. } => "all_to_all",
            Instr::SendRecv { .. } => "send_recv",
            Instr::Wait { .. } => "wait",
        }
    }
}

/// The instruction stream of one device.
#[derive(Debug, Clone, Default)]
pub struct DeviceProgram {
    /// The device this stream runs on.
    pub device: usize,
    /// The instruction stream, in issue order.
    pub instrs: Vec<Instr>,
}

impl DeviceProgram {
    /// Total bytes this device contributes across all collectives.
    pub fn bytes(&self) -> u64 {
        self.instrs.iter().map(Instr::bytes).sum()
    }

    /// Number of transfer-start instructions.
    pub fn transfer_count(&self) -> usize {
        self.instrs.iter().filter(|i| i.started_gid().is_some()).count()
    }

    /// Seconds of local compute along this device's stream.
    pub fn compute_seconds(&self) -> f64 {
        self.instrs
            .iter()
            .map(|i| match i {
                Instr::Compute { seconds, .. } => *seconds,
                _ => 0.0,
            })
            .sum()
    }
}

/// A `(Graph, Plan)` pair compiled into explicit per-device SPMD programs.
#[derive(Debug, Clone)]
pub struct LoweredProgram {
    /// Number of cuts (`devices == 2^k`).
    pub k: usize,
    /// Total device count (`2^k`).
    pub devices: usize,
    /// One aligned instruction stream per device.
    pub programs: Vec<DeviceProgram>,
    /// Per-`gid` collective metadata.
    pub transfers: Vec<TransferMeta>,
    /// Debug labels carried over from the graph (indexed by `OpId` /
    /// `TensorId`) so dumps and traces stay readable without the graph.
    pub op_names: Vec<String>,
    /// Tensor labels, same purpose as `op_names`.
    pub tensor_names: Vec<String>,
}

impl LoweredProgram {
    /// Total bytes across every device's instructions. Equals the plan's
    /// Theorem-1 cost bit for bit (asserted in tests: the lowering derives
    /// both from the same Eq. (2) form selection).
    pub fn total_bytes(&self) -> u64 {
        self.programs.iter().map(DeviceProgram::bytes).sum()
    }

    /// Bytes crossing each interconnect tier (index = cut, outermost
    /// first), from the per-collective metadata.
    pub fn tier_bytes(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.k];
        for m in &self.transfers {
            out[m.cut] += m.pair_bytes << m.cut;
        }
        out
    }

    /// Instruction-kind histogram over one device (streams are aligned, so
    /// every device reports the same counts).
    pub fn histogram(&self) -> Vec<(&'static str, usize)> {
        let mut counts: Vec<(&'static str, usize)> = Vec::new();
        for i in &self.programs[0].instrs {
            let name = i.kind_name();
            match counts.iter_mut().find(|(n, _)| *n == name) {
                Some((_, c)) => *c += 1,
                None => counts.push((name, 1)),
            }
        }
        counts
    }

    /// Structural validation of the SPMD stream discipline, for programs
    /// that did not come out of [`crate::lower::lower`]: every device
    /// stream must start each collective exactly once, `Wait` only after
    /// its start, and leave no transfer unwaited (the split-phase contract
    /// both [`crate::sim::run_program`] and the [`crate::spmd`] executor
    /// schedule by). Returns the first violation as
    /// [`PlanError::MalformedProgram`].
    ///
    /// [`PlanError::MalformedProgram`]: crate::planner::PlanError::MalformedProgram
    pub fn validate(&self) -> Result<(), crate::planner::PlanError> {
        use crate::planner::PlanError;
        let bad = |device: usize, pc: usize, reason: String| {
            Err(PlanError::MalformedProgram { device, pc, reason })
        };
        if self.k >= usize::BITS as usize || self.devices != 1usize << self.k {
            return bad(0, 0, format!("{} devices for k={}", self.devices, self.k));
        }
        if self.programs.len() != self.devices {
            return bad(0, 0, format!("{} streams for {} devices", self.programs.len(), self.devices));
        }
        for (d, prog) in self.programs.iter().enumerate() {
            let mut started = vec![false; self.transfers.len()];
            let mut waited = vec![false; self.transfers.len()];
            for (pc, instr) in prog.instrs.iter().enumerate() {
                if let Some(gid) = instr.started_gid() {
                    if gid >= self.transfers.len() {
                        return bad(d, pc, format!("start of unknown transfer g{gid}"));
                    }
                    if started[gid] {
                        return bad(d, pc, format!("transfer g{gid} started twice"));
                    }
                    started[gid] = true;
                }
                if let Instr::Wait { gid } = instr {
                    if *gid >= self.transfers.len() {
                        return bad(d, pc, format!("wait on unknown transfer g{gid}"));
                    }
                    if !started[*gid] {
                        return bad(d, pc, format!("wait before start of g{gid}"));
                    }
                    if waited[*gid] {
                        return bad(d, pc, format!("transfer g{gid} waited twice"));
                    }
                    waited[*gid] = true;
                }
            }
            for gid in 0..self.transfers.len() {
                if !started[gid] {
                    return bad(d, prog.instrs.len(), format!("transfer g{gid} never started"));
                }
                if !waited[gid] {
                    return bad(d, prog.instrs.len(), format!("transfer g{gid} never waited"));
                }
            }
        }
        Ok(())
    }

    /// [`Self::validate`] plus the binding to a concrete [`Plan`]: the
    /// program must span exactly the plan's `2^k` devices at the plan's
    /// cut depth. The SPMD executor runs this at entry, and the elastic
    /// re-planning path ([`crate::spmd::execute_with_recovery`]) re-runs
    /// it after shrinking to the surviving device set, so a stale program
    /// can never execute against a re-planned world.
    pub fn validate_for(&self, plan: &crate::planner::Plan) -> Result<(), crate::planner::PlanError> {
        self.validate()?;
        if self.devices != plan.devices() || self.k != plan.k {
            return Err(crate::planner::PlanError::MalformedProgram {
                device: 0,
                pc: 0,
                reason: format!(
                    "program spans {} devices (k={}), plan {} (k={})",
                    self.devices,
                    self.k,
                    plan.devices(),
                    plan.k
                ),
            });
        }
        Ok(())
    }

    /// Human-readable dump of one device's stream (first `limit`
    /// instructions; `usize::MAX` for all).
    pub fn describe_device(&self, device: usize, limit: usize) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let prog = &self.programs[device];
        for (i, instr) in prog.instrs.iter().take(limit).enumerate() {
            let line = match instr {
                Instr::Compute { op, seconds } => {
                    format!("compute        {:<24} {:.1} us", self.op_names[*op], seconds * 1e6)
                }
                Instr::Wait { gid } => {
                    let m = &self.transfers[*gid];
                    format!("wait           g{gid} ({} {})", m.kind.name(), self.tensor_names[m.tensor])
                }
                Instr::SendRecv { gid, peer, bytes } => {
                    let m = &self.transfers[*gid];
                    format!(
                        "send_recv      {:<24} g{gid} cut{} peer{} {} B",
                        self.tensor_names[m.tensor], m.cut, peer, bytes
                    )
                }
                other => {
                    let gid = other.started_gid().unwrap();
                    let m = &self.transfers[gid];
                    format!(
                        "{:<14} {:<24} g{gid} cut{} {} B",
                        other.kind_name(),
                        self.tensor_names[m.tensor],
                        m.cut,
                        other.bytes()
                    )
                }
            };
            let _ = writeln!(s, "  [{i:>4}] {line}");
        }
        if prog.instrs.len() > limit {
            let _ = writeln!(s, "  ... {} more", prog.instrs.len() - limit);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instr_accessors() {
        let c = Instr::Compute { op: 0, seconds: 1.0 };
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.started_gid(), None);
        let ag = Instr::AllGather { gid: 3, bytes: 128 };
        assert_eq!(ag.bytes(), 128);
        assert_eq!(ag.started_gid(), Some(3));
        assert_eq!(Instr::Wait { gid: 3 }.started_gid(), None);
        assert_eq!(Instr::SendRecv { gid: 1, peer: 2, bytes: 8 }.bytes(), 8);
    }

    #[test]
    fn validate_enforces_stream_discipline() {
        use crate::planner::PlanError;
        let meta = TransferMeta {
            gid: 0,
            kind: CollectiveKind::AllGather,
            tensor: 0,
            op: 0,
            cut: 0,
            from: Produced::Tile(Tile::Split(0)),
            to: Tile::Rep,
            pair_bytes: 8,
        };
        let mk = |instrs: Vec<Vec<Instr>>| LoweredProgram {
            k: 1,
            devices: 2,
            programs: instrs
                .into_iter()
                .enumerate()
                .map(|(device, i)| DeviceProgram { device, instrs: i })
                .collect(),
            transfers: vec![meta.clone()],
            op_names: vec!["op".into()],
            tensor_names: vec!["t".into()],
        };
        let start = Instr::AllGather { gid: 0, bytes: 4 };
        let wait = Instr::Wait { gid: 0 };
        // Well-formed: start then wait on both devices.
        let good = mk(vec![vec![start.clone(), wait.clone()]; 2]);
        assert!(good.validate().is_ok());
        // Wait before start.
        let bad = mk(vec![vec![wait.clone(), start.clone()]; 2]);
        assert!(matches!(
            bad.validate().unwrap_err(),
            PlanError::MalformedProgram { pc: 0, .. }
        ));
        // Started twice.
        let bad = mk(vec![vec![start.clone(), start.clone(), wait.clone()]; 2]);
        assert!(bad.validate().is_err());
        // Never waited.
        let bad = mk(vec![vec![start.clone()]; 2]);
        assert!(bad.validate().is_err());
        // Unknown gid.
        let bad = mk(vec![vec![Instr::Wait { gid: 9 }]; 2]);
        assert!(bad.validate().is_err());
        // An absurd k must fail structurally, not overflow the shift.
        let mut bad = mk(vec![vec![start, wait]; 2]);
        bad.k = 64;
        bad.devices = 1;
        assert!(matches!(
            bad.validate().unwrap_err(),
            PlanError::MalformedProgram { .. }
        ));
    }

    #[test]
    fn tier_bytes_apply_theorem1_weights() {
        let p = LoweredProgram {
            k: 2,
            devices: 4,
            programs: vec![DeviceProgram::default(); 4],
            transfers: vec![
                TransferMeta {
                    gid: 0,
                    kind: CollectiveKind::AllGather,
                    tensor: 0,
                    op: 0,
                    cut: 0,
                    from: Produced::Tile(Tile::Split(0)),
                    to: Tile::Rep,
                    pair_bytes: 40,
                },
                TransferMeta {
                    gid: 1,
                    kind: CollectiveKind::ReduceScatter,
                    tensor: 0,
                    op: 0,
                    cut: 1,
                    from: Produced::Red,
                    to: Tile::Split(0),
                    pair_bytes: 10,
                },
            ],
            op_names: vec![],
            tensor_names: vec!["t".into()],
        };
        // Cut 0 runs in one pair, cut 1 in two: 40 and 2*10.
        assert_eq!(p.tier_bytes(), vec![40, 20]);
    }
}
