//! SPMD lowering: compiling a `(Graph, Plan)` pair into explicit
//! per-device collective programs (the paper's §5 "parallel dataflow
//! graph", made concrete).
//!
//! The planner stops at a tiling assignment plus analytic cost totals;
//! this module builds the missing back half of the system: a small
//! instruction set ([`Instr`]) of local computes and collectives
//! (`AllGather` / `ReduceScatter` / `AllToAll` / `SendRecv` / `Wait`),
//! one aligned stream per device, where every collective is *inferred*
//! from the tiling-conversion pattern between the form a producer emits
//! and the form a consumer requires (the conversion table in the
//! lowering pass). Per-
//! instruction byte counts are exactly the §4.2.1 conversion costs, so a
//! lowered program's total traffic equals the plan's Theorem-1 cost bit
//! for bit — the optimizer, the analytic simulator
//! ([`crate::sim::try_simulate`]) and the discrete-event engine
//! ([`crate::sim::engine`]) all stay on one theory.
//!
//! Consumers:
//! - [`crate::sim::engine`] schedules lowered programs over a
//!   hierarchical [`crate::sim::engine::Topology`] and emits
//!   Chrome-trace timelines;
//! - `plan_inspector --lower [--trace]` dumps programs and timelines for
//!   the paper workloads;
//! - `benches/engine_micro.rs` gates lowering + simulation wall-clock and
//!   records the perf trajectory (`BENCH_engine.json`).

mod ir;
mod lowering;
mod pipeline;

pub use ir::{CollectiveKind, DeviceProgram, Instr, LoweredProgram, TransferMeta};
pub use lowering::{gather_realized_bytes, try_lower, try_lower_forced};
pub use pipeline::{try_lower_strategy, PipelinedProgram, StageTransfer};
// The panicking variant stays re-exported (deprecated) for one release.
#[allow(deprecated)]
pub use lowering::lower;
