//! Threaded SPMD execution of lowered programs on real tensors — the
//! correctness half of the one-theory contract.
//!
//! Everything upstream of this module reasons about the parallel plan in
//! *bytes*: Eq. (2) prices it, the lowering compiles it, the simulators
//! schedule it, and all three agree bit for bit. What none of them proved
//! is the paper's actual claim — that the rewritten parallel dataflow
//! graph **computes the same function** as the serial one. This module
//! closes that loop:
//!
//! - [`execute`] interprets a [`crate::lower::LoweredProgram`] on one
//!   worker thread per device, with real `f32` shard buffers, numeric
//!   kernels for the full op vocabulary ([`crate::graph::apply_op`]), and
//!   the collective exchanges realized over [`std::sync::mpsc`] channels
//!   (the exchange design is documented on [`execute`]'s module);
//! - the serial reference lives in [`crate::graph::eval_serial`]; the
//!   differential harness (`rust/tests/differential.rs`,
//!   `plan_inspector --execute`) runs both and compares every tensor
//!   elementwise via [`worst_divergence`].
//!
//! The narrative chapter is [`crate::book::execution`]
//! (docs/execution.md), including the tolerance model and the two byte
//! meters.
//!
//! The fault-tolerance layer sits on top ([`fault`],
//! [`execute_with`], [`execute_with_recovery`]): deterministic fault
//! injection, watchdog deadlines on every wait site, checksummed
//! checkpoints, and elastic re-planning on permanent device loss — the
//! narrative is docs/execution.md §Fault tolerance.
//!
//! The observability layer ([`crate::obs`]) threads through the same
//! hooks: [`ExecOptions::trace`] records per-instruction wall-clock
//! spans into `ExecReport::trace`, and [`ExecOptions::metrics`] counts
//! steps, failures, retries, and re-plans through a shared
//! [`crate::obs::Metrics`] registry — both `Option`-gated so the
//! default path pays one branch per site
//! ([`crate::book::observability`]).
//!
//! The pipeline axis rides on top of all of it:
//! [`try_execute_strategy`] runs a [`crate::planner::Strategy`]'s cell
//! sequence through this executor once per microbatch, stage-stamps the
//! spans, merges the microbatch results exactly, and reconciles the
//! summed meters against [`crate::planner::Strategy::total_cost`]
//! ([`crate::book::pipeline`]).

mod buf;
mod exec;
pub mod fault;
mod pipeline;
mod pool;
mod recover;

pub use buf::{for_each_row, ShardBuf};
pub use exec::{execute, execute_with, ExecError, ExecOptions, ExecReport};
pub use pipeline::{try_execute_strategy, StrategyExecReport};
pub use fault::{Fault, FaultKind, FaultPlan};
pub use pool::{StepCtx, WorkerPool};
pub use recover::{
    execute_with_recovery, Checkpoint, RecoverOptions, RecoveryOutcome, RecoveryReport,
};

use crate::graph::{max_rel_err, Graph};

/// Compare every tensor of an execution against the serial reference:
/// returns the worst relative deviation and the name of the tensor it
/// occurred on (`(0.0, "")` for an empty graph).
pub fn worst_divergence(g: &Graph, report: &ExecReport, serial: &[Vec<f32>]) -> (f64, String) {
    let mut worst = (0.0f64, String::new());
    for t in &g.tensors {
        let err = max_rel_err(&report.tensors[t.id], &serial[t.id]);
        if err > worst.0 {
            worst = (err, t.name.clone());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{eval_serial, seed_values, GraphBuilder};
    use crate::lower::try_lower;
    use crate::models::{mlp, MlpConfig};
    use crate::planner::{baselines, eval_plan, try_k_cut, Plan, PlanError, Planner, PlanFamily};
    use crate::sim::SimConfig;
    use crate::tiling::Tile;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn serial_plan_executes_byte_free() {
        // k = 0: one device, no collectives, exact agreement (the
        // executor degenerates into the interpreter).
        let g = mlp(&MlpConfig { batch: 4, dims: vec![4, 6], bias: true });
        let plan = Planner::try_plan(&g, 0, PlanFamily::Soybean).unwrap();
        let program = try_lower(&g, &plan, &cfg()).unwrap();
        let init = seed_values(&g, 1);
        let r = execute(&g, &plan, &program, &init).unwrap();
        assert_eq!(r.instr_bytes, 0);
        assert_eq!(r.payload_bytes, 0);
        let serial = eval_serial(&g, &init).unwrap();
        let (worst, t) = worst_divergence(&g, &r, &serial);
        assert_eq!(worst, 0.0, "serial-plan execution diverged on {t}");
    }

    #[test]
    fn data_parallel_mlp_matches_serial() {
        // DP baselines are priced with the forced classic gradient
        // aggregation, so the matching forced lowering keeps the meter
        // identity; the executor's data path is form-agnostic.
        let g = mlp(&MlpConfig { batch: 8, dims: vec![6, 10, 4], bias: true });
        let plan = baselines::data_parallel(&g, 2);
        let program =
            crate::lower::try_lower_forced(&g, &plan, &cfg(), &crate::planner::classic_dp_form).unwrap();
        let init = seed_values(&g, 2);
        let r = execute(&g, &plan, &program, &init).unwrap();
        assert_eq!(r.instr_bytes, plan.total_cost());
        let serial = eval_serial(&g, &init).unwrap();
        let (worst, t) = worst_divergence(&g, &r, &serial);
        assert!(worst <= 1e-5, "DP mlp diverged on {t}: {worst:e}");
    }

    #[test]
    fn soybean_plan_matches_serial_at_4_devices() {
        let g = mlp(&MlpConfig { batch: 16, dims: vec![8, 12, 8], bias: false });
        let plan = try_k_cut(&g, 2).unwrap();
        let program = try_lower(&g, &plan, &cfg()).unwrap();
        let init = seed_values(&g, 3);
        let r = execute(&g, &plan, &program, &init).unwrap();
        assert_eq!(r.instr_bytes, plan.total_cost());
        let serial = eval_serial(&g, &init).unwrap();
        let (worst, t) = worst_divergence(&g, &r, &serial);
        assert!(worst <= 1e-5, "soybean mlp diverged on {t}: {worst:e}");
    }

    #[test]
    fn malformed_plan_reports_structured_error() {
        let g = mlp(&MlpConfig { batch: 4, dims: vec![4, 4], bias: false });
        let plan = try_k_cut(&g, 1).unwrap();
        let program = try_lower(&g, &plan, &cfg()).unwrap();
        let init = seed_values(&g, 1);
        // Wrong tensor count.
        let bad = Plan { k: 1, tiles: vec![vec![Tile::Rep]], cut_costs: vec![0] };
        match execute(&g, &bad, &program, &init) {
            Err(ExecError::Plan(PlanError::MalformedPlan { .. })) => {}
            other => panic!("expected MalformedPlan, got {other:?}"),
        }
        // A split of an odd dimension.
        let mut tiles = plan.tiles.clone();
        let odd = g.tensors.iter().position(|t| t.rank() == 0).unwrap();
        tiles[odd] = vec![Tile::Split(0)];
        let bad = Plan { k: 1, tiles, cut_costs: plan.cut_costs.clone() };
        match execute(&g, &bad, &program, &init) {
            Err(ExecError::Plan(PlanError::UnsplittableTensor { cut, .. })) => assert_eq!(cut, 0),
            other => panic!("expected UnsplittableTensor, got {other:?}"),
        }
    }

    #[test]
    fn meter_mismatch_rejected() {
        let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
        let plan = try_k_cut(&g, 1).unwrap();
        let program = try_lower(&g, &plan, &cfg()).unwrap();
        let init = seed_values(&g, 1);
        // Execute against a plan whose Theorem-1 total disagrees with the
        // program: the executor refuses rather than mis-metering.
        let mut wrong = plan.clone();
        wrong.cut_costs[0] += 4;
        match execute(&g, &wrong, &program, &init) {
            Err(ExecError::MeterMismatch { metered, plan: p }) => {
                assert_eq!(metered + 4, p);
            }
            other => panic!("expected MeterMismatch, got {other:?}"),
        }
    }

    #[test]
    fn missing_input_reported() {
        let g = mlp(&MlpConfig { batch: 4, dims: vec![4, 4], bias: false });
        let plan = try_k_cut(&g, 1).unwrap();
        let program = try_lower(&g, &plan, &cfg()).unwrap();
        let mut init = seed_values(&g, 1);
        init[0] = None;
        assert!(matches!(
            execute(&g, &plan, &program, &init),
            Err(ExecError::Input(crate::graph::InterpError::MissingInput { .. }))
        ));
    }

    /// Pinned regression: the `AllToAll` `Split(a) -> Split(b)` data path.
    /// A hand-written plan homes an activation row-split while its
    /// consumer's aligned form needs it column-split, forcing the
    /// quarter-swap exchange; the numbers must survive the round trip.
    #[test]
    fn all_to_all_retiling_is_numerically_exact() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 4]);
        let h = b.relu("r", x);
        b.relu("r2", h);
        let g = b.finish();
        // x homes row-split but h homes column-split: the elementwise op
        // computes in its axis-0 form and re-tiles its output
        // Split(0) -> Split(1) — the quarter-swap AllToAll — and the
        // second relu re-tiles back on its input side.
        let mut tiles = vec![vec![Tile::Rep]; g.tensors.len()];
        tiles[x] = vec![Tile::Split(0)];
        tiles[h] = vec![Tile::Split(1)];
        let plan = eval_plan(&g, &tiles);
        let program = try_lower(&g, &plan, &cfg()).unwrap();
        assert!(
            program
                .transfers
                .iter()
                .any(|m| m.kind == crate::lower::CollectiveKind::AllToAll),
            "plan did not exercise the AllToAll path: {:?}",
            program.transfers
        );
        let init = seed_values(&g, 9);
        let r = execute(&g, &plan, &program, &init).unwrap();
        let serial = eval_serial(&g, &init).unwrap();
        let (worst, t) = worst_divergence(&g, &r, &serial);
        assert!(worst <= 1e-5, "AllToAll path diverged on {t}: {worst:e}");
    }
}
