//! Recovery-aware execution: checkpoints, bounded retry, and elastic
//! re-planning on permanent device loss.
//!
//! [`execute_with_recovery`] wraps one step of [`execute_with`] in the
//! recovery state machine of docs/execution.md §Fault tolerance:
//!
//! 1. **Checkpoint** — capture the step's input state (the producerless
//!    tensors: parameters, inputs, labels) with an FNV-1a checksum
//!    ([`Checkpoint`]); every restore verifies the digest first, so a
//!    rotted checkpoint is a structured
//!    [`ExecError::CheckpointCorrupt`], never silent garbage training.
//! 2. **Retry** — a *retryable* failure (worker panic/loss, watchdog
//!    timeout, payload corruption) restores the checkpoint and re-runs
//!    the step after an exponential backoff, up to
//!    [`RecoverOptions::max_retries`] times. Transient faults have
//!    disarmed themselves by then ([`super::fault`]), so the retry
//!    succeeds — this is how a lost packet is distinguished from a lost
//!    machine.
//! 3. **Re-plan** — when retries are exhausted and the error implicates a
//!    concrete device (a persistent kill re-fires on every attempt), the
//!    device set shrinks: [`crate::planner::replan_after_loss`] plans the
//!    same graph for the surviving `2^(k-1)` devices, the plan is
//!    re-lowered and re-validated ([`LoweredProgram::validate_for`]), and
//!    the step resumes *from the checkpoint* on the survivors. The paper's
//!    planner is parameterized by device count, so elasticity is a
//!    re-search, not a special mode.
//!
//! Non-retryable failures (malformed plan or program, bad input, meter
//! mismatch, replica divergence) propagate immediately: retrying a
//! structural bug just burns the budget. The differential gate holds
//! through recovery — a recovered run must still match
//! [`crate::graph::eval_serial`] within 1e-5 (`rust/tests/fault.rs`).

use std::time::Duration;

use crate::graph::Graph;
use crate::lower::{try_lower, LoweredProgram};
use crate::planner::{replan_after_loss, Plan};
use crate::sim::SimConfig;
use crate::util::checksum::checksum_values;

use super::exec::{execute_with, ExecError, ExecOptions, ExecReport};

/// A checksummed snapshot of one step's input state: the producerless
/// tensors (parameters, inputs, labels) in `init` layout, plus an FNV-1a
/// digest over presence + values.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Step this state feeds (step `n`'s checkpoint is the state *before*
    /// step `n` runs).
    pub step: u64,
    /// The `init` vector of the step — what every attempt restores.
    pub values: Vec<Option<Vec<f32>>>,
    /// FNV-1a digest of `values` at capture time.
    pub checksum: u64,
}

impl Checkpoint {
    /// Capture `values` as the checkpoint of `step`, digesting them now.
    pub fn capture(step: u64, values: Vec<Option<Vec<f32>>>) -> Self {
        let checksum = checksum_values(&values);
        Checkpoint { step, values, checksum }
    }

    /// Checkpoint for the step *after* a successful execution: carry the
    /// reassembled values of every producerless tensor (the updated
    /// parameter state) forward, keyed `step + 1`. This is the step-loop
    /// handoff — in a training loop the post-step state of step `n` is
    /// the restore point of step `n + 1`.
    pub fn after(g: &Graph, step: u64, report: &ExecReport) -> Self {
        let mut produced = vec![false; g.tensors.len()];
        for op in &g.ops {
            for &o in &op.outputs {
                produced[o] = true;
            }
        }
        let values = g
            .tensors
            .iter()
            .map(|t| if produced[t.id] { None } else { Some(report.tensors[t.id].clone()) })
            .collect();
        Checkpoint::capture(step + 1, values)
    }

    /// Verify the digest still matches the values — run before every
    /// restore, so bit rot surfaces as [`ExecError::CheckpointCorrupt`].
    pub fn verify(&self) -> Result<(), ExecError> {
        if checksum_values(&self.values) != self.checksum {
            return Err(ExecError::CheckpointCorrupt { step: self.step });
        }
        Ok(())
    }
}

/// Knobs for [`execute_with_recovery`].
#[derive(Debug, Clone)]
pub struct RecoverOptions {
    /// Per-attempt execution options (watchdog deadline, fault plan).
    /// Fault arming state persists across retries — transient faults stay
    /// fired, persistent ones re-fire — which is exactly the distinction
    /// the retry loop exploits.
    pub exec: ExecOptions,
    /// Retries after the first failed attempt, before the failure is
    /// treated as permanent.
    pub max_retries: u32,
    /// Backoff before retry `i` (0-based): `backoff << i` — exponential,
    /// starting small so tests stay fast.
    pub backoff: Duration,
    /// Cost/latency config for re-lowering the re-planned program after
    /// device loss.
    pub sim: SimConfig,
}

impl Default for RecoverOptions {
    fn default() -> Self {
        RecoverOptions {
            exec: ExecOptions::default(),
            max_retries: 2,
            backoff: Duration::from_millis(10),
            sim: SimConfig::default(),
        }
    }
}

impl RecoverOptions {
    /// Set the per-attempt execution options (builder style).
    #[must_use]
    pub fn exec(mut self, exec: ExecOptions) -> Self {
        self.exec = exec;
        self
    }

    /// Set the retry budget (builder style).
    #[must_use]
    pub fn max_retries(mut self, max_retries: u32) -> Self {
        self.max_retries = max_retries;
        self
    }

    /// Set the base backoff (builder style).
    #[must_use]
    pub fn backoff(mut self, backoff: Duration) -> Self {
        self.backoff = backoff;
        self
    }

    /// Set the re-lowering cost config (builder style).
    #[must_use]
    pub fn sim(mut self, sim: SimConfig) -> Self {
        self.sim = sim;
        self
    }
}

/// How a recovered step eventually succeeded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecoveryOutcome {
    /// First attempt succeeded; nothing fired.
    Clean,
    /// Succeeded on the full device set after `retries` restore+retry
    /// rounds (transient fault).
    Retried {
        /// Failed attempts before the success.
        retries: u32,
    },
    /// Permanent loss of `lost_device`: re-planned onto `devices`
    /// survivors and resumed from the checkpoint.
    Replanned {
        /// Device the root-cause error implicated.
        lost_device: usize,
        /// Device count of the recovery plan (`2^(k-1)`).
        devices: usize,
    },
}

/// Result of a recovered execution.
#[derive(Debug)]
pub struct RecoveryReport {
    /// The successful run's report (meters, reassembled tensors).
    pub report: ExecReport,
    /// How success was reached.
    pub outcome: RecoveryOutcome,
    /// The plan the successful run used — the original, or the elastic
    /// re-plan after device loss.
    pub plan: Plan,
    /// Root-cause errors of every failed attempt, in order.
    pub failures: Vec<ExecError>,
}

/// Whether retrying can plausibly help: transport and worker failures
/// yes; structural errors (plan, program, input, meters) no.
fn retryable(e: &ExecError) -> bool {
    matches!(
        e,
        ExecError::Worker { .. } | ExecError::Timeout { .. } | ExecError::Corrupt { .. }
    )
}

/// The device a root-cause error points at — the one excluded when the
/// failure is promoted to permanent loss.
fn implicated_device(e: &ExecError) -> Option<usize> {
    match e {
        ExecError::Worker { device, .. } => Some(*device),
        ExecError::Timeout { peer, .. } => Some(*peer),
        ExecError::Corrupt { from, .. } => Some(*from),
        _ => None,
    }
}

/// Execute one step with checkpointing, bounded retry, and elastic
/// re-planning (module docs for the state machine).
///
/// # Examples
///
/// A persistent mid-step device kill: every retry re-fires it, so the
/// step is re-planned onto half the devices and resumed from the
/// checkpoint — and the numbers still match the serial interpreter.
///
/// ```
/// use soybean::graph::{eval_serial, max_rel_err, seed_values};
/// use soybean::lower::try_lower;
/// use soybean::models::{mlp, MlpConfig};
/// use soybean::planner::try_k_cut;
/// use soybean::sim::SimConfig;
/// use soybean::spmd::{
///     execute_with_recovery, ExecOptions, FaultPlan, RecoverOptions, RecoveryOutcome,
/// };
/// use std::time::Duration;
///
/// let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
/// let plan = try_k_cut(&g, 2).unwrap();
/// let program = try_lower(&g, &plan, &SimConfig::default()).unwrap();
/// let init = seed_values(&g, 7);
///
/// let opts = RecoverOptions::default()
///     .exec(ExecOptions::default()
///         .deadline(Duration::from_millis(500))
///         .fault_plan(FaultPlan::kill(1, 0))) // device 1 dies at op 0, every attempt
///     .backoff(Duration::from_millis(1));
///
/// let r = execute_with_recovery(&g, &plan, &program, &init, &opts).unwrap();
/// assert_eq!(
///     r.outcome,
///     RecoveryOutcome::Replanned { lost_device: 1, devices: 2 }
/// );
/// let serial = eval_serial(&g, &init).unwrap();
/// for t in &g.tensors {
///     assert!(max_rel_err(&r.report.tensors[t.id], &serial[t.id]) <= 1e-5);
/// }
/// ```
pub fn execute_with_recovery(
    g: &Graph,
    plan: &Plan,
    program: &LoweredProgram,
    init: &[Option<Vec<f32>>],
    opts: &RecoverOptions,
) -> Result<RecoveryReport, ExecError> {
    let ckpt = Checkpoint::capture(0, init.to_vec());
    let mut failures = Vec::new();

    // Attempt 0 plus `max_retries` retries on the full device set. The
    // fault plan is shared across attempts, so transient faults stay
    // disarmed after firing and persistent ones keep firing.
    for attempt in 0..=opts.max_retries {
        if attempt > 0 {
            if let Some(m) = &opts.exec.metrics {
                m.inc("recover.retries", 1);
            }
            ckpt.verify()?;
            std::thread::sleep(opts.backoff * (1u32 << (attempt - 1).min(16)));
        }
        match execute_with(g, plan, program, &ckpt.values, &opts.exec) {
            Ok(report) => {
                let outcome = if attempt == 0 {
                    RecoveryOutcome::Clean
                } else {
                    RecoveryOutcome::Retried { retries: attempt }
                };
                return Ok(RecoveryReport { report, outcome, plan: plan.clone(), failures });
            }
            Err(e) if retryable(&e) => failures.push(e),
            Err(e) => return Err(e),
        }
    }

    // Retries exhausted: the failure is permanent. If it names a device,
    // shrink the world and resume from the checkpoint on the survivors.
    // Invariant: the loop above pushed at least one failure.
    let last = failures.last().expect("exhausted retries imply a recorded failure").clone();
    let Some(lost) = implicated_device(&last) else {
        return Err(last);
    };
    ckpt.verify()?;
    let new_plan = replan_after_loss(g, plan)?;
    let new_program = try_lower(g, &new_plan, &opts.sim)?;
    new_program.validate_for(&new_plan)?;
    if let Some(m) = &opts.exec.metrics {
        m.inc("recover.replans", 1);
    }
    // The dead device is out of the recovery world: its injected faults
    // died with it, so the survivors run clean (a fresh fault plan for
    // the new device numbering would be a different experiment) — but the
    // metrics handle and trace flag carry over, so recovery steps stay
    // observable.
    let mut clean = ExecOptions::default().deadline(opts.exec.deadline).trace(opts.exec.trace);
    clean.metrics = opts.exec.metrics.clone();
    let report = execute_with(g, &new_plan, &new_program, &ckpt.values, &clean)?;
    let devices = new_plan.devices();
    Ok(RecoveryReport {
        report,
        outcome: RecoveryOutcome::Replanned { lost_device: lost, devices },
        plan: new_plan,
        failures,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::seed_values;
    use crate::models::{mlp, MlpConfig};
    use crate::planner::try_k_cut;
    use crate::spmd::execute;

    #[test]
    fn checkpoint_verify_catches_bit_rot() {
        let mut c = Checkpoint::capture(3, vec![Some(vec![1.0, 2.0]), None]);
        c.verify().unwrap();
        c.values[0].as_mut().unwrap()[1] = 2.5;
        match c.verify() {
            Err(ExecError::CheckpointCorrupt { step }) => assert_eq!(step, 3),
            other => panic!("expected CheckpointCorrupt, got {other:?}"),
        }
    }

    #[test]
    fn checkpoint_after_carries_producerless_state() {
        let g = mlp(&MlpConfig { batch: 4, dims: vec![4, 4], bias: false });
        let plan = try_k_cut(&g, 1).unwrap();
        let program = crate::lower::try_lower(&g, &plan, &SimConfig::default()).unwrap();
        let init = seed_values(&g, 5);
        let report = execute(&g, &plan, &program, &init).unwrap();
        let next = Checkpoint::after(&g, 0, &report);
        assert_eq!(next.step, 1);
        next.verify().unwrap();
        // Producerless tensors present, produced ones absent — so the
        // checkpoint is a valid `init` for the next step.
        for (t, v) in g.tensors.iter().zip(&next.values) {
            let produced = g.ops.iter().any(|op| op.outputs.contains(&t.id));
            assert_eq!(v.is_none(), produced, "tensor {}", t.name);
        }
        let again = execute(&g, &plan, &program, &next.values).unwrap();
        assert_eq!(again.instr_bytes, plan.total_cost());
    }

    #[test]
    fn structural_errors_are_not_retried() {
        assert!(!retryable(&ExecError::MeterMismatch { metered: 1, plan: 2 }));
        assert!(!retryable(&ExecError::ReplicaDivergence { tensor: "x".into() }));
        assert!(!retryable(&ExecError::CheckpointCorrupt { step: 0 }));
        assert!(retryable(&ExecError::Worker { device: 0, reason: "boom".into() }));
        assert!(retryable(&ExecError::Timeout {
            device: 0,
            op: 0,
            slot: 0,
            peer: 1,
            waited_ms: 1,
            context: None
        }));
        assert!(retryable(&ExecError::Corrupt { device: 0, op: 0, from: 1, context: None }));
    }

    #[test]
    fn implicated_device_names_the_stalled_party() {
        assert_eq!(
            implicated_device(&ExecError::Timeout {
                device: 2,
                op: 0,
                slot: 0,
                peer: 3,
                waited_ms: 1,
                context: None
            }),
            Some(3),
            "a timeout implicates the peer that went quiet, not the waiter"
        );
        assert_eq!(
            implicated_device(&ExecError::Corrupt { device: 2, op: 0, from: 1, context: None }),
            Some(1)
        );
        assert_eq!(implicated_device(&ExecError::MeterMismatch { metered: 1, plan: 2 }), None);
    }
}
