//! The threaded SPMD executor: run a lowered program on real tensors.
//!
//! One OS thread per device walks that device's [`Instr`] stream. The
//! collective instructions are the byte meter — each start adds its priced
//! wire volume to `instr_bytes`, which therefore sums to the plan's
//! Theorem-1 cost bit for bit (the lowering identity, re-asserted here at
//! entry). The *data* a collective realizes moves through
//! [`std::sync::mpsc`] channels at op granularity, in the three phases of
//! §5.2 that the shard schedule ([`ShardTask`]) prescribes:
//!
//! 1. **Ghost gather** — each input is fetched into the op's required
//!    layout: the receiver decomposes its required region over the
//!    tensor's home (plan) layout via [`gather_sources`]; senders run the
//!    *same* deterministic decomposition for every peer, so each side
//!    knows exactly which pieces to ship (the `AllGather` / `AllToAll`
//!    patterns) without negotiation.
//! 2. **Local compute** — the shared kernel library
//!    ([`crate::graph::apply_op`]) runs on the shard-local views; at
//!    reduce cuts the result is a full-extent partial sum.
//! 3. **Scatter-reduce** — the output moves to its home layout: each
//!    receiver's home region decomposes over the *produced* layout, and
//!    every piece is summed (in `f64`) over its reduce-bit contributor
//!    set — the devices that differ from the piece's owner only at the
//!    cuts where the op produced partials (the `ReduceScatter` /
//!    `SendRecv`-partial-exchange patterns, generalized to k cuts).
//!
//! Sends never block (unbounded channels) and receives only consume
//! messages a peer's earlier-or-equal op produced, so the aligned SPMD
//! streams make the exchange deadlock-free by the same induction the
//! event engine relies on; a worker that fails broadcasts a poison
//! message so its peers error out instead of blocking. Because every
//! phase is deterministic — deterministic piece assignment, deterministic
//! contributor order, `f64` accumulation rounded once — replicated shards
//! are **bit-identical** across devices, which [`execute`] verifies while
//! reassembling full tensors (any divergence is a routing bug, reported
//! as [`ExecError::ReplicaDivergence`]).
//!
//! The channel payload volume is reported separately (`payload_bytes`,
//! and per op in `op_payload_bytes`): it is the §5.2 ghost-gather
//! *realization* of the conversions, which coincides with the collective
//! meter for single-cut plans (pinned by the property tests) but may
//! shortcut through nearer replicas — or pay the naive partial exchange —
//! on stacked cuts (docs/execution.md §Two meters).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};

use crate::exec::{gather_sources, resident_region, try_build_shard_tasks, Region, ShardTask};
use crate::graph::{apply_op, Graph, InterpError, OpId, View};
use crate::lower::{Instr, LoweredProgram};
use crate::planner::{Plan, PlanError};

use super::buf::{for_each_row, ShardBuf};

/// Slot tag for output scatter-reduce messages (inputs use their index).
const OUT_SLOT: u8 = u8::MAX;
/// Slot tag a failing worker broadcasts so peers error instead of block.
const POISON_SLOT: u8 = u8::MAX - 1;
/// Reason string of a cascade abort (a worker that stopped because a
/// peer poisoned it) — `execute` prefers reporting the root cause.
const POISON_REASON: &str = "peer worker aborted";

/// The pieces of one exchange: absolute region + dense `f32` payload.
type Pieces = Vec<(Region, Vec<f32>)>;

/// One inter-device message: every piece one sender contributes to one
/// exchange of one op.
struct Msg {
    from: usize,
    op: OpId,
    slot: u8,
    pieces: Pieces,
}

/// Structured executor failure.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecError {
    /// The plan or program is malformed (validation, shard schedule).
    Plan(PlanError),
    /// The initial values are missing or mis-sized (same checks as the
    /// serial interpreter's).
    Input(InterpError),
    /// The program's instruction bytes do not sum to the plan's Theorem-1
    /// cost — the one-theory contract the executor refuses to run without.
    MeterMismatch {
        /// Bytes the program's collective instructions sum to.
        metered: u64,
        /// The plan's Theorem-1 total.
        plan: u64,
    },
    /// Two devices hold bitwise-different values for the same element of
    /// a replicated shard — a conversion-routing bug, never tolerated.
    ReplicaDivergence {
        /// Name of the diverging tensor.
        tensor: String,
    },
    /// A worker thread failed (kernel panic, peer abort, closed channel).
    Worker {
        /// Device whose worker failed first.
        device: usize,
        /// What happened.
        reason: String,
    },
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::Plan(e) => write!(f, "{e}"),
            ExecError::Input(e) => write!(f, "{e}"),
            ExecError::MeterMismatch { metered, plan } => {
                write!(f, "program meters {metered} B but the plan's Theorem-1 cost is {plan} B")
            }
            ExecError::ReplicaDivergence { tensor } => {
                write!(f, "replicated shards of `{tensor}` diverged between devices")
            }
            ExecError::Worker { device, reason } => {
                write!(f, "worker {device} failed: {reason}")
            }
        }
    }
}

impl std::error::Error for ExecError {}

impl From<PlanError> for ExecError {
    fn from(e: PlanError) -> Self {
        ExecError::Plan(e)
    }
}

impl From<InterpError> for ExecError {
    fn from(e: InterpError) -> Self {
        ExecError::Input(e)
    }
}

/// Result of one threaded execution.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Worker-thread count (`2^k`).
    pub devices: usize,
    /// Every tensor of the graph, reassembled from the devices' home
    /// shards (indexed by `TensorId`) — compare against
    /// [`crate::graph::eval_serial`].
    pub tensors: Vec<Vec<f32>>,
    /// Bytes metered from the executed collective instructions; equals
    /// the plan's Theorem-1 total bit for bit (checked at entry).
    pub instr_bytes: u64,
    /// `f32` payload bytes actually shipped between worker threads (the
    /// §5.2 ghost-gather realization volume).
    pub payload_bytes: u64,
    /// Payload bytes attributed to each op's exchanges (indexed by
    /// `OpId`); sums to `payload_bytes`.
    pub op_payload_bytes: Vec<u64>,
}

/// What one worker thread hands back.
struct DeviceOutcome {
    home: Vec<Option<ShardBuf>>,
    instr_bytes: u64,
    payload_bytes: u64,
    op_payload: Vec<u64>,
}

struct Worker<'a> {
    d: usize,
    k: usize,
    devices: usize,
    g: &'a Graph,
    plan: &'a Plan,
    tasks: &'a [ShardTask],
    program: &'a LoweredProgram,
    senders: Vec<Sender<Msg>>,
    rx: Receiver<Msg>,
    inbox: BTreeMap<(OpId, u8, usize), Pieces>,
    home: Vec<Option<ShardBuf>>,
    instr_bytes: u64,
    payload_bytes: u64,
    op_payload: Vec<u64>,
}

impl<'a> Worker<'a> {
    fn run(mut self) -> Result<DeviceOutcome, ExecError> {
        let program = self.program;
        let d = self.d;
        for instr in &program.programs[d].instrs {
            match instr {
                Instr::Compute { op, .. } => self.compute(*op)?,
                Instr::Wait { .. } => {}
                // Collective starts: the Theorem-1 byte meter. The data
                // the collective realizes moves in the op-granular
                // exchanges of `compute` (module docs).
                other => self.instr_bytes += other.bytes(),
            }
        }
        Ok(DeviceOutcome {
            home: self.home,
            instr_bytes: self.instr_bytes,
            payload_bytes: self.payload_bytes,
            op_payload: self.op_payload,
        })
    }

    /// Block until the `(op, slot)` message from `from` is available.
    fn recv_from(
        &mut self,
        op: OpId,
        slot: u8,
        from: usize,
    ) -> Result<Pieces, ExecError> {
        loop {
            if let Some(pieces) = self.inbox.remove(&(op, slot, from)) {
                return Ok(pieces);
            }
            match self.rx.recv() {
                Ok(m) if m.slot == POISON_SLOT => {
                    return Err(ExecError::Worker { device: m.from, reason: POISON_REASON.into() })
                }
                Ok(m) => {
                    self.inbox.insert((m.op, m.slot, m.from), m.pieces);
                }
                Err(_) => {
                    return Err(ExecError::Worker {
                        device: self.d,
                        reason: format!(
                            "channel closed while waiting for op {op} slot {slot} from {from}"
                        ),
                    })
                }
            }
        }
    }

    fn send(&mut self, to: usize, op: OpId, slot: u8, pieces: Pieces) {
        let bytes: u64 = pieces.iter().map(|(r, _)| r.elements() * 4).sum();
        self.payload_bytes += bytes;
        self.op_payload[op] += bytes;
        // A send only fails if the receiver died; the poison/abort path
        // reports that failure, so the result here is ignorable.
        let _ = self.senders[to].send(Msg { from: self.d, op, slot, pieces });
    }

    /// §5.2 phase 1: assemble one input in the op's required layout.
    fn gather_input(&mut self, op: OpId, slot: usize, t: usize) -> Result<ShardBuf, ExecError> {
        let (g, plan, tasks) = (self.g, self.plan, self.tasks);
        let (devices, d) = (self.devices, self.d);
        let shape = &g.tensors[t].shape;
        let req = &tasks[op].required_ins[slot];
        let home_seq = &plan.tiles[t];
        if req == home_seq {
            // The op's aligned form wants the tensor exactly as it lives:
            // nothing moves anywhere (every device's required region is
            // its resident region), so skip the decompositions entirely.
            // Invariant: home shards exist before any consumer.
            return Ok(self.home[t].as_ref().expect("home shard materialized").clone());
        }
        // Send every peer the pieces it will fetch from this device —
        // the peer runs the identical decomposition, so the piece lists
        // agree without negotiation.
        for e in 0..devices {
            if e == d {
                continue;
            }
            let want_e = resident_region(shape, req, e);
            let mut pieces = Vec::new();
            for p in gather_sources(shape, home_seq, devices, e, &want_e) {
                if p.src == d {
                    // Invariant: home shards exist before any consumer
                    // (streams are topologically ordered).
                    let buf = self.home[t].as_ref().expect("home shard materialized");
                    let data = buf.extract(&p.region);
                    pieces.push((p.region, data));
                }
            }
            if !pieces.is_empty() {
                self.send(e, op, slot as u8, pieces);
            }
        }
        // Fetch this device's own pieces: local copies are free, remote
        // ones arrive tagged (op, slot, src).
        let want = resident_region(shape, req, d);
        let pieces = gather_sources(shape, home_seq, devices, d, &want);
        let mut buf = ShardBuf::zeros(want);
        let mut expected: BTreeSet<usize> = BTreeSet::new();
        for p in &pieces {
            if p.src == d {
                let homebuf = self.home[t].as_ref().expect("home shard materialized");
                let data = homebuf.extract(&p.region);
                buf.paste(&p.region, &data);
            } else {
                expected.insert(p.src);
            }
        }
        for src in expected {
            for (cell, data) in self.recv_from(op, slot as u8, src)? {
                buf.paste(&cell, &data);
            }
        }
        Ok(buf)
    }

    /// The devices holding *distinct* partials of the piece owned by
    /// `src`: `src` with every combination of bits at the reduce cuts.
    fn contributors(src: usize, rbits: &[usize]) -> Vec<usize> {
        let mut out = Vec::with_capacity(1 << rbits.len());
        for combo in 0..(1usize << rbits.len()) {
            let mut c = src;
            for (bi, &bit) in rbits.iter().enumerate() {
                c = (c & !(1usize << bit)) | (((combo >> bi) & 1) << bit);
            }
            out.push(c);
        }
        out
    }

    /// §5.2 phase 3: move the produced shard (partials at reduce cuts)
    /// into the tensor's home layout, summing contributor pieces in f64.
    fn scatter_output(&mut self, op: OpId, out_buf: ShardBuf) -> Result<(), ExecError> {
        let (g, plan, tasks) = (self.g, self.plan, self.tasks);
        let (devices, d, k) = (self.devices, self.d, self.k);
        let z = g.ops[op].outputs[0];
        let zshape = &g.tensors[z].shape;
        let produced = &tasks[op].produced;
        let rbits: Vec<usize> = tasks[op].reduce_cuts.iter().map(|&j| k - 1 - j).collect();
        let home_seq = &plan.tiles[z];

        // Send phase: ship every piece of every peer's home region this
        // device contributes a partial (or the value) to.
        for e in 0..devices {
            if e == d {
                continue;
            }
            let want_e = resident_region(zshape, home_seq, e);
            let mut pieces = Vec::new();
            for p in gather_sources(zshape, produced, devices, e, &want_e) {
                if Self::contributors(p.src, &rbits).contains(&d) {
                    pieces.push((p.region.clone(), out_buf.extract(&p.region)));
                }
            }
            if !pieces.is_empty() {
                self.send(e, op, OUT_SLOT, pieces);
            }
        }

        // Receive phase: accumulate each piece over its contributor set,
        // in deterministic (piece, combo) order so replicas stay
        // bit-identical across devices.
        let want = resident_region(zshape, home_seq, d);
        let mut acc = vec![0.0f64; want.elements() as usize];
        let pieces = gather_sources(zshape, produced, devices, d, &want);
        let mut expected: BTreeSet<usize> = BTreeSet::new();
        for p in &pieces {
            for c in Self::contributors(p.src, &rbits) {
                if c != d {
                    expected.insert(c);
                }
            }
        }
        let mut incoming: BTreeMap<usize, (Pieces, usize)> = BTreeMap::new();
        for src in expected {
            incoming.insert(src, (self.recv_from(op, OUT_SLOT, src)?, 0));
        }
        for p in &pieces {
            for c in Self::contributors(p.src, &rbits) {
                if c == d {
                    for_each_row(&want, &out_buf.region, &p.region, |db, sb, len| {
                        for i in 0..len {
                            acc[db + i] += out_buf.data[sb + i] as f64;
                        }
                    });
                } else {
                    let entry = incoming
                        .get_mut(&c)
                        .expect("contributor enumerated in the expected set");
                    // Invariant: sender and receiver enumerate the same
                    // gather decomposition in the same order.
                    let (cell, data) = &entry.0[entry.1];
                    assert_eq!(cell, &p.region, "piece stream misaligned with sender");
                    for_each_row(&want, cell, cell, |db, sb, len| {
                        for i in 0..len {
                            acc[db + i] += data[sb + i] as f64;
                        }
                    });
                    entry.1 += 1;
                }
            }
        }
        for (src, (list, cursor)) in &incoming {
            // Invariant: the sender shipped exactly the pieces we summed.
            assert_eq!(*cursor, list.len(), "unconsumed pieces from device {src}");
        }
        let data: Vec<f32> = acc.into_iter().map(|v| v as f32).collect();
        self.home[z] = Some(ShardBuf { region: want, data });
        Ok(())
    }

    fn compute(&mut self, op: OpId) -> Result<(), ExecError> {
        let g = self.g;
        let n_ins = g.ops[op].inputs.len();
        let mut local_ins = Vec::with_capacity(n_ins);
        for slot in 0..n_ins {
            let t = g.ops[op].inputs[slot];
            local_ins.push(self.gather_input(op, slot, t)?);
        }
        let zshape = &g.tensors[g.ops[op].outputs[0]].shape;
        let out_region = resident_region(zshape, &self.tasks[op].produced, self.d);
        let views: Vec<View<'_>> = local_ins
            .iter()
            .map(|b| View { data: &b.data, shape: &b.region.shape, offset: &b.region.offset })
            .collect();
        let data = catch_unwind(AssertUnwindSafe(|| {
            apply_op(g, &g.ops[op], &views, &out_region.shape)
        }))
        .map_err(|_| ExecError::Worker {
            device: self.d,
            reason: format!("kernel for op `{}` panicked", g.ops[op].name),
        })?;
        self.scatter_output(op, ShardBuf { region: out_region, data })
    }
}

/// Execute `program` (the lowering of `(g, plan)`) on `2^k` worker
/// threads with real `f32` shard buffers.
///
/// `init` is the same producerless-tensor value vector the serial
/// interpreter takes ([`crate::graph::seed_values`] shapes it); every
/// device slices its home shards from these arrays. On success the report
/// carries every tensor reassembled (with the replica bit-equality check)
/// plus the two byte meters.
///
/// # Examples
///
/// ```
/// use soybean::graph::{eval_serial, max_rel_err, seed_values};
/// use soybean::lower::lower;
/// use soybean::models::{mlp, MlpConfig};
/// use soybean::planner::k_cut;
/// use soybean::sim::SimConfig;
/// use soybean::spmd::execute;
///
/// let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
/// let plan = k_cut(&g, 1);
/// let program = lower(&g, &plan, &SimConfig::default());
/// let init = seed_values(&g, 7);
/// let report = execute(&g, &plan, &program, &init).unwrap();
/// // Observed collective traffic is exactly the plan's Theorem-1 total.
/// assert_eq!(report.instr_bytes, plan.total_cost());
/// // And the numbers match the serial interpreter.
/// let serial = eval_serial(&g, &init).unwrap();
/// for t in &g.tensors {
///     assert!(max_rel_err(&report.tensors[t.id], &serial[t.id]) <= 1e-5);
/// }
/// ```
pub fn execute(
    g: &Graph,
    plan: &Plan,
    program: &LoweredProgram,
    init: &[Option<Vec<f32>>],
) -> Result<ExecReport, ExecError> {
    let tasks = try_build_shard_tasks(g, plan)?;
    program.validate()?;
    let devices = plan.devices();
    if program.devices != devices {
        return Err(ExecError::Plan(PlanError::MalformedProgram {
            device: 0,
            pc: 0,
            reason: format!("program spans {} devices, plan {}", program.devices, devices),
        }));
    }
    for (d, prog) in program.programs.iter().enumerate() {
        for (pc, instr) in prog.instrs.iter().enumerate() {
            if let Instr::Compute { op, .. } = instr {
                if *op >= g.ops.len() {
                    return Err(ExecError::Plan(PlanError::MalformedProgram {
                        device: d,
                        pc,
                        reason: format!("compute of unknown op {op}"),
                    }));
                }
            }
        }
    }
    if program.total_bytes() != plan.total_cost() {
        return Err(ExecError::MeterMismatch {
            metered: program.total_bytes(),
            plan: plan.total_cost(),
        });
    }
    // Slice every device's home shard of every producerless tensor
    // (validate_init: the same input contract as the serial interpreter).
    let produced = crate::graph::validate_init(g, init)?;
    let mut homes: Vec<Vec<Option<ShardBuf>>> = vec![vec![None; g.tensors.len()]; devices];
    for t in &g.tensors {
        if produced[t.id] {
            continue;
        }
        // Invariant: validate_init checked presence and length.
        let v = init[t.id].as_ref().expect("validated init value");
        for (d, home) in homes.iter_mut().enumerate() {
            let region = resident_region(&t.shape, &plan.tiles[t.id], d);
            home[t.id] = Some(ShardBuf::from_full(v, &t.shape, region));
        }
    }

    // One channel per device; every worker holds a sender to every peer.
    // The main thread keeps no sender alive, so a fully-drained exchange
    // can observe disconnection instead of blocking forever.
    let (txs, rxs): (Vec<Sender<Msg>>, Vec<Receiver<Msg>>) = (0..devices).map(|_| channel()).unzip();
    let sender_sets: Vec<Vec<Sender<Msg>>> = (0..devices).map(|_| txs.clone()).collect();
    drop(txs);
    let results: Vec<Result<DeviceOutcome, ExecError>> = std::thread::scope(|s| {
        let handles: Vec<_> = rxs
            .into_iter()
            .zip(sender_sets)
            .enumerate()
            .map(|(d, (rx, senders))| {
                let worker = Worker {
                    d,
                    k: plan.k,
                    devices,
                    g,
                    plan,
                    tasks: &tasks,
                    program,
                    senders: senders.clone(),
                    rx,
                    inbox: BTreeMap::new(),
                    home: std::mem::take(&mut homes[d]),
                    instr_bytes: 0,
                    payload_bytes: 0,
                    op_payload: vec![0; g.ops.len()],
                };
                s.spawn(move || {
                    let out = match catch_unwind(AssertUnwindSafe(|| worker.run())) {
                        Ok(r) => r,
                        Err(_) => Err(ExecError::Worker {
                            device: d,
                            reason: "worker thread panicked".into(),
                        }),
                    };
                    if out.is_err() {
                        // Poison every peer so nobody blocks on a message
                        // this worker will never send.
                        for tx in &senders {
                            let _ = tx.send(Msg {
                                from: d,
                                op: 0,
                                slot: POISON_SLOT,
                                pieces: Vec::new(),
                            });
                        }
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(d, h)| {
                h.join().unwrap_or_else(|_| {
                    Err(ExecError::Worker { device: d, reason: "worker thread panicked".into() })
                })
            })
            .collect()
    });
    // Report the root cause, preferring a real failure over the poison
    // aborts it cascaded into.
    let mut outcomes = Vec::with_capacity(devices);
    let mut root: Option<ExecError> = None;
    let mut cascade: Option<ExecError> = None;
    for r in results {
        match r {
            Ok(o) => outcomes.push(o),
            Err(e) => {
                let is_cascade =
                    matches!(&e, ExecError::Worker { reason, .. } if reason == POISON_REASON);
                let slot = if is_cascade { &mut cascade } else { &mut root };
                slot.get_or_insert(e);
            }
        }
    }
    if let Some(e) = root.or(cascade) {
        return Err(e);
    }

    // Reassemble every tensor, checking replica shards agree bitwise.
    let mut tensors = Vec::with_capacity(g.tensors.len());
    for t in &g.tensors {
        let n: usize = t.shape.iter().product();
        let mut full = vec![0.0f32; n];
        let mut seen = vec![false; n];
        let whole = Region::full(&t.shape);
        let mut diverged = false;
        for (d, o) in outcomes.iter().enumerate() {
            let buf = o.home[t.id].as_ref().ok_or_else(|| ExecError::Worker {
                device: d,
                reason: format!("tensor {} never materialized", t.name),
            })?;
            for_each_row(&whole, &buf.region, &buf.region, |db, sb, len| {
                for i in 0..len {
                    let v = buf.data[sb + i];
                    if seen[db + i] && full[db + i].to_bits() != v.to_bits() {
                        diverged = true;
                    }
                    full[db + i] = v;
                    seen[db + i] = true;
                }
            });
        }
        if diverged {
            return Err(ExecError::ReplicaDivergence { tensor: t.name.clone() });
        }
        // Invariant: split shards tile the tensor exactly (Theorem 2).
        debug_assert!(seen.iter().all(|&s| s), "uncovered elements of {}", t.name);
        tensors.push(full);
    }

    Ok(ExecReport {
        devices,
        tensors,
        instr_bytes: outcomes.iter().map(|o| o.instr_bytes).sum(),
        payload_bytes: outcomes.iter().map(|o| o.payload_bytes).sum(),
        op_payload_bytes: (0..g.ops.len())
            .map(|i| outcomes.iter().map(|o| o.op_payload[i]).sum())
            .collect(),
    })
}
