//! The threaded SPMD executor: run a lowered program on real tensors.
//!
//! One OS thread per device walks that device's [`Instr`] stream. The
//! collective instructions are the byte meter — each start adds its priced
//! wire volume to `instr_bytes`, which therefore sums to the plan's
//! Theorem-1 cost bit for bit (the lowering identity, re-asserted here at
//! entry). The *data* a collective realizes moves through
//! [`std::sync::mpsc`] channels at op granularity, in the three phases of
//! §5.2 that the shard schedule ([`ShardTask`]) prescribes:
//!
//! 1. **Ghost gather** — each input is fetched into the op's required
//!    layout: the receiver decomposes its required region over the
//!    tensor's home (plan) layout via [`gather_sources`]; senders run the
//!    *same* deterministic decomposition for every peer, so each side
//!    knows exactly which pieces to ship (the `AllGather` / `AllToAll`
//!    patterns) without negotiation.
//! 2. **Local compute** — the shared kernel library
//!    ([`crate::graph::apply_op`]) runs on the shard-local views; at
//!    reduce cuts the result is a full-extent partial sum.
//! 3. **Scatter-reduce** — the output moves to its home layout: each
//!    receiver's home region decomposes over the *produced* layout, and
//!    every piece is summed (in `f64`) over its reduce-bit contributor
//!    set — the devices that differ from the piece's owner only at the
//!    cuts where the op produced partials (the `ReduceScatter` /
//!    `SendRecv`-partial-exchange patterns, generalized to k cuts).
//!
//! Sends never block (unbounded channels) and receives only consume
//! messages a peer's earlier-or-equal op produced, so the aligned SPMD
//! streams make the exchange deadlock-free by the same induction the
//! event engine relies on; a worker that fails broadcasts a poison
//! message so its peers error out instead of blocking (except silent
//! kills and timeouts — those are discovered by the per-wait-site
//! watchdogs, see [`ExecOptions::deadline`] and `root_cause`'s
//! attribution argument). Because every
//! phase is deterministic — deterministic piece assignment, deterministic
//! contributor order, `f64` accumulation rounded once — replicated shards
//! are **bit-identical** across devices, which [`execute`] verifies while
//! reassembling full tensors (any divergence is a routing bug, reported
//! as [`ExecError::ReplicaDivergence`]).
//!
//! The channel payload volume is reported separately (`payload_bytes`,
//! and per op in `op_payload_bytes`): it is the §5.2 ghost-gather
//! *realization* of the conversions, which coincides with the collective
//! meter for single-cut plans (pinned by the property tests) but may
//! shortcut through nearer replicas — or pay the naive partial exchange —
//! on stacked cuts (docs/execution.md §Two meters).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::exec::{gather_sources, resident_region, Region, ShardTask};
use crate::graph::{apply_op_with, Graph, InterpError, KernelBackend, OpId, View};
use crate::lower::{CollectiveKind, Instr, LoweredProgram};
use crate::obs::{Metrics, Span, SpanContext, SpanKind, StepTrace, TraceBuf};
use crate::planner::{Plan, PlanError};
use crate::util::checksum::Fnv64;

use super::buf::{for_each_row, ShardBuf};
use super::fault::{FaultKind, FaultPlan, InjectedPanic, KILLED_REASON};
use super::pool::{StepCtx, WorkerPool};

/// Slot tag for output scatter-reduce messages (inputs use their index).
/// The canonical constant lives in [`crate::obs`] so spans and error
/// contexts share the convention.
pub(crate) const OUT_SLOT: u8 = crate::obs::OUT_SLOT;
/// Slot tag a failing worker broadcasts so peers error instead of block.
pub(crate) const POISON_SLOT: u8 = u8::MAX - 1;
/// Reason string of a cascade abort (a worker that stopped because a
/// peer poisoned it) — `execute` prefers reporting the root cause.
pub(crate) const POISON_REASON: &str = "peer worker aborted";

/// The pieces of one exchange: absolute region + dense `f32` payload.
type Pieces = Vec<(Region, Vec<f32>)>;

/// One inter-device message: every piece one sender contributes to one
/// exchange of one op, with an FNV-1a digest of the payload so wire
/// corruption surfaces as [`ExecError::Corrupt`] instead of silently
/// wrong numbers. The `seq` tag names the step the message belongs to:
/// worker threads are persistent ([`WorkerPool`]), so a failed step can
/// strand pieces in a channel, and the next step must be able to discard
/// them instead of pasting stale data.
pub(crate) struct Msg {
    pub(crate) from: usize,
    pub(crate) seq: u64,
    pub(crate) op: OpId,
    pub(crate) slot: u8,
    pub(crate) pieces: Pieces,
    pub(crate) sum: u64,
}

impl Msg {
    /// The poison broadcast of a failed worker: peers waiting on any
    /// message of step `seq` error out instead of blocking.
    pub(crate) fn poison(from: usize, seq: u64) -> Self {
        Msg { from, seq, op: 0, slot: POISON_SLOT, pieces: Vec::new(), sum: 0 }
    }
}

/// Payload digest of one message: piece count, per-piece length, and the
/// element bit patterns (regions are derived deterministically on both
/// sides, so only the data crosses the trust boundary).
fn checksum_pieces(pieces: &Pieces) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(pieces.len() as u64);
    for (_, data) in pieces {
        h.write_u64(data.len() as u64);
        for &x in data {
            h.write_f32(x);
        }
    }
    h.finish()
}

/// Knobs for one threaded execution ([`execute_with`]).
///
/// Construct with the builder-style setters:
///
/// ```
/// use std::time::Duration;
/// use soybean::spmd::{ExecOptions, FaultPlan};
///
/// let opts = ExecOptions::default()
///     .deadline(Duration::from_millis(500))
///     .fault_plan(FaultPlan::kill(1, 0));
/// assert_eq!(opts.deadline, Duration::from_millis(500));
/// ```
#[derive(Debug, Clone)]
pub struct ExecOptions {
    /// Watchdog deadline per wait site: the longest a worker blocks for
    /// any single expected exchange message before reporting
    /// [`ExecError::Timeout`]. Every wait is supervised, so an execution
    /// with a stalled or dead peer terminates within a small multiple of
    /// this instead of deadlocking.
    pub deadline: Duration,
    /// Fault-injection plan; `None` (the default) makes every hook a
    /// single branch — the [`execute`] fast path. `Arc`-shared so that
    /// clones of the options (retries under
    /// [`super::execute_with_recovery`], per-step contexts in a
    /// [`WorkerPool`]) see one arming state: a transient fault that fired
    /// once stays fired.
    pub faults: Option<Arc<FaultPlan>>,
    /// Span tracing (`false` by default): when on, every worker records
    /// per-instruction wall-clock spans into a private buffer, drained
    /// into `ExecReport::trace` at the step barrier. Off, every trace
    /// site reduces to one branch on a `None` — the same discipline as
    /// the fault hooks, pinned by the `obs_micro` overhead gate.
    pub trace: bool,
    /// Metrics registry handle; when set, the pool counts
    /// `exec.steps` / `exec.failures` / `exec.instr_bytes` and observes
    /// `exec.step_seconds`, and [`super::execute_with_recovery`] counts
    /// `recover.retries` / `recover.replans` through the same handle.
    pub metrics: Option<Metrics>,
    /// Kernel backend every worker dispatches compute through
    /// ([`KernelBackend::Fast`] by default). The differential harness pins
    /// [`KernelBackend::Naive`] on both sides to isolate partitioning bugs
    /// from kernel bugs, and pins `Fast` on both sides to oracle the fast
    /// path under sharded extents.
    pub backend: KernelBackend,
    /// Pipeline stage this step executes as (0 for plain single-stage
    /// steps). Stamped onto every recorded [`Span`] so multi-stage traces
    /// keep per-stage attribution — set by
    /// [`crate::spmd::try_execute_strategy`] when it runs a strategy's
    /// cells.
    pub stage: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        // Generous enough that no healthy exchange on a loaded CI runner
        // ever trips it; chaos suites shrink it to keep trials fast.
        ExecOptions {
            deadline: Duration::from_secs(60),
            faults: None,
            trace: false,
            metrics: None,
            backend: KernelBackend::default(),
            stage: 0,
        }
    }
}

impl ExecOptions {
    /// Set the per-wait-site watchdog deadline (builder style).
    #[must_use]
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = deadline;
        self
    }

    /// Arm a fault-injection plan (builder style). The plan is wrapped in
    /// an [`Arc`] so every clone of these options shares its arming state.
    #[must_use]
    pub fn fault_plan(mut self, faults: FaultPlan) -> Self {
        self.faults = Some(Arc::new(faults));
        self
    }

    /// Toggle span tracing (builder style).
    #[must_use]
    pub fn trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Attach a metrics registry handle (builder style). Clones of the
    /// options share the registry, so counters survive retries.
    #[must_use]
    pub fn metrics(mut self, metrics: Metrics) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Pin the kernel backend (builder style).
    #[must_use]
    pub fn backend(mut self, backend: KernelBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Tag the step with a pipeline stage (builder style); every span the
    /// step records carries it.
    #[must_use]
    pub fn stage(mut self, stage: usize) -> Self {
        self.stage = stage;
        self
    }
}

/// Structured executor failure.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecError {
    /// The plan or program is malformed (validation, shard schedule).
    Plan(PlanError),
    /// The initial values are missing or mis-sized (same checks as the
    /// serial interpreter's).
    Input(InterpError),
    /// The program's instruction bytes do not sum to the plan's Theorem-1
    /// cost — the one-theory contract the executor refuses to run without.
    MeterMismatch {
        /// Bytes the program's collective instructions sum to.
        metered: u64,
        /// The plan's Theorem-1 total.
        plan: u64,
    },
    /// Two devices hold bitwise-different values for the same element of
    /// a replicated shard — a conversion-routing bug, never tolerated.
    ReplicaDivergence {
        /// Name of the diverging tensor.
        tensor: String,
    },
    /// A worker thread failed (kernel panic, peer abort, closed channel,
    /// injected kill).
    Worker {
        /// Device whose worker failed first.
        device: usize,
        /// What happened.
        reason: String,
    },
    /// A watchdog deadline expired: `device` gave up waiting for an
    /// exchange message — the structured replacement for an eternal
    /// `recv()` block, naming the stalled peer and instruction.
    Timeout {
        /// Device that gave up waiting.
        device: usize,
        /// Op whose exchange stalled (the instruction site).
        op: OpId,
        /// Input slot of the exchange (`u8::MAX` = the output scatter).
        slot: u8,
        /// Peer the message was expected from (the stalled device).
        peer: usize,
        /// How long the watchdog waited, in milliseconds.
        waited_ms: u64,
        /// When tracing is on: the failing worker's last completed span,
        /// so the root cause carries timing evidence. `None` untraced.
        context: Option<SpanContext>,
    },
    /// A received payload failed its FNV-1a integrity check — bits
    /// changed between the sender's digest and the receiver's.
    Corrupt {
        /// Device that detected the corruption (the receiver).
        device: usize,
        /// Op the corrupted exchange belonged to.
        op: OpId,
        /// Device the message came from.
        from: usize,
        /// When tracing is on: the receiver's last completed span before
        /// the corrupt payload arrived. `None` untraced.
        context: Option<SpanContext>,
    },
    /// A recovery checkpoint failed its checksum at restore time
    /// ([`super::Checkpoint::verify`]).
    CheckpointCorrupt {
        /// Step the checkpoint claimed to capture.
        step: u64,
    },
    /// A shard-buffer operation was handed a malformed region or payload
    /// (wrong rank, out of the buffer's bounds, or a length mismatch) —
    /// reported by [`super::ShardBuf::try_paste`] /
    /// [`super::ShardBuf::try_extract`] instead of an index panic.
    Shard {
        /// What was malformed.
        reason: String,
    },
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ExecError::Plan(e) => write!(f, "{e}"),
            ExecError::Input(e) => write!(f, "{e}"),
            ExecError::MeterMismatch { metered, plan } => {
                write!(f, "program meters {metered} B but the plan's Theorem-1 cost is {plan} B")
            }
            ExecError::ReplicaDivergence { tensor } => {
                write!(f, "replicated shards of `{tensor}` diverged between devices")
            }
            ExecError::Worker { device, reason } => {
                write!(f, "worker {device} failed: {reason}")
            }
            ExecError::Timeout { device, op, slot, peer, waited_ms, context } => {
                let phase = if *slot == OUT_SLOT {
                    "output scatter".to_string()
                } else {
                    format!("input slot {slot}")
                };
                write!(
                    f,
                    "device {device} timed out after {waited_ms} ms waiting on device {peer} \
                     for op {op} ({phase})"
                )?;
                if let Some(ctx) = context {
                    write!(f, "; {ctx}")?;
                }
                Ok(())
            }
            ExecError::Corrupt { device, op, from, context } => {
                write!(
                    f,
                    "device {device} received a corrupt payload from device {from} for op {op}"
                )?;
                if let Some(ctx) = context {
                    write!(f, "; {ctx}")?;
                }
                Ok(())
            }
            ExecError::CheckpointCorrupt { step } => {
                write!(f, "checkpoint of step {step} failed its checksum at restore")
            }
            ExecError::Shard { reason } => write!(f, "malformed shard operation: {reason}"),
        }
    }
}

impl std::error::Error for ExecError {}

impl From<PlanError> for ExecError {
    fn from(e: PlanError) -> Self {
        ExecError::Plan(e)
    }
}

impl From<InterpError> for ExecError {
    fn from(e: InterpError) -> Self {
        ExecError::Input(e)
    }
}

/// Result of one threaded execution.
#[derive(Debug, Clone)]
pub struct ExecReport {
    /// Worker-thread count (`2^k`).
    pub devices: usize,
    /// Every tensor of the graph, reassembled from the devices' home
    /// shards (indexed by `TensorId`) — compare against
    /// [`crate::graph::eval_serial`].
    pub tensors: Vec<Vec<f32>>,
    /// Bytes metered from the executed collective instructions; equals
    /// the plan's Theorem-1 total bit for bit (checked at entry).
    pub instr_bytes: u64,
    /// `f32` payload bytes actually shipped between worker threads (the
    /// §5.2 ghost-gather realization volume).
    pub payload_bytes: u64,
    /// Payload bytes attributed to each op's exchanges (indexed by
    /// `OpId`); sums to `payload_bytes`.
    pub op_payload_bytes: Vec<u64>,
    /// Measured spans from every worker, merged and time-ordered —
    /// `Some` iff the step ran with [`ExecOptions::trace`] on. Feed it to
    /// [`fn@crate::obs::calibrate`] or
    /// [`crate::obs::measured_trace_json`].
    pub trace: Option<StepTrace>,
}

/// What one worker thread hands back.
pub(crate) struct DeviceOutcome {
    home: Vec<Option<ShardBuf>>,
    instr_bytes: u64,
    payload_bytes: u64,
    op_payload: Vec<u64>,
    spans: Vec<Span>,
}

/// The per-step execution state of one device. A persistent pool thread
/// constructs one of these per dispatched step (borrowing the step's
/// [`StepCtx`] and the thread's own channels) and consumes it in
/// [`Worker::run`].
pub(crate) struct Worker<'a> {
    d: usize,
    k: usize,
    devices: usize,
    g: &'a Graph,
    plan: &'a Plan,
    tasks: &'a [ShardTask],
    program: &'a LoweredProgram,
    senders: &'a [Sender<Msg>],
    rx: &'a Receiver<Msg>,
    /// Step tag: stamped on every sent [`Msg`]; receives discard other
    /// steps' strays (see [`Msg`]).
    seq: u64,
    inbox: BTreeMap<(OpId, u8, usize), Pieces>,
    home: Vec<Option<ShardBuf>>,
    instr_bytes: u64,
    payload_bytes: u64,
    op_payload: Vec<u64>,
    /// Watchdog deadline per wait site ([`ExecOptions::deadline`]).
    deadline: Duration,
    /// Kernel backend for every compute dispatch
    /// ([`ExecOptions::backend`]).
    backend: KernelBackend,
    /// Armed fault-injection sites; `None` on the production path.
    faults: Option<&'a FaultPlan>,
    /// Span buffer; `Some` iff [`ExecOptions::trace`] — every trace site
    /// is one branch on this option, so the untraced path stays free.
    trace: Option<TraceBuf>,
    /// Pipeline stage tag stamped on every recorded span
    /// ([`ExecOptions::stage`]; 0 for single-stage steps).
    stage: usize,
}

impl<'a> Worker<'a> {
    /// Wire up device `d`'s execution state for one step of `ctx`.
    /// `epoch` is the step's shared trace origin (captured once by the
    /// pool before dispatch so all workers measure on one clock).
    pub(crate) fn for_step(
        d: usize,
        ctx: &'a StepCtx,
        senders: &'a [Sender<Msg>],
        rx: &'a Receiver<Msg>,
        seq: u64,
        home: Vec<Option<ShardBuf>>,
        epoch: Instant,
    ) -> Self {
        Worker {
            d,
            k: ctx.plan.k,
            devices: ctx.plan.devices(),
            g: &ctx.g,
            plan: &ctx.plan,
            tasks: &ctx.tasks,
            program: &ctx.program,
            senders,
            rx,
            seq,
            inbox: BTreeMap::new(),
            home,
            instr_bytes: 0,
            payload_bytes: 0,
            op_payload: vec![0; ctx.g.ops.len()],
            deadline: ctx.opts.deadline,
            backend: ctx.opts.backend,
            faults: ctx.opts.faults.as_deref(),
            trace: ctx.opts.trace.then(|| TraceBuf::new(epoch)),
            stage: ctx.opts.stage,
        }
    }

    pub(crate) fn run(mut self) -> Result<DeviceOutcome, ExecError> {
        let program = self.program;
        let d = self.d;
        for instr in &program.programs[d].instrs {
            match instr {
                Instr::Compute { op, .. } => self.compute(*op)?,
                Instr::Wait { .. } => {}
                // Collective starts: the Theorem-1 byte meter. The data
                // the collective realizes moves in the op-granular
                // exchanges of `compute` (module docs).
                other => {
                    let bytes = other.bytes();
                    self.instr_bytes += bytes;
                    if self.trace.is_some() {
                        self.meter_span(instr, bytes);
                    }
                }
            }
        }
        Ok(DeviceOutcome {
            home: self.home,
            instr_bytes: self.instr_bytes,
            payload_bytes: self.payload_bytes,
            op_payload: self.op_payload,
            spans: self.trace.map_or_else(Vec::new, TraceBuf::into_spans),
        })
    }

    /// Record the zero-duration byte marker for one metered collective
    /// instruction: kind/op/tensor from the transfer group's metadata,
    /// bytes from the instruction — so the trace's collective payloads
    /// sum to the Theorem-1 meter bit for bit. Only called when tracing.
    fn meter_span(&mut self, instr: &Instr, bytes: u64) {
        let Some(gid) = instr.started_gid() else { return };
        let m = &self.program.transfers[gid];
        let kind = match m.kind {
            CollectiveKind::AllGather => SpanKind::AllGather,
            CollectiveKind::ReduceScatter => SpanKind::ReduceScatter,
            CollectiveKind::AllToAll => SpanKind::AllToAll,
            CollectiveKind::SendRecv => SpanKind::SendRecv,
        };
        // Input gathers meter at the consuming slot; output conversions
        // at the scatter side — the same (op, slot) key the wall-clock
        // spans use.
        let slot = self.g.ops[m.op]
            .inputs
            .iter()
            .position(|&t| t == m.tensor)
            .map_or(OUT_SLOT, |s| s as u8);
        let tb = self.trace.as_mut().expect("meter_span is gated on tracing");
        let now = tb.now();
        tb.push(Span {
            device: self.d,
            op: m.op,
            kind,
            slot,
            gid: Some(gid),
            start_s: now,
            end_s: now,
            bytes,
            stage: self.stage,
        });
    }

    /// Block until the `(op, slot)` message from `from` is available —
    /// under the watchdog: every wait site gets [`Self::deadline`] of
    /// patience in total, after which the worker reports the stalled
    /// peer+instruction as [`ExecError::Timeout`] instead of deadlocking.
    fn recv_from(
        &mut self,
        op: OpId,
        slot: u8,
        from: usize,
    ) -> Result<Pieces, ExecError> {
        let expiry = Instant::now() + self.deadline;
        // Trace entry stamp (one branch untraced); the wait span closes
        // when the expected message is consumed below.
        let t0 = self.trace.as_ref().map(TraceBuf::now);
        let timeout = |d: usize, deadline: Duration, context: Option<SpanContext>| {
            ExecError::Timeout {
                device: d,
                op,
                slot,
                peer: from,
                waited_ms: deadline.as_millis() as u64,
                context,
            }
        };
        loop {
            if let Some(pieces) = self.inbox.remove(&(op, slot, from)) {
                if let Some(t0) = t0 {
                    let bytes: u64 = pieces.iter().map(|(r, _)| r.elements() * 4).sum();
                    let tb = self.trace.as_mut().expect("t0 implies tracing");
                    let end = tb.now();
                    tb.push(Span {
                        device: self.d,
                        op,
                        kind: SpanKind::Wait,
                        slot,
                        gid: None,
                        start_s: t0,
                        end_s: end,
                        bytes,
                        stage: self.stage,
                    });
                }
                return Ok(pieces);
            }
            let remaining = expiry.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                let ctx = self.trace.as_ref().and_then(TraceBuf::last_context);
                return Err(timeout(self.d, self.deadline, ctx));
            }
            match self.rx.recv_timeout(remaining) {
                Ok(m) if m.seq != self.seq => {
                    // A stray from an earlier step, stranded when that
                    // step failed mid-exchange on this persistent worker.
                    // Steps are barriers (the pool collects every device's
                    // result before dispatching the next step), so a
                    // mismatched seq is always stale — discard it. This
                    // check runs before the poison check: a dead step's
                    // poison must not kill a healthy one.
                }
                Ok(m) if m.slot == POISON_SLOT => {
                    return Err(ExecError::Worker { device: m.from, reason: POISON_REASON.into() })
                }
                Ok(m) => {
                    // Integrity gate on every received payload: a digest
                    // mismatch is structured corruption, not a mystery
                    // divergence three ops later.
                    if checksum_pieces(&m.pieces) != m.sum {
                        return Err(ExecError::Corrupt {
                            device: self.d,
                            op: m.op,
                            from: m.from,
                            context: self.trace.as_ref().and_then(TraceBuf::last_context),
                        });
                    }
                    self.inbox.insert((m.op, m.slot, m.from), m.pieces);
                }
                Err(RecvTimeoutError::Timeout) => {
                    let ctx = self.trace.as_ref().and_then(TraceBuf::last_context);
                    return Err(timeout(self.d, self.deadline, ctx));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(ExecError::Worker {
                        device: self.d,
                        reason: format!(
                            "channel closed while waiting for op {op} slot {slot} from {from}"
                        ),
                    })
                }
            }
        }
    }

    fn send(&mut self, to: usize, op: OpId, slot: u8, mut pieces: Pieces) {
        let bytes: u64 = pieces.iter().map(|(r, _)| r.elements() * 4).sum();
        self.payload_bytes += bytes;
        self.op_payload[op] += bytes;
        let t0 = self.trace.as_ref().map(TraceBuf::now);
        // Digest before injection: a corrupted payload carries the clean
        // sum, exactly like wire corruption under a real transport.
        let sum = checksum_pieces(&pieces);
        if let Some(fp) = self.faults {
            match fp.fire_send(self.d, op) {
                Some(FaultKind::DropMessage) => return,
                Some(FaultKind::DelayMessage { ms }) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(FaultKind::CorruptPayload) => {
                    if let Some((_, data)) = pieces.iter_mut().find(|(_, d)| !d.is_empty()) {
                        data[0] = f32::from_bits(data[0].to_bits() ^ 0x0040_0000);
                    }
                }
                _ => {}
            }
        }
        // A send only fails if the receiver died; the poison/abort path
        // reports that failure, so the result here is ignorable.
        let _ = self.senders[to].send(Msg { from: self.d, seq: self.seq, op, slot, pieces, sum });
        if let Some(t0) = t0 {
            let tb = self.trace.as_mut().expect("t0 implies tracing");
            let end = tb.now();
            tb.push(Span {
                device: self.d,
                op,
                kind: SpanKind::Send,
                slot,
                gid: None,
                start_s: t0,
                end_s: end,
                bytes,
                stage: self.stage,
            });
        }
    }

    /// §5.2 phase 1: assemble one input in the op's required layout.
    fn gather_input(&mut self, op: OpId, slot: usize, t: usize) -> Result<ShardBuf, ExecError> {
        let (g, plan, tasks) = (self.g, self.plan, self.tasks);
        let (devices, d) = (self.devices, self.d);
        let shape = &g.tensors[t].shape;
        let req = &tasks[op].required_ins[slot];
        let home_seq = &plan.tiles[t];
        if req == home_seq {
            // The op's aligned form wants the tensor exactly as it lives:
            // nothing moves anywhere (every device's required region is
            // its resident region), so skip the decompositions entirely.
            // Invariant: home shards exist before any consumer.
            return Ok(self.home[t].as_ref().expect("home shard materialized").clone());
        }
        // Send every peer the pieces it will fetch from this device —
        // the peer runs the identical decomposition, so the piece lists
        // agree without negotiation.
        for e in 0..devices {
            if e == d {
                continue;
            }
            let want_e = resident_region(shape, req, e);
            let mut pieces = Vec::new();
            for p in gather_sources(shape, home_seq, devices, e, &want_e) {
                if p.src == d {
                    // Invariant: home shards exist before any consumer
                    // (streams are topologically ordered).
                    let buf = self.home[t].as_ref().expect("home shard materialized");
                    let data = buf.extract(&p.region);
                    pieces.push((p.region, data));
                }
            }
            if !pieces.is_empty() {
                self.send(e, op, slot as u8, pieces);
            }
        }
        // Fetch this device's own pieces: local copies are free, remote
        // ones arrive tagged (op, slot, src).
        let want = resident_region(shape, req, d);
        let pieces = gather_sources(shape, home_seq, devices, d, &want);
        let mut buf = ShardBuf::zeros(want);
        let mut expected: BTreeSet<usize> = BTreeSet::new();
        for p in &pieces {
            if p.src == d {
                let homebuf = self.home[t].as_ref().expect("home shard materialized");
                let data = homebuf.extract(&p.region);
                buf.paste(&p.region, &data);
            } else {
                expected.insert(p.src);
            }
        }
        for src in expected {
            for (cell, data) in self.recv_from(op, slot as u8, src)? {
                // A remote piece crossed a trust boundary: the checked
                // paste turns a malformed region into a structured
                // [`ExecError::Shard`] instead of an index panic.
                buf.try_paste(&cell, &data)?;
            }
        }
        Ok(buf)
    }

    /// The devices holding *distinct* partials of the piece owned by
    /// `src`: `src` with every combination of bits at the reduce cuts.
    fn contributors(src: usize, rbits: &[usize]) -> Vec<usize> {
        let mut out = Vec::with_capacity(1 << rbits.len());
        for combo in 0..(1usize << rbits.len()) {
            let mut c = src;
            for (bi, &bit) in rbits.iter().enumerate() {
                c = (c & !(1usize << bit)) | (((combo >> bi) & 1) << bit);
            }
            out.push(c);
        }
        out
    }

    /// §5.2 phase 3: move the produced shard (partials at reduce cuts)
    /// into the tensor's home layout, summing contributor pieces in f64.
    fn scatter_output(&mut self, op: OpId, out_buf: ShardBuf) -> Result<(), ExecError> {
        let (g, plan, tasks) = (self.g, self.plan, self.tasks);
        let (devices, d, k) = (self.devices, self.d, self.k);
        let z = g.ops[op].outputs[0];
        let zshape = &g.tensors[z].shape;
        let produced = &tasks[op].produced;
        let rbits: Vec<usize> = tasks[op].reduce_cuts.iter().map(|&j| k - 1 - j).collect();
        let home_seq = &plan.tiles[z];

        // Send phase: ship every piece of every peer's home region this
        // device contributes a partial (or the value) to.
        for e in 0..devices {
            if e == d {
                continue;
            }
            let want_e = resident_region(zshape, home_seq, e);
            let mut pieces = Vec::new();
            for p in gather_sources(zshape, produced, devices, e, &want_e) {
                if Self::contributors(p.src, &rbits).contains(&d) {
                    pieces.push((p.region.clone(), out_buf.extract(&p.region)));
                }
            }
            if !pieces.is_empty() {
                self.send(e, op, OUT_SLOT, pieces);
            }
        }

        // Receive phase: accumulate each piece over its contributor set,
        // in deterministic (piece, combo) order so replicas stay
        // bit-identical across devices.
        let want = resident_region(zshape, home_seq, d);
        let mut acc = vec![0.0f64; want.elements() as usize];
        let pieces = gather_sources(zshape, produced, devices, d, &want);
        let mut expected: BTreeSet<usize> = BTreeSet::new();
        for p in &pieces {
            for c in Self::contributors(p.src, &rbits) {
                if c != d {
                    expected.insert(c);
                }
            }
        }
        let mut incoming: BTreeMap<usize, (Pieces, usize)> = BTreeMap::new();
        for src in expected {
            incoming.insert(src, (self.recv_from(op, OUT_SLOT, src)?, 0));
        }
        for p in &pieces {
            for c in Self::contributors(p.src, &rbits) {
                if c == d {
                    for_each_row(&want, &out_buf.region, &p.region, |db, sb, len| {
                        for i in 0..len {
                            acc[db + i] += out_buf.data[sb + i] as f64;
                        }
                    });
                } else {
                    let entry = incoming
                        .get_mut(&c)
                        .expect("contributor enumerated in the expected set");
                    // Invariant: sender and receiver enumerate the same
                    // gather decomposition in the same order.
                    let (cell, data) = &entry.0[entry.1];
                    assert_eq!(cell, &p.region, "piece stream misaligned with sender");
                    for_each_row(&want, cell, cell, |db, sb, len| {
                        for i in 0..len {
                            acc[db + i] += data[sb + i] as f64;
                        }
                    });
                    entry.1 += 1;
                }
            }
        }
        for (src, (list, cursor)) in &incoming {
            // Invariant: the sender shipped exactly the pieces we summed.
            assert_eq!(*cursor, list.len(), "unconsumed pieces from device {src}");
        }
        let data: Vec<f32> = acc.into_iter().map(|v| v as f32).collect();
        self.home[z] = Some(ShardBuf { region: want, data });
        Ok(())
    }

    fn compute(&mut self, op: OpId) -> Result<(), ExecError> {
        // Compute-site injection: `Panic` exercises the real unwind +
        // poison machinery; `Kill` models device loss — the worker stops
        // silently, and only the peers' watchdogs can discover it.
        if let Some(fp) = self.faults {
            match fp.fire_compute(self.d, op) {
                Some(FaultKind::Panic) => std::panic::panic_any(InjectedPanic),
                Some(FaultKind::Kill) => {
                    return Err(ExecError::Worker { device: self.d, reason: KILLED_REASON.into() })
                }
                _ => {}
            }
        }
        let g = self.g;
        let n_ins = g.ops[op].inputs.len();
        let mut local_ins = Vec::with_capacity(n_ins);
        for slot in 0..n_ins {
            let t = g.ops[op].inputs[slot];
            local_ins.push(self.gather_input(op, slot, t)?);
        }
        let zshape = &g.tensors[g.ops[op].outputs[0]].shape;
        let out_region = resident_region(zshape, &self.tasks[op].produced, self.d);
        let views: Vec<View<'_>> = local_ins
            .iter()
            .map(|b| View { data: &b.data, shape: &b.region.shape, offset: &b.region.offset })
            .collect();
        let t0 = self.trace.as_ref().map(TraceBuf::now);
        let data = catch_unwind(AssertUnwindSafe(|| {
            apply_op_with(self.backend, g, &g.ops[op], &views, &out_region.shape)
        }))
        .map_err(|_| ExecError::Worker {
            device: self.d,
            reason: format!("kernel for op `{}` panicked", g.ops[op].name),
        })?;
        if let Some(t0) = t0 {
            let tb = self.trace.as_mut().expect("t0 implies tracing");
            let end = tb.now();
            tb.push(Span {
                device: self.d,
                op,
                kind: SpanKind::Compute,
                slot: 0,
                gid: None,
                start_s: t0,
                end_s: end,
                bytes: 0,
                stage: self.stage,
            });
        }
        self.scatter_output(op, ShardBuf { region: out_region, data })
    }
}

/// Execute `program` (the lowering of `(g, plan)`) on `2^k` worker
/// threads with real `f32` shard buffers.
///
/// `init` is the same producerless-tensor value vector the serial
/// interpreter takes ([`crate::graph::seed_values`] shapes it); every
/// device slices its home shards from these arrays. On success the report
/// carries every tensor reassembled (with the replica bit-equality check)
/// plus the two byte meters.
///
/// # Examples
///
/// ```
/// use soybean::graph::{eval_serial, max_rel_err, seed_values};
/// use soybean::lower::try_lower;
/// use soybean::models::{mlp, MlpConfig};
/// use soybean::planner::try_k_cut;
/// use soybean::sim::SimConfig;
/// use soybean::spmd::execute;
///
/// let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
/// let plan = try_k_cut(&g, 1).unwrap();
/// let program = try_lower(&g, &plan, &SimConfig::default()).unwrap();
/// let init = seed_values(&g, 7);
/// let report = execute(&g, &plan, &program, &init).unwrap();
/// // Observed collective traffic is exactly the plan's Theorem-1 total.
/// assert_eq!(report.instr_bytes, plan.total_cost());
/// // And the numbers match the serial interpreter.
/// let serial = eval_serial(&g, &init).unwrap();
/// for t in &g.tensors {
///     assert!(max_rel_err(&report.tensors[t.id], &serial[t.id]) <= 1e-5);
/// }
/// ```
pub fn execute(
    g: &Graph,
    plan: &Plan,
    program: &LoweredProgram,
    init: &[Option<Vec<f32>>],
) -> Result<ExecReport, ExecError> {
    execute_with(g, plan, program, init, &ExecOptions::default())
}

/// Pick the root cause among the errors a run produced.
///
/// When a fault fires, several workers usually fail: the faulted one, the
/// peers its poison reached, and — for silent faults like a dropped
/// message or a killed worker — the peers whose watchdogs expired. Which
/// worker's error reaches the main thread first is a scheduling race, so
/// the report is chosen by *rank*, not arrival:
///
/// 1. **rank 0** — real failures (kernel panics, injected kills,
///    corruption, shard errors): the fault site itself.
/// 2. **rank 1** — watchdog timeouts: evidence of a stall, but possibly
///    several hops downstream of it.
/// 3. **rank 2** — poison cascades: pure echo, never the cause.
///
/// Within a rank, ties break on `(op, slot, device)`. For timeouts this
/// is not arbitrary: each phase sends before it receives, so a stall
/// propagates to strictly later `(op, slot)` wait sites — the minimal
/// timeout names the earliest stalled exchange, i.e. the true site.
/// This only holds because timeouts do not poison (the spawn closure):
/// all stalled workers get to report their own wait site, and the
/// minimum is taken over the full set rather than whichever deadline
/// happened to expire first.
pub(crate) fn root_cause(errors: Vec<ExecError>) -> Option<ExecError> {
    fn key(e: &ExecError) -> (u8, usize, u8, usize) {
        match e {
            ExecError::Worker { device, reason } if reason == POISON_REASON => {
                (2, 0, 0, *device)
            }
            ExecError::Timeout { device, op, slot, .. } => (1, *op, *slot, *device),
            ExecError::Corrupt { device, op, .. } => (0, *op, 0, *device),
            ExecError::Worker { device, .. } => (0, 0, 0, *device),
            _ => (0, 0, 0, 0),
        }
    }
    errors.into_iter().min_by_key(key)
}

/// Whether a worker failure must stay *silent* (no poison broadcast).
///
/// Two failure classes must NOT poison their peers:
///
/// - An injected kill is silent device loss — a machine that lost power
///   sends nothing, so the peers' watchdogs, not a courtesy broadcast,
///   must discover it.
/// - A timeout: the stall has already spread, so the peers' deadlines
///   expire near-simultaneously with ours — poisoning here races those
///   expiries and can convert the *true* stall site's timeout into a
///   cascade, corrupting root-cause attribution (caught by
///   tools/proto/fault_mirror.py). Every wait is supervised, so nobody
///   needs the poison to terminate.
pub(crate) fn is_silent_failure(out: &Result<DeviceOutcome, ExecError>) -> bool {
    matches!(out, Err(ExecError::Timeout { .. }))
        || matches!(out, Err(ExecError::Worker { reason, .. }) if reason == KILLED_REASON)
}

/// Reassemble every tensor from the devices' home shards, checking that
/// replicated shards agree bitwise, and sum the byte meters — the tail
/// half of a step, shared by the transient [`execute_with`] path and the
/// persistent [`WorkerPool`]. `traced` mirrors [`ExecOptions::trace`]:
/// when on, the per-worker span buffers are merged into the report.
pub(crate) fn reassemble(
    g: &Graph,
    outcomes: &[DeviceOutcome],
    traced: bool,
) -> Result<ExecReport, ExecError> {
    let mut tensors = Vec::with_capacity(g.tensors.len());
    for t in &g.tensors {
        let n: usize = t.shape.iter().product();
        let mut full = vec![0.0f32; n];
        let mut seen = vec![false; n];
        let whole = Region::full(&t.shape);
        let mut diverged = false;
        for (d, o) in outcomes.iter().enumerate() {
            let buf = o.home[t.id].as_ref().ok_or_else(|| ExecError::Worker {
                device: d,
                reason: format!("tensor {} never materialized", t.name),
            })?;
            for_each_row(&whole, &buf.region, &buf.region, |db, sb, len| {
                for i in 0..len {
                    let v = buf.data[sb + i];
                    if seen[db + i] && full[db + i].to_bits() != v.to_bits() {
                        diverged = true;
                    }
                    full[db + i] = v;
                    seen[db + i] = true;
                }
            });
        }
        if diverged {
            return Err(ExecError::ReplicaDivergence { tensor: t.name.clone() });
        }
        // Invariant: split shards tile the tensor exactly (Theorem 2).
        debug_assert!(seen.iter().all(|&s| s), "uncovered elements of {}", t.name);
        tensors.push(full);
    }

    Ok(ExecReport {
        devices: outcomes.len(),
        tensors,
        instr_bytes: outcomes.iter().map(|o| o.instr_bytes).sum(),
        payload_bytes: outcomes.iter().map(|o| o.payload_bytes).sum(),
        op_payload_bytes: (0..g.ops.len())
            .map(|i| outcomes.iter().map(|o| o.op_payload[i]).sum())
            .collect(),
        trace: traced
            .then(|| StepTrace::merge(outcomes.iter().map(|o| o.spans.clone()).collect())),
    })
}

/// [`execute`] with explicit [`ExecOptions`]: a watchdog deadline and an
/// optional fault-injection plan. The default path (`faults: None`)
/// reduces every hook to one branch on a `None`, so `execute` stays as
/// fast as before the fault-tolerance layer existed — pinned by the
/// `exec_micro` bench against the BENCH_exec baseline.
///
/// This is the one-shot convenience path: it validates the step into a
/// [`StepCtx`], spins up a transient [`WorkerPool`], runs the single
/// step, and tears the pool down. Callers executing the same program
/// repeatedly (serving, training loops) should hold a [`WorkerPool`] —
/// or a [`crate::serve::ServeEngine`] — so the worker threads stay warm
/// across steps.
pub fn execute_with(
    g: &Graph,
    plan: &Plan,
    program: &LoweredProgram,
    init: &[Option<Vec<f32>>],
    opts: &ExecOptions,
) -> Result<ExecReport, ExecError> {
    let ctx =
        Arc::new(StepCtx::try_new(g.clone(), plan.clone(), program.clone(), opts.clone())?);
    let mut pool = WorkerPool::spawn(ctx.devices());
    pool.run_step(&ctx, init)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poison(device: usize) -> ExecError {
        ExecError::Worker { device, reason: POISON_REASON.into() }
    }

    fn timeout(device: usize, op: OpId, slot: u8) -> ExecError {
        ExecError::Timeout { device, op, slot, peer: 0, waited_ms: 100, context: None }
    }

    /// The PR-5 contract, now explicit: a real failure beats the poison
    /// cascades it caused, regardless of arrival order.
    #[test]
    fn root_cause_prefers_real_failure_over_poison() {
        let real = ExecError::Worker { device: 2, reason: "kernel for op `mm` panicked".into() };
        let picked = root_cause(vec![poison(0), poison(1), real.clone(), poison(3)]);
        assert_eq!(picked, Some(real));
    }

    /// Full rank ordering: real failure > timeout > poison cascade.
    #[test]
    fn root_cause_ranks_real_over_timeout_over_poison() {
        let real = ExecError::Corrupt { device: 1, op: 3, from: 0, context: None };
        let picked =
            root_cause(vec![poison(0), timeout(2, 1, 0), real.clone(), timeout(3, 2, OUT_SLOT)]);
        assert_eq!(picked, Some(real));
        // Without a real failure, a timeout beats the cascades.
        let picked = root_cause(vec![poison(0), timeout(2, 1, 0), poison(3)]);
        assert_eq!(picked, Some(timeout(2, 1, 0)));
        // All cascades: report one rather than nothing.
        assert_eq!(root_cause(vec![poison(3), poison(1)]), Some(poison(1)));
        assert_eq!(root_cause(Vec::new()), None);
    }

    /// Stalls propagate to strictly later `(op, slot)` wait sites, so the
    /// minimal timeout names the true stalled exchange — pin the tiebreak.
    #[test]
    fn root_cause_timeout_tiebreak_is_op_slot_device() {
        let earliest = timeout(3, 1, 0);
        let picked = root_cause(vec![
            timeout(0, 2, 0),         // later op
            timeout(1, 1, OUT_SLOT),  // same op, later phase
            earliest.clone(),
            timeout(5, 1, 0),         // same site, higher device
        ]);
        assert_eq!(picked, Some(earliest));
    }

    /// Every variant formats: `Display` names the parties and the site,
    /// `Debug` round-trips the variant name.
    #[test]
    fn exec_error_display_and_debug_cover_every_variant() {
        let cases: Vec<(ExecError, &str, &str)> = vec![
            (ExecError::Plan(PlanError::Infeasible), "no feasible", "Plan"),
            (
                ExecError::Input(InterpError::MissingInput { tensor: "x".into() }),
                "x",
                "Input",
            ),
            (
                ExecError::MeterMismatch { metered: 8, plan: 16 },
                "meters 8 B but the plan's Theorem-1 cost is 16 B",
                "MeterMismatch",
            ),
            (
                ExecError::ReplicaDivergence { tensor: "w1".into() },
                "replicated shards of `w1` diverged",
                "ReplicaDivergence",
            ),
            (
                ExecError::Worker { device: 3, reason: "boom".into() },
                "worker 3 failed: boom",
                "Worker",
            ),
            (
                timeout(1, 4, 2),
                "device 1 timed out after 100 ms waiting on device 0 for op 4 (input slot 2)",
                "Timeout",
            ),
            (
                timeout(1, 4, OUT_SLOT),
                "output scatter",
                "Timeout",
            ),
            (
                ExecError::Corrupt { device: 2, op: 5, from: 6, context: None },
                "device 2 received a corrupt payload from device 6 for op 5",
                "Corrupt",
            ),
            (
                ExecError::Corrupt {
                    device: 2,
                    op: 5,
                    from: 6,
                    context: Some(SpanContext { op: 4, slot: 1, elapsed_ms: 12 }),
                },
                "last span op 4 slot 1 at +12 ms",
                "Corrupt",
            ),
            (
                ExecError::Timeout {
                    device: 1,
                    op: 4,
                    slot: 2,
                    peer: 0,
                    waited_ms: 100,
                    context: Some(SpanContext { op: 3, slot: OUT_SLOT, elapsed_ms: 95 }),
                },
                "last span op 3 (output) at +95 ms",
                "Timeout",
            ),
            (
                ExecError::CheckpointCorrupt { step: 7 },
                "checkpoint of step 7 failed its checksum",
                "CheckpointCorrupt",
            ),
            (
                ExecError::Shard { reason: "rank mismatch".into() },
                "malformed shard operation: rank mismatch",
                "Shard",
            ),
        ];
        for (e, display_frag, debug_frag) in cases {
            let shown = e.to_string();
            assert!(shown.contains(display_frag), "{shown:?} missing {display_frag:?}");
            let dbg = format!("{e:?}");
            assert!(dbg.contains(debug_frag), "{dbg:?} missing {debug_frag:?}");
        }
    }

    /// The wire digest is sensitive to payload bits, lengths, and piece
    /// structure — the properties the corruption detector relies on.
    #[test]
    fn piece_checksum_detects_flips_and_truncation() {
        let region = Region { offset: vec![0], shape: vec![2] };
        let clean: Pieces = vec![(region.clone(), vec![1.0, 2.0])];
        let sum = checksum_pieces(&clean);
        let mut flipped = clean.clone();
        flipped[0].1[0] = f32::from_bits(flipped[0].1[0].to_bits() ^ 0x0040_0000);
        assert_ne!(checksum_pieces(&flipped), sum);
        let truncated: Pieces = vec![(region, vec![1.0])];
        assert_ne!(checksum_pieces(&truncated), sum);
        assert_ne!(checksum_pieces(&Vec::new()), sum);
        assert_eq!(checksum_pieces(&clean), sum);
    }
}
