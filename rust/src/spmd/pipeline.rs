//! Pipelined SPMD execution: run a [`Strategy`]'s cell sequence on real
//! tensors, once per microbatch, and merge the results back into the
//! serial graph's tensors.
//!
//! This is the correctness half of the pipeline axis, the analogue of
//! [`execute`](super::execute) for [`crate::lower::PipelinedProgram`]s.
//! Each `(cell, microbatch)` task runs through the existing threaded
//! executor on the cell's microbatch-shaped subgraph — numerics, shard
//! exchanges, byte meter and all — with
//! [`ExecOptions::stage`](super::ExecOptions) stamped so every span
//! carries its stage. Between cells, boundary tensors hand off by value
//! (the in-process stand-in for the stage-boundary `SendRecv`s, which
//! are accounted separately — see the meter below).
//!
//! ## The microbatch merge
//!
//! With `m` microbatches the per-microbatch results recombine exactly
//! (see [`batch_carrying`] for the carrying rule):
//!
//! - **carrying gradients** concatenate along the batch axis and scale
//!   by `1/m`: each micro graph's loss is a *mean over its microbatch*,
//!   so its activation gradients come out `m×` the serial ones;
//! - **other carrying tensors** (activations, inputs) concatenate
//!   directly — each microbatch computed a row slice of the full batch;
//! - **non-carrying produced tensors** (weight gradients, updated
//!   weights, the scalar loss) *average* across microbatches: they are
//!   linear/affine in the per-microbatch mean, so the average equals the
//!   serial value exactly;
//! - **producerless tensors** (weights, inputs) pass through from the
//!   initial values.
//!
//! ## The meter
//!
//! The executor's collective meter runs per cell execution; summed over
//! every `(cell, microbatch)` task and added to the modeled boundary
//! bytes (`m ×` [`Strategy::boundary_bytes`]), the total must equal
//! [`Strategy::total_cost`] bit for bit, or the run is rejected with
//! [`ExecError::MeterMismatch`] — the same one-theory contract the
//! single-stage executor enforces, extended across the stage axis.
//!
//! The single-stage path delegates to [`execute_with`](super::execute_with)
//! on the original graph and is bit-identical to it: same output bits,
//! same meter, same trace shape.

use crate::graph::{Graph, TensorKind};
use crate::lower::PipelinedProgram;
use crate::obs::StepTrace;
use crate::planner::{batch_carrying, PlanError, Strategy};

use super::exec::{execute_with, ExecError, ExecOptions, ExecReport};

/// The result of executing a pipelined strategy.
#[derive(Debug, Clone)]
pub struct StrategyExecReport {
    /// Devices the strategy spans (`2^k`).
    pub devices: usize,
    /// Every tensor of the original graph, merged across microbatches —
    /// compare against [`crate::graph::eval_serial`] on the *unsliced*
    /// inputs.
    pub tensors: Vec<Vec<f32>>,
    /// Metered intra-cell collective bytes, summed over every
    /// `(cell, microbatch)` execution.
    pub instr_bytes: u64,
    /// Modeled cross-stage boundary bytes for the whole step
    /// (`microbatches × Strategy::boundary_bytes`).
    pub boundary_bytes: u64,
    /// The strategy's Theorem-1 + boundary total. Always equals
    /// `instr_bytes + boundary_bytes` — enforced, not assumed.
    pub modeled_bytes: u64,
    /// Merged span trace across every cell execution when
    /// [`ExecOptions::trace`] is on; spans carry their stage tags.
    pub trace: Option<StepTrace>,
}

impl StrategyExecReport {
    /// Worst relative deviation from a serial reference, with the tensor
    /// name it occurred on (the [`super::worst_divergence`] of this
    /// report type).
    #[must_use]
    pub fn worst_divergence(&self, g: &Graph, serial: &[Vec<f32>]) -> (f64, String) {
        let mut worst = (0.0f64, String::new());
        for t in &g.tensors {
            let err = crate::graph::max_rel_err(&self.tensors[t.id], &serial[t.id]);
            if err > worst.0 {
                worst = (err, t.name.clone());
            }
        }
        worst
    }
}

fn malformed(reason: String) -> ExecError {
    ExecError::Plan(PlanError::MalformedPlan { reason })
}

/// Execute a pipelined strategy on real tensors.
///
/// `pp` must be the [`crate::lower::try_lower_strategy`] compilation of
/// the same strategy. See the module docs for the merge and meter
/// semantics.
///
/// # Errors
/// Propagates per-cell executor failures and rejects byte totals that
/// do not reconcile with the strategy ([`ExecError::MeterMismatch`]).
pub fn try_execute_strategy(
    g: &Graph,
    strategy: &Strategy,
    pp: &PipelinedProgram,
    init: &[Option<Vec<f32>>],
    opts: &ExecOptions,
) -> Result<StrategyExecReport, ExecError> {
    if pp.cells.len() != strategy.cells.len() {
        return Err(malformed(format!(
            "program has {} cells but the strategy has {}",
            pp.cells.len(),
            strategy.cells.len()
        )));
    }

    // Degenerate path: the plain executor, bit for bit.
    if strategy.is_single_stage() && strategy.microbatches == 1 {
        let cell = &strategy.cells[0];
        let r: ExecReport = execute_with(g, &cell.plan, &pp.cells[0], init, opts)?;
        return Ok(StrategyExecReport {
            devices: r.devices,
            tensors: r.tensors,
            instr_bytes: r.instr_bytes,
            boundary_bytes: 0,
            modeled_bytes: strategy.total_cost(),
            trace: r.trace,
        });
    }

    let m = strategy.microbatches;
    let carrying = batch_carrying(g);
    let row_slice = |full: &[f32], t: usize, mu: usize| -> Vec<f32> {
        let rows = g.tensors[t].shape[0];
        let row_len = full.len() / rows.max(1);
        let lo = mu * (rows / m) * row_len;
        let hi = (mu + 1) * (rows / m) * row_len;
        full[lo..hi].to_vec()
    };

    // Per-microbatch values of every original tensor.
    let mut micro: Vec<Vec<Option<Vec<f32>>>> = Vec::with_capacity(m);
    let mut instr_bytes = 0u64;
    let mut span_batches: Vec<Vec<crate::obs::Span>> = Vec::new();
    for mu in 0..m {
        // Seed from the (sliced) initial values.
        let mut vals: Vec<Option<Vec<f32>>> = (0..g.tensors.len())
            .map(|t| {
                init.get(t).and_then(|v| v.as_ref()).map(|full| {
                    if carrying[t] {
                        row_slice(full, t, mu)
                    } else {
                        full.clone()
                    }
                })
            })
            .collect();
        for (ci, cell) in strategy.cells.iter().enumerate() {
            let produced = cell.graph.produced_mask();
            let local_init: Vec<Option<Vec<f32>>> = cell
                .tensors
                .iter()
                .enumerate()
                .map(|(lt, &orig)| if produced[lt] { None } else { vals[orig].clone() })
                .collect();
            let cell_opts = opts.clone().stage(cell.stage);
            let r = execute_with(&cell.graph, &cell.plan, &pp.cells[ci], &local_init, &cell_opts)?;
            instr_bytes += r.instr_bytes;
            if let Some(trace) = r.trace {
                span_batches.push(trace.spans);
            }
            for (lt, &orig) in cell.tensors.iter().enumerate() {
                if produced[lt] {
                    vals[orig] = Some(r.tensors[lt].clone());
                }
            }
        }
        micro.push(vals);
    }

    // Merge microbatch results back into the serial graph's tensors.
    let mut tensors: Vec<Vec<f32>> = Vec::with_capacity(g.tensors.len());
    for t in &g.tensors {
        if g.producer(t.id).is_none() {
            tensors.push(init.get(t.id).and_then(|v| v.clone()).unwrap_or_default());
            continue;
        }
        let parts: Vec<&Vec<f32>> = (0..m)
            .map(|mu| {
                micro[mu][t.id].as_ref().ok_or_else(|| {
                    malformed(format!("tensor `{}` never produced by any cell", t.name))
                })
            })
            .collect::<Result<_, _>>()?;
        let merged = if carrying[t.id] {
            let mut v: Vec<f32> = parts.iter().flat_map(|p| p.iter().copied()).collect();
            if t.kind == TensorKind::Gradient {
                // Each micro loss is a mean over B/m rows, so micro
                // activation gradients are m× the serial ones.
                let inv = 1.0 / m as f32;
                for x in &mut v {
                    *x *= inv;
                }
            }
            v
        } else {
            // Linear/affine in the microbatch mean: average exactly
            // reproduces the serial value.
            let inv = 1.0 / m as f32;
            let mut v = vec![0.0f32; parts[0].len()];
            for p in &parts {
                for (a, &b) in v.iter_mut().zip(p.iter()) {
                    *a += b * inv;
                }
            }
            v
        };
        tensors.push(merged);
    }

    // The one-theory contract across the stage axis.
    let boundary_bytes = m as u64 * strategy.boundary_bytes();
    let modeled_bytes = strategy.total_cost();
    if instr_bytes + boundary_bytes != modeled_bytes {
        return Err(ExecError::MeterMismatch {
            metered: instr_bytes + boundary_bytes,
            plan: modeled_bytes,
        });
    }

    let trace = if opts.trace { Some(StepTrace::merge(span_batches)) } else { None };
    Ok(StrategyExecReport {
        devices: strategy.devices(),
        tensors,
        instr_bytes,
        boundary_bytes,
        modeled_bytes,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{bfs_levels, eval_serial, seed_values};
    use crate::lower::{try_lower, try_lower_strategy};
    use crate::models::{mlp, MlpConfig};
    use crate::planner::{try_k_cut, Schedule};
    use crate::sim::SimConfig;

    fn small_mlp() -> crate::graph::Graph {
        mlp(&MlpConfig { batch: 16, dims: vec![8, 8, 8], bias: true })
    }

    /// Single-stage execution is the plain executor, bit for bit.
    #[test]
    fn single_stage_is_bit_identical() {
        let g = small_mlp();
        let cfg = SimConfig::default();
        let plan = try_k_cut(&g, 2).unwrap();
        let program = try_lower(&g, &plan, &cfg).unwrap();
        let init = seed_values(&g, 7);
        let want = execute_with(&g, &plan, &program, &init, &ExecOptions::default()).unwrap();
        let s = Strategy::single_stage(&g, plan);
        let pp = try_lower_strategy(&g, &s, &cfg).unwrap();
        let r = try_execute_strategy(&g, &s, &pp, &init, &ExecOptions::default()).unwrap();
        assert_eq!(r.instr_bytes, want.instr_bytes);
        assert_eq!(r.boundary_bytes, 0);
        assert_eq!(r.modeled_bytes, want.instr_bytes);
        for (a, b) in r.tensors.iter().zip(&want.tensors) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// Two stages, two microbatches: matches the serial interpreter and
    /// the meter reconciles across the stage axis.
    #[test]
    fn two_stage_two_micro_matches_serial() {
        let g = small_mlp();
        let cfg = SimConfig::default();
        let cut = bfs_levels(&g).levels.len() / 2;
        let s = Strategy::try_build(&g, &[cut], 2, 2, Schedule::GPipe).unwrap();
        let pp = try_lower_strategy(&g, &s, &cfg).unwrap();
        let init = seed_values(&g, 11);
        let opts = ExecOptions::default().trace(true);
        let r = try_execute_strategy(&g, &s, &pp, &init, &opts).unwrap();
        assert_eq!(r.instr_bytes + r.boundary_bytes, s.total_cost());
        assert!(r.boundary_bytes > 0);
        let serial = eval_serial(&g, &init).unwrap();
        let (worst, t) = r.worst_divergence(&g, &serial);
        assert!(worst <= 1e-5, "pipelined exec diverged on {t}: {worst:e}");
        // The merged trace attributes spans to both stages.
        let trace = r.trace.expect("tracing was on");
        assert!(trace.stage_count() == 2);
        assert!(trace.stage_busy_s().iter().all(|&b| b > 0.0));
    }
}
