//! Deterministic, seeded fault injection for the threaded SPMD executor.
//!
//! A production step loop cannot treat a stalled link or a dead worker as
//! an eternal `recv()` block, and it cannot *test* its failure handling
//! without a way to make failures happen on demand. This module is that
//! way: a [`FaultPlan`] names concrete `(device, op)` sites in a lowered
//! program and attaches a [`FaultKind`] to each — panic the worker there,
//! kill it silently (device loss), drop or delay one of its exchange
//! messages, or corrupt a payload in flight. The executor consults the
//! plan at exactly two kinds of site:
//!
//! - **compute sites** — entering [`Instr::Compute`] for op `o` on device
//!   `d` ([`FaultKind::Panic`], [`FaultKind::Kill`]);
//! - **send sites** — each outgoing exchange message device `d` ships for
//!   op `o` ([`FaultKind::DropMessage`], [`FaultKind::DelayMessage`],
//!   [`FaultKind::CorruptPayload`]).
//!
//! The hooks are free when unused: the default
//! [`ExecOptions`](super::ExecOptions) carries no plan, so each site is a
//! single branch on `None` (the CI `chaos` job pins `exec_micro` with
//! injection disabled against the committed baseline). Every fault is
//! **deterministic** — same plan, same program, same failure — and
//! *transient* faults disarm themselves after firing once, so a retry of
//! the same step succeeds, which is what lets
//! [`execute_with_recovery`](super::execute_with_recovery) distinguish a
//! lost packet from a lost device. Faults marked `persistent` re-fire on
//! every attempt, modeling permanent device loss.
//!
//! [`Instr::Compute`]: crate::lower::Instr::Compute

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Once;

use crate::graph::OpId;
use crate::util::Rng;

/// Reason string of a silent injected kill — the one worker failure that
/// must **not** poison its peers (a crashed host sends nothing), so the
/// watchdog timeouts, not the poison path, are what detect it.
pub(crate) const KILLED_REASON: &str = "killed by fault injection (device loss)";

/// What happens when a fault fires at its site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic the worker thread at the compute site — exercises the
    /// `catch_unwind` + poison-broadcast path end to end.
    Panic,
    /// Terminate the worker silently at the compute site: no poison, no
    /// further sends. Peers must discover the loss via their watchdogs.
    Kill,
    /// Swallow the matching outgoing exchange message; the receiver's
    /// watchdog reports the stalled site.
    DropMessage,
    /// Sleep `ms` milliseconds before the matching send. Below the
    /// deadline this is a tolerated hiccup; above it, a timeout.
    DelayMessage {
        /// Injected latency in milliseconds.
        ms: u64,
    },
    /// Flip bits of the payload after its checksum is computed — the
    /// receiver's integrity check reports
    /// [`ExecError::Corrupt`](super::ExecError::Corrupt).
    CorruptPayload,
}

impl FaultKind {
    /// Whether this kind fires at compute sites (vs send sites).
    fn is_compute_site(&self) -> bool {
        matches!(self, FaultKind::Panic | FaultKind::Kill)
    }

    /// Short name for scenario specs and logs.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Kill => "kill",
            FaultKind::DropMessage => "drop",
            FaultKind::DelayMessage { .. } => "delay",
            FaultKind::CorruptPayload => "corrupt",
        }
    }
}

/// One injected fault: a kind armed at a `(device, op)` site.
#[derive(Debug)]
pub struct Fault {
    /// Device whose worker the fault targets.
    pub device: usize,
    /// Op (instruction site) at which it fires.
    pub op: OpId,
    /// What happens there.
    pub kind: FaultKind,
    /// `true` re-fires on every execution attempt (permanent device
    /// loss); `false` fires once and disarms (transient fault).
    pub persistent: bool,
    /// Still armed? Transient faults disarm on firing.
    armed: AtomicBool,
}

impl Fault {
    /// A transient (fire-once) fault at `(device, op)`.
    pub fn transient(device: usize, op: OpId, kind: FaultKind) -> Self {
        Fault { device, op, kind, persistent: false, armed: AtomicBool::new(true) }
    }

    /// A persistent fault at `(device, op)` — re-fires on every attempt.
    pub fn persistent(device: usize, op: OpId, kind: FaultKind) -> Self {
        Fault { device, op, kind, persistent: true, armed: AtomicBool::new(true) }
    }

    /// Consume one firing: `true` if the fault triggers now. Persistent
    /// faults always trigger; transient ones only while armed.
    fn fire(&self) -> bool {
        if self.persistent {
            return true;
        }
        self.armed.swap(false, Ordering::AcqRel)
    }
}

impl Clone for Fault {
    fn clone(&self) -> Self {
        Fault {
            device: self.device,
            op: self.op,
            kind: self.kind,
            persistent: self.persistent,
            armed: AtomicBool::new(self.armed.load(Ordering::Acquire)),
        }
    }
}

/// A set of armed faults, shared (by reference) across worker threads.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// The faults, in arming order.
    pub faults: Vec<Fault>,
    /// The seed this plan was generated from, if any — reported in chaos
    /// failures so a failing scenario reproduces from one number.
    pub seed: Option<u64>,
}

impl FaultPlan {
    /// An empty plan (injects nothing).
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// A single-fault plan.
    pub fn single(fault: Fault) -> Self {
        FaultPlan { faults: vec![fault], seed: None }
    }

    /// Permanent device loss: kill `device`'s worker silently when it
    /// reaches op `op`, on every attempt.
    pub fn kill(device: usize, op: OpId) -> Self {
        Self::single(Fault::persistent(device, op, FaultKind::Kill))
    }

    /// Panic `device`'s worker at op `op` (transient: a retry succeeds).
    pub fn panic_at(device: usize, op: OpId) -> Self {
        Self::single(Fault::transient(device, op, FaultKind::Panic))
    }

    /// Drop the first exchange message `device` sends for op `op`.
    pub fn drop_message(device: usize, op: OpId) -> Self {
        Self::single(Fault::transient(device, op, FaultKind::DropMessage))
    }

    /// Delay the first exchange message `device` sends for op `op`.
    pub fn delay_message(device: usize, op: OpId, ms: u64) -> Self {
        Self::single(Fault::transient(device, op, FaultKind::DelayMessage { ms }))
    }

    /// Corrupt the first payload `device` sends for op `op`.
    pub fn corrupt_payload(device: usize, op: OpId) -> Self {
        Self::single(Fault::transient(device, op, FaultKind::CorruptPayload))
    }

    /// A deterministic random fault plan: one fault at a seeded
    /// `(device, op)` site over a program with `devices` devices and
    /// `ops` operators. Kills are persistent (device loss); every other
    /// kind is transient. Injected delays stay small (≤ 8 ms) so they are
    /// tolerated hiccups under any reasonable deadline — the chaos suite
    /// exercises above-deadline stalls with [`FaultPlan::drop_message`],
    /// whose timeout does not depend on scheduler noise.
    pub fn seeded(seed: u64, devices: usize, ops: usize) -> Self {
        assert!(devices > 0 && ops > 0, "seeded fault plan needs a non-empty program");
        let mut rng = Rng::new(seed);
        let device = rng.below(devices);
        let op = rng.below(ops);
        let fault = match rng.below(5) {
            0 => Fault::transient(device, op, FaultKind::Panic),
            1 => Fault::persistent(device, op, FaultKind::Kill),
            2 => Fault::transient(device, op, FaultKind::DropMessage),
            3 => Fault::transient(
                device,
                op,
                FaultKind::DelayMessage { ms: 1 + rng.below(8) as u64 },
            ),
            _ => Fault::transient(device, op, FaultKind::CorruptPayload),
        };
        FaultPlan { faults: vec![fault], seed: Some(seed) }
    }

    /// Re-arm every transient fault (for replaying one plan across
    /// independent experiments; recovery retries deliberately do *not*
    /// re-arm, so a transient fault stays fired).
    pub fn rearm(&self) {
        for f in &self.faults {
            f.armed.store(true, Ordering::Release);
        }
    }

    /// Fire the compute-site fault at `(device, op)`, if one is armed.
    pub(crate) fn fire_compute(&self, device: usize, op: OpId) -> Option<FaultKind> {
        self.site(device, op, true)
    }

    /// Fire the send-site fault at `(device, op)`, if one is armed.
    pub(crate) fn fire_send(&self, device: usize, op: OpId) -> Option<FaultKind> {
        self.site(device, op, false)
    }

    fn site(&self, device: usize, op: OpId, compute: bool) -> Option<FaultKind> {
        for f in &self.faults {
            if f.device == device && f.op == op && f.kind.is_compute_site() == compute && f.fire() {
                return Some(f.kind);
            }
        }
        None
    }

    /// One-line description for scenario logs and chaos reports.
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = self
            .faults
            .iter()
            .map(|f| {
                format!(
                    "{}@d{}:op{}{}",
                    f.kind.name(),
                    f.device,
                    f.op,
                    if f.persistent { " (persistent)" } else { "" }
                )
            })
            .collect();
        if let Some(seed) = self.seed {
            parts.push(format!("seed={seed:#x}"));
        }
        if parts.is_empty() {
            "no faults".into()
        } else {
            parts.join(", ")
        }
    }
}

/// Panic payload of [`FaultKind::Panic`] — public so the quiet hook (and
/// any external harness) can recognize injected panics by type.
#[derive(Debug)]
pub struct InjectedPanic;

/// Install a process-wide panic hook that silences *injected* panics
/// (payload type [`InjectedPanic`]) and forwards everything else to the
/// previously installed hook. Chaos suites call this once so hundreds of
/// injected worker panics do not bury real failures in backtraces;
/// idempotent.
pub fn install_quiet_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<InjectedPanic>().is_none() {
                prev(info);
            }
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_fault_fires_once() {
        let p = FaultPlan::drop_message(1, 4);
        assert_eq!(p.fire_send(1, 4), Some(FaultKind::DropMessage));
        assert_eq!(p.fire_send(1, 4), None, "transient fault must disarm");
        p.rearm();
        assert_eq!(p.fire_send(1, 4), Some(FaultKind::DropMessage));
    }

    #[test]
    fn persistent_kill_refires() {
        let p = FaultPlan::kill(0, 2);
        for _ in 0..3 {
            assert_eq!(p.fire_compute(0, 2), Some(FaultKind::Kill));
        }
    }

    #[test]
    fn sites_are_kind_specific() {
        // A send-site fault never fires at a compute site and vice versa.
        let p = FaultPlan::corrupt_payload(0, 1);
        assert_eq!(p.fire_compute(0, 1), None);
        assert_eq!(p.fire_send(0, 1), Some(FaultKind::CorruptPayload));
        let p = FaultPlan::panic_at(0, 1);
        assert_eq!(p.fire_send(0, 1), None);
        assert_eq!(p.fire_compute(0, 1), Some(FaultKind::Panic));
    }

    #[test]
    fn wrong_site_does_not_fire() {
        let p = FaultPlan::kill(2, 5);
        assert_eq!(p.fire_compute(1, 5), None);
        assert_eq!(p.fire_compute(2, 4), None);
        // Still armed for the real site.
        assert_eq!(p.fire_compute(2, 5), Some(FaultKind::Kill));
    }

    #[test]
    fn seeded_plans_are_deterministic_and_in_range() {
        for seed in 0..64 {
            let a = FaultPlan::seeded(seed, 4, 10);
            let b = FaultPlan::seeded(seed, 4, 10);
            assert_eq!(a.faults.len(), 1);
            let (fa, fb) = (&a.faults[0], &b.faults[0]);
            assert_eq!((fa.device, fa.op, fa.kind), (fb.device, fb.op, fb.kind));
            assert!(fa.device < 4 && fa.op < 10);
            assert_eq!(fa.persistent, fa.kind == FaultKind::Kill);
            if let FaultKind::DelayMessage { ms } = fa.kind {
                assert!((1..=8).contains(&ms));
            }
            assert_eq!(a.seed, Some(seed));
        }
    }

    #[test]
    fn describe_names_site_and_seed() {
        let p = FaultPlan::seeded(7, 2, 3);
        let s = p.describe();
        assert!(s.contains("@d"), "{s}");
        assert!(s.contains("seed=0x7"), "{s}");
        assert_eq!(FaultPlan::new().describe(), "no faults");
        let k = FaultPlan::kill(1, 2).describe();
        assert!(k.contains("kill@d1:op2 (persistent)"), "{k}");
    }
}
