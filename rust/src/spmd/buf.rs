//! Shard buffers: dense row-major storage over axis-aligned tensor
//! regions, with region-relative copying.
//!
//! Every piece of data the executor moves is a [`crate::exec::Region`]
//! (absolute tensor coordinates) paired with its dense contents. The one
//! primitive everything builds on is [`for_each_row`]: visit a cell's
//! contiguous last-dimension runs as `(dst_base, src_base, len)` index
//! triples relative to two enclosing regions — copies, extractions and
//! f64 accumulations are all row loops over it.

use crate::exec::Region;

use super::exec::ExecError;

/// Row-major strides of a shape (last dimension contiguous).
fn strides(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for d in (0..shape.len().saturating_sub(1)).rev() {
        s[d] = s[d + 1] * shape[d + 1];
    }
    s
}

/// Visit `cell` (absolute coordinates, contained in both `dst` and `src`)
/// as contiguous rows: `f(dst_base, src_base, len)` for each run, where
/// the bases index dense row-major arrays stored over `dst` / `src`.
/// Rank-0 regions visit one element.
pub fn for_each_row(dst: &Region, src: &Region, cell: &Region, mut f: impl FnMut(usize, usize, usize)) {
    let rank = cell.shape.len();
    if rank == 0 {
        f(0, 0, 1);
        return;
    }
    if cell.is_empty() {
        return;
    }
    let len = cell.shape[rank - 1];
    let ds = strides(&dst.shape);
    let ss = strides(&src.shape);
    let mut idx = vec![0usize; rank - 1];
    loop {
        let mut db = cell.offset[rank - 1] - dst.offset[rank - 1];
        let mut sb = cell.offset[rank - 1] - src.offset[rank - 1];
        for d in 0..rank - 1 {
            let abs = cell.offset[d] + idx[d];
            db += (abs - dst.offset[d]) * ds[d];
            sb += (abs - src.offset[d]) * ss[d];
        }
        f(db, sb, len);
        // Odometer over the outer dimensions, innermost-first.
        let mut d = rank - 1;
        loop {
            if d == 0 {
                return;
            }
            d -= 1;
            idx[d] += 1;
            if idx[d] < cell.shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// A shard: the region of the logical tensor this buffer covers, plus its
/// elements in dense row-major order.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardBuf {
    /// Which axis-aligned box of the tensor this buffer holds.
    pub region: Region,
    /// The box's elements, row-major.
    pub data: Vec<f32>,
}

impl ShardBuf {
    /// A zero-filled buffer over `region`.
    pub fn zeros(region: Region) -> Self {
        let n = region.elements() as usize;
        ShardBuf { region, data: vec![0.0; n] }
    }

    /// Slice `region` out of a whole tensor of `shape` (how every device
    /// materializes its home shard of a graph input).
    pub fn from_full(full: &[f32], shape: &[usize], region: Region) -> Self {
        let whole = Region::full(shape);
        let mut data = vec![0.0f32; region.elements() as usize];
        for_each_row(&region, &whole, &region, |db, sb, len| {
            data[db..db + len].copy_from_slice(&full[sb..sb + len]);
        });
        ShardBuf { region, data }
    }

    /// Extract `cell` (absolute coordinates, must be inside this region)
    /// as its own dense array.
    pub fn extract(&self, cell: &Region) -> Vec<f32> {
        let mut out = vec![0.0f32; cell.elements() as usize];
        for_each_row(cell, &self.region, cell, |db, sb, len| {
            out[db..db + len].copy_from_slice(&self.data[sb..sb + len]);
        });
        out
    }

    /// Copy a dense `cell` payload (stored over `cell` itself) into this
    /// buffer at its absolute position.
    pub fn paste(&mut self, cell: &Region, payload: &[f32]) {
        let region = self.region.clone();
        for_each_row(&region, cell, cell, |db, sb, len| {
            self.data[db..db + len].copy_from_slice(&payload[sb..sb + len]);
        });
    }

    /// Check `cell` + `payload` against this buffer before touching it:
    /// the rank must match, the cell must lie inside the buffer's region,
    /// and the payload must hold exactly the cell's elements. Everything
    /// that crosses a trust boundary (a piece received from a peer) goes
    /// through here so malformed input is an [`ExecError::Shard`], not an
    /// index panic.
    fn check(&self, verb: &str, cell: &Region, payload_len: Option<usize>) -> Result<(), ExecError> {
        if cell.shape.len() != self.region.shape.len() {
            return Err(ExecError::Shard {
                reason: format!(
                    "{verb} of rank-{} cell into rank-{} buffer",
                    cell.shape.len(),
                    self.region.shape.len()
                ),
            });
        }
        if !self.region.contains(cell) {
            return Err(ExecError::Shard {
                reason: format!(
                    "{verb} cell {:?}+{:?} outside buffer region {:?}+{:?}",
                    cell.offset, cell.shape, self.region.offset, self.region.shape
                ),
            });
        }
        if let Some(len) = payload_len {
            if len as u64 != cell.elements() {
                return Err(ExecError::Shard {
                    reason: format!(
                        "{verb} payload of {len} elements for a cell of {}",
                        cell.elements()
                    ),
                });
            }
        }
        Ok(())
    }

    /// Checked [`Self::extract`]: malformed `cell` becomes
    /// [`ExecError::Shard`] instead of a panic.
    pub fn try_extract(&self, cell: &Region) -> Result<Vec<f32>, ExecError> {
        self.check("extract", cell, None)?;
        Ok(self.extract(cell))
    }

    /// Checked [`Self::paste`]: malformed `cell` or mis-sized `payload`
    /// becomes [`ExecError::Shard`] instead of a panic.
    pub fn try_paste(&mut self, cell: &Region, payload: &[f32]) -> Result<(), ExecError> {
        self.check("paste", cell, Some(payload.len()))?;
        self.paste(cell, payload);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn region(offset: &[usize], shape: &[usize]) -> Region {
        Region { offset: offset.to_vec(), shape: shape.to_vec() }
    }

    #[test]
    fn from_full_slices_rows_and_cols() {
        // 4x4 tensor 0..16; take the bottom-right 2x2 block.
        let full: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let b = ShardBuf::from_full(&full, &[4, 4], region(&[2, 2], &[2, 2]));
        assert_eq!(b.data, vec![10.0, 11.0, 14.0, 15.0]);
    }

    #[test]
    fn extract_and_paste_round_trip() {
        let full: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let b = ShardBuf::from_full(&full, &[4, 4], region(&[0, 0], &[4, 4]));
        let cell = region(&[1, 1], &[2, 3]);
        let piece = b.extract(&cell);
        assert_eq!(piece, vec![5.0, 6.0, 7.0, 9.0, 10.0, 11.0]);
        let mut dst = ShardBuf::zeros(region(&[0, 0], &[4, 4]));
        dst.paste(&cell, &piece);
        assert_eq!(dst.data[5], 5.0);
        assert_eq!(dst.data[11], 11.0);
        assert_eq!(dst.data[0], 0.0);
    }

    #[test]
    fn rank0_single_element() {
        let b = ShardBuf::from_full(&[42.0], &[], region(&[], &[]));
        assert_eq!(b.data, vec![42.0]);
        assert_eq!(b.extract(&region(&[], &[])), vec![42.0]);
    }

    #[test]
    fn try_paste_rejects_malformed_pieces() {
        let mut b = ShardBuf::zeros(region(&[0, 0], &[4, 4]));
        // Wrong rank.
        let e = b.try_paste(&region(&[0], &[2]), &[0.0; 2]).unwrap_err();
        assert!(matches!(e, ExecError::Shard { ref reason } if reason.contains("rank")));
        // Out of bounds.
        let e = b.try_paste(&region(&[3, 3], &[2, 2]), &[0.0; 4]).unwrap_err();
        assert!(matches!(e, ExecError::Shard { ref reason } if reason.contains("outside")));
        // Payload length mismatch.
        let e = b.try_paste(&region(&[0, 0], &[2, 2]), &[0.0; 3]).unwrap_err();
        assert!(matches!(e, ExecError::Shard { ref reason } if reason.contains("3 elements")));
        // Well-formed paste still lands.
        b.try_paste(&region(&[1, 1], &[1, 1]), &[9.0]).unwrap();
        assert_eq!(b.data[5], 9.0);
    }

    #[test]
    fn try_extract_rejects_out_of_bounds() {
        let full: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let b = ShardBuf::from_full(&full, &[4, 4], region(&[0, 0], &[2, 4]));
        let e = b.try_extract(&region(&[2, 0], &[1, 4])).unwrap_err();
        assert!(matches!(e, ExecError::Shard { ref reason } if reason.contains("outside")));
        assert_eq!(b.try_extract(&region(&[1, 0], &[1, 2])).unwrap(), vec![4.0, 5.0]);
    }

    #[test]
    fn rank3_offsets() {
        // 2x2x2 tensor; slice the second plane.
        let full: Vec<f32> = (0..8).map(|v| v as f32).collect();
        let b = ShardBuf::from_full(&full, &[2, 2, 2], region(&[1, 0, 0], &[1, 2, 2]));
        assert_eq!(b.data, vec![4.0, 5.0, 6.0, 7.0]);
    }
}
