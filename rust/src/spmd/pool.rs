//! Persistent SPMD worker pool: spawn threads once, dispatch steps many
//! times.
//!
//! The one-shot [`super::execute`] path pays thread spawn + teardown on
//! every call, which is pure overhead when the same lowered program runs
//! step after step — the serving scenario ROADMAP item 2 names, and the
//! amortization story the planner's whole value proposition rests on
//! (find the tiling once, execute it forever). This module splits the
//! executor's lifecycle in two:
//!
//! - [`StepCtx`] is one fully *validated* step — graph, plan, lowered
//!   program, shard schedule, and options, checked once by
//!   [`StepCtx::try_new`] (shard schedule builds, program validates
//!   against the plan, every compute targets a known op, and the lowered
//!   byte meter equals the plan's Theorem-1 cost). Immutable and
//!   `Arc`-shared, so dispatching it is a pointer bump, not a re-plan.
//! - [`WorkerPool`] owns one long-lived OS thread per device plus the
//!   inter-device data channels. Each thread loops on a private job
//!   queue; [`WorkerPool::run_step`] slices the input shards, hands every
//!   thread a job, and blocks until all devices report — a step barrier.
//!
//! Because the barrier completes before the next dispatch, the only
//! cross-step hazard is a message a *failed* step stranded in a data
//! channel. Every [`super::exec::Msg`] therefore carries the step's
//! sequence number, and receivers discard strays from other steps —
//! including stale poison — before interpreting them.
//!
//! Failure semantics are identical to the transient path (they share the
//! worker body): a failing worker broadcasts poison unless the failure
//! must stay silent (kill, timeout), the pool ranks the collected errors
//! by root cause, and the surviving threads stay warm for the next step.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::exec::{resident_region, try_build_shard_tasks, ShardTask};
use crate::graph::{validate_init, Graph};
use crate::lower::{Instr, LoweredProgram};
use crate::planner::{Plan, PlanError};

use super::buf::ShardBuf;
use super::exec::{
    is_silent_failure, reassemble, root_cause, DeviceOutcome, ExecError, ExecOptions, ExecReport,
    Msg, Worker,
};

/// One fully validated, immutable step: everything a [`WorkerPool`]
/// needs to execute a lowered program except the input values.
///
/// Validation happens once, at construction — repeated dispatch of the
/// same context ([`WorkerPool::run_step`], the serving plan cache) pays
/// none of it again.
pub struct StepCtx {
    pub(crate) g: Graph,
    pub(crate) plan: Plan,
    pub(crate) program: LoweredProgram,
    pub(crate) tasks: Vec<ShardTask>,
    pub(crate) opts: ExecOptions,
}

impl StepCtx {
    /// Validate `(g, plan, program, opts)` into a dispatchable step.
    ///
    /// Runs the executor's full admission suite: the shard schedule must
    /// build, the program must validate against the plan, every compute
    /// instruction must target a known op, and the program's collective
    /// byte meter must equal the plan's Theorem-1 cost bit for bit (the
    /// one-theory contract; [`ExecError::MeterMismatch`] otherwise).
    pub fn try_new(
        g: Graph,
        plan: Plan,
        program: LoweredProgram,
        opts: ExecOptions,
    ) -> Result<Self, ExecError> {
        let tasks = try_build_shard_tasks(&g, &plan)?;
        program.validate_for(&plan)?;
        for (d, prog) in program.programs.iter().enumerate() {
            for (pc, instr) in prog.instrs.iter().enumerate() {
                if let Instr::Compute { op, .. } = instr {
                    if *op >= g.ops.len() {
                        return Err(ExecError::Plan(PlanError::MalformedProgram {
                            device: d,
                            pc,
                            reason: format!("compute of unknown op {op}"),
                        }));
                    }
                }
            }
        }
        if program.total_bytes() != plan.total_cost() {
            return Err(ExecError::MeterMismatch {
                metered: program.total_bytes(),
                plan: plan.total_cost(),
            });
        }
        Ok(StepCtx { g, plan, program, tasks, opts })
    }

    /// Device count the step is lowered for (`2^k`).
    pub fn devices(&self) -> usize {
        self.plan.devices()
    }

    /// The dataflow graph.
    pub fn graph(&self) -> &Graph {
        &self.g
    }

    /// The tiling plan.
    pub fn plan(&self) -> &Plan {
        &self.plan
    }

    /// The lowered program.
    pub fn program(&self) -> &LoweredProgram {
        &self.program
    }

    /// The execution options the step runs under.
    pub fn options(&self) -> &ExecOptions {
        &self.opts
    }
}

/// One dispatched unit of work: the step to run, this device's pre-sliced
/// home shards, and the step's shared trace epoch (all workers measure
/// spans from the same origin, so a merged trace is on one clock).
struct StepJob {
    seq: u64,
    ctx: Arc<StepCtx>,
    home: Vec<Option<ShardBuf>>,
    epoch: Instant,
}

/// A pool of persistent SPMD worker threads — one per device — that stay
/// warm across steps.
///
/// [`spawn`](WorkerPool::spawn) creates the threads and their data
/// channels once; [`run_step`](WorkerPool::run_step) dispatches one
/// validated [`StepCtx`] and blocks until every device reports (a step
/// barrier). Worker threads survive failed steps — a panic is caught at
/// the job boundary — so a pool keeps serving after a fault, which is
/// what the serving engine and the chaos suites rely on.
///
/// Dropping the pool closes the job queues and joins every thread.
pub struct WorkerPool {
    devices: usize,
    seq: u64,
    job_txs: Vec<Sender<StepJob>>,
    result_rx: Receiver<(usize, Result<DeviceOutcome, ExecError>)>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `devices` persistent worker threads and wire up the full
    /// inter-device channel mesh (every worker holds a sender to every
    /// peer; the pool itself keeps no data sender alive).
    pub fn spawn(devices: usize) -> Self {
        let (data_txs, data_rxs): (Vec<Sender<Msg>>, Vec<Receiver<Msg>>) =
            (0..devices).map(|_| channel()).unzip();
        let (result_tx, result_rx) = channel();
        let mut job_txs = Vec::with_capacity(devices);
        let mut handles = Vec::with_capacity(devices);
        for (d, rx) in data_rxs.into_iter().enumerate() {
            let senders = data_txs.clone();
            let (job_tx, job_rx) = channel::<StepJob>();
            job_txs.push(job_tx);
            let result_tx = result_tx.clone();
            handles.push(std::thread::spawn(move || {
                // The worker loop: block for the next step, run it, report,
                // repeat until the pool drops the job queue.
                while let Ok(job) = job_rx.recv() {
                    let ctx = job.ctx;
                    let worker =
                        Worker::for_step(d, &ctx, &senders, &rx, job.seq, job.home, job.epoch);
                    let out = match catch_unwind(AssertUnwindSafe(|| worker.run())) {
                        Ok(r) => r,
                        Err(_) => Err(ExecError::Worker {
                            device: d,
                            reason: "worker thread panicked".into(),
                        }),
                    };
                    if out.is_err() && !is_silent_failure(&out) {
                        // Poison every peer (tagged with this step's seq)
                        // so nobody blocks on a message this worker will
                        // never send. Silent classes skip this — see
                        // `is_silent_failure`.
                        for tx in &senders {
                            let _ = tx.send(Msg::poison(d, job.seq));
                        }
                    }
                    if result_tx.send((d, out)).is_err() {
                        break;
                    }
                }
            }));
        }
        WorkerPool { devices, seq: 0, job_txs, result_rx, handles }
    }

    /// Worker-thread count the pool was spawned with.
    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Steps dispatched so far.
    pub fn steps(&self) -> u64 {
        self.seq
    }

    /// Execute one step of `ctx` on the warm workers.
    ///
    /// `init` is the same producerless-tensor value vector the serial
    /// interpreter takes; the pool slices every device's home shards from
    /// it, dispatches one job per worker, and blocks until all devices
    /// report. On failure the ranked root cause is returned (real failure
    /// > timeout > poison cascade) and the pool remains usable.
    pub fn run_step(
        &mut self,
        ctx: &Arc<StepCtx>,
        init: &[Option<Vec<f32>>],
    ) -> Result<ExecReport, ExecError> {
        if ctx.devices() != self.devices {
            return Err(ExecError::Plan(PlanError::MalformedPlan {
                reason: format!(
                    "step is lowered for {} devices but the pool has {} workers",
                    ctx.devices(),
                    self.devices
                ),
            }));
        }
        if ctx.opts.faults.is_some() {
            // Injected panics unwind through catch_unwind like real kernel
            // panics, but should not spam stderr across a 200-trial suite.
            super::fault::install_quiet_panic_hook();
        }
        // Slice every device's home shard of every producerless tensor
        // (validate_init: the same input contract as the interpreter's).
        let g = &ctx.g;
        let produced = validate_init(g, init)?;
        let mut homes: Vec<Vec<Option<ShardBuf>>> =
            vec![vec![None; g.tensors.len()]; self.devices];
        for t in &g.tensors {
            if produced[t.id] {
                continue;
            }
            // Invariant: validate_init checked presence and length.
            let v = init[t.id].as_ref().expect("validated init value");
            for (d, home) in homes.iter_mut().enumerate() {
                let region = resident_region(&t.shape, &ctx.plan.tiles[t.id], d);
                home[t.id] = Some(ShardBuf::from_full(v, &t.shape, region));
            }
        }
        self.seq += 1;
        let seq = self.seq;
        // The step's trace epoch: captured once, after slicing, so worker
        // spans start near t = 0 and share one monotonic clock.
        let epoch = Instant::now();
        for (tx, home) in self.job_txs.iter().zip(homes) {
            tx.send(StepJob { seq, ctx: Arc::clone(ctx), home, epoch }).map_err(|_| {
                ExecError::Worker { device: 0, reason: "worker pool shut down".into() }
            })?;
        }
        // Step barrier: every device reports before the next dispatch, so
        // no message with a *future* seq can ever exist in a channel.
        let mut outcomes: Vec<Option<DeviceOutcome>> =
            (0..self.devices).map(|_| None).collect();
        let mut errors = Vec::new();
        for _ in 0..self.devices {
            let (d, out) = self.result_rx.recv().map_err(|_| ExecError::Worker {
                device: 0,
                reason: "worker pool shut down".into(),
            })?;
            match out {
                Ok(o) => outcomes[d] = Some(o),
                Err(e) => errors.push(e),
            }
        }
        if let Some(e) = root_cause(errors) {
            if let Some(m) = &ctx.opts.metrics {
                m.inc("exec.failures", 1);
            }
            return Err(e);
        }
        // No error: the barrier collected every device's outcome.
        let outcomes: Vec<DeviceOutcome> =
            outcomes.into_iter().map(|o| o.expect("every worker reported")).collect();
        let report = match reassemble(g, &outcomes, ctx.opts.trace) {
            Ok(r) => r,
            Err(e) => {
                if let Some(m) = &ctx.opts.metrics {
                    m.inc("exec.failures", 1);
                }
                return Err(e);
            }
        };
        if let Some(m) = &ctx.opts.metrics {
            m.inc("exec.steps", 1);
            m.inc("exec.instr_bytes", report.instr_bytes);
            m.observe("exec.step_seconds", epoch.elapsed().as_secs_f64());
        }
        Ok(report)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job queues ends every worker loop; join so no
        // thread outlives the pool.
        self.job_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
