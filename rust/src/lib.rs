//! # SOYBEAN-RS
//!
//! Reproduction of *"Unifying Data, Model and Hybrid Parallelism in Deep
//! Learning via Tensor Tiling"* (Wang, Huang, Li — NYU, 2018).
//!
//! SOYBEAN takes the **serial** dataflow graph of one DNN training step,
//! finds the communication-optimal **tiling** for every tensor (the paper's
//! one-cut dynamic program recursed into a k-cut plan), rewrites the graph
//! into a **parallel execution graph** of partitioned sub-operators plus
//! tiling-conversion transfers, places shards on an interconnect hierarchy,
//! and executes. Data parallelism, model parallelism, and grouped hybrids
//! all arise as special points of the tiling space.
//!
//! Module map (see DESIGN.md for the paper-section correspondence):
//!
//! - [`graph`] — the semantic dataflow graph: tensors, operators, a builder,
//!   reverse-mode autodiff, and BFS levelization (the substrate the paper
//!   inherits from MXNet's frontend). Its kernel library is two-tier: a
//!   naive reference oracle plus blocked, schedule-searched fast kernels
//!   ([`graph::KernelBackend`], `graph::fastk`).
//! - [`tiling`] — the tiling algebra of §4.1–4.2.1: basic tilings
//!   `{R, C, r}`, composition/flattening, ghost-area conversion costs, and
//!   per-operator aligned tilings (Eq. 2).
//! - [`planner`] — §4.2.2's one-cut dynamic program, §4.3's recursive k-cut
//!   algorithm, the pure data-/model-parallel baselines, a brute-force
//!   optimality checker, and the pipeline axis: [`planner::Strategy`]
//!   (stage partition × device groups × per-stage tilings × microbatch
//!   schedule) with its portfolio planner [`planner::plan_strategy`],
//!   never worse than pure tiling by construction.
//! - [`exec`] — §5: partitioning each operator into `2^k` sub-operators,
//!   inserting three-phase tiling conversions, and placing shards on the
//!   device hierarchy.
//! - [`lower`] — the SPMD lowering engine: compiles a `(Graph, Plan)` pair
//!   into explicit per-device collective programs (`AllGather` /
//!   `ReduceScatter` / `AllToAll` / `SendRecv` / `Wait` + local computes),
//!   with per-instruction bytes that sum to the plan's Theorem-1 cost bit
//!   for bit.
//! - [`sim`] — the testbed substitute: the closed-form step model of the
//!   paper figures, plus a discrete-event engine ([`sim::engine`]) that
//!   schedules lowered programs over configurable hierarchical topologies
//!   and emits Chrome-trace timelines.
//! - [`spmd`] — the std-only threaded executor: one worker thread per
//!   device runs a lowered program on real `f32` shard buffers, with the
//!   collective exchanges realized over `mpsc` channels. Differentially
//!   tested against the serial interpreter ([`graph::eval_serial`]), it
//!   is the proof that the parallel graph computes the same function as
//!   the serial one — not just the same byte count.
//! - [`runtime`] — the PJRT side: HLO-text artifact registry, dynamic
//!   `XlaBuilder` kernels, and the multi-worker execution engine (real
//!   buffers, real transfers; Python never runs here). Everything except
//!   the host-tensor type is gated behind the `pjrt` cargo feature, which
//!   needs the vendored `xla`/`anyhow` crates — the default build is
//!   dependency-free.
//! - [`serve`] — the long-lived serving runtime: [`serve::Session`]
//!   (plan once, execute forever — the unified facade over planner +
//!   lowering + executor) and [`serve::ServeEngine`] (persistent warm
//!   worker pool, dynamic batching, plan cache, latency stats).
//! - [`obs`] — observability: per-instruction span tracing in the real
//!   executor, the unified Chrome-trace writer (modeled, measured, and
//!   overlaid), the measured-vs-modeled drift report
//!   ([`obs::CalibrationReport`]), and the shared metrics registry
//!   (counters + histograms) the executor, recovery loop, and serving
//!   stats all report into.
//! - [`coordinator`] — the training loop: BSP batches, SGD, metrics.
//! - [`models`] — the model zoo: MLP, parametric CNN, AlexNet, VGG-16 as
//!   semantic graphs (the paper's evaluation workloads).
//!
//! The narrative walkthrough of the whole pipeline — serial graph →
//! aligned forms → cost LUT → one-cut/k-cut DP → SPMD lowering → event
//! engine, with a worked 2-device MLP example — lives in [`book`]
//! (sources under `docs/`).

#![warn(missing_docs)]

pub mod coordinator;
mod error;
pub mod exec;
pub mod figures;
pub mod graph;
pub mod lower;
pub mod models;
pub mod obs;
pub mod planner;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod spmd;
pub mod tiling;

pub mod util;

pub use error::Error;
pub use graph::{Graph, GraphBuilder, Op, OpId, OpKind, TensorId, TensorInfo};
pub use serve::{ServeEngine, ServeOptions, ServeStats, Session};
pub use tiling::{Tile, TileSeq};

/// The narrative documentation book (sources under `docs/`), compiled
/// into rustdoc so its worked examples run as doctests and its
/// cross-references are checked by CI's docs job.
pub mod book {
    /// The book's index: one chapter per pipeline stage.
    #[doc = include_str!("../../docs/README.md")]
    pub mod index {}

    /// The pipeline end to end and the one-theory contract.
    #[doc = include_str!("../../docs/architecture.md")]
    pub mod architecture {}

    /// Tiling algebra, Eq. (2), cost LUTs, the one-cut/k-cut DP, and the
    /// worked 2-device MLP example.
    #[doc = include_str!("../../docs/planner.md")]
    pub mod planner {}

    /// SPMD lowering and the two simulators.
    #[doc = include_str!("../../docs/lowering-and-sim.md")]
    pub mod lowering_and_sim {}

    /// Topology-aware planning: weighted LUTs and the simulator-scored
    /// portfolio.
    #[doc = include_str!("../../docs/topology.md")]
    pub mod topology {}

    /// Pipeline parallelism: the `Strategy` type, stage cells and the
    /// fused tail, exact microbatch merging, GPipe/1F1B schedules, and
    /// the pipeline-aware portfolio.
    #[doc = include_str!("../../docs/pipeline.md")]
    pub mod pipeline {}

    /// Real execution: the threaded SPMD executor, the serial reference
    /// interpreter, and the differential harness between them.
    #[doc = include_str!("../../docs/execution.md")]
    pub mod execution {}

    /// Blocked cache-aware kernels: the `KernelBackend` dispatch seam, the
    /// per-shape schedule search, boundary-tile handling, and the
    /// accumulation-order tolerance argument behind the kernel oracle.
    #[doc = include_str!("../../docs/kernels.md")]
    pub mod kernels {}

    /// Serving: the `Session` facade, the persistent worker pool, dynamic
    /// batching, plan caching, and the stats surface.
    #[doc = include_str!("../../docs/serving.md")]
    pub mod serving {}

    /// Observability: span tracing, the Chrome-trace overlay, the
    /// measured-vs-modeled drift report, and the metrics registry.
    #[doc = include_str!("../../docs/observability.md")]
    pub mod observability {}
}
