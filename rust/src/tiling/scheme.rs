//! Basic tilings, k-cut sequences, and shard-shape arithmetic.

use crate::graph::{TensorInfo, TensorKind};

/// One basic tiling of a tensor across two devices (or device groups).
///
/// For a matrix, `Split(0)` is the paper's row tiling `R`, `Split(1)` is
/// column tiling `C`, and `Rep` is replication `r`. Higher-rank tensors use
/// the §4.5 generalization `P_d`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tile {
    /// Partition dimension `d` into two equal halves.
    Split(usize),
    /// Replicate the whole tensor on both sides.
    Rep,
}

impl Tile {
    /// Paper notation: `R`, `C`, `P2`…, `r`.
    pub fn describe(&self) -> String {
        match self {
            Tile::Split(0) => "R".to_string(),
            Tile::Split(1) => "C".to_string(),
            Tile::Split(d) => format!("P{d}"),
            Tile::Rep => "r".to_string(),
        }
    }
}

/// A k-cut tiling: the basic tiling chosen at each cut, outermost (first
/// cut, slowest interconnect) first. Definition 1 in the paper.
pub type TileSeq = Vec<Tile>;

/// Paper notation for a sequence, e.g. `"rR"` for hybrid weights.
pub fn describe_seq(seq: &[Tile]) -> String {
    if seq.is_empty() {
        return "·".to_string();
    }
    seq.iter().map(Tile::describe).collect()
}

/// Shape of one shard after applying every cut in `seq` to `shape`.
///
/// Theorem 2 (flattening): the shard shape depends only on the *count* of
/// splits per dimension, not their order — each `Split(d)` halves dimension
/// `d`, `Rep` leaves the shape unchanged.
pub fn shard_shape(shape: &[usize], seq: &[Tile]) -> Vec<usize> {
    let mut out = shape.to_vec();
    for t in seq {
        if let Tile::Split(d) = t {
            assert!(
                out[*d] % 2 == 0,
                "dimension {d} of {shape:?} not divisible under {seq:?}"
            );
            out[*d] /= 2;
        }
    }
    out
}

/// The candidate basic tilings the planner enumerates for a tensor.
///
/// - scalars: replication only;
/// - matrices / vectors: any even dimension, plus replication (`T^1`);
/// - 3-D attention tensors (`[B·H, S, D/H]` head views, `[B·H, S, S]`
///   score/probability maps): the leading batch/head axis only — the
///   §4.5 pruning argument for conv image dimensions applies verbatim:
///   seq/feature splits of these tensors are dominated by batch splits
///   (every aligned form that uses them pays reshape conversions at the
///   head-view boundaries), and admitting them would square the one-cut
///   DP's boundary spaces;
/// - 4-D conv activations (NHWC): batch or channel — §4.5 shows image-dim
///   tilings are dominated by data parallelism, so they are pruned exactly
///   as in the paper's implementation;
/// - 4-D conv filters (HWIO): input- or output-channel.
pub fn candidate_tiles(t: &TensorInfo) -> Vec<Tile> {
    let mut out = vec![Tile::Rep];
    let dims: Vec<usize> = match (t.rank(), t.kind) {
        (0, _) => vec![],
        (3, _) => vec![0],
        (4, TensorKind::Weight) | (4, TensorKind::WeightGrad) | (4, TensorKind::UpdatedWeight) => {
            vec![2, 3]
        }
        (4, _) => vec![0, 3],
        (r, _) => (0..r).collect(),
    };
    for d in dims {
        if t.shape[d] >= 2 && t.shape[d] % 2 == 0 {
            out.push(Tile::Split(d));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(shape: &[usize], kind: TensorKind) -> TensorInfo {
        TensorInfo { id: 0, name: "t".into(), shape: shape.to_vec(), kind, dtype_bytes: 4 }
    }

    #[test]
    fn describe_matches_paper_notation() {
        assert_eq!(Tile::Split(0).describe(), "R");
        assert_eq!(Tile::Split(1).describe(), "C");
        assert_eq!(Tile::Rep.describe(), "r");
        assert_eq!(describe_seq(&[Tile::Rep, Tile::Split(0)]), "rR");
        assert_eq!(describe_seq(&[Tile::Split(0), Tile::Split(1)]), "RC");
    }

    #[test]
    fn shard_shapes_flatten() {
        // Figure 4(b): RR quarters the rows; RC quarters into blocks.
        assert_eq!(shard_shape(&[8, 8], &[Tile::Split(0), Tile::Split(0)]), vec![2, 8]);
        assert_eq!(shard_shape(&[8, 8], &[Tile::Split(0), Tile::Split(1)]), vec![4, 4]);
        // Order independence (Theorem 2).
        assert_eq!(
            shard_shape(&[8, 8], &[Tile::Split(1), Tile::Split(0)]),
            shard_shape(&[8, 8], &[Tile::Split(0), Tile::Split(1)])
        );
        // Replication leaves shapes alone.
        assert_eq!(shard_shape(&[8, 8], &[Tile::Rep, Tile::Rep]), vec![8, 8]);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn uneven_split_rejected() {
        shard_shape(&[3, 4], &[Tile::Split(0)]);
    }

    #[test]
    fn matrix_candidates_are_t1() {
        let c = candidate_tiles(&info(&[4, 6], TensorKind::Activation));
        assert_eq!(c, vec![Tile::Rep, Tile::Split(0), Tile::Split(1)]);
    }

    #[test]
    fn scalar_candidates_rep_only() {
        assert_eq!(candidate_tiles(&info(&[], TensorKind::Scalar)), vec![Tile::Rep]);
    }

    #[test]
    fn conv_activation_candidates_batch_and_channel() {
        let c = candidate_tiles(&info(&[256, 24, 24, 64], TensorKind::Activation));
        assert_eq!(c, vec![Tile::Rep, Tile::Split(0), Tile::Split(3)]);
    }

    #[test]
    fn conv_filter_candidates_channels_only() {
        let c = candidate_tiles(&info(&[3, 3, 64, 128], TensorKind::Weight));
        assert_eq!(c, vec![Tile::Rep, Tile::Split(2), Tile::Split(3)]);
    }

    #[test]
    fn odd_dims_not_splittable() {
        let c = candidate_tiles(&info(&[7, 4], TensorKind::Activation));
        assert_eq!(c, vec![Tile::Rep, Tile::Split(1)]);
    }

    #[test]
    fn rank3_candidates_batch_axis_only() {
        // Attention head views: only the leading batch/head axis tiles.
        let c = candidate_tiles(&info(&[32, 128, 64], TensorKind::Activation));
        assert_eq!(c, vec![Tile::Rep, Tile::Split(0)]);
        // Odd batch axis: replication only.
        let c = candidate_tiles(&info(&[3, 128, 64], TensorKind::Gradient));
        assert_eq!(c, vec![Tile::Rep]);
    }
}
