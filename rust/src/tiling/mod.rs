//! The tiling algebra of paper §4.1–§4.2.1.
//!
//! Three basic tilings partition a tensor across two devices (or device
//! groups): split along a dimension ([`Tile::Split`], the paper's `R`/`C`
//! for matrices, generalized to `P_d` in §4.5) or replicate ([`Tile::Rep`],
//! the paper's `r`). A k-cut tiling is a sequence of k basic tilings
//! ([`TileSeq`]); composition is commutative up to shard layout (§4.4,
//! Theorem 2 "flattening"), which both the placement logic and the k-cut
//! optimality argument rely on.
//!
//! Communication is tiling *conversion* (§4.2.1): an operator's inputs are
//! fetched into one of a handful of *aligned* tilings, computed locally, and
//! its output is pushed from the produced tiling to the tiling the graph
//! assigns it. [`conversion`] prices single conversions via the ghost-area
//! rule; [`aligned`] enumerates the aligned forms per operator class and
//! implements Eq. (2); [`cost_table`] precomputes every op's Eq. (2)
//! surface into dense lookup tables so the planner's inner loops never
//! re-derive aligned forms.

pub mod aligned;
pub mod conversion;
pub mod cost_table;
pub mod paper_example;
mod scheme;

pub use aligned::{form_requirements, op_cost, op_cost_detailed, op_cost_with_form, Form, OpCostBreakdown};
pub use conversion::{conversion_cost, Produced};
pub use cost_table::{CostTables, CutCostModel, OpCostTable};
pub use scheme::{candidate_tiles, describe_seq, shard_shape, Tile, TileSeq};
