//! The paper's §2.2 back-of-envelope accounting, reproduced as checked code.
//!
//! §2.2 compares data, model and hybrid parallelism on a 5-layer MLP
//! (300-wide layers, batch 400, 16 GPUs) using a *simplified*
//! parameter-server-style count: `bytes × devices × 2`. This is not the §4
//! ghost-area model the optimizer uses (that one is in [`super::conversion`]);
//! it exists so the paper's 57.6 / 76.8 / 33.6 MB arithmetic is reproduced
//! bit-for-bit as a regression anchor (`soybean reproduce example22`).

use crate::graph::Graph;

/// §2.2 data parallelism: collect all parameter gradients and synchronize
/// the updated parameters on every device.
pub fn data_parallel_comm(g: &Graph, devices: u64) -> u64 {
    g.weight_bytes() * devices * 2
}

/// §2.2 model parallelism: exchange activations and activation gradients in
/// both propagation directions.
pub fn model_parallel_comm(g: &Graph, devices: u64) -> u64 {
    g.activation_bytes() * devices * 2
}

/// §2.2 hybrid: data parallelism across `groups`, model parallelism within
/// each group of `devices / groups` members. Data parallelism shrinks the
/// per-group activation volume by the group count.
pub fn hybrid_comm(g: &Graph, devices: u64, groups: u64) -> u64 {
    assert!(devices % groups == 0 && groups >= 1);
    let within = devices / groups;
    // A "parallelism" over a single device (or a single group) moves nothing.
    let dp = if groups > 1 { g.weight_bytes() * groups * 2 } else { 0 };
    let mp_per_group =
        if within > 1 { (g.activation_bytes() / groups) * within * 2 } else { 0 };
    dp + groups * mp_per_group
}

/// Builds the §2.2 example graph: 5 fully-connected 300×300 layers, batch
/// 400 (forward only — §2.2 counts weights and activations, which the
/// forward graph determines).
pub fn example_graph() -> Graph {
    let mut b = crate::graph::GraphBuilder::new();
    let mut x = b.input("x", &[400, 300]);
    for l in 0..5 {
        let w = b.weight(&format!("w{l}"), &[300, 300]);
        x = b.matmul(&format!("fc{l}"), x, w, false, false);
    }
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: f64 = 1e6;

    #[test]
    fn paper_numbers_exact() {
        let g = example_graph();
        // "the total communication is 1.8MB × 16 × 2 = 57.6MB"
        assert_eq!(data_parallel_comm(&g, 16), 57_600_000);
        // "model parallelism transfers ... 2.4MB × 16 × 2 = 76.8MB"
        assert_eq!(model_parallel_comm(&g, 16), 76_800_000);
        // "14.4MB + 4 × 4.8MB = 33.6MB"
        assert_eq!(hybrid_comm(&g, 16, 4), 33_600_000);
    }

    #[test]
    fn paper_savings_percentages() {
        let g = example_graph();
        let dp = data_parallel_comm(&g, 16) as f64 / MB;
        let mp = model_parallel_comm(&g, 16) as f64 / MB;
        let hy = hybrid_comm(&g, 16, 4) as f64 / MB;
        // "communication savings of 41.7% and 56.2%" (the paper truncates
        // 56.25 to 56.2).
        let s_dp = (1.0 - hy / dp) * 100.0;
        let s_mp = (1.0 - hy / mp) * 100.0;
        assert!((s_dp - 41.7).abs() < 0.05, "dp saving {s_dp}");
        assert!((s_mp - 56.25).abs() < 0.05, "mp saving {s_mp}");
    }

    #[test]
    fn batch_vs_layer_crossover() {
        // §2.2: "If the batch size is 300 while the layer size is 400,
        // model parallelism becomes better."
        let mut b = crate::graph::GraphBuilder::new();
        let mut x = b.input("x", &[300, 400]);
        for l in 0..5 {
            let w = b.weight(&format!("w{l}"), &[400, 400]);
            x = b.matmul(&format!("fc{l}"), x, w, false, false);
        }
        let g = b.finish();
        assert!(model_parallel_comm(&g, 16) < data_parallel_comm(&g, 16));
    }

    #[test]
    fn hybrid_never_worse_than_best_group_extreme() {
        let g = example_graph();
        // groups=16 degenerates to pure DP; groups=1 to pure MP.
        assert_eq!(hybrid_comm(&g, 16, 16), data_parallel_comm(&g, 16));
        assert_eq!(hybrid_comm(&g, 16, 1), model_parallel_comm(&g, 16));
        // The interior optimum beats both extremes here.
        let best = (1..=16).filter(|d| 16 % d == 0).map(|d| hybrid_comm(&g, 16, d)).min().unwrap();
        assert!(best <= data_parallel_comm(&g, 16));
        assert!(best <= model_parallel_comm(&g, 16));
    }
}
