//! Ghost-area conversion costs (paper §4.2.1, Figure 7).
//!
//! The cost of converting a tensor from tiling `t1` to tiling `t2` across
//! two devices equals the "ghost area" each device must fetch: the bytes of
//! its target tile minus the bytes it already holds. Costs are totals over
//! both devices, in bytes.

use super::Tile;

/// What an operator *produces* before the output-conversion phase: either a
/// real tiling, or the intermediate reduction state `red` of Figure 6 (each
/// device holds a full-shape partial sum that must still be added).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Produced {
    /// A realized tiling.
    Tile(Tile),
    /// Full-shape partial sums awaiting reduction (Figure 6's `red`).
    Red,
}

/// Total bytes moved (across both devices) to convert a tensor of
/// `bytes` total size from `from` to tiling `to`.
///
/// The table, derived from the ghost-area rule:
///
/// | from \ to    | same split | other split | replicate |
/// |--------------|-----------:|------------:|----------:|
/// | `Split(d)`   | 0          | S/2         | S         |
/// | `Rep`        | 0          | 0           | 0         |
/// | `Red`        | S          | S           | 2S        |
///
/// - `Split -> other Split`: each device's target tile overlaps its current
///   tile in a quarter of the tensor, so each fetches S/4; total S/2.
/// - `Split -> Rep`: each device is missing the other half: total S.
/// - `Rep -> anything`: every device already holds everything: free.
/// - `Red -> t`: partial sums must cross the wire before they can be added;
///   each device fetches the part of the *other device's* partial matrix
///   overlapping its target tile (S/2 each for a split target, S each for
///   replication — an all-reduce).
pub fn conversion_cost(bytes: u64, from: Produced, to: Tile) -> u64 {
    match (from, to) {
        (Produced::Tile(Tile::Rep), _) => 0,
        (Produced::Tile(a), b) if a == b => 0,
        (Produced::Tile(Tile::Split(_)), Tile::Split(_)) => bytes / 2,
        (Produced::Tile(Tile::Split(_)), Tile::Rep) => bytes,
        (Produced::Red, Tile::Split(_)) => bytes,
        (Produced::Red, Tile::Rep) => 2 * bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: u64 = 1000;

    #[test]
    fn identity_is_free() {
        for t in [Tile::Split(0), Tile::Split(1), Tile::Rep] {
            assert_eq!(conversion_cost(S, Produced::Tile(t), t), 0);
        }
    }

    #[test]
    fn replicated_source_is_free() {
        // r already holds every element on every device.
        for t in [Tile::Split(0), Tile::Split(1), Tile::Rep] {
            assert_eq!(conversion_cost(S, Produced::Tile(Tile::Rep), t), 0);
        }
    }

    #[test]
    fn cross_split_moves_half() {
        // Figure 7(b): C -> R, the yellow quarter per device.
        assert_eq!(
            conversion_cost(S, Produced::Tile(Tile::Split(1)), Tile::Split(0)),
            S / 2
        );
        assert_eq!(
            conversion_cost(S, Produced::Tile(Tile::Split(0)), Tile::Split(1)),
            S / 2
        );
    }

    #[test]
    fn split_to_rep_is_allgather() {
        assert_eq!(conversion_cost(S, Produced::Tile(Tile::Split(0)), Tile::Rep), S);
    }

    #[test]
    fn red_to_rep_is_allreduce() {
        // Gradient aggregation in data parallelism: 2S per cut.
        assert_eq!(conversion_cost(S, Produced::Red, Tile::Rep), 2 * S);
    }

    #[test]
    fn red_to_split_is_reduce_scatter() {
        assert_eq!(conversion_cost(S, Produced::Red, Tile::Split(0)), S);
    }

    #[test]
    fn costs_monotone_in_bytes() {
        for (from, to) in [
            (Produced::Tile(Tile::Split(0)), Tile::Split(1)),
            (Produced::Tile(Tile::Split(0)), Tile::Rep),
            (Produced::Red, Tile::Rep),
        ] {
            assert!(conversion_cost(2000, from, to) >= conversion_cost(1000, from, to));
        }
    }
}
