//! Aligned tilings per operator class and the Eq. (2) cost (paper §4.2.1,
//! §4.5).
//!
//! Every operator is viewed through one of two *semantics*:
//!
//! - **Matmul-like** (`MatMul`, the three conv operators): a logical
//!   `M×K · K×N -> M×N` product with three aligned forms (Figure 6):
//!   `R·r -> R`, `r·C -> C`, and `C·R -> red`. Transposed operands and
//!   convolutions are handled by *axis maps* that translate logical row/col
//!   splits into stored-tensor dimension splits (a conv activation's
//!   logical row is its batch dimension, its logical column the channel
//!   dimension — §4.5's reduction of convolution to the matrix algebra).
//!
//! - **Grid** (elementwise ops, bias broadcast, reductions, losses, SGD
//!   updates): all operands are indexed by a shared logical grid; the
//!   aligned forms split one grid axis, with operands lacking that axis
//!   (broadcasts) replicated and outputs lacking it (reductions) produced
//!   in the `red` state. Replicating everything is disallowed (redundant
//!   computation, §4.5).
//!
//! The operator cost is the minimum over aligned forms of the input and
//! output conversion costs — exactly Eq. (2) generalized beyond matmul.

use crate::graph::{Graph, Op, OpKind};

use super::conversion::{conversion_cost, Produced};
use super::Tile;

/// Sentinel for infeasible assignments (e.g. a required split of an odd
/// dimension). Kept far below `u64::MAX` so sums never overflow.
pub const INFEASIBLE: u64 = u64::MAX / 1024;

/// Stored-tensor dimensions backing the logical row/col of a matmul
/// operand. `None` means the logical axis is absent from the stored tensor
/// (broadcast operand) — splitting that axis forces replication.
#[derive(Debug, Clone, Copy)]
struct AxisMap {
    row: Option<usize>,
    col: Option<usize>,
}

#[derive(Debug, Clone)]
enum Sem {
    MatMulLike { x: AxisMap, y: AxisMap, z: AxisMap },
    Grid {
        /// Which logical grid axes an aligned form may split.
        splittable: Vec<bool>,
        /// Per input: logical axis -> stored dim (None = broadcast).
        in_maps: Vec<Vec<Option<usize>>>,
        /// Output: logical axis -> stored dim (None = reduced away).
        out_map: Vec<Option<usize>>,
        /// Whether the fully-replicated form is admitted. Normally false
        /// (§4.5 forbids redundant computation), but the SGD update is the
        /// classic exception: every data-parallel system applies updates
        /// redundantly on replicated gradients at zero communication, and
        /// the paper's own DP accounting (2·|W| per cut) assumes exactly
        /// that.
        allow_replicated: bool,
    },
}

/// Grid semantics helper: identity map over `rank` axes.
fn ident(rank: usize) -> Vec<Option<usize>> {
    (0..rank).map(Some).collect()
}

/// Which grid axes are splittable for an elementwise op over a tensor of
/// this rank/kind — mirrors [`super::candidate_tiles`] so every candidate
/// tiling has at least one aligned form.
fn ew_splittable(rank: usize, weight_like: bool) -> Vec<bool> {
    match (rank, weight_like) {
        (4, false) => vec![true, false, false, true], // NHWC: batch, channel
        (4, true) => vec![false, false, true, true],  // HWIO: in/out channel
        (r, _) => vec![true; r],
    }
}

fn semantics(g: &Graph, op: &Op) -> Sem {
    match op.kind {
        OpKind::MatMul { ta, tb } => Sem::MatMulLike {
            x: AxisMap { row: Some(if ta { 1 } else { 0 }), col: Some(if ta { 0 } else { 1 }) },
            y: AxisMap { row: Some(if tb { 1 } else { 0 }), col: Some(if tb { 0 } else { 1 }) },
            z: AxisMap { row: Some(0), col: Some(1) },
        },
        // Forward conv: (N·OH·OW × CIN) · (CIN × COUT). Image and kernel
        // dims ride along with the batch/contraction axes (§4.5).
        OpKind::Conv2d { .. } => Sem::MatMulLike {
            x: AxisMap { row: Some(0), col: Some(3) },
            y: AxisMap { row: Some(2), col: Some(3) },
            z: AxisMap { row: Some(0), col: Some(3) },
        },
        // dX = dZ ⊛ Wᵀ: contraction over COUT, producing CIN columns.
        OpKind::Conv2dBwdData { .. } => Sem::MatMulLike {
            x: AxisMap { row: Some(0), col: Some(3) },
            y: AxisMap { row: Some(3), col: Some(2) },
            z: AxisMap { row: Some(0), col: Some(3) },
        },
        // dW = Xᵀ ⊛ dZ: contraction over batch, producing CIN×COUT.
        OpKind::Conv2dBwdFilter { .. } => Sem::MatMulLike {
            x: AxisMap { row: Some(3), col: Some(0) },
            y: AxisMap { row: Some(0), col: Some(3) },
            z: AxisMap { row: Some(2), col: Some(3) },
        },
        OpKind::Ew(_) => {
            let out = &g.tensors[op.outputs[0]];
            let rank = out.rank();
            Sem::Grid {
                splittable: ew_splittable(rank, false),
                in_maps: op.inputs.iter().map(|_| ident(rank)).collect(),
                out_map: ident(rank),
                allow_replicated: false,
            }
        }
        OpKind::BiasAdd => {
            let x = &g.tensors[op.inputs[0]];
            let rank = x.rank();
            let mut bias_map = vec![None; rank];
            bias_map[rank - 1] = Some(0);
            Sem::Grid {
                splittable: ew_splittable(rank, false),
                in_maps: vec![ident(rank), bias_map],
                out_map: ident(rank),
                allow_replicated: false,
            }
        }
        // Pooling: a per-(batch, channel) local op; logical grid = output
        // NHWC, splittable on batch/channel like any conv activation. The
        // backward op additionally reads the forward input/output (same
        // batch/channel structure).
        OpKind::Pool2 => Sem::Grid {
            splittable: vec![true, false, false, true],
            in_maps: vec![vec![Some(0), Some(1), Some(2), Some(3)]],
            out_map: ident(4),
            allow_replicated: false,
        },
        OpKind::Pool2Bwd => Sem::Grid {
            splittable: vec![true, false, false, true],
            in_maps: vec![
                vec![Some(0), Some(1), Some(2), Some(3)],
                vec![Some(0), Some(1), Some(2), Some(3)],
                vec![Some(0), Some(1), Some(2), Some(3)],
            ],
            out_map: ident(4),
            allow_replicated: false,
        },
        // Flatten: logical axes = (batch, features); a channel split of the
        // NHWC input corresponds to a column split of the flattened matrix
        // (channel-major flatten).
        OpKind::Flatten => Sem::Grid {
            splittable: vec![true, true],
            in_maps: vec![vec![Some(0), Some(3)]],
            out_map: vec![Some(0), Some(1)],
            allow_replicated: false,
        },
        OpKind::FlattenBwd => Sem::Grid {
            splittable: vec![true, true],
            in_maps: vec![vec![Some(0), Some(1)]],
            out_map: vec![Some(0), Some(3)],
            allow_replicated: false,
        },
        OpKind::ReduceSumRows => Sem::Grid {
            splittable: vec![true, true],
            in_maps: vec![ident(2)],
            out_map: vec![None, Some(0)],
            allow_replicated: false,
        },
        OpKind::SoftmaxXent => Sem::Grid {
            // Row-wise op: only the batch axis may be split (§4.5).
            splittable: vec![true, false],
            in_maps: vec![ident(2), ident(2)],
            out_map: vec![None, None],
            allow_replicated: false,
        },
        OpKind::SoftmaxXentGrad => Sem::Grid {
            splittable: vec![true, false],
            in_maps: vec![ident(2), ident(2)],
            out_map: ident(2),
            allow_replicated: false,
        },
        OpKind::SgdUpdate => {
            let w = &g.tensors[op.inputs[0]];
            let rank = w.rank();
            Sem::Grid {
                splittable: ew_splittable(rank, rank == 4),
                in_maps: vec![ident(rank), ident(rank)],
                out_map: ident(rank),
                allow_replicated: true,
            }
        }
        // Batched matmul: logical grid (batch, m, n, k). Splitting the
        // batch axis is the data-parallel form (all operands split dim 0,
        // free when already batch-tiled); the m/n/k axes reproduce the
        // three Figure-6 matmul forms per batch element, with transposes
        // handled by the stored-dimension maps exactly as for `MatMul`.
        OpKind::BatchedMatMul { ta, tb } => {
            let (am, ak) = if ta { (2, 1) } else { (1, 2) };
            let (bk, bn) = if tb { (2, 1) } else { (1, 2) };
            Sem::Grid {
                splittable: vec![true, true, true, true],
                in_maps: vec![
                    vec![Some(0), Some(am), None, Some(ak)],
                    vec![Some(0), None, Some(bn), Some(bk)],
                ],
                out_map: vec![Some(0), Some(1), Some(2), None],
                allow_replicated: false,
            }
        }
        // Layer norm reduces along the row (feature) axis for its
        // statistics, so only batch splits avoid cross-device reductions —
        // the gain/bias vectors ride along like a bias broadcast.
        OpKind::LayerNorm => Sem::Grid {
            splittable: vec![true, false],
            in_maps: vec![ident(2), vec![None, Some(0)], vec![None, Some(0)]],
            out_map: ident(2),
            allow_replicated: false,
        },
        OpKind::LayerNormGrad => Sem::Grid {
            splittable: vec![true, false],
            in_maps: vec![ident(2), ident(2), vec![None, Some(0)]],
            out_map: ident(2),
            allow_replicated: false,
        },
        // dgamma: a column reduction over (dy, x) — batch splits produce
        // partial sums (`red`), feature splits slice dy and the output.
        // `x` must stay whole-row under the feature split (`None` ⇒ Rep):
        // the kernel recomputes x̂'s per-row mean/σ from x, which a column
        // slice cannot provide. The original table required `x` split like
        // `dy`; the differential harness (ISSUE-5) caught the executor
        // silently computing statistics over half-rows under
        // model-parallel plans — see `spmd`'s pinned regression test.
        OpKind::LayerNormGammaGrad => Sem::Grid {
            splittable: vec![true, true],
            in_maps: vec![ident(2), vec![Some(0), None]],
            out_map: vec![None, Some(0)],
            allow_replicated: false,
        },
        // Row softmax: normalization runs along the last axis; every other
        // axis (batch/head, and query rows of rank-3 scores) may split.
        OpKind::Softmax => {
            let rank = g.tensors[op.inputs[0]].rank();
            let mut splittable = vec![true; rank];
            splittable[rank - 1] = false;
            Sem::Grid {
                splittable,
                in_maps: vec![ident(rank)],
                out_map: ident(rank),
                allow_replicated: false,
            }
        }
        OpKind::SoftmaxGrad => {
            let rank = g.tensors[op.inputs[0]].rank();
            let mut splittable = vec![true; rank];
            splittable[rank - 1] = false;
            Sem::Grid {
                splittable,
                in_maps: vec![ident(rank), ident(rank)],
                out_map: ident(rank),
                allow_replicated: false,
            }
        }
        // Head-view reshapes: the folded `[B·S, D]` matrix and the
        // `[B·H, S, D/H]` view share exactly one tiling — halving the
        // batch halves dim 0 of both (batch-major layouts). That is the
        // single aligned form; any other assigned tiling pays conversion.
        OpKind::SplitHeads { .. } | OpKind::MergeHeads { .. } | OpKind::QkvSlice { .. } => {
            Sem::Grid {
                splittable: vec![true],
                in_maps: vec![vec![Some(0)]],
                out_map: vec![Some(0)],
                allow_replicated: false,
            }
        }
        OpKind::QkvConcat => Sem::Grid {
            splittable: vec![true],
            in_maps: vec![vec![Some(0)]; 3],
            out_map: vec![Some(0)],
            allow_replicated: false,
        },
    }
}

/// Requirement a logical-axis split imposes on a stored tensor.
fn req_tile(map: Option<usize>) -> Tile {
    match map {
        Some(d) => Tile::Split(d),
        None => Tile::Rep,
    }
}

/// Checks a required tile is realizable on the tensor (even dimension).
fn feasible(g: &Graph, t: usize, tile: Tile) -> bool {
    match tile {
        Tile::Rep => true,
        Tile::Split(d) => {
            let shape = &g.tensors[t].shape;
            d < shape.len() && shape[d] >= 2 && shape[d] % 2 == 0
        }
    }
}

/// Which aligned form an operator cost came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Form {
    /// Matmul aligned form index: 0 = `R·r->R`, 1 = `r·C->C`, 2 = `C·R->red`.
    MatMul(u8),
    /// Grid form splitting the given logical axis.
    GridAxis(u8),
    /// The fully-replicated form (SGD update only).
    Replicated,
}

impl Form {
    /// Paper-notation label (`R·r->R`, `C·R->red`, ...).
    pub fn label(&self) -> String {
        match self {
            Form::MatMul(0) => "R·r->R".to_string(),
            Form::MatMul(1) => "r·C->C".to_string(),
            Form::MatMul(_) => "C·R->red".to_string(),
            Form::GridAxis(a) => format!("grid-split axis {a}"),
            Form::Replicated => "replicated".to_string(),
        }
    }
}

/// Cost breakdown for one operator under chosen tilings: the aligned form
/// picked and the conversion bytes per phase.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpCostBreakdown {
    /// The winning aligned form.
    pub form: Form,
    /// Conversion bytes to fetch the inputs into the form's layouts.
    pub input_bytes: u64,
    /// Conversion bytes to push the output to its assigned tiling.
    pub output_bytes: u64,
}

impl OpCostBreakdown {
    /// Input plus output conversion bytes — the op's Eq. (2) cost.
    pub fn total(&self) -> u64 {
        self.input_bytes.saturating_add(self.output_bytes)
    }
}

/// The concrete requirements of one aligned form: the stored-tensor tiling
/// each input must be converted to, and what the output is produced as.
/// Used by the execution-graph builder to materialize the plan the cost
/// model priced. Panics on a form that does not apply to this op.
pub fn form_requirements(g: &Graph, op: &Op, form: Form) -> (Vec<Tile>, Produced) {
    match (semantics(g, op), form) {
        (Sem::MatMulLike { x, y: _, z }, Form::MatMul(0)) => (
            vec![req_tile(x.row), Tile::Rep],
            Produced::Tile(req_tile(z.row)),
        ),
        (Sem::MatMulLike { y, z, .. }, Form::MatMul(1)) => (
            vec![Tile::Rep, req_tile(y.col)],
            Produced::Tile(req_tile(z.col)),
        ),
        (Sem::MatMulLike { x, y, .. }, Form::MatMul(2)) => {
            (vec![req_tile(x.col), req_tile(y.row)], Produced::Red)
        }
        (Sem::Grid { in_maps, out_map, .. }, Form::GridAxis(a)) => {
            let a = a as usize;
            let ins = in_maps.iter().map(|m| req_tile(m[a])).collect();
            let prod = match out_map[a] {
                Some(d) => Produced::Tile(Tile::Split(d)),
                None => Produced::Red,
            };
            (ins, prod)
        }
        (Sem::Grid { in_maps, .. }, Form::Replicated) => {
            (vec![Tile::Rep; in_maps.len()], Produced::Tile(Tile::Rep))
        }
        (sem, f) => panic!("form {f:?} does not apply to {} ({sem:?})", op.name),
    }
}

/// Price one *specific* aligned form (no min): the conversion costs of
/// `op` if executed via `form`. Returns `None` if the form is infeasible.
/// Used to model the paper's stock data-parallel baseline, which always
/// aggregates gradients (MXNet's parameter flow) rather than letting
/// Eq. (2) substitute a cheaper activation-shipping form.
pub fn op_cost_with_form(g: &Graph, op: &Op, ins: &[Tile], out: Tile, form: Form) -> Option<u64> {
    let (reqs, prod) = form_requirements(g, op, form);
    let mut total = 0u64;
    for ((&t, &req), &given) in op.inputs.iter().zip(&reqs).zip(ins) {
        if !feasible(g, t, req) {
            return None;
        }
        total += conversion_cost(g.tensors[t].bytes(), Produced::Tile(given), req);
    }
    if let Produced::Tile(pt) = prod {
        if !feasible(g, op.outputs[0], pt) {
            return None;
        }
    }
    total += conversion_cost(g.tensors[op.outputs[0]].bytes(), prod, out);
    Some(total)
}

/// Eq. (2): minimum over aligned forms of conversion costs, for `op` with
/// input tilings `ins` (same order as `op.inputs`) and output tiling `out`.
/// Returns `INFEASIBLE` if no aligned form is realizable.
pub fn op_cost(g: &Graph, op: &Op, ins: &[Tile], out: Tile) -> u64 {
    op_cost_detailed(g, op, ins, out).map_or(INFEASIBLE, |b| b.total())
}

/// Like [`op_cost`] but reporting which aligned form won.
pub fn op_cost_detailed(g: &Graph, op: &Op, ins: &[Tile], out: Tile) -> Option<OpCostBreakdown> {
    assert_eq!(ins.len(), op.inputs.len(), "tiling arity mismatch for {}", op.name);
    let mut best: Option<OpCostBreakdown> = None;
    let mut consider = |cand: OpCostBreakdown| {
        if best.as_ref().map_or(true, |b| cand.total() < b.total()) {
            best = Some(cand);
        }
    };

    match semantics(g, op) {
        Sem::MatMulLike { x, y, z } => {
            let (tx, ty) = (op.inputs[0], op.inputs[1]);
            let tz = op.outputs[0];
            let (bx, by, bz) =
                (g.tensors[tx].bytes(), g.tensors[ty].bytes(), g.tensors[tz].bytes());
            // (x requirement, y requirement, produced z, label)
            let forms = [
                (req_tile(x.row), Tile::Rep, Produced::Tile(req_tile(z.row)), Form::MatMul(0)),
                (Tile::Rep, req_tile(y.col), Produced::Tile(req_tile(z.col)), Form::MatMul(1)),
                (req_tile(x.col), req_tile(y.row), Produced::Red, Form::MatMul(2)),
            ];
            for (rx, ry, prod, label) in forms {
                if !feasible(g, tx, rx) || !feasible(g, ty, ry) {
                    continue;
                }
                if let Produced::Tile(pt) = prod {
                    if !feasible(g, tz, pt) {
                        continue;
                    }
                }
                let cin = conversion_cost(bx, Produced::Tile(ins[0]), rx)
                    + conversion_cost(by, Produced::Tile(ins[1]), ry);
                let cout = conversion_cost(bz, prod, out);
                consider(OpCostBreakdown { form: label, input_bytes: cin, output_bytes: cout });
            }
        }
        Sem::Grid { splittable, in_maps, out_map, allow_replicated } => {
            let tz = op.outputs[0];
            let bz = g.tensors[tz].bytes();
            if allow_replicated {
                // Fully-replicated form: every input gathered, output
                // produced replicated (redundant local compute, no wire
                // traffic afterwards).
                let mut cin = 0u64;
                for (i, &t) in op.inputs.iter().enumerate() {
                    cin += conversion_cost(g.tensors[t].bytes(), Produced::Tile(ins[i]), Tile::Rep);
                }
                let cout = conversion_cost(bz, Produced::Tile(Tile::Rep), out);
                consider(OpCostBreakdown {
                    form: Form::Replicated,
                    input_bytes: cin,
                    output_bytes: cout,
                });
            }
            for (axis, &ok) in splittable.iter().enumerate() {
                if !ok {
                    continue;
                }
                let mut cin = 0u64;
                let mut feasible_form = true;
                for (i, map) in in_maps.iter().enumerate() {
                    let r = req_tile(map[axis]);
                    if !feasible(g, op.inputs[i], r) {
                        feasible_form = false;
                        break;
                    }
                    cin += conversion_cost(g.tensors[op.inputs[i]].bytes(), Produced::Tile(ins[i]), r);
                }
                if !feasible_form {
                    continue;
                }
                let prod = match out_map[axis] {
                    Some(d) => {
                        if !feasible(g, tz, Tile::Split(d)) {
                            continue;
                        }
                        Produced::Tile(Tile::Split(d))
                    }
                    None => Produced::Red,
                };
                let cout = conversion_cost(bz, prod, out);
                consider(OpCostBreakdown {
                    form: Form::GridAxis(axis as u8),
                    input_bytes: cin,
                    output_bytes: cout,
                });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, TensorKind};

    const R: Tile = Tile::Split(0);
    const C: Tile = Tile::Split(1);
    const REP: Tile = Tile::Rep;

    /// x[400,300] · w[300,300] -> z[400,300], the §2.2 layer.
    fn layer() -> (Graph, Op) {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[400, 300]);
        let w = b.weight("w", &[300, 300]);
        b.matmul("fc", x, w, false, false);
        let g = b.finish();
        let op = g.ops[0].clone();
        (g, op)
    }

    #[test]
    fn data_parallel_forward_is_free() {
        // R · r -> R: the aligned form itself; no conversions.
        let (g, op) = layer();
        assert_eq!(op_cost(&g, &op, &[R, REP], R), 0);
    }

    #[test]
    fn model_parallel_forward_pays_reduction() {
        // C · R -> red, then red -> C costs the output size.
        let (g, op) = layer();
        let bz = 400 * 300 * 4;
        assert_eq!(op_cost(&g, &op, &[C, R], C), bz);
    }

    #[test]
    fn column_parallel_forward_is_free() {
        // r · C -> C.
        let (g, op) = layer();
        assert_eq!(op_cost(&g, &op, &[REP, C], C), 0);
    }

    #[test]
    fn unaligned_inputs_pay_ghost_area() {
        // Figure 7(b): x arrives C, needs R for the R·r->R form: S_x/2.
        let (g, op) = layer();
        let bx: u64 = 400 * 300 * 4;
        assert_eq!(op_cost(&g, &op, &[C, REP], R), bx / 2);
    }

    #[test]
    fn weight_gradient_allreduce() {
        // dW = xᵀ · dz with x,dz row-tiled and dW replicated: the C·R->red
        // form is free on inputs, then red -> r costs 2·|W| — data
        // parallelism's gradient aggregation.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[400, 300]);
        let dz = b.input("dz", &[400, 300]);
        b.matmul("bwd_w", x, dz, true, false);
        let g = b.finish();
        let op = g.ops[0].clone();
        let bw: u64 = 300 * 300 * 4;
        assert_eq!(op_cost(&g, &op, &[R, R], REP), 2 * bw);
    }

    #[test]
    fn activation_gradient_under_dp_is_free() {
        // dx = dz · wᵀ with dz row-tiled, w replicated, dx row-tiled.
        let mut b = GraphBuilder::new();
        let dz = b.input("dz", &[400, 300]);
        let w = b.weight("w", &[300, 300]);
        b.matmul("bwd_data", dz, w, false, true);
        let g = b.finish();
        let op = g.ops[0].clone();
        assert_eq!(op_cost(&g, &op, &[R, REP], R), 0);
    }

    #[test]
    fn elementwise_same_tiling_free_mismatch_pays() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 32]);
        b.relu("relu", x);
        let g = b.finish();
        let op = g.ops[0].clone();
        assert_eq!(op_cost(&g, &op, &[R], R), 0);
        assert_eq!(op_cost(&g, &op, &[C], C), 0);
        // Input R but output C: convert either side; in+out = S/2 + 0 via
        // axis-1 form (input R->C is S/2) or 0 + S/2 via axis-0 form.
        let s: u64 = 64 * 32 * 4;
        assert_eq!(op_cost(&g, &op, &[R], C), s / 2);
    }

    #[test]
    fn elementwise_cannot_replicate_everything() {
        // All-replicated is redundant computation; the op still picks a
        // split form and pays to re-replicate its output.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 32]);
        b.relu("relu", x);
        let g = b.finish();
        let op = g.ops[0].clone();
        let s: u64 = 64 * 32 * 4;
        // input replicated (free to convert anywhere), output replicated:
        // must compute split then all-gather: S.
        assert_eq!(op_cost(&g, &op, &[REP], REP), s);
    }

    #[test]
    fn bias_add_batch_split_replicates_bias() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 32]);
        let bias = b.weight("b", &[32]);
        b.bias_add("ba", x, bias);
        let g = b.finish();
        let op = g.ops[0].clone();
        // batch-split x + replicated bias -> batch-split out: free.
        assert_eq!(op_cost(&g, &op, &[R, REP], R), 0);
        // col-split x + col-split bias -> col-split out: free.
        assert_eq!(op_cost(&g, &op, &[C, Tile::Split(0)], C), 0);
        // batch-split x with split bias: must gather the bias (tiny).
        let bias_bytes: u64 = 32 * 4;
        assert_eq!(op_cost(&g, &op, &[R, Tile::Split(0)], R), bias_bytes);
    }

    #[test]
    fn bias_grad_reduction_forms() {
        let mut b = GraphBuilder::new();
        let dz = b.input("dz", &[64, 32]);
        b.raw_op("db", OpKind::ReduceSumRows, vec![dz], &[32], TensorKind::WeightGrad);
        let g = b.finish();
        let op = g.ops[0].clone();
        // dz row-split -> partial sums -> red -> replicated vector: 2·|b|.
        let bb: u64 = 32 * 4;
        assert_eq!(op_cost(&g, &op, &[R], REP), 2 * bb);
        // dz col-split -> out split: free.
        assert_eq!(op_cost(&g, &op, &[C], Tile::Split(0)), 0);
    }

    #[test]
    fn softmax_only_batch_split() {
        let mut b = GraphBuilder::new();
        let logits = b.input("l", &[64, 10]);
        let y = b.label("y", &[64, 10]);
        b.softmax_xent("loss", logits, y);
        let g = b.finish();
        let op = g.ops[0].clone();
        // Batch-split inputs: free up to the scalar allreduce (8 bytes).
        assert_eq!(op_cost(&g, &op, &[R, R], REP), 8);
        // Class-split inputs must be converted: S/2 each.
        let s: u64 = 64 * 10 * 4;
        assert_eq!(op_cost(&g, &op, &[C, C], REP), s + 8);
    }

    #[test]
    fn conv_forward_batch_split_free() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 6, 6, 4]);
        let w = b.weight("w", &[3, 3, 4, 16]);
        b.conv2d("c", x, w, 1, 1);
        let g = b.finish();
        let op = g.ops[0].clone();
        // Data parallelism on conv: batch-split activations, replicated
        // filters, batch-split output — aligned form 1, free.
        assert_eq!(op_cost(&g, &op, &[Tile::Split(0), REP], Tile::Split(0)), 0);
        // Model parallelism: split output channels of the filter.
        assert_eq!(op_cost(&g, &op, &[REP, Tile::Split(3)], Tile::Split(3)), 0);
    }

    #[test]
    fn conv_bwd_filter_aggregation() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 6, 6, 4]);
        let dz = b.input("dz", &[8, 6, 6, 16]);
        b.raw_op(
            "dw",
            OpKind::Conv2dBwdFilter { stride: 1, pad: 1 },
            vec![x, dz],
            &[3, 3, 4, 16],
            TensorKind::WeightGrad,
        );
        let g = b.finish();
        let op = g.ops[0].clone();
        // Batch-split x and dz, replicated dW: C·R->red then allreduce.
        let bw: u64 = 3 * 3 * 4 * 16 * 4;
        assert_eq!(op_cost(&g, &op, &[Tile::Split(0), Tile::Split(0)], REP), 2 * bw);
    }

    #[test]
    fn infeasible_when_no_form_fits() {
        // A matmul whose every dimension is odd cannot be evenly tiled.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 5]);
        let w = b.weight("w", &[5, 7]);
        b.matmul("odd", x, w, false, false);
        let g = b.finish();
        let op = g.ops[0].clone();
        assert_eq!(op_cost(&g, &op, &[REP, REP], REP), INFEASIBLE);
    }

    #[test]
    fn batched_matmul_batch_split_free() {
        // ctx = probs · V over batch/head groups: the batch form is free
        // when everything is batch-tiled — the data-parallel attention.
        let mut b = GraphBuilder::new();
        let p = b.input("p", &[4, 6, 8]);
        let v = b.input("v", &[4, 8, 10]);
        b.batched_matmul("ctx", p, v, false, false);
        let g = b.finish();
        let op = g.ops[0].clone();
        let s0 = Tile::Split(0);
        assert_eq!(op_cost(&g, &op, &[s0, s0], s0), 0);
        // All-replicated: must compute split and re-gather the output.
        let bz: u64 = 4 * 6 * 10 * 4;
        assert_eq!(op_cost(&g, &op, &[REP, REP], REP), bz);
    }

    #[test]
    fn batched_matmul_contraction_reduces() {
        // QKᵀ with the contraction axis split: C·R->red per batch element,
        // then red -> batch-split costs the output bytes.
        let mut b = GraphBuilder::new();
        let q = b.input("q", &[2, 4, 6]);
        let k = b.input("k", &[2, 8, 6]);
        b.batched_matmul("scores", q, k, false, true);
        let g = b.finish();
        let op = g.ops[0].clone();
        let s0 = Tile::Split(0);
        // Feature-split inputs (the contraction dim, stored dim 2 of both
        // under tb=true): the k-axis form applies with no input conversion,
        // output produced red then scattered to Split(0).
        let bz: u64 = 2 * 4 * 8 * 4;
        assert_eq!(op_cost(&g, &op, &[Tile::Split(2), Tile::Split(2)], s0), bz);
    }

    #[test]
    fn layer_norm_row_wise() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 32]);
        let ga = b.weight("g", &[32]);
        let be = b.weight("b", &[32]);
        b.layer_norm("ln", x, ga, be);
        let g = b.finish();
        let op = g.ops[0].clone();
        // Batch-split x with replicated params: the aligned form itself.
        assert_eq!(op_cost(&g, &op, &[R, REP, REP], R), 0);
        // Column-split x must be converted (row statistics): S_x/2.
        let bx: u64 = 64 * 32 * 4;
        assert_eq!(op_cost(&g, &op, &[C, REP, REP], R), bx / 2);
        // Split params must be gathered (tiny vectors).
        let bv: u64 = 32 * 4;
        assert_eq!(op_cost(&g, &op, &[R, Tile::Split(0), Tile::Split(0)], R), 2 * bv);
    }

    #[test]
    fn layer_norm_gamma_grad_reduction_forms() {
        let mut b = GraphBuilder::new();
        let dy = b.input("dy", &[64, 32]);
        let x = b.input("x", &[64, 32]);
        b.raw_op("dg", OpKind::LayerNormGammaGrad, vec![dy, x], &[32], TensorKind::WeightGrad);
        let g = b.finish();
        let op = g.ops[0].clone();
        // Batch-split operands -> partial sums -> replicated vector: 2·|g|.
        let bv: u64 = 32 * 4;
        assert_eq!(op_cost(&g, &op, &[R, R], REP), 2 * bv);
        // Feature-split operands -> split output: dy stays sliced for
        // free, but x must be gathered whole-row (the kernel recomputes
        // per-row statistics), costing S_x — the ISSUE-5 semantic fix.
        let bx: u64 = 64 * 32 * 4;
        assert_eq!(op_cost(&g, &op, &[C, C], Tile::Split(0)), bx);
    }

    #[test]
    fn softmax_rows_never_splits_last_axis() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 6, 8]);
        b.softmax_rows("probs", x);
        let g = b.finish();
        let op = g.ops[0].clone();
        let s0 = Tile::Split(0);
        assert_eq!(op_cost(&g, &op, &[s0], s0), 0);
        // Query-row splits are also aligned (axis 1).
        assert_eq!(op_cost(&g, &op, &[Tile::Split(1)], Tile::Split(1)), 0);
        // A last-axis tiling has no aligned form of its own: convert in
        // and out through a row form, paying S/2 each way.
        let s: u64 = 4 * 6 * 8 * 4;
        assert_eq!(op_cost(&g, &op, &[Tile::Split(2)], Tile::Split(2)), s);
    }

    #[test]
    fn head_view_reshapes_share_batch_tiling() {
        // qkv [B·S, 3D] -> slice -> [B·H, S, D/H]: batch split on both
        // sides is the one free form; anything else converts.
        let mut b = GraphBuilder::new();
        let qkv = b.input("qkv", &[8, 24]);
        let qh = b.qkv_slice("sq", qkv, 0, 2, 4);
        b.merge_heads("mh", qh, 2);
        let g = b.finish();
        let slice = g.ops[0].clone();
        let merge = g.ops[1].clone();
        let s0 = Tile::Split(0);
        assert_eq!(op_cost(&g, &slice, &[s0], s0), 0);
        assert_eq!(op_cost(&g, &merge, &[s0], s0), 0);
        // Column-split qkv arrives misaligned: S_qkv/2 to re-tile rows.
        let b_qkv: u64 = 8 * 24 * 4;
        assert_eq!(op_cost(&g, &slice, &[C], s0), b_qkv / 2);
        // Replicated slice output costs the (smaller) head-view bytes.
        let b_qh: u64 = 4 * 4 * 4 * 4;
        assert_eq!(op_cost(&g, &slice, &[s0], REP), b_qh);
    }

    #[test]
    fn qkv_concat_batch_form() {
        let mut b = GraphBuilder::new();
        let dq = b.input("dq", &[4, 4, 4]);
        let dk = b.input("dk", &[4, 4, 4]);
        let dv = b.input("dv", &[4, 4, 4]);
        b.raw_op("cat", OpKind::QkvConcat, vec![dq, dk, dv], &[8, 24], TensorKind::Gradient);
        let g = b.finish();
        let op = g.ops[0].clone();
        let s0 = Tile::Split(0);
        assert_eq!(op_cost(&g, &op, &[s0, s0, s0], s0), 0);
        // Gathering the concatenated gradient costs its full size.
        let b_out: u64 = 8 * 24 * 4;
        assert_eq!(op_cost(&g, &op, &[s0, s0, s0], REP), b_out);
    }

    #[test]
    fn batch_only_classifier_matches_grid_semantics() {
        // `OpKind::batch_only` and the aligned-form tables are two
        // encodings of one fact; pin them together over the transformer op
        // set (plus a row-wise loss and an elementwise counterexample):
        // each of these ops is batch-only iff its grid admits exactly one
        // splittable logical axis.
        let mut b = GraphBuilder::new();
        let qkv = b.input("qkv", &[8, 24]);
        let qh = b.qkv_slice("sq", qkv, 0, 2, 4);
        let kh = b.qkv_slice("sk", qkv, 1, 2, 4);
        b.batched_matmul("scores", qh, kh, false, true);
        b.softmax_rows("probs", qh);
        b.merge_heads("mh", qh, 2);
        let x = b.input("x", &[8, 8]);
        let ga = b.weight("g", &[8]);
        let be = b.weight("be", &[8]);
        b.layer_norm("ln", x, ga, be);
        let y = b.label("y", &[8, 8]);
        b.softmax_xent("loss", x, y);
        b.relu("relu", x);
        let g = b.finish();
        for op in &g.ops {
            match semantics(&g, op) {
                Sem::Grid { splittable, .. } => {
                    let n_split = splittable.iter().filter(|&&s| s).count();
                    assert_eq!(
                        op.kind.batch_only(),
                        n_split == 1,
                        "batch_only disagrees with grid semantics for {}",
                        op.name
                    );
                }
                Sem::MatMulLike { .. } => assert!(!op.kind.batch_only(), "{}", op.name),
            }
        }
    }

    #[test]
    fn gelu_and_ident_are_elementwise() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[64, 32]);
        let ge = b.gelu("gelu", x);
        b.ident("wire", ge);
        let g = b.finish();
        let gelu = g.ops[0].clone();
        let wire = g.ops[1].clone();
        let s: u64 = 64 * 32 * 4;
        assert_eq!(op_cost(&g, &gelu, &[R], R), 0);
        assert_eq!(op_cost(&g, &wire, &[C], C), 0);
        // A wire hop with a tiling change prices exactly one conversion.
        assert_eq!(op_cost(&g, &wire, &[R], C), s / 2);
    }

    #[test]
    fn sgd_update_same_split_free() {
        let mut b = GraphBuilder::new();
        let w = b.weight("w", &[300, 300]);
        let gr = b.input("g", &[300, 300]);
        b.raw_op("sgd", OpKind::SgdUpdate, vec![w, gr], &[300, 300], TensorKind::UpdatedWeight);
        let g = b.finish();
        let op = g.ops[0].clone();
        assert_eq!(op_cost(&g, &op, &[R, R], R), 0);
        assert_eq!(op_cost(&g, &op, &[C, C], C), 0);
        // Replicated weights with replicated grads (post-aggregation DP):
        // the update is applied redundantly on every device — free. This is
        // the one operator where the all-replicated form is admitted.
        assert_eq!(op_cost(&g, &op, &[REP, REP], REP), 0);
    }
}
