//! Precomputed per-op cost lookup tables — the planner's hot-path fuel.
//!
//! Eq. (2) is a pure function of `(op, input tiles, output tile)`, and a
//! tensor has at most `rank + 1` candidate tiles ([`candidate_tiles`]), so
//! the full cost surface of one operator fits in a tiny dense table: a
//! matmul over matrices is 3×3×3 = 27 entries, a 4-D grid op at most
//! 3⁴ = 81. [`CostTables::build_with`] evaluates every combination once
//! per graph; after that the one-cut DP's component tabulation and level
//! sweep ([`crate::planner`]) are pure table-lookup + add — no aligned-form
//! re-derivation, no per-visit allocation.
//!
//! Indexing is mixed-radix over *candidate indices*: operand `i` (inputs in
//! op order, then the first output) contributes `digit_i · mults[i]`, where
//! `digit_i` is the position of the chosen tile in the operand's candidate
//! list. Operands are steady-state alias representatives
//! ([`Graph::steady_state_aliases`]), matching the variables the planner
//! actually enumerates; an aliased tensor (e.g. an updated weight) shares
//! its representative's digit.

use crate::graph::{Graph, TensorId};
use crate::util::radix::{mults_of, odometer_inc};

use super::aligned::{op_cost, INFEASIBLE};
use super::scheme::{candidate_tiles, Tile};

/// Fixed-point picosecond pricing of one cut's conversions — the bridge
/// between the byte-valued Eq. (2) tables and a tier of a hierarchical
/// interconnect (ISSUE-4's topology-aware planning).
///
/// Byte counts are exact integers; wall-clock is not. To keep the one-cut
/// DP's integer arithmetic (and its deterministic tie-breaking), seconds
/// are modeled on a `1/256` picosecond grid:
///
/// `weighted(bytes) = bytes · ps_per_byte_fp + latency_fp · [bytes > 0]`
///
/// where `ps_per_byte_fp` is the tier's *effective* picoseconds per
/// pair-byte (`2^j / (bandwidth · min(slots, 2^j))`, all `2^j`
/// simultaneous group pairs of cut `j` sharing the contention-capped
/// aggregate — the same rule [`crate::sim::Topology::transfer_seconds`]
/// prices) and `latency_fp` charges the tier's startup latency once per
/// costed op, mirroring the analytic model's per-op-cut latency term.
///
/// The map is strictly monotone in bytes (`ps_per_byte_fp >= 1`), so for a
/// *uniform* hierarchy with zero latency the weighted argmin is exactly the
/// byte argmin — hierarchy and latency are the only two ways a weighted
/// plan can diverge from the byte plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CutCostModel {
    /// Picoseconds per pair-byte across this cut, ×[`Self::FP_ONE`].
    pub ps_per_byte_fp: u64,
    /// Fixed startup charge per costed op at this cut, ×[`Self::FP_ONE`].
    pub latency_fp: u64,
}

impl CutCostModel {
    /// Fixed-point scale: stored weights are picoseconds × 256.
    pub const FP_ONE: u64 = 256;

    /// Build from SI seconds (per pair-byte, and per-transfer latency).
    /// The per-byte weight is floored at one fixed-point unit so the map
    /// stays strictly monotone even for near-infinite bandwidth.
    pub fn from_seconds(seconds_per_byte: f64, latency_s: f64) -> Self {
        let fp = Self::FP_ONE as f64;
        CutCostModel {
            ps_per_byte_fp: ((seconds_per_byte * 1e12 * fp).round() as u64).max(1),
            latency_fp: (latency_s * 1e12 * fp).round() as u64,
        }
    }

    /// The byte objective itself (weight 1, no latency): weighted tables
    /// built with this model order plans exactly like the byte tables.
    pub fn bytes() -> Self {
        CutCostModel { ps_per_byte_fp: 1, latency_fp: 0 }
    }

    /// Price `bytes` of conversion volume, clamped below
    /// [`INFEASIBLE`](crate::tiling::aligned::INFEASIBLE) so a weighted
    /// entry can never masquerade as "no aligned form". Sums of weighted
    /// entries can still saturate past the sentinel once a single cut
    /// models more than ~70 seconds (`INFEASIBLE` fixed-point units);
    /// the weighted DP detects that and falls back to the byte objective
    /// ([`crate::planner::OneCutSolver::solve_weighted`]) rather than
    /// reporting a feasible plan as infeasible.
    pub fn weight(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        bytes
            .saturating_mul(self.ps_per_byte_fp)
            .saturating_add(self.latency_fp)
            .min(INFEASIBLE - 1)
    }

    /// Decode a weighted total back to SI seconds (approximate — latency
    /// charges are folded in), for reports and the drift-pinning test in
    /// [`crate::sim`].
    pub fn seconds(&self, bytes: u64) -> f64 {
        self.weight(bytes) as f64 / (Self::FP_ONE as f64 * 1e12)
    }
}

/// The dense Eq. (2) table of one operator.
#[derive(Debug, Clone)]
pub struct OpCostTable {
    /// Operand tensors as alias representatives: the op's inputs in order,
    /// then its first output.
    pub operands: Vec<TensorId>,
    /// Mixed-radix multiplier per operand; the radix of operand `i` is its
    /// representative's candidate count.
    pub mults: Vec<usize>,
    /// `costs[Σ digit_i · mults[i]]` — `INFEASIBLE` where no aligned form
    /// is realizable.
    pub costs: Vec<u64>,
}

impl OpCostTable {
    /// Table index for a digit assignment supplied per tensor. A tensor
    /// appearing as several operands (e.g. the weight of an `SgdUpdate`,
    /// which is both input and aliased output) must receive the same digit
    /// each time — exactly what a per-tensor assignment guarantees.
    pub fn index_by(&self, digit_of: impl Fn(TensorId) -> usize) -> usize {
        let mut idx = 0;
        for (i, &t) in self.operands.iter().enumerate() {
            idx += digit_of(t) * self.mults[i];
        }
        idx
    }
}

/// All per-op cost tables of one graph, plus the candidate lists and alias
/// map they are indexed under.
#[derive(Debug, Clone)]
pub struct CostTables {
    /// Steady-state alias map the tables were built under.
    pub alias: Vec<TensorId>,
    /// Candidate tiles per tensor id (authoritative for representatives).
    pub cands: Vec<Vec<Tile>>,
    /// One table per op, indexed by `OpId`.
    pub ops: Vec<OpCostTable>,
}

impl CostTables {
    /// Build the tables for `g` under its own steady-state alias map.
    pub fn build(g: &Graph) -> Self {
        Self::build_with(g, &g.steady_state_aliases())
    }

    /// Build the tables for `g` under a caller-supplied alias map. The
    /// k-cut recursion reuses one alias map (topology never changes across
    /// cuts) while rebuilding the numeric tables for each halved graph.
    pub fn build_with(g: &Graph, alias: &[TensorId]) -> Self {
        let cands: Vec<Vec<Tile>> = g.tensors.iter().map(candidate_tiles).collect();
        let mut ops = Vec::with_capacity(g.ops.len());
        let mut ins: Vec<Tile> = Vec::new();
        let mut digits: Vec<usize> = Vec::new();
        for op in &g.ops {
            let mut operands: Vec<TensorId> = op.inputs.iter().map(|&t| alias[t]).collect();
            operands.push(alias[op.outputs[0]]);
            let radix: Vec<usize> = operands.iter().map(|&t| cands[t].len()).collect();
            let (mults, total) = mults_of(&radix);

            // Enumerate every combination with a mixed-radix odometer.
            let mut costs = vec![0u64; total];
            digits.clear();
            digits.resize(operands.len(), 0);
            ins.clear();
            ins.resize(op.inputs.len(), Tile::Rep);
            for entry in costs.iter_mut() {
                for (i, &t) in operands.iter().enumerate() {
                    let tile = cands[t][digits[i]];
                    if i < op.inputs.len() {
                        ins[i] = tile;
                    }
                }
                let out = cands[operands[op.inputs.len()]][digits[op.inputs.len()]];
                *entry = op_cost(g, op, &ins, out);
                odometer_inc(&mut digits, &radix);
            }
            ops.push(OpCostTable { operands, mults, costs });
        }
        CostTables { alias: alias.to_vec(), cands, ops }
    }

    /// The per-tier *weighted* twin of these tables: every finite entry is
    /// re-priced from conversion bytes to fixed-point picoseconds under
    /// `w` ([`CutCostModel::weight`]); `INFEASIBLE` entries stay
    /// `INFEASIBLE`. Because Eq. (2) minimizes over aligned forms and the
    /// weighting is monotone nondecreasing in bytes, mapping the already-
    /// minimized table is exact: `min_form w(bytes(form)) =
    /// w(min_form bytes(form))`.
    ///
    /// This is what [`crate::planner::OneCutSolver::solve_weighted`] feeds
    /// the odometer DP, so the DP minimizes *modeled time on the cut's
    /// tier* instead of raw bytes. Consumes `self` and re-prices in place
    /// — the weighted solve path stays allocation-free beyond the byte
    /// tables it starts from.
    pub fn weighted(mut self, w: &CutCostModel) -> CostTables {
        for t in &mut self.ops {
            for c in &mut t.costs {
                *c = if *c >= INFEASIBLE { INFEASIBLE } else { w.weight(*c) };
            }
        }
        self
    }

    /// Total plan cost read through the tables — the LUT twin of
    /// [`crate::planner::price`], used to cross-check table contents
    /// against direct Eq. (2) evaluation. `tiles` must be alias-resolved
    /// (every tensor carries its representative's tile).
    pub fn price(&self, tiles: &[Tile]) -> u64 {
        let mut total = 0u64;
        for t in &self.ops {
            let idx = t.index_by(|tid| {
                self.cands[tid]
                    .iter()
                    .position(|&c| c == tiles[tid])
                    .expect("tile outside the candidate set")
            });
            total = total.saturating_add(t.costs[idx]);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};
    use crate::tiling::aligned::INFEASIBLE;
    use crate::util::Rng;

    const R: Tile = Tile::Split(0);
    const C: Tile = Tile::Split(1);

    fn train_graph(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        for l in 0..dims.len() - 1 {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn table_entries_equal_direct_op_cost() {
        let g = train_graph(64, &[32, 48, 16]);
        let tables = CostTables::build(&g);
        // Spot-check every entry of every table against a fresh Eq. (2)
        // evaluation via explicit digit decoding.
        for (op, t) in g.ops.iter().zip(&tables.ops) {
            let total = t.costs.len();
            for idx in 0..total {
                let mut rem = idx;
                let tiles: Vec<Tile> = t
                    .operands
                    .iter()
                    .map(|&tid| {
                        let r = tables.cands[tid].len();
                        let tile = tables.cands[tid][rem % r];
                        rem /= r;
                        tile
                    })
                    .collect();
                let ins = &tiles[..op.inputs.len()];
                let out = tiles[op.inputs.len()];
                assert_eq!(t.costs[idx], op_cost(&g, op, ins, out), "op {} idx {idx}", op.name);
            }
        }
    }

    #[test]
    fn matmul_table_matches_known_corners() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[400, 300]);
        let w = b.weight("w", &[300, 300]);
        b.matmul("fc", x, w, false, false);
        let g = b.finish();
        let tables = CostTables::build(&g);
        let t = &tables.ops[0];
        let digit = |tid: usize, tile: Tile| {
            tables.cands[tid].iter().position(|&c| c == tile).unwrap()
        };
        let idx = |ix: Tile, iw: Tile, iz: Tile| {
            digit(0, ix) * t.mults[0] + digit(1, iw) * t.mults[1] + digit(2, iz) * t.mults[2]
        };
        // Data-parallel forward is free; model-parallel pays the output.
        assert_eq!(t.costs[idx(R, Tile::Rep, R)], 0);
        assert_eq!(t.costs[idx(C, R, C)], 400 * 300 * 4);
    }

    #[test]
    fn infeasible_combinations_are_marked() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 5]);
        let w = b.weight("w", &[5, 7]);
        b.matmul("odd", x, w, false, false);
        let g = b.finish();
        let tables = CostTables::build(&g);
        // Every dimension odd: only replication is a candidate and no
        // aligned form fits — the single entry is INFEASIBLE.
        assert_eq!(tables.ops[0].costs, vec![INFEASIBLE]);
    }

    #[test]
    fn lut_price_matches_direct_price_on_random_assignments() {
        let g = train_graph(16, &[8, 4, 6]);
        let tables = CostTables::build(&g);
        let alias = g.steady_state_aliases();
        let mut rng = Rng::new(99);
        for _ in 0..300 {
            let mut tiles: Vec<Tile> =
                g.tensors.iter().map(|t| *rng.choose(&tables.cands[t.id])).collect();
            for t in 0..tiles.len() {
                tiles[t] = tiles[alias[t]];
            }
            assert_eq!(tables.price(&tiles), crate::planner::price(&g, &tiles));
        }
    }

    #[test]
    fn lut_price_matches_direct_price_on_transformer_graph() {
        // The full tiny transformer training step: every new op kind
        // (batched matmuls with both transpose patterns, layer norm +
        // grads, row softmax + grad, gelu, head-view reshapes, identity
        // wires) goes through the LUT path and must reprice identically to
        // direct Eq. (2) evaluation on random assignments.
        let g = crate::models::transformer(&crate::models::TransformerConfig::tiny());
        let tables = CostTables::build(&g);
        let alias = g.steady_state_aliases();
        let mut rng = Rng::new(0x5EED);
        for _ in 0..200 {
            let mut tiles: Vec<Tile> =
                g.tensors.iter().map(|t| *rng.choose(&tables.cands[t.id])).collect();
            for t in 0..tiles.len() {
                tiles[t] = tiles[alias[t]];
            }
            assert_eq!(tables.price(&tiles), crate::planner::price(&g, &tiles));
        }
    }

    #[test]
    fn weighted_tables_map_entries_pointwise() {
        let g = train_graph(64, &[32, 48, 16]);
        let tables = CostTables::build(&g);
        let w = CutCostModel { ps_per_byte_fp: 800, latency_fp: 5_000_000 };
        let wt = CostTables::build(&g).weighted(&w);
        for (t, tw) in tables.ops.iter().zip(&wt.ops) {
            assert_eq!(t.operands, tw.operands);
            assert_eq!(t.mults, tw.mults);
            for (&c, &cw) in t.costs.iter().zip(&tw.costs) {
                if c >= INFEASIBLE {
                    assert_eq!(cw, INFEASIBLE);
                } else if c == 0 {
                    assert_eq!(cw, 0);
                } else {
                    assert_eq!(cw, c * 800 + 5_000_000);
                }
            }
        }
    }

    #[test]
    fn byte_model_weighting_is_identity() {
        let g = train_graph(16, &[8, 4, 6]);
        let tables = CostTables::build(&g);
        let wt = CostTables::build(&g).weighted(&CutCostModel::bytes());
        for (t, tw) in tables.ops.iter().zip(&wt.ops) {
            assert_eq!(t.costs, tw.costs);
        }
    }

    #[test]
    fn cut_cost_model_from_seconds_rounds_on_fp_grid() {
        // 1 GB/s, 1 pair, 10 us latency: 1000 ps/byte and 1e7 ps.
        let w = CutCostModel::from_seconds(1.0 / 1.0e9, 10e-6);
        assert_eq!(w.ps_per_byte_fp, 1000 * CutCostModel::FP_ONE);
        assert_eq!(w.latency_fp, 10_000_000 * CutCostModel::FP_ONE);
        assert_eq!(w.weight(0), 0);
        assert_eq!(w.weight(100), 100 * 1000 * 256 + 10_000_000 * 256);
        // Infinite bandwidth floors at one fixed-point unit per byte —
        // strict monotonicity survives.
        let free = CutCostModel::from_seconds(0.0, 0.0);
        assert_eq!(free.ps_per_byte_fp, 1);
        assert!(free.weight(5) < free.weight(6));
        // Weighted prices never collide with the infeasibility sentinel.
        let w = CutCostModel { ps_per_byte_fp: u64::MAX / 2, latency_fp: u64::MAX / 2 };
        assert!(w.weight(u64::MAX / 2) < INFEASIBLE);
    }

    #[test]
    fn transformer_tables_stay_dense_and_small() {
        // Rank-3 candidate pruning keeps every per-op table tiny: the
        // biggest surface is a batched matmul over three rank-3 operands
        // (2³ = 8 entries padded by the rank-2 neighbours' radix).
        let g = crate::models::transformer(&crate::models::TransformerConfig::tiny());
        let tables = CostTables::build(&g);
        for (op, t) in g.ops.iter().zip(&tables.ops) {
            assert!(t.costs.len() <= 81, "op {} table has {} entries", op.name, t.costs.len());
        }
    }
}
