//! Precomputed per-op cost lookup tables — the planner's hot-path fuel.
//!
//! Eq. (2) is a pure function of `(op, input tiles, output tile)`, and a
//! tensor has at most `rank + 1` candidate tiles ([`candidate_tiles`]), so
//! the full cost surface of one operator fits in a tiny dense table: a
//! matmul over matrices is 3×3×3 = 27 entries, a 4-D grid op at most
//! 3⁴ = 81. [`CostTables::build_with`] evaluates every combination once
//! per graph; after that the one-cut DP's component tabulation and level
//! sweep ([`crate::planner`]) are pure table-lookup + add — no aligned-form
//! re-derivation, no per-visit allocation.
//!
//! Indexing is mixed-radix over *candidate indices*: operand `i` (inputs in
//! op order, then the first output) contributes `digit_i · mults[i]`, where
//! `digit_i` is the position of the chosen tile in the operand's candidate
//! list. Operands are steady-state alias representatives
//! ([`Graph::steady_state_aliases`]), matching the variables the planner
//! actually enumerates; an aliased tensor (e.g. an updated weight) shares
//! its representative's digit.

use crate::graph::{Graph, TensorId};
use crate::util::radix::{mults_of, odometer_inc};

use super::aligned::op_cost;
use super::scheme::{candidate_tiles, Tile};

/// The dense Eq. (2) table of one operator.
#[derive(Debug, Clone)]
pub struct OpCostTable {
    /// Operand tensors as alias representatives: the op's inputs in order,
    /// then its first output.
    pub operands: Vec<TensorId>,
    /// Mixed-radix multiplier per operand; the radix of operand `i` is its
    /// representative's candidate count.
    pub mults: Vec<usize>,
    /// `costs[Σ digit_i · mults[i]]` — `INFEASIBLE` where no aligned form
    /// is realizable.
    pub costs: Vec<u64>,
}

impl OpCostTable {
    /// Table index for a digit assignment supplied per tensor. A tensor
    /// appearing as several operands (e.g. the weight of an `SgdUpdate`,
    /// which is both input and aliased output) must receive the same digit
    /// each time — exactly what a per-tensor assignment guarantees.
    pub fn index_by(&self, digit_of: impl Fn(TensorId) -> usize) -> usize {
        let mut idx = 0;
        for (i, &t) in self.operands.iter().enumerate() {
            idx += digit_of(t) * self.mults[i];
        }
        idx
    }
}

/// All per-op cost tables of one graph, plus the candidate lists and alias
/// map they are indexed under.
#[derive(Debug, Clone)]
pub struct CostTables {
    /// Steady-state alias map the tables were built under.
    pub alias: Vec<TensorId>,
    /// Candidate tiles per tensor id (authoritative for representatives).
    pub cands: Vec<Vec<Tile>>,
    /// One table per op, indexed by `OpId`.
    pub ops: Vec<OpCostTable>,
}

impl CostTables {
    /// Build the tables for `g` under its own steady-state alias map.
    pub fn build(g: &Graph) -> Self {
        Self::build_with(g, &g.steady_state_aliases())
    }

    /// Build the tables for `g` under a caller-supplied alias map. The
    /// k-cut recursion reuses one alias map (topology never changes across
    /// cuts) while rebuilding the numeric tables for each halved graph.
    pub fn build_with(g: &Graph, alias: &[TensorId]) -> Self {
        let cands: Vec<Vec<Tile>> = g.tensors.iter().map(candidate_tiles).collect();
        let mut ops = Vec::with_capacity(g.ops.len());
        let mut ins: Vec<Tile> = Vec::new();
        let mut digits: Vec<usize> = Vec::new();
        for op in &g.ops {
            let mut operands: Vec<TensorId> = op.inputs.iter().map(|&t| alias[t]).collect();
            operands.push(alias[op.outputs[0]]);
            let radix: Vec<usize> = operands.iter().map(|&t| cands[t].len()).collect();
            let (mults, total) = mults_of(&radix);

            // Enumerate every combination with a mixed-radix odometer.
            let mut costs = vec![0u64; total];
            digits.clear();
            digits.resize(operands.len(), 0);
            ins.clear();
            ins.resize(op.inputs.len(), Tile::Rep);
            for entry in costs.iter_mut() {
                for (i, &t) in operands.iter().enumerate() {
                    let tile = cands[t][digits[i]];
                    if i < op.inputs.len() {
                        ins[i] = tile;
                    }
                }
                let out = cands[operands[op.inputs.len()]][digits[op.inputs.len()]];
                *entry = op_cost(g, op, &ins, out);
                odometer_inc(&mut digits, &radix);
            }
            ops.push(OpCostTable { operands, mults, costs });
        }
        CostTables { alias: alias.to_vec(), cands, ops }
    }

    /// Total plan cost read through the tables — the LUT twin of
    /// [`crate::planner::price`], used to cross-check table contents
    /// against direct Eq. (2) evaluation. `tiles` must be alias-resolved
    /// (every tensor carries its representative's tile).
    pub fn price(&self, tiles: &[Tile]) -> u64 {
        let mut total = 0u64;
        for t in &self.ops {
            let idx = t.index_by(|tid| {
                self.cands[tid]
                    .iter()
                    .position(|&c| c == tiles[tid])
                    .expect("tile outside the candidate set")
            });
            total = total.saturating_add(t.costs[idx]);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};
    use crate::tiling::aligned::INFEASIBLE;
    use crate::util::Rng;

    const R: Tile = Tile::Split(0);
    const C: Tile = Tile::Split(1);

    fn train_graph(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        for l in 0..dims.len() - 1 {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn table_entries_equal_direct_op_cost() {
        let g = train_graph(64, &[32, 48, 16]);
        let tables = CostTables::build(&g);
        // Spot-check every entry of every table against a fresh Eq. (2)
        // evaluation via explicit digit decoding.
        for (op, t) in g.ops.iter().zip(&tables.ops) {
            let total = t.costs.len();
            for idx in 0..total {
                let mut rem = idx;
                let tiles: Vec<Tile> = t
                    .operands
                    .iter()
                    .map(|&tid| {
                        let r = tables.cands[tid].len();
                        let tile = tables.cands[tid][rem % r];
                        rem /= r;
                        tile
                    })
                    .collect();
                let ins = &tiles[..op.inputs.len()];
                let out = tiles[op.inputs.len()];
                assert_eq!(t.costs[idx], op_cost(&g, op, ins, out), "op {} idx {idx}", op.name);
            }
        }
    }

    #[test]
    fn matmul_table_matches_known_corners() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[400, 300]);
        let w = b.weight("w", &[300, 300]);
        b.matmul("fc", x, w, false, false);
        let g = b.finish();
        let tables = CostTables::build(&g);
        let t = &tables.ops[0];
        let digit = |tid: usize, tile: Tile| {
            tables.cands[tid].iter().position(|&c| c == tile).unwrap()
        };
        let idx = |ix: Tile, iw: Tile, iz: Tile| {
            digit(0, ix) * t.mults[0] + digit(1, iw) * t.mults[1] + digit(2, iz) * t.mults[2]
        };
        // Data-parallel forward is free; model-parallel pays the output.
        assert_eq!(t.costs[idx(R, Tile::Rep, R)], 0);
        assert_eq!(t.costs[idx(C, R, C)], 400 * 300 * 4);
    }

    #[test]
    fn infeasible_combinations_are_marked() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 5]);
        let w = b.weight("w", &[5, 7]);
        b.matmul("odd", x, w, false, false);
        let g = b.finish();
        let tables = CostTables::build(&g);
        // Every dimension odd: only replication is a candidate and no
        // aligned form fits — the single entry is INFEASIBLE.
        assert_eq!(tables.ops[0].costs, vec![INFEASIBLE]);
    }

    #[test]
    fn lut_price_matches_direct_price_on_random_assignments() {
        let g = train_graph(16, &[8, 4, 6]);
        let tables = CostTables::build(&g);
        let alias = g.steady_state_aliases();
        let mut rng = Rng::new(99);
        for _ in 0..300 {
            let mut tiles: Vec<Tile> =
                g.tensors.iter().map(|t| *rng.choose(&tables.cands[t.id])).collect();
            for t in 0..tiles.len() {
                tiles[t] = tiles[alias[t]];
            }
            assert_eq!(tables.price(&tiles), crate::planner::price(&g, &tiles));
        }
    }

    #[test]
    fn lut_price_matches_direct_price_on_transformer_graph() {
        // The full tiny transformer training step: every new op kind
        // (batched matmuls with both transpose patterns, layer norm +
        // grads, row softmax + grad, gelu, head-view reshapes, identity
        // wires) goes through the LUT path and must reprice identically to
        // direct Eq. (2) evaluation on random assignments.
        let g = crate::models::transformer(&crate::models::TransformerConfig::tiny());
        let tables = CostTables::build(&g);
        let alias = g.steady_state_aliases();
        let mut rng = Rng::new(0x5EED);
        for _ in 0..200 {
            let mut tiles: Vec<Tile> =
                g.tensors.iter().map(|t| *rng.choose(&tables.cands[t.id])).collect();
            for t in 0..tiles.len() {
                tiles[t] = tiles[alias[t]];
            }
            assert_eq!(tables.price(&tiles), crate::planner::price(&g, &tiles));
        }
    }

    #[test]
    fn transformer_tables_stay_dense_and_small() {
        // Rank-3 candidate pruning keeps every per-op table tiny: the
        // biggest surface is a batched matmul over three rank-3 operands
        // (2³ = 8 entries padded by the rank-2 neighbours' radix).
        let g = crate::models::transformer(&crate::models::TransformerConfig::tiny());
        let tables = CostTables::build(&g);
        for (op, t) in g.ops.iter().zip(&tables.ops) {
            assert!(t.costs.len() <= 81, "op {} table has {} entries", op.name, t.costs.len());
        }
    }
}
