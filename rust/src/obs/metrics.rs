//! Named monotonic counters and histograms shared by the executor, the
//! recovery loop, and the serve engine.
//!
//! [`Histogram`] is the percentile machinery that used to live inside
//! `serve::stats` (nearest-rank, the convention the serving p50/p95/p99
//! have always used), moved here so every subsystem shares one
//! implementation. [`Metrics`] is a cheap clonable registry handle —
//! `Arc<Mutex<..>>` inside — wired through `ExecOptions::metrics`: the
//! worker pool counts steps/failures/bytes, `execute_with_recovery` counts
//! retries and replans, and the serve engine can observe anything else
//! through the same handle.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A sample window with nearest-rank percentiles.
///
/// Samples are unitless `f64`s (callers conventionally record seconds).
/// All accessors return `0.0` on an empty window rather than panicking, so
/// snapshots taken before any traffic are well-formed.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    samples: Vec<f64>,
}

impl Histogram {
    /// New empty histogram.
    #[must_use]
    pub fn new() -> Self {
        Histogram::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
    }

    /// Number of recorded samples.
    #[must_use]
    pub fn count(&self) -> u64 {
        self.samples.len() as u64
    }

    /// True when no samples have been recorded.
    #[must_use]
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean (`0.0` when empty).
    #[must_use]
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Smallest sample (`0.0` when empty).
    #[must_use]
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample (`0.0` when empty).
    #[must_use]
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile: the smallest sample with at least `q·n`
    /// samples at or below it (so `percentile(0.5)` of 9 samples is the
    /// 5th smallest). `0.0` when empty; any `q >= 1.0` yields the max and
    /// any `q <= 0` the min.
    #[must_use]
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        let rank = (q * n as f64).ceil() as usize;
        sorted[rank.clamp(1, n) - 1]
    }

    /// One-shot summary (count, mean, p50/p95/p99, max).
    #[must_use]
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            mean: self.mean(),
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
            max: self.max(),
        }
    }

    /// Drop all samples.
    pub fn clear(&mut self) {
        self.samples.clear();
    }
}

/// Point-in-time digest of one [`Histogram`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistSummary {
    /// Number of samples in the window.
    pub count: u64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Nearest-rank median.
    pub p50: f64,
    /// Nearest-rank 95th percentile.
    pub p95: f64,
    /// Nearest-rank 99th percentile.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

#[derive(Debug, Default)]
struct MetricsInner {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

/// Clonable registry of named monotonic counters and histograms.
///
/// Cloning shares the underlying store, so the same handle can be threaded
/// into `ExecOptions`, held by a test, and read back after the run:
///
/// ```
/// use soybean::obs::Metrics;
/// let m = Metrics::new();
/// let handle = m.clone();
/// handle.inc("exec.steps", 1);
/// m.observe("exec.step_seconds", 0.25);
/// assert_eq!(m.counter("exec.steps"), 1);
/// assert_eq!(m.snapshot().histograms["exec.step_seconds"].count, 1);
/// ```
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    inner: Arc<Mutex<MetricsInner>>,
}

impl Metrics {
    /// New empty registry.
    #[must_use]
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Add `by` to the named counter (created at zero on first use).
    pub fn inc(&self, name: &str, by: u64) {
        let mut inner = self.inner.lock().expect("metrics lock");
        *inner.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Record one sample into the named histogram.
    pub fn observe(&self, name: &str, v: f64) {
        let mut inner = self.inner.lock().expect("metrics lock");
        inner.histograms.entry(name.to_string()).or_default().record(v);
    }

    /// Current value of the named counter (`0` if never incremented).
    #[must_use]
    pub fn counter(&self, name: &str) -> u64 {
        let inner = self.inner.lock().expect("metrics lock");
        inner.counters.get(name).copied().unwrap_or(0)
    }

    /// Point-in-time snapshot of every counter and histogram.
    #[must_use]
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().expect("metrics lock");
        MetricsSnapshot {
            counters: inner.counters.clone(),
            histograms: inner.histograms.iter().map(|(k, h)| (k.clone(), h.summary())).collect(),
        }
    }
}

/// Snapshot returned by [`Metrics::snapshot`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Counter values by name.
    pub counters: BTreeMap<String, u64>,
    /// Histogram digests by name.
    pub histograms: BTreeMap<String, HistSummary>,
}

impl MetricsSnapshot {
    /// Render the snapshot as a JSON object (counters as integers,
    /// histograms as `{count, mean, p50, p95, p99, max}` objects).
    #[must_use]
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"counters\": {");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n    \"{k}\": {v}"));
        }
        out.push_str("\n  },\n  \"histograms\": {");
        for (i, (k, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    \"{k}\": {{\"count\": {}, \"mean\": {}, \"p50\": {}, \"p95\": {}, \
                 \"p99\": {}, \"max\": {}}}",
                h.count, h.mean, h.p50, h.p95, h.p99, h.max
            ));
        }
        out.push_str("\n  }\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Satellite: edge cases for the shared percentile machinery that
    // serving latency stats now run on.

    #[test]
    fn empty_window_is_all_zeros() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.percentile(0.99), 0.0);
        assert_eq!(h.summary(), HistSummary::default());
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut h = Histogram::new();
        h.record(4.25);
        for q in [0.0, 0.01, 0.5, 0.95, 0.99, 1.0, 2.0] {
            assert_eq!(h.percentile(q), 4.25, "q={q}");
        }
        assert_eq!(h.mean(), 4.25);
        assert_eq!(h.min(), 4.25);
        assert_eq!(h.max(), 4.25);
    }

    #[test]
    fn all_equal_latencies_collapse() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(0.007);
        }
        let s = h.summary();
        assert_eq!((s.p50, s.p95, s.p99, s.max), (0.007, 0.007, 0.007, 0.007));
        assert_eq!(s.count, 100);
        assert!((s.mean - 0.007).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_matches_the_serving_convention() {
        // 1..=9: p50 is the 5th smallest, p95/p99 round up to the max.
        let mut h = Histogram::new();
        for v in (1..=9).rev() {
            h.record(f64::from(v));
        }
        assert_eq!(h.percentile(0.50), 5.0);
        assert_eq!(h.percentile(0.95), 9.0);
        assert_eq!(h.percentile(0.99), 9.0);
        assert_eq!(h.min(), 1.0);
        h.clear();
        assert!(h.is_empty());
    }

    #[test]
    fn metrics_counters_accumulate_across_clones() {
        let m = Metrics::new();
        m.inc("recover.retries", 2);
        m.clone().inc("recover.retries", 1);
        assert_eq!(m.counter("recover.retries"), 3);
        assert_eq!(m.counter("never.touched"), 0);
        m.observe("lat", 1.0);
        m.observe("lat", 3.0);
        let snap = m.snapshot();
        assert_eq!(snap.counters["recover.retries"], 3);
        assert_eq!(snap.histograms["lat"].mean, 2.0);
        let json = snap.to_json();
        assert!(json.contains("\"recover.retries\": 3"));
        assert!(json.contains("\"count\": 2"));
    }
}
