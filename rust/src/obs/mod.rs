//! Observability: end-to-end tracing and measured-vs-modeled telemetry
//! for the real SPMD executor.
//!
//! Four pieces, threaded through the whole execution vertical:
//!
//! - [`trace`] — per-worker span buffers. With `ExecOptions::trace` on,
//!   every compute phase, collective send, wait stall, and metered
//!   collective instruction becomes a [`Span`] in the step's
//!   [`StepTrace`]; off (the default), each site costs one branch.
//! - [`chrome`] — the unified Chrome-trace writer (factored out of
//!   `sim::engine`), so modeled and measured timelines share one schema
//!   and [`overlay_trace_json`] can put them side by side in
//!   `chrome://tracing`.
//! - [`mod@calibrate`] — the drift report: joins a measured [`StepTrace`]
//!   against the discrete-event engine's modeled step into a
//!   [`CalibrationReport`] (per-kernel and per-collective ratios,
//!   aggregate step error, worst-N offenders, `obs_report.json`).
//! - [`metrics`] — named monotonic counters + histograms ([`Metrics`]),
//!   shared by the executor (steps/failures/step-seconds), the recovery
//!   loop (retries/replans), and the serving stats (whose percentile
//!   machinery now lives here as [`Histogram`]).
//!
//! See the book chapter: [`crate::book::observability`].

pub mod calibrate;
pub mod chrome;
pub mod metrics;
pub mod trace;

pub use calibrate::{calibrate, CalibrationReport, CollectiveDrift, KernelDrift, ProfileReport};
pub use chrome::{
    chrome_trace_json, measured_trace_json, overlay_trace_json, pipeline_trace_json,
};
pub use metrics::{HistSummary, Histogram, Metrics, MetricsSnapshot};
pub use trace::{Span, SpanContext, SpanKind, StepTrace, TraceBuf, OUT_SLOT};
