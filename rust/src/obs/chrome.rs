//! Chrome-trace (`chrome://tracing` / Perfetto) writers for modeled and
//! measured timelines.
//!
//! The discrete-event engine's writer used to live in `sim::engine`; it is
//! factored here so the simulated schedule and the real executor's span
//! trace emit the *same* schema and can be overlaid in one viewer:
//!
//! - [`chrome_trace_json`] — the modeled timeline (pid 0 = devices, pid 1 =
//!   interconnect link lanes), unchanged from its `sim::engine` days and
//!   still re-exported as `soybean::sim::chrome_trace_json`.
//! - [`measured_trace_json`] — a [`StepTrace`] from a traced executor run,
//!   same pid/tid layout for the device lanes.
//! - [`overlay_trace_json`] — both in one file: modeled on pids 0/1,
//!   measured on pid 2, sharing the `t = 0` step origin so drift is
//!   visible by eye.
//! - [`pipeline_trace_json`] — a pipelined [`PipelineReport`] schedule as
//!   one lane per stage, each `(cell, microbatch)` task a complete event,
//!   so GPipe bubbles and 1F1B steady state are visible as lane gaps.
//!
//! Measured traces carrying spans from several pipeline stages (the
//! `spmd::try_execute_strategy` path) group device lanes by stage —
//! `s{stage}/gpu{d}` — while single-stage traces keep the historical
//! `gpu{d}` layout.

use crate::lower::LoweredProgram;
use crate::obs::trace::{SpanKind, StepTrace, OUT_SLOT};
use crate::sim::engine::Lane;
use crate::sim::{EngineReport, PipelineReport, Topology};

fn esc(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn link_tid(cut: usize, pair: usize) -> usize {
    (cut << 16) | pair
}

/// Comma-separated event accumulator for one `traceEvents` array.
struct TraceDoc {
    s: String,
    first: bool,
}

impl TraceDoc {
    fn new() -> Self {
        TraceDoc {
            s: String::from("{\n\"displayTimeUnit\": \"ms\",\n\"traceEvents\": [\n"),
            first: true,
        }
    }

    fn push(&mut self, line: String) {
        if !self.first {
            self.s.push_str(",\n");
        }
        self.first = false;
        self.s.push_str(&line);
    }

    fn meta_process(&mut self, pid: usize, name: &str) {
        self.push(format!(
            "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(name)
        ));
    }

    fn meta_thread(&mut self, pid: usize, tid: usize, name: &str) {
        self.push(format!(
            "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(name)
        ));
    }

    fn complete(&mut self, name: &str, pid: usize, tid: usize, ts_s: f64, dur_s: f64, bytes: u64) {
        use std::fmt::Write as _;
        let mut line = String::new();
        let _ = write!(
            line,
            "{{\"ph\":\"X\",\"name\":\"{}\",\"pid\":{pid},\"tid\":{tid},\"ts\":{:.3},\"dur\":{:.3}",
            esc(name),
            ts_s * 1e6,
            dur_s * 1e6
        );
        if bytes > 0 {
            let _ = write!(line, ",\"args\":{{\"bytes\":{bytes}}}");
        }
        line.push('}');
        self.push(line);
    }

    fn finish(mut self) -> String {
        self.s.push_str("\n]\n}\n");
        self.s
    }
}

/// Emit the modeled timeline onto a document: devices as `pid_base`
/// threads, link instances as `pid_base + 1` threads named by tier.
fn emit_modeled(doc: &mut TraceDoc, report: &EngineReport, topo: &Topology, pid_base: usize) {
    for d in 0..report.devices {
        doc.meta_thread(pid_base, d, &format!("gpu{d}"));
    }
    // Name every link lane that actually carried traffic.
    let mut seen: Vec<(usize, usize)> = Vec::new();
    for e in &report.trace {
        if let Lane::Link { cut, pair } = e.lane {
            if !seen.contains(&(cut, pair)) {
                seen.push((cut, pair));
                let lane_name = format!("{} pair{pair}", topo.link(cut).name);
                doc.meta_thread(pid_base + 1, link_tid(cut, pair), &lane_name);
            }
        }
    }
    for e in &report.trace {
        let (pid, tid) = match e.lane {
            Lane::Device(d) => (pid_base, d),
            Lane::Link { cut, pair } => (pid_base + 1, link_tid(cut, pair)),
        };
        doc.complete(&e.name, pid, tid, e.start_s, e.dur_s, e.bytes);
    }
}

/// Span display name: kernels carry their op name; collective markers are
/// named like the engine's link spans (`all_gather:tensor`) so modeled and
/// measured lanes line up; sends/waits name the op side they stalled on.
fn span_name(span: &crate::obs::trace::Span, program: &LoweredProgram) -> String {
    if let Some(gid) = span.gid {
        let m = &program.transfers[gid];
        return format!("{}:{}", span.kind.name(), program.tensor_names[m.tensor]);
    }
    match span.kind {
        SpanKind::Compute => program.op_names[span.op].clone(),
        _ => {
            let side = if span.slot == OUT_SLOT {
                "out".to_string()
            } else {
                format!("in{}", span.slot)
            };
            format!("{}:{}#{side}", span.kind.name(), program.op_names[span.op])
        }
    }
}

/// Thread id for one `(stage, device)` lane: single-stage traces keep
/// `tid == device` (the historical layout); multi-stage traces group
/// lanes by stage so Perfetto sorts `s0/gpu*` above `s1/gpu*`.
fn stage_tid(stage: usize, device: usize) -> usize {
    (stage << 8) | device
}

/// Emit a measured [`StepTrace`] onto a document as `pid` device threads.
/// Single-stage traces keep the historical `gpu{d}` lane names; traces
/// carrying spans from several pipeline stages get one lane group per
/// stage (`s{stage}/gpu{d}`), so overlapping per-stage executor runs read
/// as a pipeline diagram rather than an interleaved smear.
fn emit_measured(doc: &mut TraceDoc, trace: &StepTrace, program: &LoweredProgram, pid: usize) {
    let staged = trace.stage_count() > 1;
    let mut seen: Vec<(usize, usize)> = Vec::new();
    for s in &trace.spans {
        if !seen.contains(&(s.stage, s.device)) {
            seen.push((s.stage, s.device));
        }
    }
    seen.sort_unstable();
    for &(stage, d) in &seen {
        let name = if staged { format!("s{stage}/gpu{d}") } else { format!("gpu{d}") };
        doc.meta_thread(pid, if staged { stage_tid(stage, d) } else { d }, &name);
    }
    for s in &trace.spans {
        let tid = if staged { stage_tid(s.stage, s.device) } else { s.device };
        doc.complete(&span_name(s, program), pid, tid, s.start_s, s.dur_s(), s.bytes);
    }
}

/// Render an engine report's timeline as Chrome-trace JSON
/// (`chrome://tracing` / Perfetto "load trace"). Devices appear as pid 0
/// threads, interconnect link instances as pid 1 threads named after their
/// tier.
#[must_use]
pub fn chrome_trace_json(report: &EngineReport, topo: &Topology) -> String {
    let mut doc = TraceDoc::new();
    doc.meta_process(0, "devices");
    doc.meta_process(1, "interconnect");
    emit_modeled(&mut doc, report, topo, 0);
    doc.finish()
}

/// Render a measured executor [`StepTrace`] in the same Chrome-trace
/// schema as [`chrome_trace_json`]: devices as pid 0 threads, one complete
/// event per span, byte payloads in `args.bytes`.
#[must_use]
pub fn measured_trace_json(trace: &StepTrace, program: &LoweredProgram) -> String {
    let mut doc = TraceDoc::new();
    doc.meta_process(0, "devices");
    emit_measured(&mut doc, trace, program, 0);
    doc.finish()
}

/// Render a pipelined schedule ([`PipelineReport`]) as Chrome-trace
/// JSON: one lane per pipeline stage (pid 0, tid = stage), one complete
/// event per scheduled `(cell, microbatch)` task, named
/// `{cell label}/mu{i}` — e.g. `s1.bwd/mu3`. Microbatch indices are
/// recovered from schedule order (the report pushes each cell's tasks in
/// FIFO microbatch order). Load in Perfetto and the GPipe bubble — or
/// 1F1B's lack of one — is visible as stage-lane idle gaps.
#[must_use]
pub fn pipeline_trace_json(report: &PipelineReport, cell_labels: &[String]) -> String {
    let mut doc = TraceDoc::new();
    doc.meta_process(0, "pipeline stages");
    for s in 0..report.stages {
        doc.meta_thread(0, s, &format!("stage{s}"));
    }
    let mut mu_count = vec![0usize; cell_labels.len()];
    for span in &report.spans {
        let label = cell_labels.get(span.op).map_or("cell", String::as_str);
        let mu = mu_count.get(span.op).copied().unwrap_or(0);
        if let Some(n) = mu_count.get_mut(span.op) {
            *n += 1;
        }
        doc.complete(
            &format!("{label}/mu{mu}"),
            0,
            span.stage,
            span.start_s,
            span.dur_s(),
            span.bytes,
        );
    }
    doc.finish()
}

/// Render modeled and measured timelines in one Chrome-trace file: the
/// engine's schedule on pids 0/1 (exactly as [`chrome_trace_json`] lays it
/// out) and the measured spans on pid 2, sharing the `t = 0` origin.
#[must_use]
pub fn overlay_trace_json(
    modeled: &EngineReport,
    topo: &Topology,
    measured: &StepTrace,
    program: &LoweredProgram,
) -> String {
    let mut doc = TraceDoc::new();
    doc.meta_process(0, "devices (modeled)");
    doc.meta_process(1, "interconnect (modeled)");
    doc.meta_process(2, "devices (measured)");
    emit_modeled(&mut doc, modeled, topo, 0);
    emit_measured(&mut doc, measured, program, 2);
    doc.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lower::try_lower;
    use crate::models::{mlp, MlpConfig};
    use crate::obs::trace::Span;
    use crate::planner::{Planner, PlanFamily};
    use crate::sim::{try_run_program, SimConfig};

    fn modeled() -> (crate::graph::Graph, LoweredProgram, Topology, EngineReport) {
        let g = mlp(&MlpConfig { batch: 16, dims: vec![8, 8], bias: true });
        let plan = Planner::try_plan(&g, 1, PlanFamily::Soybean).unwrap();
        let p = try_lower(&g, &plan, &SimConfig::default()).unwrap();
        let topo = Topology::p2_8xlarge();
        let r = try_run_program(&p, &topo).unwrap();
        (g, p, topo, r)
    }

    #[test]
    fn chrome_trace_is_valid_json() {
        let (g, p, topo, r) = modeled();
        let json = chrome_trace_json(&r, &topo);
        let doc = crate::util::json::parse(&json).expect("valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.len() >= g.ops.len());
        // Every complete event carries non-negative microsecond stamps.
        for e in events {
            if e.get("ph").and_then(|p| p.as_str()) == Some("X") {
                assert!(e.get("ts").is_some() && e.get("dur").is_some());
            }
        }
        let _ = p;
    }

    #[test]
    fn measured_and_overlay_share_the_schema() {
        let (_g, p, topo, r) = modeled();
        let gid = if p.transfers.is_empty() { None } else { Some(0) };
        let spans = vec![
            Span {
                device: 0,
                op: 0,
                kind: SpanKind::Compute,
                slot: 0,
                gid: None,
                start_s: 0.0,
                end_s: 1e-3,
                bytes: 0,
                stage: 0,
            },
            Span {
                device: 1,
                op: 0,
                kind: SpanKind::Wait,
                slot: OUT_SLOT,
                gid: None,
                start_s: 1e-3,
                end_s: 2e-3,
                bytes: 64,
                stage: 0,
            },
            Span {
                device: 1,
                op: p.transfers.first().map_or(0, |m| m.op),
                kind: SpanKind::AllGather,
                slot: 0,
                gid,
                start_s: 2e-3,
                end_s: 2e-3,
                bytes: 128,
                stage: 0,
            },
        ];
        let trace = StepTrace::merge(vec![spans]);
        let measured = measured_trace_json(&trace, &p);
        let doc = crate::util::json::parse(&measured).expect("valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 device threads named + 1 process meta + 3 spans.
        assert!(events.len() >= 6);
        assert!(measured.contains(&p.op_names[0]));
        assert!(measured.contains("wait:"));

        let overlay = overlay_trace_json(&r, &topo, &trace, &p);
        let doc = crate::util::json::parse(&overlay).expect("valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // Overlay carries both the modeled events and the measured pid 2.
        assert!(overlay.contains("devices (measured)"));
        assert!(overlay.contains("devices (modeled)"));
        assert!(events.iter().any(|e| {
            e.get("pid").and_then(|p| p.as_usize()) == Some(2)
                && e.get("ph").and_then(|p| p.as_str()) == Some("X")
        }));
        if gid.is_some() {
            assert!(measured.contains("all_gather:"));
        }
    }

    #[test]
    fn multi_stage_measured_trace_groups_lanes_by_stage() {
        let (_g, p, _topo, _r) = modeled();
        let mk = |stage: usize, device: usize| Span {
            device,
            op: 0,
            kind: SpanKind::Compute,
            slot: 0,
            gid: None,
            start_s: 0.0,
            end_s: 1e-3,
            bytes: 0,
            stage,
        };
        let trace = StepTrace::merge(vec![vec![mk(0, 0), mk(0, 1), mk(1, 0)]]);
        let json = measured_trace_json(&trace, &p);
        assert!(json.contains("s0/gpu0"));
        assert!(json.contains("s0/gpu1"));
        assert!(json.contains("s1/gpu0"));
        assert!(!json.contains("\"gpu0\""));
        crate::util::json::parse(&json).expect("valid JSON");
    }

    #[test]
    fn pipeline_trace_names_tasks_by_cell_and_microbatch() {
        use crate::graph::bfs_levels;
        use crate::planner::{Schedule, Strategy};
        use crate::sim::try_simulate_strategy;

        let g = mlp(&MlpConfig { batch: 16, dims: vec![8, 8, 8], bias: true });
        let cut = bfs_levels(&g).levels.len() / 2;
        let s = Strategy::try_build(&g, &[cut], 2, 2, Schedule::GPipe).unwrap();
        let report = try_simulate_strategy(&s, &Topology::two_tier(2)).unwrap();
        let json = pipeline_trace_json(&report, &s.cell_labels());
        let doc = crate::util::json::parse(&json).expect("valid JSON");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // stage metas + process meta + one event per (cell, microbatch).
        assert!(events.len() >= 1 + report.stages + report.spans.len());
        assert!(json.contains("stage0"));
        assert!(json.contains("stage1"));
        assert!(json.contains("s0.fwd/mu0"));
        assert!(json.contains("s0.fwd/mu1"));
        // The last stage's backward fuses into its single cell; stage 0
        // still has a distinct backward cell to drain.
        assert!(json.contains("s1.fwd/mu0"));
        assert!(json.contains("s0.bwd/mu1"));
    }
}
