//! The drift report: join measured executor spans against the
//! discrete-event engine's modeled step.
//!
//! ROADMAP item 5 ("calibrate the cost model against measured execution")
//! needs exactly one artifact: for every kernel and every lowered
//! collective, *what the engine predicted* next to *what the threaded
//! executor actually took*. [`calibrate`] computes that join and returns a
//! [`CalibrationReport`]; [`Session::profile`](crate::serve::Session::profile)
//! is the one-call facade (traced step + engine run + join) and
//! `plan_inspector --profile` dumps the report beside the overlay trace.
//!
//! Join semantics:
//!
//! - **Kernels**: modeled seconds come from the `Compute` instructions of
//!   device 0's stream (SPMD — all streams carry the same op sequence);
//!   measured seconds are the mean `Compute`-span duration across devices.
//! - **Collectives**: each lowered transfer group (gid) is modeled as
//!   `Topology::transfer_seconds(cut, pair_bytes)`. Measured comm time is
//!   the mean per-device wall-clock of the `Wait` + `Send` spans attached
//!   to the same `(stage, op, tensor)` site; when stacked cuts lower one
//!   logical conversion into several gids sharing a site, the measured
//!   time is split across them in proportion to their modeled seconds.
//! - **Stages**: every join key carries the span's pipeline-stage tag
//!   (`Span::stage`), so a multi-stage trace keeps per-stage attribution
//!   — two cells reusing local op id 0 stay two distinct rows. Single-
//!   stage traces (the only spans the plain executor emits) key
//!   everything at stage 0 and reproduce the historical join exactly.
//! - **Bytes reconcile exactly**: the metered collective markers recorded
//!   by the workers sum to the executor's collective meter, which equals
//!   the plan's Theorem-1 total bit for bit, and per gid they equal
//!   `pair_bytes << cut` — the model and the measurement agree on *bytes*
//!   by construction, so every ratio in the report is purely about *time*.

use std::collections::BTreeMap;

use crate::graph::{Graph, OpId};
use crate::lower::{Instr, LoweredProgram};
use crate::obs::trace::{SpanKind, StepTrace, OUT_SLOT};
use crate::sim::{EngineReport, Topology};
use crate::spmd::ExecReport;

/// Modeled-vs-measured row for one graph op's local kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDrift {
    /// Pipeline stage the measured spans carried (0 for single-stage
    /// steps). Multi-stage traces key drift by `(stage, op)` so the same
    /// op id in two cells yields two rows.
    pub stage: usize,
    /// Graph op id.
    pub op: OpId,
    /// Human-readable op name (`LoweredProgram::op_names`).
    pub name: String,
    /// Engine-modeled seconds for one execution of the kernel.
    pub modeled_s: f64,
    /// Mean measured seconds per device.
    pub measured_s: f64,
    /// `measured_s / modeled_s` (`0.0` when the model predicts zero).
    pub ratio: f64,
}

/// Modeled-vs-measured row for one lowered transfer group.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectiveDrift {
    /// Pipeline stage of the group's metered markers (0 for single-stage
    /// steps); with the stage, measured comm joins by `(stage, op,
    /// tensor)`.
    pub stage: usize,
    /// Transfer group id (index into `LoweredProgram::transfers`).
    pub gid: usize,
    /// Collective kind name (`all_gather`, `reduce_scatter`, ...).
    pub kind: &'static str,
    /// Name of the tensor being converted.
    pub tensor: String,
    /// Op the transfer is attached to (consumer for input gathers,
    /// producer for output conversions).
    pub op: OpId,
    /// Cut level the transfer crosses.
    pub cut: usize,
    /// Modeled bytes: `pair_bytes << cut`, the group's Theorem-1 share.
    pub modeled_bytes: u64,
    /// Measured bytes from the workers' metered collective markers.
    /// Equals `modeled_bytes` whenever the step ran fault-free.
    pub measured_bytes: u64,
    /// Engine-modeled wall-clock seconds for the group.
    pub modeled_s: f64,
    /// Measured seconds attributed to the group (see module docs).
    pub measured_s: f64,
    /// `measured_s / modeled_s` (`0.0` when the model predicts zero).
    pub ratio: f64,
}

/// The drift report: per-kernel and per-collective modeled-vs-measured
/// ratios plus aggregate step error. Produced by [`calibrate`].
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationReport {
    /// Number of devices in the step.
    pub devices: usize,
    /// Engine-modeled step wall-clock (seconds).
    pub modeled_step_s: f64,
    /// Measured step wall-clock: latest span end (seconds).
    pub measured_step_s: f64,
    /// `measured_step_s / modeled_step_s` — the aggregate step error.
    pub step_ratio: f64,
    /// Engine-modeled pure-compute critical path (seconds).
    pub modeled_compute_s: f64,
    /// Measured compute: max over devices of summed kernel span seconds.
    pub measured_compute_s: f64,
    /// Sum of all metered collective-marker bytes — reconciles bit for
    /// bit with the plan's Theorem-1 total on a fault-free step.
    pub metered_span_bytes: u64,
    /// One row per graph op that computed or was modeled.
    pub kernels: Vec<KernelDrift>,
    /// One row per lowered transfer group.
    pub collectives: Vec<CollectiveDrift>,
}

impl CalibrationReport {
    /// The `n` rows whose modeled and measured times disagree by the
    /// largest factor, as `(label, drift_factor)` with
    /// `drift_factor = max(ratio, 1/ratio)`. Rows the model prices at
    /// zero seconds are skipped (no meaningful ratio).
    #[must_use]
    pub fn worst_offenders(&self, n: usize) -> Vec<(String, f64)> {
        let mut rows: Vec<(String, f64)> = Vec::new();
        // Stage prefixes only appear on multi-stage rows, so single-stage
        // reports keep their historical labels.
        let tag = |stage: usize| if stage > 0 { format!("s{stage} ") } else { String::new() };
        for k in &self.kernels {
            if k.ratio > 0.0 {
                rows.push((
                    format!("{}kernel {} ({})", tag(k.stage), k.op, k.name),
                    k.ratio.max(1.0 / k.ratio),
                ));
            }
        }
        for c in &self.collectives {
            if c.ratio > 0.0 {
                let label = format!(
                    "{}collective gid{} {}:{} cut{}",
                    tag(c.stage),
                    c.gid,
                    c.kind,
                    c.tensor,
                    c.cut
                );
                rows.push((label, c.ratio.max(1.0 / c.ratio)));
            }
        }
        rows.sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        rows.truncate(n);
        rows
    }

    /// Render the full report as JSON (the `obs_report.json` schema).
    #[must_use]
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"devices\": {},\n", self.devices));
        s.push_str(&format!("  \"modeled_step_s\": {},\n", self.modeled_step_s));
        s.push_str(&format!("  \"measured_step_s\": {},\n", self.measured_step_s));
        s.push_str(&format!("  \"step_ratio\": {},\n", self.step_ratio));
        s.push_str(&format!("  \"modeled_compute_s\": {},\n", self.modeled_compute_s));
        s.push_str(&format!("  \"measured_compute_s\": {},\n", self.measured_compute_s));
        s.push_str(&format!("  \"metered_span_bytes\": {},\n", self.metered_span_bytes));
        s.push_str("  \"kernels\": [\n");
        for (i, k) in self.kernels.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"stage\": {}, \"op\": {}, \"name\": {}, \"modeled_s\": {}, \
                 \"measured_s\": {}, \"ratio\": {}}}{}\n",
                k.stage,
                k.op,
                crate::util::bench::json_str(&k.name),
                k.modeled_s,
                k.measured_s,
                k.ratio,
                if i + 1 < self.kernels.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n  \"collectives\": [\n");
        for (i, c) in self.collectives.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"stage\": {}, \"gid\": {}, \"kind\": \"{}\", \"tensor\": {}, \"op\": {}, \
                 \"cut\": {}, \"modeled_bytes\": {}, \"measured_bytes\": {}, \"modeled_s\": {}, \
                 \"measured_s\": {}, \"ratio\": {}}}{}\n",
                c.stage,
                c.gid,
                c.kind,
                crate::util::bench::json_str(&c.tensor),
                c.op,
                c.cut,
                c.modeled_bytes,
                c.measured_bytes,
                c.modeled_s,
                c.measured_s,
                c.ratio,
                if i + 1 < self.collectives.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write [`Self::to_json`] to a file (conventionally
    /// `obs_report.json`).
    ///
    /// # Errors
    /// Propagates the underlying I/O error.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

impl std::fmt::Display for CalibrationReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "calibration over {} devices: step modeled {:.3} ms / measured {:.3} ms \
             (ratio {:.3})",
            self.devices,
            self.modeled_step_s * 1e3,
            self.measured_step_s * 1e3,
            self.step_ratio
        )?;
        writeln!(
            f,
            "  compute modeled {:.3} ms / measured {:.3} ms; metered collective bytes {}",
            self.modeled_compute_s * 1e3,
            self.measured_compute_s * 1e3,
            self.metered_span_bytes
        )?;
        writeln!(
            f,
            "  {} kernel rows, {} collective rows; worst offenders:",
            self.kernels.len(),
            self.collectives.len()
        )?;
        for (label, factor) in self.worst_offenders(5) {
            writeln!(f, "    {factor:8.3}x  {label}")?;
        }
        Ok(())
    }
}

/// A profiled step: the measured [`ExecReport`] (with its span trace), the
/// engine's modeled [`EngineReport`], and the joined [`CalibrationReport`].
/// Returned by [`Session::profile`](crate::serve::Session::profile).
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// The traced real execution.
    pub exec: ExecReport,
    /// The engine's modeled schedule of the same program.
    pub modeled: EngineReport,
    /// The modeled-vs-measured join.
    pub calibration: CalibrationReport,
}

/// Tensor a span's `(op, slot)` site refers to, mirroring the executor's
/// wire protocol: input slots index `Op::inputs`, [`OUT_SLOT`] means the
/// op's (single) output.
fn slot_tensor(g: &Graph, op: OpId, slot: u8) -> usize {
    if slot == OUT_SLOT {
        g.ops[op].outputs[0]
    } else {
        g.ops[op].inputs[slot as usize]
    }
}

/// Join a measured [`StepTrace`] against the engine's modeled step for the
/// same lowered program. See the module docs for the join semantics.
#[must_use]
pub fn calibrate(
    g: &Graph,
    program: &LoweredProgram,
    topo: &Topology,
    modeled: &EngineReport,
    trace: &StepTrace,
) -> CalibrationReport {
    let devices = program.devices;
    let nd = devices as f64;

    // Kernels: modeled from device 0's stream (SPMD — identical streams),
    // measured as the per-device mean of Compute spans.
    let mut modeled_op: BTreeMap<OpId, f64> = BTreeMap::new();
    for i in &program.programs[0].instrs {
        if let Instr::Compute { op, seconds } = i {
            *modeled_op.entry(*op).or_insert(0.0) += *seconds;
        }
    }
    let mut meas_op: BTreeMap<(usize, OpId), f64> = BTreeMap::new();
    let mut per_device_compute = vec![0.0f64; devices];
    for s in &trace.spans {
        if s.kind == SpanKind::Compute {
            *meas_op.entry((s.stage, s.op)).or_insert(0.0) += s.dur_s();
            per_device_compute[s.device] += s.dur_s();
        }
    }
    // Row keys: every measured (stage, op) plus stage-0 rows for ops the
    // model priced but the trace never measured. Single-stage traces
    // reduce to the historical one-row-per-op join.
    let mut keys: Vec<(usize, OpId)> =
        modeled_op.keys().map(|&op| (0usize, op)).chain(meas_op.keys().copied()).collect();
    keys.sort_unstable();
    keys.dedup();
    let kernels: Vec<KernelDrift> = keys
        .into_iter()
        .map(|(stage, op)| {
            let modeled_s = modeled_op.get(&op).copied().unwrap_or(0.0);
            let measured_s = meas_op.get(&(stage, op)).copied().unwrap_or(0.0) / nd;
            KernelDrift {
                stage,
                op,
                name: program.op_names[op].clone(),
                modeled_s,
                measured_s,
                ratio: if modeled_s > 0.0 { measured_s / modeled_s } else { 0.0 },
            }
        })
        .collect();

    // Measured comm wall-clock by (stage, op, tensor) site: Wait + Send
    // spans, mean per device. The stage key keeps multi-stage traces
    // from smearing two cells' stalls onto one site.
    let mut comm: BTreeMap<(usize, OpId, usize), f64> = BTreeMap::new();
    for s in &trace.spans {
        if matches!(s.kind, SpanKind::Wait | SpanKind::Send) {
            *comm.entry((s.stage, s.op, slot_tensor(g, s.op, s.slot))).or_insert(0.0) +=
                s.dur_s();
        }
    }

    // Metered bytes per transfer group from the collective markers; the
    // first marker also pins the group's stage tag.
    let mut gid_bytes = vec![0u64; program.transfers.len()];
    let mut gid_stage = vec![0usize; program.transfers.len()];
    let mut metered_span_bytes = 0u64;
    for s in &trace.spans {
        if let Some(gid) = s.gid {
            if gid_bytes[gid] == 0 {
                gid_stage[gid] = s.stage;
            }
            gid_bytes[gid] += s.bytes;
            metered_span_bytes += s.bytes;
        }
    }

    // Modeled seconds per gid; gids sharing an (op, tensor) site split the
    // site's measured time in proportion to their modeled seconds.
    let modeled_gid: Vec<f64> =
        program.transfers.iter().map(|m| topo.transfer_seconds(m.cut, m.pair_bytes)).collect();
    let mut site_modeled: BTreeMap<(OpId, usize), f64> = BTreeMap::new();
    let mut site_count: BTreeMap<(OpId, usize), usize> = BTreeMap::new();
    for (i, m) in program.transfers.iter().enumerate() {
        *site_modeled.entry((m.op, m.tensor)).or_insert(0.0) += modeled_gid[i];
        *site_count.entry((m.op, m.tensor)).or_insert(0) += 1;
    }
    let collectives: Vec<CollectiveDrift> = program
        .transfers
        .iter()
        .enumerate()
        .map(|(gid, m)| {
            let key = (m.op, m.tensor);
            let stage = gid_stage[gid];
            let site_measured = comm.get(&(stage, m.op, m.tensor)).copied().unwrap_or(0.0) / nd;
            let share = if site_modeled[&key] > 0.0 {
                modeled_gid[gid] / site_modeled[&key]
            } else {
                1.0 / site_count[&key] as f64
            };
            let modeled_s = modeled_gid[gid];
            let measured_s = site_measured * share;
            CollectiveDrift {
                stage,
                gid,
                kind: m.kind.name(),
                tensor: program.tensor_names[m.tensor].clone(),
                op: m.op,
                cut: m.cut,
                modeled_bytes: m.pair_bytes << m.cut,
                measured_bytes: gid_bytes[gid],
                modeled_s,
                measured_s,
                ratio: if modeled_s > 0.0 { measured_s / modeled_s } else { 0.0 },
            }
        })
        .collect();

    let measured_step_s = trace.step_s();
    let measured_compute_s = per_device_compute.iter().fold(0.0f64, |a, &b| a.max(b));
    CalibrationReport {
        devices,
        modeled_step_s: modeled.step_s,
        measured_step_s,
        step_ratio: if modeled.step_s > 0.0 { measured_step_s / modeled.step_s } else { 0.0 },
        modeled_compute_s: modeled.compute_s,
        measured_compute_s,
        metered_span_bytes,
        kernels,
        collectives,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::seed_values;
    use crate::lower::try_lower;
    use crate::models::{mlp, MlpConfig};
    use crate::planner::{Planner, PlanFamily};
    use crate::sim::{try_run_program, SimConfig};
    use crate::spmd::{execute_with, ExecOptions};

    #[test]
    fn calibration_joins_a_real_traced_step() {
        let g = mlp(&MlpConfig { batch: 8, dims: vec![6, 8, 6], bias: true });
        let plan = Planner::try_plan(&g, 1, PlanFamily::Soybean).expect("plan");
        let program = try_lower(&g, &plan, &SimConfig::default()).expect("lower");
        let topo = Topology::from_sim(&SimConfig::default(), 1);
        let init = seed_values(&g, 3);
        let opts = ExecOptions::default().trace(true);
        let report = execute_with(&g, &plan, &program, &init, &opts).expect("exec");
        let trace = report.trace.clone().expect("tracing was on");
        let modeled = try_run_program(&program, &topo).expect("engine");
        let cal = calibrate(&g, &program, &topo, &modeled, &trace);

        // Bytes reconcile: markers == collective meter == Theorem-1.
        assert_eq!(cal.metered_span_bytes, report.instr_bytes);
        assert_eq!(cal.metered_span_bytes, plan.total_cost());
        for c in &cal.collectives {
            assert_eq!(c.measured_bytes, c.modeled_bytes, "gid {}", c.gid);
            assert!(c.modeled_s > 0.0, "gid {} priced at zero", c.gid);
        }
        assert_eq!(cal.collectives.len(), program.transfers.len());
        assert!(!cal.kernels.is_empty());
        assert!(cal.measured_step_s > 0.0 && cal.modeled_step_s > 0.0);
        assert!(cal.step_ratio > 0.0);

        // The report serializes to valid JSON with every section present.
        let json = cal.to_json();
        let doc = crate::util::json::parse(&json).expect("valid JSON");
        assert_eq!(doc.get("devices").and_then(|v| v.as_usize()), Some(2));
        assert!(doc.get("kernels").unwrap().as_arr().unwrap().len() == cal.kernels.len());
        assert!(doc.get("collectives").unwrap().as_arr().unwrap().len() == cal.collectives.len());
        assert!(!cal.worst_offenders(3).is_empty());
        assert!(format!("{cal}").contains("worst offenders"));
    }

    #[test]
    fn untraced_spans_yield_zero_measurements_but_full_model_rows() {
        let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
        let plan = Planner::try_plan(&g, 1, PlanFamily::Soybean).expect("plan");
        let program = try_lower(&g, &plan, &SimConfig::default()).expect("lower");
        let topo = Topology::from_sim(&SimConfig::default(), 1);
        let modeled = try_run_program(&program, &topo).expect("engine");
        let cal = calibrate(&g, &program, &topo, &modeled, &StepTrace::default());
        assert_eq!(cal.metered_span_bytes, 0);
        assert_eq!(cal.measured_step_s, 0.0);
        assert!(cal.collectives.iter().all(|c| c.measured_s == 0.0 && c.modeled_s > 0.0));
        // Zero-measurement rows are skipped by the offender ranking only
        // when the *model* prices them at zero; here ratios are 0.0.
        assert!(cal.collectives.iter().all(|c| c.ratio == 0.0));
    }

    #[test]
    fn multi_stage_spans_keep_per_stage_rows() {
        use crate::obs::trace::Span;

        let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
        let plan = Planner::try_plan(&g, 1, PlanFamily::Soybean).expect("plan");
        let program = try_lower(&g, &plan, &SimConfig::default()).expect("lower");
        let topo = Topology::from_sim(&SimConfig::default(), 1);
        let modeled = try_run_program(&program, &topo).expect("engine");
        // The same op id measured under two stage tags: two kernel rows.
        let mk = |stage: usize, dur: f64| Span {
            device: 0,
            op: 0,
            kind: SpanKind::Compute,
            slot: 0,
            gid: None,
            start_s: 0.0,
            end_s: dur,
            bytes: 0,
            stage,
        };
        let trace = StepTrace::merge(vec![vec![mk(0, 1e-3), mk(1, 3e-3)]]);
        let cal = calibrate(&g, &program, &topo, &modeled, &trace);
        let s0: Vec<_> = cal.kernels.iter().filter(|k| k.op == 0 && k.stage == 0).collect();
        let s1: Vec<_> = cal.kernels.iter().filter(|k| k.op == 0 && k.stage == 1).collect();
        assert_eq!((s0.len(), s1.len()), (1, 1));
        assert!(s1[0].measured_s > s0[0].measured_s);
        // Stage-1 rows carry the stage prefix in offender labels and the
        // stage field in JSON.
        assert!(cal.to_json().contains("\"stage\": 1"));
        assert!(cal.worst_offenders(20).iter().any(|(l, _)| l.starts_with("s1 ")));
    }
}
