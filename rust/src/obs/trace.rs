//! Span tracing for the real SPMD executor.
//!
//! When [`ExecOptions::trace`](crate::spmd::ExecOptions) is on, every worker
//! thread records one [`Span`] per compute phase, collective send, wait
//! stall, and metered collective instruction into a private [`TraceBuf`] —
//! one `Vec` per worker, drained into the step's [`StepTrace`] after the
//! barrier, so the hot path never touches a lock or another thread's
//! buffer. With tracing off (the default) the executor pays a single
//! `Option` branch per site, the same discipline as the fault hooks.
//!
//! Timestamps are `f64` seconds measured from a shared per-step epoch (one
//! `Instant` captured in `run_step` before dispatch), so spans from
//! different workers share a clock and can be overlaid against the
//! discrete-event engine's modeled timeline, which also starts at `t = 0`.

use std::time::Instant;

use crate::graph::OpId;

/// Slot tag for output-side spans. Input-side spans carry their input slot
/// index; output scatter/conversion activity is tagged with this sentinel
/// (mirroring the executor's wire protocol, where real slots are `< 254`).
pub const OUT_SLOT: u8 = u8::MAX;

/// What a [`Span`] measured.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Local kernel execution (`apply_op`) for one op.
    Compute,
    /// Serializing + enqueueing one outbound payload (checksum + channel
    /// send; includes any injected delay when fault injection is active).
    Send,
    /// Blocked in `recv` waiting for a peer's payload (the wait stall).
    Wait,
    /// Metered `AllGather` instruction (zero-duration marker carrying the
    /// instruction's Theorem-1 byte cost).
    AllGather,
    /// Metered `ReduceScatter` instruction (zero-duration byte marker).
    ReduceScatter,
    /// Metered `AllToAll` instruction (zero-duration byte marker).
    AllToAll,
    /// Metered `SendRecv` instruction (zero-duration byte marker).
    SendRecv,
}

impl SpanKind {
    /// Stable lower-snake name (matches `CollectiveKind::name` for the
    /// collective kinds, so measured markers join against modeled spans).
    #[must_use]
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Send => "send",
            SpanKind::Wait => "wait",
            SpanKind::AllGather => "all_gather",
            SpanKind::ReduceScatter => "reduce_scatter",
            SpanKind::AllToAll => "all_to_all",
            SpanKind::SendRecv => "send_recv",
        }
    }

    /// True for the four metered collective-instruction kinds.
    #[must_use]
    pub fn is_collective(self) -> bool {
        matches!(
            self,
            SpanKind::AllGather | SpanKind::ReduceScatter | SpanKind::AllToAll | SpanKind::SendRecv
        )
    }
}

/// One traced interval on one device: `(device, op, instr-kind, slot)` plus
/// start/end seconds since the step epoch and the payload bytes involved.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Device (worker thread) that recorded the span.
    pub device: usize,
    /// Graph op the activity belongs to (consumer op for input gathers,
    /// producer op for output conversions — same convention as
    /// `TransferMeta::op`).
    pub op: OpId,
    /// What was measured.
    pub kind: SpanKind,
    /// Input slot for input-side activity, [`OUT_SLOT`] for output-side.
    pub slot: u8,
    /// For metered collective markers: the lowered transfer group id, the
    /// join key into `LoweredProgram::transfers`. `None` for wall-clock
    /// spans (compute/send/wait).
    pub gid: Option<usize>,
    /// Seconds since the step epoch at span start.
    pub start_s: f64,
    /// Seconds since the step epoch at span end (`== start_s` for
    /// zero-duration meter markers).
    pub end_s: f64,
    /// Payload bytes: received bytes for waits, sent bytes for sends, the
    /// instruction's Theorem-1 bytes for collective markers, 0 for compute.
    pub bytes: u64,
    /// Pipeline stage the span belongs to (0 for single-stage steps).
    /// Stamped from [`ExecOptions::stage`](crate::spmd::ExecOptions) so
    /// multi-stage traces keep per-stage attribution — the calibration
    /// report keys drift by `(stage, op, tensor)` and the Chrome overlay
    /// renders one lane group per stage.
    pub stage: usize,
}

impl Span {
    /// Span duration in seconds.
    #[must_use]
    pub fn dur_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// Span context attached to watchdog/corruption errors when tracing is on:
/// the last span the failing worker completed before the error, so the
/// structured root cause carries timing evidence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanContext {
    /// Op of the last completed span.
    pub op: OpId,
    /// Slot of the last completed span ([`OUT_SLOT`] for output-side).
    pub slot: u8,
    /// Milliseconds from the step epoch to the moment the error was raised.
    pub elapsed_ms: u64,
}

impl std::fmt::Display for SpanContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.slot == OUT_SLOT {
            write!(f, "last span op {} (output) at +{} ms", self.op, self.elapsed_ms)
        } else {
            write!(f, "last span op {} slot {} at +{} ms", self.op, self.slot, self.elapsed_ms)
        }
    }
}

/// Per-worker span buffer: a plain `Vec` owned by one worker thread, so
/// recording is a push with no synchronization. Drained into a
/// [`StepTrace`] at the step barrier.
#[derive(Debug)]
pub struct TraceBuf {
    epoch: Instant,
    spans: Vec<Span>,
}

impl TraceBuf {
    /// New empty buffer measuring against the given step epoch.
    #[must_use]
    pub fn new(epoch: Instant) -> Self {
        TraceBuf { epoch, spans: Vec::with_capacity(64) }
    }

    /// Seconds elapsed since the step epoch.
    #[must_use]
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Record a span.
    pub fn push(&mut self, span: Span) {
        self.spans.push(span);
    }

    /// Context for error enrichment: the last completed span plus the
    /// elapsed time at the moment of the call. `None` if nothing has been
    /// recorded yet.
    #[must_use]
    pub fn last_context(&self) -> Option<SpanContext> {
        let last = self.spans.last()?;
        Some(SpanContext {
            op: last.op,
            slot: last.slot,
            elapsed_ms: self.epoch.elapsed().as_millis() as u64,
        })
    }

    /// Consume the buffer, yielding its spans.
    #[must_use]
    pub fn into_spans(self) -> Vec<Span> {
        self.spans
    }
}

/// All spans from one executed step, merged across workers and sorted by
/// start time. Attached to `ExecReport::trace` when tracing is on.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StepTrace {
    /// The merged spans, ordered by `start_s` (ties broken by device).
    pub spans: Vec<Span>,
}

impl StepTrace {
    /// Merge per-worker span vectors into one ordered trace.
    #[must_use]
    pub fn merge(per_worker: Vec<Vec<Span>>) -> Self {
        let mut spans: Vec<Span> = per_worker.into_iter().flatten().collect();
        spans.sort_by(|a, b| {
            a.start_s.total_cmp(&b.start_s).then_with(|| a.device.cmp(&b.device))
        });
        StepTrace { spans }
    }

    /// Measured step wall-clock: the latest span end, in seconds.
    #[must_use]
    pub fn step_s(&self) -> f64 {
        self.spans.iter().map(|s| s.end_s).fold(0.0, f64::max)
    }

    /// Sum of the metered collective markers' bytes. Reconciles bit for
    /// bit with the executor's collective meter and therefore with the
    /// plan's Theorem-1 total.
    #[must_use]
    pub fn collective_bytes(&self) -> u64 {
        self.spans.iter().filter(|s| s.kind.is_collective()).map(|s| s.bytes).sum()
    }

    /// Number of pipeline stages the trace spans (1 + the highest stage
    /// tag; 1 for every single-stage step).
    #[must_use]
    pub fn stage_count(&self) -> usize {
        self.spans.iter().map(|s| s.stage + 1).max().unwrap_or(1)
    }

    /// Busy seconds attributed to each stage (indexed by stage): the sum
    /// of wall-clock span durations whose `stage` tag matches. The
    /// multi-stage attribution the serving stats and drift reports key by.
    #[must_use]
    pub fn stage_busy_s(&self) -> Vec<f64> {
        let mut busy = vec![0.0; self.stage_count()];
        for s in &self.spans {
            busy[s.stage] += s.dur_s();
        }
        busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn span(device: usize, kind: SpanKind, start_s: f64, end_s: f64, bytes: u64) -> Span {
        Span { device, op: 0, kind, slot: 0, gid: None, start_s, end_s, bytes, stage: 0 }
    }

    #[test]
    fn merge_orders_by_start_then_device() {
        let t = StepTrace::merge(vec![
            vec![span(1, SpanKind::Compute, 2.0, 3.0, 0)],
            vec![span(0, SpanKind::Wait, 2.0, 2.5, 8), span(0, SpanKind::Compute, 0.5, 1.0, 0)],
        ]);
        let order: Vec<(usize, f64)> = t.spans.iter().map(|s| (s.device, s.start_s)).collect();
        assert_eq!(order, vec![(0, 0.5), (0, 2.0), (1, 2.0)]);
        assert_eq!(t.step_s(), 3.0);
    }

    #[test]
    fn collective_bytes_counts_only_meter_markers() {
        let mut ag = span(0, SpanKind::AllGather, 1.0, 1.0, 100);
        ag.gid = Some(0);
        let t = StepTrace::merge(vec![vec![
            span(0, SpanKind::Wait, 0.0, 1.0, 9999),
            ag,
            span(0, SpanKind::ReduceScatter, 1.0, 1.0, 28),
        ]]);
        assert_eq!(t.collective_bytes(), 128);
    }

    #[test]
    fn trace_buf_records_and_reports_context() {
        let mut buf = TraceBuf::new(Instant::now() - Duration::from_millis(50));
        assert!(buf.last_context().is_none());
        let t0 = buf.now();
        assert!(t0 >= 0.050);
        buf.push(Span {
            device: 2,
            op: 7,
            kind: SpanKind::Wait,
            slot: 1,
            gid: None,
            start_s: t0,
            end_s: buf.now(),
            bytes: 16,
            stage: 0,
        });
        let ctx = buf.last_context().expect("one span recorded");
        assert_eq!((ctx.op, ctx.slot), (7, 1));
        assert!(ctx.elapsed_ms >= 50);
        assert_eq!(format!("{ctx}"), format!("last span op 7 slot 1 at +{} ms", ctx.elapsed_ms));
        let out = SpanContext { op: 3, slot: OUT_SLOT, elapsed_ms: 9 };
        assert_eq!(format!("{out}"), "last span op 3 (output) at +9 ms");
        assert_eq!(buf.into_spans().len(), 1);
    }
}
