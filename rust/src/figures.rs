//! Reproduction of every table and figure in the paper's evaluation (§6).
//!
//! Each function regenerates one figure's data series through the planner
//! + simulator and returns printable rows; the CLI (`soybean reproduce`),
//! the examples and the bench targets all call through here. Absolute
//! numbers come from the simulated p2.8xlarge testbed (DESIGN.md,
//! hardware substitution); the claims under test are the *shapes*: who
//! wins, by what factor, where the crossovers sit.

use crate::models::{alexnet, cnn5, mlp, vgg16, MlpConfig};
use crate::planner::{Planner, PlanFamily};
use crate::sim::{try_simulate, try_simulate_classic_dp, SimConfig, SimReport};
use crate::tiling::paper_example;

/// One measured point: strategy × device count.
#[derive(Debug, Clone)]
pub struct Point {
    /// PlanFamily short name (`"DP"`, `"MP"`, `"SOYBEAN"`).
    pub strategy: &'static str,
    /// Device count (`2^k`).
    pub devices: usize,
    /// Simulated per-step runtime (compute + overhead).
    pub runtime_s: f64,
    /// Communication overhead after overlap credit.
    pub overhead_s: f64,
    /// Compute-only seconds.
    pub compute_s: f64,
    /// Total conversion bytes (the plan's Theorem-1 cost).
    pub comm_bytes: u64,
}

fn sweep(g: &crate::graph::Graph, ks: &[usize], cfg: &SimConfig) -> Vec<Point> {
    let mut out = Vec::new();
    for &k in ks {
        for strat in PlanFamily::all() {
            let plan = Planner::try_plan(g, k, strat).unwrap();
            let r: SimReport = if strat == PlanFamily::DataParallel {
                try_simulate_classic_dp(g, &plan, cfg).unwrap()
            } else {
                try_simulate(g, &plan, cfg).unwrap()
            };
            out.push(Point {
                strategy: strat.name(),
                devices: 1 << k,
                runtime_s: r.step_s,
                overhead_s: r.overhead_s,
                compute_s: r.compute_s,
                comm_bytes: r.total_bytes,
            });
        }
    }
    out
}

fn render(title: &str, points: &[Point]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = writeln!(s, "== {title} ==");
    let _ = writeln!(
        s,
        "{:<8} {:>8} {:>12} {:>12} {:>12} {:>14}",
        "strategy", "devices", "runtime(ms)", "compute(ms)", "overhead(ms)", "comm(MB)"
    );
    for p in points {
        let _ = writeln!(
            s,
            "{:<8} {:>8} {:>12.2} {:>12.2} {:>12.2} {:>14.2}",
            p.strategy,
            p.devices,
            p.runtime_s * 1e3,
            p.compute_s * 1e3,
            p.overhead_s * 1e3,
            p.comm_bytes as f64 / 1e6
        );
    }
    s
}

/// Figure 8(a/b/c): 4-layer MLP runtime + communication overhead for
/// DP/MP/SOYBEAN on 2..8 GPUs.
pub fn fig8(batch: usize, hidden: usize, cfg: &SimConfig) -> (String, Vec<Point>) {
    let g = mlp(&MlpConfig::fig8(batch, hidden));
    let pts = sweep(&g, &[1, 2, 3], cfg);
    (
        render(&format!("Figure 8: MLP hidden={hidden} batch={batch}"), &pts),
        pts,
    )
}

/// Figure 9(a/b): 5-layer CNN, image size vs filter count.
pub fn fig9(image: usize, filters: usize, cfg: &SimConfig) -> (String, Vec<Point>) {
    let g = cnn5(256, image, 4, filters, 10);
    let pts = sweep(&g, &[1, 2, 3], cfg);
    (
        render(&format!("Figure 9: CNN image={image}px filters={filters} batch=256"), &pts),
        pts,
    )
}

/// Figure 10(a/b): AlexNet / VGG-16 speedup over one device on 8 devices,
/// as a function of batch size.
pub fn fig10(model: &str, batches: &[usize], cfg: &SimConfig) -> (String, Vec<(usize, f64, f64)>) {
    use std::fmt::Write as _;
    let mut s = String::new();
    let mut rows = Vec::new();
    let _ = writeln!(s, "== Figure 10: {model} speedup on 8 devices ==");
    let _ = writeln!(s, "{:>8} {:>12} {:>12}", "batch", "DP", "SOYBEAN");
    for &b in batches {
        let g = match model {
            "alexnet" => alexnet(b),
            "vgg" => vgg16(b),
            other => panic!("unknown model {other}"),
        };
        let p1 = Planner::try_plan(&g, 0, PlanFamily::Soybean).unwrap();
        let pdp = Planner::try_plan(&g, 3, PlanFamily::DataParallel).unwrap();
        let psoy = Planner::try_plan(&g, 3, PlanFamily::Soybean).unwrap();
        let single = try_simulate(&g, &p1, cfg).unwrap();
        let dp = try_simulate_classic_dp(&g, &pdp, cfg).unwrap();
        let soy = try_simulate(&g, &psoy, cfg).unwrap();
        let sp_dp = single.step_s / dp.step_s;
        let sp_soy = single.step_s / soy.step_s;
        let _ = writeln!(s, "{b:>8} {sp_dp:>12.2} {sp_soy:>12.2}");
        rows.push((b, sp_dp, sp_soy));
    }
    (s, rows)
}

/// The §2.2 worked example, both accountings.
pub fn example22() -> String {
    use std::fmt::Write as _;
    let g = paper_example::example_graph();
    let mut s = String::new();
    let _ = writeln!(s, "== §2.2 worked example: 5-layer MLP(300), batch 400, 16 devices ==");
    let _ = writeln!(s, "paper accounting (bytes × devices × 2):");
    let dp_mb = paper_example::data_parallel_comm(&g, 16) as f64 / 1e6;
    let mp_mb = paper_example::model_parallel_comm(&g, 16) as f64 / 1e6;
    let hy_mb = paper_example::hybrid_comm(&g, 16, 4) as f64 / 1e6;
    let _ = writeln!(s, "  data parallelism : {dp_mb:>6.1} MB (paper: 57.6)");
    let _ = writeln!(s, "  model parallelism: {mp_mb:>6.1} MB (paper: 76.8)");
    let _ = writeln!(s, "  hybrid (4 groups): {hy_mb:>6.1} MB (paper: 33.6)");

    // The §4 conversion model on the full training graph, 16 devices.
    let gt = mlp(&MlpConfig { batch: 400, dims: vec![300; 6], bias: false });
    let dp = Planner::try_plan(&gt, 4, PlanFamily::DataParallel).unwrap();
    let mp = Planner::try_plan(&gt, 4, PlanFamily::ModelParallel).unwrap();
    let soy = Planner::try_plan(&gt, 4, PlanFamily::Soybean).unwrap();
    let _ = writeln!(s, "§4 conversion-cost model (full training step, k=4):");
    let _ = writeln!(s, "  data parallelism : {:>6.1} MB", dp.total_cost() as f64 / 1e6);
    let _ = writeln!(s, "  model parallelism: {:>6.1} MB", mp.total_cost() as f64 / 1e6);
    let _ = writeln!(
        s,
        "  SOYBEAN optimal  : {:>6.1} MB ({})",
        soy.total_cost() as f64 / 1e6,
        crate::planner::classify(&gt, &soy.tiles)
    );
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8a_shape_holds() {
        // Hidden 8192, batch 512: DP slowest, SOYBEAN fastest at 8 devices.
        let (_, pts) = fig8(512, 8192, &SimConfig::default());
        let at8: Vec<&Point> = pts.iter().filter(|p| p.devices == 8).collect();
        let dp = at8.iter().find(|p| p.strategy == "DP").unwrap();
        let mp = at8.iter().find(|p| p.strategy == "MP").unwrap();
        let soy = at8.iter().find(|p| p.strategy == "SOYBEAN").unwrap();
        assert!(soy.runtime_s <= mp.runtime_s && soy.runtime_s < dp.runtime_s);
        // Paper: DP's overhead ~5× its compute at batch 512.
        assert!(dp.overhead_s > 2.0 * dp.compute_s);
    }

    #[test]
    fn fig8b_larger_batch_softens_dp() {
        let (_, small) = fig8(512, 8192, &SimConfig::default());
        let (_, big) = fig8(2048, 8192, &SimConfig::default());
        let ratio = |pts: &[Point]| {
            let dp = pts.iter().find(|p| p.devices == 8 && p.strategy == "DP").unwrap();
            dp.overhead_s / dp.compute_s
        };
        assert!(ratio(&big) < ratio(&small));
    }

    #[test]
    fn fig9_image_size_flips_dp_vs_mp() {
        let cfg = SimConfig::default();
        // 9(a): small image, many filters -> MP beats DP.
        let (_, a) = fig9(6, 2048, &cfg);
        let dp_a = a.iter().find(|p| p.devices == 8 && p.strategy == "DP").unwrap();
        let mp_a = a.iter().find(|p| p.devices == 8 && p.strategy == "MP").unwrap();
        assert!(mp_a.comm_bytes < dp_a.comm_bytes);
        // 9(b): large image, fewer filters -> DP beats MP.
        let (_, b) = fig9(24, 512, &cfg);
        let dp_b = b.iter().find(|p| p.devices == 8 && p.strategy == "DP").unwrap();
        let mp_b = b.iter().find(|p| p.devices == 8 && p.strategy == "MP").unwrap();
        assert!(dp_b.comm_bytes < mp_b.comm_bytes);
        // SOYBEAN at least ties the winner in both.
        for (pts, dpw, mpw) in [(&a, dp_a, mp_a), (&b, dp_b, mp_b)] {
            let soy = pts.iter().find(|p| p.devices == 8 && p.strategy == "SOYBEAN").unwrap();
            assert!(soy.comm_bytes <= dpw.comm_bytes.min(mpw.comm_bytes));
        }
    }

    #[test]
    fn fig10a_soybean_needs_smaller_batch_for_speedup() {
        // AlexNet: at batch 256 SOYBEAN's speedup must beat DP's, and DP
        // should approach SOYBEAN only at large batch (paper: >1K).
        let cfg = SimConfig::default();
        let (_, rows) = fig10("alexnet", &[256, 1024], &cfg);
        let (b0, dp0, soy0) = rows[0];
        assert_eq!(b0, 256);
        assert!(soy0 > dp0 * 1.3, "soy {soy0} dp {dp0}");
        let (_, dp1, soy1) = rows[1];
        assert!(dp1 / soy1 > dp0 / soy0, "DP should close the gap at larger batch");
    }
}
