//! Tiling planners: the paper's optimizer (§4.2.2–§4.4) and its baselines.
//!
//! - [`one_cut`] — the level-structured dynamic program (Eq. 4–5) that
//!   finds the communication-minimal tiling across **two** devices/groups.
//! - [`k_cut`] — Algorithm 1: recursively apply one-cut, halving shard
//!   shapes each time, to tile across `2^k` devices; total cost follows
//!   Theorem 1, `c_k = Σ 2^(k−i) δ_i`.
//! - [`baselines`] — the pure data-parallel and model-parallel tilings of
//!   §4.1 (`T_data`, `T_model`) as fixed plans, priced by the same cost
//!   model so the figures compare like for like.
//! - [`bruteforce`] — exhaustive enumeration for small graphs; the
//!   hand-rolled property tests check the DP against it (§4.4's optimality
//!   claim, verified empirically).
//! - [`reference`] — the pre-LUT one-cut implementation, kept as the
//!   bit-identical oracle and the speedup baseline `planner_micro` times
//!   the optimized [`OneCutSolver`] against (DESIGN.md §Perf).
//! - [`topology`] — topology-aware planning: the weighted (seconds) DP
//!   objective plus the simulator-scored candidate portfolio behind
//!   [`plan_topology_aware`] (docs/topology.md).
//! - [`strategy`] — pipeline-stage strategies: [`Strategy`] generalizes
//!   [`Plan`] with a stage partition of the levelized graph, and
//!   [`plan_strategy`] scores {tiling, pipeline, tiling×pipeline}
//!   candidates with the same engine scoreboard (docs/pipeline.md).

pub mod baselines;
pub mod bruteforce;
mod kcut;
mod onecut;
pub mod reference;
pub mod strategy;
pub mod topology;

pub use kcut::{
    apply_cut, classic_dp_form, eval_plan, eval_plan_forced, price_forced, replan_after_loss,
    try_k_cut, try_k_cut_weighted, validate_plan, Plan,
};
pub use onecut::{price, try_one_cut, OneCutPlan, OneCutSolver, PlanError};
pub use strategy::{
    batch_carrying, pick_microbatches, plan_strategy, stage_cuts, Boundary, Cell, Phase,
    Schedule, StageSpec, Strategy, StrategyPlan,
};
pub use topology::{
    modeled_step_s, try_plan_topology_aware, CandidateScore, TopologyModel, TopologyPlan,
};
// The panicking variants stay re-exported (deprecated) for one release.
#[allow(deprecated)]
pub use kcut::k_cut;
#[allow(deprecated)]
pub use onecut::one_cut;
#[allow(deprecated)]
pub use topology::plan_topology_aware;

use crate::graph::Graph;
use crate::tiling::TileSeq;

/// Which planning strategy to use — the three lines of every figure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanFamily {
    /// SOYBEAN's optimal k-cut tiling.
    Soybean,
    /// Pure data parallelism (`T_data`).
    DataParallel,
    /// Pure model parallelism (`T_model`).
    ModelParallel,
}

impl PlanFamily {
    /// Short display name (`"DP"`, `"MP"`, `"SOYBEAN"`).
    pub fn name(&self) -> &'static str {
        match self {
            PlanFamily::Soybean => "SOYBEAN",
            PlanFamily::DataParallel => "DP",
            PlanFamily::ModelParallel => "MP",
        }
    }

    /// Every strategy, baselines first (figure line order).
    pub fn all() -> [PlanFamily; 3] {
        [PlanFamily::DataParallel, PlanFamily::ModelParallel, PlanFamily::Soybean]
    }
}

/// Front door used by the CLI, examples and benches.
pub struct Planner;

impl Planner {
    /// Produce a k-cut plan for `2^k` devices under the given strategy.
    /// Panics on planner failure.
    #[deprecated(note = "use `Planner::try_plan` and handle the `PlanError`")]
    pub fn plan(g: &Graph, k: usize, strategy: PlanFamily) -> Plan {
        Planner::try_plan(g, k, strategy).expect("planning failed")
    }

    /// Produce a k-cut plan for `2^k` devices under the given strategy,
    /// with structured errors — the canonical entry point.
    ///
    /// # Examples
    ///
    /// ```
    /// use soybean::models::{mlp, MlpConfig};
    /// use soybean::planner::{Planner, PlanFamily};
    ///
    /// let g = mlp(&MlpConfig { batch: 128, dims: vec![64, 64], bias: false });
    /// let soy = Planner::try_plan(&g, 2, PlanFamily::Soybean).unwrap();
    /// let dp = Planner::try_plan(&g, 2, PlanFamily::DataParallel).unwrap();
    /// assert_eq!(soy.devices(), 4);
    /// // The optimum never moves more bytes than a fixed baseline.
    /// assert!(soy.total_cost() <= dp.total_cost());
    /// ```
    pub fn try_plan(g: &Graph, k: usize, strategy: PlanFamily) -> Result<Plan, PlanError> {
        Ok(match strategy {
            PlanFamily::Soybean => try_k_cut(g, k)?,
            PlanFamily::DataParallel => baselines::data_parallel(g, k),
            PlanFamily::ModelParallel => baselines::model_parallel(g, k),
        })
    }
}

/// Classifies a plan for reporting: does it coincide with pure data
/// parallelism, pure model parallelism, or is it a hybrid?
pub fn classify(g: &Graph, tiles: &[TileSeq]) -> &'static str {
    let k = tiles.first().map_or(0, Vec::len);
    if tiles == baselines::data_parallel_tiles(g, k).as_slice() {
        return "data-parallel";
    }
    if tiles == baselines::model_parallel_tiles(g, k).as_slice() {
        return "model-parallel";
    }
    "hybrid"
}
