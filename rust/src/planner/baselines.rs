//! The paper's fixed comparison strategies: pure data parallelism
//! (`T_data`, §4.1) and pure model parallelism (`T_model`, §4.1), priced by
//! the same conversion-cost model as the optimizer.

use crate::graph::{Graph, TensorKind};
use crate::tiling::{Tile, TileSeq};

use super::kcut::{classic_dp_form, eval_plan, eval_plan_forced, Plan};

/// `T_data`: replicate every parameter (and its aggregated gradient);
/// partition everything else along the batch dimension. Repeated at every
/// cut — data parallelism composes with itself.
pub fn data_parallel_tiles(g: &Graph, k: usize) -> Vec<TileSeq> {
    g.tensors
        .iter()
        .map(|t| {
            let tile = match t.kind {
                TensorKind::Weight | TensorKind::WeightGrad | TensorKind::UpdatedWeight => Tile::Rep,
                TensorKind::Scalar => Tile::Rep,
                _ => {
                    // Batch is dimension 0 for every non-parameter tensor in
                    // the zoo; fall back to replication if it cannot be
                    // split k times.
                    let splits_evenly =
                        t.rank() >= 1 && t.shape[0] % (1 << k) == 0 && (t.shape[0] >> k) >= 1;
                    if splits_evenly {
                        Tile::Split(0)
                    } else {
                        Tile::Rep
                    }
                }
            };
            vec![tile; k]
        })
        .collect()
}

/// `T_model`: split every parameter (rows for matrices, output channels for
/// conv filters), column-split activations, replicate activation gradients.
/// Weight gradients inherit the weight's split so updates stay local.
pub fn model_parallel_tiles(g: &Graph, k: usize) -> Vec<TileSeq> {
    let fits = |t: &crate::graph::TensorInfo, d: usize| t.shape[d] % (1 << k) == 0 && (t.shape[d] >> k) >= 1;
    g.tensors
        .iter()
        .map(|t| {
            let tile = match (t.kind, t.rank()) {
                (TensorKind::Weight | TensorKind::WeightGrad | TensorKind::UpdatedWeight, 2)
                    if fits(t, 0) =>
                {
                    Tile::Split(0)
                }
                (TensorKind::Weight | TensorKind::WeightGrad | TensorKind::UpdatedWeight, 4)
                    if fits(t, 3) =>
                {
                    Tile::Split(3)
                }
                // Bias vectors follow the output-column split.
                (TensorKind::Weight | TensorKind::WeightGrad | TensorKind::UpdatedWeight, 1)
                    if fits(t, 0) =>
                {
                    Tile::Split(0)
                }
                (TensorKind::Activation, 2) if fits(t, 1) => Tile::Split(1),
                (TensorKind::Activation, 4) if fits(t, 3) => Tile::Split(3),
                // Conv activation *gradients* are exchanged channel-split
                // ("devices synchronize activations and activation
                // gradients", §2.2); MLP activation gradients follow the
                // paper's T_model and stay replicated.
                (TensorKind::Gradient, 4) if fits(t, 3) => Tile::Split(3),
                _ => Tile::Rep,
            };
            vec![tile; k]
        })
        .collect()
}

/// Data parallelism as a priced [`Plan`] — priced with the *classic*
/// gradient-aggregation forms (MXNet's stock parameter flow), not the
/// Eq. (2) minimum, matching what the paper measured as "DP".
pub fn data_parallel(g: &Graph, k: usize) -> Plan {
    eval_plan_forced(g, &data_parallel_tiles(g, k), &classic_dp_form)
}

/// Model parallelism as a priced [`Plan`].
pub fn model_parallel(g: &Graph, k: usize) -> Plan {
    eval_plan(g, &model_parallel_tiles(g, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};

    fn mlp_train(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        let nl = dims.len() - 1;
        for l in 0..nl {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
            let bias = b.weight(&format!("b{l}"), &[dims[l + 1]]);
            h = b.bias_add(&format!("fc{l}.ba"), h, bias);
            if l + 1 < nl {
                h = b.relu(&format!("fc{l}.relu"), h);
            }
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn dp_cost_is_twice_weights_per_cut() {
        // Classic data parallelism: per cut, the only traffic is gradient
        // aggregation — red -> r on every parameter gradient = 2|θ|.
        let g = mlp_train(400, &[300, 300, 300]);
        let p = data_parallel(&g, 1);
        // (+8 bytes: the scalar loss allreduce, priced honestly.)
        assert_eq!(p.cut_costs[0], 2 * g.weight_bytes() + 8);
    }

    #[test]
    fn dp_matches_section22_accounting() {
        // With the Theorem-1 weighting, 4 cuts of 2|θ| cost 15·2|θ| ≈ the
        // §2.2 parameter-server figure of 16·2|θ| (recursive halving vs
        // star topology; same Θ(n·|θ|) scaling).
        let g = mlp_train(400, &[300; 6]);
        let p = data_parallel(&g, 4);
        // First cut: exactly the 2|θ| gradient aggregation (+ scalar loss).
        assert_eq!(p.cut_costs[0], 2 * g.weight_bytes() + 8);
        // Later (inner) cuts can only get cheaper: Eq. 2 picks the best
        // aligned form per op, and once the per-group batch shard is tiny,
        // shipping activations undercuts the classic 2|θ| allreduce. The
        // total is therefore bounded by 15 identical cuts and lands within
        // the same order as the paper's parameter-server figure.
        assert!(p.total_cost() <= 15 * (2 * g.weight_bytes() + 8));
        let ps_figure = 16 * 2 * g.weight_bytes();
        let ratio = p.total_cost() as f64 / ps_figure as f64;
        assert!(ratio > 0.5 && ratio <= 1.0, "ratio {ratio}");
    }

    #[test]
    fn mp_moves_activations_not_weights() {
        let g = mlp_train(400, &[304, 304, 304]);
        let mp = model_parallel(&g, 1);
        let dp = data_parallel(&g, 1);
        // Model parallelism's per-cut traffic scales with activations.
        assert!(mp.cut_costs[0] > 0);
        // Sanity: on this shape (batch > width) DP beats MP, §2.2's rule.
        assert!(dp.total_cost() < mp.total_cost());
    }

    #[test]
    fn mp_beats_dp_when_weights_dominate() {
        let g = mlp_train(32, &[2048, 2048, 2048]);
        let mp = model_parallel(&g, 2);
        let dp = data_parallel(&g, 2);
        assert!(mp.total_cost() < dp.total_cost(), "mp {} dp {}", mp.total_cost(), dp.total_cost());
    }

    #[test]
    fn dp_infeasible_batch_falls_back_to_rep() {
        // Batch 4 cannot be split 3 times; T_data degrades to replication
        // rather than producing an invalid plan.
        let g = mlp_train(4, &[8, 8]);
        let tiles = data_parallel_tiles(&g, 3);
        for (t, seq) in g.tensors.iter().zip(&tiles) {
            if t.kind == TensorKind::Input {
                assert_eq!(seq[0], Tile::Rep);
            }
        }
    }
}
