//! Exhaustive one-cut search for small graphs + the optimality property
//! tests (paper §4.4, checked empirically).
//!
//! proptest is not in the offline vendor set, so the property tests are
//! hand-rolled: a deterministic [`crate::util::Rng`] generates random small
//! training graphs and the DP's cost is compared against full enumeration
//! of the tiling space.

use crate::graph::Graph;
use crate::tiling::{candidate_tiles, Tile};

use super::onecut::{price, OneCutPlan};

/// Exhaustively enumerate every tiling assignment (product of candidate
/// sets over all tensors) and return the optimum. Exponential — panics if
/// the state space exceeds `limit` assignments.
pub fn brute_force(g: &Graph, limit: usize) -> OneCutPlan {
    // Enumerate only alias representatives (updated weights share their
    // weight's variable — the same steady-state constraint the DP applies).
    let alias = g.steady_state_aliases();
    let reps: Vec<usize> = (0..g.tensors.len()).filter(|&t| alias[t] == t).collect();
    let cands: Vec<Vec<Tile>> =
        reps.iter().map(|&t| candidate_tiles(&g.tensors[t])).collect();
    let states: usize = cands.iter().map(Vec::len).product();
    assert!(states <= limit, "brute force space {states} exceeds limit {limit}");

    let mut best_cost = u64::MAX;
    let mut best_tiles: Vec<Tile> = vec![Tile::Rep; g.tensors.len()];
    let mut tiles = best_tiles.clone();
    for mut idx in 0..states {
        for (i, c) in cands.iter().enumerate() {
            tiles[reps[i]] = c[idx % c.len()];
            idx /= c.len();
        }
        for t in 0..tiles.len() {
            tiles[t] = tiles[alias[t]];
        }
        let cost = price(g, &tiles);
        if cost < best_cost {
            best_cost = cost;
            best_tiles.copy_from_slice(&tiles);
        }
    }
    OneCutPlan { tiles: best_tiles, cost: best_cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};
    use crate::planner::try_one_cut;
    use crate::util::Rng;

    /// Random tiny training graph: 1–2 FC layers, optional bias/relu,
    /// random even dims. Kept under ~12 tensors so brute force stays fast.
    fn random_graph(rng: &mut Rng) -> Graph {
        let dims = [2usize, 4, 6, 8];
        let batch = *rng.choose(&[2usize, 4, 8, 16]);
        let nl = 1 + rng.below(2);
        let with_bias = rng.below(2) == 1;
        let with_relu = rng.below(2) == 1;
        let mut b = GraphBuilder::new();
        let mut shape_in = *rng.choose(&dims);
        let mut h = b.input("x", &[batch, shape_in]);
        let out_dim = *rng.choose(&dims);
        let mut last = shape_in;
        for l in 0..nl {
            let next = if l + 1 == nl { out_dim } else { *rng.choose(&dims) };
            let w = b.weight(&format!("w{l}"), &[last, next]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
            if with_bias {
                let bias = b.weight(&format!("b{l}"), &[next]);
                h = b.bias_add(&format!("ba{l}"), h, bias);
            }
            if with_relu && l + 1 < nl {
                h = b.relu(&format!("r{l}"), h);
            }
            last = next;
            shape_in = next;
        }
        let y = b.label("y", &[batch, out_dim]);
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn dp_matches_bruteforce_on_fixed_cases() {
        for (batch, din, dout) in [(4usize, 4usize, 4usize), (8, 2, 6), (16, 8, 2)] {
            let mut b = GraphBuilder::new();
            let x = b.input("x", &[batch, din]);
            let w = b.weight("w", &[din, dout]);
            let h = b.matmul("fc", x, w, false, false);
            let y = b.label("y", &[batch, dout]);
            let loss = b.softmax_xent("loss", h, y);
            append_backward(&mut b, loss);
            let g = b.finish();
            let dp = try_one_cut(&g).unwrap();
            let bf = brute_force(&g, 2_000_000);
            assert_eq!(dp.cost, bf.cost, "case {batch}x{din}x{dout}");
        }
    }

    #[test]
    fn dp_matches_bruteforce_property() {
        // Hand-rolled property test over random graphs, exercising both
        // pricing paths: the LUT-backed DP and the pre-LUT reference must
        // both equal full enumeration (which prices via direct Eq. (2)
        // evaluation — an oracle independent of the cost tables).
        let mut rng = Rng::new(0xC0FFEE);
        let mut checked = 0;
        while checked < 20 {
            let g = random_graph(&mut rng);
            let alias = g.steady_state_aliases();
            let states: usize = g
                .tensors
                .iter()
                .filter(|t| alias[t.id] == t.id)
                .map(|t| candidate_tiles(t).len())
                .product();
            if states > 400_000 {
                continue; // keep the test fast; plenty of small cases occur
            }
            let dp = try_one_cut(&g).unwrap();
            let bf = brute_force(&g, 400_000);
            assert_eq!(
                dp.cost, bf.cost,
                "optimality violated on random graph (seed case {checked}):\n{}",
                g.dump()
            );
            let reference = crate::planner::reference::one_cut_reference(&g);
            assert_eq!(
                reference.cost, bf.cost,
                "reference impl diverged on random graph (seed case {checked}):\n{}",
                g.dump()
            );
            assert_eq!(dp.tiles, reference.tiles, "tie-breaking diverged (case {checked})");
            checked += 1;
        }
    }

    #[test]
    fn lut_and_direct_pricing_agree_on_random_graphs() {
        // The cost tables must reproduce direct Eq. (2) pricing for every
        // assignment, not just optimal ones.
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..10 {
            let g = random_graph(&mut rng);
            let tables = crate::tiling::CostTables::build(&g);
            let alias = g.steady_state_aliases();
            for _ in 0..50 {
                let mut tiles: Vec<Tile> =
                    g.tensors.iter().map(|t| *rng.choose(&tables.cands[t.id])).collect();
                for t in 0..tiles.len() {
                    tiles[t] = tiles[alias[t]];
                }
                assert_eq!(tables.price(&tiles), price(&g, &tiles), "\n{}", g.dump());
            }
        }
    }

    #[test]
    fn dp_matches_bruteforce_on_attention_probe() {
        // The transformer op set against full enumeration: the one-cut DP,
        // the pre-LUT reference, and brute force (which prices via direct
        // Eq. (2) evaluation, never the LUTs) must all agree bit for bit.
        let g = crate::models::attention_probe();
        let dp = try_one_cut(&g).unwrap();
        let bf = brute_force(&g, 100_000);
        assert_eq!(dp.cost, bf.cost, "DP vs brute force on attention probe:\n{}", g.dump());
        let reference = crate::planner::reference::one_cut_reference(&g);
        assert_eq!(reference.cost, bf.cost, "reference diverged on attention probe");
        assert_eq!(dp.tiles, reference.tiles, "tie-breaking diverged on attention probe");
        // Batch-tiled attention is data parallelism: the only unavoidable
        // traffic in this forward-only core is the scalar loss allreduce.
        assert_eq!(bf.cost, 8);
    }

    #[test]
    fn dp_matches_bruteforce_on_tiny_attention_variants() {
        // A few hand-picked head/seq shapes (odd seq kills query-row and
        // score splits, heads=1 degenerates the view) — brute force must
        // still agree.
        let cases = [(2usize, 4usize, 8usize, 2usize), (2, 2, 4, 1), (4, 2, 8, 2), (2, 3, 8, 2)];
        for (batch, seq, d, heads) in cases {
            let mut b = GraphBuilder::new();
            let rows = batch * seq;
            let qkv = b.input("qkv", &[rows, 3 * d]);
            let y = b.label("y", &[rows, d]);
            let qh = b.qkv_slice("sq", qkv, 0, heads, seq);
            let kh = b.qkv_slice("sk", qkv, 1, heads, seq);
            let vh = b.qkv_slice("sv", qkv, 2, heads, seq);
            let sc = b.batched_matmul("scores", qh, kh, false, true);
            let pr = b.softmax_rows("probs", sc);
            let ct = b.batched_matmul("ctx", pr, vh, false, false);
            let cm = b.merge_heads("mh", ct, heads);
            let w = b.weight("w", &[d, d]);
            let logits = b.matmul("head", cm, w, false, false);
            b.softmax_xent("loss", logits, y);
            let g = b.finish();
            let dp = try_one_cut(&g).unwrap();
            let bf = brute_force(&g, 400_000);
            assert_eq!(dp.cost, bf.cost, "case b{batch} s{seq} d{d} h{heads}:\n{}", g.dump());
        }
    }

    #[test]
    fn dp_never_worse_than_random_assignments() {
        // Weaker but broader property: DP beats 200 random assignments on a
        // mid-sized graph too big for brute force.
        let mut b = GraphBuilder::new();
        let batch = 64;
        let dims = [32usize, 48, 32, 16];
        let mut h = b.input("x", &[batch, dims[0]]);
        for l in 0..dims.len() - 1 {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
        }
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        let g = b.finish();

        let dp = try_one_cut(&g).unwrap();
        let cands: Vec<Vec<Tile>> = g.tensors.iter().map(candidate_tiles).collect();
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let tiles: Vec<Tile> = cands.iter().map(|c| *rng.choose(c)).collect();
            assert!(dp.cost <= price(&g, &tiles));
        }
    }
}
