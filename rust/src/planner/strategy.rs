//! Pipeline-stage strategies: the inter-op parallelism axis layered on
//! top of Theorem-1 tiling.
//!
//! A [`Strategy`] generalizes a [`Plan`]: it partitions the levelized
//! graph ([`crate::graph::bfs_levels`]) into contiguous **pipeline
//! stages**, assigns each stage a contiguous device group, and plans an
//! intra-op tiling *within* each stage's group with the existing k-cut
//! DP. [`Strategy::single_stage`] is the degenerate case — one stage on
//! the full device set — and is bit-identical to the plain `Plan` path
//! end to end (modeled bytes, simulated step, executed output), which is
//! what lets every existing call site migrate mechanically.
//!
//! ## Stages and cells
//!
//! The BFS levelization is *undirected*, so the forward and backward
//! operators of the same layers land in the same level (they are
//! adjacent through the shared activations and weights). A contiguous
//! level range is therefore a classic pipeline stage: it owns a layer
//! span's forward **and** backward work. Within stage `s` the ops split
//! into two **cells** by data dependence:
//!
//! - the *forward cell* `F_s`: ops with no transitive dependency on any
//!   later stage;
//! - the *backward cell* `B_s`: the rest (they wait on gradients flowing
//!   back from stage `s+1`).
//!
//! The last stage has no later stage to wait on, so its backward work
//! fuses into its (single) cell. Cells execute in the order
//! `F_0 … F_{S-1}, B_{S-2} … B_0`; [`Strategy::try_build`] verifies that
//! every producer→consumer edge respects this order and rejects the
//! partition otherwise ([`PlanError::MalformedPlan`]).
//!
//! ## Microbatching
//!
//! Each cell's subgraph is **rebatched**: every batch-carrying tensor
//! (see [`batch_carrying`]) has its leading axis divided by the
//! microbatch count `m`, and the step runs the cell sequence once per
//! microbatch. Per-microbatch activation gradients come out scaled by
//! `m` relative to the serial graph (the loss is a *mean* over the
//! microbatch), so the executor's merge divides them back; weight
//! gradients, updated weights and the scalar loss are linear/affine in
//! that mean, so averaging the per-microbatch values reproduces the
//! serial step exactly — these identities are what keeps the pipelined
//! differential gate at 1e-5 against [`crate::graph::eval_serial`].
//!
//! ## Cost accounting
//!
//! [`Strategy::total_cost`] extends Theorem 1 across the stage axis:
//! `m × (Σ_cells intra-cell k-cut cost + Σ cross-stage boundary bytes)`.
//! Boundary tensors cross between device groups once per microbatch as
//! `SendRecv` transfers; same-stage forward→backward handoffs are local
//! stashes and cost zero wire bytes. The lowered
//! [`crate::lower::PipelinedProgram`] and the executor's byte meter both
//! reconcile against this total bit for bit.

use std::collections::BTreeMap;

use crate::graph::{bfs_levels, Graph, Levels, OpId, OpKind, TensorId, TensorKind};
use crate::sim::Topology;

use super::topology::{try_plan_topology_aware, CandidateScore, TopologyPlan};
use super::{try_k_cut, Plan, PlanError};

/// Microbatch schedule flavors for a pipelined step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// All forward microbatches, then all backward (GPipe).
    GPipe,
    /// One-forward-one-backward steady state with a bounded number of
    /// in-flight microbatches per stage (PipeDream-style 1F1B).
    OneF1B,
}

impl Schedule {
    /// Lowercase display name (`"gpipe"` / `"1f1b"`).
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::GPipe => "gpipe",
            Schedule::OneF1B => "1f1b",
        }
    }

    /// Both schedules, GPipe first.
    pub fn all() -> [Schedule; 2] {
        [Schedule::GPipe, Schedule::OneF1B]
    }
}

/// Which half of a stage's work a cell holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Ops with no transitive dependency on later stages.
    Forward,
    /// Ops waiting on gradients from the next stage.
    Backward,
}

impl Phase {
    /// Short display name (`"fwd"` / `"bwd"`).
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Forward => "fwd",
            Phase::Backward => "bwd",
        }
    }
}

/// One pipeline stage: a contiguous level range on a contiguous device
/// group.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Stage index (0 = the stage holding the model input).
    pub stage: usize,
    /// First level (inclusive) of this stage's range.
    pub level_lo: usize,
    /// One past the last level of this stage's range.
    pub level_hi: usize,
    /// First device of this stage's contiguous group.
    pub device_lo: usize,
    /// Cuts of the intra-stage tiling (the group spans `2^k` devices).
    pub k: usize,
}

impl StageSpec {
    /// Devices in this stage's group.
    pub fn devices(&self) -> usize {
        1 << self.k
    }
}

/// One schedulable unit: a stage's forward or backward subgraph,
/// rebatched to microbatch shape, with its own intra-op tiling plan.
#[derive(Debug, Clone)]
pub struct Cell {
    /// The stage this cell belongs to.
    pub stage: usize,
    /// Forward or backward half (the last stage's single cell is
    /// `Forward` — its backward work fuses in).
    pub phase: Phase,
    /// The microbatch-shaped local subgraph.
    pub graph: Graph,
    /// Intra-cell tiling plan on the stage's device group.
    pub plan: Plan,
    /// Local op index → op id in the original graph.
    pub ops: Vec<OpId>,
    /// Local tensor index → tensor id in the original graph.
    pub tensors: Vec<TensorId>,
}

impl Cell {
    /// Display label, e.g. `"s0.fwd"`.
    pub fn label(&self) -> String {
        format!("s{}.{}", self.stage, self.phase.name())
    }
}

/// A tensor produced in one cell and consumed in another.
#[derive(Debug, Clone)]
pub struct Boundary {
    /// Tensor id in the original graph.
    pub tensor: TensorId,
    /// Producing cell (index into [`Strategy::cells`]).
    pub from_cell: usize,
    /// Consuming cell.
    pub to_cell: usize,
    /// Microbatch-shaped bytes crossing the stage boundary — zero when
    /// both cells share a stage (a local activation stash, no wire).
    pub bytes: u64,
}

impl Boundary {
    /// True for same-stage forward→backward handoffs (no wire traffic).
    pub fn is_stash(&self) -> bool {
        self.bytes == 0
    }
}

/// A generalized execution plan: pipeline stages × intra-stage tiling.
#[derive(Debug, Clone)]
pub struct Strategy {
    /// Total cuts across the whole device set (`2^k` devices).
    pub k: usize,
    /// Microbatches per step (1 = no microbatching).
    pub microbatches: usize,
    /// The microbatch schedule the step runs under.
    pub schedule: Schedule,
    /// The stages, in pipeline order.
    pub stages: Vec<StageSpec>,
    /// The cells, in execution order `F_0 … F_{S-1}, B_{S-2} … B_0`.
    pub cells: Vec<Cell>,
    /// Every cross-cell tensor handoff (stashes and wire transfers).
    pub boundaries: Vec<Boundary>,
}

impl Strategy {
    /// The degenerate strategy: one stage spanning every level on the
    /// full device set, running the given plan. Bit-identical to the
    /// plain `Plan` path: same Theorem-1 total, same lowered program,
    /// same executed output.
    pub fn single_stage(g: &Graph, plan: Plan) -> Strategy {
        let levels = bfs_levels(g).levels.len();
        let k = plan.k;
        Strategy {
            k,
            microbatches: 1,
            schedule: Schedule::GPipe,
            stages: vec![StageSpec { stage: 0, level_lo: 0, level_hi: levels, device_lo: 0, k }],
            cells: vec![Cell {
                stage: 0,
                phase: Phase::Forward,
                graph: g.clone(),
                plan,
                ops: (0..g.ops.len()).collect(),
                tensors: (0..g.tensors.len()).collect(),
            }],
            boundaries: Vec::new(),
        }
    }

    /// Whether this is the degenerate single-stage strategy.
    pub fn is_single_stage(&self) -> bool {
        self.stages.len() == 1
    }

    /// Number of pipeline stages.
    pub fn stage_count(&self) -> usize {
        self.stages.len()
    }

    /// Devices the strategy spans (`2^k`).
    pub fn devices(&self) -> usize {
        1 << self.k
    }

    /// Wire bytes crossing stage boundaries, per microbatch.
    pub fn boundary_bytes(&self) -> u64 {
        self.boundaries.iter().map(|b| b.bytes).sum()
    }

    /// The strategy's modeled communication total: Theorem-1 intra-cell
    /// cost plus cross-stage boundary bytes, once per microbatch. For
    /// [`Strategy::single_stage`] this equals `plan.total_cost()` bit
    /// for bit.
    pub fn total_cost(&self) -> u64 {
        let per_micro: u64 = self.cells.iter().map(|c| c.plan.total_cost()).sum::<u64>()
            + self.boundary_bytes();
        self.microbatches as u64 * per_micro
    }

    /// Display labels for the cells, in execution order.
    pub fn cell_labels(&self) -> Vec<String> {
        self.cells.iter().map(Cell::label).collect()
    }

    /// Build a pipelined strategy from explicit stage cuts.
    ///
    /// `cuts` are the interior level indices where a new stage starts
    /// (`cuts.len() + 1` stages); `k` is the total cut count (`2^k`
    /// devices split evenly across the stages, so the stage count must
    /// be a power of two ≤ `2^k`); `microbatches` must divide every
    /// batch-carrying tensor's leading axis. Fails with
    /// [`PlanError::MalformedPlan`] when the partition is not
    /// order-feasible or not microbatchable, and propagates intra-cell
    /// planner errors.
    pub fn try_build(
        g: &Graph,
        cuts: &[usize],
        k: usize,
        microbatches: usize,
        schedule: Schedule,
    ) -> Result<Strategy, PlanError> {
        let malformed = |reason: String| Err(PlanError::MalformedPlan { reason });
        let levels = bfs_levels(g);
        let n_levels = levels.levels.len();
        let s_count = cuts.len() + 1;
        if !s_count.is_power_of_two() || s_count > (1 << k) {
            return malformed(format!("{s_count} stages cannot split 2^{k} devices evenly"));
        }
        if cuts.windows(2).any(|w| w[0] >= w[1])
            || cuts.iter().any(|&c| c == 0 || c >= n_levels)
        {
            return malformed(format!("stage cuts {cuts:?} are not interior to {n_levels} levels"));
        }
        if microbatches == 0 || !microbatches.is_power_of_two() {
            return malformed(format!("{microbatches} microbatches (must be a power of two)"));
        }
        let k_stage = k - s_count.trailing_zeros() as usize;

        // Stage of every level, then of every op.
        let mut stage_of_level = vec![0usize; n_levels];
        for (l, slot) in stage_of_level.iter_mut().enumerate() {
            *slot = cuts.iter().filter(|&&c| c <= l).count();
        }
        let mut stage_of_op = vec![0usize; g.ops.len()];
        for (l, ops) in levels.levels.iter().enumerate() {
            for &u in ops {
                stage_of_op[u] = stage_of_level[l];
            }
        }

        // Transitive "highest stage this op depends on": producers come
        // before consumers in topo order, so one forward sweep suffices.
        let order = g.topo_order();
        let mut need = vec![0usize; g.ops.len()];
        for &u in &order {
            let mut n = stage_of_op[u];
            for &t in &g.ops[u].inputs {
                if let Some(v) = g.producer(t) {
                    n = n.max(need[v]);
                }
            }
            need[u] = n;
        }

        // Cell of every op, in execution order F_0..F_{S-1}, B_{S-2}..B_0.
        let cell_slots = 2 * s_count - 1;
        let cell_of_op: Vec<usize> = (0..g.ops.len())
            .map(|u| {
                let s = stage_of_op[u];
                if need[u] > s { 2 * (s_count - 1) - s } else { s }
            })
            .collect();

        // Order feasibility: every edge must flow forward in cell order.
        for op in &g.ops {
            for &t in &op.inputs {
                if let Some(v) = g.producer(t) {
                    if cell_of_op[v] > cell_of_op[op.id] {
                        return malformed(format!(
                            "edge `{}` -> `{}` runs against the cell order at cuts {cuts:?}",
                            g.ops[v].name, op.name
                        ));
                    }
                }
            }
        }

        // Microbatch shapes.
        let carrying = batch_carrying(g);
        let m = microbatches;
        for t in &g.tensors {
            if carrying[t.id] && t.shape[0] % m != 0 {
                return malformed(format!(
                    "tensor `{}` batch axis {} not divisible by {m} microbatches",
                    t.name, t.shape[0]
                ));
            }
        }
        let micro_shape = |t: TensorId| -> Vec<usize> {
            let mut s = g.tensors[t].shape.clone();
            if carrying[t] {
                s[0] /= m;
            }
            s
        };

        // Materialize the non-empty cells (execution order preserved).
        let mut cells = Vec::new();
        let mut cell_index = vec![usize::MAX; cell_slots];
        for c in 0..cell_slots {
            let ops: Vec<OpId> = order.iter().copied().filter(|&u| cell_of_op[u] == c).collect();
            if ops.is_empty() {
                continue;
            }
            let stage = if c < s_count { c } else { 2 * (s_count - 1) - c };
            let phase = if c < s_count { Phase::Forward } else { Phase::Backward };
            let mut local_of: BTreeMap<TensorId, usize> = BTreeMap::new();
            let mut tensors = Vec::new();
            let mut touch = |t: TensorId, tensors: &mut Vec<TensorId>| {
                *local_of.entry(t).or_insert_with(|| {
                    tensors.push(t);
                    tensors.len() - 1
                })
            };
            let mut local_ops = Vec::with_capacity(ops.len());
            for (li, &u) in ops.iter().enumerate() {
                let op = &g.ops[u];
                let mut lop = op.clone();
                lop.id = li;
                lop.inputs = op.inputs.iter().map(|&t| touch(t, &mut tensors)).collect();
                lop.outputs = op.outputs.iter().map(|&t| touch(t, &mut tensors)).collect();
                local_ops.push(lop);
            }
            let local_tensors = tensors
                .iter()
                .enumerate()
                .map(|(li, &t)| {
                    let mut info = g.tensors[t].clone();
                    info.id = li;
                    info.shape = micro_shape(t);
                    info
                })
                .collect();
            let graph = Graph { tensors: local_tensors, ops: local_ops };
            let plan = try_k_cut(&graph, k_stage)?;
            cell_index[c] = cells.len();
            cells.push(Cell { stage, phase, graph, plan, ops, tensors });
        }

        // Cross-cell handoffs: one boundary per (tensor, consuming cell).
        let mut boundaries = Vec::new();
        for t in &g.tensors {
            let Some(v) = g.producer(t.id) else { continue };
            let from = cell_index[cell_of_op[v]];
            let mut seen = Vec::new();
            for u in g.consumers(t.id) {
                let to = cell_index[cell_of_op[u]];
                if to == from || seen.contains(&to) {
                    continue;
                }
                seen.push(to);
                let cross_stage = cells[from].stage != cells[to].stage;
                let bytes = if cross_stage {
                    micro_shape(t.id).iter().map(|&d| d as u64).product::<u64>().max(1)
                        * g.tensors[t.id].dtype_bytes as u64
                } else {
                    0
                };
                boundaries.push(Boundary { tensor: t.id, from_cell: from, to_cell: to, bytes });
            }
        }

        let stages = (0..s_count)
            .map(|s| StageSpec {
                stage: s,
                level_lo: if s == 0 { 0 } else { cuts[s - 1] },
                level_hi: if s == s_count - 1 { n_levels } else { cuts[s] },
                device_lo: s << k_stage,
                k: k_stage,
            })
            .collect();

        Ok(Strategy { k, microbatches: m, schedule, stages, cells, boundaries })
    }
}

/// Which tensors carry the mini-batch along their leading axis.
///
/// Producerless tensors carry iff they are the model input or the
/// labels; the flag then propagates through each operator: most ops
/// preserve their first operand's batch axis, while the batch-reducing
/// ops (weight-gradient matmuls/convolutions, the mean loss, column
/// reductions, the SGD update) drop it. This is the rebatching rule the
/// microbatch slicer, the cell builder, and the executor's merge all
/// share.
pub fn batch_carrying(g: &Graph) -> Vec<bool> {
    let mut carry = vec![false; g.tensors.len()];
    for t in &g.tensors {
        if g.producer(t.id).is_none() {
            carry[t.id] =
                matches!(t.kind, TensorKind::Input | TensorKind::Label) && !t.shape.is_empty();
        }
    }
    for &u in &g.topo_order() {
        let op = &g.ops[u];
        let c = match op.kind {
            OpKind::Conv2dBwdFilter { .. }
            | OpKind::SoftmaxXent
            | OpKind::SgdUpdate
            | OpKind::LayerNormGammaGrad
            | OpKind::ReduceSumRows => false,
            OpKind::MatMul { ta, .. } => !ta && carry[op.inputs[0]],
            _ => carry[op.inputs[0]],
        };
        for &t in &op.outputs {
            carry[t] = c && !g.tensors[t].shape.is_empty();
        }
    }
    carry
}

/// The largest power-of-two microbatch count ≤ `target` that divides
/// every batch-carrying tensor's leading axis (1 when nothing divides).
pub fn pick_microbatches(g: &Graph, target: usize) -> usize {
    let carrying = batch_carrying(g);
    let mut m = target.max(1).next_power_of_two();
    if m > target {
        m /= 2;
    }
    while m > 1 {
        let ok = g
            .tensors
            .iter()
            .all(|t| !carrying[t.id] || t.shape[0] % m == 0);
        if ok {
            return m;
        }
        m /= 2;
    }
    1
}

/// A scored strategy: the winner of [`plan_strategy`]'s portfolio.
#[derive(Debug, Clone)]
pub struct StrategyPlan {
    /// The fastest strategy found (single-stage tiling when nothing
    /// pipelined beats it).
    pub strategy: Strategy,
    /// Name of the winning candidate (`"tiling"`, `"gpipe-2"`, …).
    pub chosen: &'static str,
    /// The winner's engine-simulated step (seconds).
    pub step_s: f64,
    /// The pure-tiling candidate's step — `step_s` never exceeds this.
    pub tiling_step_s: f64,
    /// The underlying topology-aware tiling plan (the portfolio's seed).
    pub tiling: TopologyPlan,
    /// Every candidate scored, portfolio order (tiling first).
    pub scores: Vec<CandidateScore>,
    /// The winner's pipeline simulation report.
    pub report: crate::sim::PipelineReport,
}

fn candidate_name(stages: usize, schedule: Schedule) -> &'static str {
    match (stages, schedule) {
        (2, Schedule::GPipe) => "gpipe-2",
        (2, Schedule::OneF1B) => "1f1b-2",
        (4, Schedule::GPipe) => "gpipe-4",
        (4, Schedule::OneF1B) => "1f1b-4",
        _ => "pipeline",
    }
}

/// Stage-partition DP: choose `s_count - 1` interior level cuts
/// minimizing the byte objective — per-stage intra-op k-cut cost on the
/// stage's (smaller) group plus boundary bytes at every cut — seeded by
/// the existing odometer DP on each candidate level range. This is the
/// *seed* objective; [`plan_strategy`] re-scores the surviving partition
/// with the event engine's schedule simulation.
pub fn stage_cuts(
    g: &Graph,
    levels: &Levels,
    s_count: usize,
    k_stage: usize,
    microbatches: usize,
) -> Result<Vec<usize>, PlanError> {
    let n = levels.levels.len();
    if n < s_count {
        return Err(PlanError::MalformedPlan {
            reason: format!("{n} levels cannot form {s_count} stages"),
        });
    }
    let carrying = batch_carrying(g);
    let m = microbatches as u64;
    let micro_bytes = |t: TensorId| -> u64 {
        let info = &g.tensors[t];
        let mut elems: u64 = info.shape.iter().map(|&d| d as u64).product::<u64>().max(1);
        if carrying[t] {
            elems /= m;
        }
        elems * info.dtype_bytes as u64
    };

    // Candidate interior cuts, thinned so the DP stays O(32^2) k-cut
    // seeds even on deep CNNs.
    let mut cand: Vec<usize> = (1..n).collect();
    if cand.len() > 32 {
        let step = cand.len() as f64 / 32.0;
        cand = (0..32).map(|i| 1 + (i as f64 * step) as usize).collect();
        cand.dedup();
    }
    let mut points = vec![0];
    points.extend(cand.iter().copied());
    points.push(n);
    points.dedup();
    let p = points.len();

    // Per-range intra-stage seed cost: odometer DP over the level
    // range's micro-shaped subgraph (both phases together — the split
    // into cells happens after the cut choice).
    let carrying_shape = |t: TensorId| -> Vec<usize> {
        let mut s = g.tensors[t].shape.clone();
        if carrying[t] {
            s[0] /= microbatches;
        }
        s
    };
    let range_cost = |lo: usize, hi: usize| -> u64 {
        let mut ops: Vec<OpId> = Vec::new();
        for lvl in &levels.levels[lo..hi] {
            ops.extend(lvl.iter().copied());
        }
        ops.sort_unstable();
        let order = g.topo_order();
        let ops: Vec<OpId> = order.into_iter().filter(|u| ops.binary_search(u).is_ok()).collect();
        let mut local_of: BTreeMap<TensorId, usize> = BTreeMap::new();
        let mut tensors: Vec<TensorId> = Vec::new();
        let mut local_ops = Vec::with_capacity(ops.len());
        for (li, &u) in ops.iter().enumerate() {
            let op = &g.ops[u];
            let mut lop = op.clone();
            lop.id = li;
            let mut touch = |t: TensorId| {
                *local_of.entry(t).or_insert_with(|| {
                    tensors.push(t);
                    tensors.len() - 1
                })
            };
            lop.inputs = op.inputs.iter().map(|&t| touch(t)).collect();
            lop.outputs = op.outputs.iter().map(|&t| touch(t)).collect();
            local_ops.push(lop);
        }
        let local_tensors = tensors
            .iter()
            .enumerate()
            .map(|(li, &t)| {
                let mut info = g.tensors[t].clone();
                info.id = li;
                info.shape = carrying_shape(t);
                info
            })
            .collect();
        let sub = Graph { tensors: local_tensors, ops: local_ops };
        match try_k_cut(&sub, k_stage) {
            Ok(plan) => plan.total_cost(),
            Err(_) => u64::MAX / 4,
        }
    };
    let cost: Vec<Vec<u64>> = (0..p)
        .map(|i| {
            (0..p)
                .map(|j| if j > i { range_cost(points[i], points[j]) } else { 0 })
                .collect()
        })
        .collect();
    let cut_bytes = |l: usize| -> u64 { levels.boundary[l - 1].iter().map(|&t| micro_bytes(t)).sum() };

    // dp[s][j] = best cost of packing points[0..=j] into s stages.
    let inf = u64::MAX / 2;
    let mut dp = vec![vec![inf; p]; s_count + 1];
    let mut from = vec![vec![usize::MAX; p]; s_count + 1];
    dp[0][0] = 0;
    for s in 1..=s_count {
        for j in 1..p {
            for i in (s - 1)..j {
                if dp[s - 1][i] >= inf {
                    continue;
                }
                let boundary = if i > 0 { cut_bytes(points[i]) } else { 0 };
                let c = dp[s - 1][i].saturating_add(cost[i][j]).saturating_add(boundary);
                if c < dp[s][j] {
                    dp[s][j] = c;
                    from[s][j] = i;
                }
            }
        }
    }
    if dp[s_count][p - 1] >= inf {
        return Err(PlanError::Infeasible);
    }
    let mut cuts = Vec::new();
    let mut j = p - 1;
    for s in (1..=s_count).rev() {
        let i = from[s][j];
        if i > 0 {
            cuts.push(points[i]);
        }
        j = i;
    }
    cuts.reverse();
    Ok(cuts)
}

/// Score a portfolio of {tiling-only, 2/4-stage pipeline × schedule}
/// candidates on the actual topology and return the fastest — never
/// worse than [`try_plan_topology_aware`]'s pure-tiling winner by
/// construction (the tiling candidate is always in the portfolio and
/// wins ties).
///
/// # Examples
///
/// ```
/// use soybean::models::{mlp, MlpConfig};
/// use soybean::planner::plan_strategy;
/// use soybean::sim::Topology;
///
/// let g = mlp(&MlpConfig { batch: 16, dims: vec![8, 8, 8], bias: false });
/// let sp = plan_strategy(&g, 4, &Topology::two_tier(2)).unwrap();
/// // Never worse than the pure-tiling winner, by construction.
/// assert!(sp.step_s <= sp.tiling_step_s);
/// assert_eq!(sp.scores[0].name, "tiling");
/// ```
pub fn plan_strategy(
    g: &Graph,
    devices: usize,
    topo: &Topology,
) -> Result<StrategyPlan, PlanError> {
    assert!(devices.is_power_of_two(), "device count must be a power of two");
    let k = devices.trailing_zeros() as usize;
    let tiling = try_plan_topology_aware(g, devices, topo)?;
    let single = Strategy::single_stage(g, tiling.plan.clone());
    let mut best_report = crate::sim::try_simulate_strategy(&single, topo)?;
    let mut best = single;
    let mut best_step = tiling.step_s;
    let mut chosen: &'static str = "tiling";
    let mut scores = vec![CandidateScore {
        name: "tiling",
        step_s: tiling.step_s,
        total_bytes: tiling.plan.total_cost(),
    }];

    let levels = bfs_levels(g);
    let m = pick_microbatches(g, 4);
    for s_count in [2usize, 4] {
        if s_count > devices || levels.levels.len() < s_count {
            continue;
        }
        let k_stage = k - s_count.trailing_zeros() as usize;
        let Ok(cuts) = stage_cuts(g, &levels, s_count, k_stage, m) else { continue };
        for sched in Schedule::all() {
            let Ok(strat) = Strategy::try_build(g, &cuts, k, m, sched) else { continue };
            let Ok(report) = crate::sim::try_simulate_strategy(&strat, topo) else { continue };
            let name = candidate_name(s_count, sched);
            scores.push(CandidateScore {
                name,
                step_s: report.step_s,
                total_bytes: strat.total_cost(),
            });
            if report.step_s < best_step {
                best_step = report.step_s;
                chosen = name;
                best = strat;
                best_report = report;
            }
        }
    }

    Ok(StrategyPlan {
        strategy: best,
        chosen,
        step_s: best_step,
        tiling_step_s: tiling.step_s,
        tiling,
        scores,
        report: best_report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mlp, transformer, MlpConfig, TransformerConfig};

    fn small_mlp() -> Graph {
        mlp(&MlpConfig { batch: 16, dims: vec![8, 8, 8], bias: true })
    }

    #[test]
    fn single_stage_total_cost_is_plan_cost() {
        let g = small_mlp();
        let plan = try_k_cut(&g, 2).unwrap();
        let want = plan.total_cost();
        let s = Strategy::single_stage(&g, plan);
        assert!(s.is_single_stage());
        assert_eq!(s.total_cost(), want);
        assert_eq!(s.boundary_bytes(), 0);
        assert_eq!(s.devices(), 4);
    }

    #[test]
    fn batch_carrying_marks_activation_chain_not_weights() {
        let g = small_mlp();
        let carry = batch_carrying(&g);
        for t in &g.tensors {
            match t.kind {
                TensorKind::Input | TensorKind::Label => assert!(carry[t.id], "{}", t.name),
                TensorKind::Weight
                | TensorKind::WeightGrad
                | TensorKind::UpdatedWeight
                | TensorKind::Scalar => assert!(!carry[t.id], "{}", t.name),
                TensorKind::Activation | TensorKind::Gradient => {
                    assert_eq!(carry[t.id], t.shape.first() == Some(&16), "{}", t.name)
                }
            }
        }
    }

    #[test]
    fn pick_microbatches_respects_divisibility() {
        assert_eq!(pick_microbatches(&small_mlp(), 4), 4);
        // Batch 4, seq 4 — the head view's leading axis is B·H = 8.
        let g = transformer(&TransformerConfig::tiny());
        assert!(pick_microbatches(&g, 4) >= 1);
    }

    #[test]
    fn two_stage_build_partitions_cells_in_order() {
        let g = small_mlp();
        let levels = bfs_levels(&g);
        let n = levels.levels.len();
        assert!(n >= 2, "mlp should levelize into 2+ levels");
        let strat = Strategy::try_build(&g, &[n / 2], 2, 2, Schedule::OneF1B).unwrap();
        assert_eq!(strat.stage_count(), 2);
        assert_eq!(strat.microbatches, 2);
        // Stage groups tile the device range contiguously.
        assert_eq!(strat.stages[0].device_lo, 0);
        assert_eq!(strat.stages[1].device_lo, 2);
        // Every op appears in exactly one cell.
        let mut seen = vec![false; g.ops.len()];
        for c in &strat.cells {
            for &u in &c.ops {
                assert!(!seen[u]);
                seen[u] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        // Cross-stage boundaries carry bytes; stashes don't.
        assert!(strat.boundaries.iter().any(|b| b.bytes > 0));
        assert!(strat.total_cost() > 0);
    }

    #[test]
    fn stage_cuts_dp_returns_interior_cuts() {
        let g = small_mlp();
        let levels = bfs_levels(&g);
        let cuts = stage_cuts(&g, &levels, 2, 1, 2).unwrap();
        assert_eq!(cuts.len(), 1);
        assert!(cuts[0] > 0 && cuts[0] < levels.levels.len());
    }

    #[test]
    fn infeasible_cuts_are_rejected() {
        let g = small_mlp();
        // Non-power-of-two stage count.
        let r = Strategy::try_build(&g, &[1, 2], 2, 1, Schedule::GPipe);
        assert!(matches!(r, Err(PlanError::MalformedPlan { .. })));
        // Cut out of range.
        let r = Strategy::try_build(&g, &[0], 2, 1, Schedule::GPipe);
        assert!(matches!(r, Err(PlanError::MalformedPlan { .. })));
    }
}
