//! The pre-LUT one-cut implementation, preserved verbatim in spirit.
//!
//! This is the straightforward rendering of the paper's DP that shipped
//! before the cost-table overhaul: Eq. (2) is re-derived through
//! [`op_cost`] inside the tabulation loops, states are decoded into
//! per-visit `Vec<Tile>`s, and tensors are resolved by linear scans. It is
//! kept for two jobs:
//!
//! - **equivalence**: the optimized [`super::one_cut`] must return exactly
//!   the same cost on every workload (asserted by unit, property and
//!   integration tests — the paper's optimality claim doesn't survive an
//!   "almost equal" refactor);
//! - **measurement**: `benches/planner_micro.rs` times this against the
//!   LUT path and reports the speedup, so the perf trajectory is tracked
//!   rather than asserted into folklore (DESIGN.md §Perf).
//!
//! Nothing else may call into this module — new planner work goes through
//! [`super::OneCutSolver`].

use std::collections::HashMap;

use crate::graph::{bfs_levels, Graph, TensorId};
use crate::tiling::aligned::INFEASIBLE;
use crate::tiling::{candidate_tiles, op_cost, Tile};

use super::onecut::{price, OneCutPlan};

/// An enumerable assignment space over a fixed list of tensors.
#[derive(Debug, Clone, Default)]
struct Space {
    ids: Vec<TensorId>,
    cands: Vec<Vec<Tile>>,
}

impl Space {
    fn new(ids: Vec<TensorId>, all_cands: &[Vec<Tile>]) -> Self {
        let cands = ids.iter().map(|&t| all_cands[t].clone()).collect();
        Space { ids, cands }
    }

    fn len(&self) -> usize {
        self.cands.iter().map(Vec::len).product()
    }

    /// Decode a mixed-radix index into per-tensor tiles (same order as ids).
    fn decode(&self, mut idx: usize) -> Vec<Tile> {
        let mut out = Vec::with_capacity(self.cands.len());
        for c in &self.cands {
            out.push(c[idx % c.len()]);
            idx /= c.len();
        }
        out
    }
}

/// One intra-level component with its tabulated cost table.
struct Component {
    boundary_ids: Vec<TensorId>,
    internal: Space,
    table: Vec<(u64, usize)>,
    boundary_radix: Vec<usize>,
}

impl Component {
    fn index_of(&self, choose: &dyn Fn(TensorId) -> usize) -> usize {
        let mut idx = 0;
        let mut mult = 1;
        for (i, &t) in self.boundary_ids.iter().enumerate() {
            idx += choose(t) * mult;
            mult *= self.boundary_radix[i];
        }
        idx
    }
}

fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]];
        x = parent[x];
    }
    x
}

/// The pre-optimization one-cut DP. Same result as [`super::one_cut`],
/// several times slower — see module docs for why it is kept.
pub fn one_cut_reference(g: &Graph) -> OneCutPlan {
    let nt = g.tensors.len();
    let all_cands: Vec<Vec<Tile>> = g.tensors.iter().map(candidate_tiles).collect();
    if g.ops.is_empty() {
        return OneCutPlan { tiles: vec![Tile::Rep; nt], cost: 0 };
    }
    let alias = g.steady_state_aliases();

    let lv = bfs_levels(g);
    let nlevels = lv.levels.len();

    let mut boundary_level = vec![usize::MAX; nt];
    for (l, b) in lv.boundary.iter().enumerate() {
        for &t in b {
            boundary_level[t] = l;
        }
    }
    let mut internal_level = vec![usize::MAX; nt];
    for (l, ts) in lv.internal.iter().enumerate() {
        for &t in ts {
            internal_level[t] = l;
        }
    }

    // Build per-level components and their tables.
    let mut level_components: Vec<Vec<Component>> = Vec::with_capacity(nlevels);
    for (l, ops) in lv.levels.iter().enumerate() {
        let mut parent: Vec<usize> = (0..ops.len()).collect();
        let mut internal_owner: HashMap<TensorId, usize> = HashMap::new();
        for (oi, &op) in ops.iter().enumerate() {
            let o = &g.ops[op];
            for &t in o.inputs.iter().chain(o.outputs.iter()) {
                let t = alias[t];
                if internal_level[t] == l {
                    match internal_owner.get(&t) {
                        None => {
                            internal_owner.insert(t, oi);
                        }
                        Some(&prev) => {
                            let (a, b) = (find(&mut parent, prev), find(&mut parent, oi));
                            if a != b {
                                parent[a] = b;
                            }
                        }
                    }
                }
            }
        }
        let mut groups: HashMap<usize, Vec<usize>> = HashMap::new();
        for (oi, &op) in ops.iter().enumerate() {
            groups.entry(find(&mut parent, oi)).or_default().push(op);
        }

        let mut comps = Vec::new();
        let mut group_keys: Vec<usize> = groups.keys().copied().collect();
        group_keys.sort_unstable();
        for key in group_keys {
            let comp_ops = groups[&key].clone();
            let mut bids: Vec<TensorId> = Vec::new();
            let mut iids: Vec<TensorId> = Vec::new();
            for &op in &comp_ops {
                let o = &g.ops[op];
                for &t in o.inputs.iter().chain(o.outputs.iter()) {
                    let t = alias[t];
                    if internal_level[t] == l {
                        if !iids.contains(&t) {
                            iids.push(t);
                        }
                    } else if !bids.contains(&t) {
                        bids.push(t);
                    }
                }
            }
            bids.sort_unstable();
            iids.sort_unstable();
            let internal = Space::new(iids, &all_cands);
            let boundary_radix: Vec<usize> = bids.iter().map(|&t| all_cands[t].len()).collect();
            let table_len: usize = boundary_radix.iter().product::<usize>().max(1);
            assert!(
                table_len.saturating_mul(internal.len().max(1)) < 50_000_000,
                "level {l} component too large for exhaustive tabulation"
            );

            let mut table = vec![(INFEASIBLE, 0usize); table_len];
            let bspace = Space::new(bids.clone(), &all_cands);
            for (bidx, entry) in table.iter_mut().enumerate() {
                let btiles = bspace.decode(bidx);
                let mut best = (INFEASIBLE, 0usize);
                for iidx in 0..internal.len().max(1) {
                    let itiles = if internal.ids.is_empty() {
                        Vec::new()
                    } else {
                        internal.decode(iidx)
                    };
                    let lookup = |t: TensorId| -> Tile {
                        let t = alias[t];
                        if let Some(p) = bids.iter().position(|&x| x == t) {
                            btiles[p]
                        } else if let Some(p) = internal.ids.iter().position(|&x| x == t) {
                            itiles[p]
                        } else {
                            unreachable!("tensor {t} not in component scope")
                        }
                    };
                    let mut cost = 0u64;
                    for &op in &comp_ops {
                        let o = &g.ops[op];
                        let ins: Vec<Tile> = o.inputs.iter().map(|&t| lookup(t)).collect();
                        let out = lookup(o.outputs[0]);
                        cost = cost.saturating_add(op_cost(g, o, &ins, out));
                        if cost >= best.0 {
                            break;
                        }
                    }
                    if cost < best.0 {
                        best = (cost, iidx);
                    }
                }
                *entry = best;
            }
            comps.push(Component { boundary_ids: bids, internal, table, boundary_radix });
        }
        level_components.push(comps);
    }

    // DP over boundary assignments. boundary[l] exists for l in 0..nlevels-1.
    let spaces: Vec<Space> = (0..nlevels.saturating_sub(1))
        .map(|l| Space::new(lv.boundary[l].clone(), &all_cands))
        .collect();
    let mut pos_in_boundary = vec![usize::MAX; nt];
    for sp in &spaces {
        for (i, &t) in sp.ids.iter().enumerate() {
            pos_in_boundary[t] = i;
        }
    }

    let empty = Space::default();
    let mut dp: Vec<Vec<(u64, usize)>> = Vec::with_capacity(nlevels);
    for l in 0..nlevels {
        let prev_space = if l == 0 { &empty } else { &spaces[l - 1] };
        let cur_space = if l + 1 < nlevels { &spaces[l] } else { &empty };
        let prev_len = prev_space.len().max(1);
        let cur_len = cur_space.len().max(1);

        let mut cur_dp = vec![(INFEASIBLE, 0usize); cur_len];
        let digits = |space: &Space, mut idx: usize| -> Vec<usize> {
            space
                .cands
                .iter()
                .map(|c| {
                    let d = idx % c.len();
                    idx /= c.len();
                    d
                })
                .collect()
        };
        let prev_digit_cache: Vec<Vec<usize>> =
            (0..prev_len).map(|i| digits(prev_space, i)).collect();

        for (cur_idx, slot) in cur_dp.iter_mut().enumerate() {
            let cur_digits = digits(cur_space, cur_idx);
            let mut best = (INFEASIBLE, 0usize);
            for prev_idx in 0..prev_len {
                let prev_cost = if l == 0 { 0 } else { dp[l - 1][prev_idx].0 };
                if prev_cost >= best.0 {
                    continue;
                }
                let prev_digits = &prev_digit_cache[prev_idx];
                let choose = |t: TensorId| -> usize {
                    let p = pos_in_boundary[t];
                    if l > 0 && boundary_level[t] == l - 1 {
                        prev_digits[p]
                    } else {
                        cur_digits[p]
                    }
                };
                let mut cost = prev_cost;
                for comp in &level_components[l] {
                    let idx = comp.index_of(&choose);
                    cost = cost.saturating_add(comp.table[idx].0);
                    if cost >= best.0 {
                        break;
                    }
                }
                if cost < best.0 {
                    best = (cost, prev_idx);
                }
            }
            *slot = best;
        }
        dp.push(cur_dp);
    }

    let (final_cost, mut state) = dp[nlevels - 1]
        .iter()
        .enumerate()
        .map(|(i, &(c, p))| (c, i, p))
        .min()
        .map(|(c, i, _)| (c, i))
        .unwrap();
    assert!(final_cost < INFEASIBLE, "no feasible one-cut tiling exists");

    let mut boundary_assign: Vec<Vec<Tile>> = vec![Vec::new(); spaces.len()];
    for l in (0..nlevels).rev() {
        let prev_state = dp[l][state].1;
        if l >= 1 {
            boundary_assign[l - 1] = spaces[l - 1].decode(prev_state);
        }
        if l + 1 < nlevels && l < spaces.len() {
            boundary_assign[l] = spaces[l].decode(state);
        }
        state = prev_state;
    }

    let mut tiles = vec![Tile::Rep; nt];
    for (l, sp) in spaces.iter().enumerate() {
        for (i, &t) in sp.ids.iter().enumerate() {
            tiles[t] = boundary_assign[l][i];
        }
    }
    let choose_final = |t: TensorId| -> usize {
        let l = boundary_level[t];
        let tile = boundary_assign[l][pos_in_boundary[t]];
        all_cands[t].iter().position(|&c| c == tile).unwrap()
    };
    for comps in &level_components {
        for comp in comps {
            let idx = comp.index_of(&choose_final);
            let (_, best_internal) = comp.table[idx];
            if !comp.internal.ids.is_empty() {
                let itiles = comp.internal.decode(best_internal);
                for (i, &t) in comp.internal.ids.iter().enumerate() {
                    tiles[t] = itiles[i];
                }
            }
        }
    }

    for t in 0..nt {
        tiles[t] = tiles[alias[t]];
    }

    let repriced = price(g, &tiles);
    debug_assert_eq!(repriced, final_cost, "reference DP cost mismatch on reconstruction");

    OneCutPlan { tiles, cost: final_cost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};
    use crate::planner::try_one_cut;

    fn mlp_train(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        for l in 0..dims.len() - 1 {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn reference_and_lut_agree_bit_for_bit() {
        for (batch, dims) in [
            (64usize, vec![32usize, 48, 16]),
            (512, vec![256, 256, 256]),
            (8, vec![1024, 1024]),
            (400, vec![300; 6]),
        ] {
            let g = mlp_train(batch, &dims);
            let a = one_cut_reference(&g);
            let b = try_one_cut(&g).unwrap();
            assert_eq!(a.cost, b.cost, "cost diverged for {batch} {dims:?}");
            assert_eq!(a.tiles, b.tiles, "tiles diverged for {batch} {dims:?}");
        }
    }
}
