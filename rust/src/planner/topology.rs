//! Topology-aware planning (ISSUE-4): feed hierarchical bandwidths back
//! into the tiling DP.
//!
//! Theorem 1 counts communication in raw bytes, which is the right
//! objective only when every cut crosses an identical link. PR 3's event
//! engine already models hierarchical interconnects
//! ([`crate::sim::Topology`]: named tiers with bandwidth, latency and a
//! contention cap), but the byte planner never saw them. This module
//! closes the loop in two moves:
//!
//! 1. **Weighted DP** ([`TopologyModel`] → [`super::try_k_cut_weighted`]):
//!    cut `j`'s Eq. (2) tables are re-priced from bytes to modeled
//!    picoseconds on tier `j` ([`CostTables::weighted`]) before the
//!    odometer DP runs, so the argmin trades conversion bytes against
//!    per-transfer latency at the tier's contention-capped effective
//!    bandwidth. Within one cut a pure bandwidth scale never changes the
//!    argmin (it is strictly monotone in bytes); *latency* does — the
//!    weighted plan drops marginal conversions whose startup cost exceeds
//!    their byte savings, exactly the transfers that serialize on a
//!    shared-bus tier (§6.2).
//! 2. **Simulator-scored portfolio** ([`try_plan_topology_aware`]): the
//!    weighted plan competes with the byte plan and the two pure
//!    baselines; every candidate is lowered to SPMD programs
//!    ([`crate::lower`]) and scheduled by the discrete-event engine
//!    ([`crate::sim::try_run_program`]) on the *actual* topology, and the
//!    fastest modeled step wins — FlexFlow's argument that a simulated
//!    task graph, not an analytic total, is what makes strategy search
//!    trustworthy. The byte plan is always in the pool and wins ties, so
//!    the topology-aware step is **never worse than the flat plan's** by
//!    construction.
//!
//! On a *flat* topology (all tiers identical) the byte objective already
//! orders plans exactly like modeled time, up to the latency term the flat
//! preset cannot use to discriminate tiers — so [`try_plan_topology_aware`]
//! short-circuits to the byte-LUT path and returns **bit-identical** plans
//! (asserted against [`super::reference`] in the property tests).
//!
//! Why the greedy byte plan is already strong on slow-outer hierarchies:
//! the k-cut recursion minimizes the outermost δ first, and on an
//! ethernet-above-PCIe machine the outer tier dominates step time — so the
//! headroom topology awareness actually buys is in latency/transfer-count
//! trades and in the inner, contention-limited tiers. The
//! `benches/topology_micro.rs` gate pins a real instance: on the two-tier
//! 2×4 preset the weighted plan pays ~0.5 MB more bytes at the innermost
//! cut to eliminate four collectives from the critical chain and lands a
//! strictly faster engine-simulated step on the 4-layer transformer.
//!
//! [`CostTables::weighted`]: crate::tiling::CostTables::weighted

use crate::graph::Graph;
use crate::lower::try_lower;
use crate::sim::{try_run_program, Topology};
use crate::tiling::CutCostModel;

use super::baselines;
use super::kcut::{eval_plan, try_k_cut, try_k_cut_weighted, Plan};
use super::onecut::PlanError;

/// The planner-side projection of a [`Topology`]: one [`CutCostModel`]
/// per cut, each pricing that cut's conversions on the tier it will cross.
///
/// Tier assignment goes through the same [`crate::sim::extend_tier_index`]
/// rule as [`Topology::link`] and [`Topology::from_sim`], so
/// planner-predicted seconds and engine-simulated seconds can never price
/// one transfer against two different links (pinned by the hand-computed
/// 2×2 test in [`crate::sim`]).
#[derive(Debug, Clone)]
pub struct TopologyModel {
    cuts: Vec<CutCostModel>,
    flat: bool,
}

impl TopologyModel {
    /// Project `topo` onto `k` cuts. Cut `j`'s per-pair-byte weight is
    /// `2^j / (bandwidth_j · min(slots_j, 2^j))` seconds — all `2^j`
    /// simultaneous group pairs share the tier's contention-capped
    /// aggregate, the same rule [`Topology::transfer_seconds`] applies.
    pub fn new(topo: &Topology, k: usize) -> Self {
        let cuts = (0..k)
            .map(|j| {
                let link = topo.link(j);
                let pairs = (1u64 << j) as f64;
                let agg = link.bandwidth * link.slots.min(pairs);
                CutCostModel::from_seconds(pairs / agg, link.latency)
            })
            .collect();
        TopologyModel { cuts, flat: topo.is_flat() }
    }

    /// Number of cuts this model prices.
    pub fn k(&self) -> usize {
        self.cuts.len()
    }

    /// The weight model for cut `j` (outermost first).
    pub fn cut(&self, j: usize) -> &CutCostModel {
        &self.cuts[j]
    }

    /// Whether the source topology was flat (every tier identical) — the
    /// case where [`try_plan_topology_aware`] stays on the byte-LUT path.
    pub fn is_flat(&self) -> bool {
        self.flat
    }
}

/// One scored candidate from [`try_plan_topology_aware`]'s portfolio.
#[derive(Debug, Clone)]
pub struct CandidateScore {
    /// Candidate generator: `"flat-bytes"`, `"weighted-dp"`,
    /// `"data-parallel"` or `"model-parallel"`.
    pub name: &'static str,
    /// Engine-simulated step seconds on the target topology.
    pub step_s: f64,
    /// The candidate's Theorem-1 byte total.
    pub total_bytes: u64,
}

/// Result of [`try_plan_topology_aware`]: the winning plan plus the full
/// scoreboard, so callers (the inspector, the topology bench) can report
/// *why* the plan won.
#[derive(Debug, Clone)]
pub struct TopologyPlan {
    /// The winning plan (the byte plan when nothing modeled faster).
    pub plan: Plan,
    /// Which candidate won ([`CandidateScore::name`]).
    pub chosen: &'static str,
    /// The winner's engine-simulated step seconds.
    pub step_s: f64,
    /// The byte plan's engine-simulated step seconds — by construction
    /// `step_s <= flat_step_s`.
    pub flat_step_s: f64,
    /// Every candidate that was generated, lowered and scheduled.
    pub scores: Vec<CandidateScore>,
}

/// Model one plan's step time on `topo`: lower to SPMD programs and
/// schedule them with the discrete-event engine. This is the scoring
/// function [`try_plan_topology_aware`] ranks candidates with — and the same
/// pipeline `benches/topology_micro.rs` asserts against, so the bench's
/// `topology-aware <= flat` inequality is structural, not statistical.
pub fn modeled_step_s(g: &Graph, plan: &Plan, topo: &Topology) -> Result<f64, PlanError> {
    let cfg = topo.to_sim_config();
    let program = try_lower(g, plan, &cfg)?;
    Ok(try_run_program(&program, topo)?.step_s)
}

/// Topology-aware planning with the full scoreboard and structured errors.
///
/// `devices` must be a power of two (`2^k` devices ⇒ a `k`-cut plan). On a
/// flat topology this returns the byte-LUT plan unchanged; otherwise the
/// candidate portfolio (byte plan, weighted-DP plan, pure baselines) is
/// scored by [`modeled_step_s`] and the strictly fastest wins, ties going
/// to the byte plan.
pub fn try_plan_topology_aware(
    g: &Graph,
    devices: usize,
    topo: &Topology,
) -> Result<TopologyPlan, PlanError> {
    assert!(devices.is_power_of_two(), "device count must be a power of two, got {devices}");
    let k = devices.trailing_zeros() as usize;

    let flat_plan = try_k_cut(g, k)?;
    let flat_step = modeled_step_s(g, &flat_plan, topo)?;
    let mut result = TopologyPlan {
        scores: vec![CandidateScore {
            name: "flat-bytes",
            step_s: flat_step,
            total_bytes: flat_plan.total_cost(),
        }],
        plan: flat_plan,
        chosen: "flat-bytes",
        step_s: flat_step,
        flat_step_s: flat_step,
    };
    // Flat topology (or a single device): the byte objective is already
    // the time objective — stay on the default path, bit-identically.
    if k == 0 || topo.is_flat() {
        return Ok(result);
    }

    let model = TopologyModel::new(topo, k);
    let candidates: Vec<(&'static str, Result<Plan, PlanError>)> = vec![
        ("weighted-dp", try_k_cut_weighted(g, k, &model)),
        ("data-parallel", Ok(eval_plan(g, &baselines::data_parallel_tiles(g, k)))),
        ("model-parallel", Ok(eval_plan(g, &baselines::model_parallel_tiles(g, k)))),
    ];
    let mut seen: Vec<Vec<crate::tiling::TileSeq>> = vec![result.plan.tiles.clone()];
    for (name, plan) in candidates {
        let Ok(plan) = plan else { continue };
        if seen.contains(&plan.tiles) {
            continue;
        }
        seen.push(plan.tiles.clone());
        let Ok(step) = modeled_step_s(g, &plan, topo) else { continue };
        result.scores.push(CandidateScore { name, step_s: step, total_bytes: plan.total_cost() });
        if step < result.step_s {
            result.plan = plan;
            result.chosen = name;
            result.step_s = step;
        }
    }
    Ok(result)
}

/// Topology-aware planning front door: the plan whose engine-modeled step
/// time on `topo` is fastest among the candidate portfolio (never slower
/// than the byte plan; bit-identical to it on flat topologies).
///
/// Panics on planner failure.
#[deprecated(note = "use `try_plan_topology_aware` (or `Session::build`) and handle the `PlanError`")]
pub fn plan_topology_aware(g: &Graph, devices: usize, topo: &Topology) -> Plan {
    try_plan_topology_aware(g, devices, topo)
        .expect("topology-aware planning failed")
        .plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, Graph, GraphBuilder};
    use crate::planner::{reference::one_cut_reference, try_k_cut};
    use crate::sim::TierLink;
    use crate::util::Rng;

    fn random_mlp(rng: &mut Rng) -> Graph {
        let even = |rng: &mut Rng| 2 * (rng.below(15) + 2);
        let batch = even(rng);
        let layers = 1 + rng.below(3);
        let dims: Vec<usize> = (0..=layers).map(|_| even(rng)).collect();
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, dims[layers]]);
        for l in 0..layers {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
            if l + 1 < layers {
                h = b.relu(&format!("relu{l}"), h);
            }
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn model_weights_follow_the_contention_capped_aggregate() {
        let topo = Topology::two_tier(3);
        let m = TopologyModel::new(&topo, 3);
        assert_eq!(m.k(), 3);
        assert!(!m.is_flat());
        // Cut 0: 1 pair on 1.25 GB/s ethernet -> 800 ps/byte.
        assert_eq!(m.cut(0).ps_per_byte_fp, 800 * CutCostModel::FP_ONE);
        // Cuts 1 and 2 cross the one-slot 12.5 GB/s PCIe bus: 2/12.5e9
        // and 4/12.5e9 seconds per pair-byte (exactly 160 and 320
        // ps/byte) — deeper cuts pay contention.
        assert_eq!(m.cut(2).ps_per_byte_fp, 2 * m.cut(1).ps_per_byte_fp);
        // Latency is the tier's, on the fixed-point grid.
        assert_eq!(m.cut(0).latency_fp, 50_000_000 * CutCostModel::FP_ONE);
        assert_eq!(m.cut(1).latency_fp, 20_000_000 * CutCostModel::FP_ONE);
    }

    /// Satellite property test, flat half: on a flat topology the
    /// topology-aware planner returns the byte planner's plan bit for bit
    /// — same cut tiles, same Theorem-1 total, and the outermost cut
    /// agrees with the pre-LUT reference implementation.
    #[test]
    fn flat_topology_is_bit_identical_to_byte_planner() {
        let mut rng = Rng::new(0x70_70_10);
        for trial in 0..8 {
            let g = random_mlp(&mut rng);
            let k = 1 + rng.below(2);
            let topo = Topology::flat(k, 4.0e9, 15e-6, 2.0);
            let byte = try_k_cut(&g, k).unwrap();
            let aware = try_plan_topology_aware(&g, 1 << k, &topo).unwrap();
            assert_eq!(aware.plan.tiles, byte.tiles, "trial {trial}");
            assert_eq!(aware.plan.total_cost(), byte.total_cost(), "trial {trial}");
            assert_eq!(aware.chosen, "flat-bytes");
            // And the outermost cut matches the pre-LUT oracle.
            assert_eq!(aware.plan.cut_costs[0], one_cut_reference(&g).cost, "trial {trial}");
        }
    }

    /// Satellite property test, hierarchical half: on random two-tier
    /// topologies the topology-aware plan never models slower than the
    /// flat plan (the flat plan is in the portfolio and ties go to it).
    #[test]
    fn two_tier_modeled_time_never_worse_than_flat_plan() {
        let mut rng = Rng::new(0x70_70_2);
        for trial in 0..6 {
            let g = random_mlp(&mut rng);
            let k = 1 + rng.below(2);
            let inter = 0.5e9 * (1 + rng.below(4)) as f64;
            let intra = 8.0e9 * (1 + rng.below(3)) as f64;
            let topo = Topology {
                tiers: vec![
                    TierLink {
                        name: "inter".into(),
                        bandwidth: inter,
                        latency: 40e-6,
                        slots: 1.0,
                    },
                    TierLink {
                        name: "intra".into(),
                        bandwidth: intra,
                        latency: 10e-6,
                        slots: 1.0 + rng.below(3) as f64,
                    },
                ],
            };
            let aware = try_plan_topology_aware(&g, 1 << k, &topo).unwrap();
            let flat = try_k_cut(&g, k).unwrap();
            let flat_step = modeled_step_s(&g, &flat, &topo).unwrap();
            assert!(
                aware.step_s <= flat_step + 1e-12,
                "trial {trial}: aware {} > flat {}",
                aware.step_s,
                flat_step
            );
            assert_eq!(aware.flat_step_s, flat_step, "trial {trial}");
            // The scoreboard always leads with the byte plan.
            assert_eq!(aware.scores[0].name, "flat-bytes");
            assert!(aware.scores.iter().any(|s| s.step_s == aware.step_s));
        }
    }

    #[test]
    fn transformer_on_two_tier_plans_and_scores() {
        let g = crate::models::transformer(&crate::models::TransformerConfig::tiny());
        let topo = Topology::two_tier(2);
        let aware = try_plan_topology_aware(&g, 4, &topo).unwrap();
        assert!(aware.step_s <= aware.flat_step_s);
        assert!(aware.step_s > 0.0);
        // Lowered bytes of the winner equal its Theorem-1 total — the
        // one-theory contract survives candidate selection.
        let cfg = topo.to_sim_config();
        let p = crate::lower::try_lower(&g, &aware.plan, &cfg).unwrap();
        assert_eq!(p.total_bytes(), aware.plan.total_cost());
    }

    #[test]
    fn single_device_short_circuits() {
        let g = random_mlp(&mut Rng::new(7));
        let aware = try_plan_topology_aware(&g, 1, &Topology::two_tier(3)).unwrap();
        assert_eq!(aware.plan.k, 0);
        assert_eq!(aware.chosen, "flat-bytes");
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_devices_rejected() {
        let g = random_mlp(&mut Rng::new(9));
        let _ = try_plan_topology_aware(&g, 6, &Topology::two_tier(3));
    }
}
