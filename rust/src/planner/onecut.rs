//! The one-cut tiling algorithm (paper §4.2.2).
//!
//! Finds the tiling `T: tensors -> {Split(d), Rep}` minimizing the total
//! conversion cost (Eq. 3) across **two** devices or device groups.
//!
//! Implementation of the paper's DP (Eq. 4–5):
//!
//! 1. BFS-levelize the undirected op graph ([`crate::graph::bfs_levels`]);
//!    tensors then split into per-level *boundary* sets (the DP states τ_l)
//!    and *internal* sets.
//! 2. Within a level, ops are grouped into *components* connected through
//!    internal tensors; each component's minimal cost is tabulated once per
//!    assignment of the boundary tensors it touches, minimizing over its
//!    internal tensors. (Ops that share only boundary tensors decompose —
//!    this is what keeps `level_cost` cheap.)
//! 3. A forward sweep over levels combines component tables into the
//!    `g_l(τ_l)` recurrence; backtracking recovers the argmin tiling.
//!
//! The search is exhaustive over the candidate tiling space, so the result
//! is optimal for the cost model — the property tests in
//! [`super::bruteforce`] check this against full enumeration and against
//! the pre-LUT reference implementation in [`super::reference`].
//!
//! # Hot-path engineering (DESIGN.md §Perf)
//!
//! The inner loops never call [`crate::tiling::op_cost`]: every op's
//! Eq. (2) surface is precomputed once per graph into a dense
//! [`CostTables`] LUT, so a state visit is one mixed-radix index plus one
//! table load per op. States are enumerated with odometer digit counters
//! (no `decode` allocations), tensor→slot positions are precomputed
//! (no linear `position()` scans), and both the per-component tabulation
//! and the per-state DP sweep fan out across cores via
//! [`crate::util::par::par_map_with`] — each state's result is computed
//! independently, so threading never changes the returned plan.
//!
//! Topology-dependent structure (levelization, alias map, components) is
//! computed once by [`OneCutSolver::new`] and reused across
//! [`OneCutSolver::solve`] calls; the k-cut recursion exploits this by
//! re-solving the same solver on successively halved graphs.

use std::fmt;

use crate::graph::{bfs_levels, Graph, Levels, OpId, TensorId};
use crate::tiling::aligned::INFEASIBLE;
use crate::tiling::{CostTables, CutCostModel, Tile};
use crate::util::par::par_map_with;
use crate::util::radix::{decode_digits, mults_of, odometer_inc};

/// Result of the one-cut DP: a basic tiling per tensor and the total
/// conversion cost (bytes moved across the cut for one training step).
#[derive(Debug, Clone)]
pub struct OneCutPlan {
    /// Indexed by `TensorId`; tensors not touched by any op get `Rep`.
    pub tiles: Vec<Tile>,
    /// Total Eq. (3) conversion bytes of the chosen tiling.
    pub cost: u64,
}

/// Structured planner failure — returned instead of panicking so callers
/// embedding the planner (services, long sweeps) can degrade gracefully.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// One intra-level component's exhaustive tabulation would exceed the
    /// solver's state limit (boundary states × internal states).
    ComponentTooLarge { level: usize, ops: usize, states: u128, limit: u128 },
    /// A level's DP state space cannot be indexed (astronomically wide
    /// boundary — no practical graph reaches this).
    BoundaryTooLarge { level: usize, states: u128 },
    /// No feasible one-cut tiling exists (e.g. every dimension odd).
    Infeasible,
    /// A (typically hand-written) plan admits no feasible aligned form for
    /// an operator at some cut — reported by the execution-graph builder
    /// ([`crate::exec::try_build_shard_tasks`]) instead of panicking.
    NoFeasibleForm {
        /// Name of the op with no realizable aligned form.
        op: String,
        /// Cut index (outermost first) at which selection failed.
        cut: usize,
    },
    /// A hand-written [`Plan`](super::Plan) is structurally invalid: wrong
    /// tensor count, ragged tile sequences, or a split of a missing
    /// dimension. Reported by [`super::validate_plan`] before any consumer
    /// (shard schedule, lowering, simulators, the SPMD executor) walks it.
    MalformedPlan {
        /// What is wrong with the plan.
        reason: String,
    },
    /// A plan assigns `Split(d)` to a tensor whose dimension `d` is odd
    /// (or too small) at that cut's halved granularity — the recursive
    /// bisection cannot realize it on real shards.
    UnsplittableTensor {
        /// Name of the tensor with the unrealizable split.
        tensor: String,
        /// Cut index (outermost first) where the split fails.
        cut: usize,
    },
    /// A hand-written [`LoweredProgram`](crate::lower::LoweredProgram)
    /// breaks the SPMD stream discipline: a transfer id out of range, a
    /// `Wait` before its start, or a collective started twice. Reported by
    /// [`crate::sim::try_run_program`] and the SPMD executor instead of
    /// panicking mid-schedule.
    MalformedProgram {
        /// Device whose stream is malformed.
        device: usize,
        /// Instruction index within that stream.
        pc: usize,
        /// What the discipline violation is.
        reason: String,
    },
    /// A [`Topology`](crate::sim::Topology) with no tiers — there is no
    /// link to price any transfer against.
    EmptyTopology,
    /// A runtime/coordinator configuration that cannot be acted on (zero
    /// classes, zero dimensions, …) — reported by the constructors that
    /// used to panic deep inside the RNG or shape arithmetic.
    MalformedConfig {
        /// What is wrong with the configuration.
        reason: String,
    },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::ComponentTooLarge { level, ops, states, limit } => write!(
                f,
                "level {level} component ({ops} ops) needs {states} states; limit {limit}"
            ),
            PlanError::BoundaryTooLarge { level, states } => {
                write!(f, "level {level} boundary space has {states} states")
            }
            PlanError::Infeasible => write!(f, "no feasible one-cut tiling exists"),
            PlanError::NoFeasibleForm { op, cut } => {
                write!(f, "no feasible aligned form for op {op} at cut {cut}")
            }
            PlanError::MalformedPlan { reason } => write!(f, "malformed plan: {reason}"),
            PlanError::UnsplittableTensor { tensor, cut } => {
                write!(f, "tensor {tensor} cannot be split at cut {cut} (odd or missing dim)")
            }
            PlanError::MalformedProgram { device, pc, reason } => {
                write!(f, "malformed SPMD program on device {device} at [{pc}]: {reason}")
            }
            PlanError::EmptyTopology => write!(f, "topology has no tiers"),
            PlanError::MalformedConfig { reason } => {
                write!(f, "malformed configuration: {reason}")
            }
        }
    }
}

impl std::error::Error for PlanError {}

/// Default cap on per-component tabulation states (same magnitude the
/// pre-LUT implementation asserted on).
const DEFAULT_STATE_LIMIT: u128 = 50_000_000;

/// Minimum (states × ops) work before a sweep is worth fork-join threads.
const PAR_MIN_WORK: usize = 1 << 15;

/// One intra-level component: ops connected through internal tensors.
/// Tensor ids are steady-state alias representatives; `bids`/`iids` are
/// sorted and deduplicated.
struct CompStruct {
    ops: Vec<OpId>,
    /// Boundary tensors this component reads (subset of prev ∪ cur).
    bids: Vec<TensorId>,
    /// Tensors internal to this level, minimized over during tabulation.
    iids: Vec<TensorId>,
}

/// A tabulated component: minimal cost and argmin internal assignment per
/// mixed-radix boundary assignment.
struct CompTab {
    costs: Vec<u64>,
    args: Vec<u32>,
    /// Mixed-radix multiplier per boundary tensor (same order as `bids`).
    bmults: Vec<usize>,
}

/// Per-op lookup descriptor inside one component: how much each boundary /
/// internal digit contributes to the op's LUT index.
struct OpTerms {
    op: OpId,
    /// `(position in bids, LUT multiplier)` — summed over occurrences.
    bw: Vec<(usize, usize)>,
    /// `(position in iids, LUT multiplier)`.
    iw: Vec<(usize, usize)>,
}

/// Union-find for component construction.
fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]];
        x = parent[x];
    }
    x
}

/// Reusable one-cut solver: the topology-dependent analysis (BFS levels,
/// steady-state aliases, boundary membership, intra-level components) is
/// computed once here; [`Self::solve`] then prices any graph with the same
/// topology — in particular the shape-halved subproblems of the k-cut
/// recursion, which would otherwise re-derive all of it from zero at every
/// level.
pub struct OneCutSolver {
    ntensors: usize,
    nops: usize,
    alias: Vec<TensorId>,
    lv: Levels,
    /// tensor -> l if in boundary[l], else `usize::MAX`.
    boundary_level: Vec<usize>,
    /// Position of a tensor within its boundary list.
    pos_in_boundary: Vec<usize>,
    /// Per level: components of ops connected through internal tensors.
    components: Vec<Vec<CompStruct>>,
    state_limit: u128,
}

impl OneCutSolver {
    /// Analyze `g`'s topology (levels, aliases, components) once.
    pub fn new(g: &Graph) -> Self {
        let nt = g.tensors.len();
        let alias = g.steady_state_aliases();
        let lv = bfs_levels(g);
        let nlevels = lv.levels.len();

        // Membership maps for quick classification.
        let mut boundary_level = vec![usize::MAX; nt];
        let mut pos_in_boundary = vec![usize::MAX; nt];
        for (l, b) in lv.boundary.iter().enumerate() {
            for (i, &t) in b.iter().enumerate() {
                boundary_level[t] = l;
                pos_in_boundary[t] = i;
            }
        }
        let mut internal_level = vec![usize::MAX; nt];
        for (l, ts) in lv.internal.iter().enumerate() {
            for &t in ts {
                internal_level[t] = l;
            }
        }

        // Per level: union ops sharing an internal tensor, then collect
        // each group's boundary/internal tensor lists.
        let mut components: Vec<Vec<CompStruct>> = Vec::with_capacity(nlevels);
        for (l, ops) in lv.levels.iter().enumerate() {
            let mut parent: Vec<usize> = (0..ops.len()).collect();
            let mut internal_owner: Vec<(TensorId, usize)> = Vec::new();
            for (oi, &op) in ops.iter().enumerate() {
                let o = &g.ops[op];
                for &t in o.inputs.iter().chain(o.outputs.iter()) {
                    let t = alias[t];
                    if internal_level[t] == l {
                        let owner = internal_owner
                            .iter()
                            .find(|&&(x, _)| x == t)
                            .map(|&(_, first)| first);
                        match owner {
                            None => internal_owner.push((t, oi)),
                            Some(prev) => {
                                let (a, b) = (find(&mut parent, prev), find(&mut parent, oi));
                                if a != b {
                                    parent[a] = b;
                                }
                            }
                        }
                    }
                }
            }
            // Group ops by root, roots in ascending order (deterministic
            // component order — ties in the DP resolve identically run to
            // run and match the reference implementation).
            let mut by_root: Vec<(usize, Vec<OpId>)> = Vec::new();
            for (oi, &op) in ops.iter().enumerate() {
                let root = find(&mut parent, oi);
                match by_root.iter().position(|(r, _)| *r == root) {
                    Some(i) => by_root[i].1.push(op),
                    None => by_root.push((root, vec![op])),
                }
            }
            by_root.sort_unstable_by_key(|(r, _)| *r);

            let mut comps = Vec::with_capacity(by_root.len());
            for (_, comp_ops) in by_root {
                let mut bids: Vec<TensorId> = Vec::new();
                let mut iids: Vec<TensorId> = Vec::new();
                for &op in &comp_ops {
                    let o = &g.ops[op];
                    for &t in o.inputs.iter().chain(o.outputs.iter()) {
                        let t = alias[t];
                        if internal_level[t] == l {
                            if !iids.contains(&t) {
                                iids.push(t);
                            }
                        } else if !bids.contains(&t) {
                            bids.push(t);
                        }
                    }
                }
                bids.sort_unstable();
                iids.sort_unstable();
                comps.push(CompStruct { ops: comp_ops, bids, iids });
            }
            components.push(comps);
        }

        OneCutSolver {
            ntensors: nt,
            nops: g.ops.len(),
            alias,
            lv,
            boundary_level,
            pos_in_boundary,
            components,
            state_limit: DEFAULT_STATE_LIMIT,
        }
    }

    /// Override the per-component tabulation state cap (tests use a tiny
    /// limit to exercise the [`PlanError::ComponentTooLarge`] path).
    pub fn with_state_limit(mut self, limit: u128) -> Self {
        self.state_limit = limit;
        self
    }

    /// Solve the one-cut problem for `g`, which must share this solver's
    /// topology (same tensors and ops; shapes may differ — that is the
    /// k-cut reuse case).
    pub fn solve(&self, g: &Graph) -> Result<OneCutPlan, PlanError> {
        self.solve_with(g, None)
    }

    /// Like [`Self::solve`], but the odometer DP minimizes *modeled time*
    /// instead of bytes: every Eq. (2) table entry is re-priced onto one
    /// interconnect tier through `w` ([`CostTables::weighted`]) before
    /// tabulation, so the argmin trades conversion bytes against
    /// per-transfer latency at that tier's effective bandwidth. The
    /// returned [`OneCutPlan::cost`] stays in **bytes** (re-priced through
    /// direct Eq. (2) evaluation) so Theorem-1 totals remain comparable
    /// across planners.
    pub fn solve_weighted(&self, g: &Graph, w: &CutCostModel) -> Result<OneCutPlan, PlanError> {
        self.solve_with(g, Some(w))
    }

    fn solve_with(&self, g: &Graph, w: Option<&CutCostModel>) -> Result<OneCutPlan, PlanError> {
        assert_eq!(g.tensors.len(), self.ntensors, "solver topology mismatch");
        assert_eq!(g.ops.len(), self.nops, "solver topology mismatch");
        if self.nops == 0 {
            return Ok(OneCutPlan { tiles: vec![Tile::Rep; self.ntensors], cost: 0 });
        }

        // Phase 1: every op's Eq. (2) surface, evaluated once — re-priced
        // from bytes to tier picoseconds when a weight model is given.
        let mut tables = CostTables::build_with(g, &self.alias);
        if let Some(w) = w {
            tables = tables.weighted(w);
        }
        let cands = &tables.cands;
        let nlevels = self.lv.levels.len();

        // Phase 2: tabulate each component over its boundary assignments.
        let mut tabs: Vec<Vec<CompTab>> = Vec::with_capacity(nlevels);
        for (l, comps) in self.components.iter().enumerate() {
            let mut level_tabs = Vec::with_capacity(comps.len());
            for comp in comps {
                level_tabs.push(self.tabulate(l, comp, &tables)?);
            }
            tabs.push(level_tabs);
        }

        // Phase 3: the g_l(τ_l) forward sweep. Boundary radices are shared
        // between adjacent levels and the backtracking pass.
        let bnd_radix: Vec<Vec<usize>> = self
            .lv
            .boundary
            .iter()
            .map(|ids| ids.iter().map(|&t| cands[t].len()).collect())
            .collect();
        // Boundary state counts in u128 (a usize product could wrap past
        // the guard below on an astronomically wide boundary).
        let bnd_len: Vec<u128> = bnd_radix.iter().map(|r| checked_product(r.iter())).collect();

        let empty_radix: Vec<usize> = Vec::new();
        let mut dp_cost: Vec<Vec<u64>> = Vec::with_capacity(nlevels);
        let mut dp_from: Vec<Vec<u32>> = Vec::with_capacity(nlevels);
        for l in 0..nlevels {
            let (prev_radix, prev_states) =
                if l == 0 { (&empty_radix, 1) } else { (&bnd_radix[l - 1], bnd_len[l - 1]) };
            let (cur_radix, cur_states) = if l + 1 < nlevels {
                (&bnd_radix[l], bnd_len[l])
            } else {
                (&empty_radix, 1)
            };
            // Guard both spaces before any state-sized allocation.
            for states in [prev_states, cur_states] {
                if states > u32::MAX as u128 {
                    return Err(PlanError::BoundaryTooLarge { level: l, states });
                }
            }
            let prev_len = prev_states as usize;
            let cur_len = cur_states as usize;

            // Split each component's table index into independent prev/cur
            // contributions, tabulated per state — the sweep's inner loop
            // is then one add + one load per component.
            let comps = &tabs[l];
            let ncomp = comps.len();
            let mut comp_prev: Vec<Vec<u32>> = Vec::with_capacity(ncomp);
            let mut comp_cur: Vec<Vec<u32>> = Vec::with_capacity(ncomp);
            for (comp, tab) in self.components[l].iter().zip(comps) {
                let mut wprev: Vec<(usize, usize)> = Vec::new();
                let mut wcur: Vec<(usize, usize)> = Vec::new();
                for (i, &t) in comp.bids.iter().enumerate() {
                    let pos = self.pos_in_boundary[t];
                    if l > 0 && self.boundary_level[t] == l - 1 {
                        wprev.push((pos, tab.bmults[i]));
                    } else {
                        wcur.push((pos, tab.bmults[i]));
                    }
                }
                comp_prev.push(space_contrib(prev_len, prev_radix, &wprev));
                comp_cur.push(space_contrib(cur_len, cur_radix, &wcur));
            }

            let prev_cost: &[u64] = if l == 0 { &[] } else { &dp_cost[l - 1] };
            let work = prev_len.saturating_mul(cur_len).saturating_mul(ncomp.max(1));
            let states: Vec<(u64, u32)> = par_map_with(
                cur_len,
                work >= PAR_MIN_WORK && cur_len >= 2,
                || (),
                |_, q| {
                    let mut best = (INFEASIBLE, 0u32);
                    for p in 0..prev_len {
                        let base = if l == 0 { 0 } else { prev_cost[p] };
                        if base >= best.0 {
                            continue;
                        }
                        let mut cost = base;
                        for c in 0..ncomp {
                            let idx = (comp_prev[c][p] + comp_cur[c][q]) as usize;
                            cost = cost.saturating_add(comps[c].costs[idx]);
                            if cost >= best.0 {
                                break;
                            }
                        }
                        if cost < best.0 {
                            best = (cost, p as u32);
                        }
                    }
                    best
                },
            );
            dp_cost.push(states.iter().map(|s| s.0).collect());
            dp_from.push(states.iter().map(|s| s.1).collect());
        }

        // Final answer: the last level has an empty "next" boundary.
        let mut final_cost = u64::MAX;
        let mut state = 0usize;
        for (i, &c) in dp_cost[nlevels - 1].iter().enumerate() {
            if c < final_cost {
                final_cost = c;
                state = i;
            }
        }
        if final_cost >= INFEASIBLE {
            // Under a weighted objective, a sum of clamped-but-finite
            // entries can saturate past the sentinel on astronomically
            // slow tiers (a cut modeling >~70 s) even though the graph is
            // feasible. Disambiguate by falling back to the byte
            // objective, whose sums stay far below the sentinel on any
            // realizable workload — the caller gets the byte-optimal plan
            // instead of a spurious `Infeasible`.
            if w.is_some() {
                return self.solve_with(g, None);
            }
            return Err(PlanError::Infeasible);
        }

        // Backtrack boundary assignments (as candidate-index digits).
        let mut bdigits: Vec<Vec<usize>> =
            bnd_radix.iter().map(|r| vec![0usize; r.len()]).collect();
        for l in (0..nlevels).rev() {
            let prev_state = dp_from[l][state] as usize;
            if l >= 1 {
                decode_digits(prev_state, &bnd_radix[l - 1], &mut bdigits[l - 1]);
            }
            if l + 1 < nlevels {
                decode_digits(state, &bnd_radix[l], &mut bdigits[l]);
            }
            state = prev_state;
        }

        // Assemble final tiles: boundaries from the DP traceback, internals
        // from the component argmins.
        let mut tiles = vec![Tile::Rep; self.ntensors];
        for (l, ids) in self.lv.boundary.iter().enumerate() {
            for (i, &t) in ids.iter().enumerate() {
                tiles[t] = cands[t][bdigits[l][i]];
            }
        }
        let mut idig: Vec<usize> = Vec::new();
        for (comps, level_tabs) in self.components.iter().zip(&tabs) {
            for (comp, tab) in comps.iter().zip(level_tabs) {
                let mut idx = 0usize;
                for (i, &t) in comp.bids.iter().enumerate() {
                    idx += bdigits[self.boundary_level[t]][self.pos_in_boundary[t]]
                        * tab.bmults[i];
                }
                let iradix: Vec<usize> = comp.iids.iter().map(|&t| cands[t].len()).collect();
                idig.clear();
                idig.resize(comp.iids.len(), 0);
                decode_digits(tab.args[idx] as usize, &iradix, &mut idig);
                for (i, &t) in comp.iids.iter().enumerate() {
                    tiles[t] = cands[t][idig[i]];
                }
            }
        }

        // Resolve aliases: updated weights inherit their weight's tiling.
        for t in 0..self.ntensors {
            tiles[t] = tiles[self.alias[t]];
        }

        // Sanity: re-price the assembled tiling through the tables the DP
        // ran on; must equal the DP cost (for the byte path this is also
        // checked against direct Eq. (2) evaluation).
        debug_assert_eq!(tables.price(&tiles), final_cost, "DP cost mismatch on reconstruction");

        // Weighted solves report the chosen tiling's cost in *bytes* so
        // Theorem-1 stays the common currency across planners.
        let cost = match w {
            None => {
                debug_assert_eq!(price(g, &tiles), final_cost, "LUT diverged from Eq. (2)");
                final_cost
            }
            Some(_) => price(g, &tiles),
        };
        Ok(OneCutPlan { tiles, cost })
    }

    /// Tabulate one component: for every boundary assignment, minimize the
    /// LUT-summed cost over internal assignments.
    fn tabulate(&self, l: usize, comp: &CompStruct, tables: &CostTables) -> Result<CompTab, PlanError> {
        let cands = &tables.cands;
        let bradix: Vec<usize> = comp.bids.iter().map(|&t| cands[t].len()).collect();
        let iradix: Vec<usize> = comp.iids.iter().map(|&t| cands[t].len()).collect();
        // Size the state space in u128 *before* building multipliers or
        // allocating: usize products would wrap first on absurd
        // components, defeating the very guard they feed.
        let states = checked_product(bradix.iter().chain(&iradix));
        // Clamp to u32::MAX regardless of the caller's limit: table
        // indices and argmins are stored as u32, so anything larger would
        // truncate into silently wrong plans rather than slow ones.
        let limit = self.state_limit.min(u32::MAX as u128);
        if states > limit {
            return Err(PlanError::ComponentTooLarge {
                level: l,
                ops: comp.ops.len(),
                states,
                limit,
            });
        }
        let (bmults, table_len) = mults_of(&bradix);
        let internal_len: usize = iradix.iter().product();

        // Map each op's LUT operands onto boundary/internal digit slots.
        let terms: Vec<OpTerms> = comp
            .ops
            .iter()
            .map(|&op| {
                let ot = &tables.ops[op];
                let mut bw: Vec<(usize, usize)> = Vec::new();
                let mut iw: Vec<(usize, usize)> = Vec::new();
                for (i, &t) in ot.operands.iter().enumerate() {
                    let m = ot.mults[i];
                    if let Some(p) = comp.bids.iter().position(|&x| x == t) {
                        bw.push((p, m));
                    } else {
                        let p = comp
                            .iids
                            .iter()
                            .position(|&x| x == t)
                            .expect("operand outside component scope");
                        iw.push((p, m));
                    }
                }
                OpTerms { op, bw, iw }
            })
            .collect();

        struct Scratch {
            last: usize,
            bdig: Vec<usize>,
            idig: Vec<usize>,
            base: Vec<usize>,
        }
        let work = table_len.saturating_mul(internal_len).saturating_mul(comp.ops.len());
        let entries: Vec<(u64, u32)> = par_map_with(
            table_len,
            work >= PAR_MIN_WORK && table_len >= 2,
            || Scratch {
                last: usize::MAX,
                bdig: vec![0usize; bradix.len()],
                idig: vec![0usize; iradix.len()],
                base: vec![0usize; terms.len()],
            },
            |s, bidx| {
                // Advance the boundary odometer (or re-seed at a chunk
                // start).
                if s.last != usize::MAX && s.last + 1 == bidx {
                    odometer_inc(&mut s.bdig, &bradix);
                } else {
                    decode_digits(bidx, &bradix, &mut s.bdig);
                }
                s.last = bidx;
                for (k, t) in terms.iter().enumerate() {
                    let mut b = 0usize;
                    for &(p, m) in &t.bw {
                        b += s.bdig[p] * m;
                    }
                    s.base[k] = b;
                }
                for d in s.idig.iter_mut() {
                    *d = 0;
                }
                let mut best = (INFEASIBLE, 0u32);
                for iidx in 0..internal_len {
                    let mut cost = 0u64;
                    for (k, t) in terms.iter().enumerate() {
                        let mut idx = s.base[k];
                        for &(p, m) in &t.iw {
                            idx += s.idig[p] * m;
                        }
                        cost = cost.saturating_add(tables.ops[t.op].costs[idx]);
                        if cost >= best.0 {
                            break;
                        }
                    }
                    if cost < best.0 {
                        best = (cost, iidx as u32);
                    }
                    odometer_inc(&mut s.idig, &iradix);
                }
                best
            },
        );

        Ok(CompTab {
            costs: entries.iter().map(|e| e.0).collect(),
            args: entries.iter().map(|e| e.1).collect(),
            bmults,
        })
    }
}

/// Overflow-proof state count: `Π radix`, saturating at `u128::MAX` (the
/// guards that consume this only care that huge is huge).
fn checked_product<'a>(radix: impl Iterator<Item = &'a usize>) -> u128 {
    radix
        .try_fold(1u128, |acc, &r| acc.checked_mul(r as u128))
        .unwrap_or(u128::MAX)
}

/// Tabulate `Σ digits[pos]·mult` for every state of a mixed-radix space
/// (the per-state slice of a component's table index).
fn space_contrib(len: usize, radix: &[usize], w: &[(usize, usize)]) -> Vec<u32> {
    let mut out = vec![0u32; len];
    let mut dig = vec![0usize; radix.len()];
    for slot in out.iter_mut() {
        let mut s = 0usize;
        for &(p, m) in w {
            s += dig[p] * m;
        }
        *slot = s as u32;
        odometer_inc(&mut dig, radix);
    }
    out
}

/// One-shot one-cut: build a solver and solve. Panics on planner failure.
#[deprecated(note = "use `try_one_cut` and handle the `PlanError`")]
pub fn one_cut(g: &Graph) -> OneCutPlan {
    try_one_cut(g).expect("one-cut planning failed")
}

/// One-shot one-cut returning structured errors.
pub fn try_one_cut(g: &Graph) -> Result<OneCutPlan, PlanError> {
    OneCutSolver::new(g).solve(g)
}

/// Total conversion cost of a complete tiling assignment (Eq. 3), by
/// direct Eq. (2) evaluation — deliberately *not* LUT-backed, so it serves
/// as the independent oracle the tables are checked against.
pub fn price(g: &Graph, tiles: &[Tile]) -> u64 {
    let mut total = 0u64;
    let mut ins: Vec<Tile> = Vec::new();
    for op in &g.ops {
        ins.clear();
        ins.extend(op.inputs.iter().map(|&t| tiles[t]));
        total = total.saturating_add(crate::tiling::op_cost(g, op, &ins, tiles[op.outputs[0]]));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};
    use crate::planner::apply_cut;
    use crate::tiling::Tile;

    fn mlp_train(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        let nl = dims.len() - 1;
        for l in 0..nl {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
            if l + 1 < nl {
                h = b.relu(&format!("fc{l}.relu"), h);
            }
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn forward_chain_prefers_data_parallel_when_batch_large() {
        // Wide batch, small weights: DP (all-R activations, rep weights)
        // should be optimal and cost exactly the gradient aggregation.
        let g = mlp_train(4096, &[64, 64, 64]);
        let plan = try_one_cut(&g).unwrap();
        // Weight matrices replicated.
        for t in &g.tensors {
            if t.kind == crate::graph::TensorKind::Weight && t.rank() == 2 {
                assert_eq!(plan.tiles[t.id], Tile::Rep, "weight {} not replicated", t.name);
            }
        }
        // Cost strictly positive (gradients must cross) but far below
        // shipping activations.
        assert!(plan.cost > 0);
        assert!(plan.cost < g.activation_bytes());
    }

    #[test]
    fn forward_chain_prefers_model_parallel_when_weights_large() {
        // Tiny batch, huge weights: replicating weights (DP) would pay
        // 2|W| per layer; splitting them must win.
        let g = mlp_train(8, &[1024, 1024, 1024]);
        let plan = try_one_cut(&g).unwrap();
        let n_split_weights = g
            .tensors
            .iter()
            .filter(|t| {
                t.kind == crate::graph::TensorKind::Weight
                    && t.rank() == 2
                    && matches!(plan.tiles[t.id], Tile::Split(_))
            })
            .count();
        assert!(n_split_weights >= 2, "expected split weights, got {n_split_weights}");
    }

    #[test]
    fn price_matches_dp_cost() {
        let g = mlp_train(64, &[32, 48, 16]);
        let plan = try_one_cut(&g).unwrap();
        assert_eq!(price(&g, &plan.tiles), plan.cost);
    }

    #[test]
    fn beats_or_matches_fixed_baselines() {
        for (batch, dims) in [
            (512usize, vec![256usize, 256, 256]),
            (32, vec![512, 512]),
            (128, vec![64, 256, 64]),
        ] {
            let g = mlp_train(batch, &dims);
            let plan = try_one_cut(&g).unwrap();
            let dp = super::super::baselines::data_parallel_tiles(&g, 1);
            let mp = super::super::baselines::model_parallel_tiles(&g, 1);
            let dp_tiles: Vec<Tile> = dp.iter().map(|s| s[0]).collect();
            let mp_tiles: Vec<Tile> = mp.iter().map(|s| s[0]).collect();
            assert!(plan.cost <= price(&g, &dp_tiles), "worse than DP for {batch} {dims:?}");
            assert!(plan.cost <= price(&g, &mp_tiles), "worse than MP for {batch} {dims:?}");
        }
    }

    #[test]
    fn single_op_graph() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let w = b.weight("w", &[8, 8]);
        b.matmul("mm", x, w, false, false);
        let g = b.finish();
        let plan = try_one_cut(&g).unwrap();
        // One matmul alone always admits a zero-cost aligned tiling.
        assert_eq!(plan.cost, 0);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::default();
        let plan = try_one_cut(&g).unwrap();
        assert_eq!(plan.cost, 0);
    }

    #[test]
    fn component_size_guard_returns_structured_error() {
        let g = mlp_train(16, &[8, 8, 8]);
        let err = OneCutSolver::new(&g).with_state_limit(1).solve(&g).unwrap_err();
        match err {
            PlanError::ComponentTooLarge { states, limit, .. } => {
                assert!(states > limit);
                assert_eq!(limit, 1);
            }
            other => panic!("expected ComponentTooLarge, got {other:?}"),
        }
        // The error formats without panicking.
        assert!(format!("{err}").contains("states"));
    }

    #[test]
    fn solver_reuse_matches_fresh_solves_on_halved_graphs() {
        // The k-cut reuse contract: one solver built from the full graph
        // prices the shape-halved subproblem identically to a fresh solver.
        let g = mlp_train(128, &[64, 32, 16]);
        let solver = OneCutSolver::new(&g);
        let first = solver.solve(&g).unwrap();
        assert_eq!(first.cost, try_one_cut(&g).unwrap().cost);
        let halved = apply_cut(&g, &first.tiles);
        let reused = solver.solve(&halved).unwrap();
        let fresh = try_one_cut(&halved).unwrap();
        assert_eq!(reused.cost, fresh.cost);
        assert_eq!(reused.tiles, fresh.tiles);
    }

    #[test]
    fn weighted_solve_with_byte_model_is_bit_identical() {
        // CutCostModel::bytes() maps every LUT entry to itself, so the
        // weighted path must reproduce the byte path exactly — tiles and
        // cost.
        use crate::tiling::CutCostModel;
        for (batch, dims) in [(512usize, vec![256usize, 256, 256]), (8, vec![1024, 1024])] {
            let g = mlp_train(batch, &dims);
            let solver = OneCutSolver::new(&g);
            let byte = solver.solve(&g).unwrap();
            let weighted = solver.solve_weighted(&g, &CutCostModel::bytes()).unwrap();
            assert_eq!(byte.tiles, weighted.tiles);
            assert_eq!(byte.cost, weighted.cost);
        }
    }

    #[test]
    fn uniform_weight_without_latency_preserves_the_argmin() {
        // A pure positive per-byte scale is strictly monotone: same
        // enumeration order, same strict-min tie-breaking, same plan.
        use crate::tiling::CutCostModel;
        let g = mlp_train(128, &[64, 96, 32]);
        let solver = OneCutSolver::new(&g);
        let byte = solver.solve(&g).unwrap();
        let w = CutCostModel { ps_per_byte_fp: 12_345, latency_fp: 0 };
        let weighted = solver.solve_weighted(&g, &w).unwrap();
        assert_eq!(byte.tiles, weighted.tiles);
        assert_eq!(byte.cost, weighted.cost, "cost is re-priced in bytes");
    }

    #[test]
    fn weighted_solve_is_optimal_for_its_own_objective() {
        // The DP is exact: under the weighted tables, no plan — in
        // particular not the byte-optimal one — models faster than the
        // weighted argmin.
        use crate::tiling::{CostTables, CutCostModel};
        let g = mlp_train(64, &[48, 48, 48]);
        let solver = OneCutSolver::new(&g);
        // A high-latency slow tier: 800 ps/byte, 50 us per transfer.
        let w = CutCostModel::from_seconds(8e-10, 50e-6);
        let weighted = solver.solve_weighted(&g, &w).unwrap();
        let byte = solver.solve(&g).unwrap();
        let wt = CostTables::build(&g).weighted(&w);
        assert!(wt.price(&weighted.tiles) <= wt.price(&byte.tiles));
        // And in bytes the ordering flips (or ties): the byte plan is the
        // byte optimum.
        assert!(byte.cost <= weighted.cost);
    }

    #[test]
    fn weighted_saturation_falls_back_to_bytes_not_infeasible() {
        // A tier so slow that weighted sums saturate past the sentinel
        // must not turn a feasible graph into PlanError::Infeasible — the
        // solver falls back to the byte objective instead.
        use crate::tiling::CutCostModel;
        let g = mlp_train(64, &[48, 48, 48]);
        let w = CutCostModel { ps_per_byte_fp: u64::MAX / 4, latency_fp: u64::MAX / 4 };
        let plan = OneCutSolver::new(&g).solve_weighted(&g, &w).unwrap();
        assert_eq!(price(&g, &plan.tiles), plan.cost);
        assert!(plan.cost < INFEASIBLE);
    }

    #[test]
    fn infeasible_graph_reports_error() {
        // Every dimension odd: no aligned form is realizable anywhere.
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[3, 5]);
        let w = b.weight("w", &[5, 7]);
        b.matmul("odd", x, w, false, false);
        let g = b.finish();
        assert_eq!(try_one_cut(&g).unwrap_err(), PlanError::Infeasible);
    }
}
