//! The one-cut tiling algorithm (paper §4.2.2).
//!
//! Finds the tiling `T: tensors -> {Split(d), Rep}` minimizing the total
//! conversion cost (Eq. 3) across **two** devices or device groups.
//!
//! Implementation of the paper's DP (Eq. 4–5):
//!
//! 1. BFS-levelize the undirected op graph ([`crate::graph::bfs_levels`]);
//!    tensors then split into per-level *boundary* sets (the DP states τ_l)
//!    and *internal* sets.
//! 2. Within a level, ops are grouped into *components* connected through
//!    internal tensors; each component's minimal cost is tabulated once per
//!    assignment of the boundary tensors it touches, minimizing over its
//!    internal tensors. (Ops that share only boundary tensors decompose —
//!    this is what keeps `level_cost` cheap.)
//! 3. A forward sweep over levels combines component tables into the
//!    `g_l(τ_l)` recurrence; backtracking recovers the argmin tiling.
//!
//! The search is exhaustive over the candidate tiling space, so the result
//! is optimal for the cost model — the property tests in
//! [`super::bruteforce`] check this against full enumeration.

use std::collections::HashMap;

use crate::graph::{bfs_levels, Graph, OpId, TensorId};
use crate::tiling::aligned::INFEASIBLE;
use crate::tiling::{candidate_tiles, op_cost, Tile};

/// Result of the one-cut DP: a basic tiling per tensor and the total
/// conversion cost (bytes moved across the cut for one training step).
#[derive(Debug, Clone)]
pub struct OneCutPlan {
    /// Indexed by `TensorId`; tensors not touched by any op get `Rep`.
    pub tiles: Vec<Tile>,
    pub cost: u64,
}

/// An enumerable assignment space over a fixed list of tensors.
#[derive(Debug, Clone, Default)]
struct Space {
    ids: Vec<TensorId>,
    cands: Vec<Vec<Tile>>,
}

impl Space {
    fn new(ids: Vec<TensorId>, all_cands: &[Vec<Tile>]) -> Self {
        let cands = ids.iter().map(|&t| all_cands[t].clone()).collect();
        Space { ids, cands }
    }

    fn len(&self) -> usize {
        self.cands.iter().map(Vec::len).product()
    }

    /// Decode a mixed-radix index into per-tensor tiles (same order as ids).
    fn decode(&self, mut idx: usize) -> Vec<Tile> {
        let mut out = Vec::with_capacity(self.cands.len());
        for c in &self.cands {
            out.push(c[idx % c.len()]);
            idx /= c.len();
        }
        out
    }
}

/// One intra-level component: ops connected through internal tensors, plus
/// the cost table over its touched boundary tensors.
struct Component {
    #[allow(dead_code)]
    ops: Vec<OpId>,
    /// Boundary tensors this component reads (subset of prev ∪ cur).
    boundary_ids: Vec<TensorId>,
    internal: Space,
    /// Indexed by the mixed-radix assignment of `boundary_ids` (using the
    /// global candidate lists); value = (min cost, best internal index).
    table: Vec<(u64, usize)>,
    /// Radix per boundary tensor (candidate count), same order as ids.
    boundary_radix: Vec<usize>,
}

impl Component {
    /// Index into `table` given a lookup map from tensor to chosen tile.
    fn index_of(&self, choose: &dyn Fn(TensorId) -> usize) -> usize {
        let mut idx = 0;
        let mut mult = 1;
        for (i, &t) in self.boundary_ids.iter().enumerate() {
            idx += choose(t) * mult;
            mult *= self.boundary_radix[i];
        }
        idx
    }
}

/// Union-find for component construction.
fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
    while parent[x] != x {
        parent[x] = parent[parent[x]];
        x = parent[x];
    }
    x
}

pub fn one_cut(g: &Graph) -> OneCutPlan {
    let nt = g.tensors.len();
    let all_cands: Vec<Vec<Tile>> = g.tensors.iter().map(candidate_tiles).collect();
    if g.ops.is_empty() {
        return OneCutPlan { tiles: vec![Tile::Rep; nt], cost: 0 };
    }
    // Steady-state constraint: updated parameters share their parameter's
    // tiling variable (see Graph::steady_state_aliases).
    let alias = g.steady_state_aliases();

    let lv = bfs_levels(g);
    let nlevels = lv.levels.len();

    // Membership maps for quick classification.
    let mut boundary_level = vec![usize::MAX; nt]; // tensor -> l if in boundary[l]
    for (l, b) in lv.boundary.iter().enumerate() {
        for &t in b {
            boundary_level[t] = l;
        }
    }
    let mut internal_level = vec![usize::MAX; nt];
    for (l, ts) in lv.internal.iter().enumerate() {
        for &t in ts {
            internal_level[t] = l;
        }
    }

    // Build per-level components and their tables.
    let mut level_components: Vec<Vec<Component>> = Vec::with_capacity(nlevels);
    for (l, ops) in lv.levels.iter().enumerate() {
        // Union ops sharing an internal tensor of this level.
        let mut parent: Vec<usize> = (0..ops.len()).collect();
        let mut internal_owner: HashMap<TensorId, usize> = HashMap::new();
        for (oi, &op) in ops.iter().enumerate() {
            let o = &g.ops[op];
            for &t in o.inputs.iter().chain(o.outputs.iter()) {
                let t = alias[t];
                if internal_level[t] == l {
                    match internal_owner.get(&t) {
                        None => {
                            internal_owner.insert(t, oi);
                        }
                        Some(&prev) => {
                            let (a, b) = (find(&mut parent, prev), find(&mut parent, oi));
                            if a != b {
                                parent[a] = b;
                            }
                        }
                    }
                }
            }
        }
        let mut groups: HashMap<usize, Vec<OpId>> = HashMap::new();
        for (oi, &op) in ops.iter().enumerate() {
            groups.entry(find(&mut parent, oi)).or_default().push(op);
        }

        let mut comps = Vec::new();
        let mut group_keys: Vec<usize> = groups.keys().copied().collect();
        group_keys.sort_unstable();
        for key in group_keys {
            let comp_ops = groups[&key].clone();
            let mut bids: Vec<TensorId> = Vec::new();
            let mut iids: Vec<TensorId> = Vec::new();
            for &op in &comp_ops {
                let o = &g.ops[op];
                for &t in o.inputs.iter().chain(o.outputs.iter()) {
                    let t = alias[t];
                    if internal_level[t] == l {
                        if !iids.contains(&t) {
                            iids.push(t);
                        }
                    } else if !bids.contains(&t) {
                        bids.push(t);
                    }
                }
            }
            bids.sort_unstable();
            iids.sort_unstable();
            let internal = Space::new(iids, &all_cands);
            let boundary_radix: Vec<usize> = bids.iter().map(|&t| all_cands[t].len()).collect();
            let table_len: usize = boundary_radix.iter().product::<usize>().max(1);
            assert!(
                table_len.saturating_mul(internal.len().max(1)) < 50_000_000,
                "level {l} component too large for exhaustive tabulation"
            );

            // Tabulate: for every boundary assignment, minimize over
            // internal assignments.
            let mut table = vec![(INFEASIBLE, 0usize); table_len];
            let bspace = Space::new(bids.clone(), &all_cands);
            for bidx in 0..table_len {
                let btiles = bspace.decode(bidx);
                let mut best = (INFEASIBLE, 0usize);
                for iidx in 0..internal.len().max(1) {
                    let itiles = if internal.ids.is_empty() {
                        Vec::new()
                    } else {
                        internal.decode(iidx)
                    };
                    let lookup = |t: TensorId| -> Tile {
                        let t = alias[t];
                        if let Some(p) = bids.iter().position(|&x| x == t) {
                            btiles[p]
                        } else if let Some(p) = internal.ids.iter().position(|&x| x == t) {
                            itiles[p]
                        } else {
                            unreachable!("tensor {t} not in component scope")
                        }
                    };
                    let mut cost = 0u64;
                    for &op in &comp_ops {
                        let o = &g.ops[op];
                        let ins: Vec<Tile> = o.inputs.iter().map(|&t| lookup(t)).collect();
                        let out = lookup(o.outputs[0]);
                        cost = cost.saturating_add(op_cost(g, o, &ins, out));
                        if cost >= best.0 {
                            break;
                        }
                    }
                    if cost < best.0 {
                        best = (cost, iidx);
                    }
                }
                table[bidx] = best;
            }
            comps.push(Component {
                ops: comp_ops,
                boundary_ids: bids,
                internal,
                table,
                boundary_radix,
            });
        }
        level_components.push(comps);
    }

    // DP over boundary assignments. boundary[l] exists for l in 0..nlevels-1.
    let spaces: Vec<Space> = (0..nlevels.saturating_sub(1))
        .map(|l| Space::new(lv.boundary[l].clone(), &all_cands))
        .collect();
    // Position of a tensor within its boundary space (for fast lookups).
    let mut pos_in_boundary = vec![usize::MAX; nt];
    for sp in &spaces {
        for (i, &t) in sp.ids.iter().enumerate() {
            pos_in_boundary[t] = i;
        }
    }

    // g[l][state over boundary[l]] = (cost, best prev state index)
    let empty = Space::default();
    let mut dp: Vec<Vec<(u64, usize)>> = Vec::with_capacity(nlevels);
    for l in 0..nlevels {
        let prev_space = if l == 0 { &empty } else { &spaces[l - 1] };
        let cur_space = if l + 1 < nlevels { &spaces[l] } else { &empty };
        let prev_len = prev_space.len().max(1);
        let cur_len = cur_space.len().max(1);

        // Decompose each component's table index into contributions from
        // prev/cur choices: choose(t) = index of t's tile in its candidate
        // list, read from whichever decoded assignment contains it.
        let mut cur_dp = vec![(INFEASIBLE, 0usize); cur_len];
        // Pre-decode candidate index vectors (not tiles) once per state:
        // the mixed-radix digits ARE the candidate indices.
        let digits = |space: &Space, mut idx: usize| -> Vec<usize> {
            space
                .cands
                .iter()
                .map(|c| {
                    let d = idx % c.len();
                    idx /= c.len();
                    d
                })
                .collect()
        };
        let prev_digit_cache: Vec<Vec<usize>> =
            (0..prev_len).map(|i| digits(prev_space, i)).collect();

        for cur_idx in 0..cur_len {
            let cur_digits = digits(cur_space, cur_idx);
            let mut best = (INFEASIBLE, 0usize);
            for prev_idx in 0..prev_len {
                let prev_cost = if l == 0 { 0 } else { dp[l - 1][prev_idx].0 };
                if prev_cost >= best.0 {
                    continue;
                }
                let prev_digits = &prev_digit_cache[prev_idx];
                let choose = |t: TensorId| -> usize {
                    let p = pos_in_boundary[t];
                    if boundary_level[t] + 1 == l + 0 {
                        // t in boundary[l-1] -> prev space
                        prev_digits[p]
                    } else {
                        cur_digits[p]
                    }
                };
                let mut cost = prev_cost;
                for comp in &level_components[l] {
                    let idx = comp.index_of(&choose);
                    cost = cost.saturating_add(comp.table[idx].0);
                    if cost >= best.0 {
                        break;
                    }
                }
                if cost < best.0 {
                    best = (cost, prev_idx);
                }
            }
            cur_dp[cur_idx] = best;
        }
        dp.push(cur_dp);
    }

    // Final answer: the last level has an empty "next" boundary.
    let (final_cost, mut state) = dp[nlevels - 1]
        .iter()
        .enumerate()
        .map(|(i, &(c, p))| (c, i, p))
        .min()
        .map(|(c, i, _)| (c, i))
        .unwrap();
    assert!(final_cost < INFEASIBLE, "no feasible one-cut tiling exists");

    // Backtrack boundary assignments.
    let mut boundary_assign: Vec<Vec<Tile>> = vec![Vec::new(); spaces.len()];
    for l in (0..nlevels).rev() {
        let prev_state = dp[l][state].1;
        if l >= 1 {
            boundary_assign[l - 1] = spaces[l - 1].decode(prev_state);
        }
        if l + 1 < nlevels && l < spaces.len() {
            boundary_assign[l] = spaces[l].decode(state);
        }
        state = prev_state;
    }

    // Assemble final tiles: boundaries from the DP traceback, internals
    // from the component argmins.
    let mut tiles = vec![Tile::Rep; nt];
    for (l, sp) in spaces.iter().enumerate() {
        for (i, &t) in sp.ids.iter().enumerate() {
            tiles[t] = boundary_assign[l][i];
        }
    }
    let choose_final = |t: TensorId| -> usize {
        let l = boundary_level[t];
        let tile = boundary_assign[l][pos_in_boundary[t]];
        all_cands[t].iter().position(|&c| c == tile).unwrap()
    };
    for comps in &level_components {
        for comp in comps {
            let idx = comp.index_of(&choose_final);
            let (_, best_internal) = comp.table[idx];
            if !comp.internal.ids.is_empty() {
                let itiles = comp.internal.decode(best_internal);
                for (i, &t) in comp.internal.ids.iter().enumerate() {
                    tiles[t] = itiles[i];
                }
            }
        }
    }

    // Resolve aliases: updated weights inherit their weight's tiling.
    for t in 0..nt {
        tiles[t] = tiles[alias[t]];
    }

    // Sanity: re-price the assembled tiling; must equal the DP cost.
    let repriced = price(g, &tiles);
    debug_assert_eq!(repriced, final_cost, "DP cost mismatch on reconstruction");

    OneCutPlan { tiles, cost: final_cost }
}

/// Total conversion cost of a complete tiling assignment (Eq. 3).
pub fn price(g: &Graph, tiles: &[Tile]) -> u64 {
    let mut total = 0u64;
    for op in &g.ops {
        let ins: Vec<Tile> = op.inputs.iter().map(|&t| tiles[t]).collect();
        total = total.saturating_add(op_cost(g, op, &ins, tiles[op.outputs[0]]));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};
    use crate::tiling::Tile;

    fn mlp_train(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        let nl = dims.len() - 1;
        for l in 0..nl {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
            if l + 1 < nl {
                h = b.relu(&format!("fc{l}.relu"), h);
            }
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn forward_chain_prefers_data_parallel_when_batch_large() {
        // Wide batch, small weights: DP (all-R activations, rep weights)
        // should be optimal and cost exactly the gradient aggregation.
        let g = mlp_train(4096, &[64, 64, 64]);
        let plan = one_cut(&g);
        // Weight matrices replicated.
        for t in &g.tensors {
            if t.kind == crate::graph::TensorKind::Weight && t.rank() == 2 {
                assert_eq!(plan.tiles[t.id], Tile::Rep, "weight {} not replicated", t.name);
            }
        }
        // Cost strictly positive (gradients must cross) but far below
        // shipping activations.
        assert!(plan.cost > 0);
        assert!(plan.cost < g.activation_bytes());
    }

    #[test]
    fn forward_chain_prefers_model_parallel_when_weights_large() {
        // Tiny batch, huge weights: replicating weights (DP) would pay
        // 2|W| per layer; splitting them must win.
        let g = mlp_train(8, &[1024, 1024, 1024]);
        let plan = one_cut(&g);
        let n_split_weights = g
            .tensors
            .iter()
            .filter(|t| {
                t.kind == crate::graph::TensorKind::Weight
                    && t.rank() == 2
                    && matches!(plan.tiles[t.id], Tile::Split(_))
            })
            .count();
        assert!(n_split_weights >= 2, "expected split weights, got {n_split_weights}");
    }

    #[test]
    fn price_matches_dp_cost() {
        let g = mlp_train(64, &[32, 48, 16]);
        let plan = one_cut(&g);
        assert_eq!(price(&g, &plan.tiles), plan.cost);
    }

    #[test]
    fn beats_or_matches_fixed_baselines() {
        for (batch, dims) in [
            (512usize, vec![256usize, 256, 256]),
            (32, vec![512, 512]),
            (128, vec![64, 256, 64]),
        ] {
            let g = mlp_train(batch, &dims);
            let plan = one_cut(&g);
            let dp = super::super::baselines::data_parallel_tiles(&g, 1);
            let mp = super::super::baselines::model_parallel_tiles(&g, 1);
            let dp_tiles: Vec<Tile> = dp.iter().map(|s| s[0]).collect();
            let mp_tiles: Vec<Tile> = mp.iter().map(|s| s[0]).collect();
            assert!(plan.cost <= price(&g, &dp_tiles), "worse than DP for {batch} {dims:?}");
            assert!(plan.cost <= price(&g, &mp_tiles), "worse than MP for {batch} {dims:?}");
        }
    }

    #[test]
    fn single_op_graph() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[8, 8]);
        let w = b.weight("w", &[8, 8]);
        b.matmul("mm", x, w, false, false);
        let g = b.finish();
        let plan = one_cut(&g);
        // One matmul alone always admits a zero-cost aligned tiling.
        assert_eq!(plan.cost, 0);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::default();
        let plan = one_cut(&g);
        assert_eq!(plan.cost, 0);
    }
}
