//! The k-cut tiling algorithm (paper §4.3, Algorithm 1).
//!
//! `2^k` devices are split into two groups; [`super::one_cut`] finds the
//! optimal tiling between them; shard shapes are halved along the chosen
//! split dimensions; and the procedure recurses within a group on the
//! reduced problem. Theorem 1 gives the total cost: the i-th cut's
//! conversion volume `δ_i` happens in `2^(i-1)` group pairs, each pair
//! spanning `2^(k-i)` devices whose traffic the outer `2^(k-i)` weight in
//! `c_k = Σ 2^(k-i)·δ_i` accounts for.

use crate::graph::{Graph, Op, OpKind, TensorKind};
use crate::tiling::{describe_seq, op_cost, op_cost_with_form, Form, Tile, TileSeq};

use super::onecut::{OneCutSolver, PlanError};

/// The form stock data parallelism always uses: gradient aggregation via
/// the reduction path (`C·R -> red` for weight-gradient matmuls/convs,
/// the batch-axis reduction for bias gradients), never the Eq. (2)
/// substitution of shipping activations. `None` = op is unconstrained.
pub fn classic_dp_form(g: &Graph, op: &Op) -> Option<Form> {
    let grad_out = g.tensors[op.outputs[0]].kind == TensorKind::WeightGrad;
    match op.kind {
        OpKind::MatMul { .. } | OpKind::Conv2dBwdFilter { .. } if grad_out => {
            Some(Form::MatMul(2))
        }
        OpKind::ReduceSumRows if grad_out => Some(Form::GridAxis(0)),
        _ => None,
    }
}

/// Like `price` but forcing specific forms for some ops.
pub fn price_forced(
    g: &Graph,
    tiles: &[Tile],
    forced: &dyn Fn(&Graph, &Op) -> Option<Form>,
) -> u64 {
    let mut total = 0u64;
    let mut ins: Vec<Tile> = Vec::new();
    for op in &g.ops {
        ins.clear();
        ins.extend(op.inputs.iter().map(|&t| tiles[t]));
        let out = tiles[op.outputs[0]];
        let c = match forced(g, op) {
            Some(f) => op_cost_with_form(g, op, &ins, out, f)
                .unwrap_or_else(|| op_cost(g, op, &ins, out)),
            None => op_cost(g, op, &ins, out),
        };
        total = total.saturating_add(c);
    }
    total
}

/// A complete k-cut tiling plan.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    /// Number of cuts (the plan spans `2^k` devices).
    pub k: usize,
    /// Per tensor (indexed by `TensorId`): the basic tiling chosen at each
    /// cut, outermost first.
    pub tiles: Vec<TileSeq>,
    /// δ_1 … δ_k: conversion bytes of each cut at that cut's granularity.
    pub cut_costs: Vec<u64>,
}

impl Plan {
    /// Device count this plan spans (`2^k`).
    pub fn devices(&self) -> usize {
        1 << self.k
    }

    /// Theorem 1: `c_k = Σ_{i=1..k} 2^(k−i) · δ_i`, where δ_k is the
    /// *outermost* cut (the paper indexes cuts innermost-first). In this
    /// struct `cut_costs[0]` is the outermost cut — performed once between
    /// the two top-level groups — and `cut_costs[j]` happens simultaneously
    /// in `2^j` group pairs, hence the `2^j` weight.
    pub fn total_cost(&self) -> u64 {
        self.cut_costs
            .iter()
            .enumerate()
            .map(|(i, &d)| (1u64 << i) * d)
            .sum()
    }

    /// The basic tiling of every tensor at cut `j` (outermost first) — the
    /// slice plan consumers (simulator metering, SPMD lowering) walk cut by
    /// cut over the `j`-times-halved graphs.
    pub fn cut_tiles(&self, j: usize) -> Vec<Tile> {
        self.tiles.iter().map(|s| s[j]).collect()
    }

    /// Table of tensor tilings in paper notation (`soybean plan` output).
    pub fn describe(&self, g: &Graph) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(s, "k={} ({} devices), total cost {} bytes", self.k, self.devices(), self.total_cost());
        for (i, d) in self.cut_costs.iter().enumerate() {
            let _ = writeln!(s, "  δ_{} = {d} bytes (weight 2^{})", i + 1, self.k - 1 - i);
        }
        for t in &g.tensors {
            let _ = writeln!(
                s,
                "  {:<24} {:<16} {}",
                t.name,
                format!("{:?}", t.shape),
                describe_seq(&self.tiles[t.id])
            );
        }
        s
    }
}

/// Structural validation of a (possibly hand-written) plan against its
/// graph: one `TileSeq` of exactly `k` tiles per tensor, and every
/// assigned split must hit an existing, even dimension at that cut's
/// halved granularity (otherwise recursive bisection cannot realize it on
/// shards). Every plan consumer — the shard-schedule builder (and through
/// it the lowering, both simulators and the SPMD executor) — calls this
/// before walking the plan, so malformed plans surface as structured
/// [`PlanError`]s instead of index/assert panics deep in the pipeline.
pub fn validate_plan(g: &Graph, plan: &Plan) -> Result<(), PlanError> {
    if plan.tiles.len() != g.tensors.len() {
        return Err(PlanError::MalformedPlan {
            reason: format!("plan covers {} tensors, graph has {}", plan.tiles.len(), g.tensors.len()),
        });
    }
    for (t, seq) in g.tensors.iter().zip(&plan.tiles) {
        if seq.len() != plan.k {
            return Err(PlanError::MalformedPlan {
                reason: format!("tensor {} has {} tiles for a k={} plan", t.name, seq.len(), plan.k),
            });
        }
        let mut shape = t.shape.clone();
        for (cut, tile) in seq.iter().enumerate() {
            if let Tile::Split(d) = tile {
                let ok = *d < shape.len() && shape[*d] >= 2 && shape[*d] % 2 == 0;
                if !ok {
                    return Err(PlanError::UnsplittableTensor { tensor: t.name.clone(), cut });
                }
                shape[*d] /= 2;
            }
        }
    }
    Ok(())
}

/// Halve every tensor's shape along its chosen split dimension, producing
/// the within-group subproblem for the next cut.
pub fn apply_cut(g: &Graph, tiles: &[Tile]) -> Graph {
    let mut sub = g.clone();
    for t in &mut sub.tensors {
        if let Tile::Split(d) = tiles[t.id] {
            // Invariant: planner-chosen tiles only split even dims
            // (candidate_tiles); hand-written plans hit validate_plan
            // before any consumer walks them down to here.
            assert!(t.shape[d] % 2 == 0);
            t.shape[d] /= 2;
        }
    }
    sub
}

/// Algorithm 1: recursively one-cut, `k` times. Panics on planner failure.
#[deprecated(note = "use `try_k_cut` and handle the `PlanError`")]
pub fn k_cut(g: &Graph, k: usize) -> Plan {
    try_k_cut(g, k).expect("k-cut planning failed")
}

/// Algorithm 1 with structured errors.
///
/// Halving shard shapes never changes the graph's *topology*, so the
/// one-cut solver's levelization, alias map and component structure are
/// built once and reused across all `k` recursion levels — only the
/// numeric cost tables are re-derived for each halved graph.
pub fn try_k_cut(g: &Graph, k: usize) -> Result<Plan, PlanError> {
    let nt = g.tensors.len();
    let mut tiles: Vec<TileSeq> = vec![Vec::with_capacity(k); nt];
    let mut cut_costs = Vec::with_capacity(k);
    let solver = OneCutSolver::new(g);
    let mut cur = g.clone();
    for _ in 0..k {
        let oc = solver.solve(&cur)?;
        cut_costs.push(oc.cost);
        for t in 0..nt {
            tiles[t].push(oc.tiles[t]);
        }
        cur = apply_cut(&cur, &oc.tiles);
    }
    Ok(Plan { k, tiles, cut_costs })
}

/// Algorithm 1 under a topology weighting: cut `j`'s one-cut DP minimizes
/// *modeled time on tier `j`* ([`OneCutSolver::solve_weighted`] — Eq. (2)
/// bytes re-priced through the cut's
/// [`CutCostModel`](crate::tiling::CutCostModel)) instead of raw bytes.
/// The returned [`Plan`] stays in the byte currency (`cut_costs` are the
/// chosen tilings' Eq. (3) byte totals), so Theorem 1, the simulator meter
/// and the lowering identity all keep working unchanged.
///
/// This is the "weighted-dp" candidate of
/// [`super::plan_topology_aware`]'s portfolio; on a uniform zero-latency
/// weighting it reproduces [`try_k_cut`] bit for bit.
pub fn try_k_cut_weighted(
    g: &Graph,
    k: usize,
    model: &super::topology::TopologyModel,
) -> Result<Plan, PlanError> {
    assert!(model.k() >= k, "topology model prices {} cuts, need {k}", model.k());
    let nt = g.tensors.len();
    let mut tiles: Vec<TileSeq> = vec![Vec::with_capacity(k); nt];
    let mut cut_costs = Vec::with_capacity(k);
    let solver = OneCutSolver::new(g);
    let mut cur = g.clone();
    for j in 0..k {
        let oc = solver.solve_weighted(&cur, model.cut(j))?;
        cut_costs.push(oc.cost);
        for t in 0..nt {
            tiles[t].push(oc.tiles[t]);
        }
        cur = apply_cut(&cur, &oc.tiles);
    }
    Ok(Plan { k, tiles, cut_costs })
}

/// Elastic re-plan after permanent device loss: a fresh plan for the
/// surviving `2^(k-1)` devices.
///
/// The paper's planner is parameterized by device count, so shrinking the
/// world is just planning again at `k-1` (the FlexFlow observation: the
/// strategy space is re-searchable when the device set changes). The
/// fresh search can pick a *different* tiling than the old plan's inner
/// cuts — at half the devices the cost trade-offs shift. If the fresh
/// search fails (it should not when the original plan exists, but the
/// solver's state limits are graph-dependent), fall back to truncating
/// the old plan's outermost cut: the inner `k-1` cuts of a valid k-cut
/// plan are always realizable at full tensor extents, because a dimension
/// that splits evenly at the *halved* granularity splits evenly at the
/// full one. Re-priced via [`eval_plan`] so the result carries honest
/// Theorem-1 costs, and re-validated either way.
///
/// Errors with [`PlanError::Infeasible`] when `old.k == 0` — a one-device
/// world has no survivors to re-plan onto.
pub fn replan_after_loss(g: &Graph, old: &Plan) -> Result<Plan, PlanError> {
    if old.k == 0 {
        return Err(PlanError::Infeasible);
    }
    if let Ok(plan) = try_k_cut(g, old.k - 1) {
        return Ok(plan);
    }
    let tiles: Vec<TileSeq> = old.tiles.iter().map(|seq| seq[1..].to_vec()).collect();
    let plan = eval_plan(g, &tiles);
    validate_plan(g, &plan)?;
    Ok(plan)
}

/// Re-price an arbitrary per-tensor `TileSeq` assignment cut by cut (used
/// for the fixed baselines so all strategies share one cost model).
pub fn eval_plan(g: &Graph, tiles: &[TileSeq]) -> Plan {
    eval_plan_forced(g, tiles, &|_, _| None)
}

/// [`eval_plan`] with per-op forced forms (the classic-DP baseline).
pub fn eval_plan_forced(
    g: &Graph,
    tiles: &[TileSeq],
    forced: &dyn Fn(&Graph, &Op) -> Option<Form>,
) -> Plan {
    let k = tiles.first().map_or(0, Vec::len);
    assert!(tiles.iter().all(|s| s.len() == k), "ragged tile sequences");
    let mut cur = g.clone();
    let mut cut_costs = Vec::with_capacity(k);
    for i in 0..k {
        let cut: Vec<Tile> = tiles.iter().map(|s| s[i]).collect();
        cut_costs.push(price_forced(&cur, &cut, forced));
        cur = apply_cut(&cur, &cut);
    }
    Plan { k, tiles: tiles.to_vec(), cut_costs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{append_backward, GraphBuilder};

    fn mlp_train(batch: usize, dims: &[usize]) -> Graph {
        let mut b = GraphBuilder::new();
        let mut h = b.input("x", &[batch, dims[0]]);
        let y = b.label("y", &[batch, *dims.last().unwrap()]);
        let nl = dims.len() - 1;
        for l in 0..nl {
            let w = b.weight(&format!("w{l}"), &[dims[l], dims[l + 1]]);
            h = b.matmul(&format!("fc{l}"), h, w, false, false);
            if l + 1 < nl {
                h = b.relu(&format!("fc{l}.relu"), h);
            }
        }
        let loss = b.softmax_xent("loss", h, y);
        append_backward(&mut b, loss);
        b.finish()
    }

    #[test]
    fn theorem1_weighting() {
        let p = Plan { k: 3, tiles: vec![], cut_costs: vec![40, 20, 10] };
        // Outermost cut once, middle twice, innermost in all four leaf
        // pairs: 1·40 + 2·20 + 4·10 = 120.
        assert_eq!(p.total_cost(), 120);
        assert_eq!(p.devices(), 8);
    }

    #[test]
    fn kcut_beats_baselines_on_paper_example() {
        // The §2.2 16-device setting: SOYBEAN must beat both pure schemes.
        let g = mlp_train(400, &[300; 6]);
        let k = 4;
        let soy = try_k_cut(&g, k).unwrap();
        let dp = super::super::baselines::data_parallel(&g, k);
        let mp = super::super::baselines::model_parallel(&g, k);
        assert!(soy.total_cost() <= dp.total_cost(), "soy {} dp {}", soy.total_cost(), dp.total_cost());
        assert!(soy.total_cost() <= mp.total_cost(), "soy {} mp {}", soy.total_cost(), mp.total_cost());
    }

    #[test]
    fn kcut_costs_consistent_with_eval() {
        let g = mlp_train(64, &[32, 32, 32]);
        let p = try_k_cut(&g, 2).unwrap();
        let re = eval_plan(&g, &p.tiles);
        assert_eq!(p.cut_costs, re.cut_costs);
    }

    #[test]
    fn greediness_theorem3() {
        // Theorem 3: each outer cut costs at most twice the next inner
        // cut — the greedy outer cut could always have used the inner
        // cut's tiling, whose cost at the outer (un-halved) granularity is
        // at most doubled.
        for (batch, dims) in [(400usize, vec![300usize; 6]), (512, vec![256; 4]), (64, vec![512, 512, 512])] {
            let g = mlp_train(batch, &dims);
            let p = try_k_cut(&g, 3).unwrap();
            for j in 0..p.cut_costs.len() - 1 {
                assert!(
                    p.cut_costs[j] <= 2 * p.cut_costs[j + 1].max(1),
                    "outer δ at cut {} = {} > 2× inner {} for {batch} {dims:?}",
                    j,
                    p.cut_costs[j],
                    p.cut_costs[j + 1]
                );
            }
        }
    }

    #[test]
    fn weighted_kcut_with_zero_latency_matches_byte_kcut() {
        // Within one cut a pure per-byte scale is strictly monotone, so a
        // zero-latency weighting — whatever its per-tier bandwidths —
        // reproduces the byte plan cut for cut, bit for bit.
        use crate::planner::topology::TopologyModel;
        use crate::sim::Topology;
        let g = mlp_train(400, &[300; 6]);
        let k = 3;
        let byte = try_k_cut(&g, k).unwrap();
        for topo in [
            Topology::flat(k, 5.0e9, 0.0, 2.0),
            Topology::flat(1, 1.0e9, 0.0, 1.0),
        ] {
            let model = TopologyModel::new(&topo, k);
            let weighted = try_k_cut_weighted(&g, k, &model).unwrap();
            assert_eq!(weighted.tiles, byte.tiles);
            assert_eq!(weighted.cut_costs, byte.cut_costs);
        }
    }

    #[test]
    fn deeper_cuts_monotone_devices() {
        let g = mlp_train(128, &[64, 64]);
        for k in 0..4 {
            let p = try_k_cut(&g, k).unwrap();
            assert_eq!(p.devices(), 1 << k);
            assert_eq!(p.cut_costs.len(), k);
        }
    }

    #[test]
    fn validate_plan_rejects_structural_breakage() {
        let g = mlp_train(8, &[4, 4]);
        let good = try_k_cut(&g, 2).unwrap();
        assert!(validate_plan(&g, &good).is_ok());
        // Wrong tensor count.
        let bad = Plan { k: 2, tiles: vec![], cut_costs: vec![0, 0] };
        assert!(matches!(
            validate_plan(&g, &bad).unwrap_err(),
            PlanError::MalformedPlan { .. }
        ));
        // Ragged sequence: one tensor has a 1-tile seq in a k=2 plan.
        let mut tiles = good.tiles.clone();
        tiles[0] = vec![Tile::Rep];
        let bad = Plan { k: 2, tiles, cut_costs: vec![0, 0] };
        assert!(matches!(
            validate_plan(&g, &bad).unwrap_err(),
            PlanError::MalformedPlan { .. }
        ));
        // Splitting the batch (8) three times dies at the third cut.
        let mut tiles = vec![vec![Tile::Rep; 4]; g.tensors.len()];
        tiles[0] = vec![Tile::Split(0); 4];
        let bad = Plan { k: 4, tiles, cut_costs: vec![0; 4] };
        match validate_plan(&g, &bad).unwrap_err() {
            PlanError::UnsplittableTensor { cut, .. } => assert_eq!(cut, 3),
            other => panic!("expected UnsplittableTensor, got {other:?}"),
        }
        // A split of a dimension the tensor does not have.
        let mut tiles = vec![vec![Tile::Rep]; g.tensors.len()];
        tiles[0] = vec![Tile::Split(5)];
        let bad = Plan { k: 1, tiles, cut_costs: vec![0] };
        assert!(matches!(
            validate_plan(&g, &bad).unwrap_err(),
            PlanError::UnsplittableTensor { cut: 0, .. }
        ));
    }

    #[test]
    fn apply_cut_halves_only_split_dims() {
        let g = mlp_train(8, &[4, 4]);
        let tiles: Vec<Tile> = g
            .tensors
            .iter()
            .map(|t| if t.rank() == 2 && t.shape[0] % 2 == 0 { Tile::Split(0) } else { Tile::Rep })
            .collect();
        let sub = apply_cut(&g, &tiles);
        for (a, b) in g.tensors.iter().zip(&sub.tensors) {
            match tiles[a.id] {
                Tile::Split(0) => assert_eq!(b.shape[0], a.shape[0] / 2),
                _ => assert_eq!(b.shape, a.shape),
            }
        }
    }
}
