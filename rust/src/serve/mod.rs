//! The serving runtime: plan once, execute forever.
//!
//! Everything upstream of this module is one-shot — every call re-plans,
//! re-lowers, and (before the [`crate::spmd::WorkerPool`] refactor)
//! re-spawned worker threads. But the planner's whole value proposition
//! is *amortization*: the optimal tiling is found once and reused across
//! every subsequent step. This module is that reuse, in two layers:
//!
//! - [`Session`] — the unified facade. `Session::build(graph, devices,
//!   &topology)` runs the full pipeline (topology-aware planning →
//!   lowering → validation) once and owns the result; `execute`,
//!   `simulate`, and `plan_summary` then work off the owned artifacts.
//!   Every method returns the single crate-level [`crate::Error`].
//! - [`ServeEngine`] — the long-lived runtime behind a request queue.
//!   One warm SPMD worker thread per device survives across steps
//!   ([`crate::spmd::WorkerPool`]); concurrent requests coalesce into
//!   the batch axis the tiling already splits (dynamic batching, bounded
//!   by [`ServeOptions::max_batch`] and [`ServeOptions::max_linger`]);
//!   lowered plans are cached by `(graph fingerprint, device count,
//!   topology fingerprint)` FNV-1a keys ([`PlanCache`]); and every
//!   request's latency feeds the [`ServeStats`] snapshot (throughput,
//!   p50/p95/p99, batch-size histogram, cache hit rate).
//!
//! The narrative chapter is [`crate::book::serving`] (docs/serving.md).
//! The sustained-load gate is `benches/serve_micro.rs`: batched
//! throughput must strictly beat batch-1 submission on the 4-layer
//! encoder, and every served output must match
//! [`crate::graph::eval_serial`] within 1e-5.

mod cache;
mod engine;
mod session;
mod stats;

pub use cache::{graph_fingerprint, topology_fingerprint, PlanCache, PlanKey};
pub use engine::{
    PendingResponse, ServeClient, ServeEngine, ServeOptions, ServeRequest, ServeResponse,
};
pub use session::{PlanSummary, Session};
pub use stats::{ServeStats, StageBreakdown};

use std::fmt;

/// Structured serving-runtime failure (queueing and request admission;
/// planning and execution failures surface as the other
/// [`crate::Error`] variants).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The engine has shut down (or its scheduler died): the request was
    /// not and will never be served.
    Closed,
    /// The request is malformed — unknown feed tensor, mis-sized feed,
    /// zero units, or more units than the engine's `max_batch`.
    BadRequest {
        /// What was malformed.
        reason: String,
    },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Closed => write!(f, "serve engine is shut down"),
            ServeError::BadRequest { reason } => write!(f, "bad request: {reason}"),
        }
    }
}

impl std::error::Error for ServeError {}
