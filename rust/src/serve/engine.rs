//! The long-lived serving engine: warm workers, dynamic batching, plan
//! caching, and the request queue.
//!
//! One scheduler thread owns a persistent [`WorkerPool`] (one warm SPMD
//! thread per device, surviving across steps) and a [`PlanCache`].
//! Clients submit requests over an mpsc queue; the scheduler coalesces
//! whatever is queued into one graph-level batch — requests stack along
//! the batch axis the tiling already splits — bounded by
//! [`ServeOptions::max_batch`] units and a [`ServeOptions::max_linger`]
//! wait for stragglers. The coalesced unit count is padded up to a
//! multiple of [`ServeOptions::batch_align`] (default: the device
//! count) by *repeating the last real unit's rows* — repetition, not
//! zeros, so normalization and softmax stay on well-conditioned inputs
//! — which bounds the set of distinct padded shapes and makes the plan
//! cache converge to a 100% hit rate after one request per shape.
//!
//! Batching is transparent to correctness: every op the zoo lowers is
//! row-independent along the folded batch axis (matmul rows, layer-norm
//! rows, per-row softmax, attention mixing only within a unit), so a
//! request's slice of the batched output equals its solo run — the
//! property `rust/tests/session.rs` and `benches/serve_micro.rs` pin
//! against [`crate::graph::eval_serial`].

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::Error;
use crate::graph::Graph;
use crate::planner::{PlanError, PlanFamily};
use crate::sim::Topology;
use crate::spmd::{ExecOptions, WorkerPool};

use super::cache::{PlanCache, PlanKey};
use super::session::{build_ctx, Session};
use super::stats::{ServeStats, StatsInner};
use super::ServeError;

/// Knobs for a [`ServeEngine`], with builder-style setters.
///
/// ```
/// use std::time::Duration;
/// use soybean::ServeOptions;
///
/// let opts = ServeOptions::default()
///     .max_batch(16)
///     .max_linger(Duration::from_millis(1))
///     .output("head.out");
/// assert_eq!(opts.max_batch, 16);
/// ```
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Most request units one coalesced batch may hold.
    pub max_batch: usize,
    /// Longest the scheduler lingers for stragglers after the first
    /// request of a batch arrives.
    pub max_linger: Duration,
    /// Pad the coalesced unit count up to a multiple of this; `0` (the
    /// default) means the engine's device count, so every shard keeps an
    /// equal, nonzero slice of the batch axis.
    pub batch_align: usize,
    /// Names of the tensors returned per request (must scale with the
    /// batch axis). Empty (the default): the last batch-scaled tensor
    /// the graph produces.
    pub outputs: Vec<String>,
    /// Execution options every served step runs under.
    pub exec: ExecOptions,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_batch: 64,
            max_linger: Duration::from_millis(2),
            batch_align: 0,
            outputs: Vec::new(),
            exec: ExecOptions::default(),
        }
    }
}

impl ServeOptions {
    /// Set the batch-unit cap (builder style).
    #[must_use]
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.max_batch = max_batch;
        self
    }

    /// Set the straggler linger (builder style).
    #[must_use]
    pub fn max_linger(mut self, max_linger: Duration) -> Self {
        self.max_linger = max_linger;
        self
    }

    /// Set the padding alignment (builder style); `0` = device count.
    #[must_use]
    pub fn batch_align(mut self, batch_align: usize) -> Self {
        self.batch_align = batch_align;
        self
    }

    /// Add one output tensor name (builder style).
    #[must_use]
    pub fn output(mut self, name: impl Into<String>) -> Self {
        self.outputs.push(name.into());
        self
    }

    /// Set the per-step execution options (builder style).
    #[must_use]
    pub fn exec(mut self, exec: ExecOptions) -> Self {
        self.exec = exec;
        self
    }
}

/// One inference request: `units` batch units plus, for every feed
/// tensor ([`ServeEngine::feed_names`]), that many units of row data.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    /// Batch units this request occupies (for the zoo's models, one
    /// unit = one batch element, e.g. one sequence).
    pub units: usize,
    /// Feed tensor name → `units * per_unit_elements` values, units
    /// contiguous and in order.
    pub feeds: BTreeMap<String, Vec<f32>>,
}

impl ServeRequest {
    /// A request of `units` units with no feeds yet.
    pub fn new(units: usize) -> Self {
        ServeRequest { units, feeds: BTreeMap::new() }
    }

    /// Attach one feed tensor's data (builder style).
    #[must_use]
    pub fn feed(mut self, name: impl Into<String>, data: Vec<f32>) -> Self {
        self.feeds.insert(name.into(), data);
        self
    }
}

/// What one served request gets back.
#[derive(Debug, Clone)]
pub struct ServeResponse {
    /// Output tensor name → this request's `units * per_unit_elements`
    /// slice of the batched result.
    pub outputs: BTreeMap<String, Vec<f32>>,
    /// Units the request occupied.
    pub units: usize,
    /// Real units of the coalesced batch the request rode in.
    pub batch_units: usize,
    /// Padded units actually executed (`batch_units` rounded up to the
    /// alignment).
    pub padded_units: usize,
    /// Submit → reply latency.
    pub latency: Duration,
}

/// A submitted request plus its reply channel and submit timestamp.
struct Envelope {
    req: ServeRequest,
    submitted: Instant,
    reply: Sender<Result<ServeResponse, Error>>,
}

enum ToEngine {
    Request(Envelope),
    Stop,
}

/// An in-flight request handle: redeem with [`PendingResponse::wait`].
pub struct PendingResponse {
    rx: Receiver<Result<ServeResponse, Error>>,
}

impl PendingResponse {
    /// Block until the engine replies (or has shut down).
    pub fn wait(self) -> Result<ServeResponse, Error> {
        self.rx.recv().unwrap_or(Err(Error::Serve(ServeError::Closed)))
    }
}

/// A clonable, thread-safe handle for submitting requests to a
/// [`ServeEngine`]. Clone one per client thread.
#[derive(Clone)]
pub struct ServeClient {
    tx: Sender<ToEngine>,
}

impl ServeClient {
    /// Enqueue a request; returns immediately with a handle.
    pub fn submit(&self, req: ServeRequest) -> PendingResponse {
        let (reply, rx) = channel();
        let env = Envelope { req, submitted: Instant::now(), reply };
        // A failed send drops the envelope (and its reply sender), which
        // `wait` observes as `Closed` — no separate error path needed.
        let _ = self.tx.send(ToEngine::Request(env));
        PendingResponse { rx }
    }

    /// Submit and block for the reply.
    pub fn infer(&self, req: ServeRequest) -> Result<ServeResponse, Error> {
        self.submit(req).wait()
    }
}

/// How the engine's batch model classifies and sizes tensors, probed at
/// launch by comparing `rebatch(1)` against `rebatch(2)`.
struct BatchModel {
    /// Feed tensor name → elements per unit (batch-scaled, producerless).
    feed: BTreeMap<String, usize>,
    /// Fixed producerless tensor name → its served value (weights).
    fixed: BTreeMap<String, Vec<f32>>,
    /// Output tensor name → elements per unit (batch-scaled).
    outputs: Vec<(String, usize)>,
}

fn elems(shape: &[usize]) -> usize {
    shape.iter().product()
}

fn config_err(reason: String) -> Error {
    Error::Plan(PlanError::MalformedConfig { reason })
}

/// Probe the rebatch closure and bind the fixed tensors' values.
fn probe_batch_model(
    rebatch: &dyn Fn(usize) -> Graph,
    session: &Session,
    base_init: &[Option<Vec<f32>>],
    opts: &ServeOptions,
) -> Result<BatchModel, Error> {
    let g1 = rebatch(1);
    let g2 = rebatch(2);
    if g1.tensors.len() != g2.tensors.len() || g1.ops.len() != g2.ops.len() {
        return Err(config_err(
            "rebatch(1) and rebatch(2) disagree on graph structure".into(),
        ));
    }
    let produced = g1.produced_mask();
    let mut feed = BTreeMap::new();
    let mut fixed_names = Vec::new();
    let mut scaled = BTreeMap::new();
    for (t1, t2) in g1.tensors.iter().zip(&g2.tensors) {
        if t1.name != t2.name {
            return Err(config_err(format!(
                "rebatch changes tensor naming: `{}` vs `{}`",
                t1.name, t2.name
            )));
        }
        let (e1, e2) = (elems(&t1.shape), elems(&t2.shape));
        if e1 != e2 {
            if e2 != 2 * e1 {
                return Err(config_err(format!(
                    "tensor `{}` does not scale linearly with units ({e1} -> {e2})",
                    t1.name
                )));
            }
            scaled.insert(t1.name.clone(), e1);
            if !produced[t1.id] {
                feed.insert(t1.name.clone(), e1);
            }
        } else if !produced[t1.id] {
            fixed_names.push(t1.name.clone());
        }
    }
    if feed.is_empty() {
        return Err(config_err("no batch-scaled feed tensor found".into()));
    }

    // Bind the fixed tensors (weights, gains) to the base session's init
    // values — shapes are batch-independent, so they serve every size.
    let sg = session.graph();
    if base_init.len() != sg.tensors.len() {
        return Err(config_err(format!(
            "base init has {} entries but the session graph has {} tensors",
            base_init.len(),
            sg.tensors.len()
        )));
    }
    let mut fixed = BTreeMap::new();
    for name in fixed_names {
        let t = sg
            .tensors
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| {
                config_err(format!("fixed tensor `{name}` missing from session graph"))
            })?;
        let v = base_init[t.id]
            .as_ref()
            .ok_or_else(|| {
                config_err(format!("base init missing value for fixed tensor `{name}`"))
            })?;
        if v.len() != elems(&t.shape) {
            return Err(config_err(format!(
                "base init value for `{name}` has {} elements, tensor wants {}",
                v.len(),
                elems(&t.shape)
            )));
        }
        fixed.insert(name, v.clone());
    }

    // Resolve the served outputs: explicit names, or the last
    // batch-scaled tensor the graph produces.
    let outputs: Vec<(String, usize)> = if opts.outputs.is_empty() {
        let last = g1
            .tensors
            .iter()
            .rev()
            .find(|t| produced[t.id] && scaled.contains_key(&t.name))
            .ok_or_else(|| config_err("graph produces no batch-scaled tensor to serve".into()))?;
        vec![(last.name.clone(), scaled[&last.name])]
    } else {
        opts.outputs
            .iter()
            .map(|name| {
                scaled
                    .get(name)
                    .map(|&e| (name.clone(), e))
                    .ok_or_else(|| {
                        config_err(format!(
                            "output `{name}` is not a batch-scaled tensor of the graph"
                        ))
                    })
            })
            .collect::<Result<_, _>>()?
    };
    Ok(BatchModel { feed, fixed, outputs })
}

/// The scheduler: owns the warm pool, the plan cache, and the coalescing
/// loop. Runs on its own thread until `Stop` or until every sender
/// (engine + all clients) is gone.
struct Scheduler<F> {
    rebatch: F,
    devices: usize,
    topo: Topology,
    strategy: PlanFamily,
    exec: ExecOptions,
    max_batch: usize,
    max_linger: Duration,
    align: usize,
    model: BatchModel,
    pool: WorkerPool,
    cache: PlanCache,
    stats: Arc<Mutex<StatsInner>>,
    /// A request that would have overflowed the current batch — first in
    /// line for the next one.
    carry: Option<Envelope>,
}

impl<F: Fn(usize) -> Graph> Scheduler<F> {
    fn run(mut self, rx: Receiver<ToEngine>) {
        let mut stopping = false;
        while !stopping {
            // First member: the carried-over overflow, or block for one.
            let first = match self.carry.take() {
                Some(e) => e,
                None => match rx.recv() {
                    Ok(ToEngine::Request(e)) => e,
                    Ok(ToEngine::Stop) | Err(_) => break,
                },
            };
            let Some(first) = self.admit(first) else { continue };
            // The batch opens here: the linger stage runs from this
            // instant to dispatch ([`StatsInner::record_batch_stages`]).
            let opened = Instant::now();
            let mut units = first.req.units;
            let mut batch = vec![first];
            // Linger for stragglers up to max_linger or a full batch.
            let deadline = Instant::now() + self.max_linger;
            while units < self.max_batch {
                let remaining = deadline.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break;
                }
                match rx.recv_timeout(remaining) {
                    Ok(ToEngine::Request(e)) => {
                        let Some(e) = self.admit(e) else { continue };
                        if units + e.req.units > self.max_batch {
                            self.carry = Some(e);
                            break;
                        }
                        units += e.req.units;
                        batch.push(e);
                    }
                    Ok(ToEngine::Stop) | Err(RecvTimeoutError::Disconnected) => {
                        stopping = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                }
            }
            self.serve_batch(batch, units, opened);
        }
        // Shutting down: everything still queued will never be served.
        if let Some(e) = self.carry.take() {
            let _ = e.reply.send(Err(Error::Serve(ServeError::Closed)));
        }
        while let Ok(m) = rx.try_recv() {
            if let ToEngine::Request(e) = m {
                let _ = e.reply.send(Err(Error::Serve(ServeError::Closed)));
            }
        }
    }

    /// Validate one request; on failure reply `BadRequest` and drop it.
    fn admit(&self, env: Envelope) -> Option<Envelope> {
        let reject = |env: Envelope, reason: String| {
            let _ =
                env.reply.send(Err(Error::Serve(ServeError::BadRequest { reason })));
            None
        };
        let u = env.req.units;
        if u == 0 {
            return reject(env, "request has zero units".into());
        }
        if u > self.max_batch {
            return reject(env, format!("request has {u} units, max_batch is {}", self.max_batch));
        }
        for name in env.req.feeds.keys() {
            if !self.model.feed.contains_key(name) {
                return reject(env, format!("unknown feed tensor `{name}`"));
            }
        }
        for (name, &per) in &self.model.feed {
            match env.req.feeds.get(name) {
                None => return reject(env, format!("missing feed tensor `{name}`")),
                Some(v) if v.len() != u * per => {
                    let got = v.len();
                    return reject(
                        env,
                        format!("feed `{name}` has {got} elements, {u} units want {}", u * per),
                    );
                }
                Some(_) => {}
            }
        }
        Some(env)
    }

    /// Execute one coalesced batch and reply to every member. `opened`
    /// is when the batch's first member was admitted — the linger stage
    /// runs from there to this call.
    fn serve_batch(&mut self, batch: Vec<Envelope>, units: usize, opened: Instant) {
        let broadcast = |batch: Vec<Envelope>, e: Error| {
            for env in batch {
                let _ = env.reply.send(Err(e.clone()));
            }
        };
        let linger_s = opened.elapsed().as_secs_f64();
        // Queue wait ends at batch pickup: sample every member now,
        // before planning and execution add to it.
        let queue_waits: Vec<f64> =
            batch.iter().map(|e| e.submitted.elapsed().as_secs_f64()).collect();
        let padded = units.div_ceil(self.align) * self.align;
        let g = (self.rebatch)(padded);
        let key = PlanKey::of(&g, self.devices, &self.topo);
        let (ctx, hit) = match self.cache.get(&key) {
            Some(c) => (c, true),
            None => {
                match build_ctx(
                    g.clone(),
                    self.devices,
                    &self.topo,
                    self.strategy,
                    self.exec.clone(),
                ) {
                    Ok((c, _)) => {
                        self.cache.insert(key, Arc::clone(&c));
                        (c, false)
                    }
                    Err(e) => return broadcast(batch, e),
                }
            }
        };
        self.stats.lock().expect("stats lock").record_cache(hit);

        // Assemble the batched init: fixed tensors verbatim, feeds
        // concatenated in arrival order, padding by repeating the last
        // real unit's block.
        let produced = g.produced_mask();
        let mut init: Vec<Option<Vec<f32>>> = vec![None; g.tensors.len()];
        for t in &g.tensors {
            if produced[t.id] {
                continue;
            }
            if let Some(v) = self.model.fixed.get(&t.name) {
                init[t.id] = Some(v.clone());
            } else if let Some(&per) = self.model.feed.get(&t.name) {
                let mut buf = Vec::with_capacity(padded * per);
                for env in &batch {
                    buf.extend_from_slice(&env.req.feeds[&t.name]);
                }
                let last = buf[(units - 1) * per..units * per].to_vec();
                for _ in units..padded {
                    buf.extend_from_slice(&last);
                }
                init[t.id] = Some(buf);
            } else {
                // The probe classified every producerless tensor; a third
                // class means the rebatch closure changed shape midway.
                return broadcast(
                    batch,
                    config_err(format!("tensor `{}` is neither fixed nor feed", t.name)),
                );
            }
        }

        let exec_t0 = Instant::now();
        let report = match self.pool.run_step(&ctx, &init) {
            Ok(r) => r,
            Err(e) => return broadcast(batch, Error::from(e)),
        };
        let execute_s = exec_t0.elapsed().as_secs_f64();

        // Slice each member's rows back out and reply.
        let slice_t0 = Instant::now();
        let mut latencies = Vec::with_capacity(batch.len());
        let mut off = 0;
        for env in batch {
            let u = env.req.units;
            let mut outputs = BTreeMap::new();
            for (name, per) in &self.model.outputs {
                let t = g
                    .tensors
                    .iter()
                    .find(|t| &t.name == name)
                    .expect("output name validated at launch");
                let rows = report.tensors[t.id][off * per..(off + u) * per].to_vec();
                outputs.insert(name.clone(), rows);
            }
            let latency = env.submitted.elapsed();
            latencies.push(latency);
            let resp = ServeResponse {
                outputs,
                units: u,
                batch_units: units,
                padded_units: padded,
                latency,
            };
            let _ = env.reply.send(Ok(resp));
            off += u;
        }
        let slice_s = slice_t0.elapsed().as_secs_f64();

        // One lock for the whole batch's bookkeeping — replies are
        // already on their way.
        let mut stats = self.stats.lock().expect("stats lock");
        stats.record_batch(units);
        stats.record_batch_stages(linger_s, execute_s, slice_s);
        for w in queue_waits {
            stats.record_queue_wait(w);
        }
        for l in latencies {
            stats.record_request(l);
        }
    }
}

/// The long-lived serving runtime (module docs for the architecture).
///
/// Construct with [`ServeEngine::launch`] from a built [`Session`] and a
/// `rebatch` closure mapping a unit count to the graph serving that many
/// units. Submit through [`ServeClient`] handles; observe through
/// [`ServeEngine::stats`]. Dropping the engine (or calling
/// [`ServeEngine::shutdown`]) stops the scheduler and joins its thread;
/// in-flight requests receive [`ServeError::Closed`].
pub struct ServeEngine {
    tx: Sender<ToEngine>,
    stats: Arc<Mutex<StatsInner>>,
    feed_names: Vec<String>,
    output_names: Vec<String>,
    handle: Option<JoinHandle<()>>,
}

impl ServeEngine {
    /// Launch the engine from a base `session`.
    ///
    /// `rebatch(u)` must build the session's model at a batch extent of
    /// `u` units with identical structure and naming (the zoo's model
    /// builders all qualify); the engine probes it at launch to learn
    /// which tensors scale with the batch (the feeds and outputs) and
    /// which are fixed (the weights, bound to `base_init`'s values —
    /// index-aligned with `session.graph()`, e.g. from
    /// [`crate::graph::seed_values`]).
    pub fn launch<F>(
        session: &Session,
        rebatch: F,
        base_init: &[Option<Vec<f32>>],
        opts: ServeOptions,
    ) -> Result<ServeEngine, Error>
    where
        F: Fn(usize) -> Graph + Send + 'static,
    {
        if opts.max_batch == 0 {
            return Err(config_err("max_batch must be at least 1".into()));
        }
        let model = probe_batch_model(&rebatch, session, base_init, &opts)?;
        let devices = session.devices();
        let topo = session.topology().clone();
        let stats = Arc::new(Mutex::new(StatsInner::new()));

        // Seed the cache with the base session's already-validated step,
        // so a batch that pads to the base extent never re-plans.
        let mut cache = PlanCache::new();
        cache.insert(
            PlanKey::of(session.graph(), devices, &topo),
            Arc::clone(session.step_ctx()),
        );

        let feed_names: Vec<String> = model.feed.keys().cloned().collect();
        let output_names: Vec<String> =
            model.outputs.iter().map(|(n, _)| n.clone()).collect();
        let scheduler = Scheduler {
            rebatch,
            devices,
            topo,
            strategy: session.strategy(),
            exec: opts.exec.clone(),
            max_batch: opts.max_batch,
            max_linger: opts.max_linger,
            align: if opts.batch_align == 0 { devices } else { opts.batch_align },
            model,
            pool: WorkerPool::spawn(devices),
            cache,
            stats: Arc::clone(&stats),
            carry: None,
        };
        let (tx, rx) = channel();
        let handle = std::thread::spawn(move || scheduler.run(rx));
        Ok(ServeEngine { tx, stats, feed_names, output_names, handle: Some(handle) })
    }

    /// A new client handle (clone freely across threads).
    pub fn client(&self) -> ServeClient {
        ServeClient { tx: self.tx.clone() }
    }

    /// Names of the tensors every request must feed.
    pub fn feed_names(&self) -> &[String] {
        &self.feed_names
    }

    /// Names of the tensors every response carries.
    pub fn output_names(&self) -> &[String] {
        &self.output_names
    }

    /// Snapshot the serving statistics.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().expect("stats lock").snapshot()
    }

    /// Zero the statistics window (requests, latencies, histogram, cache
    /// counters) — call after warmup so gates measure steady state.
    pub fn reset_stats(&self) {
        self.stats.lock().expect("stats lock").reset();
    }

    /// Stop the scheduler and join its thread. Requests still queued
    /// reply [`ServeError::Closed`].
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        if let Some(handle) = self.handle.take() {
            let _ = self.tx.send(ToEngine::Stop);
            let _ = handle.join();
        }
    }
}

impl Drop for ServeEngine {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}
