//! The plan cache: FNV-1a fingerprints over `(graph, devices, topology)`
//! and a map from that key to a validated, dispatch-ready step.
//!
//! Dynamic batching makes the served graph's batch extent vary between
//! steps, and every distinct extent is a distinct planning problem. The
//! cache bounds that cost: the first request at a given padded shape pays
//! the full plan → lower → validate pipeline, every later one is a map
//! lookup returning the shared [`StepCtx`]. Keys are structural — the
//! graph's full topology and shapes, the device count, and the
//! interconnect description — so two graphs that plan identically hit the
//! same entry and two that differ anywhere cannot collide (modulo the
//! 64-bit digest).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::graph::Graph;
use crate::sim::Topology;
use crate::spmd::StepCtx;
use crate::util::checksum::Fnv64;

/// FNV-1a digest of a graph's full structure: every tensor's name, kind,
/// dtype width and shape, and every op's name, kind (including its
/// parameters, via the derived debug form), and wiring.
pub fn graph_fingerprint(g: &Graph) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(g.tensors.len() as u64);
    for t in &g.tensors {
        h.write(t.name.as_bytes());
        h.write(&[0]);
        h.write(format!("{:?}", t.kind).as_bytes());
        h.write_u64(t.dtype_bytes as u64);
        h.write_u64(t.shape.len() as u64);
        for &d in &t.shape {
            h.write_u64(d as u64);
        }
    }
    h.write_u64(g.ops.len() as u64);
    for op in &g.ops {
        h.write(op.name.as_bytes());
        h.write(&[0]);
        h.write(format!("{:?}", op.kind).as_bytes());
        h.write_u64(op.inputs.len() as u64);
        for &i in &op.inputs {
            h.write_u64(i as u64);
        }
        h.write_u64(op.outputs.len() as u64);
        for &o in &op.outputs {
            h.write_u64(o as u64);
        }
    }
    h.finish()
}

/// FNV-1a digest of an interconnect description: every tier's name,
/// bandwidth, latency and slot count (floats by bit pattern, so the
/// digest is exact, not tolerance-based).
pub fn topology_fingerprint(topo: &Topology) -> u64 {
    let mut h = Fnv64::new();
    h.write_u64(topo.tiers.len() as u64);
    for tier in &topo.tiers {
        h.write(tier.name.as_bytes());
        h.write(&[0]);
        h.write_u64(tier.bandwidth.to_bits());
        h.write_u64(tier.latency.to_bits());
        h.write_u64(tier.slots.to_bits());
    }
    h.finish()
}

/// Cache key: the tentpole triple `(graph fingerprint, device count,
/// topology fingerprint)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    /// [`graph_fingerprint`] of the (padded) graph to serve.
    pub graph: u64,
    /// Device count the plan targets.
    pub devices: usize,
    /// [`topology_fingerprint`] of the interconnect planned for.
    pub topo: u64,
}

impl PlanKey {
    /// Build the key for `(g, devices, topo)`.
    pub fn of(g: &Graph, devices: usize, topo: &Topology) -> Self {
        PlanKey {
            graph: graph_fingerprint(g),
            devices,
            topo: topology_fingerprint(topo),
        }
    }
}

/// Map from [`PlanKey`] to a validated [`StepCtx`], with hit/miss
/// counters for the [`super::ServeStats`] hit-rate gate.
#[derive(Default)]
pub struct PlanCache {
    map: BTreeMap<PlanKey, Arc<StepCtx>>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    /// An empty cache.
    pub fn new() -> Self {
        PlanCache::default()
    }

    /// Look `key` up, counting a hit or a miss.
    pub fn get(&mut self, key: &PlanKey) -> Option<Arc<StepCtx>> {
        match self.map.get(key) {
            Some(ctx) => {
                self.hits += 1;
                Some(Arc::clone(ctx))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or seed) an entry without touching the counters.
    pub fn insert(&mut self, key: PlanKey, ctx: Arc<StepCtx>) {
        self.map.insert(key, ctx);
    }

    /// Cached entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lookup hits so far.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookup misses so far.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Hits over total lookups (1.0 for a cache that was never missed;
    /// 0.0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Zero the hit/miss counters (entries stay), so a post-warmup
    /// measurement window can assert its own rate.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mlp, MlpConfig};

    #[test]
    fn graph_fingerprint_is_shape_and_structure_sensitive() {
        let g1 = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
        let g2 = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
        assert_eq!(graph_fingerprint(&g1), graph_fingerprint(&g2), "same build, same digest");
        let bigger = mlp(&MlpConfig { batch: 16, dims: vec![4, 4], bias: false });
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&bigger), "batch changes digest");
        let biased = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: true });
        assert_ne!(graph_fingerprint(&g1), graph_fingerprint(&biased), "structure changes digest");
    }

    #[test]
    fn topology_fingerprint_sees_every_field() {
        use crate::sim::Topology;
        let a = Topology::two_tier(3);
        let b = Topology::two_tier(3);
        assert_eq!(topology_fingerprint(&a), topology_fingerprint(&b));
        let mut c = Topology::two_tier(3);
        c.tiers[0].bandwidth *= 2.0;
        assert_ne!(topology_fingerprint(&a), topology_fingerprint(&c));
        assert_ne!(topology_fingerprint(&a), topology_fingerprint(&Topology::fat_tree(3)));
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        use crate::sim::Topology;
        use crate::spmd::{ExecOptions, StepCtx};
        let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
        let topo = Topology::from_sim(&crate::sim::SimConfig::default(), 1);
        let plan = crate::planner::try_k_cut(&g, 1).unwrap();
        let program = crate::lower::try_lower(&g, &plan, &topo.to_sim_config()).unwrap();
        let ctx = Arc::new(
            StepCtx::try_new(g.clone(), plan, program, ExecOptions::default()).unwrap(),
        );
        let key = PlanKey::of(&g, 2, &topo);
        let mut cache = PlanCache::new();
        assert!(cache.get(&key).is_none());
        cache.insert(key, ctx);
        assert!(cache.get(&key).is_some());
        assert!(cache.get(&key).is_some());
        assert_eq!((cache.hits(), cache.misses()), (2, 1));
        assert!((cache.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        cache.reset_counters();
        assert_eq!(cache.hit_rate(), 0.0);
        assert_eq!(cache.len(), 1);
    }
}
