//! The serving stats surface: latency percentiles, throughput, batch
//! shapes, the plan-cache hit rate, and the per-stage request breakdown.
//!
//! The engine's scheduler records one latency sample per served request
//! (submit → reply), one histogram bump per executed batch, and — since
//! the observability layer — where each request's time went: queueing,
//! lingering for batch-mates, executing on the pool, and slicing the
//! batched output back apart ([`StageBreakdown`]). The percentile
//! machinery is the shared [`crate::obs::Histogram`], so serving and the
//! executor metrics agree on the nearest-rank convention. Counters reset
//! as a unit ([`super::ServeEngine::reset_stats`]) so a measurement
//! window can exclude warmup — the bench and the hit-rate gate both rely
//! on that.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::obs::{HistSummary, Histogram};

/// A point-in-time snapshot of the engine's serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests served (replied to successfully) in the window.
    pub requests: u64,
    /// Coalesced batches executed in the window.
    pub batches: u64,
    /// Window length so far.
    pub elapsed: Duration,
    /// Served requests per second over the window.
    pub throughput_rps: f64,
    /// Median request latency (submit → reply).
    pub p50_latency: Duration,
    /// 95th-percentile request latency.
    pub p95_latency: Duration,
    /// 99th-percentile request latency.
    pub p99_latency: Duration,
    /// Executed batch sizes (in request units) → how often each occurred.
    pub batch_histogram: BTreeMap<usize, u64>,
    /// Plan-cache hits in the window.
    pub cache_hits: u64,
    /// Plan-cache misses in the window.
    pub cache_misses: u64,
    /// Hits over total lookups (0.0 before any lookup).
    pub cache_hit_rate: f64,
    /// Where request time went: per-stage latency summaries.
    pub stages: StageBreakdown,
}

/// Per-stage latency summaries of the serving pipeline, in seconds.
///
/// `queue_wait` is sampled per *request* (submit → batch pickup); the
/// other three are sampled per *batch* — a request's end-to-end latency
/// is its queue wait plus the linger/execute/slice of the batch that
/// carried it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageBreakdown {
    /// Submit → the scheduler picked the request into a batch.
    pub queue_wait: HistSummary,
    /// Time the opened batch lingered for batch-mates before dispatch.
    pub linger: HistSummary,
    /// Time the batch spent executing on the worker pool.
    pub execute: HistSummary,
    /// Time spent slicing the batched output back into per-request
    /// replies.
    pub slice: HistSummary,
}

/// The mutable accumulator behind [`ServeStats`] — owned by the engine,
/// written by its scheduler, snapshotted on demand.
#[derive(Debug)]
pub(crate) struct StatsInner {
    started: Instant,
    requests: u64,
    batches: u64,
    latencies: Histogram,
    queue_wait: Histogram,
    linger: Histogram,
    execute: Histogram,
    slice: Histogram,
    batch_histogram: BTreeMap<usize, u64>,
    cache_hits: u64,
    cache_misses: u64,
}

impl StatsInner {
    pub(crate) fn new() -> Self {
        StatsInner {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            latencies: Histogram::new(),
            queue_wait: Histogram::new(),
            linger: Histogram::new(),
            execute: Histogram::new(),
            slice: Histogram::new(),
            batch_histogram: BTreeMap::new(),
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// Record one executed batch of `units` request units.
    pub(crate) fn record_batch(&mut self, units: usize) {
        self.batches += 1;
        *self.batch_histogram.entry(units).or_insert(0) += 1;
    }

    /// Record one served request's submit → reply latency.
    pub(crate) fn record_request(&mut self, latency: Duration) {
        self.requests += 1;
        self.latencies.record(latency.as_secs_f64());
    }

    /// Record one request's queue wait (submit → batch pickup).
    pub(crate) fn record_queue_wait(&mut self, seconds: f64) {
        self.queue_wait.record(seconds);
    }

    /// Record one batch's linger / execute / slice stage durations.
    pub(crate) fn record_batch_stages(&mut self, linger: f64, execute: f64, slice: f64) {
        self.linger.record(linger);
        self.execute.record(execute);
        self.slice.record(slice);
    }

    /// Record one plan-cache lookup.
    pub(crate) fn record_cache(&mut self, hit: bool) {
        if hit {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
        }
    }

    /// Zero everything and restart the window clock.
    pub(crate) fn reset(&mut self) {
        *self = StatsInner::new();
    }

    /// Derive the public snapshot.
    pub(crate) fn snapshot(&self) -> ServeStats {
        let elapsed = self.started.elapsed();
        let secs = elapsed.as_secs_f64();
        let lookups = self.cache_hits + self.cache_misses;
        ServeStats {
            requests: self.requests,
            batches: self.batches,
            elapsed,
            throughput_rps: if secs > 0.0 { self.requests as f64 / secs } else { 0.0 },
            p50_latency: Duration::from_secs_f64(self.latencies.percentile(0.50)),
            p95_latency: Duration::from_secs_f64(self.latencies.percentile(0.95)),
            p99_latency: Duration::from_secs_f64(self.latencies.percentile(0.99)),
            batch_histogram: self.batch_histogram.clone(),
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            cache_hit_rate: if lookups == 0 {
                0.0
            } else {
                self.cache_hits as f64 / lookups as f64
            },
            stages: StageBreakdown {
                queue_wait: self.queue_wait.summary(),
                linger: self.linger.summary(),
                execute: self.execute.summary(),
                slice: self.slice.summary(),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The latency percentiles ride the shared [`Histogram`], and the
    /// seconds → `Duration` round trip is exact at millisecond scale.
    #[test]
    fn percentile_is_nearest_rank() {
        let mut s = StatsInner::new();
        for i in 1..=100 {
            s.record_request(Duration::from_millis(i));
        }
        let snap = s.snapshot();
        assert_eq!(snap.p50_latency, Duration::from_millis(50));
        assert_eq!(snap.p95_latency, Duration::from_millis(95));
        assert_eq!(snap.p99_latency, Duration::from_millis(99));
    }

    #[test]
    fn snapshot_aggregates_and_reset_clears() {
        let mut s = StatsInner::new();
        s.record_batch(4);
        s.record_batch(4);
        s.record_batch(1);
        for i in 1..=9 {
            s.record_request(Duration::from_millis(i));
        }
        s.record_cache(false);
        s.record_cache(true);
        s.record_cache(true);
        let snap = s.snapshot();
        assert_eq!(snap.requests, 9);
        assert_eq!(snap.batches, 3);
        assert_eq!(snap.batch_histogram[&4], 2);
        assert_eq!(snap.batch_histogram[&1], 1);
        assert_eq!(snap.p50_latency, Duration::from_millis(5));
        assert_eq!(snap.p99_latency, Duration::from_millis(9));
        assert!((snap.cache_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        s.reset();
        let snap = s.snapshot();
        assert_eq!((snap.requests, snap.batches), (0, 0));
        assert_eq!(snap.cache_hit_rate, 0.0);
        assert_eq!(snap.stages, StageBreakdown::default(), "stages reset with the window");
    }

    /// The stage breakdown aggregates per-request queue waits and
    /// per-batch linger/execute/slice independently.
    #[test]
    fn stage_breakdown_separates_request_and_batch_samples() {
        let mut s = StatsInner::new();
        s.record_queue_wait(0.002);
        s.record_queue_wait(0.004);
        s.record_queue_wait(0.006);
        s.record_batch_stages(0.001, 0.010, 0.0005);
        let snap = s.snapshot();
        assert_eq!(snap.stages.queue_wait.count, 3);
        assert!((snap.stages.queue_wait.mean - 0.004).abs() < 1e-12);
        assert_eq!(snap.stages.queue_wait.p50, 0.004);
        assert_eq!(snap.stages.linger.count, 1);
        assert_eq!(snap.stages.execute.p99, 0.010);
        assert_eq!(snap.stages.slice.max, 0.0005);
        // An empty stage stays all-zero rather than NaN.
        assert_eq!(StageBreakdown::default().linger.p95, 0.0);
    }
}
