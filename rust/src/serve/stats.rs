//! The serving stats surface: latency percentiles, throughput, batch
//! shapes, and the plan-cache hit rate.
//!
//! The engine's scheduler records one latency sample per served request
//! (submit → reply) and one histogram bump per executed batch; the
//! [`ServeStats`] snapshot derives the aggregates. Counters reset as a
//! unit ([`super::ServeEngine::reset_stats`]) so a measurement window can
//! exclude warmup — the bench and the hit-rate gate both rely on that.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// A point-in-time snapshot of the engine's serving statistics.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// Requests served (replied to successfully) in the window.
    pub requests: u64,
    /// Coalesced batches executed in the window.
    pub batches: u64,
    /// Window length so far.
    pub elapsed: Duration,
    /// Served requests per second over the window.
    pub throughput_rps: f64,
    /// Median request latency (submit → reply).
    pub p50_latency: Duration,
    /// 95th-percentile request latency.
    pub p95_latency: Duration,
    /// 99th-percentile request latency.
    pub p99_latency: Duration,
    /// Executed batch sizes (in request units) → how often each occurred.
    pub batch_histogram: BTreeMap<usize, u64>,
    /// Plan-cache hits in the window.
    pub cache_hits: u64,
    /// Plan-cache misses in the window.
    pub cache_misses: u64,
    /// Hits over total lookups (0.0 before any lookup).
    pub cache_hit_rate: f64,
}

/// The mutable accumulator behind [`ServeStats`] — owned by the engine,
/// written by its scheduler, snapshotted on demand.
#[derive(Debug)]
pub(crate) struct StatsInner {
    started: Instant,
    requests: u64,
    batches: u64,
    latencies: Vec<Duration>,
    batch_histogram: BTreeMap<usize, u64>,
    cache_hits: u64,
    cache_misses: u64,
}

impl StatsInner {
    pub(crate) fn new() -> Self {
        StatsInner {
            started: Instant::now(),
            requests: 0,
            batches: 0,
            latencies: Vec::new(),
            batch_histogram: BTreeMap::new(),
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// Record one executed batch of `units` request units.
    pub(crate) fn record_batch(&mut self, units: usize) {
        self.batches += 1;
        *self.batch_histogram.entry(units).or_insert(0) += 1;
    }

    /// Record one served request's submit → reply latency.
    pub(crate) fn record_request(&mut self, latency: Duration) {
        self.requests += 1;
        self.latencies.push(latency);
    }

    /// Record one plan-cache lookup.
    pub(crate) fn record_cache(&mut self, hit: bool) {
        if hit {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
        }
    }

    /// Zero everything and restart the window clock.
    pub(crate) fn reset(&mut self) {
        *self = StatsInner::new();
    }

    /// Derive the public snapshot.
    pub(crate) fn snapshot(&self) -> ServeStats {
        let elapsed = self.started.elapsed();
        let secs = elapsed.as_secs_f64();
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        let lookups = self.cache_hits + self.cache_misses;
        ServeStats {
            requests: self.requests,
            batches: self.batches,
            elapsed,
            throughput_rps: if secs > 0.0 { self.requests as f64 / secs } else { 0.0 },
            p50_latency: percentile(&sorted, 0.50),
            p95_latency: percentile(&sorted, 0.95),
            p99_latency: percentile(&sorted, 0.99),
            batch_histogram: self.batch_histogram.clone(),
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            cache_hit_rate: if lookups == 0 {
                0.0
            } else {
                self.cache_hits as f64 / lookups as f64
            },
        }
    }
}

/// The `q`-quantile of an ascending-sorted sample set, by the
/// nearest-rank method (`ceil(q·n)`-th smallest); zero for an empty set.
fn percentile(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        let ms: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        assert_eq!(percentile(&ms, 0.50), Duration::from_millis(50));
        assert_eq!(percentile(&ms, 0.95), Duration::from_millis(95));
        assert_eq!(percentile(&ms, 0.99), Duration::from_millis(99));
        assert_eq!(percentile(&ms, 1.0), Duration::from_millis(100));
        assert_eq!(percentile(&[], 0.5), Duration::ZERO);
        assert_eq!(percentile(&[Duration::from_millis(7)], 0.5), Duration::from_millis(7));
    }

    #[test]
    fn snapshot_aggregates_and_reset_clears() {
        let mut s = StatsInner::new();
        s.record_batch(4);
        s.record_batch(4);
        s.record_batch(1);
        for i in 1..=9 {
            s.record_request(Duration::from_millis(i));
        }
        s.record_cache(false);
        s.record_cache(true);
        s.record_cache(true);
        let snap = s.snapshot();
        assert_eq!(snap.requests, 9);
        assert_eq!(snap.batches, 3);
        assert_eq!(snap.batch_histogram[&4], 2);
        assert_eq!(snap.batch_histogram[&1], 1);
        assert_eq!(snap.p50_latency, Duration::from_millis(5));
        assert_eq!(snap.p99_latency, Duration::from_millis(9));
        assert!((snap.cache_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        s.reset();
        let snap = s.snapshot();
        assert_eq!((snap.requests, snap.batches), (0, 0));
        assert_eq!(snap.cache_hit_rate, 0.0);
    }
}
