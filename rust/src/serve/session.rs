//! The [`Session`] facade: one call plans, lowers, and validates; the
//! resulting artifacts are owned and reused.
//!
//! Before this existed, running a model in parallel meant hand-wiring
//! four modules (`planner` → `lower` → `spmd`, with `sim` on the side)
//! and juggling their panicking/`try_*` duals. A `Session` is that whole
//! pipeline executed once, with the single crate-level [`Error`] on
//! every edge, and the artifacts held for repeated use — `execute` as
//! many steps as you like, `simulate` the modeled step time, print the
//! [`PlanSummary`]. The serving engine ([`super::ServeEngine`]) builds
//! on the same context to keep worker threads warm between steps.

use std::fmt;
use std::sync::Arc;

use crate::error::Error;
use crate::graph::Graph;
use crate::lower::{try_lower, try_lower_forced, LoweredProgram};
use crate::obs::{calibrate, ProfileReport};
use crate::planner::{
    baselines, classic_dp_form, classify, try_plan_topology_aware, Plan, PlanError, PlanFamily,
    Strategy,
};
use crate::sim::{try_run_program, try_simulate, try_simulate_forced, SimReport, Topology};
use crate::spmd::{ExecOptions, ExecReport, StepCtx, WorkerPool};

/// Run the full planning pipeline for `(g, devices, topo)` under a
/// strategy and validate the result into a dispatchable [`StepCtx`].
/// Shared by [`Session::build`] and the serving engine's plan-cache
/// misses, so both produce bit-identical contexts.
pub(crate) fn build_ctx(
    g: Graph,
    devices: usize,
    topo: &Topology,
    strategy: PlanFamily,
    exec: ExecOptions,
) -> Result<(Arc<StepCtx>, &'static str), Error> {
    if devices == 0 || !devices.is_power_of_two() {
        return Err(Error::Plan(PlanError::MalformedConfig {
            reason: format!("device count must be a nonzero power of two, got {devices}"),
        }));
    }
    let k = devices.trailing_zeros() as usize;
    let cfg = topo.to_sim_config();
    let (plan, program, chosen): (Plan, LoweredProgram, &'static str) = match strategy {
        PlanFamily::Soybean => {
            let tp = try_plan_topology_aware(&g, devices, topo)?;
            let program = try_lower(&g, &tp.plan, &cfg)?;
            (tp.plan, program, tp.chosen)
        }
        // The DP baseline prices gradient aggregation in its classic
        // all-reduce form, so the matching forced lowering keeps the
        // meter identity the executor insists on.
        PlanFamily::DataParallel => {
            let plan = baselines::data_parallel(&g, k);
            let program = try_lower_forced(&g, &plan, &cfg, &classic_dp_form)?;
            (plan, program, "data-parallel")
        }
        PlanFamily::ModelParallel => {
            let plan = baselines::model_parallel(&g, k);
            let program = try_lower(&g, &plan, &cfg)?;
            (plan, program, "model-parallel")
        }
    };
    let ctx = Arc::new(StepCtx::try_new(g, plan, program, exec)?);
    Ok((ctx, chosen))
}

/// A planned, lowered, validated model execution — the unified entry
/// point over planner, lowering, simulator and executor.
///
/// # Examples
///
/// Plan once, execute, and check against the serial interpreter:
///
/// ```
/// use soybean::graph::{eval_serial, max_rel_err, seed_values};
/// use soybean::models::{mlp, MlpConfig};
/// use soybean::sim::Topology;
/// use soybean::Session;
///
/// let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
/// let session = Session::build(g.clone(), 4, &Topology::p2_8xlarge()).unwrap();
/// assert_eq!(session.devices(), 4);
///
/// let init = seed_values(&g, 7);
/// let report = session.execute(&init).unwrap();
/// // One-theory contract: observed collective traffic == Theorem-1 cost.
/// assert_eq!(report.instr_bytes, session.plan().total_cost());
/// // And the numbers match the serial interpreter.
/// let serial = eval_serial(&g, &init).unwrap();
/// for t in &g.tensors {
///     assert!(max_rel_err(&report.tensors[t.id], &serial[t.id]) <= 1e-5);
/// }
/// println!("{}", session.plan_summary());
/// ```
pub struct Session {
    ctx: Arc<StepCtx>,
    topo: Topology,
    strategy: PlanFamily,
    chosen: &'static str,
    /// The generalized execution strategy the session runs: today always
    /// [`Strategy::single_stage`] over the chosen plan (serving steps
    /// are single-stage), kept here so every consumer of the session —
    /// summaries, stats, future pipelined serving — speaks the
    /// stage-aware vocabulary.
    strat: Strategy,
}

impl Session {
    /// Plan `g` for `devices` on `topo` (topology-aware SOYBEAN
    /// portfolio), lower it, and validate the result. `devices` must be
    /// a nonzero power of two.
    pub fn build(g: Graph, devices: usize, topo: &Topology) -> Result<Session, Error> {
        Session::with_strategy(g, devices, topo, PlanFamily::Soybean)
    }

    /// [`Session::build`] under an explicit strategy — the baselines the
    /// figures compare against ([`PlanFamily::DataParallel`] keeps the
    /// classic gradient-aggregation form so its byte meter stays honest).
    pub fn with_strategy(
        g: Graph,
        devices: usize,
        topo: &Topology,
        strategy: PlanFamily,
    ) -> Result<Session, Error> {
        let (ctx, chosen) = build_ctx(g, devices, topo, strategy, ExecOptions::default())?;
        let strat = Strategy::single_stage(ctx.graph(), ctx.plan().clone());
        Ok(Session { ctx, topo: topo.clone(), strategy, chosen, strat })
    }

    /// Replace the execution options (watchdog deadline, fault plan) the
    /// session executes under.
    #[must_use]
    pub fn with_exec_options(mut self, exec: ExecOptions) -> Session {
        // The context is immutable and possibly shared; re-validate is
        // unnecessary (options don't affect admission), so rebuild the
        // Arc with the same artifacts.
        let old = &*self.ctx;
        let ctx = StepCtx {
            g: old.g.clone(),
            plan: old.plan.clone(),
            program: old.program.clone(),
            tasks: old.tasks.clone(),
            opts: exec,
        };
        self.ctx = Arc::new(ctx);
        self
    }

    /// Execute one step on real tensors: `init` is the producerless-
    /// tensor value vector ([`crate::graph::seed_values`] shapes it).
    ///
    /// Spawns a transient worker pool per call — convenient for tests
    /// and one-shot runs. For sustained traffic, hand the session to a
    /// [`super::ServeEngine`], which keeps the workers warm.
    pub fn execute(&self, init: &[Option<Vec<f32>>]) -> Result<ExecReport, Error> {
        let mut pool = WorkerPool::spawn(self.devices());
        pool.run_step(&self.ctx, init).map_err(Error::from)
    }

    /// Execute one step on an existing warm [`WorkerPool`] (its device
    /// count must match the session's).
    pub fn execute_on(
        &self,
        pool: &mut WorkerPool,
        init: &[Option<Vec<f32>>],
    ) -> Result<ExecReport, Error> {
        pool.run_step(&self.ctx, init).map_err(Error::from)
    }

    /// Model the step under the closed-form simulator on the session's
    /// topology-derived cost config.
    pub fn simulate(&self) -> Result<SimReport, Error> {
        let cfg = self.topo.to_sim_config();
        let report = match self.strategy {
            PlanFamily::DataParallel => {
                try_simulate_forced(self.graph(), self.plan(), &cfg, &classic_dp_form)?
            }
            _ => try_simulate(self.graph(), self.plan(), &cfg)?,
        };
        Ok(report)
    }

    /// Profile one step: execute it with span tracing on, schedule the
    /// same program through the discrete-event engine, and join the two
    /// into a [`CalibrationReport`](crate::obs::CalibrationReport) — the
    /// measured-vs-modeled drift of every kernel and collective.
    ///
    /// The session's own execution options are respected (deadline,
    /// metrics handle); only the trace flag is forced on, for this call.
    pub fn profile(&self, init: &[Option<Vec<f32>>]) -> Result<ProfileReport, Error> {
        let old = &*self.ctx;
        let traced = Arc::new(StepCtx {
            g: old.g.clone(),
            plan: old.plan.clone(),
            program: old.program.clone(),
            tasks: old.tasks.clone(),
            opts: old.opts.clone().trace(true),
        });
        let mut pool = WorkerPool::spawn(self.devices());
        let exec = pool.run_step(&traced, init)?;
        let modeled = try_run_program(self.program(), &self.topo)?;
        let trace = exec.trace.as_ref().expect("profile ran with tracing on");
        let calibration = calibrate(self.graph(), self.program(), &self.topo, &modeled, trace);
        Ok(ProfileReport { exec, modeled, calibration })
    }

    /// A compact, printable description of what was planned.
    pub fn plan_summary(&self) -> PlanSummary {
        let plan = self.plan();
        PlanSummary {
            devices: plan.devices(),
            k: plan.k,
            stages: self.strat.stage_count(),
            chosen: self.chosen,
            kind: classify(self.graph(), &plan.tiles),
            total_bytes: plan.total_cost(),
            cut_costs: plan.cut_costs.clone(),
            ops: self.graph().ops.len(),
            tensors: self.graph().tensors.len(),
        }
    }

    /// The graph the session plans and executes.
    pub fn graph(&self) -> &Graph {
        self.ctx.graph()
    }

    /// The chosen tiling plan.
    pub fn plan(&self) -> &Plan {
        self.ctx.plan()
    }

    /// The lowered per-device program.
    pub fn program(&self) -> &LoweredProgram {
        self.ctx.program()
    }

    /// Device count (`2^k`).
    pub fn devices(&self) -> usize {
        self.ctx.devices()
    }

    /// The interconnect the session planned for.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The validated, dispatch-ready step context — share it with a
    /// [`WorkerPool`] to run the session's program on warm workers.
    pub fn step_ctx(&self) -> &Arc<StepCtx> {
        &self.ctx
    }

    /// Which planning candidate won (`"flat-bytes"`, `"weighted-dp"`,
    /// or a baseline name).
    pub fn chosen_candidate(&self) -> &'static str {
        self.chosen
    }

    /// The strategy the session was built under.
    pub fn strategy(&self) -> PlanFamily {
        self.strategy
    }

    /// The generalized execution strategy (stages × tiling). Serving
    /// sessions are single-stage today, so this is always the
    /// [`Strategy::single_stage`] wrapper of [`Session::plan`] — the
    /// stage-aware view pipelined serving will generalize.
    pub fn execution_strategy(&self) -> &Strategy {
        &self.strat
    }
}

/// What [`Session::plan_summary`] reports — every load-bearing fact
/// about the chosen plan, with a one-screen [`fmt::Display`].
#[derive(Debug, Clone)]
pub struct PlanSummary {
    /// Device count (`2^k`).
    pub devices: usize,
    /// Cut count.
    pub k: usize,
    /// Pipeline stages of the execution strategy (1 for every serving
    /// session today).
    pub stages: usize,
    /// Winning planner candidate ([`Session::chosen_candidate`]).
    pub chosen: &'static str,
    /// Plan classification: `"data-parallel"`, `"model-parallel"`, or
    /// `"hybrid"` ([`crate::planner::classify`]).
    pub kind: &'static str,
    /// Theorem-1 total conversion bytes.
    pub total_bytes: u64,
    /// Per-cut δ costs (Theorem 1 weights them `2^(k-i)`).
    pub cut_costs: Vec<u64>,
    /// Op count of the planned graph.
    pub ops: usize,
    /// Tensor count of the planned graph.
    pub tensors: usize,
}

impl fmt::Display for PlanSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "plan: {} devices (k={}), {} stage{}, candidate {} ({}), graph {} ops / {} tensors",
            self.devices,
            self.k,
            self.stages,
            if self.stages == 1 { "" } else { "s" },
            self.chosen,
            self.kind,
            self.ops,
            self.tensors
        )?;
        write!(f, "cost: {} B total, per-cut δ {:?}", self.total_bytes, self.cut_costs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mlp, MlpConfig};

    fn small() -> Graph {
        mlp(&MlpConfig { batch: 8, dims: vec![6, 10, 4], bias: true })
    }

    #[test]
    fn build_rejects_non_power_of_two_devices() {
        let topo = Topology::p2_8xlarge();
        for devices in [0, 3, 6] {
            match Session::build(small(), devices, &topo) {
                Err(Error::Plan(PlanError::MalformedConfig { reason })) => {
                    assert!(reason.contains("power of two"), "{reason}");
                }
                other => panic!("expected MalformedConfig, got {:?}", other.map(|_| ())),
            }
        }
    }

    #[test]
    fn summary_names_the_plan() {
        let s = Session::build(small(), 4, &Topology::p2_8xlarge()).unwrap();
        let sum = s.plan_summary();
        assert_eq!(sum.devices, 4);
        assert_eq!(sum.k, 2);
        assert_eq!(sum.stages, 1);
        assert_eq!(sum.total_bytes, s.plan().total_cost());
        let shown = sum.to_string();
        assert!(shown.contains("4 devices"), "{shown}");
        assert!(shown.contains("1 stage,"), "{shown}");
        assert!(shown.contains("B total"), "{shown}");
        // The session's execution strategy is the degenerate wrapper of
        // its plan — bit-identical cost.
        let strat = s.execution_strategy();
        assert!(strat.is_single_stage());
        assert_eq!(strat.total_cost(), s.plan().total_cost());
    }

    #[test]
    fn strategies_yield_distinct_plans_and_honest_meters() {
        use crate::graph::seed_values;
        let topo = Topology::p2_8xlarge();
        for strategy in PlanFamily::all() {
            let s = Session::with_strategy(small(), 2, &topo, strategy).unwrap();
            let init = seed_values(s.graph(), 3);
            let r = s.execute(&init).unwrap();
            assert_eq!(
                r.instr_bytes,
                s.plan().total_cost(),
                "meter identity broke under {}",
                strategy.name()
            );
        }
    }

    #[test]
    fn simulate_reports_the_modeled_step() {
        let s = Session::build(small(), 4, &Topology::p2_8xlarge()).unwrap();
        let sim = s.simulate().unwrap();
        assert_eq!(sim.devices, 4);
        assert!(sim.step_s > 0.0);
    }

    #[test]
    fn profile_joins_measured_and_modeled() {
        use crate::graph::seed_values;
        let s = Session::build(small(), 4, &Topology::p2_8xlarge()).unwrap();
        let init = seed_values(s.graph(), 5);
        let p = s.profile(&init).unwrap();
        assert_eq!(p.calibration.devices, 4);
        // The trace's metered markers reconcile with the Theorem-1 total.
        assert_eq!(p.calibration.metered_span_bytes, s.plan().total_cost());
        assert!(p.exec.trace.is_some());
        assert!(p.modeled.step_s > 0.0);
        // Profiling forces tracing only for its own step: the session's
        // options are untouched, so a later execute stays untraced.
        let r = s.execute(&init).unwrap();
        assert!(r.trace.is_none());
    }
}
