//! Deterministic synthetic classification data.
//!
//! Gaussian class clusters with unit-scale separation: easy enough that a
//! small MLP's loss visibly falls within a few hundred SGD steps, hard
//! enough that it cannot be solved by the bias alone.

use crate::planner::PlanError;
use crate::runtime::HostTensor;
use crate::util::Rng;

/// Deterministic synthetic classification stream: class-conditional
/// Gaussian clusters, reproducible from the seed.
pub struct SyntheticData {
    /// Input feature dimension.
    pub din: usize,
    /// Number of classes (one cluster mean each).
    pub classes: usize,
    means: Vec<Vec<f32>>,
    rng: Rng,
}

impl SyntheticData {
    /// New stream with `classes` cluster means drawn from `seed`.
    /// Panics on a degenerate configuration; [`Self::try_new`] reports it
    /// as a structured error instead.
    pub fn new(seed: u64, din: usize, classes: usize) -> Self {
        Self::try_new(seed, din, classes)
            .unwrap_or_else(|e| panic!("synthetic data config rejected: {e}"))
    }

    /// [`Self::new`] with structured errors: a stream with zero classes
    /// cannot draw labels (it used to panic inside the RNG on the first
    /// batch) and zero input features make every cluster mean identical —
    /// both are [`PlanError::MalformedConfig`], caught at construction
    /// rather than mid-training.
    pub fn try_new(seed: u64, din: usize, classes: usize) -> Result<Self, PlanError> {
        if classes == 0 {
            return Err(PlanError::MalformedConfig {
                reason: "synthetic data needs at least one class".into(),
            });
        }
        if din == 0 {
            return Err(PlanError::MalformedConfig {
                reason: "synthetic data needs at least one input feature".into(),
            });
        }
        let mut rng = Rng::new(seed);
        let means = (0..classes).map(|_| rng.normal_vec(din, 1.2)).collect();
        Ok(SyntheticData { din, classes, means, rng })
    }

    /// Next batch: `x [batch, din]`, one-hot `y [batch, classes]`.
    pub fn batch(&mut self, batch: usize) -> (HostTensor, HostTensor) {
        let mut x = Vec::with_capacity(batch * self.din);
        let mut y = vec![0.0f32; batch * self.classes];
        for i in 0..batch {
            let c = self.rng.below(self.classes);
            for j in 0..self.din {
                x.push(self.means[c][j] + self.rng.normal() as f32 * 0.6);
            }
            y[i * self.classes + c] = 1.0;
        }
        (
            HostTensor::from_vec(&[batch, self.din], x),
            HostTensor::from_vec(&[batch, self.classes], y),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let mut a = SyntheticData::new(1, 8, 4);
        let mut b = SyntheticData::new(1, 8, 4);
        let (xa, ya) = a.batch(16);
        let (xb, yb) = b.batch(16);
        assert_eq!(xa, xb);
        assert_eq!(ya, yb);
    }

    #[test]
    fn degenerate_configs_are_structured_errors() {
        match SyntheticData::try_new(1, 8, 0) {
            Err(PlanError::MalformedConfig { reason }) => assert!(reason.contains("class")),
            other => panic!("expected MalformedConfig, got {:?}", other.map(|_| ())),
        }
        match SyntheticData::try_new(1, 0, 4) {
            Err(PlanError::MalformedConfig { reason }) => assert!(reason.contains("feature")),
            other => panic!("expected MalformedConfig, got {:?}", other.map(|_| ())),
        }
        assert!(SyntheticData::try_new(1, 8, 4).is_ok());
    }

    #[test]
    fn onehot_rows_sum_to_one() {
        let mut d = SyntheticData::new(2, 8, 4);
        let (_, y) = d.batch(32);
        for i in 0..32 {
            let s: f32 = y.data[i * 4..(i + 1) * 4].iter().sum();
            assert_eq!(s, 1.0);
        }
    }
}
