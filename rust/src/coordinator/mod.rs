//! The training coordinator: BSP batch loop, parameter state, loss logging.
//!
//! Two interchangeable trainers close the loop end to end:
//!
//! - [`SerialTrainer`] — drives the AOT `mlp_step*` artifact (the whole
//!   training step as one PJRT executable, exactly what `python/compile`
//!   lowered). The correctness anchor.
//! - [`ParallelTrainer`] — drives the [`crate::runtime::Engine`] under a
//!   tiling plan: same numbers, distributed across virtual devices.
//!
//! [`SyntheticData`] supplies a deterministic separable classification
//! task so loss curves are meaningful.

mod data;
// The trainers drive the PJRT engine — gated with it (`pjrt` feature).
#[cfg(feature = "pjrt")]
mod trainer;

pub use data::SyntheticData;
#[cfg(feature = "pjrt")]
pub use trainer::{init_mlp_params, ParallelTrainer, SerialTrainer};
