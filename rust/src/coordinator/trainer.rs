//! Serial (AOT artifact) and parallel (engine) trainers.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::graph::{Graph, TensorId, TensorKind};
use crate::planner::Plan;
use crate::runtime::{ArtifactRegistry, Client, Engine, Executable, HostTensor};
use crate::util::Rng;

/// He-initialized MLP parameters (matches `python/compile/model.init_mlp`
/// in distribution, not in exact values — tests feed identical tensors to
/// both paths instead of relying on matching RNGs).
pub fn init_mlp_params(seed: u64, dims: &[usize]) -> Vec<HostTensor> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for (din, dout) in dims.iter().zip(&dims[1..]) {
        let scale = (2.0 / *din as f64).sqrt() as f32;
        out.push(HostTensor::from_vec(&[*din, *dout], rng.normal_vec(din * dout, scale)));
        out.push(HostTensor::zeros(&[*dout]));
    }
    out
}

/// Drives a whole-training-step AOT artifact (`mlp_step` family):
/// inputs `(x, y, lr, w0, b0, …)`, outputs `(loss, w0', b0', …)`.
pub struct SerialTrainer {
    exe: Arc<Executable>,
    /// Current parameter values, replaced after every step.
    pub params: Vec<HostTensor>,
    /// SGD learning rate.
    pub lr: f32,
}

impl SerialTrainer {
    /// Bind a registered AOT artifact to initial parameters.
    pub fn from_artifact(
        client: &Client,
        reg: &ArtifactRegistry,
        name: &str,
        params: Vec<HostTensor>,
        lr: f32,
    ) -> Result<Self> {
        let meta = reg.meta(name).ok_or_else(|| anyhow!("no artifact {name}"))?;
        anyhow::ensure!(
            meta.input_shapes.len() == 3 + params.len(),
            "artifact {name} expects {} params, got {}",
            meta.input_shapes.len() - 3,
            params.len()
        );
        Ok(SerialTrainer { exe: reg.get(client, name)?, params, lr })
    }

    /// One SGD step; returns the batch loss.
    pub fn step(&mut self, x: &HostTensor, y: &HostTensor) -> Result<f32> {
        let mut inputs = vec![x.clone(), y.clone(), HostTensor::scalar(self.lr)];
        inputs.extend(self.params.iter().cloned());
        let outs = self.exe.run(&inputs)?;
        let loss = outs[0].data[0];
        self.params = outs[1..].to_vec();
        Ok(loss)
    }
}

/// Drives the parallel engine: same semantics as [`SerialTrainer`], with
/// the step distributed across the plan's virtual devices.
pub struct ParallelTrainer {
    /// The underlying multi-device execution engine.
    pub engine: Engine,
    x_id: TensorId,
    y_id: TensorId,
    weight_ids: Vec<TensorId>,
}

impl ParallelTrainer {
    /// `params` must follow the graph's weight-declaration order (the
    /// builder interleaves `w0, b0, w1, b1, …`, matching the artifacts).
    pub fn new(
        client: Arc<Client>,
        g: Graph,
        plan: Plan,
        params: &[HostTensor],
        lr: f32,
    ) -> Result<Self> {
        let x_id = g
            .tensors
            .iter()
            .find(|t| t.kind == TensorKind::Input)
            .ok_or_else(|| anyhow!("no input tensor"))?
            .id;
        let y_id = g
            .tensors
            .iter()
            .find(|t| t.kind == TensorKind::Label)
            .ok_or_else(|| anyhow!("no label tensor"))?
            .id;
        let weight_ids: Vec<TensorId> = g
            .tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.id)
            .collect();
        anyhow::ensure!(
            weight_ids.len() == params.len(),
            "graph has {} parameters, got {}",
            weight_ids.len(),
            params.len()
        );
        let mut engine = Engine::new(client, g, plan, lr)?;
        for (&id, p) in weight_ids.iter().zip(params) {
            engine.load(id, p);
        }
        Ok(ParallelTrainer { engine, x_id, y_id, weight_ids })
    }

    /// One SGD step; returns the batch loss.
    pub fn step(&mut self, x: &HostTensor, y: &HostTensor) -> Result<f32> {
        self.engine.load(self.x_id, x);
        self.engine.load(self.y_id, y);
        self.engine.step()
    }

    /// Current parameter values, reassembled from shards.
    pub fn params(&self) -> Vec<HostTensor> {
        self.weight_ids.iter().map(|&id| self.engine.fetch(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SyntheticData;
    use crate::models::{mlp, MlpConfig};
    use crate::planner::{Planner, PlanFamily};

    fn client() -> Arc<Client> {
        Arc::new(Client::cpu().expect("PJRT CPU client"))
    }

    fn artifacts() -> ArtifactRegistry {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactRegistry::load(&dir).expect("run `make artifacts` first")
    }

    const SMALL_DIMS: [usize; 4] = [64, 128, 128, 10];

    #[test]
    fn serial_artifact_loss_decreases() {
        let c = client();
        let reg = artifacts();
        let params = init_mlp_params(7, &SMALL_DIMS);
        let mut t = SerialTrainer::from_artifact(&c, &reg, "mlp_step_small", params, 0.1).unwrap();
        let mut data = SyntheticData::new(3, 64, 10);
        let (x, y) = data.batch(32);
        let first = t.step(&x, &y).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = t.step(&x, &y).unwrap();
        }
        assert!(last < first * 0.5, "loss did not fall: {first} -> {last}");
    }

    #[test]
    fn pallas_artifact_matches_jnp_artifact() {
        // The Pallas-kernel step and the plain-jnp step must be numerically
        // interchangeable — L1 composing into L2, checked from L3.
        let c = client();
        let reg = artifacts();
        let params = init_mlp_params(11, &SMALL_DIMS);
        let mut a =
            SerialTrainer::from_artifact(&c, &reg, "mlp_step_small", params.clone(), 0.05).unwrap();
        let mut b =
            SerialTrainer::from_artifact(&c, &reg, "mlp_step_small_pallas", params, 0.05).unwrap();
        let mut data = SyntheticData::new(5, 64, 10);
        let (x, y) = data.batch(32);
        for s in 0..3 {
            let la = a.step(&x, &y).unwrap();
            let lb = b.step(&x, &y).unwrap();
            assert!((la - lb).abs() < 1e-4, "step {s}: {la} vs {lb}");
        }
        for (pa, pb) in a.params.iter().zip(&b.params) {
            assert!(pa.max_abs_diff(pb) < 1e-3);
        }
    }

    #[test]
    fn parallel_engine_matches_serial_artifact() {
        // THE systems test: one training step through the multi-device
        // engine equals the serial AOT step, for every strategy.
        let c = client();
        let reg = artifacts();
        let cfg = MlpConfig { batch: 32, dims: SMALL_DIMS.to_vec(), bias: true };
        let mut data = SyntheticData::new(9, 64, 10);
        let (x, y) = data.batch(32);

        for (strategy, k) in [
            (PlanFamily::DataParallel, 1),
            (PlanFamily::DataParallel, 2),
            (PlanFamily::ModelParallel, 1),
            (PlanFamily::Soybean, 2),
        ] {
            let params = init_mlp_params(13, &SMALL_DIMS);
            let mut serial =
                SerialTrainer::from_artifact(&c, &reg, "mlp_step_small", params.clone(), 0.05)
                    .unwrap();
            let g = mlp(&cfg);
            let plan = Planner::try_plan(&g, k, strategy).unwrap();
            let mut par = ParallelTrainer::new(c.clone(), g, plan, &params, 0.05).unwrap();

            for s in 0..3 {
                let ls = serial.step(&x, &y).unwrap();
                let lp = par.step(&x, &y).unwrap();
                assert!(
                    (ls - lp).abs() < 2e-3,
                    "{} k={k} step {s}: serial {ls} vs parallel {lp}",
                    strategy.name()
                );
            }
            for (ps, pp) in serial.params.iter().zip(par.params()) {
                assert!(
                    ps.max_abs_diff(&pp) < 5e-3,
                    "{} k={k}: params diverged",
                    strategy.name()
                );
            }
        }
    }

    #[test]
    fn engine_meters_traffic() {
        let c = client();
        let cfg = MlpConfig { batch: 32, dims: SMALL_DIMS.to_vec(), bias: true };
        let g = mlp(&cfg);
        let plan = Planner::try_plan(&g, 2, PlanFamily::DataParallel).unwrap();
        let params = init_mlp_params(17, &SMALL_DIMS);
        let mut par = ParallelTrainer::new(c, g, plan, &params, 0.05).unwrap();
        let mut data = SyntheticData::new(21, 64, 10);
        let (x, y) = data.batch(32);
        par.step(&x, &y).unwrap();
        // DP must move gradient bytes across both tiers.
        assert!(par.engine.metrics.total_bytes() > 0);
        assert!(par.engine.metrics.kernel_launches > 0);
    }
}
