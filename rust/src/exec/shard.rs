//! Per-operator shard schedules: the concrete realization of a plan.
//!
//! For every operator and every cut, an aligned form is selected (Eq. 2)
//! and its requirements stacked: each operand gets a *required* `TileSeq`
//! (the ghost layout gathered in §5.2's phase 1) and the output a
//! *produced* `TileSeq` plus the cuts at which it is produced `red`
//! (phase 3's extra reduction).
//!
//! One subtlety the paper leaves implicit: the cost model checks a form's
//! feasibility against *resident*-halved shapes, but the realized ghost
//! layout halves along the *form's* dimensions — stacking the model's
//! choices can demand an odd split (e.g. `C` twice on a 10-wide logits
//! matrix whose residents split by batch). The schedule therefore selects
//! each cut's form against the op's **stacked local shapes**, so the
//! composition is realizable by construction; when that differs from the
//! model's pick the realized traffic can deviate slightly from the priced
//! cost (documented in DESIGN.md).

use crate::graph::{Graph, OpId};
use crate::planner::{Plan, PlanError};
use crate::tiling::{form_requirements, op_cost_detailed, Produced, Tile, TileSeq};

/// The realized schedule of one operator under a plan.
#[derive(Debug, Clone)]
pub struct ShardTask {
    /// The op this schedule realizes.
    pub op: OpId,
    /// Per input (same order as `op.inputs`): the layout the ghost gather
    /// must produce before local execution.
    pub required_ins: Vec<TileSeq>,
    /// The layout local execution produces (`Red` cuts recorded separately;
    /// the tile here is `Rep` at those cuts, i.e. full-extent partials).
    pub produced: TileSeq,
    /// Cuts at which the output is a partial sum needing reduction across
    /// the paired groups.
    pub reduce_cuts: Vec<usize>,
}

/// Build the shard schedule for every op in `g` under `plan`.
///
/// Panics if the plan admits no feasible form at some cut (the planner
/// never produces such plans; hand-written ones might) — see
/// [`try_build_shard_tasks`] for the error-returning variant.
pub fn build_shard_tasks(g: &Graph, plan: &Plan) -> Vec<ShardTask> {
    try_build_shard_tasks(g, plan).unwrap_or_else(|e| panic!("shard schedule failed: {e}"))
}

/// Like [`build_shard_tasks`] but returning the structured
/// [`PlanError::NoFeasibleForm`] when a plan admits no aligned form for
/// some op at some cut, so embedding callers (services, sweeps over
/// hand-written plans) can degrade gracefully instead of unwinding.
pub fn try_build_shard_tasks(g: &Graph, plan: &Plan) -> Result<Vec<ShardTask>, PlanError> {
    crate::planner::validate_plan(g, plan)?;
    let k = plan.k;
    g.ops
        .iter()
        .map(|op| {
            let mut required_ins: Vec<TileSeq> = vec![Vec::with_capacity(k); op.inputs.len()];
            let mut produced: TileSeq = Vec::with_capacity(k);
            let mut reduce_cuts = Vec::new();

            // The op's *local* scratch graph: shapes follow the stacked
            // form requirements, so feasibility checks match realization.
            let mut local = g.clone();

            for i in 0..k {
                let ins: Vec<Tile> = op.inputs.iter().map(|&t| plan.tiles[t][i]).collect();
                let out = plan.tiles[op.outputs[0]][i];
                let bd = op_cost_detailed(&local, op, &ins, out)
                    .ok_or_else(|| PlanError::NoFeasibleForm { op: op.name.clone(), cut: i })?;
                let (reqs, prod) = form_requirements(&local, op, bd.form);
                // Stack requirements + halve the local shapes accordingly.
                for (slot, r) in reqs.into_iter().enumerate() {
                    required_ins[slot].push(r);
                    if let Tile::Split(d) = r {
                        local.tensors[op.inputs[slot]].shape[d] /= 2;
                    }
                }
                match prod {
                    Produced::Tile(t) => {
                        produced.push(t);
                        if let Tile::Split(d) = t {
                            local.tensors[op.outputs[0]].shape[d] /= 2;
                        }
                    }
                    Produced::Red => {
                        produced.push(Tile::Rep);
                        reduce_cuts.push(i);
                    }
                }
            }
            Ok(ShardTask { op: op.id, required_ins, produced, reduce_cuts })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mlp, MlpConfig};
    use crate::planner::{baselines, Planner, PlanFamily};
    use crate::tiling::Tile;

    #[test]
    fn dp_schedule_shape() {
        let g = mlp(&MlpConfig { batch: 64, dims: vec![32, 32, 32], bias: false });
        let plan = baselines::data_parallel(&g, 2);
        let tasks = build_shard_tasks(&g, &plan);
        assert_eq!(tasks.len(), g.ops.len());
        for task in &tasks {
            let op = &g.ops[task.op];
            match op.kind {
                crate::graph::OpKind::MatMul { ta: true, tb: false } => {
                    // dW = xᵀ·dz: under DP the output reduces at every cut.
                    assert_eq!(task.reduce_cuts, vec![0, 1], "op {}", op.name);
                }
                crate::graph::OpKind::MatMul { ta: false, .. } => {
                    // Forward & bwd-data matmuls: batch-split, no reduction.
                    assert!(task.reduce_cuts.is_empty(), "op {}", op.name);
                    assert_eq!(task.required_ins[0], vec![Tile::Split(0); 2]);
                    assert_eq!(task.required_ins[1], vec![Tile::Rep; 2]);
                }
                _ => {}
            }
        }
    }

    /// Every required layout must be realizable: stacked splits always hit
    /// even dimensions.
    fn assert_realizable(g: &Graph, tasks: &[ShardTask]) {
        for task in tasks {
            let op = &g.ops[task.op];
            for (slot, seq) in task.required_ins.iter().enumerate() {
                let mut shape = g.tensors[op.inputs[slot]].shape.clone();
                for t in seq {
                    if let Tile::Split(d) = t {
                        assert!(shape[*d] % 2 == 0, "op {} input {slot} seq {seq:?}", op.name);
                        shape[*d] /= 2;
                    }
                }
            }
            let mut shape = g.tensors[op.outputs[0]].shape.clone();
            for t in &task.produced {
                if let Tile::Split(d) = t {
                    assert!(shape[*d] % 2 == 0, "op {} output", op.name);
                    shape[*d] /= 2;
                }
            }
        }
    }

    #[test]
    fn soybean_schedule_feasible_on_models() {
        for g in [
            mlp(&MlpConfig::fig8(512, 64)),
            mlp(&MlpConfig::e2e()),
            crate::models::cnn5(16, 6, 4, 32, 10),
        ] {
            let plan = Planner::try_plan(&g, 2, PlanFamily::Soybean).unwrap();
            let tasks = build_shard_tasks(&g, &plan);
            assert_eq!(tasks.len(), g.ops.len());
            assert_realizable(&g, &tasks);
        }
    }

    #[test]
    fn stacked_layouts_realizable_even_with_narrow_dims() {
        // The regression behind the stacked-shape selection: a 10-class
        // head under 2+ cuts must not stack two column splits.
        let g = mlp(&MlpConfig { batch: 32, dims: vec![64, 128, 128, 10], bias: true });
        for (strat, k) in [
            (PlanFamily::DataParallel, 2),
            (PlanFamily::ModelParallel, 1),
            (PlanFamily::Soybean, 2),
            (PlanFamily::Soybean, 3),
        ] {
            let plan = Planner::try_plan(&g, k, strat).unwrap();
            let tasks = build_shard_tasks(&g, &plan);
            assert_realizable(&g, &tasks);
        }
    }

    #[test]
    fn infeasible_plan_returns_structured_error() {
        // A hand-written plan over a graph with no realizable form: the
        // builder reports PlanError::NoFeasibleForm instead of panicking.
        let mut b = crate::graph::GraphBuilder::new();
        let x = b.input("x", &[3, 5]);
        let w = b.weight("w", &[5, 7]);
        b.matmul("odd", x, w, false, false);
        let g = b.finish();
        let plan = Plan {
            k: 1,
            tiles: vec![vec![Tile::Rep]; g.tensors.len()],
            cut_costs: vec![0],
        };
        let err = try_build_shard_tasks(&g, &plan).unwrap_err();
        match err {
            crate::planner::PlanError::NoFeasibleForm { ref op, cut } => {
                assert_eq!(op, "odd");
                assert_eq!(cut, 0);
            }
            other => panic!("expected NoFeasibleForm, got {other:?}"),
        }
        assert!(format!("{err}").contains("odd"));
    }

    #[test]
    fn transformer_plans_materialize() {
        // The §5 execution-graph construction covers the new op set.
        let g = crate::models::transformer(&crate::models::TransformerConfig::tiny());
        for k in 0..=2 {
            let plan = Planner::try_plan(&g, k, PlanFamily::Soybean).unwrap();
            let tasks = build_shard_tasks(&g, &plan);
            assert_eq!(tasks.len(), g.ops.len());
            assert_realizable(&g, &tasks);
        }
    }

    #[test]
    fn required_layouts_have_k_entries() {
        let g = mlp(&MlpConfig { batch: 16, dims: vec![8, 8], bias: true });
        let plan = Planner::try_plan(&g, 3, PlanFamily::Soybean).unwrap();
        for task in build_shard_tasks(&g, &plan) {
            assert_eq!(task.produced.len(), 3);
            for r in &task.required_ins {
                assert_eq!(r.len(), 3);
            }
        }
    }
}
