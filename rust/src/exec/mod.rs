//! Execution-graph construction (paper §5).
//!
//! A k-cut plan assigns every tensor a [`crate::tiling::TileSeq`]; this
//! module turns that into concrete *shards*: which axis-aligned region of
//! each tensor lives on which device ([`region`]), which ghost regions an
//! operator must gather before it can run, where each missing cell is
//! fetched from ([`gather`]), and how device ids map onto the interconnect
//! hierarchy ([`placement`], §5.1).
//!
//! The same machinery serves two consumers:
//! - the **simulator** ([`crate::sim`]) reads per-link byte volumes;
//! - the **real engine** ([`crate::runtime::engine`]) moves actual f32
//!   buffers between worker threads along exactly these edges.

mod gather;
mod placement;
mod region;
mod shard;

pub use gather::{gather_sources, remote_bytes, SourcePiece};
pub use placement::{cut_of_pair, group_peers, Placement};
pub use region::{cut_bit, resident_region, Region};
pub use shard::{build_shard_tasks, try_build_shard_tasks, ShardTask};
