//! Axis-aligned shard regions under recursive bisection.
//!
//! A `TileSeq` applied to a tensor partitions it into a grid of equal tiles
//! (Theorem 2); each device's *resident region* is determined by reading
//! the device id as a bit string, one bit per cut — bit `k-1-i` selects the
//! half taken at cut `i`, so that the first (outermost, slowest-link) cut
//! splits device ids into two contiguous ranges, matching §5.1's placement.

use crate::tiling::{Tile, TileSeq};

/// An axis-aligned box within a tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    /// Lower corner, one entry per tensor dimension.
    pub offset: Vec<usize>,
    /// Extent along each dimension.
    pub shape: Vec<usize>,
}

impl Region {
    /// The whole tensor.
    pub fn full(shape: &[usize]) -> Self {
        Region { offset: vec![0; shape.len()], shape: shape.to_vec() }
    }

    /// Element count of the box.
    pub fn elements(&self) -> u64 {
        self.shape.iter().map(|&d| d as u64).product()
    }

    /// Whether any extent is zero.
    pub fn is_empty(&self) -> bool {
        self.shape.iter().any(|&d| d == 0)
    }

    /// Intersection of two boxes (empty-shaped region if disjoint).
    pub fn intersect(&self, other: &Region) -> Region {
        let rank = self.offset.len();
        let mut offset = Vec::with_capacity(rank);
        let mut shape = Vec::with_capacity(rank);
        for d in 0..rank {
            let lo = self.offset[d].max(other.offset[d]);
            let hi = (self.offset[d] + self.shape[d]).min(other.offset[d] + other.shape[d]);
            offset.push(lo);
            shape.push(hi.saturating_sub(lo));
        }
        Region { offset, shape }
    }

    /// Whether `other` is entirely inside `self`.
    pub fn contains(&self, other: &Region) -> bool {
        self.intersect(other) == *other
    }
}

/// The bit of device id `d` that drives cut `i` (of `k`): the outermost cut
/// uses the most-significant bit so groups are contiguous id ranges.
pub fn cut_bit(d: usize, i: usize, k: usize) -> usize {
    (d >> (k - 1 - i)) & 1
}

/// The resident region of a tensor of `shape` on device `d` under `seq`
/// (`seq.len() == k` cuts).
pub fn resident_region(shape: &[usize], seq: &TileSeq, d: usize) -> Region {
    let k = seq.len();
    let mut r = Region::full(shape);
    for (i, t) in seq.iter().enumerate() {
        if let Tile::Split(dim) = t {
            let half = r.shape[*dim] / 2;
            if cut_bit(d, i, k) == 1 {
                r.offset[*dim] += half;
            }
            r.shape[*dim] = half;
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::Tile;

    const R: Tile = Tile::Split(0);
    const C: Tile = Tile::Split(1);
    const REP: Tile = Tile::Rep;

    #[test]
    fn single_row_cut() {
        let top = resident_region(&[8, 4], &vec![R], 0);
        let bot = resident_region(&[8, 4], &vec![R], 1);
        assert_eq!(top, Region { offset: vec![0, 0], shape: vec![4, 4] });
        assert_eq!(bot, Region { offset: vec![4, 0], shape: vec![4, 4] });
    }

    #[test]
    fn rc_grid_four_devices() {
        // Figure 4(b) right: RC partitions into four blocks.
        let seq = vec![R, C];
        let shapes: Vec<Region> = (0..4).map(|d| resident_region(&[8, 8], &seq, d)).collect();
        assert_eq!(shapes[0].offset, vec![0, 0]);
        assert_eq!(shapes[1].offset, vec![0, 4]); // same row half, other col
        assert_eq!(shapes[2].offset, vec![4, 0]);
        assert_eq!(shapes[3].offset, vec![4, 4]);
        for s in &shapes {
            assert_eq!(s.shape, vec![4, 4]);
        }
    }

    #[test]
    fn replication_keeps_full() {
        for d in 0..4 {
            let r = resident_region(&[8, 8], &vec![REP, REP], d);
            assert_eq!(r, Region::full(&[8, 8]));
        }
    }

    #[test]
    fn hybrid_rr_quarters_rows() {
        // Figure 4(b) left: RR = four-way row tiling.
        let seq = vec![R, R];
        for d in 0..4 {
            let r = resident_region(&[8, 8], &seq, d);
            assert_eq!(r.offset, vec![2 * d, 0]);
            assert_eq!(r.shape, vec![2, 8]);
        }
    }

    #[test]
    fn shards_partition_split_tensor() {
        // Splits tile the tensor exactly: disjoint and covering.
        let seq = vec![R, C, R];
        let mut total = 0u64;
        for d in 0..8 {
            total += resident_region(&[8, 8], &seq, d).elements();
        }
        assert_eq!(total, 64);
    }

    #[test]
    fn intersect_and_contains() {
        let a = Region { offset: vec![0, 0], shape: vec![4, 4] };
        let b = Region { offset: vec![2, 2], shape: vec![4, 4] };
        let i = a.intersect(&b);
        assert_eq!(i, Region { offset: vec![2, 2], shape: vec![2, 2] });
        assert!(a.contains(&i));
        let disjoint = Region { offset: vec![6, 6], shape: vec![2, 2] };
        assert!(a.intersect(&disjoint).is_empty());
    }
}
