//! Ghost-region gathering: §5.2's three-step tiling conversion.
//!
//! When device `d` needs region `target` of a tensor whose resident layout
//! is `seq`, the flattening theorem lets us treat the resident shards as a
//! regular grid: the target box decomposes into grid cells, and every cell
//! is owned by at least one device (exactly one when the tensor is split,
//! all of them when replicated). Senders slice, receivers fetch and
//! concatenate — this function computes the slice list.

use crate::tiling::TileSeq;

use super::region::{resident_region, Region};

/// One piece of a gather: fetch `region` from `src` device. `src == self`
/// pieces are local copies (free).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SourcePiece {
    /// Device holding the piece.
    pub src: usize,
    /// The box to fetch, in tensor coordinates.
    pub region: Region,
}

/// Decompose `target` into pieces fetched from resident shards.
///
/// Preference order: the requesting device itself (local, free), then the
/// nearest peer by id distance — a stand-in for §5.1's "prefer the fastest
/// link", since nearby ids share the lower interconnect tiers.
pub fn gather_sources(
    shape: &[usize],
    seq: &TileSeq,
    devices: usize,
    me: usize,
    target: &Region,
) -> Vec<SourcePiece> {
    // Grid boundaries per axis from all residents.
    let rank = shape.len();
    let mut cuts: Vec<Vec<usize>> = vec![vec![]; rank];
    let residents: Vec<Region> = (0..devices).map(|d| resident_region(shape, seq, d)).collect();
    for r in &residents {
        for d in 0..rank {
            cuts[d].push(r.offset[d]);
            cuts[d].push(r.offset[d] + r.shape[d]);
        }
    }
    for d in 0..rank {
        cuts[d].push(target.offset[d]);
        cuts[d].push(target.offset[d] + target.shape[d]);
        cuts[d].sort_unstable();
        cuts[d].dedup();
    }

    // Enumerate grid cells intersecting the target (odometer over axes).
    let mut pieces = Vec::new();
    let mut idx = vec![0usize; rank];
    'outer: loop {
        // Build the current cell.
        let mut cell = Region { offset: vec![0; rank], shape: vec![0; rank] };
        let mut valid = true;
        for d in 0..rank {
            if idx[d] + 1 >= cuts[d].len() {
                valid = false;
                break;
            }
            cell.offset[d] = cuts[d][idx[d]];
            cell.shape[d] = cuts[d][idx[d] + 1] - cuts[d][idx[d]];
        }
        if valid {
            let part = cell.intersect(target);
            if part == cell && !cell.is_empty() {
                // Pick a source: self if possible, else nearest owner.
                let src = if residents[me].contains(&cell) {
                    me
                } else {
                    (0..devices)
                        .filter(|&d| residents[d].contains(&cell))
                        .min_by_key(|&d| (d ^ me).count_ones())
                        // Invariant: a validated TileSeq's shards cover the
                        // tensor (Theorem 2), so every grid cell has an
                        // owner — `planner::validate_plan` rejects the
                        // odd-split plans that could break coverage.
                        .unwrap_or_else(|| {
                            panic!(
                                "cell {cell:?} owned by nobody (shape {shape:?} seq {seq:?} \
                                 devices {devices} me {me} target {target:?})"
                            )
                        })
                };
                pieces.push(SourcePiece { src, region: cell });
            }
        }
        // Advance odometer.
        for d in 0..rank {
            idx[d] += 1;
            if idx[d] + 1 < cuts[d].len() {
                continue 'outer;
            }
            idx[d] = 0;
        }
        break;
    }
    if rank == 0 {
        // Scalars: one piece, local if replicated (always is).
        pieces.push(SourcePiece { src: me, region: Region::full(shape) });
    }
    pieces
}

/// Total bytes fetched from remote devices for this gather.
pub fn remote_bytes(pieces: &[SourcePiece], me: usize, dtype_bytes: u64) -> u64 {
    pieces
        .iter()
        .filter(|p| p.src != me)
        .map(|p| p.region.elements() * dtype_bytes)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::Tile;

    const R: Tile = Tile::Split(0);
    const C: Tile = Tile::Split(1);
    const REP: Tile = Tile::Rep;

    #[test]
    fn local_when_resident_covers() {
        // Row-split tensor, device wants its own rows: all local.
        let pieces = gather_sources(&[8, 4], &vec![R], 2, 0, &Region {
            offset: vec![0, 0],
            shape: vec![4, 4],
        });
        assert!(pieces.iter().all(|p| p.src == 0));
        let total: u64 = pieces.iter().map(|p| p.region.elements()).sum();
        assert_eq!(total, 16);
    }

    #[test]
    fn figure7b_ghost_fetch() {
        // Figure 7(b): tensor resident C (col-split), device 0 needs its
        // row half (C -> R conversion). It owns the top-left quarter and
        // must fetch the top-right quarter from device 1.
        let target = Region { offset: vec![0, 0], shape: vec![4, 8] };
        let pieces = gather_sources(&[8, 8], &vec![C], 2, 0, &target);
        let local: u64 = pieces.iter().filter(|p| p.src == 0).map(|p| p.region.elements()).sum();
        let remote = remote_bytes(&pieces, 0, 4);
        assert_eq!(local, 16);
        assert_eq!(remote, 16 * 4); // one quarter of 64 elements × 4 bytes
        // Matches the conversion-cost table: c(C -> R) = S/2 across both
        // devices = S/4 per device.
        let s: u64 = 8 * 8 * 4;
        assert_eq!(remote, s / 4);
    }

    #[test]
    fn replicated_source_all_local() {
        let target = Region { offset: vec![2, 0], shape: vec![4, 8] };
        let pieces = gather_sources(&[8, 8], &vec![REP], 2, 1, &target);
        assert_eq!(remote_bytes(&pieces, 1, 4), 0);
    }

    #[test]
    fn pieces_tile_target_exactly() {
        for (seq, me) in [(vec![R, C], 2usize), (vec![C, R], 1), (vec![R, REP], 3)] {
            let target = Region { offset: vec![0, 2], shape: vec![6, 4] };
            let pieces = gather_sources(&[8, 8], &seq, 4, me, &target);
            let total: u64 = pieces.iter().map(|p| p.region.elements()).sum();
            assert_eq!(total, target.elements(), "seq {seq:?}");
            // No overlaps: pairwise disjoint.
            for i in 0..pieces.len() {
                for j in i + 1..pieces.len() {
                    assert!(pieces[i].region.intersect(&pieces[j].region).is_empty());
                }
            }
        }
    }

    #[test]
    fn full_gather_from_split() {
        // Split -> Rep conversion: device fetches everything it misses.
        let target = Region::full(&[8, 8]);
        let pieces = gather_sources(&[8, 8], &vec![R, R], 4, 0, &target);
        // Owns 2 rows of 8 = 16 elements; fetches 48.
        assert_eq!(remote_bytes(&pieces, 0, 4), 48 * 4);
    }
}
