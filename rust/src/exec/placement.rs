//! Tile placement on the interconnect hierarchy (paper §5.1).
//!
//! Device ids are assigned so that the *first* cut (the most expensive,
//! Theorem 1) splits ids at the most-significant bit — i.e. across the
//! *slowest* interconnect tier — and each deeper cut lands on a faster
//! tier. Two devices' traffic crosses the tier of their highest differing
//! id bit.

/// A named interconnect hierarchy: `tiers[0]` is the slowest link (crossed
/// by the first cut), `tiers[k-1]` the fastest.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Tier names, slowest (outermost cut) first.
    pub tiers: Vec<String>,
}

impl Placement {
    /// The paper's testbed: one p2.8xlarge with 8 GPUs on a PCIe tree —
    /// QPI between CPU sockets, then PCIe switches, then direct PCIe.
    pub fn p2_8xlarge() -> Self {
        Placement {
            tiers: vec!["QPI".into(), "PCIe-switch".into(), "PCIe".into()],
        }
    }

    /// A flat hierarchy with `k` identical tiers (unit tests, simulator
    /// defaults beyond 8 devices).
    pub fn flat(k: usize, name: &str) -> Self {
        Placement { tiers: (0..k).map(|i| format!("{name}{i}")).collect() }
    }

    /// Number of tiers (= the deepest k this placement names).
    pub fn k(&self) -> usize {
        self.tiers.len()
    }
}

/// The cut index (= interconnect tier) that traffic between devices `a`
/// and `b` crosses, among `2^k` devices: the highest differing id bit.
/// Returns `None` for `a == b` (local).
pub fn cut_of_pair(a: usize, b: usize, k: usize) -> Option<usize> {
    if a == b {
        return None;
    }
    let h = usize::BITS as usize - 1 - (a ^ b).leading_zeros() as usize;
    Some(k - 1 - h)
}

/// All devices reachable from `d` by flipping exactly the given cut bits —
/// the reduction group for an output produced `red` at those cuts.
pub fn group_peers(d: usize, cuts: &[usize], k: usize) -> Vec<usize> {
    let mut peers = vec![d];
    for &c in cuts {
        let bit = 1usize << (k - 1 - c);
        let mut next = Vec::with_capacity(peers.len() * 2);
        for &p in &peers {
            next.push(p);
            next.push(p ^ bit);
        }
        peers = next;
    }
    peers.sort_unstable();
    peers.dedup();
    peers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_cut_crosses_slowest_tier() {
        // 8 devices, k=3: ids 0-3 vs 4-7 differ at the MSB = tier 0 (QPI).
        assert_eq!(cut_of_pair(0, 4, 3), Some(0));
        assert_eq!(cut_of_pair(3, 7, 3), Some(0));
        // Within a quad, pairs differing at bit 1 cross tier 1.
        assert_eq!(cut_of_pair(0, 2, 3), Some(1));
        // Adjacent ids cross the fastest tier.
        assert_eq!(cut_of_pair(6, 7, 3), Some(2));
        assert_eq!(cut_of_pair(5, 5, 3), None);
    }

    #[test]
    fn reduce_groups() {
        assert_eq!(group_peers(0, &[2], 3), vec![0, 1]);
        assert_eq!(group_peers(5, &[0], 3), vec![1, 5]);
        assert_eq!(group_peers(0, &[0, 2], 3), vec![0, 1, 4, 5]);
        assert_eq!(group_peers(3, &[], 3), vec![3]);
    }

    #[test]
    fn testbed_tiers() {
        let p = Placement::p2_8xlarge();
        assert_eq!(p.k(), 3);
        assert_eq!(p.tiers[0], "QPI");
    }
}
