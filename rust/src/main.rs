//! `soybean` — CLI front door for the SOYBEAN reproduction.
//!
//! Subcommands (std-only arg parsing; clap is not in the offline vendor
//! set):
//!
//! ```text
//! soybean plan     --model mlp --batch 512 --hidden 8192 --k 3 [--strategy soybean]
//! soybean plan     --model transformer --batch 8 --seq 128 --dmodel 256 --heads 4 --layers 4 --k 3
//! soybean simulate --model alexnet --batch 256 --k 3
//! soybean reproduce fig8a|fig8b|fig8c|fig9a|fig9b|fig10a|fig10b|example22|all
//! soybean train    --steps 100 --batch 32 [--k 2] [--strategy dp]
//! soybean inspect  --model vgg --batch 32
//! ```

use std::collections::HashMap;

use soybean::figures;
use soybean::models::{alexnet, cnn5, mlp, transformer, vgg16, MlpConfig, TransformerConfig};
use soybean::planner::{classify, Planner, PlanFamily};
use soybean::sim::{try_simulate, SimConfig};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).cloned().unwrap_or_default();
            flags.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    flags
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn strategy_of(flags: &HashMap<String, String>) -> PlanFamily {
    match flags.get("strategy").map(String::as_str) {
        Some("dp") | Some("data") => PlanFamily::DataParallel,
        Some("mp") | Some("model") => PlanFamily::ModelParallel,
        _ => PlanFamily::Soybean,
    }
}

fn model_graph(flags: &HashMap<String, String>) -> soybean::Graph {
    let batch = get(flags, "batch", 512usize);
    match flags.get("model").map(String::as_str).unwrap_or("mlp") {
        "mlp" => mlp(&MlpConfig::fig8(batch, get(flags, "hidden", 8192))),
        "cnn" => cnn5(batch, get(flags, "image", 6), 4, get(flags, "filters", 2048), 10),
        "alexnet" => alexnet(batch),
        "vgg" => vgg16(batch),
        "transformer" => {
            let micro = TransformerConfig::micro();
            transformer(&TransformerConfig {
                batch: get(flags, "batch", micro.batch),
                seq: get(flags, "seq", micro.seq),
                d_model: get(flags, "dmodel", micro.d_model),
                heads: get(flags, "heads", micro.heads),
                d_ff: get(flags, "dff", micro.d_ff),
                layers: get(flags, "layers", micro.layers),
                classes: get(flags, "classes", micro.classes),
            })
        }
        other => {
            eprintln!("unknown model {other}");
            std::process::exit(2);
        }
    }
}

/// Small real training run through the parallel PJRT engine (requires the
/// `pjrt` feature and the vendored xla toolchain).
#[cfg(feature = "pjrt")]
fn train(flags: &HashMap<String, String>) {
    use soybean::coordinator::{init_mlp_params, ParallelTrainer, SyntheticData};
    use soybean::runtime::Client;

    let steps = get(flags, "steps", 50usize);
    let batch = get(flags, "batch", 32usize);
    let k = get(flags, "k", 2usize);
    let dims = vec![64usize, 128, 128, 10];
    let g = mlp(&MlpConfig { batch, dims: dims.clone(), bias: true });
    let plan = Planner::try_plan(&g, k, strategy_of(flags)).unwrap();
    println!("plan: {} over {} devices", classify(&g, &plan.tiles), plan.devices());
    let client = std::sync::Arc::new(Client::cpu().expect("PJRT client"));
    let params = init_mlp_params(7, &dims);
    let mut trainer = ParallelTrainer::new(client, g, plan, &params, 0.1).expect("engine");
    let mut data = SyntheticData::new(3, dims[0], *dims.last().unwrap());
    for s in 0..steps {
        let (x, y) = data.batch(batch);
        let loss = trainer.step(&x, &y).expect("step");
        if s % 10 == 0 || s + 1 == steps {
            println!("step {s:>4}  loss {loss:.4}");
        }
    }
    println!(
        "engine traffic: {:.2} MB over {} transfers",
        trainer.engine.metrics.total_bytes() as f64 / 1e6,
        trainer.engine.metrics.transfers
    );
}

#[cfg(not(feature = "pjrt"))]
fn train(_flags: &HashMap<String, String>) {
    eprintln!("`soybean train` needs the real PJRT engine, which this build omits.");
    eprintln!("Enabling it takes two steps (see Cargo.toml's feature notes):");
    eprintln!("  1. add the vendored `xla` and `anyhow` crates to [dependencies]");
    eprintln!("  2. rebuild with `--features pjrt`");
    std::process::exit(2);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&argv[1.min(argv.len())..]);
    let cfg = SimConfig::default();

    match cmd {
        "plan" => {
            let g = model_graph(&flags);
            let k = get(&flags, "k", 3usize);
            let plan = Planner::try_plan(&g, k, strategy_of(&flags)).unwrap();
            println!("{}", plan.describe(&g));
            println!("classification: {}", classify(&g, &plan.tiles));
        }
        "simulate" => {
            let g = model_graph(&flags);
            let k = get(&flags, "k", 3usize);
            for strat in PlanFamily::all() {
                let plan = Planner::try_plan(&g, k, strat).unwrap();
                let r = try_simulate(&g, &plan, &cfg).unwrap();
                println!(
                    "{:<8} devices={} runtime={:.2}ms compute={:.2}ms overhead={:.2}ms comm={:.2}MB",
                    strat.name(),
                    r.devices,
                    r.step_s * 1e3,
                    r.compute_s * 1e3,
                    r.overhead_s * 1e3,
                    r.total_bytes as f64 / 1e6
                );
            }
        }
        "reproduce" => {
            let which = argv.get(1).map(String::as_str).unwrap_or("all");
            let all = which == "all";
            if all || which == "example22" {
                println!("{}", figures::example22());
            }
            if all || which == "fig8a" {
                println!("{}", figures::fig8(512, 8192, &cfg).0);
            }
            if all || which == "fig8b" {
                println!("{}", figures::fig8(2048, 8192, &cfg).0);
            }
            if all || which == "fig8c" {
                println!("{}", figures::fig8(2048, 12288, &cfg).0);
            }
            if all || which == "fig9a" {
                println!("{}", figures::fig9(6, 2048, &cfg).0);
            }
            if all || which == "fig9b" {
                println!("{}", figures::fig9(24, 512, &cfg).0);
            }
            if all || which == "fig10a" {
                println!("{}", figures::fig10("alexnet", &[64, 128, 256, 512, 1024], &cfg).0);
            }
            if all || which == "fig10b" {
                println!("{}", figures::fig10("vgg", &[16, 32, 64, 128, 256], &cfg).0);
            }
        }
        "train" => train(&flags),
        "inspect" => {
            let g = model_graph(&flags);
            println!("{}", g.dump());
            println!(
                "{} ops, {} tensors, {:.1} MB weights, {:.1} MB activations",
                g.ops.len(),
                g.tensors.len(),
                g.weight_bytes() as f64 / 1e6,
                g.activation_bytes() as f64 / 1e6
            );
        }
        _ => {
            println!("usage: soybean <plan|simulate|reproduce|train|inspect> [flags]");
            println!("  see rust/src/main.rs header for flags");
        }
    }
}
