//! Serial reference interpreter: evaluate the unpartitioned training graph
//! on one thread with real `f32` tensors.
//!
//! This is the ground truth of the ISSUE-5 differential harness: the
//! threaded SPMD executor ([`crate::spmd`]) must reproduce these values
//! elementwise (within the documented tolerance) for every plan it runs.
//! Both sides dispatch the same kernel library ([`super::apply_op`]), so a
//! divergence isolates a *partitioning* bug — wrong shard regions, wrong
//! conversion routing, a dropped reduction — rather than a kernel bug.

use std::fmt;

use super::fastk::{apply_op_with, KernelBackend};
use super::kernels::View;
use super::{Graph, TensorKind};
use crate::util::rng::Rng;

/// Structured failure of [`eval_serial`] — the graph inputs were not fully
/// or correctly provided.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum InterpError {
    /// `init` has a different length than the graph's tensor list.
    WrongArity {
        /// Tensors the graph declares.
        expected: usize,
        /// Entries provided.
        got: usize,
    },
    /// A producerless tensor (input, label, parameter) has no value.
    MissingInput {
        /// Name of the tensor without a value.
        tensor: String,
    },
    /// A provided value's element count does not match the tensor shape.
    WrongLength {
        /// Name of the mis-sized tensor.
        tensor: String,
        /// Elements the shape requires.
        expected: usize,
        /// Elements provided.
        got: usize,
    },
}

impl fmt::Display for InterpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            InterpError::WrongArity { expected, got } => {
                write!(f, "init holds {got} entries for a graph of {expected} tensors")
            }
            InterpError::MissingInput { tensor } => {
                write!(f, "graph input `{tensor}` has no initial value")
            }
            InterpError::WrongLength { tensor, expected, got } => {
                write!(f, "tensor `{tensor}` needs {expected} elements, got {got}")
            }
        }
    }
}

impl std::error::Error for InterpError {}

/// Evaluate every op of `g` in topological order on whole tensors.
///
/// `init` is indexed by `TensorId`: `Some` values for every producerless
/// tensor (inputs, labels, parameters — see [`seed_values`]), `None` for
/// tensors an op produces. Returns the value of **every** tensor.
///
/// # Examples
///
/// ```
/// use soybean::graph::{eval_serial, seed_values};
/// use soybean::models::{mlp, MlpConfig};
///
/// let g = mlp(&MlpConfig { batch: 8, dims: vec![4, 4], bias: false });
/// let vals = eval_serial(&g, &seed_values(&g, 7)).unwrap();
/// // The loss is a finite scalar.
/// let loss = g.tensors.iter().find(|t| t.rank() == 0).unwrap();
/// assert!(vals[loss.id][0].is_finite());
/// ```
pub fn eval_serial(g: &Graph, init: &[Option<Vec<f32>>]) -> Result<Vec<Vec<f32>>, InterpError> {
    eval_serial_with(g, init, KernelBackend::default())
}

/// [`eval_serial`] under an explicit kernel backend — the oracle suite
/// compares a [`KernelBackend::Fast`] evaluation of a whole graph against
/// the [`KernelBackend::Naive`] reference this way.
pub fn eval_serial_with(
    g: &Graph,
    init: &[Option<Vec<f32>>],
    backend: KernelBackend,
) -> Result<Vec<Vec<f32>>, InterpError> {
    let produced = validate_init(g, init)?;
    let mut vals: Vec<Vec<f32>> = vec![Vec::new(); g.tensors.len()];
    for t in &g.tensors {
        if !produced[t.id] {
            // Invariant: validate_init checked presence and length.
            vals[t.id] = init[t.id].as_ref().expect("validated init value").clone();
        }
    }
    for &opid in &g.topo_order() {
        let op = &g.ops[opid];
        let views: Vec<View<'_>> = op
            .inputs
            .iter()
            .map(|&t| View::full(&vals[t], &g.tensors[t].shape))
            .collect();
        let out = apply_op_with(backend, g, op, &views, &g.tensors[op.outputs[0]].shape);
        vals[op.outputs[0]] = out;
    }
    Ok(vals)
}

/// Deterministic initial values for every producerless tensor of `g`:
/// scale-preserving uniform weights (LeCun-style `±√(3/fan_in)`), one-hot
/// label rows, `1 + ε` layer-norm gains, and small-normal inputs. Produced
/// tensors get `None`. Both harness sides slice from these same arrays.
pub fn seed_values(g: &Graph, seed: u64) -> Vec<Option<Vec<f32>>> {
    let produced = g.produced_mask();
    g.tensors
        .iter()
        .map(|t| {
            if produced[t.id] {
                return None;
            }
            let mut rng = Rng::new(seed ^ (t.id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let n: usize = t.shape.iter().product();
            let v = match (t.kind, t.rank()) {
                (TensorKind::Label, 2) => {
                    let (m, c) = (t.shape[0], t.shape[1]);
                    let mut v = vec![0.0f32; m * c];
                    for i in 0..m {
                        v[i * c + rng.below(c)] = 1.0;
                    }
                    v
                }
                (TensorKind::Weight, rank) => {
                    let fan = match rank {
                        2 => t.shape[0],
                        4 => t.shape[0] * t.shape[1] * t.shape[2],
                        _ => t.shape.first().copied().unwrap_or(1).max(1),
                    };
                    let a = (3.0 / fan as f64).sqrt();
                    // Layer-norm gains center at 1 so σ-divisions stay sane.
                    let bias = if rank == 1 && t.name.ends_with(".g") { 1.0 } else { 0.0 };
                    (0..n)
                        .map(|_| (bias + (2.0 * rng.uniform() - 1.0) * a) as f32)
                        .collect()
                }
                _ => (0..n).map(|_| (0.5 * rng.normal()) as f32).collect(),
            };
            Some(v)
        })
        .collect()
}

/// Check an initial-value vector against a graph's input contract (one
/// entry per tensor; a correctly-sized `Some` for every producerless
/// tensor) — the shared front door of the serial interpreter and the
/// SPMD executor. Returns the graph's [`Graph::produced_mask`] so
/// callers can keep walking it.
pub fn validate_init(g: &Graph, init: &[Option<Vec<f32>>]) -> Result<Vec<bool>, InterpError> {
    if init.len() != g.tensors.len() {
        return Err(InterpError::WrongArity { expected: g.tensors.len(), got: init.len() });
    }
    let produced = g.produced_mask();
    for t in &g.tensors {
        if produced[t.id] {
            continue;
        }
        let want: usize = t.shape.iter().product();
        match &init[t.id] {
            Some(v) if v.len() == want => {}
            Some(v) => {
                return Err(InterpError::WrongLength {
                    tensor: t.name.clone(),
                    expected: want,
                    got: v.len(),
                })
            }
            None => return Err(InterpError::MissingInput { tensor: t.name.clone() }),
        }
    }
    Ok(produced)
}

/// Largest elementwise deviation between `got` and the reference `want`,
/// relative to the reference's largest magnitude — the differential
/// harness's comparison metric (tolerance model: docs/execution.md).
pub fn max_rel_err(got: &[f32], want: &[f32]) -> f64 {
    assert_eq!(got.len(), want.len(), "comparing tensors of different sizes");
    let scale = want.iter().fold(1e-6f64, |a, &b| a.max((b as f64).abs()));
    got.iter()
        .zip(want)
        .fold(0.0f64, |acc, (&a, &b)| acc.max((a as f64 - b as f64).abs() / scale))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::models::{mlp, MlpConfig};

    #[test]
    fn evaluates_training_step_end_to_end() {
        let g = mlp(&MlpConfig { batch: 8, dims: vec![6, 10, 4], bias: true });
        let vals = eval_serial(&g, &seed_values(&g, 3)).unwrap();
        for t in &g.tensors {
            let n: usize = t.shape.iter().product();
            assert_eq!(vals[t.id].len(), n, "tensor {}", t.name);
            assert!(vals[t.id].iter().all(|v| v.is_finite()), "tensor {}", t.name);
        }
        // SGD moved the weights.
        let w = g.tensors.iter().find(|t| t.name == "w0").unwrap();
        let upd = g.tensors.iter().find(|t| t.name == "w0.sgd.out").unwrap();
        assert_ne!(vals[w.id], vals[upd.id]);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = mlp(&MlpConfig { batch: 4, dims: vec![4, 4], bias: false });
        let a = eval_serial(&g, &seed_values(&g, 11)).unwrap();
        let b = eval_serial(&g, &seed_values(&g, 11)).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn structured_errors_on_bad_init() {
        let g = mlp(&MlpConfig { batch: 4, dims: vec![4, 4], bias: false });
        assert_eq!(
            eval_serial(&g, &[]).unwrap_err(),
            InterpError::WrongArity { expected: g.tensors.len(), got: 0 }
        );
        let mut init = seed_values(&g, 1);
        init[0] = None; // drop the mini-batch input
        assert!(matches!(
            eval_serial(&g, &init).unwrap_err(),
            InterpError::MissingInput { .. }
        ));
        let mut init = seed_values(&g, 1);
        init[0].as_mut().unwrap().pop();
        assert!(matches!(
            eval_serial(&g, &init).unwrap_err(),
            InterpError::WrongLength { .. }
        ));
    }

    #[test]
    fn one_hot_labels() {
        let mut b = GraphBuilder::new();
        let x = b.input("x", &[4, 4]);
        let y = b.label("y", &[4, 4]);
        b.softmax_xent("loss", x, y);
        let g = b.finish();
        let init = seed_values(&g, 5);
        let labels = init[y].as_ref().unwrap();
        for i in 0..4 {
            let row = &labels[i * 4..(i + 1) * 4];
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(row.iter().filter(|&&v| v == 0.0).count(), 3);
        }
    }

    #[test]
    fn max_rel_err_metric() {
        assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let e = max_rel_err(&[1.0, 2.2], &[1.0, 2.0]);
        assert!((e - 0.1).abs() < 1e-6, "{e}");
    }
}
