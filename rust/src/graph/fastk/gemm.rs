//! Blocked, transposed-packed GEMM with `f64` accumulation.
//!
//! The driver walks the classic three-level blocking (column panels of
//! `nc`, contraction blocks of `kc`, row blocks of `mc` — see the
//! [`Schedule`] docs), packs both operands into contiguous micro-panels
//! (transposition is resolved at pack time, hoisting the orientation
//! branches out of the O(m·k·n) inner loop), and runs an `mr`×`nr`
//! register-tile micro-kernel innermost. Storage is `f32`; the output
//! accumulates in a full-precision `f64` scratch that is rounded to `f32`
//! exactly once — the same contract as the naive oracle.
//!
//! ## Accumulation order
//!
//! Each output element's contraction runs in ascending `k` order: the
//! micro-kernel walks its packed panels `k`-major, and the `f64` scratch
//! carries the partial sum across successive `kc` blocks, so blocking
//! never reorders the per-element sum relative to the naive triple loop.
//! Products of `f32` values are exact in `f64` (24+24 ≤ 53 mantissa bits),
//! which is what makes the oracle suite's tight tolerance hold — see
//! docs/kernels.md §Tolerance for the full argument and the weaker
//! *contract* bound future SIMD schedules are held to.

use std::time::Instant;

use super::schedule::{Schedule, ScheduleCache};

/// A logical matrix operand: a stored row-major `f32` buffer plus the
/// transposition flag that selects the logical orientation (mirroring the
/// naive kernel's `(data, (rows, cols), trans)` triple).
#[derive(Debug, Clone, Copy)]
pub(crate) struct MatRef<'a> {
    /// Stored elements, row-major over `rows`×`cols`.
    pub data: &'a [f32],
    /// Stored row count.
    pub rows: usize,
    /// Stored column count.
    pub cols: usize,
    /// Interpret as the transpose (logical dims swap).
    pub trans: bool,
}

impl MatRef<'_> {
    /// Logical `(rows, cols)` after applying the transposition flag.
    pub(crate) fn logical_dims(&self) -> (usize, usize) {
        if self.trans {
            (self.cols, self.rows)
        } else {
            (self.rows, self.cols)
        }
    }
}

/// Pack the A block `rows [ic, ic+mb) × contraction [pc, pc+kb)` into
/// `mr`-tall micro-panels, each laid out `k`-major (`kb` groups of up to
/// `mr` consecutive rows), converting to `f64` once here so the
/// micro-kernel's inner loop is pure `f64` mul/add on contiguous panels.
/// A boundary panel ([`super::schedule::boundary_size`]`(mb, mr)` rows) is
/// packed at its true extent — never padded.
fn pack_a(a: &MatRef<'_>, ic: usize, mb: usize, pc: usize, kb: usize, mr: usize, buf: &mut Vec<f64>) {
    buf.clear();
    buf.reserve(mb * kb);
    let mut i0 = 0;
    while i0 < mb {
        let mr_eff = mr.min(mb - i0);
        if a.trans {
            // Logical A[i, l] = data[l·cols + i]: panel rows are contiguous.
            for l in 0..kb {
                let row = &a.data[(pc + l) * a.cols + ic + i0..];
                for &v in &row[..mr_eff] {
                    buf.push(v as f64);
                }
            }
        } else {
            for l in 0..kb {
                for i in 0..mr_eff {
                    buf.push(a.data[(ic + i0 + i) * a.cols + pc + l] as f64);
                }
            }
        }
        i0 += mr_eff;
    }
}

/// Pack the B block `contraction [pc, pc+kb) × cols [jc, jc+nb)` into
/// `nr`-wide `k`-major micro-panels (the mirror of [`pack_a`]).
fn pack_b(b: &MatRef<'_>, pc: usize, kb: usize, jc: usize, nb: usize, nr: usize, buf: &mut Vec<f64>) {
    buf.clear();
    buf.reserve(nb * kb);
    let mut j0 = 0;
    while j0 < nb {
        let nr_eff = nr.min(nb - j0);
        if b.trans {
            // Logical B[l, j] = data[j·cols + l].
            for l in 0..kb {
                for j in 0..nr_eff {
                    buf.push(b.data[(jc + j0 + j) * b.cols + pc + l] as f64);
                }
            }
        } else {
            for l in 0..kb {
                let row = &b.data[(pc + l) * b.cols + jc + j0..];
                for &v in &row[..nr_eff] {
                    buf.push(v as f64);
                }
            }
        }
        j0 += nr_eff;
    }
}

/// Full `MR`×`NR` micro-kernel: load the register accumulator from the
/// `f64` scratch, stream both packed panels `k`-major (`ap` is `kb`
/// chunks of `MR`, `bp` of `NR`), store back. Const dimensions let the
/// compiler keep the accumulator in registers and unroll/vectorize the
/// rank-1 update.
#[inline]
fn micro_full<const MR: usize, const NR: usize>(ap: &[f64], bp: &[f64], c: &mut [f64], c_off: usize, ldc: usize) {
    let mut acc = [[0.0f64; NR]; MR];
    for (i, row) in acc.iter_mut().enumerate() {
        row.copy_from_slice(&c[c_off + i * ldc..c_off + i * ldc + NR]);
    }
    for (ar, br) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for (i, row) in acc.iter_mut().enumerate() {
            let ai = ar[i];
            for (j, accv) in row.iter_mut().enumerate() {
                *accv += ai * br[j];
            }
        }
    }
    for (i, row) in acc.iter().enumerate() {
        c[c_off + i * ldc..c_off + i * ldc + NR].copy_from_slice(row);
    }
}

/// Generic boundary micro-kernel for partial tiles (`mr_eff < mr` and/or
/// `nr_eff < nr` — the explicit [`super::schedule::boundary_size`] tiles).
/// Same `k`-major walk and accumulation order as [`micro_full`], at
/// runtime extents.
fn micro_any(mr_eff: usize, nr_eff: usize, ap: &[f64], bp: &[f64], c: &mut [f64], c_off: usize, ldc: usize) {
    const MAX_R: usize = 8;
    debug_assert!(mr_eff <= MAX_R && nr_eff <= MAX_R);
    let mut acc = [[0.0f64; MAX_R]; MAX_R];
    for i in 0..mr_eff {
        acc[i][..nr_eff].copy_from_slice(&c[c_off + i * ldc..c_off + i * ldc + nr_eff]);
    }
    for (ar, br) in ap.chunks_exact(mr_eff).zip(bp.chunks_exact(nr_eff)) {
        for i in 0..mr_eff {
            let ai = ar[i];
            for j in 0..nr_eff {
                acc[i][j] += ai * br[j];
            }
        }
    }
    for i in 0..mr_eff {
        c[c_off + i * ldc..c_off + i * ldc + nr_eff].copy_from_slice(&acc[i][..nr_eff]);
    }
}

/// Blocked GEMM core: **adds** `op(a)·op(b)` into the `m`×`n` `f64`
/// scratch `c64` under schedule `s`. Callers zero (or carry) the scratch;
/// conv backward-filter exploits the carry to accumulate row blocks.
pub(crate) fn gemm_into(c64: &mut [f64], a: &MatRef<'_>, b: &MatRef<'_>, s: &Schedule) {
    let (m, k) = a.logical_dims();
    let (kb2, n) = b.logical_dims();
    debug_assert_eq!(k, kb2, "gemm contraction mismatch");
    debug_assert_eq!(c64.len(), m * n, "gemm scratch size");
    let mut apack: Vec<f64> = Vec::new();
    let mut bpack: Vec<f64> = Vec::new();
    let mut jc = 0;
    while jc < n {
        let nb = s.nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kb = s.kc.min(k - pc);
            pack_b(b, pc, kb, jc, nb, s.nr, &mut bpack);
            let mut ic = 0;
            while ic < m {
                let mb = s.mc.min(m - ic);
                pack_a(a, ic, mb, pc, kb, s.mr, &mut apack);
                let mut j0 = 0;
                while j0 < nb {
                    let nr_eff = s.nr.min(nb - j0);
                    let bp = &bpack[j0 * kb..(j0 + nr_eff) * kb];
                    let mut i0 = 0;
                    while i0 < mb {
                        let mr_eff = s.mr.min(mb - i0);
                        let ap = &apack[i0 * kb..(i0 + mr_eff) * kb];
                        let c_off = (ic + i0) * n + jc + j0;
                        if mr_eff == s.mr && nr_eff == s.nr {
                            match (s.mr, s.nr) {
                                (4, 4) => micro_full::<4, 4>(ap, bp, c64, c_off, n),
                                (4, 8) => micro_full::<4, 8>(ap, bp, c64, c_off, n),
                                (8, 4) => micro_full::<8, 4>(ap, bp, c64, c_off, n),
                                (8, 8) => micro_full::<8, 8>(ap, bp, c64, c_off, n),
                                _ => micro_any(mr_eff, nr_eff, ap, bp, c64, c_off, n),
                            }
                        } else {
                            micro_any(mr_eff, nr_eff, ap, bp, c64, c_off, n);
                        }
                        i0 += mr_eff;
                    }
                    j0 += nr_eff;
                }
                ic += mb;
            }
            pc += kb;
        }
        jc += nb;
    }
}

/// Schedule-cached GEMM producing the `f64` accumulator (conv
/// backward-data consumes it before any rounding). Looks the schedule up
/// in `cache` and records the one-shot first-execution measurement.
pub(crate) fn gemm_f64(a: &MatRef<'_>, b: &MatRef<'_>, cache: &ScheduleCache) -> Vec<f64> {
    let (m, k) = a.logical_dims();
    let n = b.logical_dims().1;
    let (sched, fresh) = cache.lookup(m, k, n);
    let t0 = fresh.then(Instant::now);
    let mut c64 = vec![0.0f64; m * n];
    gemm_into(&mut c64, a, b, &sched);
    if let Some(t0) = t0 {
        cache.record_measured(m, k, n, t0.elapsed());
    }
    c64
}

/// Schedule-cached GEMM rounded once to `f32` — the fast path behind
/// `MatMul` and each `BatchedMatMul` group.
pub(crate) fn gemm_f32(a: &MatRef<'_>, b: &MatRef<'_>, cache: &ScheduleCache) -> Vec<f32> {
    gemm_f64(a, b, cache).into_iter().map(|v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &MatRef<'_>, b: &MatRef<'_>) -> Vec<f32> {
        let (m, k) = a.logical_dims();
        let n = b.logical_dims().1;
        let at = |i: usize, l: usize| if a.trans { a.data[l * a.cols + i] } else { a.data[i * a.cols + l] };
        let bt = |l: usize, j: usize| if b.trans { b.data[j * b.cols + l] } else { b.data[l * b.cols + j] };
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..k {
                    acc += at(i, l) as f64 * bt(l, j) as f64;
                }
                out[i * n + j] = acc as f32;
            }
        }
        out
    }

    #[test]
    fn matches_naive_bitwise_across_schedules_and_transposes() {
        // Order-preserving blocking ⇒ bit-identical to the sequential
        // triple loop, for every transpose combo and odd boundary extent.
        let mut rng = Rng::new(0xFA57_6E44);
        let cache = ScheduleCache::new();
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (7, 5, 9), (65, 33, 17), (64, 64, 64), (13, 257, 3)] {
            for (ta, tb) in [(false, false), (true, false), (false, true), (true, true)] {
                let adata: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
                let bdata: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
                let (ar, ac) = if ta { (k, m) } else { (m, k) };
                let (br, bc) = if tb { (n, k) } else { (k, n) };
                let a = MatRef { data: &adata, rows: ar, cols: ac, trans: ta };
                let b = MatRef { data: &bdata, rows: br, cols: bc, trans: tb };
                let want = naive(&a, &b);
                let got = gemm_f32(&a, &b, &cache);
                assert!(
                    got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "({m},{k},{n}) ta={ta} tb={tb} diverged from the sequential order"
                );
            }
        }
    }

    #[test]
    fn gemm_into_accumulates() {
        // Two half-contractions carried through the scratch == one full one.
        let adata: Vec<f32> = (0..6 * 10).map(|i| (i as f32).sin()).collect();
        let bdata: Vec<f32> = (0..10 * 4).map(|i| (i as f32).cos()).collect();
        let s = Schedule { mc: 4, kc: 3, nc: 4, mr: 4, nr: 4 };
        let mut whole = vec![0.0f64; 6 * 4];
        gemm_into(&mut whole, &MatRef { data: &adata, rows: 6, cols: 10, trans: false }, &MatRef {
            data: &bdata,
            rows: 10,
            cols: 4,
            trans: false,
        }, &s);
        let mut halves = vec![0.0f64; 6 * 4];
        for half in 0..2 {
            let acols: Vec<f32> =
                (0..6).flat_map(|i| adata[i * 10 + half * 5..i * 10 + half * 5 + 5].to_vec()).collect();
            let brows = &bdata[half * 5 * 4..(half + 1) * 5 * 4];
            gemm_into(&mut halves, &MatRef { data: &acols, rows: 6, cols: 5, trans: false }, &MatRef {
                data: brows,
                rows: 5,
                cols: 4,
                trans: false,
            }, &s);
        }
        assert_eq!(whole, halves);
    }
}
