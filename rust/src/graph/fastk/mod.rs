//! The fast kernel layer: blocked, schedule-searched compute for the
//! numeric hot path, dispatched per [`KernelBackend`].
//!
//! The heavy operators — `MatMul`, `BatchedMatMul`, and the three conv
//! operators (lowered to im2col-GEMM) — run through the packed blocked
//! GEMM in [`gemm`], under a per-shape [`Schedule`] chosen by the
//! deterministic search in [`schedule`] and memoized in a
//! [`ScheduleCache`]. Everything else falls through to the naive kernel
//! library (`graph/kernels.rs`), which is **kept as the oracle**: the
//! property suite in `rust/tests/kernels.rs` differentially tests every
//! accelerated kernel against it over hundreds of seeded shapes, and
//! [`accelerated_op_names`] is the coverage contract that keeps a new fast
//! kernel from landing un-oracled.
//!
//! Both interpreters ride this dispatcher: [`apply_op`] (the default
//! [`KernelBackend::Fast`]) is what `eval_serial`, the threaded SPMD
//! executor, and the serving engine call; [`apply_op_with`] pins a backend
//! explicitly (tests, `ExecOptions::backend`). The full design — blocking
//! scheme, search space, boundary-tile handling, and the accumulation-order
//! tolerance argument — is the book chapter docs/kernels.md.

mod conv;
mod gemm;
mod schedule;

pub use schedule::{boundary_size, steps_dim, Schedule, ScheduleCache, ScheduleReport};

use gemm::MatRef;

use super::kernels::{apply_op_naive, View};
use super::{Graph, Op, OpKind};

/// Which kernel implementation executes an op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum KernelBackend {
    /// The reference triple-loop library (`graph/kernels.rs`) — the oracle
    /// the property suite measures the fast path against.
    Naive,
    /// Blocked, packed, schedule-searched kernels (the default).
    #[default]
    Fast,
}

/// Documented fast-vs-oracle agreement bound (relative error in
/// [`super::max_rel_err`]'s metric).
///
/// The current blocked kernels preserve each output element's contraction
/// order, so they agree with the oracle *bit for bit* (every `f32×f32`
/// product is exact in `f64`; see docs/kernels.md §Tolerance). The public
/// contract is deliberately the weaker reassociation bound
/// `2·ε₃₂ + κ·K·ε₆₄ ≲ 1e-6` for the suite's shapes and conditioning, so a
/// future SIMD schedule that *does* reorder the `f64` sum stays legal
/// without loosening any downstream gate: the differential harness's 1e-5
/// keeps ≥10× headroom over this bound (asserted in
/// `rust/tests/differential.rs`).
pub const KERNEL_ORACLE_TOL: f64 = 1e-6;

/// Names of the op kinds with a fast (non-oracle) kernel — the coverage
/// contract of the oracle property suite: `rust/tests/kernels.rs` asserts
/// that every name here has a generated oracle case set (and vice versa),
/// so extending [`is_accelerated`] without extending the suite fails CI.
pub fn accelerated_op_names() -> &'static [&'static str] {
    &["MatMul", "BatchedMatMul", "Conv2d", "Conv2dBwdData", "Conv2dBwdFilter"]
}

/// Whether `kind` dispatches to a fast kernel under
/// [`KernelBackend::Fast`]. This predicate *is* the dispatch condition
/// ([`apply_op_with`] consults it before matching), so it cannot drift
/// from the implementation.
pub fn is_accelerated(kind: &OpKind) -> bool {
    matches!(
        kind,
        OpKind::MatMul { .. }
            | OpKind::BatchedMatMul { .. }
            | OpKind::Conv2d { .. }
            | OpKind::Conv2dBwdData { .. }
            | OpKind::Conv2dBwdFilter { .. }
    )
}

/// The variant name of `kind` (no payload), the vocabulary
/// [`accelerated_op_names`] and the oracle suite's coverage ledger share.
pub fn op_kind_label(kind: &OpKind) -> &'static str {
    match kind {
        OpKind::MatMul { .. } => "MatMul",
        OpKind::Conv2d { .. } => "Conv2d",
        OpKind::Conv2dBwdData { .. } => "Conv2dBwdData",
        OpKind::Conv2dBwdFilter { .. } => "Conv2dBwdFilter",
        OpKind::Ew(_) => "Ew",
        OpKind::Pool2 => "Pool2",
        OpKind::Pool2Bwd => "Pool2Bwd",
        OpKind::Flatten => "Flatten",
        OpKind::FlattenBwd => "FlattenBwd",
        OpKind::BiasAdd => "BiasAdd",
        OpKind::ReduceSumRows => "ReduceSumRows",
        OpKind::SoftmaxXent => "SoftmaxXent",
        OpKind::SoftmaxXentGrad => "SoftmaxXentGrad",
        OpKind::SgdUpdate => "SgdUpdate",
        OpKind::BatchedMatMul { .. } => "BatchedMatMul",
        OpKind::LayerNorm => "LayerNorm",
        OpKind::LayerNormGrad => "LayerNormGrad",
        OpKind::LayerNormGammaGrad => "LayerNormGammaGrad",
        OpKind::Softmax => "Softmax",
        OpKind::SoftmaxGrad => "SoftmaxGrad",
        OpKind::SplitHeads { .. } => "SplitHeads",
        OpKind::MergeHeads { .. } => "MergeHeads",
        OpKind::QkvSlice { .. } => "QkvSlice",
        OpKind::QkvConcat => "QkvConcat",
    }
}

/// Apply `op` with the **default backend** ([`KernelBackend::Fast`], global
/// [`ScheduleCache`]) — the entry point both interpreters and the serving
/// engine share. Same contract as the former naive `apply_op`: shard-local
/// operand [`View`]s in, the dense row-major output region out.
pub fn apply_op(g: &Graph, op: &Op, ins: &[View<'_>], out_shape: &[usize]) -> Vec<f32> {
    apply_op_with(KernelBackend::default(), g, op, ins, out_shape)
}

/// Apply `op` under an explicit backend. [`KernelBackend::Fast`] uses the
/// process-global [`ScheduleCache`]; ops without a fast kernel
/// ([`is_accelerated`] is false) fall through to the oracle either way.
pub fn apply_op_with(backend: KernelBackend, g: &Graph, op: &Op, ins: &[View<'_>], out_shape: &[usize]) -> Vec<f32> {
    match backend {
        KernelBackend::Naive => apply_op_naive(g, op, ins, out_shape),
        KernelBackend::Fast => apply_op_fast_in(ScheduleCache::global(), g, op, ins, out_shape),
    }
}

/// The fast path against an explicit [`ScheduleCache`] — what the
/// determinism tests (two fresh caches, four racing threads) and the
/// cold-vs-warm bench split call directly.
pub fn apply_op_fast_in(cache: &ScheduleCache, g: &Graph, op: &Op, ins: &[View<'_>], out_shape: &[usize]) -> Vec<f32> {
    if !is_accelerated(&op.kind) {
        return apply_op_naive(g, op, ins, out_shape);
    }
    assert_eq!(ins.len(), op.inputs.len(), "kernel arity mismatch for {}", op.name);
    match op.kind {
        OpKind::MatMul { ta, tb } => {
            let (a, b) = (&ins[0], &ins[1]);
            gemm::gemm_f32(
                &MatRef { data: a.data, rows: a.shape[0], cols: a.shape[1], trans: ta },
                &MatRef { data: b.data, rows: b.shape[0], cols: b.shape[1], trans: tb },
                cache,
            )
        }
        OpKind::BatchedMatMul { ta, tb } => {
            let (a, b) = (&ins[0], &ins[1]);
            let groups = a.shape[0];
            let (ap, aq) = (a.shape[1], a.shape[2]);
            let (bp, bq) = (b.shape[1], b.shape[2]);
            let mut out = Vec::with_capacity(out_shape.iter().product());
            for gi in 0..groups {
                let asl = &a.data[gi * ap * aq..(gi + 1) * ap * aq];
                let bsl = &b.data[gi * bp * bq..(gi + 1) * bp * bq];
                out.extend(gemm::gemm_f32(
                    &MatRef { data: asl, rows: ap, cols: aq, trans: ta },
                    &MatRef { data: bsl, rows: bp, cols: bq, trans: tb },
                    cache,
                ));
            }
            out
        }
        OpKind::Conv2d { stride, pad } => conv::conv2d(&ins[0], &ins[1], out_shape, stride, pad, cache),
        OpKind::Conv2dBwdData { stride, pad } => {
            conv::conv2d_bwd_data(&ins[0], &ins[1], out_shape, stride, pad, cache)
        }
        OpKind::Conv2dBwdFilter { stride, pad } => {
            conv::conv2d_bwd_filter(&ins[0], &ins[1], out_shape, stride, pad, cache)
        }
        _ => unreachable!("is_accelerated admits {} without a fast kernel arm", op_kind_label(&op.kind)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<OpKind> {
        use crate::graph::EwKind;
        vec![
            OpKind::MatMul { ta: false, tb: false },
            OpKind::Conv2d { stride: 1, pad: 0 },
            OpKind::Conv2dBwdData { stride: 1, pad: 0 },
            OpKind::Conv2dBwdFilter { stride: 1, pad: 0 },
            OpKind::Ew(EwKind::Relu),
            OpKind::Pool2,
            OpKind::Pool2Bwd,
            OpKind::Flatten,
            OpKind::FlattenBwd,
            OpKind::BiasAdd,
            OpKind::ReduceSumRows,
            OpKind::SoftmaxXent,
            OpKind::SoftmaxXentGrad,
            OpKind::SgdUpdate,
            OpKind::BatchedMatMul { ta: false, tb: true },
            OpKind::LayerNorm,
            OpKind::LayerNormGrad,
            OpKind::LayerNormGammaGrad,
            OpKind::Softmax,
            OpKind::SoftmaxGrad,
            OpKind::SplitHeads { heads: 2 },
            OpKind::MergeHeads { heads: 2 },
            OpKind::QkvSlice { part: 0 },
            OpKind::QkvConcat,
        ]
    }

    #[test]
    fn accelerated_names_match_predicate() {
        // The name list and the dispatch predicate agree on every variant
        // of the vocabulary — the coverage contract's foundation.
        for kind in all_kinds() {
            assert_eq!(
                is_accelerated(&kind),
                accelerated_op_names().contains(&op_kind_label(&kind)),
                "{:?} disagrees with accelerated_op_names()",
                kind
            );
        }
    }

    #[test]
    fn default_backend_is_fast() {
        assert_eq!(KernelBackend::default(), KernelBackend::Fast);
    }
}
