//! Per-shape schedule search for the blocked GEMM drivers.
//!
//! A [`Schedule`] is a full blocking decision for one logical GEMM shape
//! `(m, k, n)`: the three cache-block extents `mc`/`kc`/`nc` and the
//! register micro-tile `mr`×`nr`. Candidates are enumerated over a small
//! fixed grid, clamped to the shape with morello-style [`steps_dim`] /
//! [`boundary_size`] arithmetic (a non-divisible extent yields an explicit
//! smaller boundary tile — never padding), scored by a tiny deterministic
//! cost model, and memoized per shape in a [`ScheduleCache`].
//!
//! ## Determinism contract
//!
//! The *chosen* schedule is a pure function of `(m, k, n)`: candidates are
//! enumerated in a canonical sorted order and the first strict cost minimum
//! wins, so two fresh caches — or four racing threads on one cache — always
//! converge on the bit-identical schedule (pinned by
//! `rust/tests/kernels.rs`). The one-shot wall-clock measurement the cache
//! records next to each entry ([`ScheduleReport::measured`]) is
//! observability for `kernels_micro` and the drift report; it deliberately
//! does **not** steer selection, because a timing-steered choice would make
//! plans and BENCH numbers irreproducible (docs/kernels.md §Search).

use std::collections::{BTreeSet, HashMap};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// How many tiles of extent `tile` cover an axis of extent `origin`
/// (morello's `Tiling::steps_dim`): `⌈origin / tile⌉`.
pub fn steps_dim(origin: usize, tile: usize) -> usize {
    origin.div_ceil(tile)
}

/// Extent of the final, partial tile along an axis — `origin mod tile`,
/// `0` when the tiling divides evenly (morello's `Tiling::boundary_size`).
/// The drivers execute this boundary tile explicitly at its true extent.
pub fn boundary_size(origin: usize, tile: usize) -> usize {
    origin % tile
}

/// One blocking decision for a logical `(m, k, n)` GEMM.
///
/// The blocked driver walks `nc`-wide column panels, `kc`-deep contraction
/// blocks and `mc`-tall row blocks (packing operands per block), and runs an
/// `mr`×`nr` register micro-tile innermost. Every field is already clamped
/// to the shape it was searched for.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Schedule {
    /// Row-block extent (rows of A packed per block).
    pub mc: usize,
    /// Contraction-block extent (the `f64` scratch row is re-walked once
    /// per `kc` block).
    pub kc: usize,
    /// Column-panel extent (columns of B packed per panel).
    pub nc: usize,
    /// Micro-tile rows (register accumulator height).
    pub mr: usize,
    /// Micro-tile columns (register accumulator width).
    pub nr: usize,
}

/// Register micro-tiles with specialized (const-unrolled) micro-kernels.
const MICRO: &[(usize, usize)] = &[(4, 4), (4, 8), (8, 4), (8, 8)];
/// Candidate contraction-block depths.
const KC: &[usize] = &[64, 128, 256];
/// Candidate row-block heights (multiples of every candidate `mr`).
const MC: &[usize] = &[32, 64, 128];
/// Candidate column-panel widths (multiples of every candidate `nr`).
const NC: &[usize] = &[64, 128, 256];

/// Enumerate the candidate schedules for shape `(m, k, n)`: the fixed grid,
/// clamped so no block exceeds its axis. Clamping collapses duplicates
/// (small shapes clamp many grid points to the same schedule); the
/// `BTreeSet` both dedupes and fixes the canonical search order.
pub(crate) fn candidates(m: usize, k: usize, n: usize) -> BTreeSet<Schedule> {
    let mut set = BTreeSet::new();
    for &(mr, nr) in MICRO {
        for &kc in KC {
            for &mc in MC {
                for &nc in NC {
                    set.insert(Schedule {
                        mc: mc.min(m).max(1),
                        kc: kc.min(k).max(1),
                        nc: nc.min(n).max(1),
                        mr,
                        nr,
                    });
                }
            }
        }
    }
    set
}

/// The tiny deterministic cost model, in abstract "element touch" units.
///
/// Terms: packed-operand traffic (A repacked once per `nc` sweep, B once
/// per `(kc, nc)` block), the `f64` scratch row re-walked per `kc` block,
/// and micro-kernel work — full `mr`×`nr` tiles at an efficiency that
/// rewards large register tiles, boundary tiles ([`boundary_size`] ≠ 0) at
/// a 3× penalty because they run the generic scalar micro-kernel. Blocks
/// that overflow the L1/L2/L3 working-set budgets are penalized
/// multiplicatively. Pure integer-derived `f64` arithmetic — no
/// measurement, no ambient state — so the argmin is reproducible.
pub(crate) fn model_cost(m: usize, k: usize, n: usize, s: &Schedule) -> f64 {
    let (mf, kf, nf) = (m as f64, k as f64, n as f64);
    // Packing traffic (read + write), in elements.
    let pack_a = steps_dim(n, s.nc) as f64 * mf * kf * 2.0;
    let pack_b = kf * nf * 2.0;
    // The f64 scratch row is loaded + stored once per contraction block.
    let c_traffic = 2.0 * mf * nf * steps_dim(k, s.kc) as f64;
    // Fraction of the output covered by full micro-tiles; the remainder is
    // boundary tiles of extent `boundary_size(m, mr)` / `boundary_size(n, nr)`.
    let full_m = (m - boundary_size(m, s.mr)) as f64 / mf;
    let full_n = (n - boundary_size(n, s.nr)) as f64 / nf;
    let full_frac = full_m * full_n;
    // A full tile amortizes `mr + nr` panel loads over `mr·nr` FMAs.
    let eff = (s.mr * s.nr) as f64 / (s.mr * s.nr + s.mr + s.nr) as f64;
    let flops = mf * kf * nf;
    let mut inner = flops * full_frac / eff + flops * (1.0 - full_frac) * 3.0;
    // Working-set fits: B micro-panel in L1, A block in L2, B block in L3
    // (packed panels are f64, hence the ×8).
    if s.kc * s.nr * 8 > 32 * 1024 {
        inner *= 1.5;
    }
    if s.mc * s.kc * 8 > 192 * 1024 {
        inner *= 1.5;
    }
    if s.kc * s.nc * 8 > 2 * 1024 * 1024 {
        inner *= 1.2;
    }
    inner + pack_a + pack_b + c_traffic
}

/// Deterministic schedule search for `(m, k, n)`: score every candidate,
/// return the first strict minimum in canonical order (plus its modeled
/// cost). Same inputs → bit-identical output, on any thread.
pub(crate) fn search(m: usize, k: usize, n: usize) -> (Schedule, f64) {
    let mut best: Option<(Schedule, f64)> = None;
    for s in candidates(m, k, n) {
        let c = model_cost(m, k, n, &s);
        match best {
            Some((_, bc)) if c >= bc => {}
            _ => best = Some((s, c)),
        }
    }
    best.expect("candidate grid is never empty")
}

/// One memoized search result, as reported by [`ScheduleCache::report`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleReport {
    /// Logical GEMM rows.
    pub m: usize,
    /// Logical contraction depth.
    pub k: usize,
    /// Logical GEMM columns.
    pub n: usize,
    /// The schedule the search chose (deterministic in `(m, k, n)`).
    pub schedule: Schedule,
    /// Its modeled cost (the quantity the search minimized).
    pub model_cost: f64,
    /// One-shot wall-clock of the first execution at this shape —
    /// recorded for observability (kernels_micro, drift reports), never
    /// consulted by the search. `None` until the shape first runs.
    pub measured: Option<Duration>,
}

struct Entry {
    schedule: Schedule,
    model_cost: f64,
    measured: Option<Duration>,
}

/// Memoized per-shape schedules: the kernel-level analogue of the
/// planner's per-graph cost LUTs (plan once, execute many).
///
/// The process-global instance ([`ScheduleCache::global`]) backs the
/// default fast path; tests construct fresh instances to pin search
/// determinism, and benches [`clear`](ScheduleCache::clear) the global one
/// to time the cold (search-inclusive) first step separately from the
/// warm steady state.
pub struct ScheduleCache {
    inner: Mutex<HashMap<(usize, usize, usize), Entry>>,
    /// Searches actually run (cold misses); lookups − searches = hits.
    searches: std::sync::atomic::AtomicU64,
}

impl Default for ScheduleCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleCache {
    /// An empty cache (fresh searches on first use).
    pub fn new() -> Self {
        ScheduleCache { inner: Mutex::new(HashMap::new()), searches: std::sync::atomic::AtomicU64::new(0) }
    }

    /// The process-global cache the default fast path memoizes into.
    pub fn global() -> &'static ScheduleCache {
        static GLOBAL: OnceLock<ScheduleCache> = OnceLock::new();
        GLOBAL.get_or_init(ScheduleCache::new)
    }

    /// The memoized (or freshly searched) schedule for `(m, k, n)`.
    pub fn schedule_for(&self, m: usize, k: usize, n: usize) -> Schedule {
        self.lookup(m, k, n).0
    }

    /// Like [`schedule_for`](Self::schedule_for), plus whether this call
    /// inserted the entry (the "first execution" flag the one-shot
    /// measurement keys on).
    pub(crate) fn lookup(&self, m: usize, k: usize, n: usize) -> (Schedule, bool) {
        if let Some(e) = self.inner.lock().expect("schedule cache poisoned").get(&(m, k, n)) {
            return (e.schedule, false);
        }
        // Search outside the lock: it is pure, so racing threads compute
        // the identical winner and first-insert just wins the tie.
        let (schedule, model_cost) = search(m, k, n);
        self.searches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut map = self.inner.lock().expect("schedule cache poisoned");
        let fresh = !map.contains_key(&(m, k, n));
        map.entry((m, k, n)).or_insert(Entry { schedule, model_cost, measured: None });
        (schedule, fresh)
    }

    /// Record the one-shot measurement for `(m, k, n)` (first write wins).
    pub(crate) fn record_measured(&self, m: usize, k: usize, n: usize, d: Duration) {
        let mut map = self.inner.lock().expect("schedule cache poisoned");
        if let Some(e) = map.get_mut(&(m, k, n)) {
            e.measured.get_or_insert(d);
        }
    }

    /// Memoized shape count.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("schedule cache poisoned").len()
    }

    /// True when no shape has been searched yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Searches actually executed (cache misses) since construction.
    pub fn searches(&self) -> u64 {
        self.searches.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Drop every memoized schedule (benches use this to re-time the cold,
    /// search-inclusive path on a warm process).
    pub fn clear(&self) {
        self.inner.lock().expect("schedule cache poisoned").clear();
    }

    /// Every memoized entry, sorted by shape (deterministic order).
    pub fn report(&self) -> Vec<ScheduleReport> {
        let map = self.inner.lock().expect("schedule cache poisoned");
        let mut rows: Vec<ScheduleReport> = map
            .iter()
            .map(|(&(m, k, n), e)| ScheduleReport {
                m,
                k,
                n,
                schedule: e.schedule,
                model_cost: e.model_cost,
                measured: e.measured,
            })
            .collect();
        rows.sort_by_key(|r| (r.m, r.k, r.n));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steps_and_boundary_arithmetic() {
        // 257 in tiles of 64: 5 steps, the last of extent 1.
        assert_eq!(steps_dim(257, 64), 5);
        assert_eq!(boundary_size(257, 64), 1);
        // Evenly divisible: no boundary tile.
        assert_eq!(steps_dim(256, 64), 4);
        assert_eq!(boundary_size(256, 64), 0);
        assert_eq!(steps_dim(1, 64), 1);
        assert_eq!(boundary_size(1, 64), 1);
    }

    #[test]
    fn candidates_clamp_to_shape() {
        for s in candidates(5, 3, 7) {
            assert!(s.mc <= 5 && s.kc <= 3 && s.nc <= 7, "{s:?} escapes the shape");
            assert!(s.mc >= 1 && s.kc >= 1 && s.nc >= 1);
        }
        // Large shapes keep the full grid alive.
        assert!(candidates(512, 512, 512).len() > 50);
    }

    #[test]
    fn search_is_deterministic() {
        let (a, ca) = search(300, 77, 129);
        let (b, cb) = search(300, 77, 129);
        assert_eq!(a, b);
        assert_eq!(ca.to_bits(), cb.to_bits());
    }

    #[test]
    fn cache_memoizes_and_counts() {
        let c = ScheduleCache::new();
        assert!(c.is_empty());
        let (s1, fresh1) = c.lookup(64, 64, 64);
        let (s2, fresh2) = c.lookup(64, 64, 64);
        assert_eq!(s1, s2);
        assert!(fresh1 && !fresh2);
        assert_eq!((c.len(), c.searches()), (1, 1));
        c.record_measured(64, 64, 64, Duration::from_micros(5));
        c.record_measured(64, 64, 64, Duration::from_micros(9));
        assert_eq!(c.report()[0].measured, Some(Duration::from_micros(5)), "first write wins");
        c.clear();
        assert!(c.is_empty());
    }
}
