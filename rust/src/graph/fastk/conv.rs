//! Convolution lowered to im2col-GEMM (forward, backward-data,
//! backward-filter), riding the blocked [`super::gemm`] drivers.
//!
//! Layouts are the graph's NHWC ⊛ HWIO: the filter's stored
//! `[KH, KW, Cin, Cout]` buffer *is* row-major `[KH·KW·Cin, Cout]`, and an
//! im2col row for output site `(n, oi, oj)` enumerates `(a, b, ci)` in
//! exactly the order the naive kernel's window loops accumulate — so each
//! output element's contraction keeps the oracle's sequential order (the
//! tolerance argument in docs/kernels.md §Tolerance). Out-of-bounds window
//! taps pack as explicit zeros, which contribute exact `+0` terms.
//!
//! Rows are processed in bounded blocks ([`row_block`]) so the packed
//! im2col scratch stays cache-friendly and memory-bounded on large
//! activations; blocks ascend in row order, preserving the global
//! accumulation order for backward-filter's carried `f64` accumulator and
//! backward-data's scatter-add.

use super::gemm::{gemm_f64, gemm_into, MatRef};
use super::schedule::ScheduleCache;
use crate::graph::kernels::View;

/// Cap on `rows × k2` elements materialized per im2col block (~8 MB of
/// `f32`); at least one row always proceeds.
fn row_block(k2: usize) -> usize {
    (2 * 1024 * 1024 / k2.max(1)).max(1)
}

/// Geometry of one lowering: input plane, window, output plane.
struct ConvGeom {
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    kh: usize,
    kw: usize,
    cout: usize,
    oh: usize,
    ow: usize,
    stride: usize,
    pad: usize,
}

impl ConvGeom {
    /// im2col row width: one entry per `(a, b, ci)` window tap.
    fn k2(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Total output sites = im2col row count.
    fn rows(&self) -> usize {
        self.n * self.oh * self.ow
    }

    /// Decompose a global row index into its `(n, oi, oj)` output site.
    fn site(&self, row: usize) -> (usize, usize, usize) {
        (row / (self.oh * self.ow), row % (self.oh * self.ow) / self.ow, row % self.ow)
    }

    /// The input tap for window offset `(a, b)` at output site `(oi, oj)`,
    /// or `None` when it falls in the padding (same predicate as the naive
    /// kernel's bounds skip).
    fn tap(&self, oi: usize, oj: usize, a: usize, b: usize) -> Option<(usize, usize)> {
        let ih = oi * self.stride + a;
        let iw = oj * self.stride + b;
        if ih < self.pad || ih - self.pad >= self.h || iw < self.pad || iw - self.pad >= self.w {
            None
        } else {
            Some((ih - self.pad, iw - self.pad))
        }
    }
}

/// Materialize im2col rows `[start, start+rows)` of `x` into `buf`
/// (`rows × k2`, zero-filled where the window leaves the input).
fn im2col(x: &[f32], g: &ConvGeom, start: usize, rows: usize, buf: &mut Vec<f32>) {
    let k2 = g.k2();
    buf.clear();
    buf.resize(rows * k2, 0.0);
    for r in 0..rows {
        let (ni, oi, oj) = g.site(start + r);
        for a in 0..g.kh {
            for b in 0..g.kw {
                if let Some((ih, iw)) = g.tap(oi, oj, a, b) {
                    let src = ((ni * g.h + ih) * g.w + iw) * g.cin;
                    let dst = r * k2 + (a * g.kw + b) * g.cin;
                    buf[dst..dst + g.cin].copy_from_slice(&x[src..src + g.cin]);
                }
            }
        }
    }
}

/// Forward conv: `out[row, co] = im2col(x)[row, ·] · w[·, co]`, blocked
/// over rows. The GEMM output layout is already NHWC.
pub(crate) fn conv2d(x: &View<'_>, w: &View<'_>, out_shape: &[usize], stride: usize, pad: usize, cache: &ScheduleCache) -> Vec<f32> {
    let g = ConvGeom {
        n: x.shape[0],
        h: x.shape[1],
        w: x.shape[2],
        cin: x.shape[3],
        kh: w.shape[0],
        kw: w.shape[1],
        cout: w.shape[3],
        oh: out_shape[1],
        ow: out_shape[2],
        stride,
        pad,
    };
    let k2 = g.k2();
    let wmat = MatRef { data: w.data, rows: k2, cols: g.cout, trans: false };
    let mut out = Vec::with_capacity(g.rows() * g.cout);
    let mut xcol = Vec::new();
    let mut start = 0;
    while start < g.rows() {
        let rows = row_block(k2).min(g.rows() - start);
        im2col(x.data, &g, start, rows, &mut xcol);
        let a = MatRef { data: &xcol, rows, cols: k2, trans: false };
        out.extend(gemm_f64(&a, &wmat, cache).into_iter().map(|v| v as f32));
        start += rows;
    }
    out
}

/// Backward-data: `dcol = dz · wᵀ` (kept in `f64`), then col2im
/// scatter-add into an `f64` image accumulator, rounded once. Both the
/// GEMM contraction (over `co`) and the scatter order match the naive
/// kernel's loops exactly.
pub(crate) fn conv2d_bwd_data(
    dz: &View<'_>,
    w: &View<'_>,
    out_shape: &[usize],
    stride: usize,
    pad: usize,
    cache: &ScheduleCache,
) -> Vec<f32> {
    let g = ConvGeom {
        n: dz.shape[0],
        h: out_shape[1],
        w: out_shape[2],
        cin: w.shape[2],
        kh: w.shape[0],
        kw: w.shape[1],
        cout: dz.shape[3],
        oh: dz.shape[1],
        ow: dz.shape[2],
        stride,
        pad,
    };
    let k2 = g.k2();
    // wᵀ: logical [Cout, K2] over the stored [K2, Cout] buffer.
    let wmat = MatRef { data: w.data, rows: k2, cols: g.cout, trans: true };
    let mut dx64 = vec![0.0f64; g.n * g.h * g.w * g.cin];
    let mut start = 0;
    while start < g.rows() {
        let rows = row_block(k2).min(g.rows() - start);
        let dzb = MatRef { data: &dz.data[start * g.cout..(start + rows) * g.cout], rows, cols: g.cout, trans: false };
        let dcol = gemm_f64(&dzb, &wmat, cache);
        for r in 0..rows {
            let (ni, oi, oj) = g.site(start + r);
            for a in 0..g.kh {
                for b in 0..g.kw {
                    if let Some((ih, iw)) = g.tap(oi, oj, a, b) {
                        let src = r * k2 + (a * g.kw + b) * g.cin;
                        let dst = ((ni * g.h + ih) * g.w + iw) * g.cin;
                        for ci in 0..g.cin {
                            dx64[dst + ci] += dcol[src + ci];
                        }
                    }
                }
            }
        }
        start += rows;
    }
    dx64.into_iter().map(|v| v as f32).collect()
}

/// Backward-filter: `dw = im2col(x)ᵀ · dz`, contracting over output sites
/// in ascending row order. Row blocks carry the `f64` accumulator through
/// [`gemm_into`], so the whole contraction rounds to `f32` exactly once.
pub(crate) fn conv2d_bwd_filter(
    x: &View<'_>,
    dz: &View<'_>,
    out_shape: &[usize],
    stride: usize,
    pad: usize,
    cache: &ScheduleCache,
) -> Vec<f32> {
    let g = ConvGeom {
        n: x.shape[0],
        h: x.shape[1],
        w: x.shape[2],
        cin: x.shape[3],
        kh: out_shape[0],
        kw: out_shape[1],
        cout: dz.shape[3],
        oh: dz.shape[1],
        ow: dz.shape[2],
        stride,
        pad,
    };
    let k2 = g.k2();
    let mut dw64 = vec![0.0f64; k2 * g.cout];
    let mut xcol = Vec::new();
    let mut start = 0;
    while start < g.rows() {
        let rows = row_block(k2).min(g.rows() - start);
        im2col(x.data, &g, start, rows, &mut xcol);
        // xcolᵀ: logical [K2, rows] over the stored [rows, K2] block.
        let a = MatRef { data: &xcol, rows, cols: k2, trans: true };
        let dzb = MatRef { data: &dz.data[start * g.cout..(start + rows) * g.cout], rows, cols: g.cout, trans: false };
        let sched = cache.schedule_for(k2, rows, g.cout);
        gemm_into(&mut dw64, &a, &dzb, &sched);
        start += rows;
    }
    dw64.into_iter().map(|v| v as f32).collect()
}
