//! Operator kinds of the training dataflow graph.
//!
//! The set covers everything the paper's evaluation needs: dense matmul
//! (MLP layers and their backward passes), 2-D convolution with its two
//! backward operators (CNN/AlexNet/VGG), elementwise activation functions,
//! bias broadcast/reduction, softmax cross-entropy, and the SGD update.

use super::TensorId;

/// Dense index of an op within its graph.
pub type OpId = usize;

/// Elementwise operator flavors (same shape in, same shape out).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    Relu,
    /// `relu_grad(dy, y)` — mask the upstream gradient by `y > 0`.
    ReluGrad,
    Add,
    Mul,
}

/// Operator kinds. Shape legality is enforced by the [`GraphBuilder`];
/// tiling semantics (aligned tilings, communication costs) are derived from
/// these in `tiling::aligned`.
///
/// [`GraphBuilder`]: super::GraphBuilder
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// `Z = op(A) · op(B)` where `op` is optional transposition. The
    /// backward multiplications of §2.1 (`dx = dy Wᵀ`, `dW = xᵀ dy`) are
    /// expressed with the transpose flags, so the *stored* tensors keep a
    /// single tiling while the planner reasons in logical row/col space.
    MatMul { ta: bool, tb: bool },

    /// NHWC ⊛ HWIO forward convolution.
    Conv2d { stride: usize, pad: usize },
    /// Gradient w.r.t. the input activations: `dX = dZ ⊛ rot180(W)`.
    Conv2dBwdData { stride: usize, pad: usize },
    /// Gradient w.r.t. the filter: `dW = Xᵀ ⊛ dZ`.
    Conv2dBwdFilter { stride: usize, pad: usize },

    /// Elementwise map over identically-shaped operands.
    Ew(EwKind),

    /// 2×2 max pooling with stride 2 over NHWC (AlexNet/VGG downsampling).
    Pool2,
    /// Gradient of [`OpKind::Pool2`]: routes `dz` back to the pre-pool shape.
    Pool2Bwd,
    /// `[N, H, W, C] -> [N, H·W·C]` (conv stack to FC head).
    Flatten,
    /// Gradient of [`OpKind::Flatten`].
    FlattenBwd,

    /// `x[M, N] + b[N]` with broadcast over rows.
    BiasAdd,
    /// Column sums: `x[M, N] -> [N]` (the bias gradient).
    ReduceSumRows,

    /// Mean softmax cross-entropy: `(logits[M, C], onehot[M, C]) -> scalar`.
    /// Row-wise: may only be partitioned along the batch dimension.
    SoftmaxXent,
    /// Its gradient w.r.t. logits: `(logits, onehot) -> [M, C]`, row-wise.
    SoftmaxXentGrad,

    /// `w' = w - lr * g`. The learning rate is a scalar attribute (not a
    /// graph tensor) so the tiling problem sees exactly the paper's graph.
    SgdUpdate,
}

impl OpKind {
    /// True for the three matmul-shaped operators (Eq. 2 applies directly).
    pub fn is_matmul_like(&self) -> bool {
        matches!(
            self,
            OpKind::MatMul { .. }
                | OpKind::Conv2d { .. }
                | OpKind::Conv2dBwdData { .. }
                | OpKind::Conv2dBwdFilter { .. }
        )
    }

    /// True for operators that the paper restricts to batch-dimension
    /// partitioning (§4.5 "all other operators").
    pub fn batch_only(&self) -> bool {
        matches!(self, OpKind::SoftmaxXent | OpKind::SoftmaxXentGrad)
    }
}

/// One operator instance: kind + operand/result tensor ids.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
    /// Debug label, e.g. `"fc1.fwd"` or `"conv3.bwd_filter"`.
    pub name: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification() {
        assert!(OpKind::MatMul { ta: false, tb: false }.is_matmul_like());
        assert!(OpKind::Conv2dBwdFilter { stride: 1, pad: 0 }.is_matmul_like());
        assert!(!OpKind::BiasAdd.is_matmul_like());
        assert!(OpKind::SoftmaxXent.batch_only());
        assert!(!OpKind::Ew(EwKind::Relu).batch_only());
    }
}
