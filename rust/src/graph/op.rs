//! Operator kinds of the training dataflow graph.
//!
//! The set covers everything the paper's evaluation needs: dense matmul
//! (MLP layers and their backward passes), 2-D convolution with its two
//! backward operators (CNN/AlexNet/VGG), elementwise activation functions,
//! bias broadcast/reduction, softmax cross-entropy, and the SGD update —
//! plus the transformer-encoder vocabulary added after the paper's 2018
//! evaluation set: layer normalization, GeLU, batched matmul (QKᵀ and
//! attention·V, whose leading batch/head axis tiles like a data axis), row
//! softmax, and the head-split/merge reshapes between the folded
//! `[B·S, D]` activations and the `[B·H, S, D/H]` attention view.

use super::TensorId;

/// Dense index of an op within its graph.
pub type OpId = usize;

/// Elementwise operator flavors (same shape in, same shape out).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    /// `max(x, 0)`.
    Relu,
    /// `relu_grad(dy, y)` — mask the upstream gradient by `y > 0`.
    ReluGrad,
    /// Elementwise sum (residual adds, gradient accumulation).
    Add,
    /// Elementwise product.
    Mul,
    /// Tanh-approximation GeLU (the transformer FF activation).
    Gelu,
    /// `gelu_grad(dy, x)` — needs the *pre-activation* input, unlike ReLU.
    GeluGrad,
    /// Identity wire. Semantically a no-op (free when input and output
    /// share a tiling); inserted by the transformer builder on residual
    /// skip paths so the undirected BFS levelization (§4.2.2) sees a
    /// layered chain instead of collapsing a whole block into one level —
    /// see DESIGN.md §Transformer.
    Ident,
}

/// Operator kinds. Shape legality is enforced by the
/// [`GraphBuilder`](super::GraphBuilder);
/// tiling semantics (aligned tilings, communication costs) are derived from
/// these in `tiling::aligned`.
///
/// [`GraphBuilder`]: super::GraphBuilder
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// `Z = op(A) · op(B)` where `op` is optional transposition. The
    /// backward multiplications of §2.1 (`dx = dy Wᵀ`, `dW = xᵀ dy`) are
    /// expressed with the transpose flags, so the *stored* tensors keep a
    /// single tiling while the planner reasons in logical row/col space.
    MatMul { ta: bool, tb: bool },

    /// NHWC ⊛ HWIO forward convolution.
    Conv2d { stride: usize, pad: usize },
    /// Gradient w.r.t. the input activations: `dX = dZ ⊛ rot180(W)`.
    Conv2dBwdData { stride: usize, pad: usize },
    /// Gradient w.r.t. the filter: `dW = Xᵀ ⊛ dZ`.
    Conv2dBwdFilter { stride: usize, pad: usize },

    /// Elementwise map over identically-shaped operands.
    Ew(EwKind),

    /// 2×2 max pooling with stride 2 over NHWC (AlexNet/VGG downsampling).
    Pool2,
    /// Gradient of [`OpKind::Pool2`]: routes `dz` back to the pre-pool shape.
    Pool2Bwd,
    /// `[N, H, W, C] -> [N, H·W·C]` (conv stack to FC head).
    Flatten,
    /// Gradient of [`OpKind::Flatten`].
    FlattenBwd,

    /// `x[M, N] + b[N]` with broadcast over rows.
    BiasAdd,
    /// Column sums: `x[M, N] -> [N]` (the bias gradient).
    ReduceSumRows,

    /// Mean softmax cross-entropy: `(logits[M, C], onehot[M, C]) -> scalar`.
    /// Row-wise: may only be partitioned along the batch dimension.
    SoftmaxXent,
    /// Its gradient w.r.t. logits: `(logits, onehot) -> [M, C]`, row-wise.
    SoftmaxXentGrad,

    /// `w' = w - lr * g`. The learning rate is a scalar attribute (not a
    /// graph tensor) so the tiling problem sees exactly the paper's graph.
    SgdUpdate,

    // -- transformer operators (post-paper workload class) ------------------
    /// Batched matmul `Z[g] = op(A[g]) · op(B[g])` over a shared leading
    /// batch/head axis. Both operands are rank 3; the batch axis tiles like
    /// a data axis (splitting it is the free, data-parallel aligned form),
    /// and the per-matrix row/col/contraction splits generalize Figure 6.
    BatchedMatMul { ta: bool, tb: bool },

    /// `y = (x - mean(x)) / std(x) * gamma + beta` row-wise over `[M, N]`
    /// with `gamma`/`beta` of shape `[N]`. The mean/variance reduce along
    /// the row (non-batch) axis, so only batch splits avoid cross-device
    /// reduction — like `SoftmaxXent`, a row-wise op (§4.5).
    LayerNorm,
    /// `dx = ln_grad(dy, x, gamma)` — same row-wise restriction.
    LayerNormGrad,
    /// `dgamma = Σ_rows dy ⊙ x̂` — a two-input column reduction shaped
    /// like [`OpKind::ReduceSumRows`] (`dbeta` reuses `ReduceSumRows`),
    /// except that `x` must stay whole-row under a feature split: x̂'s
    /// per-row statistics are recomputed from `x` inside the kernel.
    LayerNormGammaGrad,

    /// Row softmax over the *last* axis of a rank-2/3 tensor (attention
    /// probabilities). Any axis but the normalization axis may split.
    Softmax,
    /// `dx = y ⊙ (dy - rowsum(dy ⊙ y))` — inputs `(dy, y)`, same
    /// splittability as [`OpKind::Softmax`].
    SoftmaxGrad,

    /// `[B·S, D] -> [B·H, S, D/H]` head split (batch-major on both sides:
    /// halving rows of the input is halving the batch, which is halving
    /// the leading axis of the output — the one tiling the two views
    /// share, and the only split this op admits). Output shapes are fixed
    /// at build time; `heads` is carried for the autodiff inverse.
    SplitHeads { heads: usize },
    /// `[B·H, S, D/H] -> [B·S, D]` — inverse of [`OpKind::SplitHeads`].
    MergeHeads { heads: usize },

    /// Slice one of Q/K/V out of a fused `[B·S, 3·D]` projection directly
    /// into the `[B·H, S, D/H]` attention view. Fusing the three
    /// projections into one matmul keeps the one-cut DP's per-level
    /// boundary narrow (DESIGN.md §Transformer); `part` selects q=0, k=1,
    /// v=2.
    QkvSlice { part: usize },
    /// Gradient counterpart: concatenate `(dq, dk, dv)` head views back
    /// into the fused `[B·S, 3·D]` gradient.
    QkvConcat,
}

impl OpKind {
    /// True for the three matmul-shaped operators (Eq. 2 applies directly).
    pub fn is_matmul_like(&self) -> bool {
        matches!(
            self,
            OpKind::MatMul { .. }
                | OpKind::Conv2d { .. }
                | OpKind::Conv2dBwdData { .. }
                | OpKind::Conv2dBwdFilter { .. }
        )
    }

    /// True for operators restricted to batch-dimension partitioning: the
    /// paper's row-wise losses (§4.5 "all other operators") plus the
    /// transformer ops whose only aligned split is the batch/head axis
    /// (layer norm's row-wise statistics, the head-view reshapes).
    pub fn batch_only(&self) -> bool {
        matches!(
            self,
            OpKind::SoftmaxXent
                | OpKind::SoftmaxXentGrad
                | OpKind::LayerNorm
                | OpKind::LayerNormGrad
                | OpKind::SplitHeads { .. }
                | OpKind::MergeHeads { .. }
                | OpKind::QkvSlice { .. }
                | OpKind::QkvConcat
        )
    }
}

/// One operator instance: kind + operand/result tensor ids.
#[derive(Debug, Clone)]
pub struct Op {
    /// Dense index of this op within its graph.
    pub id: OpId,
    /// What the op computes (drives aligned forms and FLOP counts).
    pub kind: OpKind,
    /// Operand tensor ids, in the op kind's fixed order.
    pub inputs: Vec<TensorId>,
    /// Result tensor ids (exactly one for every current op kind).
    pub outputs: Vec<TensorId>,
    /// Debug label, e.g. `"fc1.fwd"` or `"conv3.bwd_filter"`.
    pub name: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification() {
        assert!(OpKind::MatMul { ta: false, tb: false }.is_matmul_like());
        assert!(OpKind::Conv2dBwdFilter { stride: 1, pad: 0 }.is_matmul_like());
        assert!(!OpKind::BiasAdd.is_matmul_like());
        assert!(OpKind::SoftmaxXent.batch_only());
        assert!(!OpKind::Ew(EwKind::Relu).batch_only());
    }

    #[test]
    fn transformer_classification() {
        // Batched matmul is grid-shaped (its batch form subsumes Fig. 6),
        // not one of the three §4 matmul operators.
        assert!(!OpKind::BatchedMatMul { ta: false, tb: true }.is_matmul_like());
        assert!(OpKind::LayerNorm.batch_only());
        assert!(OpKind::SplitHeads { heads: 4 }.batch_only());
        assert!(OpKind::QkvSlice { part: 1 }.batch_only());
        // Row softmax over rank-3 scores may split batch *and* query rows.
        assert!(!OpKind::Softmax.batch_only());
        assert!(!OpKind::Ew(EwKind::Gelu).batch_only());
    }
}
